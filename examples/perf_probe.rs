// quick probe: simulator + scheduler wall-clock on the heaviest workloads
use std::time::Instant;
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::baselines::naive_byoc::compile_naive;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::relay::import::from_quantized;
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::scheduler::sweep::{sweep, SweepOptions};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;
use tvm_accel::workload::Gemm;

fn main() {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let mut rng = Rng::new(1);
    let size = 512usize;
    let l = FloatDense {
        weight: (0..size*size).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
        bias: (0..size).map(|_| 0.0).collect(),
        in_dim: size, out_dim: size, relu: false,
    };
    let model = from_quantized(size, 0.04, &quantize_mlp(&[l], &[0.04, 0.05]).unwrap());
    let x = rng.i8_vec(size*size);

    let t0 = Instant::now();
    let nb = compile_naive(&accel, &model).unwrap();
    let t_compile_naive = t0.elapsed();
    let items = nb.program.items.len();
    let t0 = Instant::now();
    let (_, rep) = nb.run(&sim, &x).unwrap();
    let t_sim = t0.elapsed();
    println!("naive 512^3: compile {:?}, sim {:?} for {} items ({} sim-cycles) => {:.1} Mitems/s",
        t_compile_naive, t_sim, items, rep.cycles, items as f64 / t_sim.as_secs_f64() / 1e6);

    let ct = compile_c_toolchain(&accel, &model).unwrap();
    let t0 = Instant::now();
    let (_, repc) = ct.run(&sim, &x).unwrap();
    println!("c-toolchain 512^3: sim {:?} ({} cycles)", t0.elapsed(), repc.cycles);

    let t0 = Instant::now();
    let r = sweep(&accel.arch, Gemm::new(512,512,512), &SweepOptions::default());
    println!("sweep 512^3: {:?} ({} candidates)", t0.elapsed(), r.candidates.len());
    let t0 = Instant::now();
    let r2 = sweep(&accel.arch, Gemm::new(1,640,128), &SweepOptions::default());
    println!("sweep toycar-layer: {:?} ({} candidates)", t0.elapsed(), r2.candidates.len());
}
