//! Quickstart: integrate Gemmini with the functional-description API
//! (paper Fig. 3), compile a small quantized MLP, and run it on the
//! cycle-level simulator.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::obs::describe;
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

fn main() -> Result<()> {
    // 1. The accelerator model: functional description (Fig. 3) plus the
    //    architectural description (configs/gemmini.yaml equivalent).
    let accel = gemmini_desc()?;
    println!("accelerator: {} (PE {}x{})", accel.name, accel.arch.pe_dim, accel.arch.pe_dim);
    println!("supported relay ops: {:?}", accel.supported_ops());

    // 2. A quantized 3-layer MLP (what a TFLite import would give us).
    let mut rng = Rng::new(42);
    let dims = [64usize, 96, 32, 10];
    let layers: Vec<FloatDense> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < dims.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..dims.len()).map(|i| 0.03 + 0.01 * i as f32).collect();
    let model = from_quantized(8, scales[0], &quantize_mlp(&layers, &scales)?);
    let graph = to_qnn_graph(&model)?;
    println!("\nimported QNN graph:\n{}", graph.dump());

    // 3. Compile through the staged session: frontend configurator ->
    //    partition -> extended CoSA (cache + parallel sweep) -> mapping
    //    generator -> codegen -> link, with per-stage timings.
    let compiler = Compiler::new(accel.clone());
    let session = compiler.compile_with_report(&graph)?;
    println!("pipeline stages:\n{}", session.render_stages());
    let deployment = &session.deployment;
    println!("chosen schedules:");
    for (name, sched, cycles) in &deployment.chosen {
        println!("  {name}: {sched}");
        if let Some(c) = cycles {
            println!("    profiled: {c} cycles");
        }
    }

    // Recompiling reuses every schedule from the compiler's cache.
    compiler.compile(&graph)?;
    let cache = compiler.cache_stats();
    println!(
        "\nrecompile: {} sweeps total, cache {} hits / {} entries",
        compiler.sweeps_run(),
        cache.hits,
        cache.entries
    );

    // 4. Run a batch on the cycle-level simulator (constants staged once).
    let sim = Simulator::new(&accel.arch);
    let inputs: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(8 * dims[0])).collect();
    let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
    let batch = deployment.run_batch(&sim, &refs)?;
    println!("\n{}", describe("inference", &batch.reports[0], accel.arch.pe_dim));
    println!(
        "batch of {}: first 10 outputs of run 0: {:?}",
        batch.outputs.len(),
        &batch.outputs[0][..10]
    );
    println!(
        "batch timing: {} cycles serial, {} pipelined",
        batch.serial_cycles, batch.pipelined_cycles
    );
    Ok(())
}
