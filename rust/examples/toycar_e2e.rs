//! End-to-end driver (paper §4, Table 2 ToyCar row): load the MLPerf-Tiny
//! ToyCar autoencoder built by `make artifacts`, compile it with all three
//! backends (proposed, Gemmini C toolchain, naive BYOC/UMA), run batched
//! inferences on the cycle-level simulator, verify every output
//! element-exactly against the XLA golden model (the JAX + Pallas
//! computation loaded via PJRT), and report latency/throughput.
//!
//! This is the proof that all layers compose:
//!   Pallas kernel -> JAX model -> HLO text -> PJRT (golden)
//!   .qmodel -> relay import -> legalize/fold/partition -> CoSA ->
//!   mapping generator -> codegen -> ISA -> simulator == golden.
//!
//! Run with: `make artifacts && cargo run --release --example toycar_e2e`

use anyhow::{ensure, Context, Result};
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::{compile_naive, import_with_weight_chain};
use tvm_accel::obs::{describe, table2, LatencyRow};
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::load_qmodel;
use tvm_accel::runtime::{artifacts_dir, golden_inputs, Runtime};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

const INFERENCES: usize = 200;

fn main() -> Result<()> {
    let accel = gemmini_desc()?;
    let sim = Simulator::new(&accel.arch);
    let dir = artifacts_dir();

    // --- Load model + golden reference -----------------------------------
    let model = load_qmodel(&dir.join("toycar.qmodel"))
        .context("run `make artifacts` first")?;
    println!(
        "ToyCar autoencoder: {} dense layers, input {}",
        model.layers.len(),
        model.layers[0].in_dim
    );
    let rt = Runtime::cpu()?;
    let golden = rt.load_hlo_text(&dir.join("toycar.hlo.txt"))?;
    println!("golden model loaded via PJRT ({})", rt.platform());

    // --- Compile with the three backends ----------------------------------
    let graph = import_with_weight_chain(&model)?;
    let proposed = Compiler::new(accel.clone()).compile(&graph)?;
    println!("\nproposed backend — chosen schedules:");
    for (name, s, cyc) in &proposed.chosen {
        println!("  {name}: {s} (profiled {:?})", cyc);
    }
    let c_tool = compile_c_toolchain(&accel, &model)?;
    let naive = compile_naive(&accel, &model)?;

    // --- Run batched inferences, golden-checking every output -------------
    // `run_batch` stages each deployment's constants once for the whole
    // batch instead of once per inference.
    let mut rng = Rng::new(2026);
    let inputs: Vec<Vec<i8>> =
        (0..INFERENCES).map(|_| rng.i8_vec(model.batch * model.layers[0].in_dim)).collect();
    let input_refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();

    let batch_p = proposed.run_batch(&sim, &input_refs)?;
    let batch_c = c_tool.run_batch(&sim, &input_refs)?;
    let batch_n = naive.run_batch(&sim, &input_refs)?;
    let (outs_p, reps_p) = (&batch_p.outputs, &batch_p.reports);
    let (outs_c, reps_c) = (&batch_c.outputs, &batch_c.reports);
    let (outs_n, reps_n) = (&batch_n.outputs, &batch_n.reports);

    let mut rows = [0u64; 3];
    let mut total_macs = 0u64;
    for i in 0..INFERENCES {
        let want = golden.run(&golden_inputs(&model, &inputs[i])?)?.to_vec::<i8>()?;
        ensure!(outs_p[i] == want, "inference {i}: proposed != golden");
        ensure!(outs_c[i] == want, "inference {i}: c-toolchain != golden");
        ensure!(outs_n[i] == want, "inference {i}: naive BYOC != golden");

        rows[0] += reps_c[i].cycles;
        rows[1] += reps_p[i].cycles;
        rows[2] += reps_n[i].cycles;
        total_macs += reps_p[i].macs;
        if i == 0 {
            println!("\nper-inference reports (first inference):");
            println!("  {}", describe("c-toolchain", &reps_c[i], accel.arch.pe_dim));
            println!("  {}", describe("proposed   ", &reps_p[i], accel.arch.pe_dim));
            println!("  {}", describe("naive BYOC ", &reps_n[i], accel.arch.pe_dim));
        }
    }
    println!(
        "\nall {INFERENCES} inferences verified element-exactly against the XLA golden model ✔"
    );

    // --- Report ------------------------------------------------------------
    let t = table2(&[LatencyRow {
        workload: "ToyCar".into(),
        c_toolchain: rows[0] / INFERENCES as u64,
        proposed: rows[1] / INFERENCES as u64,
        byoc_uma: rows[2] / INFERENCES as u64,
    }]);
    println!("\n{}", t.render());
    // Throughput at the 1 GHz clock the cycle counts imply.
    let s_per_inf = rows[1] as f64 / INFERENCES as f64 / 1e9;
    println!(
        "proposed throughput @1GHz: {:.0} inferences/s ({} MACs/inference)",
        1.0 / s_per_inf,
        total_macs / INFERENCES as u64
    );
    Ok(())
}
