//! Cross-layer scheduling before/after: compile the ToyCar dense stack
//! with the graph-level residency pass off and on, run both deployments
//! on the same inputs, and print the cycle / DRAM-traffic comparison
//! (the numbers quoted in the README's "Cross-layer scheduling" section).
//!
//! Run with: `cargo run --release --example cross_layer`

use anyhow::Result;
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::pipeline::{CompileOptions, Compiler};
use tvm_accel::relay::import::{synth_qmodel, to_qnn_graph};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;
use tvm_accel::util::table::commafy;

fn main() -> Result<()> {
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let graph = to_qnn_graph(&synth_qmodel(2024, &widths, 1)?)?;
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);

    // Per-layer baseline: every boundary round-trips DRAM.
    let baseline_opts = CompileOptions { cross_layer: false, ..Default::default() };
    let baseline = Compiler::with_options(accel.clone(), baseline_opts).compile(&graph)?;

    // Graph-aware: adjacent layers keep activations resident on-chip.
    let resident = Compiler::new(accel.clone()).compile_with_report(&graph)?;
    println!("cross-layer stage report:");
    for s in resident.stages.iter().filter(|s| s.name == "crosslayer") {
        for note in &s.notes {
            println!("  {note}");
        }
    }
    println!(
        "\n{} of {} layer boundaries resident",
        resident.schedule_stats.resident_edges,
        widths.len() - 2
    );

    let mut rng = Rng::new(7);
    let x = rng.i8_vec(widths[0]);
    let (out_b, rep_b) = baseline.run(&sim, &x)?;
    let (out_r, rep_r) = resident.deployment.run(&sim, &x)?;
    assert_eq!(out_b, out_r, "outputs must be element-exact");

    println!("\nToyCar (batch 1), per-layer baseline vs cross-layer resident:");
    for (name, b, r) in [
        ("total cycles", rep_b.cycles, rep_r.cycles),
        ("DRAM-transfer cycles", rep_b.dram_transfer_cycles, rep_r.dram_transfer_cycles),
        ("DRAM bytes read", rep_b.dram_read_bytes, rep_r.dram_read_bytes),
        ("DRAM bytes written", rep_b.dram_write_bytes, rep_r.dram_write_bytes),
    ] {
        println!(
            "  {name:<22} {:>12} -> {:>12}  ({:+.1}%)",
            commafy(b),
            commafy(r),
            100.0 * (r as f64 - b as f64) / b as f64
        );
    }
    assert!(
        rep_r.dram_transfer_cycles < rep_b.dram_transfer_cycles,
        "resident deployment must move strictly less data"
    );
    println!("\noutputs element-exact, DRAM traffic strictly lower ✔");
    Ok(())
}
