// quick probe: simulator + scheduler wall-clock on the heaviest workloads
use std::time::Instant;
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::baselines::naive_byoc::compile_naive;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::relay::import::from_quantized;
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::scheduler::sweep::{sweep, SweepOptions};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;
use tvm_accel::workload::Gemm;

fn main() {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let mut rng = Rng::new(1);
    let size = 512usize;
    let l = FloatDense {
        weight: (0..size*size).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
        bias: (0..size).map(|_| 0.0).collect(),
        in_dim: size, out_dim: size, relu: false,
    };
    let model = from_quantized(size, 0.04, &quantize_mlp(&[l], &[0.04, 0.05]).unwrap());
    let x = rng.i8_vec(size*size);

    let t0 = Instant::now();
    let nb = compile_naive(&accel, &model).unwrap();
    let t_compile_naive = t0.elapsed();
    let items = nb.program.items.len();
    let t0 = Instant::now();
    let (_, rep) = nb.run(&sim, &x).unwrap();
    let t_sim = t0.elapsed();
    println!("naive 512^3: compile {:?}, sim {:?} for {} items ({} sim-cycles) => {:.1} Mitems/s",
        t_compile_naive, t_sim, items, rep.cycles, items as f64 / t_sim.as_secs_f64() / 1e6);

    let ct = compile_c_toolchain(&accel, &model).unwrap();
    let t0 = Instant::now();
    let (_, repc) = ct.run(&sim, &x).unwrap();
    println!("c-toolchain 512^3: sim {:?} ({} cycles)", t0.elapsed(), repc.cycles);

    let shapes = [
        ("512^3", Gemm::new(512, 512, 512)),
        ("toycar-layer", Gemm::new(1, 640, 128)),
    ];
    for (name, g) in shapes {
        let t0 = Instant::now();
        let serial =
            sweep(&accel.arch, g, &SweepOptions { parallel: false, ..Default::default() });
        let t_serial = t0.elapsed();
        let t0 = Instant::now();
        let parallel = sweep(&accel.arch, g, &SweepOptions::default());
        let t_parallel = t0.elapsed();
        assert_eq!(serial.candidates, parallel.candidates);
        println!(
            "sweep {name}: serial {t_serial:?} vs parallel {t_parallel:?} \
             ({} candidates, identical)",
            parallel.candidates.len()
        );
    }

    // Schedule cache: the second compile of the same model runs no sweeps.
    let compiler = tvm_accel::pipeline::Compiler::new(accel.clone());
    let graph = tvm_accel::relay::import::to_qnn_graph(&model).unwrap();
    let t0 = Instant::now();
    compiler.compile(&graph).unwrap();
    let cold = t0.elapsed();
    let t0 = Instant::now();
    compiler.compile(&graph).unwrap();
    let warm = t0.elapsed();
    println!(
        "compile 512^3 dense: cold {cold:?} vs cached {warm:?} ({} sweeps total)",
        compiler.sweeps_run()
    );
}
