//! Heterogeneous deployment: one compile, several accelerators.
//!
//! Loads *two* architectural descriptions — `configs/gemmini.yaml` (16×16
//! weight-stationary) and `configs/bigarray_os.yaml` (32×32
//! output-stationary) — gives both the same ~60-line functional
//! description, and compiles a ToyCar-width dense stack against the pair
//! in a single session. The partition stage probes every layer on each
//! candidate through the shared schedule cache and places it on the
//! target with the lowest profiled cycle cost; the per-stage report lists
//! the choice and its cost per layer. The linked `MultiDeployment` drives
//! both instruction streams over one shared DRAM image, and the result is
//! checked element-exactly against the graph interpreter.
//!
//! Run with: `cargo run --release --example heterogeneous`

use std::collections::BTreeMap;

use anyhow::Result;
use tvm_accel::accel::gemmini::desc_for_arch;
use tvm_accel::arch::parse::arch_from_file;
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::relay::{Tensor, TensorData};
use tvm_accel::util::prng::Rng;
use tvm_accel::util::table::commafy;

fn main() -> Result<()> {
    // 1. Two accelerator models from their YAML architectural
    //    descriptions; the functional description transfers unchanged.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut targets = Vec::new();
    for file in ["gemmini.yaml", "bigarray_os.yaml"] {
        let arch = arch_from_file(&dir.join(file))?;
        let name = arch.name.clone();
        println!(
            "loaded {:<12} {}x{} PE array, dataflows {:?}",
            name, arch.pe_dim, arch.pe_dim, arch.dataflows
        );
        targets.push(desc_for_arch(&name, arch)?);
    }

    // 2. The ToyCar dense stack (batch 1), quantized in-process.
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let mut rng = Rng::new(77);
    let layers: Vec<FloatDense> = widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < widths.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..widths.len()).map(|i| 0.03 + 0.005 * i as f32).collect();
    let model = from_quantized(1, scales[0], &quantize_mlp(&layers, &scales)?);
    let graph = to_qnn_graph(&model)?;

    // 3. One compile against the target *set*: cost-driven partition →
    //    per-layer schedule/mapping/codegen → one linked deployment.
    let multi = Compiler::with_targets(&targets)?;
    let out = multi.compile_with_report(&graph)?;
    println!("\npipeline stages (partition lists target + cost per layer):");
    println!("{}", out.render_stages());
    println!("per-layer placement:\n{}", out.deployment.render_assignments());
    for (i, t) in targets.iter().enumerate() {
        println!("  {} layer(s) on {}", out.deployment.nodes_on_target(i), t.name);
    }
    // The partition objective now prices target *switches*: every
    // cross-target boundary is charged the DRAM round-trip it forces on
    // the activation (same-target placement can elide it via cross-layer
    // residency). The report lists each evaluated boundary.
    println!("evaluated switch boundaries:\n{}", out.deployment.render_boundaries());
    let max_switch =
        out.deployment.boundaries.iter().map(|b| b.penalty).max().unwrap_or(0);
    assert!(
        max_switch > 0,
        "the partition report must list a nonzero switch cost for at least one boundary"
    );
    println!("nonzero switch cost priced into the objective: up to {max_switch} cycles ✔");
    println!(
        "\n{} sweeps for {} layers across {} targets (shared schedule cache)",
        multi.sweeps_run(),
        out.deployment.assignments.len(),
        targets.len()
    );

    // 4. Execute the heterogeneous deployment (segments hand off through
    //    shared DRAM) and check against the graph interpreter.
    let input = rng.i8_vec(widths[0]);
    let (got, rep) = out.deployment.run(&input)?;
    let mut inputs = BTreeMap::new();
    inputs.insert(
        "x".to_string(),
        Tensor::new(vec![1, widths[0]], TensorData::I8(input)).unwrap(),
    );
    let want = eval(&graph, &inputs)?;
    assert_eq!(TensorData::I8(got), want[0].data, "heterogeneous run must match interpreter");
    println!(
        "ran {} segment(s): {} cycles ({} host), {} MACs — matches the interpreter ✔",
        out.deployment.segments.len(),
        commafy(rep.cycles),
        commafy(rep.host_cycles),
        commafy(rep.macs)
    );
    Ok(())
}
