//! Heterogeneous deployment: one compile, several accelerators.
//!
//! Loads *two* architectural descriptions — `configs/gemmini.yaml` (16×16
//! weight-stationary) and `configs/bigarray_os.yaml` (32×32
//! output-stationary) — gives both the same ~60-line functional
//! description, and compiles a ToyCar-width dense stack against the pair
//! in a single session. The partition stage probes every layer on each
//! candidate through the shared schedule cache and places it on the
//! target with the lowest profiled cycle cost; the per-stage report lists
//! the choice and its cost per layer. The linked `MultiDeployment` drives
//! both instruction streams over one shared DRAM image, and the result is
//! checked element-exactly against the graph interpreter.
//!
//! The second half repeats the compile against the cross-family
//! gemmini + vector pair (the vector unit loads through the backend
//! registry): the cost-driven partition sends the narrow bottleneck
//! layer to the 8-lane vector engine, and the overlapped executor
//! double-buffers each boundary handoff so the makespan beats the serial
//! segment walk.
//!
//! Run with: `cargo run --release --example heterogeneous`

use std::collections::BTreeMap;

use anyhow::Result;
use tvm_accel::accel::gemmini::desc_for_arch;
use tvm_accel::arch::parse::arch_from_file;
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::relay::{Tensor, TensorData};
use tvm_accel::service::socket::load_target;
use tvm_accel::util::prng::Rng;
use tvm_accel::util::table::commafy;

fn main() -> Result<()> {
    // 1. Two accelerator models from their YAML architectural
    //    descriptions; the functional description transfers unchanged.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut targets = Vec::new();
    for file in ["gemmini.yaml", "bigarray_os.yaml"] {
        let arch = arch_from_file(&dir.join(file))?;
        let name = arch.name.clone();
        println!(
            "loaded {:<12} {}x{} PE array, dataflows {:?}",
            name, arch.pe_dim, arch.pe_dim, arch.dataflows
        );
        targets.push(desc_for_arch(&name, arch)?);
    }

    // 2. The ToyCar dense stack (batch 1), quantized in-process.
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let mut rng = Rng::new(77);
    let layers: Vec<FloatDense> = widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < widths.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..widths.len()).map(|i| 0.03 + 0.005 * i as f32).collect();
    let model = from_quantized(1, scales[0], &quantize_mlp(&layers, &scales)?);
    let graph = to_qnn_graph(&model)?;

    // 3. One compile against the target *set*: cost-driven partition →
    //    per-layer schedule/mapping/codegen → one linked deployment.
    let multi = Compiler::with_targets(&targets)?;
    let out = multi.compile_with_report(&graph)?;
    println!("\npipeline stages (partition lists target + cost per layer):");
    println!("{}", out.render_stages());
    println!("per-layer placement:\n{}", out.deployment.render_assignments());
    for (i, t) in targets.iter().enumerate() {
        println!("  {} layer(s) on {}", out.deployment.nodes_on_target(i), t.name);
    }
    // The partition objective now prices target *switches*: every
    // cross-target boundary is charged the DRAM round-trip it forces on
    // the activation (same-target placement can elide it via cross-layer
    // residency). The report lists each evaluated boundary.
    println!("evaluated switch boundaries:\n{}", out.deployment.render_boundaries());
    let max_switch =
        out.deployment.boundaries.iter().map(|b| b.penalty).max().unwrap_or(0);
    assert!(
        max_switch > 0,
        "the partition report must list a nonzero switch cost for at least one boundary"
    );
    println!("nonzero switch cost priced into the objective: up to {max_switch} cycles ✔");
    println!(
        "\n{} sweeps for {} layers across {} targets (shared schedule cache)",
        multi.sweeps_run(),
        out.deployment.assignments.len(),
        targets.len()
    );

    // 4. Execute the heterogeneous deployment (segments hand off through
    //    shared DRAM) and check against the graph interpreter.
    let input = rng.i8_vec(widths[0]);
    let (got, rep) = out.deployment.run(&input)?;
    let mut inputs = BTreeMap::new();
    inputs.insert(
        "x".to_string(),
        Tensor::new(vec![1, widths[0]], TensorData::I8(input)).unwrap(),
    );
    let want = eval(&graph, &inputs)?;
    assert_eq!(TensorData::I8(got), want[0].data, "heterogeneous run must match interpreter");
    println!(
        "ran {} segment(s): {} cycles ({} host), {} MACs — matches the interpreter ✔",
        out.deployment.segments.len(),
        commafy(rep.cycles),
        commafy(rep.host_cycles),
        commafy(rep.macs)
    );

    // 5. The cross-family pair: gemmini + the 8-lane vector unit
    //    (resolved through the backend registry by its `backend:` key).
    //    Gemmini's per-row DMA overhead on a half-empty array makes the
    //    narrow 128→8 bottleneck cheaper on the vector engine, so the
    //    cost-driven partition splits the stack — and the overlapped
    //    executor hides part of each boundary handoff by running the
    //    consumer's head under the producer's tail.
    let vector = load_target(&dir.join("vector.yaml"))?;
    println!(
        "\nloaded {:<12} {}-lane vector unit (registry backend)",
        vector.name, vector.arch.pe_dim
    );
    let pair = vec![targets[0].clone(), vector];
    let hetero = Compiler::with_targets(&pair)?;
    let out2 = hetero.compile_with_report(&graph)?;
    println!("per-layer placement (gemmini+vector):\n{}", out2.deployment.render_assignments());
    for (i, t) in pair.iter().enumerate() {
        println!("  {} layer(s) on {}", out2.deployment.nodes_on_target(i), t.name);
    }
    println!("switch boundaries:\n{}", out2.deployment.render_boundaries());
    assert!(
        out2.deployment.segments.len() > 1,
        "the cost-driven partition must split ToyCar across gemmini and the vector unit"
    );
    let (got2, rep2, ov) = out2.deployment.run_overlapped(&input)?;
    assert_eq!(
        TensorData::I8(got2),
        want[0].data,
        "gemmini+vector run must match interpreter"
    );
    assert!(
        rep2.overlapped_cycles < rep2.cycles,
        "overlapped makespan must beat the serial handoff (got {} vs {})",
        rep2.overlapped_cycles,
        rep2.cycles
    );
    println!(
        "gemmini+vector: serial {} cycles, overlapped {} cycles — \
         overlap hides {} cycles across {} segment(s) ✔",
        commafy(ov.serial_cycles),
        commafy(ov.overlapped_cycles),
        commafy(ov.saved_cycles()),
        out2.deployment.segments.len()
    );
    Ok(())
}
