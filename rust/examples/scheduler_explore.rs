//! Scheduler exploration (paper Fig. 2b): run the extended CoSA sweep over
//! dataflows × uneven-mapping × double-buffering for a GEMM, print the
//! candidate mappings in CoSA's YAML output format, and profile them on
//! the simulator to pick the measured best.
//!
//! Run with: `cargo run --release --example scheduler_explore -- --n 128 --c 128 --k 128`
//! (add `--serial` to disable the parallel sweep)

use anyhow::Result;
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::backend::codegen::{generate, LayerBufs};
use tvm_accel::backend::mapping::apply_schedule;
use tvm_accel::isa::program::Program;
use tvm_accel::isa::Instr;
use tvm_accel::scheduler::sweep::{sweep, SweepOptions};
use tvm_accel::sim::Simulator;
use tvm_accel::tir::{QuantAttrs, TirFunc};
use tvm_accel::util::cli::Args;
use tvm_accel::util::table::{commafy, Table};
use tvm_accel::workload::Gemm;

fn main() -> Result<()> {
    let args = Args::from_env(&["n", "c", "k"])?;
    let g = Gemm::new(
        args.opt_usize("n", 128)?,
        args.opt_usize("c", 128)?,
        args.opt_usize("k", 128)?,
    );
    let accel = gemmini_desc()?;
    println!("extended-CoSA sweep for GEMM {g} on {}\n", accel.name);

    // `--serial` forces the reference single-threaded sweep (the parallel
    // default returns the identical candidate list, just faster).
    let opts = SweepOptions {
        max_candidates: 8,
        parallel: !args.flag("serial"),
        ..Default::default()
    };
    let result = sweep(&accel.arch, g, &opts);
    println!(
        "{} configuration points explored, {} candidates kept\n",
        result.configs_explored,
        result.candidates.len()
    );

    // Profile every candidate on the simulator (Fig. 2b's final step).
    let sim = Simulator::new(&accel.arch);
    let mut t = Table::new("Candidate mappings (analytic estimate vs measured)").header(&[
        "#", "dataflow", "insn tile", "on-chip tile", "order", "db", "est cycles", "measured",
    ]);
    let mut best: Option<(usize, u64)> = None;
    for (i, s) in result.candidates.iter().enumerate() {
        let f = TirFunc::unscheduled(
            "explore",
            g,
            QuantAttrs { scale: 0.05, act: tvm_accel::isa::Activation::None },
        );
        let scheduled = apply_schedule(&accel, &f, s)?;
        let mut prog = Program::new("explore");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64)?.offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64)?.offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64)?.offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64)?.offset,
        };
        generate(&accel, &scheduled, s, &bufs, &mut prog)?;
        prog.push(Instr::Fence);
        let mut dram = prog.make_dram()?;
        let rep = sim.run(&prog, &mut dram)?;
        if best.map(|(_, c)| rep.cycles < c).unwrap_or(true) {
            best = Some((i, rep.cycles));
        }
        t.row(vec![
            format!("{i}"),
            s.dataflow.to_string(),
            format!("{:?}", s.insn_tile),
            format!("{:?}", s.onchip_tile),
            format!("{}{}{}", s.dram_order[0], s.dram_order[1], s.dram_order[2]),
            format!("{}", s.double_buffer),
            commafy(s.est.latency as u64),
            commafy(rep.cycles),
        ]);
    }
    println!("{}", t.render());

    let (bi, bc) = best.expect("at least one candidate");
    println!(
        "measured best: candidate {bi} at {} cycles\n\nCoSA-format mapping:\n{}",
        commafy(bc),
        result.candidates[bi].to_yaml()
    );
    Ok(())
}
