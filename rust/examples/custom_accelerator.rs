//! Integrating a *different* accelerator (the paper's promise: a new
//! GEMM-based accelerator needs only a functional description + an
//! architectural YAML, no compiler surgery).
//!
//! Here: "bigarray-os", a 32x32 output-stationary array with a 512 KiB
//! scratchpad, described entirely by `configs/bigarray_os.yaml` + the same
//! ~60-line functional description. The whole backend — legalization,
//! scheduling, tensorization, codegen — is regenerated automatically, and
//! the same model runs correctly on both machines.
//!
//! Run with: `cargo run --release --example custom_accelerator`

use anyhow::Result;
use tvm_accel::accel::gemmini::{desc_for_arch, gemmini_desc};
use tvm_accel::arch::parse::arch_from_file;
use tvm_accel::obs::describe;
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

fn main() -> Result<()> {
    // 1. Architectural description from YAML (the CoSA-style input).
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/bigarray_os.yaml");
    let arch = arch_from_file(&path)?;
    println!(
        "loaded {}: {}x{} PE array, dataflows {:?}, scratchpad {} KiB",
        arch.name,
        arch.pe_dim,
        arch.pe_dim,
        arch.dataflows,
        arch.levels.iter().find(|l| l.name == "Scratchpad").unwrap().size_bytes / 1024
    );

    // 2. Functional description: identical registration code as Gemmini —
    //    the compute/memory/config intrinsics transfer unchanged.
    let custom = desc_for_arch("bigarray-os", arch)?;
    let gemmini = gemmini_desc()?;

    // 3. One model, two accelerators.
    let mut rng = Rng::new(7);
    let dims = [128usize, 256, 64];
    let layers: Vec<FloatDense> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i == 0,
        })
        .collect();
    let model = from_quantized(32, 0.03, &quantize_mlp(&layers, &[0.03, 0.05, 0.07])?);
    let graph = to_qnn_graph(&model)?;
    let input = rng.i8_vec(32 * dims[0]);

    let mut outputs = Vec::new();
    for accel in [&gemmini, &custom] {
        let dep = Compiler::new(accel.clone()).compile(&graph)?;
        let sim = Simulator::new(&accel.arch);
        let (out, rep) = dep.run(&sim, &input)?;
        println!("\n== {} ==", accel.name);
        for (name, s, cyc) in &dep.chosen {
            println!("  {name}: {s} (profiled {cyc:?})");
        }
        println!("  {}", describe("run", &rep, accel.arch.pe_dim));
        outputs.push(out);
    }

    assert_eq!(outputs[0], outputs[1], "both accelerators must agree bit-exactly");
    println!("\nboth accelerators produced identical outputs ✔");
    Ok(())
}
