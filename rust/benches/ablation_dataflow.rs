//! Dataflow ablation (paper Fig. 2a): the accelerator's dataflow fixes
//! the spatial dims and the valid-mapping space; WS and OS therefore
//! perform differently per workload shape. The sweep explores both and
//! the measured winner depends on the shape — deep-reduction layers favor
//! WS (weights resident), output-heavy shapes tolerate OS.
//!
//! Run with: `cargo bench --bench ablation_dataflow`.

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::arch::Dataflow;
use tvm_accel::backend::codegen::{generate, LayerBufs};
use tvm_accel::backend::mapping::apply_schedule;
use tvm_accel::isa::program::Program;
use tvm_accel::isa::Instr;
use tvm_accel::scheduler::solver::{solve, SolverConfig};
use tvm_accel::sim::Simulator;
use tvm_accel::tir::{QuantAttrs, TirFunc};
use tvm_accel::util::table::{commafy, Table};
use tvm_accel::workload::Gemm;

fn best_cycles(g: Gemm, df: Dataflow) -> Option<u64> {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let cfg = SolverConfig { double_buffer: true, top_k: 3, ..SolverConfig::new(df) };
    let mut best = None;
    for s in solve(&accel.arch, g, &cfg) {
        let f = TirFunc::unscheduled(
            "df",
            g,
            QuantAttrs { scale: 0.05, act: tvm_accel::isa::Activation::None },
        );
        let scheduled = apply_schedule(&accel, &f, &s).unwrap();
        let mut prog = Program::new("df");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64).unwrap().offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64).unwrap().offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64).unwrap().offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64).unwrap().offset,
        };
        generate(&accel, &scheduled, &s, &bufs, &mut prog).unwrap();
        prog.push(Instr::Fence);
        let mut dram = prog.make_dram().unwrap();
        let c = sim.run(&prog, &mut dram).unwrap().cycles;
        if best.map(|b| c < b).unwrap_or(true) {
            best = Some(c);
        }
    }
    best
}

fn main() {
    let workloads = [
        ("square 64^3", Gemm::new(64, 64, 64)),
        ("square 128^3", Gemm::new(128, 128, 128)),
        ("deep reduction (64,1024,64)", Gemm::new(64, 1024, 64)),
        ("wide output (64,64,1024)", Gemm::new(64, 64, 1024)),
        ("tall batch (1024,64,64)", Gemm::new(1024, 64, 64)),
    ];
    let mut t = Table::new("Dataflow ablation (Fig. 2a): WS vs OS, measured cycles")
        .header(&["workload", "WS", "OS", "OS/WS"]);
    for (name, g) in workloads {
        let ws = best_cycles(g, Dataflow::WeightStationary).expect("WS maps");
        let os = best_cycles(g, Dataflow::OutputStationary).expect("OS maps");
        t.row(vec![
            name.to_string(),
            commafy(ws),
            commafy(os),
            format!("{:.2}x", os as f64 / ws as f64),
        ]);
    }
    println!("{}", t.render());
    println!("WS is Gemmini's performant configuration (paper §4); the constraint");
    println!("sets of Fig. 2a are what the architectural description encodes.");
}
