//! Ablation of the paper's scheduler extensions (Fig. 2b tuning knobs):
//! uneven mapping (memory-share exploration) and double buffering, each
//! on/off, measured on the simulator for a square layer and a skewed
//! (weight-heavy) layer.
//!
//! Run with: `cargo bench --bench ablation_scheduler`.

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::backend::codegen::{generate, LayerBufs};
use tvm_accel::backend::mapping::apply_schedule;
use tvm_accel::isa::program::Program;
use tvm_accel::isa::Instr;
use tvm_accel::scheduler::sweep::{sweep, SweepOptions};
use tvm_accel::sim::Simulator;
use tvm_accel::tir::{QuantAttrs, TirFunc};
use tvm_accel::util::table::{commafy, Table};
use tvm_accel::workload::Gemm;

fn run_best(g: Gemm, uneven: bool, db: bool) -> (u64, String) {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let opts = SweepOptions {
        uneven_mapping: uneven,
        double_buffering: db,
        // Profile a wide shortlist so each knob grid's measured best is
        // found even when the analytic model mis-ranks (Fig. 2b's point).
        max_candidates: 16,
        ..Default::default()
    };
    let result = sweep(&accel.arch, g, &opts);
    let mut best: Option<(u64, String)> = None;
    for s in &result.candidates {
        let f = TirFunc::unscheduled(
            "ablate",
            g,
            QuantAttrs { scale: 0.05, act: tvm_accel::isa::Activation::None },
        );
        let scheduled = apply_schedule(&accel, &f, s).unwrap();
        let mut prog = Program::new("ablate");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64).unwrap().offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64).unwrap().offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64).unwrap().offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64).unwrap().offset,
        };
        generate(&accel, &scheduled, s, &bufs, &mut prog).unwrap();
        prog.push(Instr::Fence);
        let mut dram = prog.make_dram().unwrap();
        let rep = sim.run(&prog, &mut dram).unwrap();
        if best.as_ref().map(|(c, _)| rep.cycles < *c).unwrap_or(true) {
            best = Some((rep.cycles, format!("{s}")));
        }
    }
    best.expect("at least one candidate")
}

fn main() {
    // Workloads whose operands exceed the 256 KiB scratchpad, so tiles
    // actually stream and the knobs have something to overlap/allocate.
    let workloads = [
        ("square 512^3", Gemm::new(512, 512, 512)),
        ("deep (256,1024,256)", Gemm::new(256, 1024, 256)),
        ("wide (256,256,1024)", Gemm::new(256, 256, 1024)),
        ("tall (1024,512,256)", Gemm::new(1024, 512, 256)),
    ];
    let mut t = Table::new("Scheduler ablation: measured cycles of the best mapping").header(&[
        "workload",
        "baseline",
        "+double-buffer",
        "+uneven",
        "+both",
        "both vs baseline",
    ]);
    for (name, g) in workloads {
        let (base, _) = run_best(g, false, false);
        let (db, _) = run_best(g, false, true);
        let (ue, _) = run_best(g, true, false);
        let (both, best_s) = run_best(g, true, true);
        t.row(vec![
            name.to_string(),
            commafy(base),
            commafy(db),
            commafy(ue),
            commafy(both),
            format!("{:.2}x", base as f64 / both as f64),
        ]);
        eprintln!("  {name}: best mapping {best_s}");
        // Allow a small profiling-coverage slack: the knob grid changes
        // which analytic top-k get profiled.
        assert!(
            both as f64 <= base as f64 * 1.05,
            "{name}: full knobs must not lose to baseline ({both} vs {base})"
        );
        assert!(db as f64 <= base as f64 * 1.05, "{name}: double buffering must not hurt");
    }
    println!("\n{}", t.render());
    println!("(Fig. 2b: the sweep over dataflows x uneven mapping x double buffering");
    println!(" is what turns the raw CoSA mapping into the deployed one.)");
}
