//! Regenerates **Table 2** of the paper: inference latency (cycles) of
//! single dense layers (64³..512³) and the full ToyCar network under the
//! three backends — Gemmini's C-function toolchain, the proposed
//! CoSA-scheduled flow, and the naive BYOC/UMA backend.
//!
//! Absolute cycles differ from the paper's RTL testbed; the claims being
//! reproduced are the *relative* ones: proposed ≈ C toolchain, naive BYOC
//! 2–5× worse on single layers and orders of magnitude worse on ToyCar.
//!
//! Run with: `cargo bench --bench table2_latency`.

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::{compile_naive, import_with_weight_chain};
use tvm_accel::obs::{table2, LatencyRow};
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::{from_quantized, QModel};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;
use tvm_accel::workload::suites;

fn square_model(size: usize, seed: u64) -> QModel {
    let mut rng = Rng::new(seed);
    let l = FloatDense {
        weight: (0..size * size).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
        bias: (0..size).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
        in_dim: size,
        out_dim: size,
        relu: false,
    };
    from_quantized(size, 0.04, &quantize_mlp(&[l], &[0.04, 0.05]).unwrap())
}

fn toycar_model(seed: u64) -> QModel {
    let mut rng = Rng::new(seed);
    let widths = suites::toycar_widths();
    let layers: Vec<FloatDense> = widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < widths.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..widths.len()).map(|i| 0.04 + 0.01 * i as f32).collect();
    from_quantized(1, scales[0], &quantize_mlp(&layers, &scales).unwrap())
}

fn measure(model: &QModel, name: &str) -> LatencyRow {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let x = Rng::new(7).i8_vec(model.batch * model.layers[0].in_dim);

    let graph = import_with_weight_chain(model).unwrap();
    let proposed = Compiler::new(accel.clone()).compile(&graph).unwrap();
    let (out_p, rep_p) = proposed.run(&sim, &x).unwrap();

    let ct = compile_c_toolchain(&accel, model).unwrap();
    let (out_c, rep_c) = ct.run(&sim, &x).unwrap();

    let nb = compile_naive(&accel, model).unwrap();
    let (out_n, rep_n) = nb.run(&sim, &x).unwrap();

    assert_eq!(out_p, out_c, "{name}: proposed != c_toolchain");
    assert_eq!(out_p, out_n, "{name}: proposed != naive");

    LatencyRow {
        workload: name.to_string(),
        c_toolchain: rep_c.cycles,
        byoc_uma: rep_n.cycles,
        proposed: rep_p.cycles,
    }
}

fn main() {
    println!("regenerating Table 2 (compiles 15 deployments; takes ~a minute)...\n");
    let mut rows = Vec::new();
    for (i, (name, g)) in suites::table2_single_layers().iter().enumerate() {
        let model = square_model(g.n, 500 + i as u64);
        rows.push(measure(&model, name));
        eprintln!("  done {name}");
    }
    rows.push(measure(&toycar_model(600), "ToyCar"));
    eprintln!("  done ToyCar\n");

    println!("{}", table2(&rows).render());

    println!("paper's Table 2 for reference (absolute cycles are testbed-specific):");
    println!("  (64,64,64):     C 69,994    proposed 69,995    BYOC 160,163    (2.29x)");
    println!("  (128,128,128):  C 279,206   proposed 280,598   BYOC 843,481    (3.01x)");
    println!("  (256,256,256):  C 1,138,769 proposed 1,139,145 BYOC 4,261,116  (3.74x)");
    println!("  (512,512,512):  C 4,877,499 proposed 4,892,657 BYOC 21,508,629 (4.40x)");
    println!("  ToyCar:         C 50,064    proposed 51,034    BYOC 10,136,186 (198.6x)");

    // Shape assertions (the reproduction claims).
    for r in &rows {
        let pc = r.proposed as f64 / r.c_toolchain as f64;
        assert!(
            pc < 1.25,
            "{}: proposed must be comparable to the C toolchain (got {pc:.2}x)",
            r.workload
        );
        let np = r.byoc_uma as f64 / r.proposed as f64;
        if r.workload == "ToyCar" {
            assert!(np > 20.0, "ToyCar: naive BYOC must be orders of magnitude worse");
        } else {
            assert!(np > 1.5, "{}: naive BYOC must lose clearly (got {np:.2}x)", r.workload);
        }
    }
    println!("\nshape checks passed: proposed ≈ C toolchain; BYOC slower everywhere,");
    println!("catastrophically so on ToyCar.");
}
