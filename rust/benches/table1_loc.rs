//! Regenerates **Table 1** of the paper: lines of code a user must write
//! to enable lowering + scheduling for a new accelerator, manual
//! integration vs the proposed functional description.
//!
//! The "manual" side counts this repo's actual backend machinery — the
//! code a manual TVM-style integration would hand-write per accelerator
//! (legalization patterns, strategy binding, intrinsic registration,
//! TIR scheduling, codegen). The "proposed" side counts what a user
//! actually writes here: the Gemmini functional description plus the
//! architectural YAML.
//!
//! Run with: `cargo bench --bench table1_loc`.

use std::path::Path;

use tvm_accel::util::table::Table;

/// Count non-blank, non-comment lines (matching how LoC tables are
/// usually produced).
fn loc(path: &Path) -> usize {
    let src = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let mut in_block_comment = false;
    src.lines()
        .filter(|l| {
            let t = l.trim();
            if in_block_comment {
                if t.contains("*/") {
                    in_block_comment = false;
                }
                return false;
            }
            if t.starts_with("/*") {
                in_block_comment = !t.contains("*/");
                return false;
            }
            !t.is_empty()
                && !t.starts_with("//")
                && !t.starts_with('#')
                && !t.starts_with("*")
        })
        .count()
}

fn total(paths: &[&str]) -> usize {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    paths.iter().map(|p| loc(&root.join(p))).sum()
}

fn main() {
    // Manual integration: everything the configurators generate/automate.
    let manual_frontend = total(&["src/relay/legalize.rs", "src/frontend/mod.rs"]);
    let manual_backend = total(&[
        "src/backend/strategy.rs",
        "src/backend/intrin.rs",
        "src/backend/mapping.rs",
    ]);
    let manual_sched = total(&["src/backend/codegen.rs", "src/tir/schedule.rs"]);
    let manual = manual_frontend + manual_backend + manual_sched;

    // Proposed: what a user writes for one accelerator.
    let proposed = total(&["src/accel/gemmini.rs", "configs/gemmini.yaml"]);

    let reduction = 100.0 * (1.0 - proposed as f64 / manual as f64);

    let mut t = Table::new(
        "Table 1: LoC for enabling lowering and scheduling (manual vs proposed)",
    )
    .header(&["Component", "LoC"]);
    t.row(vec!["Manual: legalization + frontend config".into(), manual_frontend.to_string()]);
    t.row(vec!["Manual: strategy/intrinsic/mapping generators".into(), manual_backend.to_string()]);
    t.row(vec!["Manual: TIR scheduling + codegen".into(), manual_sched.to_string()]);
    t.row(vec!["Manual total".into(), manual.to_string()]);
    t.row(vec!["Proposed: functional description (+ YAML)".into(), proposed.to_string()]);
    t.row(vec!["Reduction".into(), format!("{reduction:.0}%")]);
    println!("{}", t.render());

    println!(
        "paper's Table 1: manual ≈ 230 (C++) + 398 (Python Relay) + 425 (TE/TIR) = 1053 LoC;"
    );
    println!("proposed ≈ 208 LoC functional description → ~80% reduction.\n");

    assert!(
        reduction >= 70.0,
        "reproduction expects ≥70% LoC reduction, got {reduction:.0}%"
    );
    println!("shape check passed: {reduction:.0}% reduction (paper: ~80%).");
}
