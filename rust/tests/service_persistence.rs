//! Integration tests for the compile service and the persistent schedule
//! cache: the acceptance bar is that a cold compile followed by an
//! identical one — through a fresh process-equivalent (new server, same
//! cache file) or a running server — performs **zero** schedule sweeps
//! the second time while emitting byte-identical programs.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::pipeline::{CompileOptions, Compiler};
use tvm_accel::relay::import::{parse_qmodel, synth_qmodel, write_qmodel, QModel};
use tvm_accel::scheduler::persist;
use tvm_accel::service::protocol::{parse_message, ObjBuilder};
use tvm_accel::service::socket::{self, ServeOptions};
use tvm_accel::service::{memo_sibling_path, CompileServer, CompiledArtifact};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh scratch directory per test (unique per process + call).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tvm-accel-it-{}-{}-{}",
        tag,
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

fn sample_model(seed: u64, dims: &[usize], batch: usize) -> QModel {
    synth_qmodel(seed, dims, batch).unwrap()
}

/// Save/load roundtrip through a real compile: every entry the compile
/// produced survives the disk trip exactly.
#[test]
fn persisted_cache_roundtrip_is_entry_exact() {
    let dir = scratch_dir("roundtrip");
    let file = dir.join("schedules.bin");
    let server = CompileServer::new(CompileOptions::default());
    let model = sample_model(71, &[32, 48, 16], 4);
    let accel = gemmini_desc().unwrap();
    server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();

    let cache = server.cache();
    let written = persist::save_to_file(&cache, &file).unwrap();
    assert!(written >= 2, "two distinct shapes compiled (plus any constrained entries)");
    assert_eq!(written, cache.snapshot().len());

    let (entries, rep) = persist::load_file(&file);
    assert_eq!(rep.loaded, written);
    assert_eq!(rep.skipped, 0);
    assert_eq!(entries, cache.snapshot_stamped(), "roundtrip must be entry-exact");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupted or truncated artifacts degrade to a (partially) cold cache —
/// never an error.
#[test]
fn corrupt_and_truncated_artifacts_degrade_to_cold() {
    let dir = scratch_dir("corrupt");
    let file = dir.join("schedules.bin");
    let server = CompileServer::new(CompileOptions::default());
    let model = sample_model(72, &[24, 16, 8], 2);
    let accel = gemmini_desc().unwrap();
    server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    persist::save_to_file(&server.cache(), &file).unwrap();
    let bytes = std::fs::read(&file).unwrap();
    let total = persist::load_file(&file).0.len();
    assert!(total >= 2);

    // Flip a byte inside the first entry's payload: that entry is skipped,
    // the rest load.
    let mut flipped = bytes.clone();
    flipped[8 + 12 + 4] ^= 0x5a;
    std::fs::write(&file, &flipped).unwrap();
    let fresh = CompileServer::with_cache_file(CompileOptions::default(), file.clone()).1;
    assert_eq!(fresh.loaded, total - 1);
    assert_eq!(fresh.skipped, 1);

    // Truncate mid-entry: the readable prefix survives.
    std::fs::write(&file, &bytes[..bytes.len() - 7]).unwrap();
    let (entries, rep) = persist::load_file(&file);
    assert_eq!(entries.len(), total - 1);
    assert_eq!(rep.skipped, 1);

    // Garbage and missing files are plainly cold.
    std::fs::write(&file, b"definitely not a schedule cache").unwrap();
    assert_eq!(persist::load_file(&file).0.len(), 0);
    assert_eq!(persist::load_file(&dir.join("missing.bin")).0.len(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A format-version bump invalidates the artifact cleanly (cold load, no
/// error, and the next save rewrites it in the current version).
#[test]
fn version_bump_invalidates_cleanly() {
    let dir = scratch_dir("version");
    let file = dir.join("schedules.bin");
    let server = CompileServer::new(CompileOptions::default());
    let model = sample_model(73, &[16, 16], 2);
    let accel = gemmini_desc().unwrap();
    server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    persist::save_to_file(&server.cache(), &file).unwrap();

    let mut bytes = std::fs::read(&file).unwrap();
    let future = (persist::FORMAT_VERSION + 1).to_le_bytes();
    bytes[4..8].copy_from_slice(&future);
    std::fs::write(&file, &bytes).unwrap();

    let (server2, rep) =
        CompileServer::with_cache_file(CompileOptions::default(), file.clone());
    assert_eq!(rep.loaded, 0, "future version must load cold");
    assert_eq!(server2.cache_stats().entries, 0);
    // Compiling through the hydrant rewrites the artifact in the current
    // version.
    let reply = server2.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    assert!(reply.sweeps > 0);
    let (entries, _) = persist::load_file(&file);
    assert_eq!(entries.len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance criterion: cold compile, then a second identical
/// invocation through a *fresh* server hydrated from the same cache file
/// — zero sweeps, zero misses, byte-identical program.
#[test]
fn hydrated_compile_is_sweep_free_and_byte_identical() {
    let dir = scratch_dir("accept");
    let file = dir.join("schedules.bin");
    let model = sample_model(74, &[40, 16, 16, 8, 16, 16, 40], 1);
    let accel = gemmini_desc().unwrap();

    // Invocation 1: cold, persists on update.
    let (cold_server, load) =
        CompileServer::with_cache_file(CompileOptions::default(), file.clone());
    assert_eq!(load.loaded, 0);
    let cold = cold_server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    assert!(cold.sweeps >= 5, "ToyCar-like trunk has 5 distinct shapes");
    assert!(file.exists(), "compile with sweeps must persist the cache");
    let persisted = cold_server.cache_stats().entries;

    // Invocation 2: a fresh server (the 'second CLI invocation').
    let (warm_server, load) =
        CompileServer::with_cache_file(CompileOptions::default(), file.clone());
    assert_eq!(load.loaded, persisted);
    let warm = warm_server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    assert_eq!(warm.sweeps, 0, "hydrated compile must run zero sweeps");
    assert_eq!(warm.cache_misses, 0);
    assert_eq!(
        warm.artifact.program().items,
        cold.artifact.program().items,
        "cache-hydrated compile must emit a byte-identical program"
    );
    assert_eq!(warm.artifact.program_fnv(), cold.artifact.program_fnv());

    // And both match a plain cold Compiler without any service plumbing.
    let graph = tvm_accel::baselines::naive_byoc::import_with_weight_chain(&model).unwrap();
    let plain = Compiler::new(accel).compile(&graph).unwrap();
    let CompiledArtifact::Single(dep) = &warm.artifact else {
        panic!("single-target compile must produce a single deployment")
    };
    assert_eq!(dep.program.items, plain.program.items);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cross-process incremental compiles: a server's incremental-session
/// memo persists as the cache artifact's `.memo` sibling, and a *fresh*
/// server hydrated from that sibling serves every layer straight from
/// the memo — zero sweeps and byte-identical output even with the
/// schedule-cache artifact deleted out from under it.
#[test]
fn persisted_memo_survives_process_restart() {
    let dir = scratch_dir("memo");
    let file = dir.join("schedules.bin");
    let model = sample_model(78, &[32, 48, 16], 4);
    let accel = gemmini_desc().unwrap();

    // Process 1: cold incremental compile; persisting writes the memo
    // sibling alongside the cache artifact.
    let (cold_server, _) =
        CompileServer::with_cache_file(CompileOptions::default(), file.clone());
    let cold =
        cold_server.compile_model_incremental(&model, std::slice::from_ref(&accel)).unwrap();
    assert!(cold.sweeps >= 2, "cold incremental compile still sweeps");
    assert_eq!(cold.schedule_stats.memo_hits, 0);
    assert!(cold_server.memo().len() >= 2, "every selection is memoized");
    let memo_file = memo_sibling_path(&file);
    assert!(memo_file.exists(), "persist must write the .memo sibling");

    // Delete the schedule-cache artifact: what follows can only come from
    // the memo.
    std::fs::remove_file(&file).unwrap();

    // Process 2: a fresh server hydrates the memo sibling and serves the
    // whole model from it.
    let (warm_server, load) =
        CompileServer::with_cache_file(CompileOptions::default(), file.clone());
    assert_eq!(load.loaded, 0, "cache artifact is gone; only the memo remains");
    assert_eq!(warm_server.memo().len(), cold_server.memo().len());
    let warm =
        warm_server.compile_model_incremental(&model, std::slice::from_ref(&accel)).unwrap();
    assert_eq!(warm.sweeps, 0, "memo-hydrated compile must run zero sweeps");
    assert_eq!(
        warm.schedule_stats.memo_hits, warm.schedule_stats.layers,
        "every layer must be served from the persisted memo"
    );
    assert_eq!(
        warm.artifact.program().items,
        cold.artifact.program().items,
        "memo-hydrated compile must emit a byte-identical program"
    );

    // The plain (non-incremental) path is unaffected by the hydrated memo.
    let plain = warm_server.compile_model(&model, std::slice::from_ref(&accel)).unwrap();
    assert_eq!(plain.schedule_stats.memo_hits, 0);
    assert_eq!(plain.artifact.program().items, cold.artifact.program().items);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two concurrent requests for models sharing every layer shape: the
/// single-flight gate must run one sweep per distinct shape *total*.
#[test]
fn concurrent_server_requests_share_inflight_searches() {
    let server = Arc::new(CompileServer::new(CompileOptions::default()));
    let accel = gemmini_desc().unwrap();
    // Different weights, identical shapes: distinct models, shared keys.
    let a = sample_model(75, &[32, 24, 8], 4);
    let b = sample_model(76, &[32, 24, 8], 4);
    let sweeps: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = [&a, &b]
            .into_iter()
            .map(|m| {
                let server = server.clone();
                let accel = accel.clone();
                let model = m.clone();
                scope.spawn(move || {
                    server
                        .compile_model(&model, std::slice::from_ref(&accel))
                        .expect("compile request")
                        .sweeps
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("request panicked")).sum()
    });
    assert!(sweeps >= 2, "at least one sweep per shared layer shape");
    assert!(server.cache_stats().entries >= 2);
    // Everything was searched exactly once across the pair: a third,
    // sequential request finds every key warm.
    let third =
        server.compile_model(&a, std::slice::from_ref(&accel)).expect("third request");
    assert_eq!(third.sweeps, 0, "single-flight must have deduplicated every search");
}

/// End-to-end over the Unix socket: serve in a thread, compile twice, the
/// second response must report 100% cache hits (zero sweeps/misses) and
/// the same program hash; `shutdown` stops the server.
#[test]
fn socket_roundtrip_reports_warm_second_request() {
    let dir = scratch_dir("socket");
    let sock = dir.join("srv.sock");
    let cache_file = dir.join("schedules.bin");
    let model_file = dir.join("m.qmodel");
    let model = sample_model(77, &[32, 48, 16], 4);
    std::fs::write(&model_file, write_qmodel(&model)).unwrap();
    // Sanity: the file parses back.
    parse_qmodel(&std::fs::read(&model_file).unwrap()).unwrap();

    let (server, _) =
        CompileServer::with_cache_file(CompileOptions::default(), cache_file.clone());
    let server = Arc::new(server);
    let opts = ServeOptions {
        socket: sock.clone(),
        default_targets: vec![gemmini_desc().unwrap()],
    };
    let serve_thread = {
        let server = server.clone();
        std::thread::spawn(move || socket::serve(server, opts))
    };
    // Wait for the socket to appear.
    for _ in 0..200 {
        if sock.exists() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert!(sock.exists(), "server never bound its socket");

    let req = ObjBuilder::new()
        .str_field("cmd", "compile")
        .str_field("model", &model_file.display().to_string())
        .finish();
    let cold = parse_message(&socket::request(&sock, &req).unwrap()).unwrap();
    assert_eq!(cold.bool_field("ok"), Some(true), "cold compile failed: {cold:?}");
    assert!(cold.num_field("sweeps").unwrap() > 0.0);

    let warm = parse_message(&socket::request(&sock, &req).unwrap()).unwrap();
    assert_eq!(warm.bool_field("ok"), Some(true));
    assert_eq!(warm.num_field("sweeps"), Some(0.0), "warm request must not sweep");
    assert_eq!(warm.num_field("cache_misses"), Some(0.0));
    assert!(warm.num_field("cache_hits").unwrap() >= 2.0);
    assert_eq!(
        warm.str_field("program_fnv"),
        cold.str_field("program_fnv"),
        "warm compile must emit the identical program"
    );

    let bye = parse_message(
        &socket::request(&sock, &ObjBuilder::new().str_field("cmd", "shutdown").finish())
            .unwrap(),
    )
    .unwrap();
    assert_eq!(bye.bool_field("ok"), Some(true));
    serve_thread.join().expect("serve thread panicked").expect("serve errored");
    assert!(cache_file.exists(), "shutdown must persist the cache");
    let _ = std::fs::remove_dir_all(&dir);
}
