//! Integration tests across the whole Rust stack (no Python artifacts
//! needed): importer → frontend → scheduler → mapping → codegen →
//! simulator, checked against the graph interpreter, plus Table-2-shape
//! performance orderings.

use std::collections::BTreeMap;

use tvm_accel::accel::gemmini::{desc_for_arch, gemmini_desc};
use tvm_accel::arch::parse::arch_from_yaml;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::{compile_naive, import_with_weight_chain};
use tvm_accel::pipeline::{CompileOptions, Compiler, MultiCompiler, SessionMemo};
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::{from_quantized, parse_qmodel, write_qmodel, QModel};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::relay::{Tensor, TensorData};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

fn mk_model(rng: &mut Rng, dims: &[usize], batch: usize) -> QModel {
    let layers: Vec<FloatDense> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.35).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < dims.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..dims.len()).map(|i| 0.03 + 0.008 * i as f32).collect();
    from_quantized(batch, scales[0], &quantize_mlp(&layers, &scales).unwrap())
}

/// ToyCar-sized model entirely inside Rust (importer round-trip included).
#[test]
fn toycar_stack_all_backends_agree_with_interpreter() {
    let mut rng = Rng::new(1001);
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let model = mk_model(&mut rng, &widths, 1);

    // Serialize + reparse: the .qmodel round trip.
    let model = parse_qmodel(&write_qmodel(&model)).unwrap();

    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let graph = import_with_weight_chain(&model).unwrap();

    let x = rng.i8_vec(640);
    let mut inputs = BTreeMap::new();
    inputs.insert(
        "x".to_string(),
        Tensor::new(vec![1, 640], TensorData::I8(x.clone())).unwrap(),
    );
    let want = eval(&graph, &inputs).unwrap();

    let proposed = Compiler::new(accel.clone()).compile(&graph).unwrap();
    let (out_p, rep_p) = proposed.run(&sim, &x).unwrap();
    assert_eq!(TensorData::I8(out_p), want[0].data);

    let ct = compile_c_toolchain(&accel, &model).unwrap();
    let (out_c, rep_c) = ct.run(&sim, &x).unwrap();
    assert_eq!(TensorData::I8(out_c), want[0].data);

    let nb = compile_naive(&accel, &model).unwrap();
    let (out_n, rep_n) = nb.run(&sim, &x).unwrap();
    assert_eq!(TensorData::I8(out_n), want[0].data);

    // Table 2 ordering: proposed ~ C toolchain, naive catastrophically
    // slower on this host-preprocessing-dominated workload.
    let ratio_pc = rep_p.cycles as f64 / rep_c.cycles as f64;
    assert!(
        ratio_pc < 1.6,
        "proposed ({}) should be comparable to C toolchain ({})",
        rep_p.cycles,
        rep_c.cycles
    );
    let ratio_np = rep_n.cycles as f64 / rep_p.cycles as f64;
    assert!(
        ratio_np > 20.0,
        "naive ({}) should be far slower than proposed ({})",
        rep_n.cycles,
        rep_p.cycles
    );
}

/// The Table 2 single-layer shape: proposed within a small factor of the
/// C toolchain, naive in the 2-6x band.
#[test]
fn dense_single_layer_orderings() {
    let mut rng = Rng::new(1002);
    let model = mk_model(&mut rng, &[64, 64], 64);
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let x = rng.i8_vec(64 * 64);

    let graph = import_with_weight_chain(&model).unwrap();
    let proposed = Compiler::new(accel.clone()).compile(&graph).unwrap();
    let ct = compile_c_toolchain(&accel, &model).unwrap();
    let nb = compile_naive(&accel, &model).unwrap();

    let (op, rp) = proposed.run(&sim, &x).unwrap();
    let (oc, rc) = ct.run(&sim, &x).unwrap();
    let (on, rn) = nb.run(&sim, &x).unwrap();
    assert_eq!(op, oc);
    assert_eq!(op, on);

    let pc = rp.cycles as f64 / rc.cycles as f64;
    assert!(pc < 1.5, "proposed/C = {pc:.2} (p={}, c={})", rp.cycles, rc.cycles);
    let np = rn.cycles as f64 / rp.cycles as f64;
    assert!(np > 1.5, "naive/proposed = {np:.2}");
}

/// Custom accelerator from YAML: same functional description, different
/// architecture; outputs identical to Gemmini's.
#[test]
fn custom_arch_from_yaml_is_functionally_identical() {
    const YAML: &str = r#"
name: mini8
pe_array:
  dim: 8
  dataflows: [WS]
memory:
  - name: Accumulator
    size: 16384
    residents: [Output]
    elem_bytes: [1, 1, 4]
  - name: Scratchpad
    size: 65536
    residents: [Input, Weight]
dma:
  bytes_per_cycle: 8
  request_latency: 40
  per_row_overhead: 4
host:
  cycles_per_elem_alu: 4
  cycles_per_elem_move: 2
  insn_issue_cycles: 2
  fence_cycles: 20
constraints:
  insn_tile_limit: 8
  double_buffering: true
  memory_shares:
    - [0.5, 0.5, 1.0]
"#;
    let arch = arch_from_yaml(YAML).unwrap();
    let custom = desc_for_arch("mini8", arch).unwrap();
    let gemmini = gemmini_desc().unwrap();

    let mut rng = Rng::new(1003);
    let model = mk_model(&mut rng, &[48, 32, 24], 8);
    let graph = import_with_weight_chain(&model).unwrap();
    let x = rng.i8_vec(8 * 48);

    let mut outs = Vec::new();
    for accel in [&gemmini, &custom] {
        let dep = Compiler::new(accel.clone()).compile(&graph).unwrap();
        let sim = Simulator::new(&accel.arch);
        let (o, _) = dep.run(&sim, &x).unwrap();
        outs.push(o);
    }
    assert_eq!(outs[0], outs[1]);
}

/// Scheduling knobs must not change results, only performance.
#[test]
fn knobs_affect_cycles_not_results() {
    let mut rng = Rng::new(1004);
    let model = mk_model(&mut rng, &[128, 128], 128);
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let graph = import_with_weight_chain(&model).unwrap();
    let x = rng.i8_vec(128 * 128);

    let mut configs = Vec::new();
    for (ue, db) in [(true, true), (false, true), (true, false), (false, false)] {
        let opts = CompileOptions {
            sweep: tvm_accel::scheduler::sweep::SweepOptions {
                uneven_mapping: ue,
                double_buffering: db,
                ..Default::default()
            },
            profile_candidates: 2,
            ..Default::default()
        };
        let dep = Compiler::with_options(accel.clone(), opts).compile(&graph).unwrap();
        let (o, r) = dep.run(&sim, &x).unwrap();
        configs.push((o, r.cycles));
    }
    for w in configs.windows(2) {
        assert_eq!(w[0].0, w[1].0, "results differ across scheduler knobs");
    }
    // Full knobs should be at least as fast as none.
    assert!(configs[0].1 <= configs[3].1);
}

/// The staged session pipeline: full ToyCar-width compile with stage
/// reports, schedule-cache reuse across layers and across compiles, and
/// batched inference agreeing with individual runs.
#[test]
fn session_pipeline_cache_and_batch_on_toycar_widths() {
    let mut rng = Rng::new(1005);
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let model = mk_model(&mut rng, &widths, 1);
    let accel = gemmini_desc().unwrap();
    let graph = import_with_weight_chain(&model).unwrap();

    let compiler = Compiler::new(accel.clone());
    let out = compiler.compile_with_report(&graph).unwrap();
    let names: Vec<&str> = out.stages.iter().map(|s| s.name).collect();
    assert_eq!(
        names,
        ["frontend", "partition", "schedule", "crosslayer", "mapping", "codegen", "link"]
    );

    // 10 dense layers, but only 5 distinct GEMM shapes: the repeated
    // trunk layers must come from the cache within one compile. The
    // cross-layer stage may add boundary-constrained searches on top of
    // the 5 per-shape sweeps; those are memoized under their own keys.
    assert_eq!(out.schedule_stats.layers, 10);
    let sweeps_first = compiler.sweeps_run();
    assert!(sweeps_first >= 5, "at least one sweep per distinct layer shape");
    assert_eq!(out.schedule_stats.cache_hits, 5);

    // A second compile of the same graph performs zero additional sweeps
    // (boundary-constrained selections included).
    let again = compiler.compile(&graph).unwrap();
    assert_eq!(compiler.sweeps_run(), sweeps_first);
    assert_eq!(again.program.items, out.deployment.program.items);

    // Batched inference matches individual runs element- and cycle-exactly.
    let sim = Simulator::new(&accel.arch);
    let inputs: Vec<Vec<i8>> = (0..3).map(|_| rng.i8_vec(640)).collect();
    let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
    let batch = out.deployment.run_batch(&sim, &refs).unwrap();
    for (i, x) in inputs.iter().enumerate() {
        let (o, r) = out.deployment.run(&sim, x).unwrap();
        assert_eq!(batch.outputs[i], o);
        assert_eq!(batch.reports[i].cycles, r.cycles);
    }
    assert!(batch.pipelined_cycles <= batch.serial_cycles);
}

/// Heterogeneous compile: the ToyCar stack against the *set* of shipped
/// accelerator configs (Gemmini 16x16 WS + bigarray-os 32x32 OS) in one
/// deployment. Partition is cost-driven per layer, the stage report names
/// each layer's target and cost, execution (per-target instruction-stream
/// segments over shared DRAM) matches the interpreter element-exactly, and
/// a single-target multi compile stays byte-identical to the plain path.
#[test]
fn heterogeneous_toycar_across_shipped_configs() {
    use tvm_accel::arch::parse::arch_from_file;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut targets = Vec::new();
    for file in ["gemmini.yaml", "bigarray_os.yaml"] {
        let arch = arch_from_file(&dir.join(file)).unwrap();
        let name = arch.name.clone();
        targets.push(desc_for_arch(&name, arch).unwrap());
    }

    let mut rng = Rng::new(1006);
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let model = mk_model(&mut rng, &widths, 1);
    let graph = import_with_weight_chain(&model).unwrap();
    let x = rng.i8_vec(640);

    let multi = Compiler::with_targets(&targets).unwrap();
    let out = multi.compile_with_report(&graph).unwrap();
    let dep = &out.deployment;
    assert_eq!(dep.assignments.len(), 10, "every dense layer placed");
    for a in &dep.assignments {
        assert!(a.cycles.is_some(), "layer {} has a profiled cost", a.layer);
    }
    // The partition report lists target + cost per layer.
    let partition = out.stages.iter().find(|s| s.name == "partition").unwrap();
    assert!(
        partition.notes.len() >= 11,
        "headline + one note per layer, got {:?}",
        partition.notes
    );
    // 5 distinct shapes x 2 candidates: every probe beyond that is a
    // cache hit, and the schedule stage re-runs none of them (the
    // cross-layer stage may add boundary-constrained searches on top).
    // A second compile of the same graph pins the total down: everything
    // — probes and constrained re-searches included — must be warm.
    assert!(multi.sweeps_run() >= 10, "one sweep per (shape, candidate)");
    let sweeps_first = multi.sweeps_run();
    let again = multi.compile(&graph).unwrap();
    assert_eq!(multi.sweeps_run(), sweeps_first, "repeat compile must be sweep-free");
    assert_eq!(again.program.items, out.deployment.program.items);

    let mut inputs = BTreeMap::new();
    inputs.insert(
        "x".to_string(),
        Tensor::new(vec![1, 640], TensorData::I8(x.clone())).unwrap(),
    );
    let want = eval(&graph, &inputs).unwrap();
    let (got, rep) = dep.run(&x).unwrap();
    assert_eq!(TensorData::I8(got), want[0].data);
    assert!(rep.macs > 0);

    // Single-target compiles stay byte-identical to the plain compiler.
    let solo = Compiler::with_targets(&targets[..1]).unwrap().compile(&graph).unwrap();
    let plain = Compiler::new(targets[0].clone()).compile(&graph).unwrap();
    assert_eq!(solo.program.items, plain.program.items);
    assert_eq!(solo.segments.len(), 1);
}

/// The incremental-session memo: recompiling a model after changing ONE
/// layer's shape re-runs the schedule search for exactly that layer. The
/// shared cache is disabled so the memo is the only thing standing
/// between the unchanged layers and a fresh sweep.
#[test]
fn incremental_recompile_resweeps_only_the_changed_layer() {
    let opts = CompileOptions {
        schedule_cache: false, // isolate the memo from the shared cache
        cross_layer: false,    // no boundary-constrained re-searches
        ..Default::default()
    };
    let compiler = Compiler::with_options(gemmini_desc().unwrap(), opts.clone());
    let memo = SessionMemo::new();

    let mut rng = Rng::new(1007);
    let before = import_with_weight_chain(&mk_model(&mut rng, &[32, 48, 16], 4)).unwrap();
    let first = compiler.compile_incremental_with_report(&before, &memo).unwrap();
    assert_eq!(first.schedule_stats.searched, 2);
    assert_eq!(first.schedule_stats.memo_hits, 0);
    assert!(first.schedule_stats.solver_leaves > 0, "cold sweeps cost solver leaves");
    let sweeps_cold = compiler.sweeps_run();
    assert_eq!(sweeps_cold, 2, "one sweep per layer with the cache off");

    // Widen the output layer only: fc0 keeps its (4, 32, 48) GEMM, fc1
    // changes from (4, 48, 16) to (4, 48, 24).
    let after = import_with_weight_chain(&mk_model(&mut rng, &[32, 48, 24], 4)).unwrap();
    let second = compiler.compile_incremental_with_report(&after, &memo).unwrap();
    assert_eq!(
        compiler.sweeps_run(),
        sweeps_cold + 1,
        "only the changed layer re-runs the search"
    );
    assert_eq!(second.schedule_stats.memo_hits, 1);
    assert_eq!(second.schedule_stats.searched, 1);

    // The memo is a pure bypass: a further incremental compile of the
    // edited model is sweep-free, and its program is byte-identical to
    // what a cold compiler emits for the same graph.
    let third = compiler.compile_incremental(&after, &memo).unwrap();
    assert_eq!(compiler.sweeps_run(), sweeps_cold + 1, "fully warm recompile");
    let cold = Compiler::with_options(gemmini_desc().unwrap(), opts).compile(&after).unwrap();
    assert_eq!(third.program.items, cold.program.items);
    assert!(memo.hits() >= 3, "memo served stage-3 lookups across compiles");
}

/// The memo also serves the multi-target partitioner's cost probes: the
/// probes populate it during stage 2, so stage 3 re-schedules nothing,
/// and a repeat incremental compile runs zero sweeps even with the
/// shared cache disabled.
#[test]
fn incremental_memo_serves_multi_target_probes() {
    use tvm_accel::arch::parse::arch_from_file;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut targets = Vec::new();
    for file in ["gemmini.yaml", "bigarray_os.yaml"] {
        let arch = arch_from_file(&dir.join(file)).unwrap();
        let name = arch.name.clone();
        targets.push(desc_for_arch(&name, arch).unwrap());
    }
    let opts = CompileOptions {
        schedule_cache: false,
        cross_layer: false,
        ..Default::default()
    };
    let multi = MultiCompiler::with_options(targets, opts).unwrap();
    let memo = SessionMemo::new();

    let mut rng = Rng::new(1008);
    let graph = import_with_weight_chain(&mk_model(&mut rng, &[32, 48, 16], 4)).unwrap();
    let out = multi.compile_incremental_with_report(&graph, &memo).unwrap();
    let sweeps_first = multi.sweeps_run();
    assert!(sweeps_first >= 2, "each (shape, candidate) probe swept once");
    assert_eq!(
        out.schedule_stats.memo_hits, 2,
        "stage 3 reuses the partition probes' memo entries"
    );
    assert_eq!(out.schedule_stats.searched, 0);

    let again = multi.compile_incremental(&graph, &memo).unwrap();
    assert_eq!(multi.sweeps_run(), sweeps_first, "repeat incremental compile is sweep-free");
    assert_eq!(again.program.items, out.deployment.program.items);
}

/// Convolution support (paper Table 1 covers "2D convolution and dense"):
/// a QNN conv2d chain legalizes onto the GEMM path via the registered
/// im2col preprocessing; compiled output matches the direct-convolution
/// interpreter semantics element-exactly.
#[test]
fn conv2d_lowered_via_im2col_is_exact() {
    use tvm_accel::relay::{DType, GraphBuilder, Op, TensorType};

    let mut rng = Rng::new(2002);
    let (n, h, w, c, k, kh, kw) = (2usize, 8usize, 8usize, 3usize, 8usize, 3usize, 3usize);
    let (stride, pad) = (1usize, 1usize);

    let mut b = GraphBuilder::new();
    let x = b.input("x", TensorType::new(vec![n, h, w, c], DType::I8));
    let wt = b.constant(
        "w",
        Tensor::new(vec![k, kh, kw, c], TensorData::I8(rng.i8_vec(k * kh * kw * c))).unwrap(),
    );
    let bias = b.constant(
        "b",
        Tensor::new(
            vec![k],
            TensorData::I32((0..k).map(|_| rng.below(200) as i32 - 100).collect()),
        )
        .unwrap(),
    );
    let conv = b.op("conv", Op::QnnConv2d { stride, pad }, &[x, wt]).unwrap();
    let ba = b.op("bias", Op::BiasAdd, &[conv, bias]).unwrap();
    let rq = b.op("requant", Op::Requantize { scale: 0.02 }, &[ba]).unwrap();
    let act = b.op("relu", Op::Relu, &[rq]).unwrap();
    let g = b.outputs(&[act]);
    g.validate().unwrap();

    // Ground truth: direct convolution through the interpreter.
    let input = Tensor::new(vec![n, h, w, c], TensorData::I8(rng.i8_vec(n * h * w * c))).unwrap();
    let mut m = BTreeMap::new();
    m.insert("x".to_string(), input.clone());
    let want = eval(&g, &m).unwrap();

    // Frontend: legalize (conv → im2col + accel.dense) + fold + partition.
    let accel = gemmini_desc().unwrap();
    let fcfg = tvm_accel::frontend::configure(&accel);
    assert!(fcfg.legalize.conv2d, "conv2d must be enabled by the Gemmini description");
    let pg = tvm_accel::frontend::run_frontend(&g, &fcfg).unwrap();
    let hist = tvm_accel::relay::legalize::op_histogram(&pg.graph);
    assert_eq!(hist.get("qnn.conv2d"), None, "conv must legalize away:\n{}", pg.graph.dump());
    assert_eq!(hist.get("accel.dense"), Some(&1));
    assert_eq!(hist.get("im2col"), Some(&1), "activation im2col stays (host)");
    assert_eq!(hist.get("transpose"), None, "weight preprocessing folds");

    // Legalized semantics match direct convolution.
    let legalized_out = eval(&pg.graph, &m).unwrap();
    assert_eq!(want[0].data, legalized_out[0].data);

    // Full compile + simulate.
    let dep = Compiler::new(accel.clone()).compile(&g).unwrap();
    let sim = Simulator::new(&accel.arch);
    let (got, rep) = dep.run(&sim, input.data.as_i8().unwrap()).unwrap();
    assert_eq!(TensorData::I8(got), want[0].data);
    // The im2col preprocessing runs on the host (non-constant activation).
    assert!(rep.insn_counts.contains_key("host.im2col"));
    // The GEMM itself ran on the accelerator.
    assert!(rep.macs >= (n * (h * w) * kh * kw * c * k / 2) as u64);
}

/// Strided/padded conv variants stay exact through the full stack.
#[test]
fn conv2d_stride_and_pad_variants_exact() {
    use tvm_accel::relay::{DType, GraphBuilder, Op, TensorType};
    for (i, (stride, pad, hw, kk)) in
        [(2usize, 0usize, 9usize, 3usize), (1, 0, 6, 2), (2, 1, 8, 3)].iter().enumerate()
    {
        let mut rng = Rng::new(3000 + i as u64);
        let (n, c, k) = (1usize, 4usize, 5usize);
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![n, *hw, *hw, c], DType::I8));
        let wt = b.constant(
            "w",
            Tensor::new(vec![k, *kk, *kk, c], TensorData::I8(rng.i8_vec(k * kk * kk * c)))
                .unwrap(),
        );
        let conv = b
            .op("conv", Op::QnnConv2d { stride: *stride, pad: *pad }, &[x, wt])
            .unwrap();
        let rq = b.op("rq", Op::Requantize { scale: 0.03 }, &[conv]).unwrap();
        let g = b.outputs(&[rq]);

        let input =
            Tensor::new(vec![n, *hw, *hw, c], TensorData::I8(rng.i8_vec(n * hw * hw * c)))
                .unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), input.clone());
        let want = eval(&g, &m).unwrap();

        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel.clone()).compile(&g).unwrap();
        let sim = Simulator::new(&accel.arch);
        let (got, _) = dep.run(&sim, input.data.as_i8().unwrap()).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data, "variant {i}");
    }
}
