//! Wire-format conformance for the two machine-readable obs surfaces.
//!
//! * The `metrics` verb's payload must be valid Prometheus text
//!   exposition: every sample belongs to a family declared by exactly one
//!   `# HELP` and one `# TYPE` line, every value parses, and histogram
//!   buckets are cumulative and end at `le="+Inf"` with a consistent
//!   `_count`/`_sum` pair.
//! * `tvm-accel profile`'s output must be a structurally valid
//!   Chrome-trace-event JSON whose events carry known phases, whose
//!   compile spans nest properly, and whose per-track execution slices
//!   never overlap (the simulator's queues are in-order).

use std::collections::BTreeMap;

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::bench::square_model;
use tvm_accel::obs::{spans_to_chrome, timeline_to_chrome, ChromeTrace, Track};
use tvm_accel::pipeline::{CompileOptions, Compiler};
use tvm_accel::relay::import::to_qnn_graph;
use tvm_accel::service::CompileServer;
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

/// `[a-zA-Z_:][a-zA-Z0-9_:]*` — the Prometheus metric-name grammar.
fn metric_name_ok(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split one sample line into (metric name, label pairs, value).
fn parse_sample(line: &str) -> (String, Vec<(String, String)>, f64) {
    let (name_labels, value) = line.rsplit_once(' ').unwrap_or_else(|| {
        panic!("sample line has no value: {line:?}");
    });
    let value: f64 =
        value.parse().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
    let (name, labels) = match name_labels.split_once('{') {
        None => (name_labels.to_string(), Vec::new()),
        Some((n, rest)) => {
            let body = rest.strip_suffix('}').unwrap_or_else(|| {
                panic!("unclosed label set in {line:?}");
            });
            let mut pairs = Vec::new();
            for kv in body.split(',').filter(|s| !s.is_empty()) {
                let (k, v) = kv.split_once('=').unwrap_or_else(|| {
                    panic!("label without '=' in {line:?}");
                });
                let v = v
                    .strip_prefix('"')
                    .and_then(|v| v.strip_suffix('"'))
                    .unwrap_or_else(|| panic!("unquoted label value in {line:?}"));
                pairs.push((k.to_string(), v.to_string()));
            }
            (n.to_string(), pairs)
        }
    };
    (name, labels, value)
}

#[test]
fn metrics_exposition_conforms_to_prometheus_text_format() {
    let server = CompileServer::new(CompileOptions::default());
    let targets = vec![gemmini_desc().unwrap()];
    let model = square_model(32, 9).expect("model");
    server.compile_model(&model, &targets).expect("first compile");
    server.compile_model(&model, &targets).expect("second compile");
    let text = server.metrics_text();

    let mut help: BTreeMap<String, u32> = BTreeMap::new();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut samples: Vec<(String, Vec<(String, String)>, f64)> = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap();
            assert!(metric_name_ok(name), "bad family name in {line:?}");
            *help.entry(name.to_string()).or_insert(0) += 1;
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap();
            let ty = it.next().unwrap_or("");
            assert!(
                matches!(ty, "counter" | "gauge" | "histogram"),
                "unknown metric type in {line:?}"
            );
            assert!(
                types.insert(name.to_string(), ty.to_string()).is_none(),
                "family {name} declares TYPE twice"
            );
            assert_eq!(help.get(name), Some(&1), "family {name}: HELP must precede TYPE");
        } else if !line.is_empty() {
            let (name, labels, value) = parse_sample(line);
            assert!(metric_name_ok(&name), "bad sample name in {line:?}");
            assert!(value.is_finite(), "non-finite value in {line:?}");
            samples.push((name, labels, value));
        }
    }
    for (name, n) in &help {
        assert_eq!(*n, 1, "family {name} declares HELP {n} times");
        assert!(types.contains_key(name), "family {name} has HELP but no TYPE");
    }

    // Every sample belongs to a declared family (histogram samples via
    // their _bucket/_sum/_count suffixes).
    for (name, _, _) in &samples {
        let family = types.contains_key(name)
            || ["_bucket", "_sum", "_count"].iter().any(|suf| {
                name.strip_suffix(suf)
                    .is_some_and(|f| types.get(f).map(String::as_str) == Some("histogram"))
            });
        assert!(family, "sample {name} belongs to no declared family");
    }

    // The serve-path families the CI smoke test scrapes.
    for family in [
        "tvmaccel_requests_total",
        "tvmaccel_requests_in_flight",
        "tvmaccel_cache_hits_total",
        "tvmaccel_cache_misses_total",
        "tvmaccel_schedule_sweeps_total",
        "tvmaccel_cache_entries",
        "tvmaccel_compile_duration_seconds",
        "tvmaccel_stage_duration_seconds",
    ] {
        assert!(types.contains_key(family), "expected family {family} missing:\n{text}");
    }

    // Histogram conformance on the single-series compile-latency family:
    // buckets cumulative, closed by +Inf, consistent with _count/_sum.
    let buckets: Vec<(String, f64)> = samples
        .iter()
        .filter(|(n, _, _)| n == "tvmaccel_compile_duration_seconds_bucket")
        .map(|(_, labels, v)| {
            let le = labels
                .iter()
                .find(|(k, _)| k == "le")
                .map(|(_, v)| v.clone())
                .expect("bucket sample without le label");
            (le, *v)
        })
        .collect();
    assert!(buckets.len() >= 2, "histogram renders its bucket series");
    for w in buckets.windows(2) {
        assert!(w[1].1 >= w[0].1, "buckets must be cumulative: {buckets:?}");
    }
    assert_eq!(buckets.last().unwrap().0, "+Inf", "bucket series must end at +Inf");
    let count = samples
        .iter()
        .find(|(n, _, _)| n == "tvmaccel_compile_duration_seconds_count")
        .map(|(_, _, v)| *v)
        .expect("_count sample");
    assert_eq!(count, buckets.last().unwrap().1, "+Inf bucket must equal _count");
    assert_eq!(count, 2.0, "two compiles were observed");
    assert!(
        samples.iter().any(|(n, _, _)| n == "tvmaccel_compile_duration_seconds_sum"),
        "_sum sample present"
    );

    // The per-stage histogram carries its stage label alongside le.
    assert!(
        samples.iter().any(|(n, labels, _)| {
            n == "tvmaccel_stage_duration_seconds_bucket"
                && labels.iter().any(|(k, v)| k == "stage" && v == "schedule")
                && labels.iter().any(|(k, v)| k == "le" && v == "+Inf")
        }),
        "schedule-stage latency series missing:\n{text}"
    );
}

/// Minimal structural JSON check: balanced braces/brackets outside
/// strings, escapes honored, nothing dangling.
fn assert_well_formed_json(s: &str) {
    let mut stack = Vec::new();
    let mut in_str = false;
    let mut esc = false;
    for c in s.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => stack.push(c),
            '}' => assert_eq!(stack.pop(), Some('{'), "unbalanced braces"),
            ']' => assert_eq!(stack.pop(), Some('['), "unbalanced brackets"),
            _ => {}
        }
    }
    assert!(!in_str, "unterminated string");
    assert!(stack.is_empty(), "unclosed container(s): {stack:?}");
}

#[test]
fn profile_trace_is_well_formed_chrome_json() {
    let model = square_model(32, 11).expect("model");
    let graph = to_qnn_graph(&model).expect("import");
    let accel = gemmini_desc().unwrap();
    let out = Compiler::new(accel.clone()).compile_traced(&graph).expect("compile");
    let sim = Simulator::new(&accel.arch);
    let input = Rng::new(3).i8_vec(model.batch * model.layers[0].in_dim);
    let (_, _, tl) = out.deployment.run_profiled(&sim, &input).expect("run");

    // Spans nest: every child interval sits inside its parent's.
    let spans = out.trace.spans();
    for s in &spans {
        if let Some(p) = s.parent {
            assert!(
                s.start_ns >= spans[p].start_ns && s.end_ns <= spans[p].end_ns,
                "span {} escapes its parent {}",
                s.name,
                spans[p].name
            );
        }
    }

    // Per-track slices never overlap (each simulator queue is in-order,
    // and DMA occupancy serializes transfers).
    for track in [Track::Dma, Track::Compute, Track::Store, Track::Host] {
        let mut on_track: Vec<(u64, u64)> = tl
            .slices
            .iter()
            .filter(|s| s.track == track)
            .map(|s| (s.start, s.end))
            .collect();
        on_track.sort_unstable();
        for w in on_track.windows(2) {
            assert!(
                w[1].0 >= w[0].1,
                "{} track overlaps: {:?} then {:?}",
                track.name(),
                w[0],
                w[1]
            );
        }
    }
    assert!(
        tl.slices.iter().any(|s| s.track == Track::Dma),
        "the run staged data over DMA"
    );
    assert!(
        tl.slices.iter().any(|s| s.track == Track::Compute),
        "the run computed something"
    );

    // Exported JSON: structurally valid, known event phases only, and
    // the metadata that names processes/tracks is present.
    let mut ct = ChromeTrace::new();
    ct.process_name(1, "compile pipeline");
    ct.thread_name(1, 1, "stages");
    spans_to_chrome(&mut ct, 1, 1, &spans);
    ct.process_name(2, &accel.name);
    timeline_to_chrome(&mut ct, 2, &tl);
    let json = ct.render();

    assert_well_formed_json(&json);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.ends_with("],\"displayTimeUnit\":\"ms\"}"));
    for chunk in json.split("\"ph\":\"").skip(1) {
        let ph = chunk.chars().next().unwrap();
        assert!(
            matches!(ph, 'X' | 'i' | 'M'),
            "unexpected event phase {ph:?} in trace"
        );
    }
    assert!(json.contains("\"name\":\"process_name\""));
    assert!(json.contains("\"name\":\"compile\""));
    assert!(json.contains("\"name\":\"mvin\""), "DMA slices exported");
}
