//! Integration: the compiled accelerator programs must agree
//! element-exactly with the XLA golden models built by `make artifacts`
//! (the JAX + Pallas computations loaded through PJRT).
//!
//! These tests skip with a notice when artifacts are absent so `cargo
//! test` works on a fresh checkout; `make test` always builds them first.

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::compile_naive;
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::load_qmodel;
use tvm_accel::runtime::{artifacts_dir, golden_inputs, Runtime};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("toycar.qmodel").exists();
    if !ok {
        eprintln!("skipping golden test: run `make artifacts` first");
    }
    ok
}

fn check_model(name: &str, inferences: usize, seed: u64) {
    let dir = artifacts_dir();
    let model = load_qmodel(&dir.join(format!("{name}.qmodel"))).unwrap();
    let rt = Runtime::cpu().unwrap();
    let golden = rt.load_hlo_text(&dir.join(format!("{name}.hlo.txt"))).unwrap();

    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    let graph = tvm_accel::relay::import::to_qnn_graph(&model).unwrap();
    let dep = Compiler::new(accel.clone()).compile(&graph).unwrap();

    let mut rng = Rng::new(seed);
    for i in 0..inferences {
        let x = rng.i8_vec(model.batch * model.layers[0].in_dim);
        let want = golden
            .run(&golden_inputs(&model, &x).unwrap())
            .unwrap()
            .to_vec::<i8>()
            .unwrap();
        let (got, _) = dep.run(&sim, &x).unwrap();
        assert_eq!(got, want, "{name}: inference {i} mismatch vs XLA golden");
    }
}

#[test]
fn toycar_matches_xla_golden() {
    if !have_artifacts() {
        return;
    }
    check_model("toycar", 5, 11);
}

#[test]
fn dense64_matches_xla_golden() {
    if !have_artifacts() {
        return;
    }
    check_model("dense_64", 3, 12);
}

#[test]
fn dense128_matches_xla_golden() {
    if !have_artifacts() {
        return;
    }
    check_model("dense_128", 2, 13);
}

#[test]
fn pallas_and_ref_hlo_agree() {
    // The Pallas-kernel HLO and the pure-jnp oracle HLO are different
    // programs; both must produce identical outputs through PJRT.
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let model = load_qmodel(&dir.join("toycar.qmodel")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let pallas = rt.load_hlo_text(&dir.join("toycar.hlo.txt")).unwrap();
    let oracle = rt.load_hlo_text(&dir.join("toycar_ref.hlo.txt")).unwrap();
    let mut rng = Rng::new(14);
    for _ in 0..3 {
        let x = rng.i8_vec(model.batch * model.layers[0].in_dim);
        let ins = golden_inputs(&model, &x).unwrap();
        let a = pallas.run(&ins).unwrap().to_vec::<i8>().unwrap();
        let ins2 = golden_inputs(&model, &x).unwrap();
        let b = oracle.run(&ins2).unwrap().to_vec::<i8>().unwrap();
        assert_eq!(a, b, "Pallas HLO != oracle HLO");
    }
}

#[test]
fn all_backends_match_golden_on_toycar() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let model = load_qmodel(&dir.join("toycar.qmodel")).unwrap();
    let rt = Runtime::cpu().unwrap();
    let golden = rt.load_hlo_text(&dir.join("toycar.hlo.txt")).unwrap();
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);

    let graph = tvm_accel::baselines::naive_byoc::import_with_weight_chain(&model).unwrap();
    let proposed = Compiler::new(accel.clone()).compile(&graph).unwrap();
    let ct = compile_c_toolchain(&accel, &model).unwrap();
    let nb = compile_naive(&accel, &model).unwrap();

    let mut rng = Rng::new(15);
    let x = rng.i8_vec(model.batch * model.layers[0].in_dim);
    let want = golden
        .run(&golden_inputs(&model, &x).unwrap())
        .unwrap()
        .to_vec::<i8>()
        .unwrap();
    for (name, dep) in [("proposed", &proposed), ("c_toolchain", &ct), ("naive", &nb)] {
        let (got, _) = dep.run(&sim, &x).unwrap();
        assert_eq!(got, want, "{name} != golden");
    }
}
