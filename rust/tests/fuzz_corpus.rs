//! Seed regression corpus: every `.repro` file under `tests/corpus/` is
//! a past (or representative) fuzz reproducer, replayed here through the
//! *full* differential oracle — which iterates every registered backend
//! — on every `cargo test` run, so past fuzz finds stay fixed as
//! permanent tier-1 tests.
//!
//! To promote a new finding: copy the minimized reproducer the fuzzer
//! wrote (`fuzz-reproducers/seed-<hex>.repro` by default) into
//! `tests/corpus/` and commit it; this test picks it up by glob.

use std::collections::BTreeMap;
use std::ffi::OsStr;
use std::path::PathBuf;

use tvm_accel::backend;
use tvm_accel::fuzz::{check_case, load_repro_tagged, parse_repro_tagged, write_repro_tagged};
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::to_qnn_graph;
use tvm_accel::relay::{Tensor, TensorData};
use tvm_accel::sim::Simulator;

fn corpus_entries() -> Vec<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension() == Some(OsStr::new("repro")))
        .collect();
    entries.sort();
    entries
}

#[test]
fn every_corpus_entry_passes_every_axis() {
    let entries = corpus_entries();
    assert!(!entries.is_empty(), "the committed corpus must not be empty");
    for path in &entries {
        let (case, _) =
            load_repro_tagged(path).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let verdict = check_case(&case);
        assert!(
            verdict.passed(),
            "{} (seed {:#018x}) regressed: {verdict:?}",
            path.display(),
            case.seed
        );
    }
}

/// Beyond the oracle's composite verdict: pin the per-backend claim
/// directly. Every corpus case, compiled single-target on *each*
/// registered backend's default description, matches the interpreter
/// element-exactly on every input.
#[test]
fn every_corpus_entry_is_exact_on_every_registered_backend() {
    for path in &corpus_entries() {
        let (case, _) = load_repro_tagged(path).unwrap();
        let graph = to_qnn_graph(&case.model).unwrap();
        for b in backend::backends() {
            let accel = b.default_desc().unwrap_or_else(|e| {
                panic!("{}: backend {}: default_desc: {e:#}", path.display(), b.id())
            });
            let dep = Compiler::new(accel.clone()).compile(&graph).unwrap_or_else(|e| {
                panic!("{}: backend {}: compile: {e:#}", path.display(), b.id())
            });
            let sim = Simulator::new(&accel.arch);
            for (i, input) in case.inputs.iter().enumerate() {
                let mut m = BTreeMap::new();
                m.insert(
                    "x".to_string(),
                    Tensor::new(
                        vec![case.model.batch, case.model.layers[0].in_dim],
                        TensorData::I8(input.clone()),
                    )
                    .unwrap(),
                );
                let want = eval(&graph, &m).unwrap()[0].data.as_i8().unwrap().to_vec();
                let (got, _) = dep.run(&sim, input).unwrap_or_else(|e| {
                    panic!("{}: backend {}: run: {e:#}", path.display(), b.id())
                });
                assert_eq!(
                    got,
                    want,
                    "{} (seed {:#018x}) input {i} diverges on backend {}",
                    path.display(),
                    case.seed,
                    b.id()
                );
            }
        }
    }
}

#[test]
fn corpus_entries_roundtrip_byte_identically() {
    // A committed reproducer must be in canonical form: re-serializing
    // the parsed case (with its recorded backend) yields the exact file
    // bytes, so corpus diffs stay reviewable.
    for path in &corpus_entries() {
        let bytes = std::fs::read(path).unwrap();
        let (case, backend) = parse_repro_tagged(&bytes).unwrap();
        assert_eq!(
            write_repro_tagged(&case, &backend),
            bytes,
            "{} is not in canonical serialized form",
            path.display()
        );
    }
}
