//! Seed regression corpus: every `.repro` file under `tests/corpus/` is
//! a past (or representative) fuzz reproducer, replayed here through the
//! *full* differential oracle on every `cargo test` run — past fuzz
//! finds stay fixed as permanent tier-1 tests.
//!
//! To promote a new finding: copy the minimized reproducer the fuzzer
//! wrote (`fuzz-reproducers/seed-<hex>.repro` by default) into
//! `tests/corpus/` and commit it; this test picks it up by glob.

use std::ffi::OsStr;
use std::path::PathBuf;

use tvm_accel::fuzz::{check_case, load_repro, parse_repro, write_repro};

fn corpus_entries() -> Vec<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension() == Some(OsStr::new("repro")))
        .collect();
    entries.sort();
    entries
}

#[test]
fn every_corpus_entry_passes_every_axis() {
    let entries = corpus_entries();
    assert!(!entries.is_empty(), "the committed corpus must not be empty");
    for path in &entries {
        let case = load_repro(path).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let verdict = check_case(&case);
        assert!(
            verdict.passed(),
            "{} (seed {:#018x}) regressed: {verdict:?}",
            path.display(),
            case.seed
        );
    }
}

#[test]
fn corpus_entries_roundtrip_byte_identically() {
    // A committed reproducer must be in canonical form: re-serializing
    // the parsed case yields the exact file bytes, so corpus diffs stay
    // reviewable.
    for path in &corpus_entries() {
        let bytes = std::fs::read(path).unwrap();
        let case = parse_repro(&bytes).unwrap();
        assert_eq!(
            write_repro(&case),
            bytes,
            "{} is not in canonical serialized form",
            path.display()
        );
    }
}
