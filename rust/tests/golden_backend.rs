//! Golden-hash byte-identity for the Gemmini backend.
//!
//! The backend-trait refactor's safety net: compiling the ToyCar stack
//! and every Table-2 square workload through the (trait-dispatched)
//! pipeline must emit programs whose disassembly *and* encoded command
//! words hash exactly to the values recorded in
//! `tests/golden/gemmini_hashes.json`. Any codegen or encoding drift —
//! however plausible-looking — fails here first.
//!
//! Bootstrap: the committed file starts as `{"bootstrap":"1"}`. In that
//! state the test *records* the measured hashes into the file (and
//! passes); CI's golden-hash step commits the recorded file from a green
//! run, arming the check for every run after. To intentionally accept a
//! codegen change, reset the file to the bootstrap sentinel and let CI
//! re-record.

use std::path::PathBuf;

use tvm_accel::accel::AccelDesc;
use tvm_accel::backend::Backend;
use tvm_accel::baselines::naive_byoc::import_with_weight_chain;
use tvm_accel::bench;
use tvm_accel::isa::program::{Item, Program};
use tvm_accel::pipeline::Compiler;
use tvm_accel::scheduler::persist::fnv1a64;
use tvm_accel::service::protocol::{parse_message, ObjBuilder};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/gemmini_hashes.json")
}

fn gem() -> AccelDesc {
    tvm_accel::accel::gemmini::gemmini_desc().expect("gemmini desc")
}

/// `(disassembly fnv, encoded-command-words fnv)` of one program, both
/// as fixed-width hex. The words hash encodes every accelerator
/// instruction through the backend codec, so it pins the binary
/// encoding as well as the instruction stream.
fn program_hashes(prog: &Program, backend: &dyn Backend) -> (String, String) {
    let disasm = fnv1a64(prog.disassemble().as_bytes());
    let mut bytes = Vec::new();
    for item in &prog.items {
        if let Item::Accel(i) = item {
            for w in backend.encode(i) {
                bytes.push(w.funct);
                bytes.extend_from_slice(&w.rs1.to_le_bytes());
                bytes.extend_from_slice(&w.rs2.to_le_bytes());
            }
        }
    }
    (format!("{disasm:016x}"), format!("{:016x}", fnv1a64(&bytes)))
}

/// Compile the golden suite (Table-2 squares + ToyCar) and hash every
/// program. Deterministic: seeded models, deterministic search.
fn measure() -> Vec<(String, String, String)> {
    let mut out = Vec::new();
    for (name, model) in bench::standard_suite().expect("suite builds") {
        let graph = import_with_weight_chain(&model).expect("import");
        let compiler = Compiler::new(gem());
        let backend = compiler.backend().expect("registered backend");
        let dep = compiler.compile(&graph).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let (d, w) = program_hashes(&dep.program, backend);
        out.push((name, d, w));
    }
    out
}

fn render(measured: &[(String, String, String)]) -> String {
    let mut b = ObjBuilder::new();
    for (name, d, w) in measured {
        b = b.str_field(&format!("{name}.disasm"), d).str_field(&format!("{name}.words"), w);
    }
    b.finish() + "\n"
}

#[test]
fn gemmini_programs_match_golden_hashes() {
    let path = golden_path();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e} (commit the bootstrap sentinel)", path.display()));
    let golden = parse_message(text.trim())
        .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));

    let measured = measure();
    assert!(!measured.is_empty());

    if golden.str_field("bootstrap").is_some() {
        // Record mode: write the measured hashes where CI's golden-hash
        // step will commit them from a green run.
        std::fs::write(&path, render(&measured))
            .unwrap_or_else(|e| panic!("recording {}: {e}", path.display()));
        eprintln!(
            "WARNING: golden hashes were in bootstrap mode — recorded {} entries to {}; \
             byte-identity is NOT being checked until the recorded file is committed.",
            2 * measured.len(),
            path.display()
        );
        return;
    }

    for (name, disasm, words) in &measured {
        assert_eq!(
            golden.str_field(&format!("{name}.disasm")),
            Some(disasm.as_str()),
            "{name}: disassembly hash drifted (reset {} to {{\"bootstrap\":\"1\"}} only if \
             the codegen change is intentional)",
            path.display()
        );
        assert_eq!(
            golden.str_field(&format!("{name}.words")),
            Some(words.as_str()),
            "{name}: encoded-command-words hash drifted (binary encoding changed)",
            path.display()
        );
    }
}

#[test]
fn golden_hashes_are_stable_across_compiles() {
    // The hashes themselves must be reproducible within a process, or
    // the golden file could never be trusted: compile the smallest suite
    // entry twice and require identical hashes.
    let model = bench::square_model(64, 500).expect("model");
    let graph = import_with_weight_chain(&model).expect("import");
    let hashes: Vec<(String, String)> = (0..2)
        .map(|_| {
            let c = Compiler::new(gem());
            let b = c.backend().expect("backend");
            let dep = c.compile(&graph).unwrap_or_else(|e| panic!("{e:#}"));
            program_hashes(&dep.program, b)
        })
        .collect();
    assert_eq!(hashes[0], hashes[1], "golden hashing must be deterministic");
}
