//! Cross-layer residency integration tests (the tentpole acceptance bar):
//!
//! * a ToyCar deployment with ≥1 resident edge produces element-exact
//!   outputs versus the non-resident baseline while spending strictly
//!   fewer DRAM-transfer cycles;
//! * single-layer models and residency-infeasible graphs emit
//!   byte-identical programs with the pass on or off;
//! * random MLPs compiled with residency stay exact end to end (the
//!   capacity property itself is unit-tested in `scheduler::graph`).

use std::collections::BTreeMap;

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::pipeline::{CompileOptions, Compiler};
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::{synth_qmodel, to_qnn_graph};
use tvm_accel::relay::{Graph, Tensor, TensorData};
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

fn no_cross_layer() -> CompileOptions {
    CompileOptions { cross_layer: false, ..Default::default() }
}

fn mlp_graph(seed: u64, dims: &[usize], batch: usize) -> Graph {
    to_qnn_graph(&synth_qmodel(seed, dims, batch).unwrap()).unwrap()
}

#[test]
fn toycar_resident_edges_exact_with_fewer_dram_cycles() {
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    let graph = mlp_graph(501, &widths, 1);
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);

    let resident = Compiler::new(accel.clone()).compile_with_report(&graph).unwrap();
    assert!(
        resident.schedule_stats.resident_edges >= 1,
        "ToyCar activations fit on-chip; the cross-layer pass must keep at least one \
         edge resident (stages: {})",
        resident.render_stages()
    );
    let baseline =
        Compiler::with_options(accel.clone(), no_cross_layer()).compile(&graph).unwrap();

    let mut rng = Rng::new(502);
    for i in 0..3 {
        let x = rng.i8_vec(640);
        let (got_r, rep_r) = resident.deployment.run(&sim, &x).unwrap();
        let (got_b, rep_b) = baseline.run(&sim, &x).unwrap();
        assert_eq!(got_r, got_b, "inference {i}: resident output diverged from baseline");

        // Both agree with the interpreter (semantic ground truth).
        let mut m = BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![1, 640], TensorData::I8(x.clone())).unwrap(),
        );
        let want = eval(&graph, &m).unwrap();
        assert_eq!(TensorData::I8(got_r), want[0].data, "inference {i} vs interpreter");

        // The elided store+reload pairs show up as strictly fewer
        // DRAM-transfer cycles (and bytes), with the on-chip park in the
        // instruction stream instead.
        assert!(
            rep_r.dram_transfer_cycles < rep_b.dram_transfer_cycles,
            "inference {i}: resident {} DRAM-transfer cycles vs baseline {}",
            rep_r.dram_transfer_cycles,
            rep_b.dram_transfer_cycles
        );
        assert!(rep_r.dram_read_bytes < rep_b.dram_read_bytes);
        assert!(rep_r.dram_write_bytes < rep_b.dram_write_bytes);
        assert!(rep_r.insn_counts.contains_key("mvout_spad"));
        assert!(!rep_b.insn_counts.contains_key("mvout_spad"));
    }
}

#[test]
fn single_layer_models_byte_identical_with_pass_on_or_off() {
    let graph = mlp_graph(503, &[64, 32], 4);
    let accel = gemmini_desc().unwrap();
    let on = Compiler::new(accel.clone()).compile(&graph).unwrap();
    let off = Compiler::with_options(accel, no_cross_layer()).compile(&graph).unwrap();
    assert_eq!(
        on.program.items, off.program.items,
        "a single-layer model has no edges: the pass must be a no-op"
    );
    assert_eq!(on.program.disassemble(), off.program.disassemble());
}

#[test]
fn host_op_between_layers_blocks_residency_byte_identically() {
    use tvm_accel::isa::Activation;
    use tvm_accel::relay::{DType, GraphBuilder, Op, TensorType};

    // accel.dense -> transpose (host) -> accel.dense: the producer's
    // activation is consumed by a host op, so no edge is resident and the
    // emitted program must be byte-identical to the pass-off pipeline.
    let mut b = GraphBuilder::new();
    let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
    let mk_dense = |b: &mut GraphBuilder, name: &str, x, c: usize, k: usize| {
        let w = b.constant(
            format!("{name}_w"),
            Tensor::new(vec![c, k], TensorData::I8(vec![1; c * k])).unwrap(),
        );
        let bias = b.constant(
            format!("{name}_b"),
            Tensor::new(vec![k], TensorData::I32(vec![0; k])).unwrap(),
        );
        b.op(
            name,
            Op::AccelDense { scale: 1.0, act: Activation::None, weight_transposed: true },
            &[x, w, bias],
        )
        .unwrap()
    };
    let l1 = mk_dense(&mut b, "l1", x, 8, 8);
    let t = b.op("t", Op::Transpose, &[l1]).unwrap();
    let l2 = mk_dense(&mut b, "l2", t, 8, 8);
    let g = b.outputs(&[l2]);

    let accel = gemmini_desc().unwrap();
    let on = Compiler::new(accel.clone()).compile_with_report(&g).unwrap();
    let off = Compiler::with_options(accel, no_cross_layer()).compile(&g).unwrap();
    assert_eq!(on.schedule_stats.resident_edges, 0);
    assert_eq!(on.deployment.program.items, off.program.items);
}

#[test]
fn prop_random_mlps_with_residency_stay_exact() {
    let accel = gemmini_desc().unwrap();
    let sim = Simulator::new(&accel.arch);
    tvm_accel::util::prop::check("cross-layer e2e exact", 6, |rng| {
        let pick = [8usize, 16, 24, 32, 48, 64];
        let n_layers = rng.range(2, 4);
        let mut dims = Vec::with_capacity(n_layers + 1);
        for _ in 0..=n_layers {
            dims.push(*rng.pick(&pick));
        }
        let batch = *rng.pick(&[1usize, 2, 4, 8]);
        let graph = mlp_graph(rng.next_u64(), &dims, batch);

        let resident = Compiler::new(accel.clone())
            .compile(&graph)
            .map_err(|e| format!("resident compile failed for {dims:?}: {e:#}"))?;
        let baseline = Compiler::with_options(accel.clone(), no_cross_layer())
            .compile(&graph)
            .map_err(|e| format!("baseline compile failed for {dims:?}: {e:#}"))?;

        let x = rng.i8_vec(batch * dims[0]);
        let (got_r, rep_r) =
            resident.run(&sim, &x).map_err(|e| format!("resident run: {e:#}"))?;
        let (got_b, rep_b) =
            baseline.run(&sim, &x).map_err(|e| format!("baseline run: {e:#}"))?;
        if got_r != got_b {
            return Err(format!("outputs diverged for dims {dims:?} batch {batch}"));
        }
        if rep_r.dram_transfer_cycles > rep_b.dram_transfer_cycles {
            return Err(format!(
                "residency increased DRAM transfer cycles for dims {dims:?}: {} > {}",
                rep_r.dram_transfer_cycles, rep_b.dram_transfer_cycles
            ));
        }
        Ok(())
    });
}
