//! Property tests for the graph-level overlapped execution schedule.
//!
//! For every multi-target pairing the fuzzer exercises (the heterogeneous
//! systolic pair and the cross-family gemmini+vector pair), compile a
//! bottlenecked MLP, run it under the overlapped executor, and check the
//! schedule's structural promises:
//!
//! * outputs stay element-exact against the graph interpreter (the
//!   overlap is a timing reinterpretation, never a functional change);
//! * the overlapped makespan never exceeds the serial handoff total;
//! * data dependencies hold — a consumer segment's first read of its
//!   boundary region never lands before the producer released it;
//! * per-target tracks never self-overlap: segment windows on one target
//!   are disjoint, and the shifted profiler timelines pass the same
//!   per-track non-overlap check `obs_format.rs` applies to single runs.

use std::collections::BTreeMap;

use tvm_accel::fuzz::oracle::multi_target_pairings;
use tvm_accel::obs::timeline::{Timeline, Track};
use tvm_accel::pipeline::{MultiCompiler, OverlapReport, ProgramSegment};
use tvm_accel::relay::eval::eval;
use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
use tvm_accel::relay::{Graph, Tensor, TensorData};
use tvm_accel::util::prng::Rng;

/// A seeded quantized MLP with the given layer widths.
fn mlp_graph(seed: u64, dims: &[usize], batch: usize) -> Graph {
    let mut rng = Rng::new(seed);
    let layers: Vec<FloatDense> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < dims.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..dims.len()).map(|i| 0.03 + 0.004 * i as f32).collect();
    let model = from_quantized(batch, scales[0], &quantize_mlp(&layers, &scales).unwrap());
    to_qnn_graph(&model).unwrap()
}

/// The schedule-level invariants every overlapped run must satisfy.
fn check_schedule(tag: &str, segments: &[ProgramSegment], ov: &OverlapReport) {
    let n = segments.len();
    assert_eq!(ov.starts.len(), n, "{tag}: one start per segment");
    assert_eq!(ov.durations.len(), n, "{tag}: one duration per segment");
    assert!(
        ov.overlapped_cycles <= ov.serial_cycles,
        "{tag}: overlapped {} > serial {}",
        ov.overlapped_cycles,
        ov.serial_cycles
    );
    assert_eq!(
        ov.serial_cycles,
        ov.durations.iter().sum::<u64>(),
        "{tag}: serial total is the duration sum"
    );
    assert_eq!(
        ov.overlapped_cycles,
        ov.starts.iter().zip(&ov.durations).map(|(s, d)| s + d).max().unwrap_or(0),
        "{tag}: makespan is the latest segment finish"
    );
    for i in 0..n {
        assert!(ov.heads[i] <= ov.durations[i], "{tag}: head within segment {i}");
        assert!(ov.readies[i] <= ov.durations[i], "{tag}: ready within segment {i}");
    }
    // Data dependency: segment i's first boundary read happens at or
    // after its producer's release (the producer's last boundary write).
    for i in 1..n {
        assert!(
            ov.starts[i] + ov.heads[i] >= ov.starts[i - 1] + ov.readies[i - 1],
            "{tag}: segment {i} reads its boundary at {} before producer released at {}",
            ov.starts[i] + ov.heads[i],
            ov.starts[i - 1] + ov.readies[i - 1]
        );
    }
    // Per-target tracks never self-overlap: the busy windows of all
    // segments placed on one target are pairwise disjoint.
    let mut per_target: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
    for (i, seg) in segments.iter().enumerate() {
        per_target
            .entry(seg.target)
            .or_default()
            .push((ov.starts[i], ov.starts[i] + ov.durations[i]));
    }
    for (target, mut windows) in per_target {
        windows.sort_unstable();
        for w in windows.windows(2) {
            assert!(
                w[1].0 >= w[0].1,
                "{tag}: target {target} self-overlaps: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
    }
}

/// The `obs_format.rs` per-track non-overlap check, applied to the merged
/// shifted timelines of every segment that ran on one target.
fn check_tracks(tag: &str, name: &str, timelines: &[&Timeline]) {
    for track in [Track::Dma, Track::Compute, Track::Store, Track::Host] {
        let mut on_track: Vec<(u64, u64)> = timelines
            .iter()
            .flat_map(|tl| tl.slices.iter())
            .filter(|s| s.track == track)
            .map(|s| (s.start, s.end))
            .collect();
        on_track.sort_unstable();
        for w in on_track.windows(2) {
            assert!(
                w[1].0 >= w[0].1,
                "{tag}: {name} {} track overlaps across segments: {:?} then {:?}",
                track.name(),
                w[0],
                w[1]
            );
        }
    }
}

#[test]
fn overlapped_schedule_respects_dependencies_on_every_pairing() {
    // A bottlenecked stack (wide → narrow → wide) at small batch: the
    // shape mix that makes cost-driven partitions place layers on
    // different targets when the models disagree about the bottleneck.
    let dims = [96usize, 64, 8, 48];
    let batch = 2;
    let graph = mlp_graph(31, &dims, batch);
    let mut rng = Rng::new(77);
    let input = rng.i8_vec(batch * dims[0]);
    let mut m = BTreeMap::new();
    m.insert(
        "x".to_string(),
        Tensor::new(vec![batch, dims[0]], TensorData::I8(input.clone())).unwrap(),
    );
    let want = eval(&graph, &m).unwrap();

    for (tag, targets) in multi_target_pairings().unwrap() {
        let dep = MultiCompiler::new(targets).unwrap().compile(&graph).unwrap();
        let (got, rep, ov) = dep.run_overlapped(&input).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data, "{tag}: overlapped run is exact");
        assert_eq!(rep.cycles, ov.serial_cycles, "{tag}");
        assert_eq!(rep.overlapped_cycles, ov.overlapped_cycles, "{tag}");
        check_schedule(tag, &dep.segments, &ov);

        // Profiled timelines sit at the overlapped starts; per target,
        // the merged tracks must still be non-overlapping.
        let (got2, rep2, timelines) = dep.run_profiled(&input).unwrap();
        assert_eq!(TensorData::I8(got2), want[0].data, "{tag}: profiled run is exact");
        assert_eq!(rep2.cycles, rep.cycles, "{tag}: profiling is passive");
        assert_eq!(timelines.len(), dep.segments.len(), "{tag}");
        let names: Vec<&str> = timelines.iter().map(|(n, _)| n.as_str()).collect();
        for name in &names {
            let on_target: Vec<&Timeline> = timelines
                .iter()
                .filter(|(n, _)| n == name)
                .map(|(_, tl)| tl)
                .collect();
            check_tracks(tag, name, &on_target);
        }
    }
}

#[test]
fn overlapped_never_exceeds_serial_across_shapes() {
    // Sweep a few shapes/batches per pairing; the ≤ invariant must hold
    // on every compile, split or not.
    let cases: [(&[usize], usize, u64); 3] =
        [(&[64, 96, 32], 4, 5), (&[32, 8, 32], 1, 6), (&[48, 48, 48, 48], 2, 7)];
    for (dims, batch, seed) in cases {
        let graph = mlp_graph(seed, dims, batch);
        let mut rng = Rng::new(seed + 100);
        let input = rng.i8_vec(batch * dims[0]);
        for (tag, targets) in multi_target_pairings().unwrap() {
            let dep = MultiCompiler::new(targets).unwrap().compile(&graph).unwrap();
            let (_, rep, ov) = dep.run_overlapped(&input).unwrap();
            assert!(
                rep.overlapped_cycles > 0 && rep.overlapped_cycles <= rep.cycles,
                "{tag} dims {dims:?}: overlapped {} vs serial {}",
                rep.overlapped_cycles,
                rep.cycles
            );
            check_schedule(tag, &dep.segments, &ov);
        }
    }
}
