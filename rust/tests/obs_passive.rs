//! Observability is strictly passive.
//!
//! The whole obs layer — trace spans, timeline capture, metric counters —
//! must be invisible to the artifact: a traced compile emits a program
//! byte-identical to an untraced one (so the golden hashes in
//! `tests/golden/gemmini_hashes.json` pin traced and untraced compiles
//! alike), and a profiled run reports exactly the counters of an
//! unprofiled run.

use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::bench::{square_model, toycar_model};
use tvm_accel::pipeline::Compiler;
use tvm_accel::relay::import::to_qnn_graph;
use tvm_accel::sim::Simulator;
use tvm_accel::util::prng::Rng;

#[test]
fn toycar_traced_compile_is_byte_identical() {
    let model = toycar_model(42).expect("toycar model");
    let graph = to_qnn_graph(&model).expect("import");

    let plain = Compiler::new(gemmini_desc().unwrap()).compile(&graph).expect("untraced");
    let traced_out =
        Compiler::new(gemmini_desc().unwrap()).compile_traced(&graph).expect("traced");
    let traced = traced_out.deployment;

    assert_eq!(
        plain.program.items, traced.program.items,
        "tracing must not perturb the instruction stream"
    );
    assert_eq!(
        plain.program.disassemble(),
        traced.program.disassemble(),
        "tracing must not perturb the disassembly (golden hashes pin this)"
    );
    assert_eq!(
        plain.program.layout.total_bytes(),
        traced.program.layout.total_bytes(),
        "tracing must not perturb the DRAM layout"
    );
    assert_eq!(plain.chosen.len(), traced.chosen.len());
    for (a, b) in plain.chosen.iter().zip(&traced.chosen) {
        assert_eq!(a.1, b.1, "{}: tracing must not perturb schedule selection", a.0);
        assert_eq!(a.2, b.2, "{}: tracing must not perturb profiled cost", a.0);
    }

    // The traced session really did trace: stage spans under one root,
    // and at least one solver sweep for this cold compile.
    let spans = traced_out.trace.spans();
    assert!(spans.iter().any(|s| s.name == "compile"));
    assert!(spans.iter().any(|s| s.name == "schedule"));
    assert!(spans.iter().any(|s| s.name == "sweep"), "cold compile records sweep spans");
}

#[test]
fn profiled_run_reports_the_same_counters() {
    let model = square_model(64, 500).expect("model");
    let graph = to_qnn_graph(&model).expect("import");
    let accel = gemmini_desc().unwrap();
    let dep = Compiler::new(accel.clone()).compile(&graph).expect("compile");
    let sim = Simulator::new(&accel.arch);

    let input = Rng::new(7).i8_vec(model.batch * model.layers[0].in_dim);
    let (out_plain, rep_plain) = dep.run(&sim, &input).expect("run");
    let (out_prof, rep_prof, tl) = dep.run_profiled(&sim, &input).expect("run_profiled");

    assert_eq!(out_plain, out_prof, "profiling must not change the computation");
    // RunReport holds only scalars and a BTreeMap, so its Debug form is a
    // deterministic, complete field-by-field comparison.
    assert_eq!(
        format!("{rep_plain:?}"),
        format!("{rep_prof:?}"),
        "profiling must not change any run counter"
    );
    assert!(!tl.slices.is_empty(), "the profiled run captured timeline slices");
}
