//! A compact Relay-style graph IR with QNN (quantized) operators.
//!
//! This is the substrate for the paper's Frontend Configurator (§3.3): the
//! importer produces quantized models as *sequences* of fine-grained QNN
//! ops (dense → bias-add → requantize → clip, as TFLite parses them); the
//! legalization pass ([`legalize`]) rewrites supported sequences into
//! generalized accelerator operators; constant folding ([`fold`]) folds
//! constant-related preprocessing (the UMA fix of §4); and partitioning
//! ([`partition`]) splits the graph into accelerator and host regions.

pub mod eval;
pub mod fold;
pub mod import;
pub mod legalize;
pub mod partition;
pub mod quantize;

use std::fmt;

use anyhow::{anyhow, bail, ensure, Result};

use crate::isa::Activation;

/// Element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    I8,
    I32,
    F32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 | DType::F32 => 4,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::I8 => write!(f, "i8"),
            DType::I32 => write!(f, "i32"),
            DType::F32 => write!(f, "f32"),
        }
    }
}

/// Tensor type: shape + dtype.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorType {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorType {
    pub fn new(shape: Vec<usize>, dtype: DType) -> TensorType {
        TensorType { shape, dtype }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }
}

impl fmt::Display for TensorType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[", self.dtype)?;
        for (i, s) in self.shape.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{s}")?;
        }
        write!(f, "]")
    }
}

/// Constant tensor data.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    I8(Vec<i8>),
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
            TensorData::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::I8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            TensorData::I8(v) => Ok(v),
            other => Err(anyhow!("expected i8 data, got {}", other.dtype())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32(v) => Ok(v),
            other => Err(anyhow!("expected i32 data, got {}", other.dtype())),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            other => Err(anyhow!("expected f32 data, got {}", other.dtype())),
        }
    }
}

/// A constant tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub ty: TensorType,
    pub data: TensorData,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: TensorData) -> Result<Tensor> {
        let elems: usize = shape.iter().product();
        ensure!(
            elems == data.len(),
            "tensor shape {:?} has {elems} elems, data has {}",
            shape,
            data.len()
        );
        let dtype = data.dtype();
        Ok(Tensor { ty: TensorType::new(shape, dtype), data })
    }
}

/// Graph operators. `Qnn*`, `BiasAdd`, `Requantize`, `Clip` are the
/// fine-grained ops an importer produces; `AccelDense` is the generalized
/// operator introduced by legalization (§3.3 Frontend Configurator).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input placeholder.
    Input,
    /// Constant (weights, biases).
    Constant(Tensor),
    /// Quantized dense: `O[N,K](i32) = Σ_c X[N,C](i8) · Wᵀ` with TFLite
    /// weight layout `W[K,C]` (i8).
    QnnDense,
    /// Quantized 2-D convolution: NHWC activation (i8) × OHWI weights
    /// `W[K, kh, kw, C]` (i8) → NHWK (i32). Zero padding (symmetric
    /// quantization: zero point 0).
    QnnConv2d { stride: usize, pad: usize },
    /// im2col expansion: NHWC (i8) → `[N·OH·OW, kh·kw·C]` (i8); the
    /// accelerator-registered preprocessing that lowers convolutions onto
    /// the GEMM path. Runs on the host when its input is not constant.
    Im2col { kh: usize, kw: usize, stride: usize, pad: usize },
    /// `O[N,K](i32) = X[N,K](i32) + B[K](i32)`.
    BiasAdd,
    /// int32 → int8 with scale: `round_ties_even(x · scale)` saturated.
    Requantize { scale: f32 },
    /// int8 clip to `[lo, hi]`.
    Clip { lo: i8, hi: i8 },
    /// int8 relu (`max(x, 0)`).
    Relu,
    /// 2-D transpose.
    Transpose,
    /// Reshape to a new shape with the same element count.
    Reshape { shape: Vec<usize> },
    /// f32 → int8 quantize: `round_ties_even(x / scale)` saturated.
    Quantize { scale: f32 },
    /// int8 → f32 dequantize: `x · scale`.
    Dequantize { scale: f32 },
    /// Generalized accelerator dense (post-legalization): inputs
    /// `(X[N,C] i8, W i8, B[K] i32)`, output i8;
    /// `O = act(requant(X·W(ᵀ) + B, scale))`.
    ///
    /// `weight_transposed = false`: W is in importer (TFLite) layout
    /// `[K, C]`. After the preprocessing pass inserts the registered
    /// weight transposition (paper Fig. 3a), the flag flips and W is in
    /// accelerator layout `[C, K]`.
    AccelDense { scale: f32, act: Activation, weight_transposed: bool },
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Constant(_) => "constant",
            Op::QnnDense => "qnn.dense",
            Op::QnnConv2d { .. } => "qnn.conv2d",
            Op::Im2col { .. } => "im2col",
            Op::BiasAdd => "bias_add",
            Op::Requantize { .. } => "qnn.requantize",
            Op::Clip { .. } => "clip",
            Op::Relu => "relu",
            Op::Transpose => "transpose",
            Op::Reshape { .. } => "reshape",
            Op::Quantize { .. } => "qnn.quantize",
            Op::Dequantize { .. } => "qnn.dequantize",
            Op::AccelDense { .. } => "accel.dense",
        }
    }
}

/// Node identifier (index into `Graph::nodes`).
pub type NodeId = usize;

/// One graph node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub ty: TensorType,
}

/// A dataflow graph in topological order (nodes only reference earlier
/// nodes; enforced at construction).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub inputs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
}

impl Graph {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Users of each node.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Validate topological order and arities/types.
    pub fn validate(&self) -> Result<()> {
        for n in &self.nodes {
            for &i in &n.inputs {
                ensure!(i < n.id, "node {} uses later node {}", n.id, i);
            }
            if matches!(n.op, Op::Input) {
                continue;
            }
            let inferred = infer_type(&n.op, &self.input_types(n))?;
            ensure!(
                inferred == n.ty,
                "node {} ({}): stored type {} != inferred {}",
                n.id,
                n.op.name(),
                n.ty,
                inferred
            );
        }
        for &o in &self.outputs {
            ensure!(o < self.nodes.len(), "output {o} out of range");
        }
        Ok(())
    }

    fn input_types(&self, n: &Node) -> Vec<TensorType> {
        n.inputs.iter().map(|&i| self.nodes[i].ty.clone()).collect()
    }

    /// Pretty printer (one line per node).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for n in &self.nodes {
            let ins: Vec<String> = n.inputs.iter().map(|i| format!("%{i}")).collect();
            s.push_str(&format!(
                "%{} = {}({}) : {}   // {}\n",
                n.id,
                n.op.name(),
                ins.join(", "),
                n.ty,
                n.name
            ));
        }
        s.push_str(&format!(
            "outputs: {}\n",
            self.outputs.iter().map(|o| format!("%{o}")).collect::<Vec<_>>().join(", ")
        ));
        s
    }
}

/// Infer the output type of `op` applied to inputs of the given types.
pub fn infer_type(op: &Op, ins: &[TensorType]) -> Result<TensorType> {
    let want = |n: usize| -> Result<()> {
        ensure!(ins.len() == n, "{} expects {n} inputs, got {}", op.name(), ins.len());
        Ok(())
    };
    match op {
        Op::Input => bail!("input nodes carry their own type"),
        Op::Constant(t) => {
            want(0)?;
            Ok(t.ty.clone())
        }
        Op::QnnDense => {
            want(2)?;
            let (x, w) = (&ins[0], &ins[1]);
            ensure!(x.dtype == DType::I8 && w.dtype == DType::I8, "qnn.dense wants i8");
            ensure!(x.shape.len() == 2 && w.shape.len() == 2, "qnn.dense wants 2-D");
            ensure!(
                x.shape[1] == w.shape[1],
                "qnn.dense reduction mismatch: x {} vs w {}",
                x.shape[1],
                w.shape[1]
            );
            Ok(TensorType::new(vec![x.shape[0], w.shape[0]], DType::I32))
        }
        Op::QnnConv2d { stride, pad } => {
            want(2)?;
            let (x, w) = (&ins[0], &ins[1]);
            ensure!(x.dtype == DType::I8 && w.dtype == DType::I8, "qnn.conv2d wants i8");
            ensure!(x.shape.len() == 4, "qnn.conv2d wants NHWC input");
            ensure!(w.shape.len() == 4, "qnn.conv2d wants OHWI weights");
            let (n, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
            let (k, kh, kw, wc) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
            ensure!(c == wc, "qnn.conv2d channel mismatch: {c} vs {wc}");
            ensure!(*stride >= 1, "stride must be >= 1");
            ensure!(h + 2 * pad >= kh && wd + 2 * pad >= kw, "kernel larger than input");
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (wd + 2 * pad - kw) / stride + 1;
            Ok(TensorType::new(vec![n, oh, ow, k], DType::I32))
        }
        Op::Im2col { kh, kw, stride, pad } => {
            want(1)?;
            let x = &ins[0];
            ensure!(x.dtype == DType::I8, "im2col wants i8");
            ensure!(x.shape.len() == 4, "im2col wants NHWC input");
            let (n, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
            ensure!(h + 2 * pad >= *kh && wd + 2 * pad >= *kw, "kernel larger than input");
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (wd + 2 * pad - kw) / stride + 1;
            Ok(TensorType::new(vec![n * oh * ow, kh * kw * c], DType::I8))
        }
        Op::BiasAdd => {
            want(2)?;
            let (x, b) = (&ins[0], &ins[1]);
            ensure!(x.dtype == DType::I32 && b.dtype == DType::I32, "bias_add wants i32");
            ensure!(
                b.shape == vec![*x.shape.last().unwrap()],
                "bias shape {:?} must match last dim of {:?}",
                b.shape,
                x.shape
            );
            Ok(x.clone())
        }
        Op::Requantize { .. } => {
            want(1)?;
            ensure!(ins[0].dtype == DType::I32, "requantize wants i32");
            Ok(TensorType::new(ins[0].shape.clone(), DType::I8))
        }
        Op::Clip { .. } | Op::Relu => {
            want(1)?;
            ensure!(ins[0].dtype == DType::I8, "{} wants i8", op.name());
            Ok(ins[0].clone())
        }
        Op::Transpose => {
            want(1)?;
            ensure!(ins[0].shape.len() == 2, "transpose wants 2-D");
            Ok(TensorType::new(
                vec![ins[0].shape[1], ins[0].shape[0]],
                ins[0].dtype,
            ))
        }
        Op::Reshape { shape } => {
            want(1)?;
            let n: usize = shape.iter().product();
            ensure!(n == ins[0].elems(), "reshape element count mismatch");
            Ok(TensorType::new(shape.clone(), ins[0].dtype))
        }
        Op::Quantize { .. } => {
            want(1)?;
            ensure!(ins[0].dtype == DType::F32, "quantize wants f32");
            Ok(TensorType::new(ins[0].shape.clone(), DType::I8))
        }
        Op::Dequantize { .. } => {
            want(1)?;
            ensure!(ins[0].dtype == DType::I8, "dequantize wants i8");
            Ok(TensorType::new(ins[0].shape.clone(), DType::F32))
        }
        Op::AccelDense { weight_transposed, .. } => {
            want(3)?;
            let (x, w, b) = (&ins[0], &ins[1], &ins[2]);
            ensure!(x.dtype == DType::I8 && w.dtype == DType::I8, "accel.dense wants i8");
            ensure!(b.dtype == DType::I32, "accel.dense bias wants i32");
            ensure!(
                x.shape.len() == 2 && w.shape.len() == 2,
                "accel.dense wants 2-D"
            );
            // Importer layout: W[K,C]; accelerator layout: W[C,K].
            let (red, out) = if *weight_transposed {
                (w.shape[0], w.shape[1])
            } else {
                (w.shape[1], w.shape[0])
            };
            ensure!(x.shape[1] == red, "accel.dense reduction mismatch");
            ensure!(b.shape == vec![out], "accel.dense bias shape");
            Ok(TensorType::new(vec![x.shape[0], out], DType::I8))
        }
    }
}

/// Convenience builder maintaining topological order and inferred types.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    g: Graph,
}

impl GraphBuilder {
    pub fn new() -> GraphBuilder {
        GraphBuilder::default()
    }

    pub fn input(&mut self, name: impl Into<String>, ty: TensorType) -> NodeId {
        let id = self.g.nodes.len();
        self.g.nodes.push(Node { id, name: name.into(), op: Op::Input, inputs: vec![], ty });
        self.g.inputs.push(id);
        id
    }

    pub fn constant(&mut self, name: impl Into<String>, t: Tensor) -> NodeId {
        let id = self.g.nodes.len();
        let ty = t.ty.clone();
        self.g.nodes.push(Node {
            id,
            name: name.into(),
            op: Op::Constant(t),
            inputs: vec![],
            ty,
        });
        id
    }

    pub fn op(&mut self, name: impl Into<String>, op: Op, inputs: &[NodeId]) -> Result<NodeId> {
        let ins: Vec<TensorType> =
            inputs.iter().map(|&i| self.g.nodes[i].ty.clone()).collect();
        let ty = infer_type(&op, &ins)?;
        let id = self.g.nodes.len();
        self.g.nodes.push(Node { id, name: name.into(), op, inputs: inputs.to_vec(), ty });
        Ok(id)
    }

    /// Peek at a node's type while building.
    pub fn ty(&self, id: NodeId) -> &TensorType {
        &self.g.nodes[id].ty
    }

    pub fn outputs(mut self, outs: &[NodeId]) -> Graph {
        self.g.outputs = outs.to_vec();
        self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn qnn_layer() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![4, 8], DType::I8));
        let w =
            b.constant("w", Tensor::new(vec![6, 8], TensorData::I8(vec![1; 48])).unwrap());
        let bias =
            b.constant("b", Tensor::new(vec![6], TensorData::I32(vec![0; 6])).unwrap());
        let d = b.op("dense", Op::QnnDense, &[x, w]).unwrap();
        let ba = b.op("bias", Op::BiasAdd, &[d, bias]).unwrap();
        let rq = b.op("requant", Op::Requantize { scale: 0.5 }, &[ba]).unwrap();
        let cl = b.op("clip", Op::Clip { lo: -128, hi: 127 }, &[rq]).unwrap();
        b.outputs(&[cl])
    }

    #[test]
    fn builds_and_validates() {
        let g = qnn_layer();
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 7);
        assert_eq!(g.node(g.outputs[0]).ty, TensorType::new(vec![4, 6], DType::I8));
    }

    #[test]
    fn type_inference_catches_mismatch() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![4, 8], DType::I8));
        let w =
            b.constant("w", Tensor::new(vec![6, 9], TensorData::I8(vec![1; 54])).unwrap());
        assert!(b.op("dense", Op::QnnDense, &[x, w]).is_err());
    }

    #[test]
    fn transpose_and_reshape_types() {
        let mut b = GraphBuilder::new();
        let w =
            b.constant("w", Tensor::new(vec![2, 3], TensorData::I8(vec![0; 6])).unwrap());
        let t = b.op("t", Op::Transpose, &[w]).unwrap();
        assert_eq!(b.ty(t).shape, vec![3, 2]);
        let r = b.op("r", Op::Reshape { shape: vec![6] }, &[t]).unwrap();
        assert_eq!(b.ty(r).shape, vec![6]);
        assert!(b.op("bad", Op::Reshape { shape: vec![7] }, &[t]).is_err());
    }

    #[test]
    fn tensor_shape_data_mismatch() {
        assert!(Tensor::new(vec![2, 2], TensorData::I8(vec![0; 3])).is_err());
    }

    #[test]
    fn dump_mentions_ops() {
        let g = qnn_layer();
        let d = g.dump();
        assert!(d.contains("qnn.dense"));
        assert!(d.contains("outputs: %6"));
    }

    #[test]
    fn consumers_computed() {
        let g = qnn_layer();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![3]); // x feeds dense
        assert_eq!(cons[3], vec![4]); // dense feeds bias_add
    }
}
