//! Legalization pass (paper §3.3, Frontend Configurator).
//!
//! TVM imports a quantized dense layer as a *sequence* of fine-grained ops
//! (QNN dense, bias add, requantize, clip) that cannot lower to a single
//! TIR function. This pass rewrites each supported sequence into one
//! generalized operator (`accel.dense`), and — consulting the accelerator's
//! registered preprocessing — inserts the weight transposition so the
//! constant-folding pass can fold it at compile time.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::isa::Activation;

use super::{Graph, GraphBuilder, Node, NodeId, Op};

/// What the legalizer is allowed to rewrite (derived by the frontend
/// configurator from the accelerator's functional description).
#[derive(Debug, Clone, Default)]
pub struct LegalizeConfig {
    /// Accept `qnn.dense (+ bias_add) + requantize (+ clip|relu)` chains.
    pub dense: bool,
    /// Accept `qnn.conv2d (+ bias_add) + requantize (+ clip|relu)` chains,
    /// lowering them onto the GEMM path via the registered im2col
    /// preprocessing (paper §3.2: convolutions reach the accelerator
    /// through transformations "like transposition, flattening, or
    /// im2col").
    pub conv2d: bool,
    /// Insert the registered weight-layout preprocessing (transpose) so it
    /// can be constant-folded. The naive BYOC flow sets this too — the
    /// difference there is that folding never runs.
    pub insert_weight_transpose: bool,
}

/// A matched dense sequence.
struct DenseMatch {
    dense: NodeId,
    bias_add: Option<NodeId>,
    requantize: NodeId,
    act: Option<NodeId>,
    /// The final node of the chain (its value is what consumers see).
    tail: NodeId,
    scale: f32,
    activation: Activation,
}

/// Find maximal dense chains. A chain only matches if every intermediate
/// value has a single consumer (otherwise fusing would change visible
/// values).
fn match_dense_chains(g: &Graph, cfg: &LegalizeConfig) -> Vec<DenseMatch> {
    let consumers = g.consumers();
    let single = |id: NodeId| consumers[id].len() == 1;
    let mut out = Vec::new();
    for n in &g.nodes {
        let head_ok = match n.op {
            Op::QnnDense => cfg.dense,
            Op::QnnConv2d { .. } => cfg.conv2d,
            _ => false,
        };
        if !head_ok {
            continue;
        }
        let mut cur = n.id;
        // Optional bias add.
        let mut bias_add = None;
        if single(cur) {
            let next = consumers[cur][0];
            if matches!(g.node(next).op, Op::BiasAdd)
                && matches!(g.node(g.node(next).inputs[1]).op, Op::Constant(_))
            {
                bias_add = Some(next);
                cur = next;
            }
        }
        // Mandatory requantize.
        if !single(cur) {
            continue;
        }
        let rq = consumers[cur][0];
        let Op::Requantize { scale } = g.node(rq).op else {
            continue;
        };
        cur = rq;
        // Optional activation.
        let mut act_node = None;
        let mut activation = Activation::None;
        if single(cur) {
            let next = consumers[cur][0];
            match g.node(next).op {
                Op::Clip { lo, hi } => {
                    act_node = Some(next);
                    activation = Activation::Clip { lo, hi };
                }
                Op::Relu => {
                    act_node = Some(next);
                    activation = Activation::Relu;
                }
                _ => {}
            }
        }
        let tail = act_node.unwrap_or(rq);
        out.push(DenseMatch {
            dense: n.id,
            bias_add,
            requantize: rq,
            act: act_node,
            tail,
            scale,
            activation,
        });
    }
    out
}

/// Run legalization, returning the rewritten graph. Nodes not involved in
/// a matched chain are copied unchanged.
pub fn legalize(g: &Graph, cfg: &LegalizeConfig) -> Result<Graph> {
    if !cfg.dense && !cfg.conv2d {
        return Ok(g.clone());
    }
    let matches = match_dense_chains(g, cfg);
    // Nodes absorbed into a fused op (they disappear from the new graph).
    let mut absorbed: BTreeMap<NodeId, usize> = BTreeMap::new(); // node -> match idx
    for (mi, m) in matches.iter().enumerate() {
        absorbed.insert(m.dense, mi);
        if let Some(b) = m.bias_add {
            absorbed.insert(b, mi);
        }
        absorbed.insert(m.requantize, mi);
        if let Some(a) = m.act {
            absorbed.insert(a, mi);
        }
    }

    let mut b = GraphBuilder::new();
    // old id -> new id (for nodes that survive or for chain tails).
    let mut remap: BTreeMap<NodeId, NodeId> = BTreeMap::new();
    for n in &g.nodes {
        if let Some(&mi) = absorbed.get(&n.id) {
            let m = &matches[mi];
            if n.id != m.tail {
                continue; // interior nodes vanish
            }
            // Emit the fused op at the tail position.
            let dense = g.node(m.dense);
            let mut x = remap[&dense.inputs[0]];
            let mut w = remap[&dense.inputs[1]];
            let k_out = *dense.ty.shape.last().unwrap();
            let bias = match m.bias_add {
                Some(ba) => remap[&g.node(ba).inputs[1]],
                None => {
                    // Synthesize a zero bias so the generalized op has a
                    // uniform signature.
                    b.constant(
                        format!("{}_zero_bias", dense.name),
                        super::Tensor::new(
                            vec![k_out],
                            super::TensorData::I32(vec![0; k_out]),
                        )?,
                    )
                }
            };
            // Convolution heads first lower onto the GEMM path: im2col on
            // the activation (registered preprocessing; host-side when
            // non-constant) and a flatten of the OHWI weights (folds).
            let conv_out_shape = if let Op::QnnConv2d { stride, pad } = dense.op {
                let wshape = g.node(dense.inputs[1]).ty.shape.clone();
                let (kh, kw) = (wshape[1], wshape[2]);
                x = b.op(
                    format!("{}_im2col", dense.name),
                    Op::Im2col { kh, kw, stride, pad },
                    &[x],
                )?;
                w = b.op(
                    format!("{}_wflat", dense.name),
                    Op::Reshape { shape: vec![wshape[0], kh * kw * wshape[3]] },
                    &[w],
                )?;
                Some(dense.ty.shape.clone())
            } else {
                None
            };
            let mut transposed = false;
            if cfg.insert_weight_transpose {
                // Registered preprocessing: accelerator wants W[C,K].
                w = b.op(format!("{}_wT", dense.name), Op::Transpose, &[w])?;
                transposed = true;
            }
            let mut fused = b.op(
                format!("{}_fused", dense.name),
                Op::AccelDense {
                    scale: m.scale,
                    act: m.activation,
                    weight_transposed: transposed,
                },
                &[x, w, bias],
            )?;
            if let Some(shape) = conv_out_shape {
                fused = b.op(format!("{}_nhwk", dense.name), Op::Reshape { shape }, &[fused])?;
            }
            remap.insert(m.tail, fused);
            continue;
        }
        // Unabsorbed node: copy with remapped inputs.
        let new_id = match &n.op {
            Op::Input => b.input(n.name.clone(), n.ty.clone()),
            Op::Constant(t) => b.constant(n.name.clone(), t.clone()),
            op => {
                let ins: Vec<NodeId> = n.inputs.iter().map(|i| remap[i]).collect();
                b.op(n.name.clone(), op.clone(), &ins)?
            }
        };
        remap.insert(n.id, new_id);
    }
    let outs: Vec<NodeId> = g.outputs.iter().map(|o| remap[o]).collect();
    let out = b.outputs(&outs);
    out.validate()?;
    // Shape preservation: the fused tail has the type of the old tail.
    for (old, new) in &remap {
        let keep = absorbed.get(old).map(|&mi| matches[mi].tail == *old).unwrap_or(true);
        if keep {
            ensure!(
                g.node(*old).ty == out.node(*new).ty,
                "legalize changed type of node %{old}"
            );
        }
    }
    Ok(out)
}

/// Count nodes per op name (test/diagnostic helper).
pub fn op_histogram(g: &Graph) -> BTreeMap<&'static str, usize> {
    let mut h = BTreeMap::new();
    for n in &g.nodes {
        *h.entry(n.op.name()).or_insert(0) += 1;
    }
    h
}

#[allow(dead_code)]
fn _assert_node_sync(_: &Node) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::eval::eval;
    use crate::relay::{DType, Tensor, TensorData, TensorType};
    use crate::util::prng::Rng;

    fn full_cfg() -> LegalizeConfig {
        LegalizeConfig { dense: true, conv2d: true, insert_weight_transpose: true }
    }

    /// Build a 2-layer QNN MLP: dense+bias+requant+relu, dense+bias+requant+clip.
    fn two_layer(rng: &mut Rng) -> (Graph, Tensor) {
        let (n, c1, c2, c3) = (3, 10, 7, 5);
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![n, c1], DType::I8));
        let w1 = b.constant(
            "w1",
            Tensor::new(vec![c2, c1], TensorData::I8(rng.i8_vec(c2 * c1))).unwrap(),
        );
        let b1 = b.constant(
            "b1",
            Tensor::new(
                vec![c2],
                TensorData::I32((0..c2).map(|_| rng.below(60) as i32 - 30).collect()),
            )
            .unwrap(),
        );
        let d1 = b.op("d1", Op::QnnDense, &[x, w1]).unwrap();
        let a1 = b.op("a1", Op::BiasAdd, &[d1, b1]).unwrap();
        let r1 = b.op("r1", Op::Requantize { scale: 0.04 }, &[a1]).unwrap();
        let act1 = b.op("act1", Op::Relu, &[r1]).unwrap();
        let w2 = b.constant(
            "w2",
            Tensor::new(vec![c3, c2], TensorData::I8(rng.i8_vec(c3 * c2))).unwrap(),
        );
        let b2 = b.constant(
            "b2",
            Tensor::new(
                vec![c3],
                TensorData::I32((0..c3).map(|_| rng.below(60) as i32 - 30).collect()),
            )
            .unwrap(),
        );
        let d2 = b.op("d2", Op::QnnDense, &[act1, w2]).unwrap();
        let a2 = b.op("a2", Op::BiasAdd, &[d2, b2]).unwrap();
        let r2 = b.op("r2", Op::Requantize { scale: 0.07 }, &[a2]).unwrap();
        let act2 = b.op("act2", Op::Clip { lo: -120, hi: 120 }, &[r2]).unwrap();
        let g = b.outputs(&[act2]);
        let inp = Tensor::new(vec![n, c1], TensorData::I8(rng.i8_vec(n * c1))).unwrap();
        (g, inp)
    }

    #[test]
    fn fuses_both_layers() {
        let mut rng = Rng::new(5);
        let (g, _) = two_layer(&mut rng);
        let lg = legalize(&g, &full_cfg()).unwrap();
        let h = op_histogram(&lg);
        assert_eq!(h.get("accel.dense"), Some(&2));
        assert_eq!(h.get("qnn.dense"), None);
        assert_eq!(h.get("qnn.requantize"), None);
        // Weight transposes inserted for folding.
        assert_eq!(h.get("transpose"), Some(&2));
    }

    #[test]
    fn semantics_preserved() {
        let mut rng = Rng::new(6);
        let (g, inp) = two_layer(&mut rng);
        let lg = legalize(&g, &full_cfg()).unwrap();
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), inp);
        let before = eval(&g, &m).unwrap();
        let after = eval(&lg, &m).unwrap();
        assert_eq!(before[0].data, after[0].data);
    }

    #[test]
    fn dense_without_bias_gets_zero_bias() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 4], DType::I8));
        let w = b.constant(
            "w",
            Tensor::new(vec![3, 4], TensorData::I8(vec![1; 12])).unwrap(),
        );
        let d = b.op("d", Op::QnnDense, &[x, w]).unwrap();
        let r = b.op("r", Op::Requantize { scale: 1.0 }, &[d]).unwrap();
        let g = b.outputs(&[r]);
        let lg = legalize(&g, &full_cfg()).unwrap();
        let h = op_histogram(&lg);
        assert_eq!(h.get("accel.dense"), Some(&1));
        // Zero bias constant appears.
        assert!(lg.nodes.iter().any(|n| n.name.ends_with("_zero_bias")));
    }

    #[test]
    fn multi_consumer_intermediate_blocks_fusion() {
        // If the i32 dense output feeds two consumers, fusing would hide a
        // live value — the chain must not match.
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 4], DType::I8));
        let w = b.constant(
            "w",
            Tensor::new(vec![3, 4], TensorData::I8(vec![1; 12])).unwrap(),
        );
        let d = b.op("d", Op::QnnDense, &[x, w]).unwrap();
        let r1 = b.op("r1", Op::Requantize { scale: 1.0 }, &[d]).unwrap();
        let r2 = b.op("r2", Op::Requantize { scale: 0.5 }, &[d]).unwrap();
        let g = b.outputs(&[r1, r2]);
        let lg = legalize(&g, &full_cfg()).unwrap();
        assert_eq!(op_histogram(&lg).get("accel.dense"), None);
        assert_eq!(op_histogram(&lg).get("qnn.dense"), Some(&1));
    }

    #[test]
    fn disabled_config_is_identity() {
        let mut rng = Rng::new(7);
        let (g, _) = two_layer(&mut rng);
        let lg = legalize(&g, &LegalizeConfig::default()).unwrap();
        assert_eq!(g.nodes.len(), lg.nodes.len());
    }
}
