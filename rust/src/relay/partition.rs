//! Graph partitioning: assign each node to an accelerator or the host
//! CPU, based on the operator support derived from the accelerator's
//! functional description (paper §3.3: "the frontend configurator sets up
//! the graph partitioning ... using predefined supported operators").
//!
//! Two entry points:
//!
//! * [`partition`] — the classic BYOC split against a *single* supported
//!   operator set: every supported node goes to the one accelerator,
//!   everything else to the host.
//! * [`partition_multi`] — cost-driven placement across a *set* of
//!   candidate accelerators (MATCH-style per-layer target selection): for
//!   every node, each candidate that supports the operator is asked for a
//!   cost (the session supplies profiled cycles from the cached schedule
//!   search), and the node is assigned to the cheapest target. Ties break
//!   deterministically toward the lower target index; a node no candidate
//!   supports falls back to the host.
//!
//! Both produce a [`PartitionedGraph`] whose `regions` are the maximal
//! topological runs of accelerator nodes *on the same target* — the unit
//! that later becomes one contiguous instruction-stream segment.

#![warn(missing_docs)]

use std::collections::BTreeSet;

use anyhow::{ensure, Result};

use super::{Graph, Node, NodeId, Op};

/// Execution target of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Offloaded to an accelerator (see [`PartitionedGraph::accel_of`] for
    /// which one).
    Accel,
    /// Executed by the host CPU.
    Host,
    /// No runtime work (inputs, constants staged in DRAM at load time).
    None,
}

/// One evaluated target-switch boundary: placing `node` on `to` while its
/// direct producer sits on `from` forces the activation through DRAM
/// (store by `from`, reload by `to`) — a round-trip same-target placement
/// could have elided via cross-layer residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryEval {
    /// The consumer node whose placement was evaluated.
    pub node: NodeId,
    /// Target its direct producer was assigned to.
    pub from: usize,
    /// Candidate target evaluated for the consumer.
    pub to: usize,
    /// Switch penalty charged to the candidate, in cycles.
    pub penalty: u64,
    /// Portion of the penalty the overlapped executor hides (the
    /// consumer's boundary reload double-buffers under the producer's
    /// tail). The objective charges `penalty - min(discount, penalty)`.
    pub discount: u64,
    /// Whether this candidate won the placement (the penalty was paid).
    pub taken: bool,
}

/// A partitioned graph: the (unmodified) graph plus per-node targets and
/// the list of accelerator regions (maximal runs of accel nodes on the
/// same target, in topological order).
#[derive(Debug, Clone)]
pub struct PartitionedGraph {
    /// The graph that was partitioned (unmodified).
    pub graph: Graph,
    /// Per-node execution target, indexed by [`NodeId`].
    pub targets: Vec<Target>,
    /// Index of the chosen accelerator for each [`Target::Accel`] node
    /// (into the candidate list handed to [`partition_multi`]; always
    /// `Some(0)` from single-target [`partition`]), `None` otherwise.
    pub accel_of: Vec<Option<usize>>,
    /// Cost of the chosen target per node, when the partitioner evaluated
    /// one (cost-driven [`partition_multi`] only; `None` from
    /// [`partition`] and for host/no-work nodes). Excludes any switch
    /// penalty — see [`PartitionedGraph::boundaries`].
    pub costs: Vec<Option<u64>>,
    /// Every cross-target boundary the cost-driven partitioner evaluated
    /// (empty from single-target [`partition`]): what switching away from
    /// the producer's target would cost per candidate, and whether the
    /// switch was actually taken.
    pub boundaries: Vec<BoundaryEval>,
    /// Maximal topological runs of accel nodes on the same target
    /// (constants between them do not break a region).
    pub regions: Vec<Vec<NodeId>>,
}

impl PartitionedGraph {
    /// Number of nodes offloaded to any accelerator.
    pub fn accel_nodes(&self) -> usize {
        self.targets.iter().filter(|t| **t == Target::Accel).count()
    }

    /// Number of nodes executed by the host CPU.
    pub fn host_nodes(&self) -> usize {
        self.targets.iter().filter(|t| **t == Target::Host).count()
    }

    /// Number of nodes assigned to accelerator `target` (an index into the
    /// candidate list given to [`partition_multi`]).
    pub fn nodes_on(&self, target: usize) -> usize {
        self.accel_of.iter().filter(|t| **t == Some(target)).count()
    }
}

/// Regions: maximal topological runs of accel nodes that share a target.
/// Host nodes break a region; constants/inputs do not.
fn build_regions(g: &Graph, targets: &[Target], accel_of: &[Option<usize>]) -> Vec<Vec<NodeId>> {
    let mut regions = Vec::new();
    let mut cur: Vec<NodeId> = Vec::new();
    let mut cur_target: Option<usize> = None;
    for n in &g.nodes {
        match targets[n.id] {
            Target::Accel => {
                let t = accel_of[n.id];
                if cur_target.is_some() && cur_target != t && !cur.is_empty() {
                    regions.push(std::mem::take(&mut cur));
                }
                cur_target = t;
                cur.push(n.id);
            }
            Target::Host => {
                if !cur.is_empty() {
                    regions.push(std::mem::take(&mut cur));
                }
                cur_target = None;
            }
            Target::None => {}
        }
    }
    if !cur.is_empty() {
        regions.push(cur);
    }
    regions
}

/// Partition `g` given the set of accelerator-supported operator names
/// (e.g. `{"accel.dense"}` from the functional description).
pub fn partition(g: &Graph, supported: &BTreeSet<String>) -> Result<PartitionedGraph> {
    let mut targets = Vec::with_capacity(g.nodes.len());
    let mut accel_of = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let t = match &n.op {
            Op::Input | Op::Constant(_) => Target::None,
            op if supported.contains(op.name()) => Target::Accel,
            _ => Target::Host,
        };
        accel_of.push(if t == Target::Accel { Some(0) } else { None });
        targets.push(t);
    }
    let regions = build_regions(g, &targets, &accel_of);
    let costs = vec![None; g.nodes.len()];
    let pg = PartitionedGraph {
        graph: g.clone(),
        targets,
        accel_of,
        costs,
        boundaries: Vec::new(),
        regions,
    };
    ensure!(
        pg.targets.len() == g.nodes.len(),
        "partition must cover every node"
    );
    Ok(pg)
}

/// Cost-driven partition across several candidate accelerators.
///
/// `supported[t]` is the operator set of candidate `t`; `cost(node, t)` is
/// queried for **every** candidate that supports the node (so a caching
/// caller pays each (shape, target) search once and serves repeats from
/// its cache). It returns `Ok(Some(cost))` with a comparable cost — the
/// session passes profiled cycles from the schedule search — or
/// `Ok(None)` when the candidate turns out to be infeasible for this
/// particular node (op support is name-granular, feasibility is
/// shape-level: e.g. memories too small for the layer's minimal tile);
/// infeasible candidates are simply skipped.
///
/// `boundary(node, from, to)` prices a target *switch* as
/// `(penalty, discount)`: when `node`'s direct data producer (its first
/// input) was already placed on accelerator `from`, every candidate
/// `to != from` is additionally charged `penalty` — the DRAM round-trip
/// the switch forces on the activation, which same-target placement
/// could elide via cross-layer residency — minus `discount`, the portion
/// of that round-trip the overlapped executor hides by double-buffering
/// the consumer's reload under the producer's tail. The discount is
/// clamped to the penalty, so the effective charge never goes negative.
/// Each evaluated boundary is recorded in
/// [`PartitionedGraph::boundaries`].
///
/// The node is assigned to the candidate with the cheapest
/// `cost + penalty - min(discount, penalty)` — the *overlapped-makespan*
/// objective, which can prefer a split that serializes worse but
/// overlaps better. Ties break toward the lower index, so the assignment
/// is deterministic. A node that no candidate supports (or that every
/// candidate reports infeasible) falls back to [`Target::Host`]. An `Err`
/// from `cost` aborts the partition.
pub fn partition_multi(
    g: &Graph,
    supported: &[BTreeSet<String>],
    mut cost: impl FnMut(&Node, usize) -> Result<Option<u64>>,
    mut boundary: impl FnMut(&Node, usize, usize) -> (u64, u64),
) -> Result<PartitionedGraph> {
    ensure!(!supported.is_empty(), "need at least one candidate accelerator");
    let mut targets = Vec::with_capacity(g.nodes.len());
    let mut accel_of: Vec<Option<usize>> = Vec::with_capacity(g.nodes.len());
    let mut costs = Vec::with_capacity(g.nodes.len());
    let mut boundaries = Vec::new();
    for n in &g.nodes {
        let (t, chosen, c) = match &n.op {
            Op::Input | Op::Constant(_) => (Target::None, None, None),
            op => {
                // Where the node's activation comes from (nodes are in
                // topological order, so the producer is already placed).
                let producer_target =
                    n.inputs.first().and_then(|&i| accel_of.get(i).copied().flatten());
                let mut best: Option<(usize, u64, u64)> = None;
                for (idx, s) in supported.iter().enumerate() {
                    if !s.contains(op.name()) {
                        continue;
                    }
                    let Some(c) = cost(n, idx)? else {
                        continue; // supported by name, infeasible for this node
                    };
                    let penalty = match producer_target {
                        Some(from) if from != idx => {
                            let (p, d) = boundary(n, from, idx);
                            boundaries.push(BoundaryEval {
                                node: n.id,
                                from,
                                to: idx,
                                penalty: p,
                                discount: d.min(p),
                                taken: false, // fixed up below
                            });
                            p - d.min(p)
                        }
                        _ => 0,
                    };
                    // Strict `<` keeps the lowest index on equal cost.
                    if best.map(|(_, _, bc)| c + penalty < bc).unwrap_or(true) {
                        best = Some((idx, c, c + penalty));
                    }
                }
                match best {
                    Some((idx, c, _)) => {
                        for b in boundaries.iter_mut().rev() {
                            if b.node != n.id {
                                break;
                            }
                            b.taken = b.to == idx;
                        }
                        (Target::Accel, Some(idx), Some(c))
                    }
                    None => (Target::Host, None, None),
                }
            }
        };
        targets.push(t);
        accel_of.push(chosen);
        costs.push(c);
    }
    let regions = build_regions(g, &targets, &accel_of);
    Ok(PartitionedGraph { graph: g.clone(), targets, accel_of, costs, boundaries, regions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;
    use crate::relay::{DType, GraphBuilder, Tensor, TensorData, TensorType};

    fn supported() -> BTreeSet<String> {
        let mut s = BTreeSet::new();
        s.insert("accel.dense".to_string());
        s
    }

    fn accel_dense(b: &mut GraphBuilder, name: &str, x: NodeId, c: usize, k: usize) -> NodeId {
        let w = b.constant(
            format!("{name}_w"),
            Tensor::new(vec![c, k], TensorData::I8(vec![1; c * k])).unwrap(),
        );
        let bias = b.constant(
            format!("{name}_b"),
            Tensor::new(vec![k], TensorData::I32(vec![0; k])).unwrap(),
        );
        b.op(
            name,
            Op::AccelDense { scale: 1.0, act: Activation::None, weight_transposed: true },
            &[x, w, bias],
        )
        .unwrap()
    }

    #[test]
    fn contiguous_accel_layers_form_one_region() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![1, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        let l2 = accel_dense(&mut b, "l2", l1, 8, 4);
        let g = b.outputs(&[l2]);
        let pg = partition(&g, &supported()).unwrap();
        assert_eq!(pg.accel_nodes(), 2);
        assert_eq!(pg.host_nodes(), 0);
        assert_eq!(pg.regions.len(), 1);
        assert_eq!(pg.regions[0].len(), 2);
        assert_eq!(pg.nodes_on(0), 2);
        assert_eq!(pg.accel_of[l1], Some(0));
    }

    #[test]
    fn host_op_splits_regions() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        // A host-only transpose between the two dense layers.
        let t = b.op("t", Op::Transpose, &[l1]).unwrap();
        let l2 = accel_dense(&mut b, "l2", t, 8, 4);
        let g = b.outputs(&[l2]);
        let pg = partition(&g, &supported()).unwrap();
        assert_eq!(pg.regions.len(), 2);
        assert_eq!(pg.host_nodes(), 1);
        assert_eq!(pg.targets[t], Target::Host);
    }

    #[test]
    fn unsupported_everything_goes_to_host() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 2], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);
        let pg = partition(&g, &BTreeSet::new()).unwrap();
        assert_eq!(pg.accel_nodes(), 0);
        assert_eq!(pg.host_nodes(), 1);
        assert!(pg.regions.is_empty());
    }

    fn two_layer_graph() -> (Graph, NodeId, NodeId) {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![1, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        let l2 = accel_dense(&mut b, "l2", l1, 8, 4);
        (b.outputs(&[l2]), l1, l2)
    }

    #[test]
    fn multi_assigns_each_node_to_cheapest_target() {
        let (g, l1, l2) = two_layer_graph();
        let sets = vec![supported(), supported()];
        // Target 0 cheaper for l1, target 1 cheaper for l2.
        let pg = partition_multi(
            &g,
            &sets,
            |n, t| {
                Ok(Some(match (n.name.as_str(), t) {
                    ("l1", 0) => 10,
                    ("l1", 1) => 20,
                    ("l2", 0) => 30,
                    ("l2", 1) => 5,
                    _ => unreachable!(),
                }))
            },
            |_, _, _| (0, 0),
        )
        .unwrap();
        assert_eq!(pg.accel_of[l1], Some(0));
        assert_eq!(pg.accel_of[l2], Some(1));
        assert_eq!(pg.costs[l1], Some(10));
        assert_eq!(pg.costs[l2], Some(5));
        // Different targets split the region even without a host node.
        assert_eq!(pg.regions.len(), 2);
    }

    #[test]
    fn multi_tie_breaks_toward_lower_index() {
        let (g, l1, l2) = two_layer_graph();
        let sets = vec![supported(), supported(), supported()];
        let pg = partition_multi(&g, &sets, |_, _| Ok(Some(42)), |_, _, _| (0, 0)).unwrap();
        assert_eq!(pg.accel_of[l1], Some(0));
        assert_eq!(pg.accel_of[l2], Some(0));
        assert_eq!(pg.regions.len(), 1, "same target keeps one region");
    }

    #[test]
    fn multi_unsupported_node_falls_back_to_host() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        let t = b.op("t", Op::Transpose, &[l1]).unwrap();
        let g = b.outputs(&[t]);
        // Neither candidate supports transpose; candidate 1 supports
        // nothing at all.
        let sets = vec![supported(), BTreeSet::new()];
        let mut queried = Vec::new();
        let pg = partition_multi(
            &g,
            &sets,
            |n, t| {
                queried.push((n.name.clone(), t));
                Ok(Some(7))
            },
            |_, _, _| (0, 0),
        )
        .unwrap();
        assert_eq!(pg.targets[t], Target::Host);
        assert_eq!(pg.accel_of[t], None);
        assert_eq!(pg.accel_of[l1], Some(0));
        // Cost is only queried for supporting candidates.
        assert_eq!(queried, vec![("l1".to_string(), 0)]);
    }

    #[test]
    fn multi_skips_infeasible_candidates() {
        let (g, l1, l2) = two_layer_graph();
        let sets = vec![supported(), supported()];
        // Candidate 0 is cheaper but infeasible for l2 (shape-level):
        // l2 must land on candidate 1; a node infeasible everywhere
        // falls back to the host.
        let pg = partition_multi(
            &g,
            &sets,
            |n, t| {
                Ok(match (n.name.as_str(), t) {
                    ("l1", 0) => Some(1),
                    ("l1", 1) => Some(2),
                    ("l2", 0) => None,
                    ("l2", 1) => Some(9),
                    _ => unreachable!(),
                })
            },
            |_, _, _| (0, 0),
        )
        .unwrap();
        assert_eq!(pg.accel_of[l1], Some(0));
        assert_eq!(pg.accel_of[l2], Some(1));

        let all_infeasible =
            partition_multi(&g, &sets, |_, _| Ok(None), |_, _, _| (0, 0)).unwrap();
        assert_eq!(all_infeasible.targets[l1], Target::Host);
        assert_eq!(all_infeasible.targets[l2], Target::Host);
        assert_eq!(all_infeasible.accel_nodes(), 0);
    }

    #[test]
    fn multi_with_no_candidates_rejected() {
        let (g, _, _) = two_layer_graph();
        assert!(partition_multi(&g, &[], |_, _| Ok(None), |_, _, _| (0, 0)).is_err());
    }

    #[test]
    fn overlap_discount_can_flip_the_serial_sum_optimum() {
        // l1 lands on target 0 (cheaper there). For l2, target 1 is 2
        // cycles faster raw but a switch costs 5: the serial-sum
        // objective (10 vs 8+5=13) keeps l2 on target 0, while the
        // overlapped objective (10 vs 8+5-4=9) prefers the split —
        // the consumer reload hides under the producer's tail.
        let (g, l1, l2) = two_layer_graph();
        let sets = vec![supported(), supported()];
        let cost = |n: &Node, t: usize| {
            Ok(Some(match (n.name.as_str(), t) {
                ("l1", 0) => 10,
                ("l1", 1) => 20,
                ("l2", 0) => 10,
                ("l2", 1) => 8,
                _ => unreachable!(),
            }))
        };
        let serial = partition_multi(&g, &sets, cost, |_, _, _| (5, 0)).unwrap();
        assert_eq!(serial.accel_of[l1], Some(0));
        assert_eq!(serial.accel_of[l2], Some(0), "full penalty keeps l2 home");
        assert!(serial.boundaries.iter().any(|b| b.node == l2 && !b.taken));

        let overlapped = partition_multi(&g, &sets, cost, |_, _, _| (5, 4)).unwrap();
        assert_eq!(overlapped.accel_of[l1], Some(0));
        assert_eq!(
            overlapped.accel_of[l2],
            Some(1),
            "discounted boundary makes the split the optimum"
        );
        let b = overlapped
            .boundaries
            .iter()
            .find(|b| b.node == l2 && b.taken)
            .expect("the taken switch is recorded");
        assert_eq!((b.penalty, b.discount), (5, 4));
        assert_eq!(overlapped.regions.len(), 2);

        // A discount larger than the penalty clamps: the charge is 0,
        // never negative.
        let clamped = partition_multi(&g, &sets, cost, |_, _, _| (5, 99)).unwrap();
        assert_eq!(clamped.accel_of[l2], Some(1));
        let b = clamped.boundaries.iter().find(|b| b.node == l2 && b.taken).unwrap();
        assert_eq!(b.discount, 5, "recorded discount is clamped to the penalty");
    }
}
