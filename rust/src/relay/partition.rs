//! Graph partitioning: assign each node to the accelerator or the host
//! CPU, based on the operator support derived from the accelerator's
//! functional description (paper §3.3: "the frontend configurator sets up
//! the graph partitioning ... using predefined supported operators").

use std::collections::BTreeSet;

use anyhow::{ensure, Result};

use super::{Graph, NodeId, Op};

/// Execution target of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Offloaded to the accelerator.
    Accel,
    /// Executed by the host CPU.
    Host,
    /// No runtime work (inputs, constants staged in DRAM at load time).
    None,
}

/// A partitioned graph: the (unmodified) graph plus per-node targets and
/// the list of accelerator regions (maximal runs of accel nodes in
/// topological order).
#[derive(Debug, Clone)]
pub struct PartitionedGraph {
    pub graph: Graph,
    pub targets: Vec<Target>,
    pub regions: Vec<Vec<NodeId>>,
}

impl PartitionedGraph {
    pub fn accel_nodes(&self) -> usize {
        self.targets.iter().filter(|t| **t == Target::Accel).count()
    }

    pub fn host_nodes(&self) -> usize {
        self.targets.iter().filter(|t| **t == Target::Host).count()
    }
}

/// Partition `g` given the set of accelerator-supported operator names
/// (e.g. `{"accel.dense"}` from the functional description).
pub fn partition(g: &Graph, supported: &BTreeSet<String>) -> Result<PartitionedGraph> {
    let mut targets = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let t = match &n.op {
            Op::Input | Op::Constant(_) => Target::None,
            op if supported.contains(op.name()) => Target::Accel,
            _ => Target::Host,
        };
        targets.push(t);
    }
    // Regions: maximal topological runs of accel nodes (constants between
    // them do not break a region).
    let mut regions = Vec::new();
    let mut cur: Vec<NodeId> = Vec::new();
    for n in &g.nodes {
        match targets[n.id] {
            Target::Accel => cur.push(n.id),
            Target::Host => {
                if !cur.is_empty() {
                    regions.push(std::mem::take(&mut cur));
                }
            }
            Target::None => {}
        }
    }
    if !cur.is_empty() {
        regions.push(cur);
    }
    let pg = PartitionedGraph { graph: g.clone(), targets, regions };
    ensure!(
        pg.targets.len() == g.nodes.len(),
        "partition must cover every node"
    );
    Ok(pg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;
    use crate::relay::{DType, GraphBuilder, Tensor, TensorData, TensorType};

    fn supported() -> BTreeSet<String> {
        let mut s = BTreeSet::new();
        s.insert("accel.dense".to_string());
        s
    }

    fn accel_dense(b: &mut GraphBuilder, name: &str, x: NodeId, c: usize, k: usize) -> NodeId {
        let w = b.constant(
            format!("{name}_w"),
            Tensor::new(vec![c, k], TensorData::I8(vec![1; c * k])).unwrap(),
        );
        let bias = b.constant(
            format!("{name}_b"),
            Tensor::new(vec![k], TensorData::I32(vec![0; k])).unwrap(),
        );
        b.op(
            name,
            Op::AccelDense { scale: 1.0, act: Activation::None, weight_transposed: true },
            &[x, w, bias],
        )
        .unwrap()
    }

    #[test]
    fn contiguous_accel_layers_form_one_region() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![1, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        let l2 = accel_dense(&mut b, "l2", l1, 8, 4);
        let g = b.outputs(&[l2]);
        let pg = partition(&g, &supported()).unwrap();
        assert_eq!(pg.accel_nodes(), 2);
        assert_eq!(pg.host_nodes(), 0);
        assert_eq!(pg.regions.len(), 1);
        assert_eq!(pg.regions[0].len(), 2);
    }

    #[test]
    fn host_op_splits_regions() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
        let l1 = accel_dense(&mut b, "l1", x, 8, 8);
        // A host-only transpose between the two dense layers.
        let t = b.op("t", Op::Transpose, &[l1]).unwrap();
        let l2 = accel_dense(&mut b, "l2", t, 8, 4);
        let g = b.outputs(&[l2]);
        let pg = partition(&g, &supported()).unwrap();
        assert_eq!(pg.regions.len(), 2);
        assert_eq!(pg.host_nodes(), 1);
        assert_eq!(pg.targets[t], Target::Host);
    }

    #[test]
    fn unsupported_everything_goes_to_host() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 2], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);
        let pg = partition(&g, &BTreeSet::new()).unwrap();
        assert_eq!(pg.accel_nodes(), 0);
        assert_eq!(pg.host_nodes(), 1);
        assert!(pg.regions.is_empty());
    }
}
