//! Post-training symmetric int8 quantization helpers.
//!
//! These mirror the quantization performed by the Python exporter
//! (`python/compile/export_model.py`); keeping both implementations
//! bit-identical (same scale selection, same round-ties-even) is what lets
//! the XLA golden model and the Rust compiler agree exactly.

use anyhow::{ensure, Result};

use super::{Graph, GraphBuilder, NodeId, Op, Tensor, TensorData, TensorType};
use crate::relay::DType;

/// Choose a symmetric scale so `max |x|` maps to 127.
pub fn symmetric_scale(xs: &[f32]) -> f32 {
    let maxabs = xs.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if maxabs == 0.0 {
        1.0
    } else {
        maxabs / 127.0
    }
}

/// Quantize to int8 with the given scale (round-ties-even, saturating).
pub fn quantize_i8(xs: &[f32], scale: f32) -> Vec<i8> {
    xs.iter()
        .map(|&v| (v / scale).round_ties_even().clamp(-128.0, 127.0) as i8)
        .collect()
}

/// One dense layer of a float MLP.
#[derive(Debug, Clone)]
pub struct FloatDense {
    /// Weights in TFLite layout `[out, in]`.
    pub weight: Vec<f32>,
    pub bias: Vec<f32>,
    pub in_dim: usize,
    pub out_dim: usize,
    pub relu: bool,
}

/// Quantized layer parameters.
#[derive(Debug, Clone)]
pub struct QuantDense {
    pub weight_q: Vec<i8>,
    pub bias_q: Vec<i32>,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Requantize multiplier `s_in · s_w / s_out`.
    pub requant: f32,
    /// Activation scale of this layer's output.
    pub out_scale: f32,
    pub relu: bool,
}

/// Quantize an MLP layer by layer. `act_scales[i]` is the calibration
/// scale of layer `i`'s *input* activation (so `act_scales[0]` is the model
/// input scale and `act_scales[n]` the output scale) — in a real flow these
/// come from calibration data; tests use fixed values.
pub fn quantize_mlp(layers: &[FloatDense], act_scales: &[f32]) -> Result<Vec<QuantDense>> {
    ensure!(
        act_scales.len() == layers.len() + 1,
        "need one activation scale per boundary"
    );
    let mut out = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        ensure!(l.weight.len() == l.in_dim * l.out_dim, "weight size");
        ensure!(l.bias.len() == l.out_dim, "bias size");
        let s_in = act_scales[i];
        let s_out = act_scales[i + 1];
        let s_w = symmetric_scale(&l.weight);
        let weight_q = quantize_i8(&l.weight, s_w);
        // Bias scale is s_in · s_w (accumulator domain).
        let bias_q = l
            .bias
            .iter()
            .map(|&b| (b / (s_in * s_w)).round_ties_even() as i32)
            .collect();
        out.push(QuantDense {
            weight_q,
            bias_q,
            in_dim: l.in_dim,
            out_dim: l.out_dim,
            requant: s_in * s_w / s_out,
            out_scale: s_out,
            relu: l.relu,
        });
    }
    Ok(out)
}

/// Build the fine-grained QNN graph (dense → bias_add → requantize →
/// clip/relu per layer) for a quantized MLP — the exact shape a TFLite
/// importer would produce, and the input to legalization.
pub fn build_qnn_graph(batch: usize, layers: &[QuantDense]) -> Result<Graph> {
    ensure!(!layers.is_empty(), "empty model");
    let mut b = GraphBuilder::new();
    let mut cur: NodeId =
        b.input("x", TensorType::new(vec![batch, layers[0].in_dim], DType::I8));
    for (i, l) in layers.iter().enumerate() {
        let w = b.constant(
            format!("w{i}"),
            Tensor::new(vec![l.out_dim, l.in_dim], TensorData::I8(l.weight_q.clone()))?,
        );
        let bias = b.constant(
            format!("b{i}"),
            Tensor::new(vec![l.out_dim], TensorData::I32(l.bias_q.clone()))?,
        );
        let d = b.op(format!("dense{i}"), Op::QnnDense, &[cur, w])?;
        let a = b.op(format!("bias{i}"), Op::BiasAdd, &[d, bias])?;
        let r = b.op(format!("requant{i}"), Op::Requantize { scale: l.requant }, &[a])?;
        cur = if l.relu {
            b.op(format!("relu{i}"), Op::Relu, &[r])?
        } else {
            r
        };
    }
    let g = b.outputs(&[cur]);
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::eval::eval;
    use crate::util::prng::Rng;

    fn random_mlp(rng: &mut Rng, dims: &[usize]) -> Vec<FloatDense> {
        dims.windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.4).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect()
    }

    #[test]
    fn scale_selection() {
        assert_eq!(symmetric_scale(&[0.0, 0.0]), 1.0);
        let s = symmetric_scale(&[-2.54, 1.0]);
        assert!((s - 2.54 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn quantize_saturates_and_rounds() {
        let q = quantize_i8(&[300.0, -300.0, 0.5, -0.5, 1.5], 1.0);
        // round-ties-even: 0.5 -> 0, -0.5 -> 0, 1.5 -> 2.
        assert_eq!(q, vec![127, -128, 0, 0, 2]);
    }

    #[test]
    fn quantized_mlp_tracks_float_model() {
        // Quantized inference should approximate the float model within a
        // few quantization steps.
        let mut rng = Rng::new(21);
        let dims = [16usize, 32, 8];
        let layers = random_mlp(&mut rng, &dims);
        let act_scales = [0.02f32, 0.05, 0.08];
        let q = quantize_mlp(&layers, &act_scales).unwrap();
        let g = build_qnn_graph(1, &q).unwrap();

        // Float reference.
        let x_f: Vec<f32> = (0..dims[0]).map(|_| rng.f64() as f32 - 0.5).collect();
        let mut cur = x_f.clone();
        for l in &layers {
            let mut next = vec![0f32; l.out_dim];
            for j in 0..l.out_dim {
                let mut s = l.bias[j];
                for c in 0..l.in_dim {
                    s += cur[c] * l.weight[j * l.in_dim + c];
                }
                next[j] = if l.relu { s.max(0.0) } else { s };
            }
            cur = next;
        }

        // Quantized inference through the graph interpreter.
        let x_q = quantize_i8(&x_f, act_scales[0]);
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![1, dims[0]], TensorData::I8(x_q)).unwrap(),
        );
        let out = eval(&g, &m).unwrap();
        let got: Vec<f32> = out[0]
            .data
            .as_i8()
            .unwrap()
            .iter()
            .map(|&v| v as f32 * act_scales[2])
            .collect();
        for (a, b) in cur.iter().zip(&got) {
            assert!(
                (a - b).abs() < 6.0 * act_scales[2],
                "float {a} vs quant {b}"
            );
        }
    }

    #[test]
    fn qnn_graph_has_expected_shape() {
        let mut rng = Rng::new(3);
        let layers = random_mlp(&mut rng, &[8, 8, 8]);
        let q = quantize_mlp(&layers, &[0.1, 0.1, 0.1]).unwrap();
        let g = build_qnn_graph(4, &q).unwrap();
        let h = crate::relay::legalize::op_histogram(&g);
        assert_eq!(h["qnn.dense"], 2);
        assert_eq!(h["bias_add"], 2);
        assert_eq!(h["qnn.requantize"], 2);
        assert_eq!(h["relu"], 1); // only the hidden layer
    }

    #[test]
    fn act_scale_arity_checked() {
        let mut rng = Rng::new(4);
        let layers = random_mlp(&mut rng, &[4, 4]);
        assert!(quantize_mlp(&layers, &[0.1]).is_err());
    }
}
