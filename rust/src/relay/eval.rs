//! Reference interpreter for the graph IR.
//!
//! Independent of both the simulator and the XLA runtime, this is the
//! semantic ground truth the compiled programs are tested against (the
//! third leg of the validation triangle: graph eval ↔ simulator ↔ XLA).

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Context, Result};

use crate::isa::Activation;
use crate::sim::requantize;

use super::{Graph, NodeId, Op, Tensor, TensorData, TensorType};

/// Evaluate `g` on the given input tensors (keyed by input node name).
pub fn eval(g: &Graph, inputs: &BTreeMap<String, Tensor>) -> Result<Vec<Tensor>> {
    let mut values: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        let v = match &n.op {
            Op::Input => inputs
                .get(&n.name)
                .cloned()
                .ok_or_else(|| anyhow!("missing input '{}'", n.name))?,
            Op::Constant(t) => t.clone(),
            op => {
                let ins: Vec<&Tensor> = n
                    .inputs
                    .iter()
                    .map(|&i| values[i].as_ref().expect("topological order"))
                    .collect();
                eval_op(op, &ins, &n.ty).with_context(|| format!("node %{} {}", n.id, op.name()))?
            }
        };
        ensure!(
            v.ty == n.ty,
            "node %{}: value type {} != node type {}",
            n.id,
            v.ty,
            n.ty
        );
        values[n.id] = Some(v);
    }
    g.outputs
        .iter()
        .map(|&o: &NodeId| {
            values[o]
                .clone()
                .ok_or_else(|| anyhow!("output %{o} not computed"))
        })
        .collect()
}

fn eval_op(op: &Op, ins: &[&Tensor], out_ty: &TensorType) -> Result<Tensor> {
    let t = match op {
        Op::QnnDense => {
            let x = ins[0].data.as_i8()?;
            let w = ins[1].data.as_i8()?;
            let (n, c) = (ins[0].ty.shape[0], ins[0].ty.shape[1]);
            let k = ins[1].ty.shape[0];
            let mut out = vec![0i32; n * k];
            for i in 0..n {
                for j in 0..k {
                    let mut s = 0i32;
                    for cc in 0..c {
                        // TFLite layout: w[j, cc].
                        s += x[i * c + cc] as i32 * w[j * c + cc] as i32;
                    }
                    out[i * k + j] = s;
                }
            }
            Tensor::new(vec![n, k], TensorData::I32(out))?
        }
        Op::QnnConv2d { stride, pad } => {
            let x = ins[0].data.as_i8()?;
            let w = ins[1].data.as_i8()?;
            let [n, h, wd, c]: [usize; 4] = ins[0].ty.shape.clone().try_into().unwrap();
            let [k, kh, kw, _]: [usize; 4] = ins[1].ty.shape.clone().try_into().unwrap();
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (wd + 2 * pad - kw) / stride + 1;
            let mut out = vec![0i32; n * oh * ow * k];
            for b in 0..n {
                for oy in 0..oh {
                    for ox in 0..ow {
                        for kk in 0..k {
                            let mut s = 0i32;
                            for dy in 0..kh {
                                for dx in 0..kw {
                                    let iy = (oy * stride + dy) as isize - *pad as isize;
                                    let ix = (ox * stride + dx) as isize - *pad as isize;
                                    if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize
                                    {
                                        continue; // zero padding
                                    }
                                    for ch in 0..c {
                                        let xv = x[((b * h + iy as usize) * wd
                                            + ix as usize)
                                            * c
                                            + ch]
                                            as i32;
                                        let wv = w[((kk * kh + dy) * kw + dx) * c + ch] as i32;
                                        s += xv * wv;
                                    }
                                }
                            }
                            out[((b * oh + oy) * ow + ox) * k + kk] = s;
                        }
                    }
                }
            }
            Tensor::new(vec![n, oh, ow, k], TensorData::I32(out))?
        }
        Op::Im2col { kh, kw, stride, pad } => {
            let x = ins[0].data.as_i8()?;
            let [n, h, wd, c]: [usize; 4] = ins[0].ty.shape.clone().try_into().unwrap();
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (wd + 2 * pad - kw) / stride + 1;
            let cols = kh * kw * c;
            let mut out = vec![0i8; n * oh * ow * cols];
            for b in 0..n {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let row = ((b * oh + oy) * ow + ox) * cols;
                        for dy in 0..*kh {
                            for dx in 0..*kw {
                                let iy = (oy * stride + dy) as isize - *pad as isize;
                                let ix = (ox * stride + dx) as isize - *pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue; // rows pre-zeroed
                                }
                                let src = ((b * h + iy as usize) * wd + ix as usize) * c;
                                let dst = row + (dy * kw + dx) * c;
                                out[dst..dst + c]
                                    .copy_from_slice(&x[src..src + c]);
                            }
                        }
                    }
                }
            }
            Tensor::new(vec![n * oh * ow, cols], TensorData::I8(out))?
        }
        Op::BiasAdd => {
            let x = ins[0].data.as_i32()?;
            let b = ins[1].data.as_i32()?;
            let k = *ins[0].ty.shape.last().unwrap();
            let out = x
                .iter()
                .enumerate()
                .map(|(i, &v)| v.wrapping_add(b[i % k]))
                .collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::I32(out))?
        }
        Op::Requantize { scale } => {
            let x = ins[0].data.as_i32()?;
            let out = x.iter().map(|&v| requantize(v, *scale, Activation::None)).collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::I8(out))?
        }
        Op::Clip { lo, hi } => {
            let x = ins[0].data.as_i8()?;
            let out = x.iter().map(|&v| v.clamp(*lo, *hi)).collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::I8(out))?
        }
        Op::Relu => {
            let x = ins[0].data.as_i8()?;
            let out = x.iter().map(|&v| v.max(0)).collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::I8(out))?
        }
        Op::Transpose => {
            let (r, c) = (ins[0].ty.shape[0], ins[0].ty.shape[1]);
            match &ins[0].data {
                TensorData::I8(x) => {
                    let mut out = vec![0i8; r * c];
                    for i in 0..r {
                        for j in 0..c {
                            out[j * r + i] = x[i * c + j];
                        }
                    }
                    Tensor::new(vec![c, r], TensorData::I8(out))?
                }
                TensorData::I32(x) => {
                    let mut out = vec![0i32; r * c];
                    for i in 0..r {
                        for j in 0..c {
                            out[j * r + i] = x[i * c + j];
                        }
                    }
                    Tensor::new(vec![c, r], TensorData::I32(out))?
                }
                TensorData::F32(x) => {
                    let mut out = vec![0f32; r * c];
                    for i in 0..r {
                        for j in 0..c {
                            out[j * r + i] = x[i * c + j];
                        }
                    }
                    Tensor::new(vec![c, r], TensorData::F32(out))?
                }
            }
        }
        Op::Reshape { shape } => Tensor::new(shape.clone(), ins[0].data.clone())?,
        Op::Quantize { scale } => {
            let x = ins[0].data.as_f32()?;
            let out = x
                .iter()
                .map(|&v| (v / scale).round_ties_even().clamp(-128.0, 127.0) as i8)
                .collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::I8(out))?
        }
        Op::Dequantize { scale } => {
            let x = ins[0].data.as_i8()?;
            let out = x.iter().map(|&v| v as f32 * scale).collect();
            Tensor::new(ins[0].ty.shape.clone(), TensorData::F32(out))?
        }
        Op::AccelDense { scale, act, weight_transposed } => {
            let x = ins[0].data.as_i8()?;
            let w = ins[1].data.as_i8()?;
            let b = ins[2].data.as_i32()?;
            let (n, c) = (ins[0].ty.shape[0], ins[0].ty.shape[1]);
            let k = if *weight_transposed { ins[1].ty.shape[1] } else { ins[1].ty.shape[0] };
            let mut out = vec![0i8; n * k];
            for i in 0..n {
                for j in 0..k {
                    let mut s = b[j];
                    for cc in 0..c {
                        // [C,K] when transposed, [K,C] in importer layout.
                        let wv = if *weight_transposed { w[cc * k + j] } else { w[j * c + cc] };
                        s += x[i * c + cc] as i32 * wv as i32;
                    }
                    out[i * k + j] = requantize(s, *scale, *act);
                }
            }
            Tensor::new(vec![n, k], TensorData::I8(out))?
        }
        Op::Input | Op::Constant(_) => unreachable!("handled by caller"),
    };
    ensure!(&t.ty == out_ty, "eval produced {}, node expects {}", t.ty, out_ty);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::{DType, GraphBuilder};
    use crate::util::prng::Rng;

    fn input_map(name: &str, t: Tensor) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(name.to_string(), t);
        m
    }

    #[test]
    fn qnn_sequence_matches_fused_accel_dense() {
        // The legalized op must be semantically identical to the sequence.
        let mut rng = Rng::new(77);
        let (n, c, k) = (5, 12, 9);
        let x = Tensor::new(vec![n, c], TensorData::I8(rng.i8_vec(n * c))).unwrap();
        let w = Tensor::new(vec![k, c], TensorData::I8(rng.i8_vec(k * c))).unwrap();
        let bias = Tensor::new(
            vec![k],
            TensorData::I32((0..k).map(|_| rng.below(100) as i32 - 50).collect()),
        )
        .unwrap();
        let scale = 0.05f32;

        // Graph 1: the fine-grained sequence.
        let mut b1 = GraphBuilder::new();
        let xi = b1.input("x", TensorType::new(vec![n, c], DType::I8));
        let wc = b1.constant("w", w.clone());
        let bc = b1.constant("b", bias.clone());
        let d = b1.op("d", Op::QnnDense, &[xi, wc]).unwrap();
        let ba = b1.op("ba", Op::BiasAdd, &[d, bc]).unwrap();
        let rq = b1.op("rq", Op::Requantize { scale }, &[ba]).unwrap();
        let cl = b1.op("cl", Op::Clip { lo: -100, hi: 100 }, &[rq]).unwrap();
        let g1 = b1.outputs(&[cl]);

        // Graph 2: the generalized accelerator op.
        let mut b2 = GraphBuilder::new();
        let xi = b2.input("x", TensorType::new(vec![n, c], DType::I8));
        let wc = b2.constant("w", w);
        let bc = b2.constant("b", bias);
        let ad = b2
            .op(
                "ad",
                Op::AccelDense {
                    scale,
                    act: Activation::Clip { lo: -100, hi: 100 },
                    weight_transposed: false,
                },
                &[xi, wc, bc],
            )
            .unwrap();
        let g2 = b2.outputs(&[ad]);

        let o1 = eval(&g1, &input_map("x", x.clone())).unwrap();
        let o2 = eval(&g2, &input_map("x", x)).unwrap();
        assert_eq!(o1[0].data, o2[0].data);
    }

    #[test]
    fn quantize_dequantize_roundtrip_small_values() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![4], DType::F32));
        let q = b.op("q", Op::Quantize { scale: 0.5 }, &[x]).unwrap();
        let dq = b.op("dq", Op::Dequantize { scale: 0.5 }, &[q]).unwrap();
        let g = b.outputs(&[dq]);
        let t = Tensor::new(vec![4], TensorData::F32(vec![1.0, -2.5, 0.0, 3.0])).unwrap();
        let out = eval(&g, &input_map("x", t)).unwrap();
        assert_eq!(out[0].data.as_f32().unwrap(), &[1.0, -2.5, 0.0, 3.0]);
    }

    #[test]
    fn transpose_eval() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 3], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);
        let inp = Tensor::new(vec![2, 3], TensorData::I8(vec![1, 2, 3, 4, 5, 6])).unwrap();
        let out = eval(&g, &input_map("x", inp)).unwrap();
        assert_eq!(out[0].data.as_i8().unwrap(), &[1, 4, 2, 5, 3, 6]);
        assert_eq!(out[0].ty.shape, vec![3, 2]);
    }

    #[test]
    fn missing_input_is_an_error() {
        let g = crate::relay::tests::qnn_layer();
        assert!(eval(&g, &BTreeMap::new()).is_err());
    }

    // ---- quantized-model edge cases (independent of the fuzzer, so a
    // ---- regression here localizes to the interpreter itself) ----

    use crate::relay::import::{to_qnn_graph, QLayer, QModel};

    fn layer(in_dim: usize, out_dim: usize, requant: f32, act: u8) -> QLayer {
        QLayer {
            in_dim,
            out_dim,
            requant,
            out_scale: 0.1,
            act,
            lo: -100,
            hi: 100,
            weight: vec![0; out_dim * in_dim],
            bias: vec![0; out_dim],
        }
    }

    fn eval_qmodel(model: &QModel, input: Vec<i8>) -> Vec<i8> {
        let g = to_qnn_graph(model).unwrap();
        let t = Tensor::new(
            vec![model.batch, model.layers[0].in_dim],
            TensorData::I8(input),
        )
        .unwrap();
        let out = eval(&g, &input_map("x", t)).unwrap();
        out[0].data.as_i8().unwrap().to_vec()
    }

    #[test]
    fn single_layer_1x1x1_gemm() {
        // The smallest possible model: batch 1, one 1×1 layer.
        // acc = 3*4 + 10 = 22; requant 0.5 → 11.
        let mut l = layer(1, 1, 0.5, 0);
        l.weight = vec![4];
        l.bias = vec![10];
        let m = QModel { batch: 1, input_scale: 0.05, layers: vec![l] };
        assert_eq!(eval_qmodel(&m, vec![3]), vec![11]);
    }

    #[test]
    fn saturation_at_both_i8_rails() {
        // Identity requant with huge biases must clamp to exactly -128
        // and 127, not wrap.
        let mut l = layer(1, 2, 1.0, 0);
        l.bias = vec![100_000, -100_000];
        let m = QModel { batch: 1, input_scale: 0.05, layers: vec![l] };
        assert_eq!(eval_qmodel(&m, vec![1]), vec![127, -128]);
    }

    #[test]
    fn identity_requant_passes_accumulator_through() {
        // scale 1.0: in-range accumulators come back exactly.
        let mut l = layer(1, 1, 1.0, 0);
        l.weight = vec![7];
        l.bias = vec![-3];
        let m = QModel { batch: 1, input_scale: 0.05, layers: vec![l] };
        assert_eq!(eval_qmodel(&m, vec![5]), vec![32]); // 5*7 - 3
    }

    #[test]
    fn zero_input_graph_is_bias_only() {
        // An all-zero input exercises the bias-only data path: the dense
        // contributes nothing, so the output is the requantized bias.
        let mut l = layer(3, 2, 1.0, 0);
        l.weight = vec![9; 2 * 3]; // must not matter
        l.bias = vec![42, -7];
        let m = QModel { batch: 2, input_scale: 0.05, layers: vec![l] };
        assert_eq!(eval_qmodel(&m, vec![0; 2 * 3]), vec![42, -7, 42, -7]);
    }
}
