//! Constant folding + dead-code elimination.
//!
//! The paper's key frontend fix (§4): "TVM typically disables constant
//! folding for matched operators after graph partitioning, and re-enabling
//! it is non-trivial. We addressed this by extending UMA's Lower module to
//! extract and propagate constant parameters correctly." Here the pass runs
//! over the legalized graph, so constant-related preprocessing — the weight
//! transposition inserted for `accel.dense` — evaluates at compile time and
//! never reaches the runtime program. The naive BYOC baseline skips this
//! pass, reproducing the paper's degraded configuration.

use std::collections::BTreeMap;

use anyhow::Result;

use super::eval::eval;
use super::{Graph, GraphBuilder, NodeId, Op, Tensor};

/// Fold every op whose inputs are all constants into a `Constant` node,
/// then drop nodes unreachable from the outputs.
pub fn fold_constants(g: &Graph) -> Result<Graph> {
    // Evaluate constant subgraphs node by node.
    let mut const_val: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        match &n.op {
            Op::Input => {}
            Op::Constant(t) => const_val[n.id] = Some(t.clone()),
            op => {
                if n.inputs.iter().all(|&i| const_val[i].is_some()) {
                    // Reuse the interpreter on a one-op subgraph.
                    let mut b = GraphBuilder::new();
                    let ins: Vec<NodeId> = n
                        .inputs
                        .iter()
                        .map(|&i| b.constant(format!("c{i}"), const_val[i].clone().unwrap()))
                        .collect();
                    let id = b.op("f", op.clone(), &ins)?;
                    let sub = b.outputs(&[id]);
                    let mut out = eval(&sub, &BTreeMap::new())?;
                    const_val[n.id] = Some(out.remove(0));
                }
            }
        }
    }

    // Rebuild: folded nodes become constants; then DCE by reachability.
    let mut reachable = vec![false; g.nodes.len()];
    let mut stack: Vec<NodeId> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if reachable[id] {
            continue;
        }
        reachable[id] = true;
        // A folded node needs none of its inputs anymore.
        if const_val[id].is_none() || matches!(g.node(id).op, Op::Constant(_)) {
            for &i in &g.node(id).inputs {
                stack.push(i);
            }
        }
    }
    // Keep graph inputs alive even if unused (interface stability).
    for &i in &g.inputs {
        reachable[i] = true;
    }

    let mut b = GraphBuilder::new();
    let mut remap: BTreeMap<NodeId, NodeId> = BTreeMap::new();
    for n in &g.nodes {
        if !reachable[n.id] {
            continue;
        }
        let new_id = match (&n.op, &const_val[n.id]) {
            (Op::Input, _) => b.input(n.name.clone(), n.ty.clone()),
            (Op::Constant(t), _) => b.constant(n.name.clone(), t.clone()),
            (_, Some(v)) => b.constant(format!("{}_folded", n.name), v.clone()),
            (op, None) => {
                let ins: Vec<NodeId> = n.inputs.iter().map(|i| remap[i]).collect();
                b.op(n.name.clone(), op.clone(), &ins)?
            }
        };
        remap.insert(n.id, new_id);
    }
    let outs: Vec<NodeId> = g.outputs.iter().map(|o| remap[o]).collect();
    let out = b.outputs(&outs);
    out.validate()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;
    use crate::relay::legalize::{legalize, op_histogram, LegalizeConfig};
    use crate::relay::{DType, TensorData, TensorType};
    use crate::util::prng::Rng;

    #[test]
    fn folds_weight_transpose() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 4], DType::I8));
        let w = b.constant(
            "w",
            Tensor::new(vec![3, 4], TensorData::I8((0..12).map(|v| v as i8).collect()))
                .unwrap(),
        );
        let bias =
            b.constant("b", Tensor::new(vec![3], TensorData::I32(vec![0; 3])).unwrap());
        let wt = b.op("wt", Op::Transpose, &[w]).unwrap();
        let ad = b
            .op(
                "ad",
                Op::AccelDense {
                    scale: 1.0,
                    act: Activation::None,
                    weight_transposed: true,
                },
                &[x, wt, bias],
            )
            .unwrap();
        let g = b.outputs(&[ad]);
        let fg = fold_constants(&g).unwrap();
        let h = op_histogram(&fg);
        assert_eq!(h.get("transpose"), None, "transpose must fold away:\n{}", fg.dump());
        assert_eq!(h.get("accel.dense"), Some(&1));
        // The folded weight constant is in [C,K] layout.
        let folded = fg
            .nodes
            .iter()
            .find(|n| n.name == "wt_folded")
            .expect("folded transpose constant");
        assert_eq!(folded.ty.shape, vec![4, 3]);
    }

    #[test]
    fn legalize_then_fold_leaves_only_fused_ops() {
        // End-to-end frontend: QNN chain -> legalize -> fold gives a graph
        // of input + constants + accel.dense only.
        let mut rng = Rng::new(9);
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![1, 16], DType::I8));
        let w = b.constant(
            "w",
            Tensor::new(vec![8, 16], TensorData::I8(rng.i8_vec(128))).unwrap(),
        );
        let bias =
            b.constant("b", Tensor::new(vec![8], TensorData::I32(vec![5; 8])).unwrap());
        let d = b.op("d", Op::QnnDense, &[x, w]).unwrap();
        let a = b.op("a", Op::BiasAdd, &[d, bias]).unwrap();
        let r = b.op("r", Op::Requantize { scale: 0.1 }, &[a]).unwrap();
        let g = b.outputs(&[r]);

        let lg = legalize(
            &g,
            &LegalizeConfig { dense: true, conv2d: false, insert_weight_transpose: true },
        )
        .unwrap();
        let fg = fold_constants(&lg).unwrap();
        let h = op_histogram(&fg);
        assert_eq!(h.get("accel.dense"), Some(&1));
        assert_eq!(h.get("transpose"), None);
        assert_eq!(h.get("qnn.dense"), None);
        // Semantics unchanged.
        let inp = Tensor::new(vec![1, 16], TensorData::I8(rng.i8_vec(16))).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), inp);
        let o1 = eval(&g, &m).unwrap();
        let o2 = eval(&fg, &m).unwrap();
        assert_eq!(o1[0].data, o2[0].data);
    }

    #[test]
    fn dce_removes_dead_constants() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2], DType::I8));
        let _dead =
            b.constant("dead", Tensor::new(vec![4], TensorData::I8(vec![1; 4])).unwrap());
        let r = b.op("relu", Op::Relu, &[x]).unwrap();
        let g = b.outputs(&[r]);
        let fg = fold_constants(&g).unwrap();
        assert!(fg.nodes.iter().all(|n| n.name != "dead"));
    }

    #[test]
    fn non_constant_paths_untouched() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 2], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);
        let fg = fold_constants(&g).unwrap();
        assert_eq!(op_histogram(&fg).get("transpose"), Some(&1));
    }
}
