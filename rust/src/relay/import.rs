//! `.qmodel` importer — the DNN-specification input of Fig. 1.
//!
//! The Python exporter (`python/compile/export_model.py`) writes quantized
//! MLP models in a compact little-endian binary format; this importer
//! reconstructs the fine-grained QNN graph exactly as a TFLite frontend
//! would parse the model. Format (all little-endian):
//!
//! ```text
//! magic   b"QMDL"            4 bytes
//! version u8 = 1
//! n_layers u32, batch u32, input_scale f32
//! per layer:
//!   in_dim u32, out_dim u32, requant f32, out_scale f32,
//!   act u8 (0 = none, 1 = relu, 2 = clip), lo i8, hi i8,
//!   weights i8[out_dim * in_dim]   (TFLite layout [out, in])
//!   bias    i32[out_dim]
//! ```

use anyhow::{bail, ensure, Context, Result};

use super::quantize::QuantDense;
use super::{Graph, GraphBuilder, NodeId, Op, Tensor, TensorData, TensorType};
use crate::relay::DType;

/// A parsed quantized model.
#[derive(Debug, Clone)]
pub struct QModel {
    pub batch: usize,
    pub input_scale: f32,
    pub layers: Vec<QLayer>,
}

/// One imported layer.
#[derive(Debug, Clone)]
pub struct QLayer {
    pub in_dim: usize,
    pub out_dim: usize,
    pub requant: f32,
    pub out_scale: f32,
    /// 0 = none, 1 = relu, 2 = clip(lo, hi).
    pub act: u8,
    pub lo: i8,
    pub hi: i8,
    /// TFLite layout `[out, in]`.
    pub weight: Vec<i8>,
    pub bias: Vec<i32>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "truncated qmodel at byte {}", self.pos);
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn i8(&mut self) -> Result<i8> {
        Ok(self.take(1)?[0] as i8)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Parse a `.qmodel` byte buffer.
pub fn parse_qmodel(buf: &[u8]) -> Result<QModel> {
    let mut c = Cursor { buf, pos: 0 };
    if c.take(4)? != b"QMDL" {
        bail!("bad qmodel magic");
    }
    let version = c.u8()?;
    ensure!(version == 1, "unsupported qmodel version {version}");
    let n_layers = c.u32()? as usize;
    let batch = c.u32()? as usize;
    let input_scale = c.f32()?;
    ensure!(n_layers > 0 && n_layers < 1024, "implausible layer count {n_layers}");
    ensure!(batch > 0, "batch must be positive");
    let mut layers = Vec::with_capacity(n_layers);
    for li in 0..n_layers {
        let in_dim = c.u32()? as usize;
        let out_dim = c.u32()? as usize;
        let requant = c.f32()?;
        let out_scale = c.f32()?;
        let act = c.u8()?;
        let lo = c.i8()?;
        let hi = c.i8()?;
        ensure!(act <= 2, "layer {li}: bad activation tag {act}");
        ensure!(in_dim > 0 && out_dim > 0, "layer {li}: zero dim");
        let wbytes = c.take(out_dim * in_dim)?;
        let weight: Vec<i8> = wbytes.iter().map(|&b| b as i8).collect();
        let mut bias = Vec::with_capacity(out_dim);
        for _ in 0..out_dim {
            bias.push(i32::from_le_bytes(c.take(4)?.try_into().unwrap()));
        }
        layers.push(QLayer { in_dim, out_dim, requant, out_scale, act, lo, hi, weight, bias });
    }
    ensure!(c.pos == buf.len(), "trailing bytes in qmodel");
    // Chain consistency.
    for w in layers.windows(2) {
        ensure!(
            w[0].out_dim == w[1].in_dim,
            "layer chain mismatch: {} -> {}",
            w[0].out_dim,
            w[1].in_dim
        );
    }
    Ok(QModel { batch, input_scale, layers })
}

/// Load a `.qmodel` file.
pub fn load_qmodel(path: &std::path::Path) -> Result<QModel> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_qmodel(&buf).with_context(|| format!("parsing {}", path.display()))
}

/// Serialize a model back to bytes (used by tests and by the Rust-side
/// model tooling; the Python exporter writes the same format).
pub fn write_qmodel(m: &QModel) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"QMDL");
    out.push(1);
    out.extend_from_slice(&(m.layers.len() as u32).to_le_bytes());
    out.extend_from_slice(&(m.batch as u32).to_le_bytes());
    out.extend_from_slice(&m.input_scale.to_le_bytes());
    for l in &m.layers {
        out.extend_from_slice(&(l.in_dim as u32).to_le_bytes());
        out.extend_from_slice(&(l.out_dim as u32).to_le_bytes());
        out.extend_from_slice(&l.requant.to_le_bytes());
        out.extend_from_slice(&l.out_scale.to_le_bytes());
        out.push(l.act);
        out.push(l.lo as u8);
        out.push(l.hi as u8);
        out.extend(l.weight.iter().map(|&v| v as u8));
        for &b in &l.bias {
            out.extend_from_slice(&b.to_le_bytes());
        }
    }
    out
}

/// Build the fine-grained QNN graph from an imported model (what "TVM's
/// import module typically parses a quantized operator as", §3.3).
pub fn to_qnn_graph(m: &QModel) -> Result<Graph> {
    let mut b = GraphBuilder::new();
    let mut cur: NodeId =
        b.input("x", TensorType::new(vec![m.batch, m.layers[0].in_dim], DType::I8));
    for (i, l) in m.layers.iter().enumerate() {
        let w = b.constant(
            format!("w{i}"),
            Tensor::new(vec![l.out_dim, l.in_dim], TensorData::I8(l.weight.clone()))?,
        );
        let bias = b.constant(
            format!("b{i}"),
            Tensor::new(vec![l.out_dim], TensorData::I32(l.bias.clone()))?,
        );
        let d = b.op(format!("dense{i}"), Op::QnnDense, &[cur, w])?;
        let a = b.op(format!("bias{i}"), Op::BiasAdd, &[d, bias])?;
        let r = b.op(format!("requant{i}"), Op::Requantize { scale: l.requant }, &[a])?;
        cur = match l.act {
            0 => r,
            1 => b.op(format!("relu{i}"), Op::Relu, &[r])?,
            2 => b.op(format!("clip{i}"), Op::Clip { lo: l.lo, hi: l.hi }, &[r])?,
            _ => unreachable!("validated in parse"),
        };
    }
    let g = b.outputs(&[cur]);
    g.validate()?;
    Ok(g)
}

/// Deterministic synthetic quantized MLP: `dims` are the layer widths
/// (at least two), ReLU on every layer but the last, weights/biases drawn
/// from the seeded [`crate::util::prng::Rng`]. This is the one model
/// builder shared by `tvm-accel gen-model`, the compile-service tests and
/// the CI smoke job — same seed, same bytes, everywhere.
pub fn synth_qmodel(seed: u64, dims: &[usize], batch: usize) -> Result<QModel> {
    use super::quantize::{quantize_mlp, FloatDense};
    ensure!(dims.len() >= 2, "need at least two layer widths, got {}", dims.len());
    ensure!(dims.iter().all(|&d| d > 0), "every layer width must be positive");
    ensure!(batch > 0, "batch must be positive");
    let mut rng = crate::util::prng::Rng::new(seed);
    let layers: Vec<FloatDense> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < dims.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..dims.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
    let q = quantize_mlp(&layers, &scales)?;
    Ok(from_quantized(batch, scales[0], &q))
}

/// Convert quantizer output ([`QuantDense`]) into a model, for building
/// `.qmodel`s from Rust (tests, tooling).
pub fn from_quantized(batch: usize, input_scale: f32, layers: &[QuantDense]) -> QModel {
    QModel {
        batch,
        input_scale,
        layers: layers
            .iter()
            .map(|l| QLayer {
                in_dim: l.in_dim,
                out_dim: l.out_dim,
                requant: l.requant,
                out_scale: l.out_scale,
                act: if l.relu { 1 } else { 0 },
                lo: -128,
                hi: 127,
                weight: l.weight_q.clone(),
                bias: l.bias_q.clone(),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample_model(rng: &mut Rng) -> QModel {
        let dims = [12usize, 8, 4];
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| QLayer {
                in_dim: w[0],
                out_dim: w[1],
                requant: 0.03 + i as f32 * 0.01,
                out_scale: 0.1,
                act: if i == 0 { 1 } else { 0 },
                lo: -128,
                hi: 127,
                weight: rng.i8_vec(w[0] * w[1]),
                bias: (0..w[1]).map(|_| rng.below(100) as i32 - 50).collect(),
            })
            .collect();
        QModel { batch: 2, input_scale: 0.05, layers }
    }

    #[test]
    fn roundtrip_write_parse() {
        let mut rng = Rng::new(31);
        let m = sample_model(&mut rng);
        let bytes = write_qmodel(&m);
        let back = parse_qmodel(&bytes).unwrap();
        assert_eq!(back.batch, m.batch);
        assert_eq!(back.layers.len(), 2);
        for (a, b) in m.layers.iter().zip(&back.layers) {
            assert_eq!(a.weight, b.weight);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.requant, b.requant);
            assert_eq!(a.act, b.act);
        }
    }

    #[test]
    fn rejects_corrupt_models() {
        let mut rng = Rng::new(32);
        let m = sample_model(&mut rng);
        let bytes = write_qmodel(&m);
        assert!(parse_qmodel(&bytes[..bytes.len() - 1]).is_err()); // truncated
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(parse_qmodel(&bad_magic).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(parse_qmodel(&extra).is_err()); // trailing bytes
    }

    #[test]
    fn qnn_graph_from_model() {
        let mut rng = Rng::new(33);
        let m = sample_model(&mut rng);
        let g = to_qnn_graph(&m).unwrap();
        let h = crate::relay::legalize::op_histogram(&g);
        assert_eq!(h["qnn.dense"], 2);
        assert_eq!(h["relu"], 1);
        assert_eq!(g.node(g.outputs[0]).ty.shape, vec![2, 4]);
    }

    #[test]
    fn chain_mismatch_rejected() {
        let mut rng = Rng::new(34);
        let mut m = sample_model(&mut rng);
        m.layers[1].in_dim = 9;
        m.layers[1].weight = rng.i8_vec(9 * m.layers[1].out_dim);
        let bytes = write_qmodel(&m);
        assert!(parse_qmodel(&bytes).is_err());
    }
}
