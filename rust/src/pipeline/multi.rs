//! Cost-driven multi-accelerator compilation.
//!
//! One compile can target a *set* of accelerator descriptions plus the
//! implicit host fallback (the ROADMAP's multi-backend partitioning item,
//! following BYOC's partitioning model and MATCH's per-layer target
//! selection by profiled cost):
//!
//! ```text
//! MultiCompiler::new(vec![gemmini, bigarray_os])
//!     └─ partition: probe each layer on every supporting candidate via
//!        the shared schedule cache → assign to the cheapest target
//!     └─ schedule/mapping/codegen: per-layer against the assigned target
//!     └─ link: one MultiDeployment with per-target instruction-stream
//!        segments over a single shared DRAM image
//! ```
//!
//! The candidates pool one content-addressed [`ScheduleCache`], keyed by
//! accelerator fingerprint + GEMM shape + search options — so the cost
//! probes in the partition stage are exactly the searches the schedule
//! stage would run, and repeated shapes (per target) are searched once.
//! Two candidates describing the same machine even share entries.
//!
//! Functionally, execution is a serial handoff: each [`ProgramSegment`]
//! runs on its target's simulator, all segments share one DRAM, and the
//! per-segment reports are summed ([`RunReport::cycles`] stays that
//! serial total, so outputs — and single-target programs — are untouched
//! by anything below). On top of it every run *also* prices the
//! graph-level asynchronous schedule: segments are placed by data
//! dependency, so a consumer segment may start while its producer is
//! still running — its double-buffered reload of the boundary activation
//! (the first cycles of its head) only has to land after the producer's
//! last write of that region — and segments on different targets proceed
//! concurrently, each target's track serializing internally. The
//! simulator observes the actual boundary-region access times
//! ([`crate::sim::BoundaryWatch`]), the schedule is computed from them,
//! and the resulting makespan is reported as
//! [`RunReport::overlapped_cycles`] (provably ≤ the serial total) and in
//! per-segment detail as [`OverlapReport`].

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::accel::AccelDesc;
use crate::isa::program::Program;
use crate::obs::span::Trace;
use crate::obs::timeline::Timeline;
use crate::relay::Graph;
use crate::scheduler::cache::{CacheStats, ScheduleCache};
use crate::scheduler::Schedule;
use crate::sim::report::RunReport;
use crate::sim::{BoundaryWatch, Simulator};

use super::session::{render_stage_reports, ScheduleStats, StageReport};
use super::{BatchRun, CompileOptions, Compiler, CompilerSession, SessionMemo};

/// One contiguous run of program items emitted for (and executed by) a
/// single target. `target` indexes the deployment's target list; host ops
/// inside the range are executed by the host CPU as usual.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgramSegment {
    /// Index into [`MultiDeployment::targets`].
    pub target: usize,
    /// First item index (inclusive).
    pub start: usize,
    /// One past the last item index (exclusive).
    pub end: usize,
}

/// One evaluated target-switch boundary, with target names resolved: what
/// placing `layer` on `to` costs in forced DRAM round-trip cycles when its
/// producer sits on `from` (see
/// [`crate::scheduler::graph::switch_round_trip_cycles`]). `taken` marks
/// switches the partitioner actually paid for; the rest were avoided
/// because same-target placement (cost + no penalty) won.
#[derive(Debug, Clone)]
pub struct LayerBoundary {
    /// Graph-node name of the layer whose placement was evaluated.
    pub layer: String,
    /// Display name of the producer's target.
    pub from: String,
    /// Display name of the evaluated candidate target.
    pub to: String,
    /// Switch penalty in cycles.
    pub penalty: u64,
    /// The portion of `penalty` the overlapped executor hides by
    /// double-buffering the consumer's boundary reload under the
    /// producer's tail (see
    /// [`crate::scheduler::graph::switch_overlap_discount`]); the
    /// partitioner charges `penalty - overlap_discount`.
    pub overlap_discount: u64,
    /// Whether this switch won the placement.
    pub taken: bool,
}

/// Which accelerator one layer landed on, and at what cost.
#[derive(Debug, Clone)]
pub struct LayerAssignment {
    /// Graph-node name of the layer.
    pub layer: String,
    /// Index of the chosen accelerator in the deployment's target list.
    pub target: usize,
    /// Display name of the chosen accelerator.
    pub target_name: String,
    /// The schedule selected for the layer on that target.
    pub schedule: Schedule,
    /// Profiled cycle cost of that schedule, when profiling ran.
    pub cycles: Option<u64>,
}

/// A compiled multi-accelerator deployment: one program over one shared
/// DRAM image, split into per-target instruction-stream segments.
#[derive(Debug, Clone)]
pub struct MultiDeployment {
    /// The candidate accelerator descriptions, in the order given to the
    /// compiler (segment/assignment indices point into this list).
    pub targets: Vec<AccelDesc>,
    /// The deployable program (instructions of *all* targets plus host
    /// ops, one DRAM layout + init image).
    pub program: Program,
    /// Per-target segments covering `program.items` in execution order.
    pub segments: Vec<ProgramSegment>,
    /// Per-segment boundary activation regions, parallel to `segments`:
    /// entry *i* is the DRAM `(offset, bytes)` range the activation
    /// crossing the segment *i−1* → *i* handoff occupies (`None` for the
    /// first segment, which consumes the graph input instead). Segment
    /// *i*'s executor watches entry *i* as its incoming region and entry
    /// *i+1* as its outgoing one to time the overlapped schedule.
    pub boundary_regions: Vec<Option<(u64, u64)>>,
    /// The processed (post-frontend) graph.
    pub graph: Graph,
    /// DRAM byte offset of the int8 input region.
    pub input_offset: u64,
    /// Number of int8 input elements.
    pub input_elems: usize,
    /// DRAM byte offset of the int8 output region.
    pub output_offset: u64,
    /// Number of int8 output elements.
    pub output_elems: usize,
    /// Per-layer target choice + schedule (codegen order).
    pub assignments: Vec<LayerAssignment>,
    /// Every cross-target boundary the partitioner evaluated, with the
    /// switch penalty charged (the forced DRAM round-trip) and whether the
    /// switch was taken. Empty for single-target compiles.
    pub boundaries: Vec<LayerBoundary>,
}

/// The overlapped (graph-level asynchronous) schedule of one
/// multi-deployment run, computed from the boundary access times the
/// simulator observed. All vectors are parallel to
/// [`MultiDeployment::segments`].
#[derive(Debug, Clone, Default)]
pub struct OverlapReport {
    /// Global start cycle of each segment under the overlapped schedule.
    pub starts: Vec<u64>,
    /// Measured duration of each segment (its serial `RunReport::cycles`).
    pub durations: Vec<u64>,
    /// Segment-local cycle of each segment's *first read* of its incoming
    /// boundary region — the head it can run before needing the
    /// producer's data (0 when unobserved: no claimed overlap).
    pub heads: Vec<u64>,
    /// Segment-local cycle of each segment's *last write* to its outgoing
    /// boundary region — when its consumer's data is ready (the duration
    /// when unobserved: release only at segment end).
    pub readies: Vec<u64>,
    /// Serial handoff total (Σ durations) — equals `RunReport::cycles`.
    pub serial_cycles: u64,
    /// Overlapped makespan: max over segments of `start + duration`.
    /// Always ≤ `serial_cycles`.
    pub overlapped_cycles: u64,
}

impl OverlapReport {
    /// Cycles the overlapped schedule saves over the serial handoff.
    pub fn saved_cycles(&self) -> u64 {
        self.serial_cycles - self.overlapped_cycles
    }
}

/// Place segments under the dependency-driven overlapped model: segment
/// *i* starts at the later of (a) when its target's track frees up and
/// (b) the latest start at which its first boundary read (`heads[i]`
/// cycles in) still lands after the producer's release
/// (`start_{i-1} + readies[i-1]`). Since `readies[i] ≤ durations[i]`,
/// induction gives `starts[i] ≤ Σ_{j<i} durations[j]`, hence
/// overlapped ≤ serial.
fn overlap_schedule(
    n_targets: usize,
    segments: &[ProgramSegment],
    durations: Vec<u64>,
    heads: Vec<u64>,
    readies: Vec<u64>,
) -> OverlapReport {
    let mut avail = vec![0u64; n_targets];
    let mut prev_release = 0u64;
    let mut starts = Vec::with_capacity(segments.len());
    for (i, seg) in segments.iter().enumerate() {
        let dep = if i == 0 { 0 } else { prev_release.saturating_sub(heads[i]) };
        let start = avail[seg.target].max(dep);
        starts.push(start);
        avail[seg.target] = start + durations[i];
        prev_release = start + readies[i];
    }
    let overlapped_cycles =
        starts.iter().zip(&durations).map(|(s, d)| s + d).max().unwrap_or(0);
    let serial_cycles = durations.iter().sum();
    OverlapReport { starts, durations, heads, readies, serial_cycles, overlapped_cycles }
}

impl MultiDeployment {
    fn simulators(&self) -> Vec<Simulator> {
        self.targets.iter().map(|t| Simulator::new(&t.arch)).collect()
    }

    /// Double-buffered input staging needs a spare slot in the first
    /// layer's input buffer (see `Deployment`'s hint of the same name).
    fn input_hint(&self) -> Option<(u64, u64)> {
        match self.assignments.first() {
            Some(a) if a.schedule.double_buffer => {
                Some((self.input_offset, self.input_elems as u64))
            }
            _ => None,
        }
    }

    /// The boundary regions segment `i` watches while executing: incoming
    /// is the activation it consumes across the handoff into it, outgoing
    /// the one it produces for the next segment.
    fn watch_for(&self, i: usize) -> BoundaryWatch {
        BoundaryWatch {
            incoming: self.boundary_regions.get(i).copied().flatten(),
            outgoing: self.boundary_regions.get(i + 1).copied().flatten(),
        }
    }

    /// Execute every segment (serial, fence-drained handoff over the
    /// shared DRAM), watching each segment's boundary regions, then place
    /// the segments under the overlapped schedule. The merged report's
    /// `overlapped_cycles` carries the makespan; with `timelines` set,
    /// one per-segment [`Timeline`] is captured and shifted to its
    /// overlapped start so the tracks show true concurrent starts.
    fn run_segments(
        &self,
        sims: &[Simulator],
        dram: &mut crate::sim::memory::Dram,
        mut timelines: Option<&mut Vec<(String, Timeline)>>,
    ) -> Result<(RunReport, OverlapReport)> {
        let mut rep = RunReport::default();
        let hint = self.input_hint();
        let n = self.segments.len();
        let (mut durations, mut heads, mut readies) =
            (Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n));
        for (i, seg) in self.segments.iter().enumerate() {
            let sim = sims
                .get(seg.target)
                .with_context(|| format!("segment names unknown target {}", seg.target))?;
            let watch = self.watch_for(i);
            let ctx = || {
                format!(
                    "items {}..{} on target '{}'",
                    seg.start, seg.end, self.targets[seg.target].name
                )
            };
            let (r, obs) = match timelines.as_deref_mut() {
                Some(tls) => {
                    let mut tl = Timeline::new();
                    let out = sim
                        .run_slice_observed(
                            &self.program,
                            dram,
                            seg.start..seg.end,
                            hint,
                            watch,
                            &mut tl,
                        )
                        .with_context(ctx)?;
                    tls.push((self.targets[seg.target].name.clone(), tl));
                    out
                }
                None => sim
                    .run_slice_watched(&self.program, dram, seg.start..seg.end, hint, watch)
                    .with_context(ctx)?,
            };
            durations.push(r.cycles);
            // Unobserved boundaries fall back to "no head to run early,
            // data ready only at segment end" — never claiming overlap
            // the execution didn't exhibit. `ready ≤ duration` is what
            // makes overlapped ≤ serial provable, so clamp.
            heads.push(obs.first_read.unwrap_or(0));
            readies.push(obs.last_write.unwrap_or(r.cycles).min(r.cycles));
            rep.merge(&r);
        }
        let ov = overlap_schedule(self.targets.len(), &self.segments, durations, heads, readies);
        rep.overlapped_cycles = ov.overlapped_cycles;
        if let Some(tls) = timelines {
            for (tl, &start) in tls.iter_mut().zip(&ov.starts) {
                tl.1.shift(start);
            }
        }
        Ok((rep, ov))
    }

    /// Run one inference: stage constants into a fresh DRAM, write the
    /// int8 input, execute each segment on its target's simulator (serial
    /// handoff over the shared DRAM), and read the int8 output. The
    /// report is the sum over segments, with
    /// [`RunReport::overlapped_cycles`] carrying the overlapped makespan.
    pub fn run(&self, input: &[i8]) -> Result<(Vec<i8>, RunReport)> {
        let (out, rep, _) = self.run_overlapped(input)?;
        Ok((out, rep))
    }

    /// [`MultiDeployment::run`], additionally returning the full
    /// per-segment [`OverlapReport`]: where each segment starts under the
    /// dependency-driven schedule, its observed boundary head/ready
    /// cycles, and the serial vs overlapped totals.
    pub fn run_overlapped(&self, input: &[i8]) -> Result<(Vec<i8>, RunReport, OverlapReport)> {
        ensure!(
            input.len() == self.input_elems,
            "input has {} elems, model wants {}",
            input.len(),
            self.input_elems
        );
        let sims = self.simulators();
        let mut dram = self.program.make_dram()?;
        dram.write_i8_slice(self.input_offset, input)?;
        let (rep, ov) = self.run_segments(&sims, &mut dram, None)?;
        let out = dram.read_i8_slice(self.output_offset, self.output_elems)?;
        Ok((out, rep, ov))
    }

    /// [`MultiDeployment::run`] with execution-timeline capture: one
    /// [`Timeline`] per program segment, labeled with the executing
    /// target's display name. Each timeline is shifted to its segment's
    /// *overlapped-schedule* start cycle, so exporting the tracks side by
    /// side shows the true concurrent starts (a consumer's head under its
    /// producer's tail), not serial offsets. Outputs and the merged
    /// report are identical to an unprofiled run.
    pub fn run_profiled(
        &self,
        input: &[i8],
    ) -> Result<(Vec<i8>, RunReport, Vec<(String, Timeline)>)> {
        ensure!(
            input.len() == self.input_elems,
            "input has {} elems, model wants {}",
            input.len(),
            self.input_elems
        );
        let sims = self.simulators();
        let mut dram = self.program.make_dram()?;
        dram.write_i8_slice(self.input_offset, input)?;
        let mut timelines = Vec::with_capacity(self.segments.len());
        let (rep, _) = self.run_segments(&sims, &mut dram, Some(&mut timelines))?;
        let out = dram.read_i8_slice(self.output_offset, self.output_elems)?;
        Ok((out, rep, timelines))
    }

    /// Run many inferences back to back, staging the DRAM image once
    /// (mirrors [`super::Deployment::run_batch`]). The returned
    /// [`BatchRun`]'s pipelined model is the better of the host-prefix
    /// overlap (inference *i+1*'s preprocessing under inference *i*'s
    /// accelerator work) and the full cross-accelerator layer pipeline:
    /// inference *i+1*'s head segments start on target A as soon as A's
    /// track frees, while inference *i*'s tail still occupies target B.
    pub fn run_batch(&self, inputs: &[&[i8]]) -> Result<BatchRun> {
        let sims = self.simulators();
        let mut dram = self.program.make_dram()?;
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut reports = Vec::with_capacity(inputs.len());
        let mut overlaps = Vec::with_capacity(inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            ensure!(
                input.len() == self.input_elems,
                "batch input {i} has {} elems, model wants {}",
                input.len(),
                self.input_elems
            );
            dram.write_i8_slice(self.input_offset, input)?;
            let (rep, ov) = self.run_segments(&sims, &mut dram, None)?;
            reports.push(rep);
            overlaps.push(ov);
            outputs.push(dram.read_i8_slice(self.output_offset, self.output_elems)?);
        }
        let mut brun = BatchRun::new(outputs, reports);
        brun.pipelined_cycles =
            brun.pipelined_cycles.min(self.batch_overlap_makespan(&overlaps));
        Ok(brun)
    }

    /// Makespan of the whole batch under the segment-level pipeline:
    /// per-target availability persists *across* inferences, so inference
    /// *i+1* claims target A the moment A's track frees, while within an
    /// inference the usual dependency/head-overlap placement applies. A
    /// single-segment deployment degenerates to the serial total, so
    /// `run_batch`'s `min` keeps the host-prefix model there.
    fn batch_overlap_makespan(&self, overlaps: &[OverlapReport]) -> u64 {
        let mut avail = vec![0u64; self.targets.len()];
        let mut makespan = 0u64;
        for ov in overlaps {
            let mut prev_release = 0u64;
            for (i, seg) in self.segments.iter().enumerate() {
                let dep = if i == 0 { 0 } else { prev_release.saturating_sub(ov.heads[i]) };
                let start = avail[seg.target].max(dep);
                avail[seg.target] = start + ov.durations[i];
                prev_release = start + ov.readies[i];
                makespan = makespan.max(start + ov.durations[i]);
            }
        }
        makespan
    }

    /// Number of layers assigned to accelerator `target`.
    pub fn nodes_on_target(&self, target: usize) -> usize {
        self.assignments.iter().filter(|a| a.target == target).count()
    }

    /// Render the evaluated target-switch boundaries (penalty in cycles,
    /// how much of it the overlapped executor hides, taken or avoided) as
    /// an indented summary.
    pub fn render_boundaries(&self) -> String {
        let mut out = String::new();
        for b in &self.boundaries {
            out.push_str(&format!(
                "{:<12} {} -> {}: switch cost {} cycles, overlap hides {} ({})\n",
                b.layer,
                b.from,
                b.to,
                b.penalty,
                b.overlap_discount.min(b.penalty),
                if b.taken { "taken" } else { "avoided" }
            ));
        }
        out
    }

    /// The partitioner's compile-time estimate of the serial vs overlapped
    /// end-to-end cycles: profiled per-layer costs plus taken switch
    /// penalties, with the overlap discount of every taken boundary
    /// subtracted from the overlapped figure. Returns
    /// `(serial_estimate, overlapped_estimate)`.
    pub fn overlap_estimate(&self) -> (u64, u64) {
        let compute: u64 = self.assignments.iter().map(|a| a.cycles.unwrap_or(0)).sum();
        let taken = self.boundaries.iter().filter(|b| b.taken);
        let (switch, hidden) = taken.fold((0u64, 0u64), |(s, h), b| {
            (s + b.penalty, h + b.overlap_discount.min(b.penalty))
        });
        (compute + switch, compute + switch - hidden)
    }

    /// Render the per-layer target choices as an indented summary.
    pub fn render_assignments(&self) -> String {
        let mut out = String::new();
        for a in &self.assignments {
            let cost = match a.cycles {
                Some(c) => format!("{c} cycles"),
                None => "unprofiled".to_string(),
            };
            out.push_str(&format!("{:<12} -> {:<12} {cost}\n", a.layer, a.target_name));
        }
        out
    }
}

/// Everything a multi-target session produces: the deployment plus the
/// per-stage reports (the partition stage lists the chosen target and its
/// cost per layer) and schedule-selection counters.
#[derive(Debug, Clone)]
pub struct MultiSessionOutput {
    /// The compiled multi-accelerator deployment.
    pub deployment: MultiDeployment,
    /// Per-stage timing + diagnostics, in execution order.
    pub stages: Vec<StageReport>,
    /// Schedule-selection counters from the schedule stage.
    pub schedule_stats: ScheduleStats,
    /// The session's trace (see
    /// [`super::SessionOutput::trace`][crate::pipeline::SessionOutput]).
    pub trace: Arc<Trace>,
}

impl MultiSessionOutput {
    /// Render the stage reports as an indented summary (for CLIs/examples).
    pub fn render_stages(&self) -> String {
        render_stage_reports(&self.stages)
    }
}

/// The cost-driven multi-accelerator compiler: one compile places each
/// supported layer on the cheapest of several candidate accelerators
/// (host fallback for layers no candidate supports). Construct with
/// [`Compiler::with_targets`] or [`MultiCompiler::new`]. All candidates
/// share one [`ScheduleCache`], so cost probes double as the schedule
/// search and long-lived compilers amortize it across compiles.
///
/// With a single candidate the emitted program is byte-identical to
/// [`Compiler::new`] + [`Compiler::compile`] for that accelerator.
pub struct MultiCompiler {
    compilers: Vec<Compiler>,
}

impl MultiCompiler {
    /// A multi-target compiler with default [`CompileOptions`]. Fails on
    /// an empty target list.
    pub fn new(targets: Vec<AccelDesc>) -> Result<MultiCompiler> {
        MultiCompiler::with_options(targets, CompileOptions::default())
    }

    /// A multi-target compiler with explicit options (shared by every
    /// candidate; the search options are part of the schedule-cache key).
    pub fn with_options(targets: Vec<AccelDesc>, options: CompileOptions) -> Result<MultiCompiler> {
        MultiCompiler::with_shared_cache(targets, options, Arc::new(ScheduleCache::new()))
    }

    /// A multi-target compiler pooled on an externally owned schedule
    /// cache — the compile service hands every request a `MultiCompiler`
    /// over its long-lived, disk-hydrated cache, so candidate probes hit
    /// entries produced by earlier requests (and other processes).
    pub fn with_shared_cache(
        targets: Vec<AccelDesc>,
        options: CompileOptions,
        cache: Arc<ScheduleCache>,
    ) -> Result<MultiCompiler> {
        ensure!(!targets.is_empty(), "need at least one accelerator description");
        let compilers = targets
            .into_iter()
            .map(|accel| Compiler::with_shared_cache(accel, options.clone(), cache.clone()))
            .collect();
        Ok(MultiCompiler { compilers })
    }

    /// The candidate accelerator descriptions, in target-index order.
    pub fn targets(&self) -> impl Iterator<Item = &AccelDesc> {
        self.compilers.iter().map(|c| &c.accel)
    }

    /// Compile a (QNN) graph into a multi-accelerator deployment.
    pub fn compile(&self, graph: &Graph) -> Result<MultiDeployment> {
        Ok(self.compile_with_report(graph)?.deployment)
    }

    /// Compile and return the per-stage reports alongside the deployment.
    pub fn compile_with_report(&self, graph: &Graph) -> Result<MultiSessionOutput> {
        CompilerSession::multi(self.compilers.iter().collect()).run_multi(graph)
    }

    /// Compile with fine-grained tracing (see
    /// [`Compiler::compile_traced`]): cache consults, single-flight
    /// elections and sweep spans across every candidate land in the
    /// returned trace. Byte-identical to [`MultiCompiler::compile`].
    pub fn compile_traced(&self, graph: &Graph) -> Result<MultiSessionOutput> {
        CompilerSession::multi(self.compilers.iter().collect()).traced().run_multi(graph)
    }

    /// Compile against an incremental-session memo: layers (and partition
    /// cost probes) whose cache key already appears in `memo` skip the
    /// sweep, the profiling, and even the shared-cache lookup. See
    /// [`Compiler::compile_incremental`].
    pub fn compile_incremental(
        &self,
        graph: &Graph,
        memo: &SessionMemo,
    ) -> Result<MultiDeployment> {
        Ok(self.compile_incremental_with_report(graph, memo)?.deployment)
    }

    /// [`MultiCompiler::compile_incremental`] with per-stage reports.
    pub fn compile_incremental_with_report(
        &self,
        graph: &Graph,
        memo: &SessionMemo,
    ) -> Result<MultiSessionOutput> {
        CompilerSession::multi_with_memo(self.compilers.iter().collect(), memo).run_multi(graph)
    }

    /// Total Fig. 2(b) sweeps executed across all candidates.
    pub fn sweeps_run(&self) -> u64 {
        self.compilers.iter().map(|c| c.sweeps_run()).sum()
    }

    /// Cache hits observed by this multi-compiler's own lookups, summed
    /// across candidates (per-request attribution; the shared cache's
    /// counters aggregate every attached compiler).
    pub fn cache_hits(&self) -> u64 {
        self.compilers.iter().map(|c| c.cache_hits()).sum()
    }

    /// Cache misses observed by this multi-compiler's own lookups (see
    /// [`MultiCompiler::cache_hits`]).
    pub fn cache_misses(&self) -> u64 {
        self.compilers.iter().map(|c| c.cache_misses()).sum()
    }

    /// Solver leaves costed across all candidates' sweeps (see
    /// [`Compiler::solver_leaves_visited`]).
    pub fn solver_leaves_visited(&self) -> u64 {
        self.compilers.iter().map(|c| c.solver_leaves_visited()).sum()
    }

    /// Dominated sweep configuration points pruned across all candidates
    /// (see [`Compiler::configs_pruned`]).
    pub fn configs_pruned(&self) -> u64 {
        self.compilers.iter().map(|c| c.configs_pruned()).sum()
    }

    /// Counters of the schedule cache shared by all candidates.
    pub fn cache_stats(&self) -> CacheStats {
        self.compilers[0].cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::{desc_for_arch, gemmini_desc};
    use crate::arch::ArchDesc;
    use crate::relay::eval::eval;
    use crate::relay::import::{synth_qmodel, to_qnn_graph};
    use crate::relay::{Tensor, TensorData};
    use crate::util::prng::Rng;
    use std::collections::BTreeMap;

    fn mlp_graph(seed: u64, dims: &[usize], batch: usize) -> Graph {
        to_qnn_graph(&synth_qmodel(seed, dims, batch).unwrap()).unwrap()
    }

    fn bigarray_desc() -> AccelDesc {
        let mut arch = ArchDesc::gemmini();
        arch.name = "bigarray-os".into();
        arch.pe_dim = 32;
        arch.constraints.insn_tile_limit = 32;
        arch.dataflows = vec![crate::arch::Dataflow::OutputStationary];
        arch.levels[1].size_bytes = 131072; // accumulator
        arch.levels[2].size_bytes = 524288; // scratchpad
        arch.dma.bytes_per_cycle = 32;
        desc_for_arch("bigarray-os", arch).unwrap()
    }

    #[test]
    fn single_target_multi_compiler_matches_plain_compiler() {
        let graph = mlp_graph(21, &[32, 48, 16], 4);
        let accel = gemmini_desc().unwrap();
        let multi = Compiler::with_targets(std::slice::from_ref(&accel)).unwrap();
        let md = multi.compile(&graph).unwrap();
        let plain = Compiler::new(accel).compile(&graph).unwrap();
        assert_eq!(md.program.items, plain.program.items, "single-target must be byte-identical");
        assert_eq!(md.input_offset, plain.input_offset);
        assert_eq!(md.output_offset, plain.output_offset);
        let all = ProgramSegment { target: 0, start: 0, end: md.program.items.len() };
        assert_eq!(md.segments, vec![all]);
        // One segment has nothing to overlap with: the makespan equals the
        // serial total.
        let mut rng = Rng::new(21);
        let (_, rep, ov) = md.run_overlapped(&rng.i8_vec(4 * 32)).unwrap();
        assert_eq!(ov.overlapped_cycles, ov.serial_cycles);
        assert_eq!(rep.overlapped_cycles, rep.cycles);
        assert_eq!(ov.saved_cycles(), 0);
    }

    #[test]
    fn overlap_schedule_hides_head_under_producer_tail() {
        let segs = [
            ProgramSegment { target: 0, start: 0, end: 1 },
            ProgramSegment { target: 1, start: 1, end: 2 },
        ];
        // Producer releases its boundary write at cycle 80 (of 100); the
        // consumer first reads it 30 cycles into its own run. The consumer
        // may therefore start at 80 - 30 = 50, overlapping its head with
        // the producer's tail: makespan 50 + 60 = 110 < 160 serial.
        let ov = overlap_schedule(2, &segs, vec![100, 60], vec![0, 30], vec![80, 60]);
        assert_eq!(ov.starts, vec![0, 50]);
        assert_eq!(ov.serial_cycles, 160);
        assert_eq!(ov.overlapped_cycles, 110);
        assert_eq!(ov.saved_cycles(), 50);
        // Unobserved boundaries (head 0, ready = duration) degenerate to
        // the serial handoff.
        let ov = overlap_schedule(2, &segs, vec![100, 60], vec![0, 0], vec![100, 60]);
        assert_eq!(ov.starts, vec![0, 100]);
        assert_eq!(ov.overlapped_cycles, ov.serial_cycles);
    }

    #[test]
    fn overlap_schedule_never_self_overlaps_a_target_track() {
        // Three segments, the outer two on target 0: even with a huge head
        // on segment 2, target 0's track must serialize.
        let segs = [
            ProgramSegment { target: 0, start: 0, end: 1 },
            ProgramSegment { target: 1, start: 1, end: 2 },
            ProgramSegment { target: 0, start: 2, end: 3 },
        ];
        let ov = overlap_schedule(
            2,
            &segs,
            vec![100, 50, 40],
            vec![0, 50, 40],
            vec![50, 10, 40],
        );
        // Segment 1's head covers the whole producer wait (start 0 legal),
        // but its track is target 1 so it can truly start at 0; segment 2
        // would also be dependency-free early, yet target 0 is busy until
        // cycle 100.
        assert_eq!(ov.starts, vec![0, 0, 100]);
        assert!(ov.overlapped_cycles <= ov.serial_cycles);
        // Dependency invariant: every consumer's first boundary read lands
        // at or after its producer's release.
        for i in 1..3 {
            assert!(ov.starts[i] + ov.heads[i] >= ov.starts[i - 1] + ov.readies[i - 1]);
        }
    }

    #[test]
    fn heterogeneous_compile_is_exact_and_reports_targets() {
        let mut rng = Rng::new(22);
        let dims = [64usize, 96, 32];
        let batch = 8;
        let graph = mlp_graph(22, &dims, batch);
        let multi =
            Compiler::with_targets(&[gemmini_desc().unwrap(), bigarray_desc()]).unwrap();
        let out = multi.compile_with_report(&graph).unwrap();
        let dep = &out.deployment;

        // Every dense layer got a target, cost, and a partition note.
        assert_eq!(dep.assignments.len(), 2);
        let partition = out.stages.iter().find(|s| s.name == "partition").unwrap();
        assert!(partition.notes.len() >= 3, "per-layer notes expected: {:?}", partition.notes);
        for a in &dep.assignments {
            assert!(a.cycles.is_some(), "profiled cost recorded for {}", a.layer);
            assert!(partition.notes.iter().any(|n| n.contains(&a.layer)));
        }

        // Execution agrees element-exactly with the interpreter.
        let input = rng.i8_vec(batch * dims[0]);
        let (got, rep) = dep.run(&input).unwrap();
        let mut m = BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![batch, dims[0]], TensorData::I8(input.clone())).unwrap(),
        );
        let want = eval(&graph, &m).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data);
        assert!(rep.cycles > 0);

        // The overlapped makespan is priced on every run, never exceeds
        // the serial handoff, and the detailed report is consistent.
        let (got2, rep2, ov) = dep.run_overlapped(&input).unwrap();
        assert_eq!(TensorData::I8(got2), want[0].data);
        assert!(rep.overlapped_cycles > 0);
        assert!(rep.overlapped_cycles <= rep.cycles);
        assert_eq!(rep2.overlapped_cycles, rep.overlapped_cycles);
        assert_eq!(ov.serial_cycles, rep.cycles);
        assert_eq!(ov.overlapped_cycles, rep.overlapped_cycles);
        assert_eq!(ov.starts.len(), dep.segments.len());
        let (est_serial, est_overlapped) = dep.overlap_estimate();
        assert!(est_overlapped <= est_serial);

        // Batch runs agree with individual runs; the pipelined batch model
        // never exceeds the serial total.
        let inputs: Vec<Vec<i8>> = (0..3).map(|_| rng.i8_vec(batch * dims[0])).collect();
        let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
        let brun = dep.run_batch(&refs).unwrap();
        for (i, x) in inputs.iter().enumerate() {
            let (o, r) = dep.run(x).unwrap();
            assert_eq!(brun.outputs[i], o);
            assert_eq!(brun.reports[i].cycles, r.cycles);
        }
        assert!(brun.pipelined_cycles <= brun.serial_cycles);
    }

    #[test]
    fn identical_candidates_tie_break_to_first_and_share_cache() {
        let graph = mlp_graph(23, &[32, 32, 32], 4);
        // Two descriptions of the same machine: identical fingerprints, so
        // the shared cache serves the second candidate's probes and every
        // equal-cost tie breaks to target 0.
        let a = gemmini_desc().unwrap();
        let b = desc_for_arch("gemmini-clone", ArchDesc::gemmini()).unwrap();
        let multi = Compiler::with_targets(&[a.clone(), b]).unwrap();
        let dep = multi.compile(&graph).unwrap();
        for asg in &dep.assignments {
            assert_eq!(asg.target, 0, "{} must tie-break to target 0", asg.layer);
        }
        // One sweep per distinct search, not per (search, candidate): the
        // two-candidate compile runs exactly as many sweeps as a plain
        // single-target compile of the same graph.
        let plain_compiler = Compiler::new(a);
        let plain = plain_compiler.compile(&graph).unwrap();
        assert_eq!(
            multi.sweeps_run(),
            plain_compiler.sweeps_run(),
            "identical fingerprints must share cache entries"
        );
        // And the result is byte-identical to the single-target compile.
        assert_eq!(dep.program.items, plain.program.items);
    }

    #[test]
    fn all_host_graph_still_links_and_runs() {
        use crate::relay::{DType, GraphBuilder, Op, TensorType};
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![4, 6], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);

        let multi =
            Compiler::with_targets(&[gemmini_desc().unwrap(), bigarray_desc()]).unwrap();
        let dep = multi.compile(&g).unwrap();
        assert!(dep.assignments.is_empty());
        assert_eq!(dep.segments.len(), 1, "all-host program is one segment");

        let mut rng = Rng::new(24);
        let input = rng.i8_vec(24);
        let (got, rep) = dep.run(&input).unwrap();
        let mut m = BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![4, 6], TensorData::I8(input)).unwrap(),
        );
        let want = eval(&g, &m).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data);
        assert_eq!(rep.cycles, rep.host_cycles, "no accelerator work");
        assert_eq!(multi.sweeps_run(), 0);
    }

    #[test]
    fn unsupported_node_between_layers_falls_back_to_host() {
        use crate::isa::Activation;
        use crate::relay::{DType, GraphBuilder, Op, TensorType};
        // accel.dense -> transpose (host-only) -> accel.dense.
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
        let mk_dense = |b: &mut GraphBuilder, name: &str, x, c: usize, k: usize| {
            let w = b
                .constant(
                    format!("{name}_w"),
                    Tensor::new(vec![c, k], TensorData::I8(vec![1; c * k])).unwrap(),
                );
            let bias = b.constant(
                format!("{name}_b"),
                Tensor::new(vec![k], TensorData::I32(vec![0; k])).unwrap(),
            );
            b.op(
                name,
                Op::AccelDense { scale: 1.0, act: Activation::None, weight_transposed: true },
                &[x, w, bias],
            )
            .unwrap()
        };
        let l1 = mk_dense(&mut b, "l1", x, 8, 8);
        let t = b.op("t", Op::Transpose, &[l1]).unwrap();
        let l2 = mk_dense(&mut b, "l2", t, 8, 8);
        let g = b.outputs(&[l2]);

        let multi =
            Compiler::with_targets(&[gemmini_desc().unwrap(), bigarray_desc()]).unwrap();
        let dep = multi.compile(&g).unwrap();
        assert_eq!(dep.assignments.len(), 2, "both dense layers offloaded");
        let (got, rep) = dep.run(&[1i8; 64]).unwrap();
        assert_eq!(got.len(), 64);
        assert!(rep.host_cycles > 0, "transpose must run on the host");
        assert!(
            rep.insn_counts.contains_key("host.transpose"),
            "host fallback executed: {:?}",
            rep.insn_counts
        );
    }
}
