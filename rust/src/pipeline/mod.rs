//! The end-to-end compiler façade (paper Fig. 1): model + accelerator
//! description → deployable program.
//!
//! The pipeline chains the configurators: frontend (legalize → fold →
//! partition), strategy generator, extended-CoSA sweep, simulator-in-the-
//! loop schedule selection ("the generated schedules ... are evaluated on
//! the hardware to determine the most efficient configuration based on
//! real execution profiling", §3.1), mapping generator and codegen. Host
//! nodes lower to host-CPU operations.

use anyhow::{bail, ensure, Context, Result};

use crate::accel::AccelDesc;
use crate::backend::codegen::{generate, LayerBufs};
use crate::backend::mapping::apply_schedule;
use crate::backend::strategy::generate_strategy_typed;
use crate::frontend::{configure, run_frontend};
use crate::isa::program::{HostOp, Program};
use crate::isa::Instr;
use crate::relay::partition::{PartitionedGraph, Target};
use crate::relay::{Graph, Op, TensorData};
use crate::scheduler::sweep::{sweep, SweepOptions};
use crate::scheduler::Schedule;
use crate::sim::report::RunReport;
use crate::sim::Simulator;
use crate::workload::{Dim, Gemm};

/// Compilation options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Use the extended CoSA scheduler (false = the naive default schedule
    /// of the BYOC baseline).
    pub use_scheduler: bool,
    /// Run compile-time constant folding (§4 fix; false in the naive
    /// baseline).
    pub fold_constants: bool,
    /// How many top sweep candidates to profile on the simulator before
    /// picking (0 = trust the analytic model).
    pub profile_candidates: usize,
    pub sweep: SweepOptions,
}

impl Default for CompileOptions {
    fn default() -> CompileOptions {
        CompileOptions {
            use_scheduler: true,
            fold_constants: true,
            profile_candidates: 6,
            sweep: SweepOptions::default(),
        }
    }
}

/// A compiled deployment.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub program: Program,
    /// The processed (post-frontend) graph.
    pub graph: Graph,
    pub input_offset: u64,
    pub input_elems: usize,
    pub output_offset: u64,
    pub output_elems: usize,
    /// Chosen schedule per accelerator layer (name, schedule, profiled
    /// cycles if profiling ran).
    pub chosen: Vec<(String, Schedule, Option<u64>)>,
}

impl Deployment {
    /// Run one inference on the simulator: stage constants, write the
    /// int8 input, execute, read the int8 output.
    pub fn run(&self, sim: &Simulator, input: &[i8]) -> Result<(Vec<i8>, RunReport)> {
        ensure!(
            input.len() == self.input_elems,
            "input has {} elems, model wants {}",
            input.len(),
            self.input_elems
        );
        let mut dram = self.program.make_dram()?;
        dram.write_i8_slice(self.input_offset, input)?;
        let rep = sim.run(&self.program, &mut dram)?;
        let out = dram.read_i8_slice(self.output_offset, self.output_elems)?;
        Ok((out, rep))
    }
}

/// The compiler: construct once per accelerator description.
pub struct Compiler {
    pub accel: AccelDesc,
    pub options: CompileOptions,
}

impl Compiler {
    pub fn new(accel: AccelDesc) -> Compiler {
        Compiler { accel, options: CompileOptions::default() }
    }

    pub fn with_options(accel: AccelDesc, options: CompileOptions) -> Compiler {
        Compiler { accel, options }
    }

    /// The naive default schedule (UMA/BYOC without CoSA): the TE-default
    /// lowering offloads one output row-block at a time with the full
    /// reduction staged (no multi-level tiling, no loop-order
    /// optimization, no double buffering, even memory shares).
    pub fn naive_schedule(&self, g: Gemm) -> Schedule {
        let dim = self.accel.arch.pe_dim;
        let insn = [g.n.min(dim), g.c.min(dim), g.k.min(dim)];
        // Stage as much of the reduction as naturally fits the row-block
        // (capped, multiple of the instruction tile).
        let c_t = if g.c <= insn[1] {
            g.c
        } else {
            (g.c.min(2048) / insn[1]) * insn[1]
        };
        Schedule {
            workload: g,
            dataflow: self.accel.arch.dataflows[0],
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: insn,
            onchip_tile: [insn[0], c_t, insn[2]],
            dram_order: [Dim::N, Dim::K, Dim::C],
            est: Default::default(),
        }
    }

    /// Pick the schedule for one layer: sweep + (optional) simulator
    /// profiling of the top candidates.
    fn select_schedule(&self, g: Gemm) -> Result<(Schedule, Option<u64>)> {
        if !self.options.use_scheduler {
            return Ok((self.naive_schedule(g), None));
        }
        let result = sweep(&self.accel.arch, g, &self.options.sweep);
        ensure!(
            !result.candidates.is_empty(),
            "scheduler found no valid mapping for {g:?}"
        );
        if self.options.profile_candidates == 0 {
            return Ok((result.candidates[0].clone(), None));
        }
        // Fig. 2(b): evaluate the refined candidates on the (simulated)
        // hardware and keep the measured best.
        let sim = Simulator::new(&self.accel.arch);
        let mut best: Option<(Schedule, u64)> = None;
        for s in result.candidates.iter().take(self.options.profile_candidates) {
            let cycles = self.profile_layer(&sim, s)?;
            if best.as_ref().map(|(_, c)| cycles < *c).unwrap_or(true) {
                best = Some((s.clone(), cycles));
            }
        }
        let (s, c) = best.unwrap();
        Ok((s, Some(c)))
    }

    /// Measure one candidate schedule by compiling and simulating the
    /// layer in isolation (timing is data-independent).
    fn profile_layer(&self, sim: &Simulator, s: &Schedule) -> Result<u64> {
        let g = s.workload;
        let quant = crate::tir::QuantAttrs { scale: 0.05, act: crate::isa::Activation::None };
        let f = crate::tir::TirFunc::unscheduled("profile", g, quant);
        let scheduled = apply_schedule(&self.accel, &f, s)?;
        let mut prog = Program::new("profile");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64)?.offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64)?.offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64)?.offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64)?.offset,
        };
        generate(&self.accel, &scheduled, s, &bufs, &mut prog)?;
        prog.push(Instr::Fence);
        let mut dram = prog.make_dram()?;
        Ok(sim.run(&prog, &mut dram)?.cycles)
    }

    /// Compile a (QNN) graph into a deployment.
    pub fn compile(&self, graph: &Graph) -> Result<Deployment> {
        let mut fcfg = configure(&self.accel);
        fcfg.fold_constants = self.options.fold_constants;
        let pg: PartitionedGraph = run_frontend(graph, &fcfg)?;
        let g = &pg.graph;
        ensure!(g.inputs.len() == 1, "exactly one graph input supported");
        ensure!(g.outputs.len() == 1, "exactly one graph output supported");

        let mut prog = Program::new("deployment");
        // One DRAM region per node value.
        let mut region: Vec<u64> = Vec::with_capacity(g.nodes.len());
        for n in &g.nodes {
            let r = prog
                .layout
                .alloc(format!("n{}_{}", n.id, n.name), n.ty.bytes() as u64)?
                .offset;
            region.push(r);
            if let Op::Constant(t) = &n.op {
                let bytes = match &t.data {
                    TensorData::I8(v) => v.iter().map(|&x| x as u8).collect(),
                    TensorData::I32(v) => {
                        v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                    }
                    TensorData::F32(v) => {
                        v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                    }
                };
                prog.add_init(r, bytes);
            }
        }

        let mut chosen = Vec::new();
        for n in &g.nodes {
            match pg.targets[n.id] {
                Target::None => {}
                Target::Accel => {
                    let shapes: Vec<Vec<usize>> =
                        n.inputs.iter().map(|&i| g.node(i).ty.shape.clone()).collect();
                    let strat = generate_strategy_typed(&self.accel, n, &shapes)?;
                    let (sched, cycles) = self.select_schedule(strat.gemm)?;
                    let scheduled = apply_schedule(&self.accel, &strat.tir, &sched)?;
                    let bufs = LayerBufs {
                        x: region[n.inputs[0]],
                        w: region[n.inputs[1]],
                        bias: region[n.inputs[2]],
                        out: region[n.id],
                    };
                    generate(&self.accel, &scheduled, &sched, &bufs, &mut prog)
                        .with_context(|| format!("codegen for layer '{}'", n.name))?;
                    // Drain before anything consumes this layer's DRAM
                    // output (the timing model tracks on-chip hazards only).
                    prog.push(Instr::Fence);
                    chosen.push((n.name.clone(), sched, cycles));
                }
                Target::Host => {
                    self.emit_host(g, n, &region, &mut prog)
                        .with_context(|| format!("host lowering for '{}'", n.name))?;
                }
            }
        }

        let in_node = g.node(g.inputs[0]);
        let out_node = g.node(g.outputs[0]);
        Ok(Deployment {
            input_offset: region[in_node.id],
            input_elems: in_node.ty.elems(),
            output_offset: region[out_node.id],
            output_elems: out_node.ty.elems(),
            program: prog,
            graph: pg.graph,
            chosen,
        })
    }

    /// Lower one host-assigned node to host ops.
    fn emit_host(&self, g: &Graph, n: &crate::relay::Node, region: &[u64], prog: &mut Program) -> Result<()> {
        let src = |i: usize| region[n.inputs[i]];
        let dst = region[n.id];
        match &n.op {
            Op::Transpose => {
                let s = &g.node(n.inputs[0]).ty.shape;
                prog.push_host(HostOp::TransposeI8 { src: src(0), dst, rows: s[0], cols: s[1] });
            }
            Op::Im2col { kh, kw, stride, pad } => {
                let s = &g.node(n.inputs[0]).ty.shape;
                prog.push_host(HostOp::Im2col {
                    src: src(0),
                    dst,
                    n: s[0],
                    h: s[1],
                    w: s[2],
                    c: s[3],
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    pad: *pad,
                });
            }
            Op::Reshape { .. } => {
                prog.push_host(HostOp::Memcpy {
                    src: src(0),
                    dst,
                    bytes: n.ty.bytes(),
                });
            }
            Op::Quantize { scale } => prog.push_host(HostOp::QuantizeF32 {
                src: src(0),
                dst,
                n: n.ty.elems(),
                scale: *scale,
            }),
            Op::Dequantize { scale } => prog.push_host(HostOp::DequantizeI8 {
                src: src(0),
                dst,
                n: n.ty.elems(),
                scale: *scale,
            }),
            Op::Requantize { scale } => prog.push_host(HostOp::RequantizeI32 {
                src: src(0),
                dst,
                n: n.ty.elems(),
                scale: *scale,
            }),
            Op::Clip { lo, hi } => {
                prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
                prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: *lo, hi: *hi });
            }
            Op::Relu => {
                prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
                prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: 0, hi: 127 });
            }
            Op::BiasAdd => {
                let s = &g.node(n.inputs[0]).ty.shape;
                prog.push_host(HostOp::BiasAddI32 {
                    x: src(0),
                    bias: src(1),
                    dst,
                    n: s[0],
                    k: s[1],
                });
            }
            Op::QnnDense => {
                // Host fallback: transpose TFLite-layout weights into a
                // scratch region, then int8 GEMM.
                let x = &g.node(n.inputs[0]).ty.shape;
                let w = &g.node(n.inputs[1]).ty.shape;
                let scratch = prog
                    .layout
                    .alloc(format!("n{}_wT_scratch", n.id), (w[0] * w[1]) as u64)?
                    .offset;
                prog.push_host(HostOp::TransposeI8 {
                    src: src(1),
                    dst: scratch,
                    rows: w[0],
                    cols: w[1],
                });
                prog.push_host(HostOp::MatmulI8 {
                    a: src(0),
                    b: scratch,
                    c: dst,
                    n: x[0],
                    c_dim: x[1],
                    k: w[0],
                });
            }
            other => bail!("no host lowering for operator '{}'", other.name()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::eval::eval;
    use crate::relay::import::{from_quantized, to_qnn_graph};
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::relay::{Tensor, TensorData};
    use crate::util::prng::Rng;

    fn mlp_model(rng: &mut Rng, dims: &[usize], batch: usize) -> crate::relay::import::QModel {
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect();
        let scales: Vec<f32> = (0..=layers.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
        let q = quantize_mlp(&layers, &scales).unwrap();
        from_quantized(batch, scales[0], &q)
    }

    /// Compile + simulate must agree element-exactly with the graph
    /// interpreter (semantic ground truth).
    fn check_deployment(opts: CompileOptions, dims: &[usize], batch: usize, seed: u64) -> RunReport {
        let mut rng = Rng::new(seed);
        let model = mlp_model(&mut rng, dims, batch);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let compiler = Compiler::with_options(accel.clone(), opts);
        let dep = compiler.compile(&graph).unwrap();

        let input = rng.i8_vec(batch * dims[0]);
        let sim = Simulator::new(&accel.arch);
        let (got, rep) = dep.run(&sim, &input).unwrap();

        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![batch, dims[0]], TensorData::I8(input)).unwrap(),
        );
        let want = eval(&graph, &m).unwrap();
        assert_eq!(&TensorData::I8(got), &want[0].data);
        rep
    }

    #[test]
    fn proposed_flow_end_to_end_exact() {
        check_deployment(CompileOptions::default(), &[32, 48, 16], 4, 1);
    }

    #[test]
    fn naive_flow_end_to_end_exact_and_slower() {
        let proposed = check_deployment(CompileOptions::default(), &[64, 64, 64], 8, 2);
        let naive = check_deployment(
            CompileOptions {
                use_scheduler: false,
                fold_constants: false,
                profile_candidates: 0,
                ..Default::default()
            },
            &[64, 64, 64],
            8,
            2,
        );
        assert!(
            naive.cycles > proposed.cycles,
            "naive {} should exceed proposed {}",
            naive.cycles,
            proposed.cycles
        );
        // The naive flow does runtime host preprocessing; proposed does none.
        assert!(naive.host_cycles > 0);
        assert_eq!(proposed.host_cycles, 0);
    }

    #[test]
    fn profiling_selection_records_cycles() {
        let mut rng = Rng::new(3);
        let model = mlp_model(&mut rng, &[32, 32], 4);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel).compile(&graph).unwrap();
        assert_eq!(dep.chosen.len(), 1);
        assert!(dep.chosen[0].2.is_some());
    }

    #[test]
    fn toycar_like_stack_compiles_exact() {
        // Small-width stand-in exercising the 10-layer dense stack shape.
        check_deployment(
            CompileOptions { profile_candidates: 2, ..Default::default() },
            &[40, 16, 16, 8, 16, 16, 40],
            1,
            4,
        );
    }
}
