//! The end-to-end compiler façade (paper Fig. 1): model + accelerator
//! description → deployable program.
//!
//! The heavy lifting lives in [`session`]: a [`CompilerSession`] chains
//! the configurators as seven explicit stages (frontend → partition →
//! schedule → crosslayer → mapping → codegen → link), each producing an
//! inspectable artifact plus timing/diagnostics. [`Compiler::compile`] is
//! a thin wrapper that runs a session and returns just the
//! [`Deployment`]; [`Compiler::compile_with_report`] additionally returns
//! the per-stage [`StageReport`]s.
//!
//! The crosslayer stage is the graph-aware part
//! ([`crate::scheduler::graph`]): activations flowing between adjacent
//! same-target layers stay resident in the scratchpad when the schedules
//! allow it, eliding the per-boundary DRAM store + reload; where the
//! per-layer winners are incompatible it re-runs boundary-constrained
//! searches, memoized under cache keys extended with the residency
//! constraint.
//!
//! Schedule selection ("the generated schedules ... are evaluated on the
//! hardware to determine the most efficient configuration based on real
//! execution profiling", §3.1) is memoized in a content-addressed
//! [`ScheduleCache`]: repeated layer shapes — within one model and across
//! models compiled by a long-lived `Compiler` — skip the Fig. 2(b) sweep
//! and the simulator profiling entirely. On a miss the sweep fans out
//! across scoped worker threads and the top-K candidates are profiled in
//! parallel, with deterministic, serial-identical results.
//!
//! A compile can also target *several* accelerator descriptions at once:
//! [`Compiler::with_targets`] builds a [`MultiCompiler`] whose partition
//! stage places each supported layer on the candidate with the cheapest
//! profiled schedule (host fallback otherwise) and links one
//! [`MultiDeployment`] driving per-target instruction streams — see
//! [`multi`].

#![warn(missing_docs)]

pub mod multi;
pub mod session;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::accel::AccelDesc;
use crate::backend::codegen::LayerBufs;
use crate::backend::Backend;
use crate::isa::program::Program;
use crate::isa::Instr;
use crate::obs::span::Trace;
use crate::obs::timeline::Timeline;
use crate::relay::Graph;
use crate::scheduler::cache::{
    CacheKey, CacheStats, CachedSelection, ScheduleCache, SearchGate, SearchKey,
};
use crate::scheduler::graph::ResidencyConstraint;
use crate::scheduler::sweep::SweepOptions;
use crate::scheduler::Schedule;
use crate::sim::report::RunReport;
use crate::sim::Simulator;
use crate::workload::{Dim, Gemm};

pub use multi::{
    LayerAssignment, LayerBoundary, MultiCompiler, MultiDeployment, MultiSessionOutput,
    OverlapReport, ProgramSegment,
};
pub use session::{CompilerSession, ScheduleStats, SessionOutput, StageReport};

/// Compilation options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Use the extended CoSA scheduler (false = the naive default schedule
    /// of the BYOC baseline).
    pub use_scheduler: bool,
    /// Run compile-time constant folding (§4 fix; false in the naive
    /// baseline).
    pub fold_constants: bool,
    /// How many top sweep candidates to profile on the simulator before
    /// picking (0 = trust the analytic model).
    pub profile_candidates: usize,
    /// Memoize schedule selections in the compiler's content-addressed
    /// cache (keyed by arch fingerprint + GEMM shape + search options).
    pub schedule_cache: bool,
    /// Run the graph-level cross-layer pass: keep activations resident
    /// on-chip across producer→consumer layer boundaries when feasible
    /// (re-running boundary-constrained searches where needed), eliding
    /// the DRAM round-trip per resident edge. Graphs with no feasible
    /// edge — and single-layer models — emit byte-identical programs
    /// either way. Requires `use_scheduler`.
    pub cross_layer: bool,
    /// Knobs of the Fig. 2(b) sweep grid.
    pub sweep: SweepOptions,
}

impl Default for CompileOptions {
    fn default() -> CompileOptions {
        CompileOptions {
            use_scheduler: true,
            fold_constants: true,
            profile_candidates: 6,
            schedule_cache: true,
            cross_layer: true,
            sweep: SweepOptions::default(),
        }
    }
}

/// Where a layer's schedule came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleSource {
    /// The naive default schedule (`use_scheduler = false`).
    Naive,
    /// Served from the schedule cache — no sweep, no profiling.
    Cache,
    /// Full sweep + profiling ran for this shape.
    Search,
    /// Served from an incremental-session memo ([`SessionMemo`]) — not
    /// even the shared cache was consulted.
    Memo,
}

/// A session-scoped schedule memo for incremental recompiles.
///
/// A [`SessionMemo`] remembers every selection made while compiling a
/// model; passing the same memo to a later compile
/// ([`Compiler::compile_incremental`] /
/// [`MultiCompiler::compile_incremental`]) re-runs the search only for
/// layers whose [`CacheKey`] — shape × arch fingerprint × search options
/// × residency constraint — changed since the last compile. Unlike the
/// shared [`ScheduleCache`] it is consulted *before* the single-flight
/// gate (so it also works with `schedule_cache: false`), and is only
/// used when explicitly passed — plain [`Compiler::compile`] calls are
/// unaffected. It lives in memory; services that want incremental
/// compiles to survive a process restart snapshot it to a versioned
/// artifact via [`crate::scheduler::persist::save_memo_to_file`] and
/// rehydrate with [`crate::scheduler::persist::hydrate_memo_from_file`].
#[derive(Debug, Default)]
pub struct SessionMemo {
    entries: Mutex<HashMap<CacheKey, (Schedule, Option<u64>)>>,
    hits: AtomicU64,
}

impl SessionMemo {
    /// An empty memo.
    pub fn new() -> SessionMemo {
        SessionMemo::default()
    }

    /// Memoized selections held.
    pub fn len(&self) -> usize {
        self.entries.lock().expect("memo lock poisoned").len()
    }

    /// True when nothing has been memoized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from this memo (across all compiles it was used in).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Snapshot every memoized selection (key, schedule, profiled cycles),
    /// in unspecified order — the input to
    /// [`crate::scheduler::persist::encode_memo`].
    pub fn snapshot(&self) -> Vec<(CacheKey, Schedule, Option<u64>)> {
        self.entries
            .lock()
            .expect("memo lock poisoned")
            .iter()
            .map(|(k, (s, c))| (*k, s.clone(), *c))
            .collect()
    }

    /// Bulk-insert selections (from a persisted snapshot,
    /// [`crate::scheduler::persist::load_memo_file`]). Existing keys are
    /// overwritten; the hit counter is unaffected.
    pub fn hydrate(
        &self,
        entries: impl IntoIterator<Item = (CacheKey, Schedule, Option<u64>)>,
    ) {
        let mut map = self.entries.lock().expect("memo lock poisoned");
        for (k, s, c) in entries {
            map.insert(k, (s, c));
        }
    }

    /// Whether a selection for `key` is memoized (counter-neutral —
    /// useful for prewarm planning without inflating the hit counter).
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.entries.lock().expect("memo lock poisoned").contains_key(key)
    }

    fn get(&self, key: &CacheKey) -> Option<(Schedule, Option<u64>)> {
        let found = self.entries.lock().expect("memo lock poisoned").get(key).cloned();
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    fn put(&self, key: CacheKey, schedule: &Schedule, cycles: Option<u64>) {
        self.entries
            .lock()
            .expect("memo lock poisoned")
            .insert(key, (schedule.clone(), cycles));
    }
}

/// A compiled deployment.
#[derive(Debug, Clone)]
pub struct Deployment {
    /// The deployable program (instructions + host ops + DRAM image).
    pub program: Program,
    /// The processed (post-frontend) graph.
    pub graph: Graph,
    /// DRAM byte offset of the int8 input region.
    pub input_offset: u64,
    /// Number of int8 input elements.
    pub input_elems: usize,
    /// DRAM byte offset of the int8 output region.
    pub output_offset: u64,
    /// Number of int8 output elements.
    pub output_elems: usize,
    /// Chosen schedule per accelerator layer (name, schedule, profiled
    /// cycles if profiling ran).
    pub chosen: Vec<(String, Schedule, Option<u64>)>,
}

impl Deployment {
    /// Run one inference on the simulator: stage constants, write the
    /// int8 input, execute, read the int8 output.
    pub fn run(&self, sim: &Simulator, input: &[i8]) -> Result<(Vec<i8>, RunReport)> {
        ensure!(
            input.len() == self.input_elems,
            "input has {} elems, model wants {}",
            input.len(),
            self.input_elems
        );
        let mut dram = self.program.make_dram()?;
        dram.write_i8_slice(self.input_offset, input)?;
        let rep = sim.run_hinted(&self.program, &mut dram, self.input_stage_hint())?;
        let out = dram.read_i8_slice(self.output_offset, self.output_elems)?;
        Ok((out, rep))
    }

    /// [`Deployment::run`] with execution-timeline capture: alongside the
    /// output and report, return the per-track occupancy [`Timeline`]
    /// (DMA / compute / store / host) the simulator reconstructed.
    /// Outputs and every report counter are identical to an unprofiled
    /// run — capture is strictly passive.
    pub fn run_profiled(
        &self,
        sim: &Simulator,
        input: &[i8],
    ) -> Result<(Vec<i8>, RunReport, Timeline)> {
        ensure!(
            input.len() == self.input_elems,
            "input has {} elems, model wants {}",
            input.len(),
            self.input_elems
        );
        let mut dram = self.program.make_dram()?;
        dram.write_i8_slice(self.input_offset, input)?;
        let mut tl = Timeline::new();
        let rep =
            sim.run_profiled(&self.program, &mut dram, self.input_stage_hint(), &mut tl)?;
        let out = dram.read_i8_slice(self.output_offset, self.output_elems)?;
        Ok((out, rep, tl))
    }

    /// The input-region hint for [`Simulator::run_hinted`]: double-buffered
    /// input staging needs a *spare* slot in the first accelerator layer's
    /// input buffer — with a single-buffered first layer the next
    /// inference's input physically cannot stream in while the current one
    /// executes, so no staging prefix is reported (and the pipelined batch
    /// model claims no such overlap).
    fn input_stage_hint(&self) -> Option<(u64, u64)> {
        match self.chosen.first() {
            Some((_, s, _)) if s.double_buffer => {
                Some((self.input_offset, self.input_elems as u64))
            }
            _ => None,
        }
    }

    /// Run many inferences back to back, amortizing the DRAM allocation
    /// and constant staging across the batch: the init image is staged
    /// once and only the input region is rewritten per inference. Outputs
    /// and reports are element-identical to `inputs.len()` separate
    /// [`Deployment::run`] calls (the program fully rewrites every region
    /// it reads each run); on top of the serial per-inference reports the
    /// returned [`BatchRun`] carries the pipelined batch timing model.
    pub fn run_batch(&self, sim: &Simulator, inputs: &[&[i8]]) -> Result<BatchRun> {
        let mut dram = self.program.make_dram()?;
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut reports = Vec::with_capacity(inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            ensure!(
                input.len() == self.input_elems,
                "batch input {i} has {} elems, model wants {}",
                input.len(),
                self.input_elems
            );
            dram.write_i8_slice(self.input_offset, input)?;
            let rep = sim.run_hinted(&self.program, &mut dram, self.input_stage_hint())?;
            outputs.push(dram.read_i8_slice(self.output_offset, self.output_elems)?);
            reports.push(rep);
        }
        Ok(BatchRun::new(outputs, reports))
    }
}

/// Result of a batched run: per-inference outputs and reports (identical
/// to N separate `run` calls) plus batch-level cycle totals under two
/// timing models — strictly serial inferences, and the pipelined model
/// where the host preprocesses inference *i+1* while the accelerator
/// still executes inference *i*.
#[derive(Debug, Clone)]
pub struct BatchRun {
    /// Per-inference int8 outputs, in input order.
    pub outputs: Vec<Vec<i8>>,
    /// Per-inference reports (element- and cycle-identical to `run`).
    pub reports: Vec<RunReport>,
    /// Total cycles when inferences run strictly back to back
    /// (sum of the per-inference `cycles`).
    pub serial_cycles: u64,
    /// Total cycles under the pipelined model: each inference's host
    /// preprocessing prefix *and* its first input-tile DMA
    /// (double-buffered input staging) overlap the previous inference's
    /// accelerator execution, so the batch hides
    /// `min(prefix + staging, previous accel time)` per inference. Always
    /// ≤ [`BatchRun::serial_cycles`]; equal when no inference has host
    /// preprocessing or input staging before its first compute.
    pub pipelined_cycles: u64,
}

impl BatchRun {
    pub(crate) fn new(outputs: Vec<Vec<i8>>, reports: Vec<RunReport>) -> BatchRun {
        let serial_cycles = reports.iter().map(|r| r.cycles).sum();
        let pipelined_cycles = pipelined_cycles(&reports);
        BatchRun { outputs, reports, serial_cycles, pipelined_cycles }
    }

    /// Mean serial latency per inference (0 for an empty batch).
    pub fn mean_cycles(&self) -> u64 {
        if self.reports.is_empty() {
            0
        } else {
            self.serial_cycles / self.reports.len() as u64
        }
    }
}

/// The pipelined batch timing model. Inference `i` is split into its
/// overlappable prefix `P_i` — the host preprocessing before the first
/// accelerator instruction (`H_i`) plus the first input-tile DMA
/// (`S_i`, double-buffered input staging: the next inference's input can
/// stream into the spare tile slot while the current one executes) — and
/// the remainder `A_i`. The first inference pays `P_0 + A_0` in full;
/// afterwards inference `i`'s prefix runs during `A_{i-1}`, so only the
/// part of `P_i` exceeding `A_{i-1}` remains on the critical path:
/// `total += A_i + max(0, P_i - A_{i-1})`. Outputs are unaffected — this
/// reinterprets the measured per-inference reports.
pub(crate) fn pipelined_cycles(reports: &[RunReport]) -> u64 {
    let mut total = 0u64;
    let mut prev_accel = 0u64;
    for (i, r) in reports.iter().enumerate() {
        let host = r.host_prefix_cycles.min(r.cycles);
        let staging = r.input_stage_cycles.min(r.cycles - host);
        let prefix = host + staging;
        let accel = r.cycles - prefix;
        if i == 0 {
            total += r.cycles;
        } else {
            total += accel + prefix.saturating_sub(prev_accel);
        }
        prev_accel = accel;
    }
    total
}

/// The compiler: construct once per accelerator description. Long-lived
/// compilers accumulate schedule-cache entries across `compile` calls, so
/// recompiling a model (or compiling another model with shared layer
/// shapes) skips the scheduling search.
pub struct Compiler {
    /// The accelerator this compiler targets (functional + architectural
    /// description).
    pub accel: AccelDesc,
    /// Compilation options shared by every `compile` call.
    pub options: CompileOptions,
    /// Content-addressed schedule memoization (see [`ScheduleCache`]).
    /// Shared (`Arc`) so a [`MultiCompiler`] can pool selections across
    /// its candidate targets — the cache key includes the accelerator
    /// fingerprint, so entries never cross machines by accident.
    cache: Arc<ScheduleCache>,
    /// Number of schedule sweeps actually executed (cache misses).
    sweeps_run: AtomicU64,
    /// Cache hits observed by *this* compiler's lookups (the shared
    /// cache's own counters aggregate every compiler attached to it).
    cache_hits: AtomicU64,
    /// Cache misses observed by this compiler's lookups.
    cache_misses: AtomicU64,
    /// Solver leaves costed across this compiler's sweeps (search effort).
    solver_leaves: AtomicU64,
    /// Dominated sweep configuration points skipped across this
    /// compiler's sweeps.
    configs_pruned: AtomicU64,
    /// Session trace attached for the duration of a traced compile
    /// ([`Compiler::compile_traced`]): schedule-cache consults,
    /// single-flight elections and sweep spans are recorded into it.
    /// `None` (the default) costs one uncontended mutex lock per
    /// schedule selection and records nothing.
    trace: Mutex<Option<Arc<Trace>>>,
}

/// Drop guard for single-flight search leadership: if the leader errors
/// — or panics — before publishing, leadership is released so blocked
/// followers can retry the search instead of hanging a long-lived
/// compile server on that key forever.
struct SearchLease<'a> {
    cache: &'a ScheduleCache,
    key: CacheKey,
    armed: bool,
}

impl Drop for SearchLease<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.cache.abandon(&self.key);
        }
    }
}

impl Compiler {
    /// A compiler for one accelerator with default [`CompileOptions`].
    pub fn new(accel: AccelDesc) -> Compiler {
        Compiler::with_options(accel, CompileOptions::default())
    }

    /// A compiler for one accelerator with explicit options.
    pub fn with_options(accel: AccelDesc, options: CompileOptions) -> Compiler {
        Compiler::with_shared_cache(accel, options, Arc::new(ScheduleCache::new()))
    }

    /// A compiler wired to an externally owned schedule cache: the
    /// building block of [`MultiCompiler`] (whose targets pool one cache)
    /// and of the compile service ([`crate::service::CompileServer`]),
    /// which hands every request a compiler over its long-lived,
    /// disk-hydrated cache. The key covers the accelerator fingerprint,
    /// so sharing one cache across machines is always safe.
    pub fn with_shared_cache(
        accel: AccelDesc,
        options: CompileOptions,
        cache: Arc<ScheduleCache>,
    ) -> Compiler {
        Compiler {
            accel,
            options,
            cache,
            sweeps_run: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            solver_leaves: AtomicU64::new(0),
            configs_pruned: AtomicU64::new(0),
            trace: Mutex::new(None),
        }
    }

    /// Attach a session trace (see [`CompilerSession::traced`]); every
    /// schedule selection records its cache/memo/sweep events into it
    /// until [`Compiler::detach_trace`].
    pub(crate) fn attach_trace(&self, trace: Arc<Trace>) {
        *self.trace.lock().unwrap_or_else(|e| e.into_inner()) = Some(trace);
    }

    /// Detach the session trace (recording stops).
    pub(crate) fn detach_trace(&self) {
        *self.trace.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// The currently attached trace, if a traced session is running.
    fn trace_handle(&self) -> Option<Arc<Trace>> {
        self.trace.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// A handle to this compiler's schedule cache (for persistence or for
    /// wiring further compilers to the same cache).
    pub fn schedule_cache(&self) -> Arc<ScheduleCache> {
        self.cache.clone()
    }

    /// The backend family this compiler's accelerator lowers through
    /// (resolved from the registry via [`AccelDesc::backend_impl`]).
    pub fn backend(&self) -> Result<&'static dyn Backend> {
        self.accel.backend_impl()
    }

    /// A cost-driven multi-accelerator compiler over a *set* of candidate
    /// descriptions (plus the implicit host fallback): each supported
    /// layer is placed on the candidate whose profiled schedule is
    /// cheapest. See [`MultiCompiler`]. Fails on an empty slice.
    pub fn with_targets(targets: &[AccelDesc]) -> Result<MultiCompiler> {
        MultiCompiler::new(targets.to_vec())
    }

    /// Compile a (QNN) graph into a deployment (thin façade over a
    /// [`CompilerSession`]).
    pub fn compile(&self, graph: &Graph) -> Result<Deployment> {
        Ok(CompilerSession::new(self).run(graph)?.deployment)
    }

    /// Compile and return the per-stage reports alongside the deployment.
    pub fn compile_with_report(&self, graph: &Graph) -> Result<SessionOutput> {
        CompilerSession::new(self).run(graph)
    }

    /// Compile with fine-grained tracing: the returned
    /// [`SessionOutput::trace`] carries, besides the per-stage spans every
    /// compile records, the schedule-cache consults, single-flight
    /// elections and solver-sweep spans of this run. Tracing is strictly
    /// passive — the deployment is byte-identical to
    /// [`Compiler::compile`]'s.
    pub fn compile_traced(&self, graph: &Graph) -> Result<SessionOutput> {
        CompilerSession::new(self).traced().run(graph)
    }

    /// Compile like [`Compiler::compile`], memoizing every schedule
    /// selection in `memo`. Recompiling after editing a model re-runs the
    /// search only for layers whose cache key (shape × arch × options ×
    /// residency constraint) is new — unchanged layers skip the sweep,
    /// the profiling, and even the shared-cache lookup.
    pub fn compile_incremental(&self, graph: &Graph, memo: &SessionMemo) -> Result<Deployment> {
        Ok(self.compile_incremental_with_report(graph, memo)?.deployment)
    }

    /// [`Compiler::compile_incremental`] with per-stage reports.
    pub fn compile_incremental_with_report(
        &self,
        graph: &Graph,
        memo: &SessionMemo,
    ) -> Result<SessionOutput> {
        CompilerSession::with_memo(self, memo).run(graph)
    }

    /// How many Fig. 2(b) sweeps this compiler has executed (schedule
    /// selections that were not cache hits or naive defaults).
    pub fn sweeps_run(&self) -> u64 {
        self.sweeps_run.load(Ordering::Relaxed)
    }

    /// Cache hits observed by this compiler's own lookups. Unlike
    /// [`Compiler::cache_stats`] — which reports the shared cache's
    /// lifetime counters across every compiler attached to it — this is
    /// attributable to exactly this compiler (the compile service uses it
    /// for per-request accounting).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Cache misses observed by this compiler's own lookups (see
    /// [`Compiler::cache_hits`]).
    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    /// Solver leaves costed across this compiler's sweeps — the search
    /// effort the pruned sweep actually spent (cache/memo hits add none).
    pub fn solver_leaves_visited(&self) -> u64 {
        self.solver_leaves.load(Ordering::Relaxed)
    }

    /// Dominated sweep configuration points that rode a shared group
    /// search for free instead of running their own (see
    /// [`crate::scheduler::solver::SearchStats`]).
    pub fn configs_pruned(&self) -> u64 {
        self.configs_pruned.load(Ordering::Relaxed)
    }

    /// Schedule-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Drop all cached schedule selections. Rarely needed: the cache key
    /// covers the accelerator fingerprint (architecture + functional
    /// description) and the search options, all recomputed per lookup, so
    /// mutating `accel` or `options` in place changes keys rather than
    /// serving stale entries. The one blind spot is re-registering a
    /// *different implementation* under an unchanged intrinsic name —
    /// call this if you do that.
    pub fn clear_schedule_cache(&self) {
        self.cache.clear();
    }

    /// The naive default schedule (UMA/BYOC without CoSA): the TE-default
    /// lowering offloads one output row-block at a time with the full
    /// reduction staged (no multi-level tiling, no loop-order
    /// optimization, no double buffering, even memory shares).
    pub fn naive_schedule(&self, g: Gemm) -> Schedule {
        let dim = self.accel.arch.pe_dim;
        let insn = [g.n.min(dim), g.c.min(dim), g.k.min(dim)];
        // Stage as much of the reduction as naturally fits the row-block
        // (capped, multiple of the instruction tile).
        let c_t = if g.c <= insn[1] {
            g.c
        } else {
            (g.c.min(2048) / insn[1]) * insn[1]
        };
        Schedule {
            workload: g,
            dataflow: self.accel.arch.dataflows[0],
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: insn,
            onchip_tile: [insn[0], c_t, insn[2]],
            dram_order: [Dim::N, Dim::K, Dim::C],
            est: Default::default(),
        }
    }

    /// Pick the schedule for one layer: cache lookup, then sweep +
    /// (optional) simulator profiling of the top candidates on a miss.
    /// `accel_fp` is [`crate::scheduler::cache::accel_fingerprint`] of
    /// `self.accel`, computed once per session rather than per layer.
    pub(crate) fn select_schedule(
        &self,
        g: Gemm,
        accel_fp: u64,
        memo: Option<&SessionMemo>,
    ) -> Result<(Schedule, Option<u64>, ScheduleSource)> {
        if !self.options.use_scheduler {
            return Ok((self.naive_schedule(g), None, ScheduleSource::Naive));
        }
        let key = CacheKey::unconstrained(
            accel_fp,
            g,
            SearchKey::new(&self.options.sweep, self.options.profile_candidates),
        );
        let trace = self.trace_handle();
        let shape = || format!("{}x{}x{}", g.n, g.c, g.k);
        // An incremental-session memo short-circuits everything — even
        // the shared cache — so it works with `schedule_cache: false` and
        // adds no hit/miss accounting noise.
        if let Some(memo) = memo {
            if let Some((schedule, cycles)) = memo.get(&key) {
                if let Some(tr) = &trace {
                    tr.instant("memo_hit", vec![("shape", shape())]);
                }
                return Ok((schedule, cycles, ScheduleSource::Memo));
            }
        }
        // Single-flight gate: on a hit (including one produced by another
        // thread's concurrent search on the same key) return immediately;
        // otherwise this thread is the leader and owes a publish — the
        // lease guard releases leadership on error *and* on unwind.
        let mut lease = if self.options.schedule_cache {
            match self.cache.begin(&key) {
                SearchGate::Ready(hit) => {
                    self.cache_hits.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = &trace {
                        tr.instant("cache_hit", vec![("shape", shape())]);
                    }
                    if let Some(memo) = memo {
                        memo.put(key, &hit.schedule, hit.profiled_cycles);
                    }
                    return Ok((hit.schedule, hit.profiled_cycles, ScheduleSource::Cache));
                }
                SearchGate::Leader => {
                    self.cache_misses.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = &trace {
                        tr.instant(
                            "cache_miss",
                            vec![("shape", shape()), ("single_flight", "leader".to_string())],
                        );
                    }
                    Some(SearchLease { cache: self.cache.as_ref(), key, armed: true })
                }
            }
        } else {
            None
        };

        let searched = (|| -> Result<(Schedule, Option<u64>)> {
            self.sweeps_run.fetch_add(1, Ordering::Relaxed);
            let sweep_started = Instant::now();
            let result = self.backend()?.sweep(&self.accel.arch, g, &self.options.sweep);
            self.solver_leaves.fetch_add(result.stats.leaves_visited, Ordering::Relaxed);
            self.configs_pruned.fetch_add(result.stats.configs_pruned, Ordering::Relaxed);
            if let Some(tr) = &trace {
                tr.record(
                    "sweep",
                    sweep_started,
                    vec![
                        ("shape", shape()),
                        ("leaves_visited", result.stats.leaves_visited.to_string()),
                        ("configs_pruned", result.stats.configs_pruned.to_string()),
                    ],
                );
            }
            ensure!(
                !result.candidates.is_empty(),
                "scheduler found no valid mapping for {g:?}"
            );
            if self.options.profile_candidates == 0 {
                Ok((result.candidates[0].clone(), None))
            } else {
                // Fig. 2(b): evaluate the refined candidates on the
                // (simulated) hardware and keep the measured best.
                let top = self.options.profile_candidates.min(result.candidates.len());
                let (s, c) = self.profile_top_candidates(&result.candidates[..top])?;
                Ok((s, Some(c)))
            }
        })();
        match searched {
            Ok((schedule, cycles)) => {
                if let Some(lease) = lease.as_mut() {
                    lease.cache.publish(
                        key,
                        CachedSelection {
                            schedule: schedule.clone(),
                            profiled_cycles: cycles,
                        },
                    );
                    lease.armed = false;
                }
                if let Some(memo) = memo {
                    memo.put(key, &schedule, cycles);
                }
                Ok((schedule, cycles, ScheduleSource::Search))
            }
            // The lease's drop releases leadership for a blocked follower.
            Err(e) => Err(e),
        }
    }

    /// Pick a schedule under a cross-layer residency constraint: the full
    /// sweep filtered to candidates satisfying `rc`, then profiled like
    /// [`Compiler::select_schedule`]. Selections are memoized under the
    /// extended cache key (shape + residency constraint), so recompiles of
    /// resident graphs stay warm.
    ///
    /// When no candidate satisfies the constraint, the *unconstrained*
    /// analytic winner is cached under the constrained key instead of
    /// nothing — a deterministic infeasibility marker that keeps repeat
    /// compiles sweep-free. The cross-layer planner re-checks
    /// `rc.admits(..)` on every returned schedule, so a non-admitting
    /// result simply leaves the edge non-resident. `Ok(None)` only when
    /// the scheduler is off or the sweep found no mapping at all.
    ///
    /// NOTE: the single-flight gate / lease / publish choreography here
    /// intentionally parallels [`Compiler::select_schedule`] (which also
    /// tracks [`ScheduleSource`] and bails rather than marking when the
    /// sweep is empty) — a fix to either path almost certainly applies to
    /// both.
    pub(crate) fn select_schedule_constrained(
        &self,
        g: Gemm,
        rc: ResidencyConstraint,
        accel_fp: u64,
        memo: Option<&SessionMemo>,
    ) -> Result<Option<(Schedule, Option<u64>)>> {
        if !self.options.use_scheduler {
            return Ok(None);
        }
        let key = CacheKey {
            arch: accel_fp,
            gemm: g,
            search: SearchKey::new(&self.options.sweep, self.options.profile_candidates),
            residency: rc,
        };
        let trace = self.trace_handle();
        let shape = || format!("{}x{}x{}", g.n, g.c, g.k);
        if let Some(memo) = memo {
            if let Some((schedule, cycles)) = memo.get(&key) {
                if let Some(tr) = &trace {
                    tr.instant(
                        "memo_hit",
                        vec![("shape", shape()), ("constrained", "true".to_string())],
                    );
                }
                return Ok(Some((schedule, cycles)));
            }
        }
        let mut lease = if self.options.schedule_cache {
            match self.cache.begin(&key) {
                SearchGate::Ready(hit) => {
                    self.cache_hits.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = &trace {
                        tr.instant(
                            "cache_hit",
                            vec![("shape", shape()), ("constrained", "true".to_string())],
                        );
                    }
                    if let Some(memo) = memo {
                        memo.put(key, &hit.schedule, hit.profiled_cycles);
                    }
                    return Ok(Some((hit.schedule, hit.profiled_cycles)));
                }
                SearchGate::Leader => {
                    self.cache_misses.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = &trace {
                        tr.instant(
                            "cache_miss",
                            vec![
                                ("shape", shape()),
                                ("constrained", "true".to_string()),
                                ("single_flight", "leader".to_string()),
                            ],
                        );
                    }
                    Some(SearchLease { cache: self.cache.as_ref(), key, armed: true })
                }
            }
        } else {
            None
        };

        self.sweeps_run.fetch_add(1, Ordering::Relaxed);
        let sweep_started = Instant::now();
        let result = self.backend()?.sweep(&self.accel.arch, g, &self.options.sweep);
        self.solver_leaves.fetch_add(result.stats.leaves_visited, Ordering::Relaxed);
        self.configs_pruned.fetch_add(result.stats.configs_pruned, Ordering::Relaxed);
        if let Some(tr) = &trace {
            tr.record(
                "sweep",
                sweep_started,
                vec![
                    ("shape", shape()),
                    ("constrained", "true".to_string()),
                    ("leaves_visited", result.stats.leaves_visited.to_string()),
                    ("configs_pruned", result.stats.configs_pruned.to_string()),
                ],
            );
        }
        if result.candidates.is_empty() {
            // No mapping at all (the lease's drop releases single-flight
            // leadership). Unreachable for layers that already scheduled.
            return Ok(None);
        }
        let candidates: Vec<Schedule> = result
            .candidates
            .iter()
            .filter(|s| rc.admits(s, &self.accel.arch))
            .cloned()
            .collect();
        let searched = if candidates.is_empty() {
            // Infeasibility marker: cache the unconstrained analytic
            // winner (which fails `rc.admits`, so the planner rejects it)
            // rather than re-sweeping this dead end on every compile.
            (result.candidates[0].clone(), None)
        } else if self.options.profile_candidates == 0 {
            (candidates[0].clone(), None)
        } else {
            let top = self.options.profile_candidates.min(candidates.len());
            let (s, c) = self.profile_top_candidates(&candidates[..top])?;
            (s, Some(c))
        };
        if let Some(lease) = lease.as_mut() {
            lease.cache.publish(
                key,
                CachedSelection {
                    schedule: searched.0.clone(),
                    profiled_cycles: searched.1,
                },
            );
            lease.armed = false;
        }
        if let Some(memo) = memo {
            memo.put(key, &searched.0, searched.1);
        }
        Ok(Some(searched))
    }

    /// Profile the candidates on scoped worker threads (contiguous chunks
    /// capped at the available parallelism, one simulator per worker —
    /// timing is data-independent and deterministic) and return the
    /// measured best. Ties break toward the lower index, exactly like the
    /// serial loop this replaced.
    fn profile_top_candidates(&self, candidates: &[Schedule]) -> Result<(Schedule, u64)> {
        assert!(!candidates.is_empty());
        let measured: Vec<Result<u64>> = if candidates.len() == 1 {
            let sim = Simulator::new(&self.accel.arch);
            vec![self.profile_layer(&sim, &candidates[0])]
        } else {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(candidates.len());
            let chunk_len = crate::util::ceil_div(candidates.len(), workers);
            let mut out = Vec::with_capacity(candidates.len());
            std::thread::scope(|scope| {
                let handles: Vec<_> = candidates
                    .chunks(chunk_len)
                    .map(|chunk| {
                        scope.spawn(move || {
                            let sim = Simulator::new(&self.accel.arch);
                            chunk
                                .iter()
                                .map(|s| self.profile_layer(&sim, s))
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                for h in handles {
                    out.extend(h.join().expect("profiling worker panicked"));
                }
            });
            out
        };
        let mut best: Option<(usize, u64)> = None;
        for (i, r) in measured.into_iter().enumerate() {
            let cycles = r?;
            if best.map(|(_, c)| cycles < c).unwrap_or(true) {
                best = Some((i, cycles));
            }
        }
        let (i, c) = best.expect("measured at least one candidate");
        Ok((candidates[i].clone(), c))
    }

    /// Measure one candidate schedule by compiling and simulating the
    /// layer in isolation (timing is data-independent).
    fn profile_layer(&self, sim: &Simulator, s: &Schedule) -> Result<u64> {
        let g = s.workload;
        let quant = crate::tir::QuantAttrs { scale: 0.05, act: crate::isa::Activation::None };
        let f = crate::tir::TirFunc::unscheduled("profile", g, quant);
        let backend = self.backend()?;
        let scheduled = backend.apply_schedule(&self.accel, &f, s)?;
        let mut prog = Program::new("profile");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64)?.offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64)?.offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64)?.offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64)?.offset,
        };
        backend.generate(&self.accel, &scheduled, s, &bufs, &mut prog)?;
        prog.push(Instr::Fence);
        let mut dram = prog.make_dram()?;
        Ok(sim.run(&prog, &mut dram)?.cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::eval::eval;
    use crate::relay::import::{from_quantized, to_qnn_graph};
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::relay::{Tensor, TensorData};
    use crate::util::prng::Rng;

    fn mlp_model(rng: &mut Rng, dims: &[usize], batch: usize) -> crate::relay::import::QModel {
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect();
        let scales: Vec<f32> = (0..=layers.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
        let q = quantize_mlp(&layers, &scales).unwrap();
        from_quantized(batch, scales[0], &q)
    }

    /// Compile + simulate must agree element-exactly with the graph
    /// interpreter (semantic ground truth).
    fn check_deployment(
        opts: CompileOptions,
        dims: &[usize],
        batch: usize,
        seed: u64,
    ) -> RunReport {
        let mut rng = Rng::new(seed);
        let model = mlp_model(&mut rng, dims, batch);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let compiler = Compiler::with_options(accel.clone(), opts);
        let dep = compiler.compile(&graph).unwrap();

        let input = rng.i8_vec(batch * dims[0]);
        let sim = Simulator::new(&accel.arch);
        let (got, rep) = dep.run(&sim, &input).unwrap();

        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![batch, dims[0]], TensorData::I8(input)).unwrap(),
        );
        let want = eval(&graph, &m).unwrap();
        assert_eq!(&TensorData::I8(got), &want[0].data);
        rep
    }

    #[test]
    fn proposed_flow_end_to_end_exact() {
        check_deployment(CompileOptions::default(), &[32, 48, 16], 4, 1);
    }

    #[test]
    fn naive_flow_end_to_end_exact_and_slower() {
        let proposed = check_deployment(CompileOptions::default(), &[64, 64, 64], 8, 2);
        let naive = check_deployment(
            CompileOptions {
                use_scheduler: false,
                fold_constants: false,
                profile_candidates: 0,
                ..Default::default()
            },
            &[64, 64, 64],
            8,
            2,
        );
        assert!(
            naive.cycles > proposed.cycles,
            "naive {} should exceed proposed {}",
            naive.cycles,
            proposed.cycles
        );
        // The naive flow does runtime host preprocessing; proposed does none.
        assert!(naive.host_cycles > 0);
        assert_eq!(proposed.host_cycles, 0);
    }

    #[test]
    fn profiling_selection_records_cycles() {
        let mut rng = Rng::new(3);
        let model = mlp_model(&mut rng, &[32, 32], 4);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel).compile(&graph).unwrap();
        assert_eq!(dep.chosen.len(), 1);
        assert!(dep.chosen[0].2.is_some());
    }

    #[test]
    fn toycar_like_stack_compiles_exact() {
        // Small-width stand-in exercising the 10-layer dense stack shape.
        check_deployment(
            CompileOptions { profile_candidates: 2, ..Default::default() },
            &[40, 16, 16, 8, 16, 16, 40],
            1,
            4,
        );
    }

    #[test]
    fn second_compile_of_same_graph_runs_zero_sweeps() {
        // The acceptance bar for the schedule cache: compiling a graph
        // twice through one Compiler performs zero sweeps the second time.
        let mut rng = Rng::new(5);
        let model = mlp_model(&mut rng, &[32, 48, 16], 4);
        let graph = to_qnn_graph(&model).unwrap();
        let compiler = Compiler::new(gemmini_desc().unwrap());

        let first = compiler.compile(&graph).unwrap();
        let sweeps_after_first = compiler.sweeps_run();
        assert!(
            sweeps_after_first >= 2,
            "at least one sweep per distinct layer shape (plus any \
             boundary-constrained re-searches)"
        );

        let second = compiler.compile(&graph).unwrap();
        assert_eq!(
            compiler.sweeps_run(),
            sweeps_after_first,
            "second compile must be served entirely from the cache"
        );
        assert_eq!(first.program.items, second.program.items);
        let stats = compiler.cache_stats();
        assert!(stats.entries >= 2);
        assert!(stats.hits >= 2, "both layers hit on the second compile");
    }

    #[test]
    fn repeated_shapes_within_one_model_share_sweeps() {
        // ToyCar-style trunk: 6 layers but only 5 distinct GEMM shapes —
        // the repeated (1,16,16) layer must not sweep twice.
        let mut rng = Rng::new(6);
        let model = mlp_model(&mut rng, &[40, 16, 16, 8, 16, 16, 40], 1);
        let graph = to_qnn_graph(&model).unwrap();
        let compiler = Compiler::new(gemmini_desc().unwrap());
        let out = compiler.compile_with_report(&graph).unwrap();
        assert_eq!(out.schedule_stats.layers, 6);
        assert!(compiler.sweeps_run() >= 5);
        assert_eq!(out.schedule_stats.cache_hits, 1);
        assert_eq!(out.schedule_stats.searched, 5);
    }

    #[test]
    fn cache_can_be_disabled() {
        let mut rng = Rng::new(7);
        let model = mlp_model(&mut rng, &[16, 16, 16], 2);
        let graph = to_qnn_graph(&model).unwrap();
        let opts = CompileOptions { schedule_cache: false, ..Default::default() };
        let compiler = Compiler::with_options(gemmini_desc().unwrap(), opts);
        compiler.compile(&graph).unwrap();
        let per_compile = compiler.sweeps_run();
        // Two layers with the same shape: each sweeps (no memoization).
        assert!(per_compile >= 2);
        compiler.compile(&graph).unwrap();
        // And the second compile re-runs every search.
        assert_eq!(compiler.sweeps_run(), 2 * per_compile);
        assert_eq!(compiler.cache_stats().entries, 0);
    }

    #[test]
    fn cached_compile_is_deterministic_with_fresh_compiler() {
        // A cache hit must reproduce exactly what a cold compiler produces.
        let mut rng = Rng::new(8);
        let model = mlp_model(&mut rng, &[24, 24, 24], 2);
        let graph = to_qnn_graph(&model).unwrap();
        let warm = Compiler::new(gemmini_desc().unwrap());
        warm.compile(&graph).unwrap();
        let warm_dep = warm.compile(&graph).unwrap(); // all cache hits
        let cold_dep = Compiler::new(gemmini_desc().unwrap()).compile(&graph).unwrap();
        assert_eq!(warm_dep.program.items, cold_dep.program.items);
        for (a, b) in warm_dep.chosen.iter().zip(&cold_dep.chosen) {
            assert_eq!(a.1, b.1);
            assert_eq!(a.2, b.2);
        }
    }

    #[test]
    fn run_batch_matches_individual_runs() {
        let mut rng = Rng::new(9);
        let model = mlp_model(&mut rng, &[32, 24, 8], 4);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel.clone()).compile(&graph).unwrap();
        let sim = Simulator::new(&accel.arch);

        let inputs: Vec<Vec<i8>> = (0..5).map(|_| rng.i8_vec(4 * 32)).collect();
        let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
        let batch = dep.run_batch(&sim, &refs).unwrap();
        assert_eq!(batch.outputs.len(), 5);

        let mut serial = 0;
        for (i, input) in inputs.iter().enumerate() {
            let (out, rep) = dep.run(&sim, input).unwrap();
            assert_eq!(batch.outputs[i], out, "inference {i} output diverged");
            assert_eq!(batch.reports[i].cycles, rep.cycles, "inference {i} timing diverged");
            assert_eq!(batch.reports[i].macs, rep.macs);
            serial += rep.cycles;
        }
        assert_eq!(batch.serial_cycles, serial);
        // The proposed flow has no host preprocessing. When the first
        // layer's winning schedule is double-buffered, its input-tile DMA
        // forms a staging prefix the pipelined model hides behind the
        // previous inference's execution; single-buffered first layers
        // have no spare slot, so nothing overlaps and the model
        // degenerates to serial.
        assert_eq!(batch.reports[0].host_prefix_cycles, 0);
        let first_db = dep.chosen[0].1.double_buffer;
        if first_db {
            assert!(
                batch.reports[0].input_stage_cycles > 0,
                "double-buffered first layer must report its input staging prefix"
            );
            assert!(batch.pipelined_cycles < batch.serial_cycles);
        } else {
            assert_eq!(batch.reports[0].input_stage_cycles, 0);
            assert_eq!(batch.pipelined_cycles, batch.serial_cycles);
        }
        assert!(batch.pipelined_cycles >= batch.reports[0].cycles);
        assert_eq!(batch.mean_cycles(), serial / 5);
    }

    #[test]
    fn pipelined_batch_overlaps_host_prefix() {
        use crate::isa::Activation;
        use crate::relay::{DType, GraphBuilder, Op, Tensor, TensorType};
        // host transpose (runtime preprocessing) -> accel dense: every
        // inference starts with a host prefix the pipeline can hide.
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![8, 8], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let w = b.constant(
            "w",
            Tensor::new(vec![8, 8], TensorData::I8(vec![1; 64])).unwrap(),
        );
        let bias =
            b.constant("b", Tensor::new(vec![8], TensorData::I32(vec![0; 8])).unwrap());
        let d = b
            .op(
                "dense",
                Op::AccelDense { scale: 1.0, act: Activation::None, weight_transposed: true },
                &[t, w, bias],
            )
            .unwrap();
        let g = b.outputs(&[d]);

        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel.clone()).compile(&g).unwrap();
        let sim = Simulator::new(&accel.arch);
        let mut rng = Rng::new(13);
        let inputs: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(64)).collect();
        let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
        let batch = dep.run_batch(&sim, &refs).unwrap();

        // Outputs stay element-exact vs individual runs.
        for (i, input) in inputs.iter().enumerate() {
            let (out, _) = dep.run(&sim, input).unwrap();
            assert_eq!(batch.outputs[i], out, "inference {i} output diverged");
        }
        // Each inference has a real host prefix and real accelerator work,
        // so the pipelined model must be strictly cheaper than serial —
        // and never cheaper than a single full inference.
        let r = &batch.reports[0];
        assert!(r.host_prefix_cycles > 0, "transpose must form a host prefix");
        assert!(r.cycles > r.host_prefix_cycles, "accel part must be non-empty");
        assert!(
            batch.pipelined_cycles < batch.serial_cycles,
            "pipelined {} should beat serial {}",
            batch.pipelined_cycles,
            batch.serial_cycles
        );
        assert!(batch.pipelined_cycles >= r.cycles);
    }

    #[test]
    fn run_batch_rejects_bad_input_length() {
        let mut rng = Rng::new(12);
        let model = mlp_model(&mut rng, &[16, 8], 2);
        let graph = to_qnn_graph(&model).unwrap();
        let accel = gemmini_desc().unwrap();
        let dep = Compiler::new(accel.clone()).compile(&graph).unwrap();
        let sim = Simulator::new(&accel.arch);
        let short = vec![0i8; 3];
        assert!(dep.run_batch(&sim, &[short.as_slice()]).is_err());
    }
}
