//! The staged compiler session: the paper's Fig. 1 configurator chain made
//! explicit.
//!
//! [`Compiler::compile`] used to be one opaque function; a
//! [`CompilerSession`] runs the same flow as six observable stages —
//!
//! ```text
//! frontend → partition → schedule → mapping → codegen → link
//! ```
//!
//! — each producing an inspectable artifact plus a [`StageReport`] with
//! wall-clock timing and diagnostics. The schedule stage consults the
//! compiler's content-addressed schedule cache and runs the Fig. 2(b)
//! sweep + simulator profiling only on misses. `Compiler::compile` is now
//! a thin façade over this module; callers that want the per-stage
//! breakdown use [`Compiler::compile_with_report`].
//!
//! See `ARCHITECTURE.md` (next to this file) for the stage graph and the
//! cache-keying rules.

use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::codegen::{generate, LayerBufs};
use crate::backend::mapping::apply_schedule;
use crate::backend::strategy::{generate_strategy_typed, Strategy};
use crate::frontend::{configure, run_frontend_passes};
use crate::isa::program::{HostOp, Program};
use crate::isa::Instr;
use crate::relay::partition::{partition, PartitionedGraph, Target};
use crate::relay::{Graph, Node, Op, TensorData};
use crate::scheduler::cache::accel_fingerprint;
use crate::scheduler::Schedule;
use crate::tir::TirFunc;

use super::{Compiler, Deployment, ScheduleSource};

/// Timing + diagnostics for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: &'static str,
    pub elapsed: Duration,
    /// Human-readable diagnostics (counts, cache statistics, sizes).
    pub notes: Vec<String>,
}

/// Counters from the schedule-selection stage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScheduleStats {
    /// Accelerator layers scheduled.
    pub layers: usize,
    /// Layers satisfied from the schedule cache (no sweep, no profiling).
    pub cache_hits: usize,
    /// Layers that ran the full sweep + profiling.
    pub searched: usize,
    /// Layers given the naive default schedule (`use_scheduler = false`).
    pub naive: usize,
}

/// Everything a session produces: the deployment plus the per-stage
/// reports and schedule-selection counters.
#[derive(Debug, Clone)]
pub struct SessionOutput {
    pub deployment: Deployment,
    pub stages: Vec<StageReport>,
    pub schedule_stats: ScheduleStats,
}

impl SessionOutput {
    /// Render the stage reports as an indented summary (for CLIs/examples).
    pub fn render_stages(&self) -> String {
        let mut out = String::new();
        for s in &self.stages {
            out.push_str(&format!("{:<10} {:>8} µs", s.name, s.elapsed.as_micros()));
            if let Some(first) = s.notes.first() {
                out.push_str(&format!("  {first}"));
            }
            out.push('\n');
            for note in s.notes.iter().skip(1) {
                out.push_str(&format!("{:22}{note}\n", ""));
            }
        }
        out
    }
}

/// Per-accelerator-layer plan produced by the schedule stage and consumed
/// by mapping/codegen.
struct LayerPlan {
    strategy: Strategy,
    schedule: Schedule,
    profiled_cycles: Option<u64>,
}

/// One compilation run through the staged pipeline. Construct with
/// [`CompilerSession::new`], consume with [`CompilerSession::run`].
pub struct CompilerSession<'a> {
    compiler: &'a Compiler,
    stages: Vec<StageReport>,
}

impl<'a> CompilerSession<'a> {
    pub fn new(compiler: &'a Compiler) -> CompilerSession<'a> {
        CompilerSession { compiler, stages: Vec::new() }
    }

    fn finish_stage(&mut self, name: &'static str, started: Instant, notes: Vec<String>) {
        self.stages.push(StageReport { name, elapsed: started.elapsed(), notes });
    }

    /// Run every stage over `graph`, producing the deployment and reports.
    pub fn run(mut self, graph: &Graph) -> Result<SessionOutput> {
        let c = self.compiler;

        // --- Stage 1: frontend (legalize + constant fold) ----------------
        let t0 = Instant::now();
        let mut fcfg = configure(&c.accel);
        fcfg.fold_constants = c.options.fold_constants;
        let processed = run_frontend_passes(graph, &fcfg)?;
        self.finish_stage(
            "frontend",
            t0,
            vec![format!(
                "{} nodes in, {} after legalize{}",
                graph.nodes.len(),
                processed.nodes.len(),
                if fcfg.fold_constants { "+fold" } else { " (folding off)" }
            )],
        );

        // --- Stage 2: partition ------------------------------------------
        let t0 = Instant::now();
        let pg: PartitionedGraph = partition(&processed, &fcfg.supported)?;
        ensure!(pg.graph.inputs.len() == 1, "exactly one graph input supported");
        ensure!(pg.graph.outputs.len() == 1, "exactly one graph output supported");
        self.finish_stage(
            "partition",
            t0,
            vec![format!(
                "{} accel / {} host nodes in {} offload region(s)",
                pg.accel_nodes(),
                pg.host_nodes(),
                pg.regions.len()
            )],
        );
        let g = &pg.graph;

        // --- Stage 3: per-layer schedule selection (cache + sweep) -------
        let t0 = Instant::now();
        let mut plans: Vec<Option<LayerPlan>> = Vec::new();
        plans.resize_with(g.nodes.len(), || None);
        let mut stats = ScheduleStats::default();
        let accel_fp = accel_fingerprint(&c.accel);
        for n in &g.nodes {
            if pg.targets[n.id] != Target::Accel {
                continue;
            }
            let shapes: Vec<Vec<usize>> =
                n.inputs.iter().map(|&i| g.node(i).ty.shape.clone()).collect();
            let strategy = generate_strategy_typed(&c.accel, n, &shapes)?;
            let (schedule, profiled_cycles, source) = c
                .select_schedule(strategy.gemm, accel_fp)
                .with_context(|| format!("schedule selection for layer '{}'", n.name))?;
            stats.layers += 1;
            match source {
                ScheduleSource::Cache => stats.cache_hits += 1,
                ScheduleSource::Search => stats.searched += 1,
                ScheduleSource::Naive => stats.naive += 1,
            }
            plans[n.id] = Some(LayerPlan { strategy, schedule, profiled_cycles });
        }
        let cache = c.cache_stats();
        self.finish_stage(
            "schedule",
            t0,
            vec![
                format!(
                    "{} layer(s): {} cache hit(s), {} searched, {} naive",
                    stats.layers, stats.cache_hits, stats.searched, stats.naive
                ),
                format!(
                    "cache: {} entries, {} hits / {} misses lifetime",
                    cache.entries, cache.hits, cache.misses
                ),
            ],
        );

        // --- Stage 4: mapping (apply TIR schedules) ----------------------
        let t0 = Instant::now();
        let mut lowered: Vec<Option<TirFunc>> = Vec::new();
        lowered.resize_with(g.nodes.len(), || None);
        let mut mapped = 0usize;
        for n in &g.nodes {
            if let Some(plan) = &plans[n.id] {
                let f = apply_schedule(&c.accel, &plan.strategy.tir, &plan.schedule)
                    .with_context(|| format!("mapping for layer '{}'", n.name))?;
                lowered[n.id] = Some(f);
                mapped += 1;
            }
        }
        self.finish_stage("mapping", t0, vec![format!("{mapped} TIR function(s) scheduled")]);

        // --- Stage 5: codegen (allocate + emit) --------------------------
        let t0 = Instant::now();
        let mut prog = Program::new("deployment");
        let region = allocate_regions(g, &mut prog)?;
        let mut chosen = Vec::new();
        for n in &g.nodes {
            match pg.targets[n.id] {
                Target::None => {}
                Target::Accel => {
                    let plan = plans[n.id].as_ref().expect("scheduled accel layer");
                    let scheduled = lowered[n.id].as_ref().expect("mapped accel layer");
                    let bufs = LayerBufs {
                        x: region[n.inputs[0]],
                        w: region[n.inputs[1]],
                        bias: region[n.inputs[2]],
                        out: region[n.id],
                    };
                    generate(&c.accel, scheduled, &plan.schedule, &bufs, &mut prog)
                        .with_context(|| format!("codegen for layer '{}'", n.name))?;
                    // Drain before anything consumes this layer's DRAM
                    // output (the timing model tracks on-chip hazards only).
                    prog.push(Instr::Fence);
                    chosen.push((n.name.clone(), plan.schedule.clone(), plan.profiled_cycles));
                }
                Target::Host => {
                    lower_host_node(g, n, &region, &mut prog)
                        .with_context(|| format!("host lowering for '{}'", n.name))?;
                }
            }
        }
        self.finish_stage(
            "codegen",
            t0,
            vec![format!(
                "{} program item(s), {} DRAM bytes",
                prog.items.len(),
                prog.layout.total_bytes()
            )],
        );

        // --- Stage 6: link (bind I/O, wrap the deployment) ---------------
        let t0 = Instant::now();
        let in_node = g.node(g.inputs[0]);
        let out_node = g.node(g.outputs[0]);
        let deployment = Deployment {
            input_offset: region[in_node.id],
            input_elems: in_node.ty.elems(),
            output_offset: region[out_node.id],
            output_elems: out_node.ty.elems(),
            program: prog,
            graph: pg.graph,
            chosen,
        };
        self.finish_stage(
            "link",
            t0,
            vec![format!(
                "input {} elem(s) @ {:#x}, output {} elem(s) @ {:#x}",
                deployment.input_elems,
                deployment.input_offset,
                deployment.output_elems,
                deployment.output_offset
            )],
        );

        Ok(SessionOutput { deployment, stages: self.stages, schedule_stats: stats })
    }
}

/// Allocate one DRAM region per node value and stage constant contents
/// into the program's init image.
fn allocate_regions(g: &Graph, prog: &mut Program) -> Result<Vec<u64>> {
    let mut region: Vec<u64> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let r = prog
            .layout
            .alloc(format!("n{}_{}", n.id, n.name), n.ty.bytes() as u64)?
            .offset;
        region.push(r);
        if let Op::Constant(t) = &n.op {
            let bytes = match &t.data {
                TensorData::I8(v) => v.iter().map(|&x| x as u8).collect(),
                TensorData::I32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                }
                TensorData::F32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                }
            };
            prog.add_init(r, bytes);
        }
    }
    Ok(region)
}

/// Lower one host-assigned node to host ops.
fn lower_host_node(g: &Graph, n: &Node, region: &[u64], prog: &mut Program) -> Result<()> {
    let src = |i: usize| region[n.inputs[i]];
    let dst = region[n.id];
    match &n.op {
        Op::Transpose => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::TransposeI8 { src: src(0), dst, rows: s[0], cols: s[1] });
        }
        Op::Im2col { kh, kw, stride, pad } => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::Im2col {
                src: src(0),
                dst,
                n: s[0],
                h: s[1],
                w: s[2],
                c: s[3],
                kh: *kh,
                kw: *kw,
                stride: *stride,
                pad: *pad,
            });
        }
        Op::Reshape { .. } => {
            prog.push_host(HostOp::Memcpy {
                src: src(0),
                dst,
                bytes: n.ty.bytes(),
            });
        }
        Op::Quantize { scale } => prog.push_host(HostOp::QuantizeF32 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Dequantize { scale } => prog.push_host(HostOp::DequantizeI8 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Requantize { scale } => prog.push_host(HostOp::RequantizeI32 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Clip { lo, hi } => {
            prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
            prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: *lo, hi: *hi });
        }
        Op::Relu => {
            prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
            prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: 0, hi: 127 });
        }
        Op::BiasAdd => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::BiasAddI32 {
                x: src(0),
                bias: src(1),
                dst,
                n: s[0],
                k: s[1],
            });
        }
        Op::QnnDense => {
            // Host fallback: transpose TFLite-layout weights into a
            // scratch region, then int8 GEMM.
            let x = &g.node(n.inputs[0]).ty.shape;
            let w = &g.node(n.inputs[1]).ty.shape;
            let scratch = prog
                .layout
                .alloc(format!("n{}_wT_scratch", n.id), (w[0] * w[1]) as u64)?
                .offset;
            prog.push_host(HostOp::TransposeI8 {
                src: src(1),
                dst: scratch,
                rows: w[0],
                cols: w[1],
            });
            prog.push_host(HostOp::MatmulI8 {
                a: src(0),
                b: scratch,
                c: dst,
                n: x[0],
                c_dim: x[1],
                k: w[0],
            });
        }
        other => bail!("no host lowering for operator '{}'", other.name()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::import::{from_quantized, to_qnn_graph};
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::util::prng::Rng;

    fn small_graph(dims: &[usize], batch: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect();
        let scales: Vec<f32> = (0..dims.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
        let q = quantize_mlp(&layers, &scales).unwrap();
        to_qnn_graph(&from_quantized(batch, scales[0], &q)).unwrap()
    }

    #[test]
    fn session_reports_every_stage_in_order() {
        let graph = small_graph(&[32, 16, 8], 2, 9);
        let compiler = Compiler::new(gemmini_desc().unwrap());
        let out = CompilerSession::new(&compiler).run(&graph).unwrap();
        let names: Vec<&str> = out.stages.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            ["frontend", "partition", "schedule", "mapping", "codegen", "link"]
        );
        for s in &out.stages {
            assert!(!s.notes.is_empty(), "stage {} has no diagnostics", s.name);
        }
        assert_eq!(out.schedule_stats.layers, 2);
        assert_eq!(out.schedule_stats.searched + out.schedule_stats.cache_hits, 2);
        assert!(!out.render_stages().is_empty());
    }

    #[test]
    fn session_deployment_identical_to_facade() {
        let graph = small_graph(&[24, 24, 24], 3, 10);
        let compiler = Compiler::new(gemmini_desc().unwrap());
        let via_session = CompilerSession::new(&compiler).run(&graph).unwrap().deployment;
        let via_facade = compiler.compile(&graph).unwrap();
        assert_eq!(via_session.program.items, via_facade.program.items);
        assert_eq!(via_session.input_offset, via_facade.input_offset);
        assert_eq!(via_session.output_offset, via_facade.output_offset);
        assert_eq!(via_session.chosen.len(), via_facade.chosen.len());
        for (a, b) in via_session.chosen.iter().zip(&via_facade.chosen) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1, b.1);
        }
    }
}
