//! The staged compiler session: the paper's Fig. 1 configurator chain made
//! explicit.
//!
//! [`Compiler::compile`] used to be one opaque function; a
//! [`CompilerSession`] runs the same flow as seven observable stages —
//!
//! ```text
//! frontend → partition → schedule → crosslayer → mapping → codegen → link
//! ```
//!
//! — each producing an inspectable artifact plus a [`StageReport`] with
//! wall-clock timing and diagnostics. The schedule stage consults the
//! compiler's content-addressed schedule cache and runs the Fig. 2(b)
//! sweep + simulator profiling only on misses; the crosslayer stage then
//! plans graph-level activation residency ([`crate::scheduler::graph`]),
//! keeping producer→consumer activations on-chip where feasible (its
//! boundary-constrained re-searches share the same cache, under keys
//! extended with the residency constraint). `Compiler::compile` is now
//! a thin façade over this module; callers that want the per-stage
//! breakdown use [`Compiler::compile_with_report`].
//!
//! The same staged core also serves the multi-accelerator path
//! ([`crate::pipeline::MultiCompiler`]): with several candidate targets
//! the partition stage becomes cost-driven — every supported layer is
//! probed against each candidate's (cached) schedule search and assigned
//! to the cheapest one — and codegen tracks contiguous per-target
//! instruction-stream segments. With exactly one target the session takes
//! the classic single-target path, byte-identical to the pre-multi
//! pipeline (the existing integration tests are the guard).
//!
//! Sessions never own a cache themselves: they run against whatever
//! [`crate::scheduler::cache::ScheduleCache`] their compilers were
//! constructed over ([`Compiler::with_shared_cache`]). The compile
//! service ([`crate::service::CompileServer`]) exploits exactly that —
//! it hydrates one cache from disk, pre-shards the schedule searches
//! across a worker pool, and then runs an ordinary session whose schedule
//! stage is all hits — while staying bit-compatible with a cold session.
//!
//! See `ARCHITECTURE.md` (next to this file) for the stage graph and the
//! cache-keying rules.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::accel::AccelDesc;
use crate::arch::ArchDesc;
use crate::backend::codegen::LayerBufs;
use crate::backend::strategy::Strategy;
use crate::backend::Backend;
use crate::frontend::{configure_all, run_frontend_passes};
use crate::isa::program::{HostOp, Program};
use crate::isa::Instr;
use crate::obs::span::{SpanId, Trace};
use crate::relay::partition::{partition, partition_multi, PartitionedGraph, Target};
use crate::relay::{Graph, Node, Op, TensorData};
use crate::scheduler::cache::accel_fingerprint;
use crate::scheduler::graph::{
    plan as plan_residency, switch_overlap_discount, switch_round_trip_cycles, LayerResidency,
    LayerSched,
};
use crate::scheduler::Schedule;
use crate::tir::TirFunc;

use super::multi::{
    LayerAssignment, LayerBoundary, MultiDeployment, MultiSessionOutput, ProgramSegment,
};
use super::{Compiler, Deployment, ScheduleSource, SessionMemo};

/// Timing + diagnostics for one pipeline stage.
///
/// Stage reports are a *view over trace spans*: the session opens one
/// span per stage on its [`Trace`], and each report's `name`/`elapsed`
/// are read back from the closed span (the notes double as span
/// attributes). The span is the single source of timing truth — the
/// Chrome-trace exporter and `tvm-accel bench` derive from the same
/// spans.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Stage name (`"frontend"`, `"partition"`, …).
    pub name: &'static str,
    /// Wall-clock time the stage took (span duration).
    pub elapsed: Duration,
    /// Human-readable diagnostics (counts, cache statistics, sizes; the
    /// multi-target partition stage lists the chosen target and its cost
    /// per layer here).
    pub notes: Vec<String>,
}

/// Render a list of stage reports as an indented summary (for
/// CLIs/examples).
pub(crate) fn render_stage_reports(stages: &[StageReport]) -> String {
    let mut out = String::new();
    for s in stages {
        out.push_str(&format!("{:<10} {:>8} µs", s.name, s.elapsed.as_micros()));
        if let Some(first) = s.notes.first() {
            out.push_str(&format!("  {first}"));
        }
        out.push('\n');
        for note in s.notes.iter().skip(1) {
            out.push_str(&format!("{:22}{note}\n", ""));
        }
    }
    out
}

/// Counters from the schedule-selection stage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScheduleStats {
    /// Accelerator layers scheduled.
    pub layers: usize,
    /// Layers satisfied from the schedule cache (no sweep, no profiling).
    pub cache_hits: usize,
    /// Layers satisfied from the incremental-session memo
    /// ([`SessionMemo`]) — unchanged since the previous compile of the
    /// same session, so not even the shared cache was consulted.
    pub memo_hits: usize,
    /// Layers that ran the full sweep + profiling.
    pub searched: usize,
    /// Layers given the naive default schedule (`use_scheduler = false`).
    pub naive: usize,
    /// Solver leaves costed by this session's sweeps (schedule stage plus
    /// any partition probes and constrained cross-layer re-searches).
    pub solver_leaves: u64,
    /// Dominated sweep configuration points that rode a shared group
    /// search instead of running their own DFS.
    pub configs_pruned: u64,
    /// Producer→consumer edges the cross-layer stage kept resident
    /// on-chip (each elides one DRAM store + reload pair).
    pub resident_edges: usize,
}

/// Everything a session produces: the deployment plus the per-stage
/// reports and schedule-selection counters.
#[derive(Debug, Clone)]
pub struct SessionOutput {
    /// The compiled single-target deployment.
    pub deployment: Deployment,
    /// Per-stage timing + diagnostics, in execution order.
    pub stages: Vec<StageReport>,
    /// Schedule-selection counters from the schedule stage.
    pub schedule_stats: ScheduleStats,
    /// The session's trace: one `compile` root span, a child span per
    /// stage, and (when compiled via [`Compiler::compile_traced`])
    /// schedule-cache/sweep events nested inside the `schedule` stage.
    pub trace: Arc<Trace>,
}

impl SessionOutput {
    /// Render the stage reports as an indented summary (for CLIs/examples).
    pub fn render_stages(&self) -> String {
        render_stage_reports(&self.stages)
    }
}

/// Per-accelerator-layer plan produced by the schedule stage and consumed
/// by mapping/codegen.
struct LayerPlan {
    strategy: Strategy,
    schedule: Schedule,
    profiled_cycles: Option<u64>,
    /// Index of the assigned accelerator (into the session's target list).
    target: usize,
}

/// One compilation run through the staged pipeline. Construct with
/// [`CompilerSession::new`] (one target) or via
/// [`crate::pipeline::MultiCompiler`] (several), consume with
/// [`CompilerSession::run`].
pub struct CompilerSession<'a> {
    compilers: Vec<&'a Compiler>,
    stages: Vec<StageReport>,
    /// Incremental-session memo: schedules selected by a previous run of
    /// the same session, keyed by the full [`CacheKey`]
    /// (shape × arch × options × residency constraint). `None` for
    /// ordinary one-shot compiles.
    ///
    /// [`CacheKey`]: crate::scheduler::cache::CacheKey
    memo: Option<&'a SessionMemo>,
    /// The span recorder stage timings are read from.
    trace: Arc<Trace>,
    /// When set, the trace is attached to every compiler for the run so
    /// schedule-cache/sweep events are recorded too. Stage spans are
    /// always recorded (they *are* the stage timings); this flag only
    /// governs the finer-grained events. Purely observational either way.
    traced: bool,
}

impl<'a> CompilerSession<'a> {
    /// A session compiling for a single accelerator.
    pub fn new(compiler: &'a Compiler) -> CompilerSession<'a> {
        CompilerSession {
            compilers: vec![compiler],
            stages: Vec::new(),
            memo: None,
            trace: Arc::new(Trace::new()),
            traced: false,
        }
    }

    /// A single-target session that reuses (and extends) an
    /// incremental-session memo: layers whose cache key already appears in
    /// `memo` skip the sweep, the profiling, and the shared-cache lookup.
    pub fn with_memo(compiler: &'a Compiler, memo: &'a SessionMemo) -> CompilerSession<'a> {
        CompilerSession { memo: Some(memo), ..CompilerSession::new(compiler) }
    }

    /// A session over several candidate targets (cost-driven partition).
    pub(crate) fn multi(compilers: Vec<&'a Compiler>) -> CompilerSession<'a> {
        assert!(!compilers.is_empty(), "session needs at least one target");
        CompilerSession {
            compilers,
            stages: Vec::new(),
            memo: None,
            trace: Arc::new(Trace::new()),
            traced: false,
        }
    }

    /// [`CompilerSession::multi`] with an incremental-session memo; the
    /// cost-driven partition probes reuse it too (cache keys embed the
    /// accelerator fingerprint, so one memo safely spans targets).
    pub(crate) fn multi_with_memo(
        compilers: Vec<&'a Compiler>,
        memo: &'a SessionMemo,
    ) -> CompilerSession<'a> {
        let mut s = CompilerSession::multi(compilers);
        s.memo = Some(memo);
        s
    }

    /// Enable fine-grained tracing: schedule-cache consults, single-flight
    /// elections, and sweep spans are recorded alongside the stage spans.
    pub fn traced(mut self) -> CompilerSession<'a> {
        self.traced = true;
        self
    }

    fn start_stage(&self, name: &'static str) -> SpanId {
        self.trace.begin(name)
    }

    /// Close a stage span and derive its [`StageReport`] from the span:
    /// the report is a view, the span is the record.
    fn finish_stage(&mut self, span: SpanId, notes: Vec<String>) {
        self.trace.end(span, notes.iter().map(|n| ("note", n.clone())).collect());
        let (name, elapsed) = self.trace.info_of(span).expect("stage span was opened");
        self.stages.push(StageReport { name, elapsed, notes });
    }

    /// Run every stage over `graph`, producing the deployment and reports.
    /// This is the single-target entry point; multi-target sessions go
    /// through [`crate::pipeline::MultiCompiler::compile_with_report`].
    pub fn run(self, graph: &Graph) -> Result<SessionOutput> {
        ensure!(
            self.compilers.len() == 1,
            "CompilerSession::run compiles for one target; use MultiCompiler for {}",
            self.compilers.len()
        );
        let (dep, stages, schedule_stats, trace) = self.run_core(graph)?;
        let MultiDeployment {
            program,
            graph,
            input_offset,
            input_elems,
            output_offset,
            output_elems,
            assignments,
            ..
        } = dep;
        let chosen = assignments.into_iter().map(|a| (a.layer, a.schedule, a.cycles)).collect();
        Ok(SessionOutput {
            deployment: Deployment {
                program,
                graph,
                input_offset,
                input_elems,
                output_offset,
                output_elems,
                chosen,
            },
            stages,
            schedule_stats,
            trace,
        })
    }

    /// Run every stage, keeping the segmented multi-target deployment.
    pub(crate) fn run_multi(self, graph: &Graph) -> Result<MultiSessionOutput> {
        let (deployment, stages, schedule_stats, trace) = self.run_core(graph)?;
        Ok(MultiSessionOutput { deployment, stages, schedule_stats, trace })
    }

    /// The staged core shared by the single- and multi-target paths. With
    /// one target, partition is the plain supported-op split and the
    /// emitted program is byte-identical to the pre-multi pipeline; with
    /// several, partition turns cost-driven and codegen records
    /// per-target instruction-stream segments.
    fn run_core(
        mut self,
        graph: &Graph,
    ) -> Result<(MultiDeployment, Vec<StageReport>, ScheduleStats, Arc<Trace>)> {
        let lead = self.compilers[0];
        let is_multi = self.compilers.len() > 1;
        // Fine-grained tracing: hand every compiler the session trace so
        // select_schedule records cache/memo/sweep events into it. The
        // guard detaches on every exit path (including `?` errors) —
        // compilers are long-lived and must not keep a stale trace.
        let _trace_attach = if self.traced {
            Some(TraceAttach::attach(&self.compilers, &self.trace))
        } else {
            None
        };
        let root = self.trace.begin("compile");
        // Resolve each target's backend family once: strategy binding,
        // mapping, codegen and residency support all dispatch through it.
        let backends: Vec<&'static dyn Backend> = self
            .compilers
            .iter()
            .map(|c| c.backend())
            .collect::<Result<Vec<_>>>()?;
        let search_effort = |compilers: &[&Compiler]| -> (u64, u64) {
            compilers.iter().fold((0, 0), |(l, p), c| {
                (l + c.solver_leaves_visited(), p + c.configs_pruned())
            })
        };
        let effort0 = search_effort(&self.compilers);

        // --- Stage 1: frontend (legalize + constant fold) ----------------
        let t0 = self.start_stage("frontend");
        let fcfg = {
            let accels: Vec<&AccelDesc> = self.compilers.iter().map(|c| &c.accel).collect();
            let mut fcfg = configure_all(&accels);
            fcfg.fold_constants = lead.options.fold_constants;
            fcfg
        };
        let processed = run_frontend_passes(graph, &fcfg)?;
        self.finish_stage(
            t0,
            vec![format!(
                "{} nodes in, {} after legalize{}",
                graph.nodes.len(),
                processed.nodes.len(),
                if fcfg.fold_constants { "+fold" } else { " (folding off)" }
            )],
        );

        // --- Stage 2: partition ------------------------------------------
        let t0 = self.start_stage("partition");
        let fps: Vec<u64> = self.compilers.iter().map(|c| accel_fingerprint(&c.accel)).collect();
        let mut infeasible: Vec<String> = Vec::new();
        // Use counts over the processed graph: an activation with several
        // consumers (or one that is a graph output) must materialize in
        // DRAM no matter where its consumer runs, so a target switch
        // cannot forgo any residency elision there and is not penalized.
        let mut act_uses = vec![0usize; processed.nodes.len()];
        for n in &processed.nodes {
            for &i in &n.inputs {
                act_uses[i] += 1;
            }
        }
        for &o in &processed.outputs {
            act_uses[o] += 1;
        }
        let pg: PartitionedGraph = if !is_multi {
            partition(&processed, &fcfg.supported)?
        } else {
            // Cost-driven placement: probe each supporting candidate's
            // (cached, parallel) schedule search and keep the cheapest. A
            // candidate that cannot actually bind or schedule the layer
            // (support is op-name-granular, feasibility is shape-level) is
            // skipped rather than failing the compile; the skips surface
            // in the stage notes.
            let supported: Vec<BTreeSet<String>> =
                self.compilers.iter().map(|c| c.accel.supported_ops()).collect();
            let compilers = &self.compilers;
            let memo = self.memo;
            partition_multi(
                &processed,
                &supported,
                |node, t| {
                    let shapes: Vec<Vec<usize>> = node
                        .inputs
                        .iter()
                        .map(|&i| processed.node(i).ty.shape.clone())
                        .collect();
                    let c = compilers[t];
                    let probe = backends[t]
                        .generate_strategy(&c.accel, node, &shapes)
                        .and_then(|strategy| c.select_schedule(strategy.gemm, fps[t], memo));
                    match probe {
                        // Profiled cycles when profiling ran; the analytic cost
                        // otherwise (0 for the naive default schedule, which
                        // then tie-breaks toward the first target).
                        Ok((schedule, profiled, _)) => {
                            Ok(Some(profiled.unwrap_or_else(|| schedule.est.cost() as u64)))
                        }
                        Err(e) => {
                            infeasible.push(format!(
                                "{} infeasible on {}: {:#}",
                                node.name, c.accel.name, e
                            ));
                            Ok(None)
                        }
                    }
                },
                // Switch penalty: placing a layer off its producer's target
                // forces the activation through DRAM (store by `from`, load
                // by `to`) — a round-trip same-target placement could elide
                // via cross-layer residency. Previously switching was free.
                // The penalty is the *foregone elision*, so it only applies
                // where residency could actually happen: pass enabled and a
                // single-use, non-output activation. The second tuple field
                // is the overlap discount — the consumer-side load half the
                // overlapped executor hides under the producer's tail — so
                // placements are costed against the overlapped makespan
                // rather than the serial handoff sum.
                |node, from, to| {
                    if !lead.options.cross_layer || !lead.options.use_scheduler {
                        return (0, 0);
                    }
                    // A same-target elision is only foregone if the
                    // producer's backend family can actually keep
                    // activations resident.
                    if !backends[from].supports_residency() {
                        return (0, 0);
                    }
                    let Some(&src) = node.inputs.first() else { return (0, 0) };
                    if act_uses[src] != 1 {
                        return (0, 0);
                    }
                    let elems = processed.node(src).ty.elems();
                    let penalty = switch_round_trip_cycles(
                        &compilers[from].accel.arch,
                        &compilers[to].accel.arch,
                        elems,
                    );
                    let discount =
                        switch_overlap_discount(&compilers[to].accel.arch, elems).min(penalty);
                    (penalty, discount)
                },
            )?
        };
        ensure!(pg.graph.inputs.len() == 1, "exactly one graph input supported");
        ensure!(pg.graph.outputs.len() == 1, "exactly one graph output supported");
        let mut notes = vec![format!(
            "{} accel / {} host nodes in {} offload region(s)",
            pg.accel_nodes(),
            pg.host_nodes(),
            pg.regions.len()
        )];
        if is_multi {
            for n in &pg.graph.nodes {
                if pg.targets[n.id] == Target::Accel {
                    let t = pg.accel_of[n.id].expect("accel node has a target");
                    let cost = match pg.costs[n.id] {
                        Some(c) => format!("{c} cycles"),
                        None => "unprofiled".to_string(),
                    };
                    notes.push(format!(
                        "{} -> {} ({cost})",
                        n.name, self.compilers[t].accel.name
                    ));
                }
            }
            for b in &pg.boundaries {
                notes.push(format!(
                    "{}: switch {} -> {} costs {} cycle round-trip, overlap hides {} ({})",
                    pg.graph.node(b.node).name,
                    self.compilers[b.from].accel.name,
                    self.compilers[b.to].accel.name,
                    b.penalty,
                    b.discount,
                    if b.taken { "taken" } else { "avoided" }
                ));
            }
            notes.append(&mut infeasible);
        }
        self.finish_stage(t0, notes);
        let g = &pg.graph;

        // --- Stage 3: per-layer schedule selection (cache + sweep) -------
        let t0 = self.start_stage("schedule");
        let mut plans: Vec<Option<LayerPlan>> = Vec::new();
        plans.resize_with(g.nodes.len(), || None);
        let mut stats = ScheduleStats::default();
        for n in &g.nodes {
            if pg.targets[n.id] != Target::Accel {
                continue;
            }
            let target = pg.accel_of[n.id].expect("accel node has a target");
            let c = self.compilers[target];
            let shapes: Vec<Vec<usize>> =
                n.inputs.iter().map(|&i| g.node(i).ty.shape.clone()).collect();
            let strategy = backends[target].generate_strategy(&c.accel, n, &shapes)?;
            let (schedule, profiled_cycles, source) = c
                .select_schedule(strategy.gemm, fps[target], self.memo)
                .with_context(|| format!("schedule selection for layer '{}'", n.name))?;
            stats.layers += 1;
            match source {
                ScheduleSource::Cache => stats.cache_hits += 1,
                ScheduleSource::Memo => stats.memo_hits += 1,
                ScheduleSource::Search => stats.searched += 1,
                ScheduleSource::Naive => stats.naive += 1,
            }
            plans[n.id] = Some(LayerPlan { strategy, schedule, profiled_cycles, target });
        }
        let cache = lead.cache_stats();
        let effort_now = search_effort(&self.compilers);
        self.finish_stage(
            t0,
            vec![
                format!(
                    "{} layer(s): {} memo hit(s), {} cache hit(s), {} searched, {} naive",
                    stats.layers, stats.memo_hits, stats.cache_hits, stats.searched, stats.naive
                ),
                format!(
                    "cache: {} entries, {} hits / {} misses lifetime",
                    cache.entries, cache.hits, cache.misses
                ),
                format!(
                    "search effort: {} solver leaf(s) visited, {} config point(s) pruned",
                    effort_now.0 - effort0.0,
                    effort_now.1 - effort0.1
                ),
            ],
        );

        // --- Stage 4: cross-layer residency planning ---------------------
        // Decide per producer→consumer edge whether the activation stays
        // resident on-chip (eliding the DRAM round-trip), re-running
        // boundary-constrained searches where the per-layer winners' loop
        // orders are incompatible. Layer plans are updated in place;
        // codegen consumes the per-node residency decisions. With no
        // feasible edge every plan is untouched and the emitted program is
        // byte-identical to the per-layer pipeline.
        let t0 = self.start_stage("crosslayer");
        let mut node_resid: Vec<LayerResidency> =
            vec![LayerResidency::default(); g.nodes.len()];
        let mut notes: Vec<String> = Vec::new();
        let cross_layer = lead.options.cross_layer && lead.options.use_scheduler;
        if cross_layer {
            // Accelerator layers in emission order.
            let order: Vec<usize> = g
                .nodes
                .iter()
                .filter(|n| pg.targets[n.id] == Target::Accel)
                .map(|n| n.id)
                .collect();
            // An activation with more than one use (or that is a graph
            // output) must materialize in DRAM regardless.
            let mut uses = vec![0usize; g.nodes.len()];
            for n in &g.nodes {
                for &i in &n.inputs {
                    uses[i] += 1;
                }
            }
            for &o in &g.outputs {
                uses[o] += 1;
            }
            let mut edges: Vec<(usize, usize)> = Vec::new();
            for (li, w) in order.windows(2).enumerate() {
                let (p, c) = (w[0], w[1]);
                // Same target, and its backend family can actually keep
                // activations resident on-chip (a DRAM-streaming family
                // like the vector backend never forms an edge).
                let resident_capable = match (&plans[p], &plans[c]) {
                    (Some(pp), Some(cp)) => {
                        pp.target == cp.target && backends[pp.target].supports_residency()
                    }
                    _ => false,
                };
                if g.node(c).inputs.first() == Some(&p) && uses[p] == 1 && resident_capable {
                    edges.push((li, li + 1));
                }
            }
            let layer_scheds: Vec<LayerSched> = order
                .iter()
                .map(|&id| {
                    let pl = plans[id].as_ref().expect("accel layer has a plan");
                    LayerSched {
                        name: g.node(id).name.clone(),
                        gemm: pl.strategy.gemm,
                        schedule: pl.schedule.clone(),
                        profiled_cycles: pl.profiled_cycles,
                        target: pl.target,
                    }
                })
                .collect();
            let arches: Vec<&ArchDesc> =
                self.compilers.iter().map(|c| &c.accel.arch).collect();
            let compilers = &self.compilers;
            let memo = self.memo;
            let gs = plan_residency(&arches, layer_scheds, &edges, |t, gemm, rc| {
                compilers[t].select_schedule_constrained(gemm, rc, fps[t], memo)
            })?;
            stats.resident_edges = gs.resident.len();
            notes.push(format!(
                "{} edge(s) considered, {} resident (~{} DRAM round-trip cycle(s) \
                 elided), {} constrained search(es)",
                edges.len(),
                gs.resident.len(),
                gs.saved_cycles(),
                gs.searches
            ));
            notes.extend(gs.notes.iter().cloned());
            for (li, &id) in order.iter().enumerate() {
                let pl = plans[id].as_mut().expect("accel layer has a plan");
                pl.schedule = gs.layers[li].schedule.clone();
                pl.profiled_cycles = gs.layers[li].profiled_cycles;
                node_resid[id] = gs.residency[li];
            }
        } else {
            notes.push("cross-layer pass disabled".to_string());
        }
        self.finish_stage(t0, notes);
        let effort_final = search_effort(&self.compilers);
        stats.solver_leaves = effort_final.0 - effort0.0;
        stats.configs_pruned = effort_final.1 - effort0.1;

        // --- Stage 5: mapping (apply TIR schedules) ----------------------
        let t0 = self.start_stage("mapping");
        let mut lowered: Vec<Option<TirFunc>> = Vec::new();
        lowered.resize_with(g.nodes.len(), || None);
        let mut mapped = 0usize;
        for n in &g.nodes {
            if let Some(plan) = &plans[n.id] {
                let accel = &self.compilers[plan.target].accel;
                let f = backends[plan.target]
                    .apply_schedule(accel, &plan.strategy.tir, &plan.schedule)
                    .with_context(|| format!("mapping for layer '{}'", n.name))?;
                lowered[n.id] = Some(f);
                mapped += 1;
            }
        }
        self.finish_stage(t0, vec![format!("{mapped} TIR function(s) scheduled")]);

        // --- Stage 6: codegen (allocate + emit) --------------------------
        let t0 = self.start_stage("codegen");
        let mut prog = Program::new("deployment");
        let region = allocate_regions(g, &mut prog)?;
        let mut assignments: Vec<LayerAssignment> = Vec::new();
        // Segment boundaries: (first item index, target). A new boundary
        // opens whenever the emitting accelerator changes; host items fall
        // into the surrounding segment. `seg_bounds[i]` records the DRAM
        // region the boundary activation crosses *into* segment i (its
        // opening layer's first input) — the overlapped executor watches
        // that region to find the consumer's first boundary read.
        let mut seg_starts: Vec<(usize, usize)> = Vec::new();
        let mut seg_bounds: Vec<Option<(u64, u64)>> = Vec::new();
        for n in &g.nodes {
            match pg.targets[n.id] {
                Target::None => {}
                Target::Accel => {
                    let plan = plans[n.id].as_ref().expect("scheduled accel layer");
                    let accel = &self.compilers[plan.target].accel;
                    if seg_starts.last().map(|&(_, t)| t) != Some(plan.target) {
                        let bound = if seg_starts.is_empty() {
                            None
                        } else {
                            Some((
                                region[n.inputs[0]],
                                g.node(n.inputs[0]).ty.bytes() as u64,
                            ))
                        };
                        seg_starts.push((prog.items.len(), plan.target));
                        seg_bounds.push(bound);
                    }
                    let scheduled = lowered[n.id].as_ref().expect("mapped accel layer");
                    let bufs = LayerBufs {
                        x: region[n.inputs[0]],
                        w: region[n.inputs[1]],
                        bias: region[n.inputs[2]],
                        out: region[n.id],
                    };
                    backends[plan.target]
                        .generate_resident(
                            accel,
                            scheduled,
                            &plan.schedule,
                            &bufs,
                            &node_resid[n.id],
                            &mut prog,
                        )
                        .with_context(|| format!("codegen for layer '{}'", n.name))?;
                    // Drain before anything consumes this layer's DRAM
                    // output (the timing model tracks on-chip hazards only).
                    prog.push(Instr::Fence);
                    assignments.push(LayerAssignment {
                        layer: n.name.clone(),
                        target: plan.target,
                        target_name: accel.name.clone(),
                        schedule: plan.schedule.clone(),
                        cycles: plan.profiled_cycles,
                    });
                }
                Target::Host => {
                    lower_host_node(g, n, &region, &mut prog)
                        .with_context(|| format!("host lowering for '{}'", n.name))?;
                }
            }
        }
        // Materialize segments so they cover every item (leading host items
        // join the first segment; an all-host program is one segment on
        // target 0).
        let mut segments: Vec<ProgramSegment> = Vec::new();
        for (i, &(start, target)) in seg_starts.iter().enumerate() {
            let end = seg_starts.get(i + 1).map(|&(s, _)| s).unwrap_or(prog.items.len());
            segments.push(ProgramSegment { target, start, end });
        }
        if segments.is_empty() {
            segments.push(ProgramSegment { target: 0, start: 0, end: prog.items.len() });
            seg_bounds.push(None);
        } else {
            segments[0].start = 0;
        }
        let mut notes = vec![format!(
            "{} program item(s), {} DRAM bytes",
            prog.items.len(),
            prog.layout.total_bytes()
        )];
        if is_multi {
            notes.push(format!(
                "{} instruction-stream segment(s) across {} target(s)",
                segments.len(),
                self.compilers.len()
            ));
        }
        self.finish_stage(t0, notes);

        // --- Stage 7: link (bind I/O, wrap the deployment) ---------------
        let t0 = self.start_stage("link");
        let in_node = g.node(g.inputs[0]);
        let out_node = g.node(g.outputs[0]);
        let boundaries: Vec<LayerBoundary> = pg
            .boundaries
            .iter()
            .map(|b| LayerBoundary {
                layer: pg.graph.node(b.node).name.clone(),
                from: self.compilers[b.from].accel.name.clone(),
                to: self.compilers[b.to].accel.name.clone(),
                penalty: b.penalty,
                overlap_discount: b.discount,
                taken: b.taken,
            })
            .collect();
        let deployment = MultiDeployment {
            targets: self.compilers.iter().map(|c| c.accel.clone()).collect(),
            input_offset: region[in_node.id],
            input_elems: in_node.ty.elems(),
            output_offset: region[out_node.id],
            output_elems: out_node.ty.elems(),
            program: prog,
            segments,
            boundary_regions: seg_bounds,
            graph: pg.graph,
            assignments,
            boundaries,
        };
        self.finish_stage(
            t0,
            vec![format!(
                "input {} elem(s) @ {:#x}, output {} elem(s) @ {:#x}",
                deployment.input_elems,
                deployment.input_offset,
                deployment.output_elems,
                deployment.output_offset
            )],
        );

        self.trace.end(root, vec![("stages", self.stages.len().to_string())]);
        Ok((deployment, self.stages, stats, self.trace))
    }
}

/// Drop guard from [`CompilerSession::run_core`]: attaches the session
/// trace to every compiler on construction and detaches it on drop, so
/// long-lived compilers never keep recording into a finished session's
/// trace — even when a stage errors out mid-run.
struct TraceAttach<'a> {
    compilers: Vec<&'a Compiler>,
}

impl<'a> TraceAttach<'a> {
    fn attach(compilers: &[&'a Compiler], trace: &Arc<Trace>) -> TraceAttach<'a> {
        for c in compilers {
            c.attach_trace(Arc::clone(trace));
        }
        TraceAttach { compilers: compilers.to_vec() }
    }
}

impl Drop for TraceAttach<'_> {
    fn drop(&mut self) {
        for c in &self.compilers {
            c.detach_trace();
        }
    }
}

/// Allocate one DRAM region per node value and stage constant contents
/// into the program's init image.
fn allocate_regions(g: &Graph, prog: &mut Program) -> Result<Vec<u64>> {
    let mut region: Vec<u64> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let r = prog
            .layout
            .alloc(format!("n{}_{}", n.id, n.name), n.ty.bytes() as u64)?
            .offset;
        region.push(r);
        if let Op::Constant(t) = &n.op {
            let bytes = match &t.data {
                TensorData::I8(v) => v.iter().map(|&x| x as u8).collect(),
                TensorData::I32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                }
                TensorData::F32(v) => {
                    v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                }
            };
            prog.add_init(r, bytes);
        }
    }
    Ok(region)
}

/// Lower one host-assigned node to host ops.
fn lower_host_node(g: &Graph, n: &Node, region: &[u64], prog: &mut Program) -> Result<()> {
    let src = |i: usize| region[n.inputs[i]];
    let dst = region[n.id];
    match &n.op {
        Op::Transpose => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::TransposeI8 { src: src(0), dst, rows: s[0], cols: s[1] });
        }
        Op::Im2col { kh, kw, stride, pad } => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::Im2col {
                src: src(0),
                dst,
                n: s[0],
                h: s[1],
                w: s[2],
                c: s[3],
                kh: *kh,
                kw: *kw,
                stride: *stride,
                pad: *pad,
            });
        }
        Op::Reshape { .. } => {
            prog.push_host(HostOp::Memcpy {
                src: src(0),
                dst,
                bytes: n.ty.bytes(),
            });
        }
        Op::Quantize { scale } => prog.push_host(HostOp::QuantizeF32 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Dequantize { scale } => prog.push_host(HostOp::DequantizeI8 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Requantize { scale } => prog.push_host(HostOp::RequantizeI32 {
            src: src(0),
            dst,
            n: n.ty.elems(),
            scale: *scale,
        }),
        Op::Clip { lo, hi } => {
            prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
            prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: *lo, hi: *hi });
        }
        Op::Relu => {
            prog.push_host(HostOp::Memcpy { src: src(0), dst, bytes: n.ty.bytes() });
            prog.push_host(HostOp::ClipI8 { buf: dst, n: n.ty.elems(), lo: 0, hi: 127 });
        }
        Op::BiasAdd => {
            let s = &g.node(n.inputs[0]).ty.shape;
            prog.push_host(HostOp::BiasAddI32 {
                x: src(0),
                bias: src(1),
                dst,
                n: s[0],
                k: s[1],
            });
        }
        Op::QnnDense => {
            // Host fallback: transpose TFLite-layout weights into a
            // scratch region, then int8 GEMM.
            let x = &g.node(n.inputs[0]).ty.shape;
            let w = &g.node(n.inputs[1]).ty.shape;
            let scratch = prog
                .layout
                .alloc(format!("n{}_wT_scratch", n.id), (w[0] * w[1]) as u64)?
                .offset;
            prog.push_host(HostOp::TransposeI8 {
                src: src(1),
                dst: scratch,
                rows: w[0],
                cols: w[1],
            });
            prog.push_host(HostOp::MatmulI8 {
                a: src(0),
                b: scratch,
                c: dst,
                n: x[0],
                c_dim: x[1],
                k: w[0],
            });
        }
        other => bail!("no host lowering for operator '{}'", other.name()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::import::{from_quantized, to_qnn_graph};
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::util::prng::Rng;

    fn small_graph(dims: &[usize], batch: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.3).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect();
        let scales: Vec<f32> = (0..dims.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
        let q = quantize_mlp(&layers, &scales).unwrap();
        to_qnn_graph(&from_quantized(batch, scales[0], &q)).unwrap()
    }

    #[test]
    fn session_reports_every_stage_in_order() {
        let graph = small_graph(&[32, 16, 8], 2, 9);
        let compiler = Compiler::new(gemmini_desc().unwrap());
        let out = CompilerSession::new(&compiler).run(&graph).unwrap();
        let names: Vec<&str> = out.stages.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            ["frontend", "partition", "schedule", "crosslayer", "mapping", "codegen", "link"]
        );
        for s in &out.stages {
            assert!(!s.notes.is_empty(), "stage {} has no diagnostics", s.name);
        }
        assert_eq!(out.schedule_stats.layers, 2);
        assert_eq!(out.schedule_stats.searched + out.schedule_stats.cache_hits, 2);
        assert!(!out.render_stages().is_empty());
    }

    #[test]
    fn session_deployment_identical_to_facade() {
        let graph = small_graph(&[24, 24, 24], 3, 10);
        let compiler = Compiler::new(gemmini_desc().unwrap());
        let via_session = CompilerSession::new(&compiler).run(&graph).unwrap().deployment;
        let via_facade = compiler.compile(&graph).unwrap();
        assert_eq!(via_session.program.items, via_facade.program.items);
        assert_eq!(via_session.input_offset, via_facade.input_offset);
        assert_eq!(via_session.output_offset, via_facade.output_offset);
        assert_eq!(via_session.chosen.len(), via_facade.chosen.len());
        for (a, b) in via_session.chosen.iter().zip(&via_facade.chosen) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1, b.1);
        }
    }
}
