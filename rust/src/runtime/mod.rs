//! XLA/PJRT runtime bridge: load AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py` from the JAX + Pallas model) and execute them on
//! the PJRT CPU client.
//!
//! In this system the XLA executables serve as the **golden functional
//! reference**: the JAX model (whose GEMM hot-spot is the Pallas kernel)
//! is lowered once at build time to HLO *text* (the interchange format the
//! pinned xla_extension 0.5.1 accepts — see /opt/xla-example/README.md),
//! and the Rust side checks every compiled accelerator program's output
//! against it, closing the loop compiler → simulator ↔ JAX/Pallas.
//!
//! Python never runs at deployment time: artifacts are built by
//! `make artifacts` and this module only loads files.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

/// A loaded-and-compiled HLO artifact.
pub struct GoldenModel {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

/// The PJRT CPU client (create once, load many artifacts).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for the CPU.
    pub fn load_hlo_text(&self, path: &Path) -> Result<GoldenModel> {
        ensure!(path.exists(), "artifact {} not found — run `make artifacts`", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(GoldenModel { exe, path: path.to_path_buf() })
    }
}

/// Build an int8 literal of the given shape.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "shape {:?} != data len {}", dims, data.len());
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, dims);
    lit.copy_raw_from(data).context("filling i8 literal")?;
    Ok(lit)
}

/// Build an int32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "shape {:?} != data len {}", dims, data.len());
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S32, dims);
    lit.copy_raw_from(data).context("filling i32 literal")?;
    Ok(lit)
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "shape {:?} != data len {}", dims, data.len());
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, dims);
    lit.copy_raw_from(data).context("filling f32 literal")?;
    Ok(lit)
}

impl GoldenModel {
    /// Execute with the given inputs; the artifact returns a 1-tuple (the
    /// aot exporter lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("executing golden model")?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    /// Execute on int8 inputs, returning the int8 output tensor.
    pub fn run_i8(&self, inputs: &[(&[i8], &[usize])]) -> Result<Vec<i8>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(d, s)| literal_i8(d, s))
            .collect::<Result<_>>()?;
        let out = self.run(&lits)?;
        Ok(out.to_vec::<i8>()?)
    }
}

/// Build the golden model's input literals for a quantized MLP: the int8
/// activation followed by each layer's `(weight [C,K] i8, bias [K] i32)`
/// — the parameter order `aot.py` exports.
pub fn golden_inputs(
    model: &crate::relay::import::QModel,
    x: &[i8],
) -> Result<Vec<xla::Literal>> {
    ensure!(
        x.len() == model.batch * model.layers[0].in_dim,
        "input length mismatch"
    );
    let mut lits = vec![literal_i8(x, &[model.batch, model.layers[0].in_dim])?];
    for l in &model.layers {
        // .qmodel stores TFLite layout [K,C]; the exported HLO takes [C,K].
        let mut wt = vec![0i8; l.in_dim * l.out_dim];
        for k in 0..l.out_dim {
            for c in 0..l.in_dim {
                wt[c * l.out_dim + k] = l.weight[k * l.in_dim + c];
            }
        }
        lits.push(literal_i8(&wt, &[l.in_dim, l.out_dim])?);
        lits.push(literal_i32(&l.bias, &[l.out_dim])?);
    }
    Ok(lits)
}

/// Default artifact directory (`artifacts/` at the repo root — one level
/// above the cargo package — matching `python/compile/aot.py`'s default
/// output; overridable via `TVM_ACCEL_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TVM_ACCEL_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests need the PJRT CPU client (always available) but not the
    // Python-built artifacts; artifact round-trips are covered by the
    // integration tests in rust/tests/ which skip gracefully when
    // artifacts are absent.

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn i8_literal_roundtrip() {
        let data: Vec<i8> = (-4..4).collect();
        let lit = literal_i8(&data, &[2, 4]).unwrap();
        assert_eq!(lit.to_vec::<i8>().unwrap(), data);
        assert_eq!(lit.element_count(), 8);
    }

    #[test]
    fn f32_literal_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25];
        let lit = literal_f32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_i8(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::cpu().unwrap();
        match rt.load_hlo_text(Path::new("/nonexistent/model.hlo.txt")) {
            Ok(_) => panic!("load of missing artifact must fail"),
            Err(e) => assert!(e.to_string().contains("make artifacts")),
        }
    }
}
