//! Cycle-level timing model: decoupled load / execute / store queues with
//! an address-range scoreboard, mirroring Gemmini's ROB + three controller
//! queues.
//!
//! Instructions are issued in program order (the host front-end can issue
//! at most one command per `issue_gap` cycles and stalls when the target
//! queue is full), then execute in order *within* their queue while the
//! three queues proceed concurrently. Cross-queue hazards are resolved by a
//! per-row scoreboard over the scratchpad and accumulator (RAW / WAR / WAW
//! on row ranges), exactly the granularity Gemmini's ROB tracks.

use crate::isa::Space;

/// Which controller queue an instruction dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueId {
    Load,
    Ex,
    Store,
}

/// One on-chip access for hazard tracking.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    pub space: Space,
    pub row: u32,
    pub rows: u32,
    pub write: bool,
}

impl Access {
    pub fn read(space: Space, row: u32, rows: u32) -> Access {
        Access { space, row, rows, write: false }
    }

    pub fn write(space: Space, row: u32, rows: u32) -> Access {
        Access { space, row, rows, write: true }
    }
}

/// Per-row last-reader / last-writer completion times for one memory.
#[derive(Debug)]
struct RowTracker {
    last_write: Vec<u64>,
    last_read: Vec<u64>,
}

impl RowTracker {
    fn new(rows: usize) -> RowTracker {
        RowTracker { last_write: vec![0; rows], last_read: vec![0; rows] }
    }

    fn range(&self, a: &Access) -> std::ops::Range<usize> {
        let lo = (a.row as usize).min(self.last_write.len());
        let hi = ((a.row + a.rows) as usize).min(self.last_write.len());
        lo..hi
    }

    /// Earliest time `a` may start given recorded hazards.
    fn ready(&self, a: &Access) -> u64 {
        let mut t = 0;
        for i in self.range(a) {
            // RAW: any access waits for the last writer.
            t = t.max(self.last_write[i]);
            if a.write {
                // WAR: writers also wait for the last reader.
                t = t.max(self.last_read[i]);
            }
        }
        t
    }

    fn record(&mut self, a: &Access, finish: u64) {
        for i in self.range(a) {
            if a.write {
                self.last_write[i] = self.last_write[i].max(finish);
            } else {
                self.last_read[i] = self.last_read[i].max(finish);
            }
        }
    }
}

/// One in-order controller queue with bounded occupancy.
#[derive(Debug)]
struct Queue {
    depth: usize,
    /// Completion times of in-flight entries, oldest first.
    inflight: std::collections::VecDeque<u64>,
    last_finish: u64,
}

impl Queue {
    fn new(depth: usize) -> Queue {
        Queue { depth, inflight: std::collections::VecDeque::new(), last_finish: 0 }
    }

    /// Earliest time a new entry can be accepted (oldest entry must have
    /// retired if the queue is full by then).
    fn slot_ready(&self) -> u64 {
        if self.inflight.len() < self.depth {
            0
        } else {
            self.inflight[self.inflight.len() - self.depth]
        }
    }

    fn push(&mut self, finish: u64) {
        self.inflight.push_back(finish);
        // Keep only what matters for future slot_ready queries.
        while self.inflight.len() > 2 * self.depth {
            self.inflight.pop_front();
        }
        self.last_finish = self.last_finish.max(finish);
    }
}

/// The whole timing engine.
#[derive(Debug)]
pub struct Timing {
    issue_cursor: u64,
    load: Queue,
    ex: Queue,
    store: Queue,
    spad: RowTracker,
    acc: RowTracker,
    /// Busy-until time of the single DMA engine shared by load and store.
    dma_busy: u64,
    pub host_cycles: u64,
}

/// Default queue depth (Gemmini's reservation station holds 16 entries
/// split across the three controllers).
pub const QUEUE_DEPTH: usize = 8;

impl Timing {
    pub fn new(spad_rows: usize, acc_rows: usize) -> Timing {
        Timing {
            issue_cursor: 0,
            load: Queue::new(QUEUE_DEPTH),
            ex: Queue::new(QUEUE_DEPTH),
            store: Queue::new(QUEUE_DEPTH),
            spad: RowTracker::new(spad_rows),
            acc: RowTracker::new(acc_rows),
            dma_busy: 0,
            host_cycles: 0,
        }
    }

    fn queue_mut(&mut self, q: QueueId) -> &mut Queue {
        match q {
            QueueId::Load => &mut self.load,
            QueueId::Ex => &mut self.ex,
            QueueId::Store => &mut self.store,
        }
    }

    fn tracker(&self, s: Space) -> &RowTracker {
        match s {
            Space::Spad => &self.spad,
            Space::Acc => &self.acc,
        }
    }

    fn tracker_mut(&mut self, s: Space) -> &mut RowTracker {
        match s {
            Space::Spad => &mut self.spad,
            Space::Acc => &mut self.acc,
        }
    }

    /// Account one instruction: issued after `issue_gap` cycles of
    /// front-end work, dispatched to `q`, running for `latency` cycles once
    /// its queue is free and all hazards in `accesses` are resolved.
    ///
    /// `dma_occupancy` models a pipelined DMA engine: the engine is held
    /// for only the data-movement portion of the transfer, while the fixed
    /// request latency (included in `latency`) overlaps with the next
    /// request — multiple outstanding requests, as in Gemmini's RTL DMA.
    /// Returns (start, finish).
    pub fn step(
        &mut self,
        q: QueueId,
        issue_gap: u64,
        latency: u64,
        dma_occupancy: Option<u64>,
        accesses: &[Access],
    ) -> (u64, u64) {
        self.issue_cursor += issue_gap;
        let issue_t = self.issue_cursor.max(self.queue_mut(q).slot_ready());

        let mut ready = issue_t.max(self.queue_mut(q).last_finish);
        for a in accesses {
            ready = ready.max(self.tracker(a.space).ready(a));
        }
        if dma_occupancy.is_some() {
            ready = ready.max(self.dma_busy);
        }
        let start = ready;
        let finish = start + latency;
        if let Some(occ) = dma_occupancy {
            self.dma_busy = start + occ.min(latency);
        }
        for a in accesses {
            self.tracker_mut(a.space).record(a, finish);
        }
        self.queue_mut(q).push(finish);
        // The front-end is blocked until the command was accepted.
        self.issue_cursor = self.issue_cursor.max(issue_t);
        (start, finish)
    }

    /// Time at which every queue has drained.
    pub fn drained(&self) -> u64 {
        self.load
            .last_finish
            .max(self.ex.last_finish)
            .max(self.store.last_finish)
            .max(self.issue_cursor)
    }

    /// A full fence: block issue until drained, plus `extra` cycles.
    pub fn fence(&mut self, extra: u64) -> u64 {
        let t = self.drained() + extra;
        self.issue_cursor = t;
        t
    }

    /// A host-CPU operation of `cost` cycles; the host cannot overlap with
    /// outstanding accelerator work it just fenced (conservative: host ops
    /// serialize, see DESIGN.md).
    pub fn host(&mut self, cost: u64) -> u64 {
        let t = self.drained() + cost;
        self.issue_cursor = t;
        self.host_cycles += cost;
        t
    }

    pub fn now(&self) -> u64 {
        self.drained()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn independent_queues_overlap() {
        let mut t = Timing::new(64, 64);
        // A load and a compute touching disjoint rows overlap fully.
        let (_, f1) = t.step(
            QueueId::Load,
            1,
            100,
            Some(100),
            &[Access::write(Space::Spad, 0, 4)],
        );
        let (s2, f2) = t.step(
            QueueId::Ex,
            1,
            50,
            None,
            &[Access::read(Space::Spad, 32, 4)],
        );
        assert_eq!(f1, 101);
        assert!(s2 <= 2, "compute should start immediately, started {s2}");
        assert!(f2 < f1);
    }

    #[test]
    fn raw_hazard_serializes() {
        let mut t = Timing::new(64, 64);
        let (_, f1) = t.step(QueueId::Load, 1, 100, Some(100), &[Access::write(Space::Spad, 0, 4)]);
        // Compute reading the loaded rows must wait for the load.
        let (s2, _) = t.step(QueueId::Ex, 1, 10, None, &[Access::read(Space::Spad, 2, 1)]);
        assert!(s2 >= f1, "RAW violated: start {s2} < load finish {f1}");
    }

    #[test]
    fn war_hazard_blocks_overwrite() {
        let mut t = Timing::new(64, 64);
        // Long-running compute reads rows 0..4.
        let (_, f1) = t.step(QueueId::Ex, 1, 200, None, &[Access::read(Space::Spad, 0, 4)]);
        // A load overwriting those rows must wait (WAR).
        let (s2, _) = t.step(QueueId::Load, 1, 10, Some(10), &[Access::write(Space::Spad, 0, 4)]);
        assert!(s2 >= f1);
    }

    #[test]
    fn queue_capacity_stalls_issue() {
        let mut t = Timing::new(1024, 64);
        // Fill the load queue with long operations on disjoint rows; DMA is
        // serial so they chain anyway; use no-DMA ex ops to test capacity.
        let mut finishes = Vec::new();
        for i in 0..(QUEUE_DEPTH as u32 + 2) {
            let (_, f) = t.step(
                QueueId::Ex,
                0,
                1000,
                None,
                &[Access::read(Space::Spad, i * 8, 1)],
            );
            finishes.push(f);
        }
        // In-order queue: op i starts after op i-1 finishes regardless; the
        // interesting assertion is monotone finishing.
        for w in finishes.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn dma_is_shared_between_load_and_store() {
        let mut t = Timing::new(64, 64);
        // 100-cycle transfer of which 80 is engine occupancy (20 request
        // latency pipelines with the next transfer).
        let (_, f1) =
            t.step(QueueId::Load, 1, 100, Some(80), &[Access::write(Space::Spad, 0, 1)]);
        let (s2, _) =
            t.step(QueueId::Store, 1, 100, Some(80), &[Access::read(Space::Acc, 0, 1)]);
        assert!(s2 >= f1 - 20, "DMA data movement must serialize");
        assert!(s2 < f1, "request latency must pipeline");
    }

    #[test]
    fn fence_drains_everything() {
        let mut t = Timing::new(64, 64);
        t.step(QueueId::Load, 1, 500, Some(500), &[Access::write(Space::Spad, 0, 1)]);
        let ft = t.fence(20);
        assert_eq!(ft, 501 + 20);
        // Subsequent work starts after the fence.
        let (s, _) = t.step(QueueId::Ex, 0, 1, None, &[]);
        assert!(s >= ft);
    }

    #[test]
    fn host_serializes_and_accumulates() {
        let mut t = Timing::new(64, 64);
        t.step(QueueId::Load, 1, 100, Some(100), &[Access::write(Space::Spad, 0, 1)]);
        let ht = t.host(40);
        assert_eq!(ht, 101 + 40);
        assert_eq!(t.host_cycles, 40);
    }
}
