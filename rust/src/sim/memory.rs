//! Simulator memories: flat byte-addressed DRAM plus the accelerator's
//! software-managed scratchpad (int8 rows) and accumulator (int32 rows).

use anyhow::{ensure, Result};

/// Byte-addressed main memory with typed little-endian accessors.
#[derive(Debug, Clone)]
pub struct Dram {
    bytes: Vec<u8>,
}

impl Dram {
    pub fn new(size: usize) -> Dram {
        Dram { bytes: vec![0; size] }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    fn check(&self, off: u64, n: usize) -> Result<usize> {
        let off = off as usize;
        ensure!(
            off + n <= self.bytes.len(),
            "DRAM access out of bounds: +{off:#x}..+{:#x} (size {:#x})",
            off + n,
            self.bytes.len()
        );
        Ok(off)
    }

    pub fn read_i8(&self, off: u64) -> Result<i8> {
        let o = self.check(off, 1)?;
        Ok(self.bytes[o] as i8)
    }

    pub fn write_i8(&mut self, off: u64, v: i8) -> Result<()> {
        let o = self.check(off, 1)?;
        self.bytes[o] = v as u8;
        Ok(())
    }

    pub fn read_i32(&self, off: u64) -> Result<i32> {
        let o = self.check(off, 4)?;
        Ok(i32::from_le_bytes(self.bytes[o..o + 4].try_into().unwrap()))
    }

    pub fn write_i32(&mut self, off: u64, v: i32) -> Result<()> {
        let o = self.check(off, 4)?;
        self.bytes[o..o + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn read_f32(&self, off: u64) -> Result<f32> {
        let o = self.check(off, 4)?;
        Ok(f32::from_le_bytes(self.bytes[o..o + 4].try_into().unwrap()))
    }

    pub fn write_f32(&mut self, off: u64, v: f32) -> Result<()> {
        let o = self.check(off, 4)?;
        self.bytes[o..o + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Bulk helpers for staging tensors in tests / the runtime bridge.
    pub fn write_i8_slice(&mut self, off: u64, data: &[i8]) -> Result<()> {
        let o = self.check(off, data.len())?;
        for (i, &v) in data.iter().enumerate() {
            self.bytes[o + i] = v as u8;
        }
        Ok(())
    }

    pub fn read_i8_slice(&self, off: u64, n: usize) -> Result<Vec<i8>> {
        let o = self.check(off, n)?;
        Ok(self.bytes[o..o + n].iter().map(|&b| b as i8).collect())
    }

    pub fn write_i32_slice(&mut self, off: u64, data: &[i32]) -> Result<()> {
        self.check(off, data.len() * 4)?;
        for (i, &v) in data.iter().enumerate() {
            self.write_i32(off + 4 * i as u64, v)?;
        }
        Ok(())
    }

    pub fn read_i32_slice(&self, off: u64, n: usize) -> Result<Vec<i32>> {
        self.check(off, n * 4)?;
        (0..n).map(|i| self.read_i32(off + 4 * i as u64)).collect()
    }

    pub fn write_f32_slice(&mut self, off: u64, data: &[f32]) -> Result<()> {
        self.check(off, data.len() * 4)?;
        for (i, &v) in data.iter().enumerate() {
            self.write_f32(off + 4 * i as u64, v)?;
        }
        Ok(())
    }

    pub fn read_f32_slice(&self, off: u64, n: usize) -> Result<Vec<f32>> {
        self.check(off, n * 4)?;
        (0..n).map(|i| self.read_f32(off + 4 * i as u64)).collect()
    }

    /// Copy `n` bytes within DRAM (regions may not overlap).
    pub fn copy_bytes(&mut self, src: u64, dst: u64, n: usize) -> Result<()> {
        let s = self.check(src, n)?;
        let d = self.check(dst, n)?;
        ensure!(
            s + n <= d || d + n <= s || s == d,
            "overlapping DRAM copy: src {s:#x} dst {d:#x} n {n}"
        );
        let tmp: Vec<u8> = self.bytes[s..s + n].to_vec();
        self.bytes[d..d + n].copy_from_slice(&tmp);
        Ok(())
    }
}

/// On-chip scratchpad: `rows` rows of `dim` int8 elements.
#[derive(Debug, Clone)]
pub struct Scratchpad {
    pub dim: usize,
    pub rows: usize,
    data: Vec<i8>,
}

impl Scratchpad {
    pub fn new(dim: usize, size_bytes: usize) -> Scratchpad {
        let rows = size_bytes / dim;
        Scratchpad { dim, rows, data: vec![0; rows * dim] }
    }

    pub fn row(&self, r: u32) -> Result<&[i8]> {
        let r = r as usize;
        ensure!(r < self.rows, "scratchpad row {r} out of range ({})", self.rows);
        Ok(&self.data[r * self.dim..(r + 1) * self.dim])
    }

    pub fn row_mut(&mut self, r: u32) -> Result<&mut [i8]> {
        let r = r as usize;
        ensure!(r < self.rows, "scratchpad row {r} out of range ({})", self.rows);
        Ok(&mut self.data[r * self.dim..(r + 1) * self.dim])
    }
}

/// On-chip accumulator: `rows` rows of `dim` int32 partial sums.
#[derive(Debug, Clone)]
pub struct Accumulator {
    pub dim: usize,
    pub rows: usize,
    data: Vec<i32>,
}

impl Accumulator {
    pub fn new(dim: usize, size_bytes: usize) -> Accumulator {
        let rows = size_bytes / (dim * 4);
        Accumulator { dim, rows, data: vec![0; rows * dim] }
    }

    pub fn row(&self, r: u32) -> Result<&[i32]> {
        let r = r as usize;
        ensure!(r < self.rows, "accumulator row {r} out of range ({})", self.rows);
        Ok(&self.data[r * self.dim..(r + 1) * self.dim])
    }

    pub fn row_mut(&mut self, r: u32) -> Result<&mut [i32]> {
        let r = r as usize;
        ensure!(r < self.rows, "accumulator row {r} out of range ({})", self.rows);
        Ok(&mut self.data[r * self.dim..(r + 1) * self.dim])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_typed_roundtrip() {
        let mut d = Dram::new(64);
        d.write_i8(0, -5).unwrap();
        assert_eq!(d.read_i8(0).unwrap(), -5);
        d.write_i32(4, -123456).unwrap();
        assert_eq!(d.read_i32(4).unwrap(), -123456);
        d.write_f32(8, 3.25).unwrap();
        assert_eq!(d.read_f32(8).unwrap(), 3.25);
    }

    #[test]
    fn dram_bounds_checked() {
        let mut d = Dram::new(8);
        assert!(d.read_i32(6).is_err());
        assert!(d.write_i8(8, 0).is_err());
        assert!(d.read_i8(7).is_ok());
    }

    #[test]
    fn dram_slices() {
        let mut d = Dram::new(32);
        d.write_i8_slice(0, &[1, -2, 3]).unwrap();
        assert_eq!(d.read_i8_slice(0, 3).unwrap(), vec![1, -2, 3]);
        d.write_i32_slice(4, &[7, -8]).unwrap();
        assert_eq!(d.read_i32_slice(4, 2).unwrap(), vec![7, -8]);
    }

    #[test]
    fn dram_copy_rejects_overlap() {
        let mut d = Dram::new(32);
        assert!(d.copy_bytes(0, 4, 8).is_err());
        assert!(d.copy_bytes(0, 16, 8).is_ok());
    }

    #[test]
    fn scratchpad_rows() {
        let mut sp = Scratchpad::new(16, 256);
        assert_eq!(sp.rows, 16);
        sp.row_mut(3).unwrap()[5] = -9;
        assert_eq!(sp.row(3).unwrap()[5], -9);
        assert!(sp.row(16).is_err());
    }

    #[test]
    fn accumulator_rows() {
        let mut acc = Accumulator::new(16, 1024);
        assert_eq!(acc.rows, 16);
        acc.row_mut(0).unwrap()[0] = 1 << 20;
        assert_eq!(acc.row(0).unwrap()[0], 1 << 20);
    }
}
