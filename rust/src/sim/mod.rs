//! Cycle-level, functionally exact simulator for Gemmini-class
//! accelerators.
//!
//! The simulator plays the role of the paper's cycle-accurate Verilator
//! setup (§4): it executes [`crate::isa::program::Program`]s *functionally* (real
//! int8/int32 arithmetic, so outputs can be checked against the XLA golden
//! model) while a decoupled-queue timing model ([`timing`]) accounts
//! cycles with the same structural bottlenecks as the RTL — DMA bandwidth,
//! systolic-array occupancy, per-command issue overhead, hazards on
//! scratchpad/accumulator rows, and host-side preprocessing cost.

pub mod loopws;
pub mod memory;
pub mod report;
pub mod timing;

use anyhow::{bail, ensure, Context, Result};

use crate::arch::{ArchDesc, Dataflow};
use crate::isa::program::{HostOp, Item, Program};
use crate::isa::{Activation, Instr, LocalAddr, Space};
use crate::obs::timeline::{Timeline, Track};
use crate::util::ceil_div;
use memory::{Accumulator, Dram, Scratchpad};
use report::RunReport;
use timing::{Access, QueueId, Timing};

/// Maximum rows a single `MVIN`/`MVOUT` may move (DMA command limit).
pub const MAX_DMA_ROWS: u16 = 4096;

/// DRAM regions a watched run observes for the overlapped execution
/// model: the *incoming* boundary region a segment reads from its
/// producer, and the *outgoing* boundary region it writes for its
/// consumer. Each region is `(byte offset, length in bytes)`. A `None`
/// region records nothing — the observation defaults then claim no
/// overlap, which is always safe.
#[derive(Debug, Clone, Copy, Default)]
pub struct BoundaryWatch {
    /// Region whose first read the run should timestamp.
    pub incoming: Option<(u64, u64)>,
    /// Region whose last write the run should timestamp.
    pub outgoing: Option<(u64, u64)>,
}

impl BoundaryWatch {
    fn active(&self) -> bool {
        self.incoming.is_some() || self.outgoing.is_some()
    }

    /// Does the byte span `[lo, hi)` touch the incoming region?
    fn reads(&self, lo: u64, hi: u64) -> bool {
        self.incoming.is_some_and(|(off, len)| lo < off + len && off < hi)
    }

    /// Does the byte span `[lo, hi)` touch the outgoing region?
    fn writes(&self, lo: u64, hi: u64) -> bool {
        self.outgoing.is_some_and(|(off, len)| lo < off + len && off < hi)
    }
}

/// What a watched run observed about its [`BoundaryWatch`] regions, in
/// cycles local to the executed slice. The defaults are conservative:
/// `first_read: None` means "assume the region is needed at cycle 0"
/// (no head overlap) and `last_write: None` means "assume it is ready
/// only when the slice finishes" (no tail overlap).
#[derive(Debug, Clone, Copy, Default)]
pub struct BoundaryObs {
    /// Start cycle of the first DRAM access reading the incoming region.
    pub first_read: Option<u64>,
    /// Finish cycle of the last DRAM access writing the outgoing region.
    pub last_write: Option<u64>,
}

impl BoundaryObs {
    fn note_read(&mut self, at: u64) {
        self.first_read = Some(self.first_read.map_or(at, |v| v.min(at)));
    }

    fn note_write(&mut self, at: u64) {
        self.last_write = Some(self.last_write.map_or(at, |v| v.max(at)));
    }
}

/// Requantize an int32 accumulator value to int8 with round-to-nearest-even
/// (matches `jnp.round`; keep in sync with `python/compile/kernels/ref.py`).
#[inline]
pub fn requantize(v: i32, scale: f32, act: Activation) -> i8 {
    let mut x = (v as f32 * scale).round_ties_even();
    if let Activation::Relu = act {
        x = x.max(0.0);
    }
    let mut q = x.clamp(-128.0, 127.0) as i32;
    if let Activation::Clip { lo, hi } = act {
        q = q.clamp(lo as i32, hi as i32);
    }
    q as i8
}

/// Mutable machine state during execution.
struct ExecState {
    dim: usize,
    spad: Scratchpad,
    acc: Accumulator,
    ld_stride: u32,
    st_stride: u32,
    st_scale: f32,
    st_act: Activation,
    dataflow: Dataflow,
    /// Stationary tile (weights under WS), row-major dim×dim.
    b_tile: Vec<i8>,
    b_rows: u16,
    b_cols: u16,
    /// Accumulator destination named by the last PRELOAD.
    dst: Option<LocalAddr>,
    /// Under OS: C-tile column count carried by the PRELOAD.
    os_cols: u16,
    /// Vector backend: the accumulator register file (one int32 per lane).
    vacc: Vec<i32>,
    /// Vector backend: requant scale configured by `VCFG_REQ`.
    v_scale: f32,
    /// Vector backend: activation configured by `VCFG_REQ`.
    v_act: Activation,
}

impl ExecState {
    fn new(arch: &ArchDesc) -> Result<ExecState> {
        let dim = arch.pe_dim;
        let spad_level = arch
            .levels
            .iter()
            .find(|l| l.name == "Scratchpad")
            .context("arch has no Scratchpad level")?;
        let acc_level = arch
            .levels
            .iter()
            .find(|l| l.name == "Accumulator")
            .context("arch has no Accumulator level")?;
        Ok(ExecState {
            dim,
            spad: Scratchpad::new(dim, spad_level.size_bytes),
            acc: Accumulator::new(dim, acc_level.size_bytes),
            ld_stride: 0,
            st_stride: 0,
            st_scale: 1.0,
            st_act: Activation::None,
            dataflow: Dataflow::WeightStationary,
            b_tile: vec![0; dim * dim],
            b_rows: 0,
            b_cols: 0,
            dst: None,
            os_cols: 0,
            vacc: vec![0; dim],
            v_scale: 1.0,
            v_act: Activation::None,
        })
    }
}

/// The simulator: construct once per architecture, run many programs.
pub struct Simulator {
    pub arch: ArchDesc,
    /// Verify every local access against configured sizes (on by default;
    /// benches may disable for the perf hot loop once a program is known
    /// good).
    pub check_bounds: bool,
}

impl Simulator {
    pub fn new(arch: &ArchDesc) -> Simulator {
        Simulator { arch: arch.clone(), check_bounds: true }
    }

    /// Execute `prog` against `dram`, returning the timing/traffic report.
    /// DRAM contents are mutated in place (outputs land in their regions).
    pub fn run(&self, prog: &Program, dram: &mut Dram) -> Result<RunReport> {
        self.run_slice(prog, dram, 0..prog.items.len())
    }

    /// [`Simulator::run`] with an input-region hint `(byte offset, bytes)`:
    /// DMA loads sourced from that region before the first compute are
    /// additionally reported as `input_stage_cycles` (the staging prefix a
    /// double-buffered pipelined batch can overlap with the previous
    /// inference — see `Deployment::run_batch`). Cycles and outputs are
    /// unaffected by the hint.
    pub fn run_hinted(
        &self,
        prog: &Program,
        dram: &mut Dram,
        input_region: Option<(u64, u64)>,
    ) -> Result<RunReport> {
        self.run_slice_hinted(prog, dram, 0..prog.items.len(), input_region)
    }

    /// Execute one contiguous slice of `prog`'s items against `dram` with a
    /// fresh machine state (scratchpad/accumulator cleared, queues empty).
    ///
    /// This is the execution primitive behind heterogeneous deployments: a
    /// [`crate::pipeline::MultiDeployment`] routes each program segment to
    /// the simulator of its assigned accelerator while all segments share
    /// one DRAM. Slices must therefore start at points where no on-chip
    /// state is live across the boundary — the compiler guarantees this by
    /// splitting only at layer boundaries, after the fence that drains each
    /// layer's output to DRAM.
    pub fn run_slice(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
    ) -> Result<RunReport> {
        self.run_slice_hinted(prog, dram, range, None)
    }

    /// [`Simulator::run_slice`] with the input-region hint of
    /// [`Simulator::run_hinted`].
    pub fn run_slice_hinted(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
        input_region: Option<(u64, u64)>,
    ) -> Result<RunReport> {
        Ok(self.run_slice_inner(prog, dram, range, input_region, BoundaryWatch::default(), None)?.0)
    }

    /// [`Simulator::run_slice_hinted`], additionally observing when the
    /// slice first reads its incoming boundary region and last writes its
    /// outgoing one (see [`BoundaryWatch`]). This is the measurement
    /// primitive behind the overlapped multi-target timing model: the
    /// observed head/tail cycles bound how far a consumer segment's start
    /// may slide under its producer. Watching is passive — outputs and
    /// the [`RunReport`] are identical to an unwatched run.
    pub fn run_slice_watched(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
        input_region: Option<(u64, u64)>,
        watch: BoundaryWatch,
    ) -> Result<(RunReport, BoundaryObs)> {
        self.run_slice_inner(prog, dram, range, input_region, watch, None)
    }

    /// [`Simulator::run_slice_watched`] with the timeline recording of
    /// [`Simulator::run_profiled`] (one call drives both the overlapped
    /// schedule and the per-segment profiler tracks).
    pub fn run_slice_observed(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
        input_region: Option<(u64, u64)>,
        watch: BoundaryWatch,
        tl: &mut Timeline,
    ) -> Result<(RunReport, BoundaryObs)> {
        self.run_slice_inner(prog, dram, range, input_region, watch, Some(tl))
    }

    /// [`Simulator::run_hinted`], additionally recording each priced
    /// instruction's resource-occupancy interval into `tl` (DMA engine,
    /// execute queue, store queue, host core — see
    /// [`crate::obs::timeline`]). Recording is passive: outputs and the
    /// [`RunReport`] are identical to an unprofiled run.
    pub fn run_profiled(
        &self,
        prog: &Program,
        dram: &mut Dram,
        input_region: Option<(u64, u64)>,
        tl: &mut Timeline,
    ) -> Result<RunReport> {
        Ok(self
            .run_slice_inner(
                prog,
                dram,
                0..prog.items.len(),
                input_region,
                BoundaryWatch::default(),
                Some(tl),
            )?
            .0)
    }

    /// [`Simulator::run_slice_hinted`] with the timeline recording of
    /// [`Simulator::run_profiled`] (the per-segment profiling primitive
    /// behind `MultiDeployment::run_profiled`).
    pub fn run_slice_profiled(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
        input_region: Option<(u64, u64)>,
        tl: &mut Timeline,
    ) -> Result<RunReport> {
        Ok(self
            .run_slice_inner(prog, dram, range, input_region, BoundaryWatch::default(), Some(tl))?
            .0)
    }

    fn run_slice_inner(
        &self,
        prog: &Program,
        dram: &mut Dram,
        range: std::ops::Range<usize>,
        input_region: Option<(u64, u64)>,
        watch: BoundaryWatch,
        mut tl: Option<&mut Timeline>,
    ) -> Result<(RunReport, BoundaryObs)> {
        ensure!(range.start <= range.end, "inverted item range {range:?}");
        ensure!(
            range.end <= prog.items.len(),
            "item range {range:?} exceeds program length {}",
            prog.items.len()
        );
        let mut st = ExecState::new(&self.arch)?;
        let mut t = Timing::new(st.spad.rows, st.acc.rows);
        let mut rep = RunReport::default();
        let mut obs = BoundaryObs::default();
        let issue = self.arch.host.insn_issue_cycles;

        // Host cycles before the first accelerator instruction: the
        // preprocessing prefix a pipelined batch can overlap with the
        // previous inference (see `RunReport::host_prefix_cycles`).
        let mut seen_accel = false;
        for (off, item) in prog.items[range.clone()].iter().enumerate() {
            let idx = range.start + off;
            if matches!(item, Item::Accel(_)) {
                seen_accel = true;
            }
            match item {
                Item::Accel(Instr::LoopWs { .. }) => {
                    let Item::Accel(macro_insn) = item else { unreachable!() };
                    rep.count("loop_ws");
                    rep.issued_commands += 1;
                    let micro = loopws::expand(&self.arch, st.st_scale, st.st_act, macro_insn)
                        .with_context(|| format!("expanding LOOP_WS at item {idx}"))?;
                    // The macro command itself takes a few issue slots
                    // (Gemmini splits LOOP_WS across several RoCC words).
                    let mut gap = 4 * issue;
                    for m in &micro {
                        // FSM-generated micro-ops issue back-to-back.
                        self.exec_instr(
                            &mut st,
                            dram,
                            &mut t,
                            &mut rep,
                            m,
                            gap,
                            true,
                            input_region,
                            watch,
                            &mut obs,
                            tl.as_deref_mut(),
                        )
                        .with_context(|| format!("LOOP_WS micro-op {m}"))?;
                        gap = 1;
                    }
                }
                Item::Accel(i) => {
                    rep.issued_commands += 1;
                    self.exec_instr(
                        &mut st,
                        dram,
                        &mut t,
                        &mut rep,
                        i,
                        issue,
                        false,
                        input_region,
                        watch,
                        &mut obs,
                        tl.as_deref_mut(),
                    )
                    .with_context(|| format!("item {idx}: {i}"))?;
                }
                Item::Host(h) => {
                    self.exec_host(dram, &mut t, &mut rep, h, watch, &mut obs, tl.as_deref_mut())
                        .with_context(|| format!("item {idx}: {h:?}"))?;
                    if !seen_accel {
                        rep.host_prefix_cycles = t.host_cycles;
                    }
                }
            }
        }
        // Account trailing in-flight work even without a final fence.
        rep.cycles = t.now();
        rep.host_cycles = t.host_cycles;
        Ok((rep, obs))
    }

    /// (total latency, engine occupancy) of one DMA transfer: the fixed
    /// request latency pipelines across transfers; per-row overhead and
    /// data movement occupy the engine.
    fn dma_latency(&self, rows: u64, bytes: u64) -> (u64, u64) {
        let occ = rows * self.arch.dma.per_row_overhead
            + ceil_div(bytes as usize, self.arch.dma.bytes_per_cycle) as u64;
        (self.arch.dma.request_latency + occ, occ)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_instr(
        &self,
        st: &mut ExecState,
        dram: &mut Dram,
        t: &mut Timing,
        rep: &mut RunReport,
        i: &Instr,
        issue_gap: u64,
        from_fsm: bool,
        input_region: Option<(u64, u64)>,
        watch: BoundaryWatch,
        obs: &mut BoundaryObs,
        tl: Option<&mut Timeline>,
    ) -> Result<()> {
        if !from_fsm {
            rep.count(i.mnemonic());
        } else if !matches!(i, Instr::LoopWs { .. }) {
            rep.count(i.mnemonic());
        }
        let dim = st.dim;
        match *i {
            Instr::ConfigEx { dataflow } => {
                st.dataflow = dataflow;
                t.step(QueueId::Ex, issue_gap, 1, None, &[]);
            }
            Instr::ConfigLd { stride } => {
                st.ld_stride = stride;
                t.step(QueueId::Load, issue_gap, 1, None, &[]);
            }
            Instr::ConfigSt { stride, scale, act } => {
                st.st_stride = stride;
                st.st_scale = scale;
                st.st_act = act;
                t.step(QueueId::Store, issue_gap, 1, None, &[]);
            }
            Instr::Mvin { dram: base, local, rows, cols } => {
                ensure!(rows > 0 && cols > 0, "empty mvin");
                ensure!(rows <= MAX_DMA_ROWS, "mvin rows {rows} exceeds DMA limit");
                ensure!(cols as usize <= dim, "mvin cols {cols} exceeds DIM {dim}");
                let stride = st.ld_stride as u64;
                // stride 0 = broadcast: every row reads the same DRAM row
                // (Gemmini's repeating-bias load).
                ensure!(
                    stride == 0 || stride >= cols as u64,
                    "mvin stride {stride} < cols {cols}"
                );
                let bytes = match local.space {
                    Space::Spad => {
                        for r in 0..rows as u64 {
                            let src = base + r * stride;
                            let data = dram.read_i8_slice(src, cols as usize)?;
                            let row = st.spad.row_mut(local.row + r as u32)?;
                            row[..cols as usize].copy_from_slice(&data);
                            // Zero-fill the remainder of the row so partial
                            // tiles never read stale data.
                            row[cols as usize..dim].fill(0);
                        }
                        rows as u64 * cols as u64
                    }
                    Space::Acc => {
                        for r in 0..rows as u64 {
                            let src = base + r * stride * 4;
                            let data = dram.read_i32_slice(src, cols as usize)?;
                            let row = st.acc.row_mut(local.row + r as u32)?;
                            if local.accumulate {
                                for (dst, v) in row.iter_mut().zip(&data) {
                                    *dst = dst.wrapping_add(*v);
                                }
                            } else {
                                row[..cols as usize].copy_from_slice(&data);
                                row[cols as usize..dim].fill(0);
                            }
                        }
                        rows as u64 * cols as u64 * 4
                    }
                };
                rep.dram_read_bytes += bytes;
                let (lat, occ) = self.dma_latency(rows as u64, bytes);
                rep.dram_transfer_cycles += occ;
                // Loads staging the run's input region before any compute
                // form the input-staging prefix a pipelined batch can
                // double-buffer (see `RunReport::input_stage_cycles`).
                if let Some((start, len)) = input_region {
                    if rep.macs == 0 && base >= start && base < start + len {
                        rep.input_stage_cycles += occ;
                    }
                }
                let (start, _) = t.step(
                    QueueId::Load,
                    issue_gap,
                    lat,
                    Some(occ),
                    &[Access::write(local.space, local.row, rows as u32)],
                );
                if watch.active() {
                    let (row_bytes, row_stride) = match local.space {
                        Space::Spad => (cols as u64, stride),
                        Space::Acc => (cols as u64 * 4, stride * 4),
                    };
                    let hi = base + (rows as u64 - 1) * row_stride + row_bytes;
                    if watch.reads(base, hi) {
                        obs.note_read(start);
                    }
                }
                if let Some(tl) = tl {
                    // Engine occupancy only: the request-latency tail
                    // pipelines with the next transfer (mirrors `dma_busy`).
                    tl.push(Track::Dma, "mvin", start, start + occ.min(lat));
                }
            }
            Instr::Mvout { dram: base, local, rows, cols } => {
                ensure!(rows > 0 && cols > 0, "empty mvout");
                ensure!(cols as usize <= dim, "mvout cols {cols} exceeds DIM {dim}");
                let stride = st.st_stride as u64;
                ensure!(stride >= cols as u64, "mvout stride {stride} < cols {cols}");
                let bytes_onchip = match local.space {
                    Space::Acc => {
                        for r in 0..rows as u64 {
                            let dst = base + r * stride;
                            let row = st.acc.row(local.row + r as u32)?.to_vec();
                            for c in 0..cols as usize {
                                let q = requantize(row[c], st.st_scale, st.st_act);
                                dram.write_i8(dst + c as u64, q)?;
                            }
                        }
                        rows as u64 * cols as u64 * 4
                    }
                    Space::Spad => {
                        for r in 0..rows as u64 {
                            let dst = base + r * stride;
                            let row = st.spad.row(local.row + r as u32)?.to_vec();
                            for c in 0..cols as usize {
                                dram.write_i8(dst + c as u64, row[c])?;
                            }
                        }
                        rows as u64 * cols as u64
                    }
                };
                rep.dram_write_bytes += rows as u64 * cols as u64;
                let (lat, occ) = self.dma_latency(rows as u64, bytes_onchip);
                rep.dram_transfer_cycles += occ;
                let (start, finish) = t.step(
                    QueueId::Store,
                    issue_gap,
                    lat,
                    Some(occ),
                    &[Access::read(local.space, local.row, rows as u32)],
                );
                if watch.writes(base, base + (rows as u64 - 1) * stride + cols as u64) {
                    obs.note_write(finish);
                }
                if let Some(tl) = tl {
                    tl.push(Track::Dma, "mvout", start, start + occ.min(lat));
                }
            }
            Instr::MvoutSpad { src, dst, rows, cols } => {
                ensure!(rows > 0 && cols > 0, "empty mvout_spad");
                ensure!(cols as usize <= dim, "mvout_spad cols {cols} exceeds DIM {dim}");
                ensure!(src.space == Space::Acc, "mvout_spad source must be accumulator");
                ensure!(dst.space == Space::Spad, "mvout_spad dest must be scratchpad");
                for r in 0..rows as u32 {
                    let row = st.acc.row(src.row + r)?.to_vec();
                    let out = st.spad.row_mut(dst.row + r)?;
                    for (dst_v, &acc_v) in
                        out[..cols as usize].iter_mut().zip(row[..cols as usize].iter())
                    {
                        *dst_v = requantize(acc_v, st.st_scale, st.st_act);
                    }
                    // Zero-fill like MVIN so partial tiles never read stale
                    // data through the resident region.
                    out[cols as usize..dim].fill(0);
                }
                // Purely on-chip: occupies the store queue, but neither the
                // DMA engine nor DRAM bandwidth (the whole point of keeping
                // the activation resident).
                let (start, finish) = t.step(
                    QueueId::Store,
                    issue_gap,
                    rows as u64 + 4,
                    None,
                    &[
                        Access::read(Space::Acc, src.row, rows as u32),
                        Access::write(Space::Spad, dst.row, rows as u32),
                    ],
                );
                if let Some(tl) = tl {
                    tl.push(Track::Store, "mvout_spad", start, finish);
                }
            }
            Instr::Preload { local, dst, rows, cols } => {
                ensure!(rows as usize <= dim && cols as usize <= dim, "preload tile > DIM");
                ensure!(dst.space == Space::Acc, "preload dst must be accumulator");
                let mut accesses = vec![];
                match (st.dataflow, local) {
                    (Dataflow::WeightStationary, Some(b)) => {
                        ensure!(b.space == Space::Spad, "WS preload source must be scratchpad");
                        st.b_tile.iter_mut().for_each(|v| *v = 0);
                        for r in 0..rows as u32 {
                            let row = st.spad.row(b.row + r)?;
                            st.b_tile[r as usize * dim..r as usize * dim + cols as usize]
                                .copy_from_slice(&row[..cols as usize]);
                        }
                        st.b_rows = rows;
                        st.b_cols = cols;
                        accesses.push(Access::read(Space::Spad, b.row, rows as u32));
                    }
                    (Dataflow::WeightStationary, None) => {
                        st.b_tile.iter_mut().for_each(|v| *v = 0);
                        st.b_rows = rows;
                        st.b_cols = cols;
                    }
                    (Dataflow::OutputStationary, _) => {
                        // OS: preload names the C tile; zero it unless the
                        // destination requests accumulation. rows/cols give
                        // the C tile shape.
                        st.os_cols = cols;
                        if !dst.accumulate {
                            for r in 0..rows as u32 {
                                let row = st.acc.row_mut(dst.row + r)?;
                                row.iter_mut().for_each(|v| *v = 0);
                            }
                            accesses.push(Access::write(Space::Acc, dst.row, rows as u32));
                        }
                    }
                }
                st.dst = Some(dst);
                // WS: the PE array double-buffers its weight registers, so
                // streaming the next stationary tile overlaps the previous
                // compute — a preload costs only its issue beat. OS:
                // binding a new output tile drains the in-PE accumulators
                // first (a full-DIM cost) — this is why WS is Gemmini's
                // performant configuration.
                let lat = match st.dataflow {
                    Dataflow::WeightStationary => 4,
                    Dataflow::OutputStationary => rows as u64 + dim as u64,
                };
                let (start, finish) = t.step(QueueId::Ex, issue_gap, lat, None, &accesses);
                if let Some(tl) = tl {
                    tl.push(Track::Compute, "preload", start, finish);
                }
            }
            Instr::Compute { a, d, rows, cols, preloaded } => {
                ensure!(a.space == Space::Spad, "compute A must come from scratchpad");
                ensure!(rows as usize <= dim && cols as usize <= dim, "compute tile > DIM");
                let dst = st.dst.context("compute without preceding preload")?;
                let _ = preloaded; // B persistence is implicit in st.b_tile.
                let mut accesses =
                    vec![Access::read(Space::Spad, a.row, rows as u32)];
                let os_tile: Vec<i8>;
                let (b_cols, b_tile): (usize, &[i8]) = match st.dataflow {
                    Dataflow::WeightStationary => {
                        ensure!(
                            cols == st.b_rows,
                            "compute cols {cols} != preloaded B rows {}",
                            st.b_rows
                        );
                        (st.b_cols as usize, &st.b_tile)
                    }
                    Dataflow::OutputStationary => {
                        // OS: the second operand addresses a B tile in the
                        // scratchpad (Gemmini's compute rs2 under OS).
                        let b = d.context("OS compute requires B operand")?;
                        ensure!(b.space == Space::Spad, "OS compute B must be scratchpad");
                        let b_rows = cols as usize;
                        let b_cols = st.os_cols as usize;
                        let mut tile = vec![0i8; b_rows * dim];
                        for r in 0..b_rows as u32 {
                            let row = st.spad.row(b.row + r)?;
                            tile[r as usize * dim..(r as usize + 1) * dim]
                                .copy_from_slice(row);
                        }
                        accesses.push(Access::read(Space::Spad, b.row, b_rows as u32));
                        os_tile = tile;
                        (b_cols, os_tile.as_slice())
                    }
                };
                // Matmul: C[rows × b_cols] (+)= A[rows × cols] · B[cols × b_cols].
                // k-middle / j-inner loop order so the inner accumulation
                // vectorizes (hot path: see EXPERIMENTS.md §Perf).
                let overwrite = !dst.accumulate && st.dataflow == Dataflow::WeightStationary;
                // Split-borrow scratchpad (A source) and accumulator (C
                // destination) so no per-compute staging copy is needed.
                let spad = &st.spad;
                let acc = &mut st.acc;
                for r in 0..rows as usize {
                    let a_row = spad.row(a.row + r as u32)?;
                    let acc_row = acc.row_mut(dst.row + r as u32)?;
                    if overwrite {
                        acc_row.fill(0);
                    }
                    for kk in 0..cols as usize {
                        let av = a_row[kk] as i32;
                        if av == 0 {
                            continue;
                        }
                        let b_row = &b_tile[kk * dim..kk * dim + b_cols];
                        for (acc, &bv) in acc_row[..b_cols].iter_mut().zip(b_row) {
                            *acc = acc.wrapping_add(av * bv as i32);
                        }
                    }
                }
                // Bias operand under WS (unused by our codegen, which loads
                // bias via mvin-to-accumulator, but part of the ISA).
                if st.dataflow == Dataflow::WeightStationary {
                    if let Some(dd) = d {
                        ensure!(dd.space == Space::Acc, "WS compute D must be accumulator");
                        for r in 0..rows as u32 {
                            let drow = st.acc.row(dd.row + r)?.to_vec();
                            let crow = st.acc.row_mut(dst.row + r)?;
                            for j in 0..b_cols {
                                crow[j] = crow[j].wrapping_add(drow[j]);
                            }
                        }
                        accesses.push(Access::read(Space::Acc, dd.row, rows as u32));
                    }
                }
                accesses.push(Access::write(Space::Acc, dst.row, rows as u32));
                rep.macs += rows as u64 * cols as u64 * b_cols as u64;
                // Systolic timing: `rows` beats to stream A plus a small
                // pipeline overhead. Back-to-back computes keep the array
                // full, so the full fill/drain cost is not paid per tile
                // (it shows up in the preload/flush costs instead).
                let lat = rows as u64 + 8;
                let (start, finish) = t.step(QueueId::Ex, issue_gap, lat, None, &accesses);
                if let Some(tl) = tl {
                    tl.push(Track::Compute, "compute", start, finish);
                }
            }
            Instr::LoopWs { .. } => bail!("nested LOOP_WS is not supported"),
            Instr::Fence => {
                t.fence(self.arch.host.fence_cycles);
            }
            Instr::Flush => {
                st.b_tile.iter_mut().for_each(|v| *v = 0);
                st.b_rows = 0;
                st.b_cols = 0;
                let (start, finish) = t.step(QueueId::Ex, issue_gap, dim as u64, None, &[]);
                if let Some(tl) = tl {
                    tl.push(Track::Compute, "flush", start, finish);
                }
            }
            // Vector-backend family: an in-order scalar/SIMD engine with a
            // single accumulator register file. Everything runs through the
            // Ex queue (no decoupled load/store pipelines), and every
            // latency below depends only on shapes + architecture, never on
            // data — the timing model itself is owned by the backend
            // (`backend::vector::timing`).
            Instr::VcfgReq { scale, act } => {
                st.v_scale = scale;
                st.v_act = act;
                t.step(QueueId::Ex, issue_gap, 1, None, &[]);
            }
            Instr::VldBias { dram: base, len } => {
                ensure!(len > 0, "empty vld_bias");
                ensure!(len as usize <= dim, "vld_bias len {len} exceeds lane count {dim}");
                let data = dram.read_i32_slice(base, len as usize)?;
                st.vacc[..len as usize].copy_from_slice(&data);
                st.vacc[len as usize..].fill(0);
                rep.dram_read_bytes += len as u64 * 4;
                let (lat, occ) = crate::backend::vector::timing::ld_bias(&self.arch, len);
                rep.dram_transfer_cycles += occ;
                let (start, _) = t.step(QueueId::Ex, issue_gap, lat, Some(occ), &[]);
                if watch.reads(base, base + len as u64 * 4) {
                    obs.note_read(start);
                }
                if let Some(tl) = tl {
                    tl.push(Track::Dma, "vld_bias", start, start + occ.min(lat));
                }
            }
            Instr::VmacStrip { x_dram, w_dram, w_stride, n_out, n_in } => {
                ensure!(n_out > 0 && n_in > 0, "empty vmac_strip");
                ensure!(
                    n_out as usize <= dim,
                    "vmac_strip n_out {n_out} exceeds lane count {dim}"
                );
                ensure!(
                    w_stride >= n_out as u32,
                    "vmac_strip stride {w_stride} < n_out {n_out}"
                );
                let x = dram.read_i8_slice(x_dram, n_in as usize)?;
                for c in 0..n_in as usize {
                    let xv = x[c] as i32;
                    let w_row =
                        dram.read_i8_slice(w_dram + c as u64 * w_stride as u64, n_out as usize)?;
                    for o in 0..n_out as usize {
                        st.vacc[o] = st.vacc[o].wrapping_add(xv * w_row[o] as i32);
                    }
                }
                rep.macs += n_out as u64 * n_in as u64;
                rep.dram_read_bytes += n_in as u64 * (1 + n_out as u64);
                let (lat, occ, stream) =
                    crate::backend::vector::timing::mac_strip(&self.arch, n_out, n_in);
                rep.dram_transfer_cycles += stream;
                let (start, finish) = t.step(QueueId::Ex, issue_gap, lat, Some(occ), &[]);
                let w_hi = w_dram + (n_in as u64 - 1) * w_stride as u64 + n_out as u64;
                if watch.reads(x_dram, x_dram + n_in as u64) || watch.reads(w_dram, w_hi) {
                    obs.note_read(start);
                }
                if let Some(tl) = tl {
                    // The strip both streams operands (DMA) and MACs them
                    // (lanes) — it shows on both tracks.
                    tl.push(Track::Dma, "vmac_strip", start, start + occ.min(lat));
                    tl.push(Track::Compute, "vmac_strip", start, finish);
                }
            }
            Instr::VstOut { dram: base, len } => {
                ensure!(len > 0, "empty vst_out");
                ensure!(len as usize <= dim, "vst_out len {len} exceeds lane count {dim}");
                for j in 0..len as usize {
                    let q = requantize(st.vacc[j], st.v_scale, st.v_act);
                    dram.write_i8(base + j as u64, q)?;
                }
                rep.dram_write_bytes += len as u64;
                let (lat, occ) = crate::backend::vector::timing::st_out(&self.arch, len);
                rep.dram_transfer_cycles += occ;
                let (start, finish) = t.step(QueueId::Ex, issue_gap, lat, Some(occ), &[]);
                if watch.writes(base, base + len as u64) {
                    obs.note_write(finish);
                }
                if let Some(tl) = tl {
                    tl.push(Track::Dma, "vst_out", start, start + occ.min(lat));
                }
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_host(
        &self,
        dram: &mut Dram,
        t: &mut Timing,
        rep: &mut RunReport,
        h: &HostOp,
        watch: BoundaryWatch,
        obs: &mut BoundaryObs,
        tl: Option<&mut Timeline>,
    ) -> Result<()> {
        rep.count(h.mnemonic());
        // Functional execution.
        match *h {
            HostOp::TransposeI8 { src, dst, rows, cols } => {
                let data = dram.read_i8_slice(src, rows * cols)?;
                let mut out = vec![0i8; rows * cols];
                for r in 0..rows {
                    for c in 0..cols {
                        out[c * rows + r] = data[r * cols + c];
                    }
                }
                dram.write_i8_slice(dst, &out)?;
            }
            HostOp::QuantizeF32 { src, dst, n, scale } => {
                let v = dram.read_f32_slice(src, n)?;
                let q: Vec<i8> = v
                    .iter()
                    .map(|&x| (x / scale).round_ties_even().clamp(-128.0, 127.0) as i8)
                    .collect();
                dram.write_i8_slice(dst, &q)?;
            }
            HostOp::DequantizeI8 { src, dst, n, scale } => {
                let v = dram.read_i8_slice(src, n)?;
                let f: Vec<f32> = v.iter().map(|&x| x as f32 * scale).collect();
                dram.write_f32_slice(dst, &f)?;
            }
            HostOp::RequantizeI32 { src, dst, n, scale } => {
                let v = dram.read_i32_slice(src, n)?;
                let q: Vec<i8> = v
                    .iter()
                    .map(|&x| requantize(x, scale, Activation::None))
                    .collect();
                dram.write_i8_slice(dst, &q)?;
            }
            HostOp::WidenI8ToI32 { src, dst, n } => {
                for i in 0..n {
                    let v = dram.read_i8(src + i as u64)?;
                    dram.write_i32(dst + 4 * i as u64, v as i32)?;
                }
            }
            HostOp::Memcpy { src, dst, bytes } => {
                dram.copy_bytes(src, dst, bytes)?;
            }
            HostOp::AddI32 { a, b, dst, n } => {
                for i in 0..n {
                    let x = dram.read_i32(a + 4 * i as u64)?;
                    let y = dram.read_i32(b + 4 * i as u64)?;
                    dram.write_i32(dst + 4 * i as u64, x.wrapping_add(y))?;
                }
            }
            HostOp::BiasAddI32 { x, bias, dst, n, k } => {
                for i in 0..n {
                    for j in 0..k {
                        let v = dram.read_i32(x + 4 * (i * k + j) as u64)?;
                        let b = dram.read_i32(bias + 4 * j as u64)?;
                        dram.write_i32(dst + 4 * (i * k + j) as u64, v.wrapping_add(b))?;
                    }
                }
            }
            HostOp::MatmulI8 { a, b, c, n, c_dim, k } => {
                for i in 0..n {
                    for j in 0..k {
                        let mut s = 0i32;
                        for kk in 0..c_dim {
                            let x = dram.read_i8(a + (i * c_dim + kk) as u64)? as i32;
                            let y = dram.read_i8(b + (kk * k + j) as u64)? as i32;
                            s += x * y;
                        }
                        dram.write_i32(c + 4 * (i * k + j) as u64, s)?;
                    }
                }
            }
            HostOp::ClipI8 { buf, n, lo, hi } => {
                for i in 0..n {
                    let v = dram.read_i8(buf + i as u64)?;
                    dram.write_i8(buf + i as u64, v.clamp(lo, hi))?;
                }
            }
            HostOp::Im2col { src, dst, n, h, w, c, kh, kw, stride, pad } => {
                let x = dram.read_i8_slice(src, n * h * w * c)?;
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (w + 2 * pad - kw) / stride + 1;
                let cols = kh * kw * c;
                let mut out = vec![0i8; n * oh * ow * cols];
                for b in 0..n {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let row = ((b * oh + oy) * ow + ox) * cols;
                            for dy in 0..kh {
                                for dx in 0..kw {
                                    let iy = (oy * stride + dy) as isize - pad as isize;
                                    let ix = (ox * stride + dx) as isize - pad as isize;
                                    if iy < 0
                                        || ix < 0
                                        || iy >= h as isize
                                        || ix >= w as isize
                                    {
                                        continue; // zero padding
                                    }
                                    let s = ((b * h + iy as usize) * w + ix as usize) * c;
                                    let d = row + (dy * kw + dx) * c;
                                    out[d..d + c].copy_from_slice(&x[s..s + c]);
                                }
                            }
                        }
                    }
                }
                dram.write_i8_slice(dst, &out)?;
            }
        }
        // Timing: fixed dispatch overhead plus per-element costs.
        let cost = 10
            + h.alu_elems() * self.arch.host.cycles_per_elem_alu
            + h.moved_elems() * self.arch.host.cycles_per_elem_move;
        let end = t.host(cost);
        if watch.active() {
            let (reads, writes) = host_spans(h);
            if reads.iter().any(|&(lo, hi)| watch.reads(lo, hi)) {
                obs.note_read(end - cost);
            }
            if writes.iter().any(|&(lo, hi)| watch.writes(lo, hi)) {
                obs.note_write(end);
            }
        }
        if let Some(tl) = tl {
            tl.push(Track::Host, h.mnemonic(), end - cost, end);
        }
        Ok(())
    }
}

/// The `[lo, hi)` DRAM byte spans a host op reads and writes — mirrors
/// the functional implementations in `exec_host`, for boundary watching.
fn host_spans(h: &HostOp) -> (Vec<(u64, u64)>, Vec<(u64, u64)>) {
    match *h {
        HostOp::TransposeI8 { src, dst, rows, cols } => {
            let n = (rows * cols) as u64;
            (vec![(src, src + n)], vec![(dst, dst + n)])
        }
        HostOp::QuantizeF32 { src, dst, n, .. } => {
            (vec![(src, src + 4 * n as u64)], vec![(dst, dst + n as u64)])
        }
        HostOp::DequantizeI8 { src, dst, n, .. } => {
            (vec![(src, src + n as u64)], vec![(dst, dst + 4 * n as u64)])
        }
        HostOp::RequantizeI32 { src, dst, n, .. } => {
            (vec![(src, src + 4 * n as u64)], vec![(dst, dst + n as u64)])
        }
        HostOp::WidenI8ToI32 { src, dst, n } => {
            (vec![(src, src + n as u64)], vec![(dst, dst + 4 * n as u64)])
        }
        HostOp::Memcpy { src, dst, bytes } => {
            (vec![(src, src + bytes as u64)], vec![(dst, dst + bytes as u64)])
        }
        HostOp::AddI32 { a, b, dst, n } => {
            let len = 4 * n as u64;
            (vec![(a, a + len), (b, b + len)], vec![(dst, dst + len)])
        }
        HostOp::BiasAddI32 { x, bias, dst, n, k } => {
            let len = 4 * (n * k) as u64;
            (vec![(x, x + len), (bias, bias + 4 * k as u64)], vec![(dst, dst + len)])
        }
        HostOp::MatmulI8 { a, b, c, n, c_dim, k } => (
            vec![(a, a + (n * c_dim) as u64), (b, b + (c_dim * k) as u64)],
            vec![(c, c + 4 * (n * k) as u64)],
        ),
        HostOp::ClipI8 { buf, n, .. } => {
            (vec![(buf, buf + n as u64)], vec![(buf, buf + n as u64)])
        }
        HostOp::Im2col { src, dst, n, h, w, c, kh, kw, stride, pad } => {
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (w + 2 * pad - kw) / stride + 1;
            (
                vec![(src, src + (n * h * w * c) as u64)],
                vec![(dst, dst + (n * oh * ow * kh * kw * c) as u64)],
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::Program;

    fn arch() -> ArchDesc {
        ArchDesc::gemmini()
    }

    /// Hand-written single-tile GEMM: C[2x2] = A[2x3] · B[3x2], requantize
    /// scale 1.0 (identity), checked element-exactly.
    #[test]
    fn single_tile_matmul_ws() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("tile");
        let ra = prog.layout.alloc("a", 6).unwrap().offset;
        let rb = prog.layout.alloc("b", 6).unwrap().offset;
        let rc = prog.layout.alloc("c", 4).unwrap().offset;
        let mut dram = Dram::new(prog.layout.total_bytes() as usize + 64);
        // A = [[1,2,3],[4,5,6]]; B = [[1,0],[0,1],[1,1]]
        dram.write_i8_slice(ra, &[1, 2, 3, 4, 5, 6]).unwrap();
        dram.write_i8_slice(rb, &[1, 0, 0, 1, 1, 1]).unwrap();
        prog.push(Instr::ConfigEx { dataflow: Dataflow::WeightStationary });
        prog.push(Instr::ConfigLd { stride: 3 });
        prog.push(Instr::Mvin { dram: ra, local: LocalAddr::spad(0), rows: 2, cols: 3 });
        prog.push(Instr::ConfigLd { stride: 2 });
        prog.push(Instr::Mvin { dram: rb, local: LocalAddr::spad(8), rows: 3, cols: 2 });
        prog.push(Instr::Preload {
            local: Some(LocalAddr::spad(8)),
            dst: LocalAddr::acc(0),
            rows: 3,
            cols: 2,
        });
        prog.push(Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 2,
            cols: 3,
            preloaded: true,
        });
        prog.push(Instr::ConfigSt { stride: 2, scale: 1.0, act: Activation::None });
        prog.push(Instr::Mvout { dram: rc, local: LocalAddr::acc(0), rows: 2, cols: 2 });
        prog.push(Instr::Fence);
        let rep = sim.run(&prog, &mut dram).unwrap();
        // C = [[1*1+3*1, 2+3],[4+6, 5+6]] = [[4,5],[10,11]]
        assert_eq!(dram.read_i8_slice(rc, 4).unwrap(), vec![4, 5, 10, 11]);
        assert!(rep.cycles > 0);
        assert_eq!(rep.macs, 2 * 3 * 2);
    }

    /// K-tiled accumulation across two compute instructions.
    #[test]
    fn k_tiled_accumulation() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("ktile");
        let ra = prog.layout.alloc("a", 2).unwrap().offset;
        let rb = prog.layout.alloc("b", 2).unwrap().offset;
        let rc = prog.layout.alloc("c", 1).unwrap().offset;
        let mut dram = Dram::new(64);
        dram.write_i8_slice(ra, &[3, 5]).unwrap(); // A = [3 | 5] split in k
        dram.write_i8_slice(rb, &[2, 7]).unwrap(); // B = [2 ; 7]
        prog.push(Instr::ConfigEx { dataflow: Dataflow::WeightStationary });
        prog.push(Instr::ConfigLd { stride: 1 });
        // k-slice 0
        prog.push(Instr::Mvin { dram: ra, local: LocalAddr::spad(0), rows: 1, cols: 1 });
        prog.push(Instr::Mvin { dram: rb, local: LocalAddr::spad(1), rows: 1, cols: 1 });
        prog.push(Instr::Preload {
            local: Some(LocalAddr::spad(1)),
            dst: LocalAddr::acc(0),
            rows: 1,
            cols: 1,
        });
        prog.push(Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 1,
            cols: 1,
            preloaded: true,
        });
        // k-slice 1 accumulates
        prog.push(Instr::Mvin { dram: ra + 1, local: LocalAddr::spad(2), rows: 1, cols: 1 });
        prog.push(Instr::Mvin { dram: rb + 1, local: LocalAddr::spad(3), rows: 1, cols: 1 });
        prog.push(Instr::Preload {
            local: Some(LocalAddr::spad(3)),
            dst: LocalAddr::acc_accumulate(0),
            rows: 1,
            cols: 1,
        });
        prog.push(Instr::Compute {
            a: LocalAddr::spad(2),
            d: None,
            rows: 1,
            cols: 1,
            preloaded: true,
        });
        prog.push(Instr::ConfigSt { stride: 1, scale: 1.0, act: Activation::None });
        prog.push(Instr::Mvout { dram: rc, local: LocalAddr::acc(0), rows: 1, cols: 1 });
        prog.push(Instr::Fence);
        sim.run(&prog, &mut dram).unwrap();
        // 3*2 + 5*7 = 41
        assert_eq!(dram.read_i8(rc).unwrap(), 41);
    }

    #[test]
    fn requantize_semantics() {
        assert_eq!(requantize(100, 0.5, Activation::None), 50);
        assert_eq!(requantize(-300, 1.0, Activation::None), -128); // saturate
        assert_eq!(requantize(300, 1.0, Activation::None), 127);
        assert_eq!(requantize(-40, 1.0, Activation::Relu), 0);
        assert_eq!(requantize(99, 1.0, Activation::Clip { lo: -10, hi: 10 }), 10);
        // Round-half-to-even: 2.5 -> 2, 3.5 -> 4.
        assert_eq!(requantize(5, 0.5, Activation::None), 2);
        assert_eq!(requantize(7, 0.5, Activation::None), 4);
    }

    #[test]
    fn relu_applied_on_mvout() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("relu");
        let ra = prog.layout.alloc("a", 1).unwrap().offset;
        let rb = prog.layout.alloc("b", 1).unwrap().offset;
        let rc = prog.layout.alloc("c", 1).unwrap().offset;
        let mut dram = Dram::new(64);
        dram.write_i8(ra, -3).unwrap();
        dram.write_i8(rb, 5).unwrap();
        prog.push(Instr::ConfigEx { dataflow: Dataflow::WeightStationary });
        prog.push(Instr::ConfigLd { stride: 1 });
        prog.push(Instr::Mvin { dram: ra, local: LocalAddr::spad(0), rows: 1, cols: 1 });
        prog.push(Instr::Mvin { dram: rb, local: LocalAddr::spad(1), rows: 1, cols: 1 });
        prog.push(Instr::Preload {
            local: Some(LocalAddr::spad(1)),
            dst: LocalAddr::acc(0),
            rows: 1,
            cols: 1,
        });
        prog.push(Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 1,
            cols: 1,
            preloaded: true,
        });
        prog.push(Instr::ConfigSt { stride: 1, scale: 1.0, act: Activation::Relu });
        prog.push(Instr::Mvout { dram: rc, local: LocalAddr::acc(0), rows: 1, cols: 1 });
        prog.push(Instr::Fence);
        sim.run(&prog, &mut dram).unwrap();
        assert_eq!(dram.read_i8(rc).unwrap(), 0); // relu(-15) = 0
    }

    #[test]
    fn bias_via_accumulator_mvin() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("bias");
        let ra = prog.layout.alloc("a", 1).unwrap().offset;
        let rb = prog.layout.alloc("b", 1).unwrap().offset;
        let rbias = prog.layout.alloc("bias", 4).unwrap().offset;
        let rc = prog.layout.alloc("c", 1).unwrap().offset;
        let mut dram = Dram::new(64);
        dram.write_i8(ra, 4).unwrap();
        dram.write_i8(rb, 6).unwrap();
        dram.write_i32(rbias, 100).unwrap();
        prog.push(Instr::ConfigEx { dataflow: Dataflow::WeightStationary });
        prog.push(Instr::ConfigLd { stride: 1 });
        // Load bias into the accumulator first, then accumulate the matmul.
        prog.push(Instr::Mvin { dram: rbias, local: LocalAddr::acc(0), rows: 1, cols: 1 });
        prog.push(Instr::Mvin { dram: ra, local: LocalAddr::spad(0), rows: 1, cols: 1 });
        prog.push(Instr::Mvin { dram: rb, local: LocalAddr::spad(1), rows: 1, cols: 1 });
        prog.push(Instr::Preload {
            local: Some(LocalAddr::spad(1)),
            dst: LocalAddr::acc_accumulate(0),
            rows: 1,
            cols: 1,
        });
        prog.push(Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 1,
            cols: 1,
            preloaded: true,
        });
        prog.push(Instr::ConfigSt { stride: 1, scale: 1.0, act: Activation::None });
        prog.push(Instr::Mvout { dram: rc, local: LocalAddr::acc(0), rows: 1, cols: 1 });
        prog.push(Instr::Fence);
        sim.run(&prog, &mut dram).unwrap();
        assert_eq!(dram.read_i8(rc).unwrap(), 124); // 100 + 24
    }

    #[test]
    fn host_ops_functional() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("host");
        let rsrc = prog.layout.alloc("src", 6).unwrap().offset;
        let rdst = prog.layout.alloc("dst", 6).unwrap().offset;
        let mut dram = Dram::new(64);
        dram.write_i8_slice(rsrc, &[1, 2, 3, 4, 5, 6]).unwrap();
        prog.push_host(HostOp::TransposeI8 { src: rsrc, dst: rdst, rows: 2, cols: 3 });
        let rep = sim.run(&prog, &mut dram).unwrap();
        assert_eq!(dram.read_i8_slice(rdst, 6).unwrap(), vec![1, 4, 2, 5, 3, 6]);
        assert!(rep.host_cycles > 0);
        assert_eq!(rep.cycles, rep.host_cycles);
    }

    #[test]
    fn mvin_rejects_bad_stride() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("bad");
        prog.push(Instr::ConfigLd { stride: 2 });
        prog.push(Instr::Mvin { dram: 0, local: LocalAddr::spad(0), rows: 1, cols: 4 });
        let mut dram = Dram::new(64);
        assert!(sim.run(&prog, &mut dram).is_err());
    }

    /// Hand-written vector-family program: bias load, one MAC strip over a
    /// `[C=2, K=2]` weight block in the shared transposed layout, requantized
    /// store — checked element-exactly.
    #[test]
    fn vector_family_semantics() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("vec");
        let rx = prog.layout.alloc("x", 2).unwrap().offset;
        let rw = prog.layout.alloc("w", 4).unwrap().offset;
        let rbias = prog.layout.alloc("bias", 8).unwrap().offset;
        let rout = prog.layout.alloc("out", 2).unwrap().offset;
        let mut dram = Dram::new(64);
        dram.write_i8_slice(rx, &[2, 3]).unwrap();
        // w[c*stride + o] with stride 2: column o=0 is [1,3], o=1 is [2,4].
        dram.write_i8_slice(rw, &[1, 2, 3, 4]).unwrap();
        dram.write_i32(rbias, 100).unwrap();
        dram.write_i32(rbias + 4, -5).unwrap();
        prog.push(Instr::VcfgReq { scale: 1.0, act: Activation::None });
        prog.push(Instr::VldBias { dram: rbias, len: 2 });
        prog.push(Instr::VmacStrip { x_dram: rx, w_dram: rw, w_stride: 2, n_out: 2, n_in: 2 });
        prog.push(Instr::VstOut { dram: rout, len: 2 });
        prog.push(Instr::Fence);
        let rep = sim.run(&prog, &mut dram).unwrap();
        // out[0] = 100 + 2*1 + 3*3 = 111; out[1] = -5 + 2*2 + 3*4 = 11
        assert_eq!(dram.read_i8_slice(rout, 2).unwrap(), vec![111, 11]);
        assert_eq!(rep.macs, 4);
        assert_eq!(rep.dram_write_bytes, 2);
        assert!(rep.cycles > 0);
    }

    #[test]
    fn compute_without_preload_fails() {
        let a = arch();
        let sim = Simulator::new(&a);
        let mut prog = Program::new("bad2");
        prog.push(Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 1,
            cols: 1,
            preloaded: true,
        });
        let mut dram = Dram::new(64);
        assert!(sim.run(&prog, &mut dram).is_err());
    }
}
