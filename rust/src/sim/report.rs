//! Run reports: cycle counts, traffic and utilization counters produced by
//! a simulation, used by the benches to regenerate the paper's tables.

use std::collections::BTreeMap;

use crate::util::table::commafy;

/// Counters collected over one program execution.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Total latency in accelerator cycles (the paper's Table 2 metric).
    pub cycles: u64,
    /// Cycles spent in host-CPU operations (preprocessing etc.).
    pub host_cycles: u64,
    /// Host cycles spent *before the first accelerator instruction* (the
    /// run's preprocessing prefix). This is the portion a pipelined batch
    /// can overlap with the previous inference's accelerator execution —
    /// see `Deployment::run_batch`'s pipelined timing model.
    pub host_prefix_cycles: u64,
    /// Bytes moved DRAM → on-chip.
    pub dram_read_bytes: u64,
    /// Bytes moved on-chip → DRAM.
    pub dram_write_bytes: u64,
    /// Cycles the DMA engine spent moving data to/from DRAM (per-transfer
    /// occupancy, summed). On-chip moves (`mvout_spad`, the cross-layer
    /// residency store) contribute nothing here, so a deployment with
    /// resident edges shows strictly fewer DRAM-transfer cycles than its
    /// round-tripping baseline.
    pub dram_transfer_cycles: u64,
    /// DMA occupancy of the loads that stage this run's *input region*
    /// before the first compute fires (the first input-tile DMA). Under
    /// double-buffered input staging a pipelined batch overlaps this
    /// prefix — like `host_prefix_cycles` — with the previous inference's
    /// accelerator execution (see `Deployment::run_batch`).
    pub input_stage_cycles: u64,
    /// Multiply-accumulates performed by the PE array.
    pub macs: u64,
    /// Instruction counts by mnemonic (LOOP_WS micro-ops counted under
    /// their own mnemonics, the macro under `loop_ws`).
    pub insn_counts: BTreeMap<&'static str, u64>,
    /// Commands issued by the host front-end (one per RoCC instruction).
    pub issued_commands: u64,
    /// Overlapped makespan of a multi-target run: the end-to-end latency
    /// when segments are scheduled by data dependency (the consumer's
    /// boundary reload double-buffered under the producer's tail) instead
    /// of as a serial handoff. Always ≤ `cycles`. Zero means "not a
    /// multi-target run" — single-target reports never set it.
    pub overlapped_cycles: u64,
}

impl RunReport {
    pub fn count(&mut self, mnemonic: &'static str) {
        *self.insn_counts.entry(mnemonic).or_insert(0) += 1;
    }

    /// Fold another report into this one (cycles and traffic add, counters
    /// merge). Used by heterogeneous deployments, which execute a program
    /// as serial segments — one per accelerator handoff — and report the
    /// sum as the end-to-end run.
    pub fn merge(&mut self, other: &RunReport) {
        // The preprocessing prefix extends across the boundary only while
        // no accelerator instruction has executed yet (`issued_commands`
        // counts exactly those): an all-host leading segment contributes
        // its full host time plus the next segment's own prefix.
        if self.issued_commands == 0 {
            self.host_prefix_cycles = self.host_cycles + other.host_prefix_cycles;
        }
        // Input staging is a *prefix* notion too: it only extends across a
        // segment boundary while no compute has fired yet. Summing it
        // unconditionally would claim overlap for mid-run loads — and,
        // with resident edges eliding boundary transfers, would leave the
        // merged DRAM counters inconsistent with the instruction stream.
        if self.macs == 0 {
            self.input_stage_cycles += other.input_stage_cycles;
        }
        self.cycles += other.cycles;
        self.host_cycles += other.host_cycles;
        self.dram_read_bytes += other.dram_read_bytes;
        self.dram_write_bytes += other.dram_write_bytes;
        self.dram_transfer_cycles += other.dram_transfer_cycles;
        self.macs += other.macs;
        self.issued_commands += other.issued_commands;
        // Per-segment reports never carry an overlapped makespan (the
        // schedule is a whole-deployment notion) — `MultiDeployment`
        // sets the merged report's value after scheduling all segments.
        self.overlapped_cycles += other.overlapped_cycles;
        for (&m, &n) in &other.insn_counts {
            *self.insn_counts.entry(m).or_insert(0) += n;
        }
    }

    /// PE-array utilization: achieved MACs over peak MACs for the run.
    pub fn utilization(&self, pe_dim: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / ((pe_dim * pe_dim) as f64 * self.cycles as f64)
    }

    /// Arithmetic intensity in MACs per DRAM byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        let traffic = self.dram_read_bytes + self.dram_write_bytes;
        if traffic == 0 {
            return 0.0;
        }
        self.macs as f64 / traffic as f64
    }

    /// One-line summary for logs. Multi-target runs additionally show the
    /// overlapped makespan next to the serial cycle count.
    pub fn summary(&self) -> String {
        let overlapped = if self.overlapped_cycles > 0 {
            format!(" overlapped={}", commafy(self.overlapped_cycles))
        } else {
            String::new()
        };
        format!(
            "cycles={}{overlapped} (host {}) macs={} dram r/w={}/{} xfer={} staged-in={} \
             issued={}",
            commafy(self.cycles),
            commafy(self.host_cycles),
            commafy(self.macs),
            commafy(self.dram_read_bytes),
            commafy(self.dram_write_bytes),
            commafy(self.dram_transfer_cycles),
            commafy(self.input_stage_cycles),
            commafy(self.issued_commands),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_math() {
        let r = RunReport { cycles: 1000, macs: 128_000, ..Default::default() };
        // 128k MACs over 1000 cycles on a 16x16 array = 0.5 utilization.
        assert!((r.utilization(16) - 0.5).abs() < 1e-12);
        assert_eq!(RunReport::default().utilization(16), 0.0);
    }

    #[test]
    fn merge_extends_prefix_only_before_accel_work() {
        // Leading all-host segment + segment with its own prefix: the
        // combined prefix spans both.
        let mut lead = RunReport {
            cycles: 50,
            host_cycles: 50,
            host_prefix_cycles: 50,
            ..Default::default()
        };
        let tail = RunReport {
            cycles: 200,
            host_cycles: 30,
            host_prefix_cycles: 20,
            issued_commands: 9,
            ..Default::default()
        };
        lead.merge(&tail);
        assert_eq!(lead.host_prefix_cycles, 70);
        assert_eq!(lead.cycles, 250);
        // Once accelerator work ran, later segments never extend it.
        let mut busy = RunReport {
            cycles: 100,
            host_prefix_cycles: 10,
            issued_commands: 4,
            ..Default::default()
        };
        busy.merge(&tail);
        assert_eq!(busy.host_prefix_cycles, 10);
    }

    #[test]
    fn merge_sums_dram_transfer_and_gates_input_staging() {
        // A leading segment that computed: later segments' input staging
        // must NOT extend the merged prefix, but transfer cycles sum.
        let mut busy = RunReport {
            cycles: 100,
            macs: 64,
            dram_transfer_cycles: 40,
            input_stage_cycles: 10,
            ..Default::default()
        };
        let tail = RunReport {
            cycles: 80,
            macs: 32,
            dram_transfer_cycles: 25,
            input_stage_cycles: 9,
            ..Default::default()
        };
        busy.merge(&tail);
        assert_eq!(busy.dram_transfer_cycles, 65);
        assert_eq!(busy.input_stage_cycles, 10, "staging after compute never extends");
        // A compute-free leading segment (e.g. all-host preprocessing)
        // does extend the staging prefix.
        let mut lead = RunReport {
            cycles: 30,
            input_stage_cycles: 5,
            ..Default::default()
        };
        lead.merge(&tail);
        assert_eq!(lead.input_stage_cycles, 14);
    }

    #[test]
    fn summary_shows_overlapped_only_when_set() {
        let plain = RunReport { cycles: 100, ..Default::default() };
        assert!(!plain.summary().contains("overlapped"), "{}", plain.summary());
        let multi = RunReport { cycles: 100, overlapped_cycles: 80, ..Default::default() };
        assert!(multi.summary().contains("overlapped=80"), "{}", multi.summary());
    }

    #[test]
    fn intensity_math() {
        let r = RunReport {
            macs: 4096,
            dram_read_bytes: 1024,
            dram_write_bytes: 1024,
            ..Default::default()
        };
        assert!((r.arithmetic_intensity() - 2.0).abs() < 1e-12);
    }
}
