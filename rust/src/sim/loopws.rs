//! `LOOP_WS` FSM expansion.
//!
//! Gemmini's hardware tiling loop takes a full `C[m×n] (+)= A[m×k]·B[k×n]`
//! problem and generates the mvin/preload/compute/mvout micro-op sequence
//! itself, double-buffering scratchpad and accumulator tiles. A single RoCC
//! command therefore replaces thousands of host-issued instructions — this
//! is the mechanism behind the C toolchain's "efficient loop instruction
//! invocation" (paper §4). The expansion below reproduces that schedule;
//! the micro-ops run through the same timing model as ordinary
//! instructions but with back-to-back issue.

use anyhow::{bail, ensure, Result};

use crate::arch::{ArchDesc, Dataflow};
use crate::isa::{Activation, Instr, LocalAddr};
use crate::util::ceil_div;

/// Expand a `LOOP_WS` macro instruction into micro-ops. `st_scale`/`st_act`
/// are the currently configured requantization parameters, which the FSM
/// preserves.
///
/// Scratchpad layout (rows): A tiles double-buffered at `[0, 2·DIM)`,
/// B tiles at `[2·DIM, 4·DIM)`. Accumulator tiles double-buffered at
/// `[0, 2·DIM)`.
pub fn expand(
    arch: &ArchDesc,
    st_scale: f32,
    st_act: Activation,
    insn: &Instr,
) -> Result<Vec<Instr>> {
    let Instr::LoopWs {
        a_dram,
        b_dram,
        c_dram,
        d_dram,
        m,
        n,
        k,
        a_stride,
        b_stride,
        c_stride,
    } = *insn
    else {
        bail!("expand() requires a LOOP_WS instruction");
    };
    ensure!(m > 0 && n > 0 && k > 0, "LOOP_WS with empty bounds");
    let dim = arch.pe_dim as u32;
    let ti = ceil_div(m as usize, dim as usize) as u32;
    let tj = ceil_div(n as usize, dim as usize) as u32;
    let tk = ceil_div(k as usize, dim as usize) as u32;

    // Resident-chunk layout (as in Gemmini's sp_tiled_matmul): the whole
    // A panel (ti×tk DIM-tiles) and B panel (tk×tj DIM-tiles) live in the
    // scratchpad for the duration of the call; each tile is loaded exactly
    // once (A when j0 == 0, B when i0 == 0). The caller (tiled_matmul_auto
    // / the C-toolchain baseline) chooses chunk sizes that fit.
    let spad_rows = {
        let lvl = arch
            .levels
            .iter()
            .find(|l| l.name == "Scratchpad")
            .ok_or_else(|| anyhow::anyhow!("arch has no Scratchpad level"))?;
        (lvl.size_bytes / arch.pe_dim) as u32
    };
    let a_rows = ti * tk * dim;
    let b_rows = tk * tj * dim;
    ensure!(
        a_rows + b_rows <= spad_rows,
        "LOOP_WS operands exceed scratchpad: {}+{} rows of {spad_rows} —          partition the problem (tiled_matmul_auto)",
        a_rows,
        b_rows
    );

    let mut out = Vec::with_capacity((ti * tj * (3 * tk + 2)) as usize + 4);
    out.push(Instr::ConfigEx { dataflow: Dataflow::WeightStationary });
    // Preserve the program-configured requantization; the FSM only fixes
    // the store stride to the C matrix row stride.
    out.push(Instr::ConfigSt { stride: c_stride, scale: st_scale, act: st_act });

    let a_slot = |i0: u32, k0: u32| LocalAddr::spad((i0 * tk + k0) * dim);
    let b_slot = |k0: u32, j0: u32| LocalAddr::spad(a_rows + (k0 * tj + j0) * dim);
    let acc_slot = |p: u32| p * dim;

    for i0 in 0..ti {
        let mc = (m - i0 * dim).min(dim) as u16;
        for j0 in 0..tj {
            let nc = (n - j0 * dim).min(dim) as u16;
            let acc_parity = (i0 * tj + j0) % 2;
            let dst_row = acc_slot(acc_parity);
            // Bias tile first: Gemmini's repeating-bias mode broadcasts
            // the [N] int32 vector into every row (DRAM stride 0).
            let mut has_init = false;
            if let Some(d) = d_dram {
                out.push(Instr::ConfigLd { stride: 0 });
                out.push(Instr::Mvin {
                    dram: d + j0 as u64 * dim as u64 * 4,
                    local: LocalAddr::acc(dst_row),
                    rows: mc,
                    cols: nc,
                });
                has_init = true;
            }
            for k0 in 0..tk {
                let kc = (k - k0 * dim).min(dim) as u16;
                // A tile: rows mc × cols kc at (i0, k0); loaded once.
                if j0 == 0 {
                    out.push(Instr::ConfigLd { stride: a_stride });
                    out.push(Instr::Mvin {
                        dram: a_dram
                            + (i0 as u64 * dim as u64) * a_stride as u64
                            + k0 as u64 * dim as u64,
                        local: a_slot(i0, k0),
                        rows: mc,
                        cols: kc,
                    });
                }
                // B tile: rows kc × cols nc at (k0, j0); loaded once.
                if i0 == 0 {
                    out.push(Instr::ConfigLd { stride: b_stride });
                    out.push(Instr::Mvin {
                        dram: b_dram
                            + (k0 as u64 * dim as u64) * b_stride as u64
                            + j0 as u64 * dim as u64,
                        local: b_slot(k0, j0),
                        rows: kc,
                        cols: nc,
                    });
                }
                let dst = if has_init || k0 > 0 {
                    LocalAddr::acc_accumulate(dst_row)
                } else {
                    LocalAddr::acc(dst_row)
                };
                out.push(Instr::Preload {
                    local: Some(b_slot(k0, j0)),
                    dst,
                    rows: kc,
                    cols: nc,
                });
                out.push(Instr::Compute {
                    a: a_slot(i0, k0),
                    d: None,
                    rows: mc,
                    cols: kc,
                    preloaded: true,
                });
            }
            out.push(Instr::Mvout {
                dram: c_dram + (i0 as u64 * dim as u64) * c_stride as u64 + j0 as u64 * dim as u64,
                local: LocalAddr::acc(dst_row),
                rows: mc,
                cols: nc,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::Program;
    use crate::isa::Activation;
    use crate::sim::memory::Dram;
    use crate::sim::Simulator;
    use crate::util::prng::Rng;

    /// Reference int8 GEMM with requantization, mirroring the simulator's
    /// semantics (bias is a broadcast [n] vector, as in Gemmini's
    /// repeating-bias mode).
    fn ref_gemm(
        a: &[i8],
        b: &[i8],
        bias: Option<&[i32]>,
        m: usize,
        k: usize,
        n: usize,
        scale: f32,
    ) -> Vec<i8> {
        let mut out = vec![0i8; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = bias.map_or(0, |d| d[j]);
                for kk in 0..k {
                    s += a[i * k + kk] as i32 * b[kk * n + j] as i32;
                }
                out[i * n + j] = crate::sim::requantize(s, scale, Activation::None);
            }
        }
        out
    }

    fn run_loop_ws(m: usize, k: usize, n: usize, bias: bool, seed: u64) {
        let arch = ArchDesc::gemmini();
        let sim = Simulator::new(&arch);
        let mut rng = Rng::new(seed);
        let a: Vec<i8> = rng.i8_vec(m * k);
        let b: Vec<i8> = rng.i8_vec(k * n);
        let d: Vec<i32> = (0..n).map(|_| rng.below(200) as i32 - 100).collect();
        let scale = 0.03125f32;

        let mut prog = Program::new("loop_ws_test");
        let ra = prog.layout.alloc("a", (m * k) as u64).unwrap().offset;
        let rb = prog.layout.alloc("b", (k * n) as u64).unwrap().offset;
        let rc = prog.layout.alloc("c", (m * n) as u64).unwrap().offset;
        let rd = prog.layout.alloc("d", (n * 4) as u64).unwrap().offset;
        let mut dram = Dram::new(prog.layout.total_bytes() as usize + 64);
        dram.write_i8_slice(ra, &a).unwrap();
        dram.write_i8_slice(rb, &b).unwrap();
        dram.write_i32_slice(rd, &d).unwrap();

        prog.push(Instr::ConfigSt { stride: n as u32, scale, act: Activation::None });
        prog.push(Instr::LoopWs {
            a_dram: ra,
            b_dram: rb,
            c_dram: rc,
            d_dram: bias.then_some(rd),
            m: m as u32,
            n: n as u32,
            k: k as u32,
            a_stride: k as u32,
            b_stride: n as u32,
            c_stride: n as u32,
        });
        prog.push(Instr::Fence);
        let rep = sim.run(&prog, &mut dram).unwrap();

        let got = dram.read_i8_slice(rc, m * n).unwrap();
        let want = ref_gemm(&a, &b, bias.then_some(&d).map(|v| &v[..]), m, k, n, scale);
        assert_eq!(got, want, "loop_ws {m}x{k}x{n} bias={bias}");
        assert_eq!(rep.macs, (m * k * n) as u64);
    }

    #[test]
    fn loop_ws_exact_square() {
        run_loop_ws(32, 32, 32, false, 1);
    }

    #[test]
    fn loop_ws_with_bias() {
        run_loop_ws(32, 16, 48, true, 2);
    }

    #[test]
    fn loop_ws_ragged_edges() {
        run_loop_ws(33, 17, 19, false, 3);
        run_loop_ws(7, 70, 5, true, 4);
        run_loop_ws(1, 640, 128, false, 5);
    }

    #[test]
    fn loop_ws_issue_efficiency() {
        // One LOOP_WS issues far fewer host commands than the equivalent
        // unrolled program would (that's its entire purpose).
        let arch = ArchDesc::gemmini();
        let sim = Simulator::new(&arch);
        let mut prog = Program::new("eff");
        let ra = prog.layout.alloc("a", 64 * 64).unwrap().offset;
        let rb = prog.layout.alloc("b", 64 * 64).unwrap().offset;
        let rc = prog.layout.alloc("c", 64 * 64).unwrap().offset;
        let mut dram = Dram::new(prog.layout.total_bytes() as usize + 64);
        prog.push(Instr::ConfigSt { stride: 64, scale: 1.0, act: Activation::None });
        prog.push(Instr::LoopWs {
            a_dram: ra,
            b_dram: rb,
            c_dram: rc,
            d_dram: None,
            m: 64,
            n: 64,
            k: 64,
            a_stride: 64,
            b_stride: 64,
            c_stride: 64,
        });
        prog.push(Instr::Fence);
        let rep = sim.run(&prog, &mut dram).unwrap();
        assert_eq!(rep.issued_commands, 3); // config_st + loop_ws + fence
        // Resident panels: each A and B DIM-tile loaded exactly once.
        assert_eq!(rep.insn_counts["mvin"] as usize, 2 * 4 * 4);
    }
}
