//! A compact Tensor-IR: loop nests over GEMM workloads with memory-staging
//! and tensorization nodes.
//!
//! The paper's backend does all scheduling "at the TIR level via the
//! Mapping Generator" (§3.3) — UMA bypasses TE scheduling, so loop
//! transformations (multi-level tiling, reordering), cache staging and
//! intrinsic rewriting all happen here. [`schedule`] provides the
//! primitives (`split`, `reorder`, `insert_stages`, `tensorize`,
//! `set_double_buffer`); [`crate::backend::codegen`] walks the scheduled
//! tree and emits accelerator instructions.

pub mod schedule;

use std::fmt;

use anyhow::{bail, ensure, Result};

use crate::isa::Activation;
use crate::workload::{Dim, Gemm, Operand};

/// Loop nesting level, mirroring the memory hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LoopLevel {
    /// Iterates on-chip tiles over DRAM-resident data (outermost).
    Dram,
    /// Iterates instruction tiles within an on-chip tile.
    OnChip,
    /// Iterates elements within an instruction tile (absorbed by
    /// tensorization).
    Insn,
}

/// One loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoopInfo {
    pub dim: Dim,
    pub level: LoopLevel,
    /// Trip count.
    pub extent: usize,
    /// Elements advanced per trip (tile size at this level).
    pub step: usize,
}

/// TIR nodes.
#[derive(Debug, Clone, PartialEq)]
pub enum TirNode {
    Loop { info: LoopInfo, body: Vec<TirNode> },
    /// Stage an operand tile into its on-chip memory (lowered to MVINs).
    /// `double_buffer` selects ping-pong slots.
    CacheRead { operand: Operand, double_buffer: bool },
    /// Load the bias vector into the accumulator tile (lowered to a
    /// broadcast MVIN).
    LoadBias,
    /// Write the finished output tile back to DRAM (lowered to MVOUTs with
    /// the fused requantize/activation).
    CacheWrite,
    /// A tensorized instruction-tile computation (PRELOAD + COMPUTE).
    Tensorize { intrinsic: String, tile: [usize; 3] },
    /// The unscheduled scalar GEMM body.
    GemmBody,
}

/// Quantization attributes fused into the output stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantAttrs {
    pub scale: f32,
    pub act: Activation,
}

/// A TIR function: one GEMM layer plus its loop nest.
#[derive(Debug, Clone, PartialEq)]
pub struct TirFunc {
    pub name: String,
    pub gemm: Gemm,
    pub quant: QuantAttrs,
    pub body: Vec<TirNode>,
}

impl TirFunc {
    /// The unscheduled form the strategy generator produces: a perfect
    /// `N, C, K` DRAM-level nest around the scalar body.
    pub fn unscheduled(name: impl Into<String>, gemm: Gemm, quant: QuantAttrs) -> TirFunc {
        let mk = |dim: Dim, inner: TirNode| TirNode::Loop {
            info: LoopInfo { dim, level: LoopLevel::Dram, extent: gemm.bound(dim), step: 1 },
            body: vec![inner],
        };
        let body = mk(Dim::N, mk(Dim::C, mk(Dim::K, TirNode::GemmBody)));
        TirFunc { name: name.into(), gemm, quant, body: vec![body] }
    }

    /// Collect the perfect loop chain (outermost first). Errors if the
    /// nest branches before its innermost loop.
    pub fn loop_chain(&self) -> Result<Vec<LoopInfo>> {
        let mut out = Vec::new();
        let mut cur: &[TirNode] = &self.body;
        loop {
            let loops: Vec<&TirNode> =
                cur.iter().filter(|n| matches!(n, TirNode::Loop { .. })).collect();
            match loops.len() {
                0 => break,
                1 => {
                    let TirNode::Loop { info, body } = loops[0] else { unreachable!() };
                    out.push(*info);
                    cur = body;
                }
                _ => bail!("loop nest branches (not a perfect nest)"),
            }
        }
        Ok(out)
    }

    /// Structural validation of a *scheduled* function (after
    /// `insert_stages` + `tensorize`):
    /// * per dim, levels nest Dram ⊃ OnChip (⊃ Insn, pre-tensorize);
    /// * tile chain per dim multiplies back to ≥ the bound;
    /// * the DRAM-level C loop (extent > 1) is the innermost DRAM loop
    ///   (outputs must finish in the accumulator — no int32 spills).
    pub fn validate(&self) -> Result<()> {
        let chain = self.loop_chain()?;
        for d in Dim::ALL {
            let levels: Vec<(LoopLevel, usize, usize)> = chain
                .iter()
                .filter(|l| l.dim == d)
                .map(|l| (l.level, l.extent, l.step))
                .collect();
            ensure!(!levels.is_empty(), "dim {d} has no loop");
            // Outer → inner must be strictly increasing level (Dram before
            // OnChip before Insn).
            for w in levels.windows(2) {
                ensure!(
                    w[0].0 < w[1].0,
                    "dim {d}: level {:?} nested inside {:?}",
                    w[1].0,
                    w[0].0
                );
            }
            // Tile chain covers the bound.
            let covered: usize = levels[0].1 * levels[0].2;
            ensure!(
                covered >= self.gemm.bound(d),
                "dim {d}: loops cover {covered} < bound {}",
                self.gemm.bound(d)
            );
            // step of an outer loop equals extent×step of the next level.
            for w in levels.windows(2) {
                ensure!(
                    w[0].2 == w[1].1 * w[1].2,
                    "dim {d}: step {} != inner extent x step {}",
                    w[0].2,
                    w[1].1 * w[1].2
                );
            }
        }
        // Once staged (CacheWrite present), the DRAM C loop must be the
        // innermost DRAM loop if it iterates: an output tile must finish in
        // the accumulator before the next one starts (no int32 spills).
        let staged = self.count(&|n| matches!(n, TirNode::CacheWrite)) > 0;
        if staged {
            let dram: Vec<&LoopInfo> =
                chain.iter().filter(|l| l.level == LoopLevel::Dram).collect();
            if let Some(cpos) = dram.iter().position(|l| l.dim == Dim::C) {
                let c_trips = dram[cpos].extent;
                if c_trips > 1 {
                    ensure!(
                        cpos == dram.len() - 1,
                        "DRAM C loop (extent {c_trips}) must be innermost among DRAM loops"
                    );
                }
            }
        }
        Ok(())
    }

    /// Count nodes matching a predicate (diagnostics/tests).
    pub fn count(&self, pred: &dyn Fn(&TirNode) -> bool) -> usize {
        fn walk(nodes: &[TirNode], pred: &dyn Fn(&TirNode) -> bool, acc: &mut usize) {
            for n in nodes {
                if pred(n) {
                    *acc += 1;
                }
                if let TirNode::Loop { body, .. } = n {
                    walk(body, pred, acc);
                }
            }
        }
        let mut acc = 0;
        walk(&self.body, pred, &mut acc);
        acc
    }

    /// TVMScript-style pretty printer.
    pub fn script(&self) -> String {
        fn emit(nodes: &[TirNode], indent: usize, out: &mut String) {
            let pad = "  ".repeat(indent);
            for n in nodes {
                match n {
                    TirNode::Loop { info, body } => {
                        let lvl = match info.level {
                            LoopLevel::Dram => "dram",
                            LoopLevel::OnChip => "onchip",
                            LoopLevel::Insn => "insn",
                        };
                        out.push_str(&format!(
                            "{pad}for {}_{} in range({}):  # step {} [{}]\n",
                            info.dim.to_string().to_lowercase(),
                            lvl,
                            info.extent,
                            info.step,
                            lvl
                        ));
                        emit(body, indent + 1, out);
                    }
                    TirNode::CacheRead { operand, double_buffer } => {
                        out.push_str(&format!(
                            "{pad}cache_read({operand}{})\n",
                            if *double_buffer { ", double_buffer" } else { "" }
                        ));
                    }
                    TirNode::LoadBias => out.push_str(&format!("{pad}load_bias()\n")),
                    TirNode::CacheWrite => out.push_str(&format!("{pad}cache_write()\n")),
                    TirNode::Tensorize { intrinsic, tile } => out.push_str(&format!(
                        "{pad}{intrinsic}(tile=({}, {}, {}))\n",
                        tile[0], tile[1], tile[2]
                    )),
                    TirNode::GemmBody => {
                        out.push_str(&format!("{pad}O[n,k] += In[n,c] * W[c,k]\n"))
                    }
                }
            }
        }
        let mut s = format!(
            "def {}(In: i8[{}x{}], W: i8[{}x{}], B: i32[{}]) -> i8[{}x{}]:\n",
            self.name,
            self.gemm.n,
            self.gemm.c,
            self.gemm.c,
            self.gemm.k,
            self.gemm.k,
            self.gemm.n,
            self.gemm.k
        );
        emit(&self.body, 1, &mut s);
        s
    }
}

impl fmt::Display for TirFunc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.script())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quant() -> QuantAttrs {
        QuantAttrs { scale: 0.5, act: Activation::None }
    }

    #[test]
    fn unscheduled_is_perfect_nest() {
        let f = TirFunc::unscheduled("l0", Gemm::new(8, 4, 2), quant());
        let chain = f.loop_chain().unwrap();
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[0].dim, Dim::N);
        assert_eq!(chain[0].extent, 8);
        assert!(chain.iter().all(|l| l.level == LoopLevel::Dram && l.step == 1));
        f.validate().unwrap();
    }

    #[test]
    fn script_renders() {
        let f = TirFunc::unscheduled("layer", Gemm::new(4, 4, 4), quant());
        let s = f.script();
        assert!(s.contains("def layer"));
        assert!(s.contains("O[n,k] += In[n,c] * W[c,k]"));
    }

    #[test]
    fn validate_rejects_uncovered_bound() {
        let mut f = TirFunc::unscheduled("bad", Gemm::new(8, 4, 2), quant());
        // Shrink the N loop so it no longer covers the bound.
        if let TirNode::Loop { info, .. } = &mut f.body[0] {
            info.extent = 4;
        }
        assert!(f.validate().is_err());
    }
}
