//! TIR schedule primitives.
//!
//! These are the transformations the Mapping Generator applies (paper
//! §3.3): multi-level tiling (`split`), loop reordering (`reorder`),
//! tensorization (`tensorize`, rewriting the instruction-tile nest into a
//! hardware-intrinsic call), memory staging (`insert_stages`) and
//! double-buffer annotation (`set_double_buffer`).
//!
//! Primitive order: `split`* → `reorder` → `tensorize` → `insert_stages`
//! (→ `set_double_buffer`); each step checks its preconditions.

use anyhow::{anyhow, bail, ensure, Result};

use crate::util::ceil_div;
use crate::workload::{Dim, Operand};

use super::{LoopInfo, LoopLevel, TirFunc, TirNode};

/// Extract the (perfect) chain and the leaf nodes under the innermost
/// loop. Errors if stages were already inserted.
fn chain_and_leaf(f: &TirFunc) -> Result<(Vec<LoopInfo>, Vec<TirNode>)> {
    let mut chain = Vec::new();
    let mut cur: &[TirNode] = &f.body;
    loop {
        let n_loops = cur.iter().filter(|n| matches!(n, TirNode::Loop { .. })).count();
        match n_loops {
            0 => return Ok((chain, cur.to_vec())),
            1 => {
                ensure!(
                    cur.len() == 1,
                    "stages already inserted; primitives that rebuild the nest must run first"
                );
                let TirNode::Loop { info, body } = &cur[0] else { unreachable!() };
                chain.push(*info);
                cur = body;
            }
            _ => bail!("loop nest branches"),
        }
    }
}

/// Rebuild a perfect nest from a chain and leaf nodes.
fn rebuild(f: &TirFunc, chain: &[LoopInfo], leaf: Vec<TirNode>) -> TirFunc {
    let mut body = leaf;
    for info in chain.iter().rev() {
        body = vec![TirNode::Loop { info: *info, body }];
    }
    TirFunc { name: f.name.clone(), gemm: f.gemm, quant: f.quant, body }
}

/// Multi-level tiling: split `dim`'s DRAM loop into a
/// DRAM → OnChip → Insn chain with the given tile sizes
/// (`onchip` elements per DRAM trip, `insn` per OnChip trip).
pub fn split(f: &TirFunc, dim: Dim, onchip: usize, insn: usize) -> Result<TirFunc> {
    ensure!(insn >= 1 && onchip >= insn, "bad split factors ({onchip}, {insn})");
    let (chain, leaf) = chain_and_leaf(f)?;
    let pos = chain
        .iter()
        .position(|l| l.dim == dim && l.level == LoopLevel::Dram && l.step == 1)
        .ok_or_else(|| anyhow!("dim {dim} has no unsplit DRAM loop"))?;
    let bound = chain[pos].extent;
    ensure!(onchip <= bound, "on-chip tile {onchip} exceeds bound {bound}");
    let mut new_chain = chain.clone();
    new_chain[pos] = LoopInfo {
        dim,
        level: LoopLevel::Dram,
        extent: ceil_div(bound, onchip),
        step: onchip,
    };
    new_chain.insert(
        pos + 1,
        LoopInfo { dim, level: LoopLevel::OnChip, extent: ceil_div(onchip, insn), step: insn },
    );
    new_chain.insert(
        pos + 2,
        LoopInfo { dim, level: LoopLevel::Insn, extent: insn, step: 1 },
    );
    Ok(rebuild(f, &new_chain, leaf))
}

/// Reorder the nest to the given total order of `(dim, level)` pairs.
/// Every loop in the nest must appear exactly once.
pub fn reorder(f: &TirFunc, order: &[(Dim, LoopLevel)]) -> Result<TirFunc> {
    let (chain, leaf) = chain_and_leaf(f)?;
    ensure!(
        order.len() == chain.len(),
        "reorder lists {} loops, nest has {}",
        order.len(),
        chain.len()
    );
    let mut new_chain = Vec::with_capacity(chain.len());
    for &(d, lv) in order {
        let info = chain
            .iter()
            .find(|l| l.dim == d && l.level == lv)
            .ok_or_else(|| anyhow!("no loop ({d}, {lv:?}) in nest"))?;
        new_chain.push(*info);
    }
    // No duplicates (find-based lookup would silently alias).
    for i in 0..order.len() {
        for j in i + 1..order.len() {
            ensure!(order[i] != order[j], "duplicate loop {:?}", order[i]);
        }
    }
    Ok(rebuild(f, &new_chain, leaf))
}

/// Tensorize: replace the three innermost `Insn` loops (and the scalar
/// body) with a hardware-intrinsic call. The loops must be innermost and
/// their extents become the intrinsic tile (checked against `max_tile`,
/// the Eq. (1) instruction limit).
pub fn tensorize(f: &TirFunc, intrinsic: &str, max_tile: usize) -> Result<TirFunc> {
    let (chain, leaf) = chain_and_leaf(f)?;
    ensure!(
        leaf.iter().any(|n| matches!(n, TirNode::GemmBody)),
        "nothing to tensorize (body already rewritten?)"
    );
    let n_insn = chain.iter().filter(|l| l.level == LoopLevel::Insn).count();
    ensure!(n_insn == 3, "expect 3 Insn loops (run split first), found {n_insn}");
    let split_at = chain.len() - 3;
    let (outer, inner) = chain.split_at(split_at);
    ensure!(
        inner.iter().all(|l| l.level == LoopLevel::Insn),
        "Insn loops must be innermost before tensorize"
    );
    let mut tile = [0usize; 3];
    for l in inner {
        ensure!(
            l.extent <= max_tile,
            "insn loop {} extent {} exceeds instruction limit {max_tile} (Eq. 1)",
            l.dim,
            l.extent
        );
        tile[l.dim.index()] = l.extent;
    }
    let leaf = vec![TirNode::Tensorize { intrinsic: intrinsic.to_string(), tile }];
    Ok(rebuild(f, outer, leaf))
}

/// Insert memory staging at canonical positions:
///
/// ```text
/// for dram₀ { for dram₁ {
///     load_bias()
///     for dramC {            # innermost DRAM loop (C)
///         cache_read(Input); cache_read(Weight)
///         <onchip loops ... tensorize>
///     }
///     cache_write()
/// } }
/// ```
///
/// Requires the DRAM loops outermost with C innermost among them (the
/// canonical form the mapping generator produces).
pub fn insert_stages(f: &TirFunc, double_buffer: bool) -> Result<TirFunc> {
    let (chain, leaf) = chain_and_leaf(f)?;
    let dram: Vec<&LoopInfo> = chain.iter().filter(|l| l.level == LoopLevel::Dram).collect();
    ensure!(dram.len() == 3, "expect 3 DRAM loops, found {}", dram.len());
    ensure!(
        chain[..3].iter().all(|l| l.level == LoopLevel::Dram),
        "DRAM loops must be outermost"
    );
    ensure!(
        chain[2].dim == Dim::C,
        "DRAM C loop must be innermost among DRAM loops (got {})",
        chain[2].dim
    );

    // Innermost part: on-chip (and possibly insn) loops + leaf.
    let mut inner = leaf;
    for info in chain[3..].iter().rev() {
        inner = vec![TirNode::Loop { info: *info, body: inner }];
    }
    // C-loop body: cache reads then the compute nest.
    let mut c_body = vec![
        TirNode::CacheRead { operand: Operand::Input, double_buffer },
        TirNode::CacheRead { operand: Operand::Weight, double_buffer },
    ];
    c_body.extend(inner);
    let c_loop = TirNode::Loop { info: *dram[2], body: c_body };
    // dram₁ body: bias, C loop, writeback.
    let d1_body = vec![TirNode::LoadBias, c_loop, TirNode::CacheWrite];
    let d1 = TirNode::Loop { info: *dram[1], body: d1_body };
    let d0 = TirNode::Loop { info: *dram[0], body: vec![d1] };
    let out = TirFunc { name: f.name.clone(), gemm: f.gemm, quant: f.quant, body: vec![d0] };
    out.validate()?;
    Ok(out)
}

/// Toggle double-buffer annotations on all cache reads (post-staging).
pub fn set_double_buffer(f: &mut TirFunc, value: bool) {
    fn walk(nodes: &mut [TirNode], value: bool) {
        for n in nodes {
            match n {
                TirNode::CacheRead { double_buffer, .. } => *double_buffer = value,
                TirNode::Loop { body, .. } => walk(body, value),
                _ => {}
            }
        }
    }
    walk(&mut f.body, value);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;
    use crate::tir::QuantAttrs;
    use crate::workload::Gemm;

    fn base(n: usize, c: usize, k: usize) -> TirFunc {
        TirFunc::unscheduled(
            "t",
            Gemm::new(n, c, k),
            QuantAttrs { scale: 1.0, act: Activation::None },
        )
    }

    fn full_order() -> Vec<(Dim, LoopLevel)> {
        vec![
            (Dim::N, LoopLevel::Dram),
            (Dim::K, LoopLevel::Dram),
            (Dim::C, LoopLevel::Dram),
            (Dim::K, LoopLevel::OnChip),
            (Dim::C, LoopLevel::OnChip),
            (Dim::N, LoopLevel::OnChip),
            (Dim::N, LoopLevel::Insn),
            (Dim::C, LoopLevel::Insn),
            (Dim::K, LoopLevel::Insn),
        ]
    }

    fn scheduled() -> TirFunc {
        let f = base(64, 64, 64);
        let f = split(&f, Dim::N, 32, 16).unwrap();
        let f = split(&f, Dim::C, 32, 16).unwrap();
        let f = split(&f, Dim::K, 32, 16).unwrap();
        let f = reorder(&f, &full_order()).unwrap();
        let f = tensorize(&f, "gemmini_matmul", 16).unwrap();
        insert_stages(&f, true).unwrap()
    }

    #[test]
    fn split_produces_three_levels() {
        let f = split(&base(64, 64, 64), Dim::N, 32, 16).unwrap();
        let chain = f.loop_chain().unwrap();
        assert_eq!(chain.len(), 5);
        assert_eq!(chain[0], LoopInfo { dim: Dim::N, level: LoopLevel::Dram, extent: 2, step: 32 });
        assert_eq!(
            chain[1],
            LoopInfo { dim: Dim::N, level: LoopLevel::OnChip, extent: 2, step: 16 }
        );
        assert_eq!(chain[2], LoopInfo { dim: Dim::N, level: LoopLevel::Insn, extent: 16, step: 1 });
    }

    #[test]
    fn split_handles_ragged_bounds() {
        let f = split(&base(100, 64, 64), Dim::N, 48, 16).unwrap();
        let chain = f.loop_chain().unwrap();
        assert_eq!(chain[0].extent, 3); // ceil(100/48)
        f.validate().unwrap();
    }

    #[test]
    fn reorder_then_tensorize_and_stage() {
        let f = scheduled();
        f.validate().unwrap();
        assert_eq!(f.count(&|n| matches!(n, TirNode::Tensorize { .. })), 1);
        assert_eq!(f.count(&|n| matches!(n, TirNode::CacheRead { .. })), 2);
        assert_eq!(f.count(&|n| matches!(n, TirNode::LoadBias)), 1);
        assert_eq!(f.count(&|n| matches!(n, TirNode::CacheWrite)), 1);
        assert_eq!(f.count(&|n| matches!(n, TirNode::GemmBody)), 0);
        let s = f.script();
        assert!(s.contains("gemmini_matmul(tile=(16, 16, 16))"));
        assert!(s.contains("double_buffer"));
    }

    #[test]
    fn tensorize_enforces_eq1() {
        let f = base(64, 64, 64);
        let f = split(&f, Dim::N, 64, 32).unwrap(); // insn tile 32 > 16
        let f = split(&f, Dim::C, 32, 16).unwrap();
        let f = split(&f, Dim::K, 32, 16).unwrap();
        let f = reorder(&f, &full_order()).unwrap();
        assert!(tensorize(&f, "gemmini_matmul", 16).is_err());
    }

    #[test]
    fn reorder_rejects_missing_or_duplicate() {
        let f = split(&base(64, 64, 64), Dim::N, 32, 16).unwrap();
        assert!(reorder(&f, &[(Dim::N, LoopLevel::Dram)]).is_err());
        let f2 = base(8, 8, 8);
        assert!(reorder(
            &f2,
            &[(Dim::N, LoopLevel::Dram), (Dim::N, LoopLevel::Dram), (Dim::C, LoopLevel::Dram)]
        )
        .is_err());
    }

    #[test]
    fn insert_stages_requires_c_innermost() {
        let f = base(64, 64, 64);
        let f = split(&f, Dim::N, 32, 16).unwrap();
        let f = split(&f, Dim::C, 32, 16).unwrap();
        let f = split(&f, Dim::K, 32, 16).unwrap();
        let bad_order = vec![
            (Dim::C, LoopLevel::Dram),
            (Dim::N, LoopLevel::Dram),
            (Dim::K, LoopLevel::Dram),
            (Dim::K, LoopLevel::OnChip),
            (Dim::C, LoopLevel::OnChip),
            (Dim::N, LoopLevel::OnChip),
            (Dim::N, LoopLevel::Insn),
            (Dim::C, LoopLevel::Insn),
            (Dim::K, LoopLevel::Insn),
        ];
        let f = reorder(&f, &bad_order).unwrap();
        let f = tensorize(&f, "gemmini_matmul", 16).unwrap();
        assert!(insert_stages(&f, false).is_err());
    }

    #[test]
    fn double_buffer_toggle() {
        let mut f = scheduled();
        set_double_buffer(&mut f, false);
        assert_eq!(
            f.count(&|n| matches!(n, TirNode::CacheRead { double_buffer: true, .. })),
            0
        );
        set_double_buffer(&mut f, true);
        assert_eq!(
            f.count(&|n| matches!(n, TirNode::CacheRead { double_buffer: true, .. })),
            2
        );
    }

    #[test]
    fn primitives_after_staging_are_rejected() {
        let f = scheduled();
        assert!(split(&f, Dim::N, 16, 16).is_err());
        assert!(reorder(&f, &full_order()).is_err());
    }
}
