//! Architectural description of a GEMM-based accelerator (paper §3.2).
//!
//! This is the second half of the accelerator model: where the *functional*
//! description ([`crate::accel`]) says what operators and intrinsics exist,
//! the architectural description gives the scheduler what it needs —
//! hardware organization (compute/storage topology) and hardware
//! constraints (limits on the set of valid mappings) — in the same shape as
//! CoSA's YAML inputs.

pub mod parse;

use std::fmt;

use crate::workload::{Dim, Operand};

/// Dataflow of the spatial array (paper Fig. 2a).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weights resident in the PE array; spatial dims C (rows) × K (cols),
    /// N streamed temporally.
    WeightStationary,
    /// Outputs resident in the PE array; spatial dims N (rows) × K (cols),
    /// C streamed temporally (accumulation in place).
    OutputStationary,
}

impl Dataflow {
    /// The two GEMM dims mapped spatially onto the (rows, cols) of the
    /// PE array under this dataflow.
    pub fn spatial_dims(self) -> [Dim; 2] {
        match self {
            Dataflow::WeightStationary => [Dim::C, Dim::K],
            Dataflow::OutputStationary => [Dim::N, Dim::K],
        }
    }

    /// The dim streamed temporally through the array (the innermost
    /// temporal loop at the array level).
    pub fn streamed_dim(self) -> Dim {
        match self {
            Dataflow::WeightStationary => Dim::N,
            Dataflow::OutputStationary => Dim::C,
        }
    }

    /// The operand held stationary in the PEs.
    pub fn stationary_operand(self) -> Operand {
        match self {
            Dataflow::WeightStationary => Operand::Weight,
            Dataflow::OutputStationary => Operand::Output,
        }
    }

    pub fn parse(s: &str) -> Option<Dataflow> {
        match s {
            "WS" | "ws" | "weight_stationary" | "WeightStationary" => {
                Some(Dataflow::WeightStationary)
            }
            "OS" | "os" | "output_stationary" | "OutputStationary" => {
                Some(Dataflow::OutputStationary)
            }
            _ => None,
        }
    }
}

impl fmt::Display for Dataflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dataflow::WeightStationary => write!(f, "WS"),
            Dataflow::OutputStationary => write!(f, "OS"),
        }
    }
}

/// Kind of a memory level in the hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LevelKind {
    /// The PE array itself (registers inside the systolic array).
    PeArray,
    /// A software-managed on-chip buffer (scratchpad / accumulator).
    OnChip,
    /// External DRAM (unbounded for scheduling purposes).
    Dram,
}

/// One level of the memory hierarchy, innermost first.
#[derive(Debug, Clone)]
pub struct MemLevel {
    pub name: String,
    pub kind: LevelKind,
    /// Capacity in bytes; ignored for `Dram`.
    pub size_bytes: usize,
    /// Which operands may reside at this level (CoSA's memory-level
    /// skipping: e.g. Gemmini's accumulator holds only outputs).
    pub residents: Vec<Operand>,
    /// Bytes per element for each operand stored here, indexed by
    /// `Operand::index()` (Gemmini: int8 in scratchpad, int32 in
    /// accumulator).
    pub elem_bytes: [usize; 3],
}

impl MemLevel {
    pub fn holds(&self, op: Operand) -> bool {
        self.residents.contains(&op)
    }
}

/// DMA / memory-system timing parameters used by the simulator and by the
/// scheduler's traffic model.
#[derive(Debug, Clone, Copy)]
pub struct DmaParams {
    /// Sustained bus width between DRAM and on-chip memories.
    pub bytes_per_cycle: usize,
    /// Fixed request latency per DMA transfer (command + memory latency).
    pub request_latency: u64,
    /// Per-row overhead of a strided (2-D) transfer.
    pub per_row_overhead: u64,
}

/// Host CPU cost model: the paper's BYOC gap is dominated by host-side
/// preprocessing (transpose/quantize) that was not constant-folded; the
/// simulator charges these per-element costs for host-executed ops.
#[derive(Debug, Clone, Copy)]
pub struct HostParams {
    /// Cycles per scalar ALU op on the host (in accelerator clock cycles).
    pub cycles_per_elem_alu: u64,
    /// Cycles per element moved by the host (load+store path).
    pub cycles_per_elem_move: u64,
    /// Fixed cost of issuing one custom (RoCC-style) instruction.
    pub insn_issue_cycles: u64,
    /// Cost of a full fence (drain all accelerator queues).
    pub fence_cycles: u64,
}

/// Hardware constraints on valid mappings (paper Fig. 2a, Eq. 1).
#[derive(Debug, Clone)]
pub struct ArchConstraints {
    /// Eq. (1): at the PE-array level, spatial and temporal loop bounds per
    /// GEMM dim must not exceed `DIM` (a single compute instruction covers
    /// at most a DIM×DIM×DIM tile).
    pub insn_tile_limit: usize,
    /// Dims that may not be tiled spatially at the array (the remaining
    /// spatial freedom is already fixed by the dataflow).
    pub fixed_spatial: bool,
    /// Whether the accelerator supports double buffering of on-chip
    /// memories (halves usable capacity when enabled).
    pub supports_double_buffering: bool,
    /// Memory-share configurations to explore for uneven mapping:
    /// fractions of each on-chip level granted to (Input, Weight, Output).
    /// An empty list means even split among residents.
    pub memory_share_configs: Vec<[f64; 3]>,
}

/// Complete architectural description.
#[derive(Debug, Clone)]
pub struct ArchDesc {
    pub name: String,
    /// Side length of the square PE array.
    pub pe_dim: usize,
    /// Dataflows the accelerator can execute.
    pub dataflows: Vec<Dataflow>,
    /// Memory hierarchy, innermost (PE array) first, DRAM last.
    pub levels: Vec<MemLevel>,
    pub dma: DmaParams,
    pub host: HostParams,
    pub constraints: ArchConstraints,
}

impl ArchDesc {
    /// Index of the level with the given name.
    pub fn level_index(&self, name: &str) -> Option<usize> {
        self.levels.iter().position(|l| l.name == name)
    }

    /// The on-chip levels (between the PE array and DRAM), innermost first.
    pub fn onchip_levels(&self) -> impl Iterator<Item = (usize, &MemLevel)> {
        self.levels
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LevelKind::OnChip)
    }

    /// Innermost on-chip level holding `op` — the level the PE array reads
    /// `op` from.
    pub fn feed_level(&self, op: Operand) -> Option<usize> {
        self.levels
            .iter()
            .enumerate()
            .find(|(_, l)| l.kind == LevelKind::OnChip && l.holds(op))
            .map(|(i, _)| i)
    }

    /// Validate internal consistency; called after parsing.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::{bail, ensure};
        ensure!(self.pe_dim >= 1, "pe_dim must be >= 1");
        ensure!(!self.dataflows.is_empty(), "at least one dataflow required");
        ensure!(self.levels.len() >= 3, "need at least PE, one on-chip level, DRAM");
        if self.levels.first().map(|l| l.kind) != Some(LevelKind::PeArray) {
            bail!("innermost level must be the PE array");
        }
        if self.levels.last().map(|l| l.kind) != Some(LevelKind::Dram) {
            bail!("outermost level must be DRAM");
        }
        for op in Operand::ALL {
            if self.feed_level(op).is_none() {
                bail!("no on-chip level holds operand {op}");
            }
        }
        for shares in &self.constraints.memory_share_configs {
            ensure!(
                shares.iter().all(|&s| s > 0.0 && s <= 1.0),
                "memory shares must be in (0, 1]"
            );
            // Operands sharing the same on-chip level must fit together.
            for (_, level) in self.onchip_levels() {
                let sum: f64 = level.residents.iter().map(|&op| shares[op.index()]).sum();
                ensure!(
                    sum <= 1.0 + 1e-9,
                    "memory shares of {}'s residents sum to {sum} > 1",
                    level.name
                );
            }
        }
        ensure!(self.dma.bytes_per_cycle > 0, "dma.bytes_per_cycle must be > 0");
        ensure!(
            self.constraints.insn_tile_limit >= self.pe_dim,
            "instruction tile limit below PE dim is unschedulable"
        );
        Ok(())
    }

    /// The reference Gemmini-class configuration (defaults of the public
    /// Gemmini generator: 16×16 int8 array, 256 KiB scratchpad, 64 KiB
    /// int32 accumulator, WS-preferred).
    pub fn gemmini() -> ArchDesc {
        ArchDesc {
            name: "gemmini".into(),
            pe_dim: 16,
            dataflows: vec![Dataflow::WeightStationary, Dataflow::OutputStationary],
            levels: vec![
                MemLevel {
                    name: "PEArray".into(),
                    kind: LevelKind::PeArray,
                    size_bytes: 0,
                    residents: vec![Operand::Input, Operand::Weight, Operand::Output],
                    elem_bytes: [1, 1, 4],
                },
                MemLevel {
                    name: "Accumulator".into(),
                    kind: LevelKind::OnChip,
                    size_bytes: 64 * 1024,
                    residents: vec![Operand::Output],
                    elem_bytes: [1, 1, 4],
                },
                MemLevel {
                    name: "Scratchpad".into(),
                    kind: LevelKind::OnChip,
                    size_bytes: 256 * 1024,
                    residents: vec![Operand::Input, Operand::Weight],
                    elem_bytes: [1, 1, 4],
                },
                MemLevel {
                    name: "DRAM".into(),
                    kind: LevelKind::Dram,
                    size_bytes: usize::MAX,
                    residents: vec![Operand::Input, Operand::Weight, Operand::Output],
                    elem_bytes: [1, 1, 1],
                },
            ],
            dma: DmaParams { bytes_per_cycle: 16, request_latency: 40, per_row_overhead: 4 },
            host: HostParams {
                cycles_per_elem_alu: 4,
                cycles_per_elem_move: 2,
                insn_issue_cycles: 2,
                fence_cycles: 20,
            },
            constraints: ArchConstraints {
                insn_tile_limit: 16,
                fixed_spatial: true,
                supports_double_buffering: true,
                memory_share_configs: vec![
                    [0.5, 0.5, 1.0],
                    [0.25, 0.75, 1.0],
                    [0.75, 0.25, 1.0],
                ],
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemmini_is_valid() {
        ArchDesc::gemmini().validate().unwrap();
    }

    #[test]
    fn dataflow_spatial_dims() {
        assert_eq!(Dataflow::WeightStationary.spatial_dims(), [Dim::C, Dim::K]);
        assert_eq!(Dataflow::OutputStationary.spatial_dims(), [Dim::N, Dim::K]);
        assert_eq!(Dataflow::WeightStationary.streamed_dim(), Dim::N);
        assert_eq!(Dataflow::OutputStationary.streamed_dim(), Dim::C);
        // The streamed dim is never one of the spatial dims.
        for df in [Dataflow::WeightStationary, Dataflow::OutputStationary] {
            assert!(!df.spatial_dims().contains(&df.streamed_dim()));
            // The stationary operand depends on both spatial dims.
            let op = df.stationary_operand();
            for d in df.spatial_dims() {
                assert!(op.uses(d), "{df}: {op} should use {d}");
            }
        }
    }

    #[test]
    fn feed_levels() {
        let a = ArchDesc::gemmini();
        assert_eq!(a.feed_level(Operand::Output), Some(1)); // accumulator
        assert_eq!(a.feed_level(Operand::Input), Some(2)); // scratchpad
        assert_eq!(a.feed_level(Operand::Weight), Some(2));
    }

    #[test]
    fn validation_catches_bad_shares() {
        let mut a = ArchDesc::gemmini();
        a.constraints.memory_share_configs.push([0.9, 0.9, 0.9]);
        assert!(a.validate().is_err());
    }

    #[test]
    fn validation_requires_dram_last() {
        let mut a = ArchDesc::gemmini();
        a.levels.pop();
        assert!(a.validate().is_err());
    }

    #[test]
    fn dataflow_parse_roundtrip() {
        assert_eq!(Dataflow::parse("WS"), Some(Dataflow::WeightStationary));
        assert_eq!(Dataflow::parse("output_stationary"), Some(Dataflow::OutputStationary));
        assert_eq!(Dataflow::parse("nope"), None);
    }
}
