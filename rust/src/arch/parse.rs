//! YAML → [`ArchDesc`] parsing (the CoSA-style architectural input format,
//! paper §3.2: "YAML template files that specify (a) the hardware
//! organization ... and (b) hardware constraints").
//!
//! The PE-array and DRAM levels are implicit: users describe only the
//! on-chip buffers between them. See `configs/gemmini.yaml` for the
//! reference instance.

use anyhow::{anyhow, bail, Context, Result};

use super::{ArchConstraints, ArchDesc, Dataflow, DmaParams, HostParams, LevelKind, MemLevel};
use crate::util::yaml::{self, Yaml};
use crate::workload::Operand;

fn parse_operand(s: &str) -> Result<Operand> {
    match s {
        "Input" | "input" | "in" => Ok(Operand::Input),
        "Weight" | "weight" | "w" => Ok(Operand::Weight),
        "Output" | "output" | "out" => Ok(Operand::Output),
        other => bail!("unknown operand '{other}'"),
    }
}

fn parse_elem_bytes(v: &Yaml) -> Result<[usize; 3]> {
    let seq = v.as_seq()?;
    if seq.len() != 3 {
        bail!("elem_bytes must have 3 entries (Input, Weight, Output)");
    }
    Ok([seq[0].as_usize()?, seq[1].as_usize()?, seq[2].as_usize()?])
}

fn parse_shares(v: &Yaml) -> Result<[f64; 3]> {
    let seq = v.as_seq()?;
    if seq.len() != 3 {
        bail!("memory share entry must have 3 fractions (Input, Weight, Output)");
    }
    Ok([seq[0].as_f64()?, seq[1].as_f64()?, seq[2].as_f64()?])
}

/// Parse an architectural description from YAML text.
pub fn arch_from_yaml(src: &str) -> Result<ArchDesc> {
    let doc = yaml::parse(src)?;

    let name = doc.get("name")?.as_str()?.to_string();

    let pe = doc.get("pe_array").context("pe_array section")?;
    let pe_dim = pe.get("dim")?.as_usize()?;
    let mut dataflows = Vec::new();
    for d in pe.get("dataflows")?.as_seq()? {
        let s = d.as_str()?;
        dataflows.push(
            Dataflow::parse(s).ok_or_else(|| anyhow!("unknown dataflow '{s}'"))?,
        );
    }

    let mut levels = vec![MemLevel {
        name: "PEArray".into(),
        kind: LevelKind::PeArray,
        size_bytes: 0,
        residents: Operand::ALL.to_vec(),
        elem_bytes: [1, 1, 4],
    }];
    for lv in doc.get("memory").context("memory section")?.as_seq()? {
        let lname = lv.get("name")?.as_str()?.to_string();
        let size = lv.get("size")?.as_usize()?;
        let mut residents = Vec::new();
        for r in lv.get("residents")?.as_seq()? {
            residents.push(parse_operand(r.as_str()?)?);
        }
        let elem_bytes = match lv.get_opt("elem_bytes") {
            Some(v) => parse_elem_bytes(v)?,
            None => [1, 1, 4],
        };
        levels.push(MemLevel {
            name: lname,
            kind: LevelKind::OnChip,
            size_bytes: size,
            residents,
            elem_bytes,
        });
    }
    levels.push(MemLevel {
        name: "DRAM".into(),
        kind: LevelKind::Dram,
        size_bytes: usize::MAX,
        residents: Operand::ALL.to_vec(),
        elem_bytes: [1, 1, 1],
    });

    let dma_y = doc.get("dma").context("dma section")?;
    let dma = DmaParams {
        bytes_per_cycle: dma_y.get("bytes_per_cycle")?.as_usize()?,
        request_latency: dma_y.get("request_latency")?.as_usize()? as u64,
        per_row_overhead: dma_y.get("per_row_overhead")?.as_usize()? as u64,
    };

    let host_y = doc.get("host").context("host section")?;
    let host = HostParams {
        cycles_per_elem_alu: host_y.get("cycles_per_elem_alu")?.as_usize()? as u64,
        cycles_per_elem_move: host_y.get("cycles_per_elem_move")?.as_usize()? as u64,
        insn_issue_cycles: host_y.get("insn_issue_cycles")?.as_usize()? as u64,
        fence_cycles: host_y.get("fence_cycles")?.as_usize()? as u64,
    };

    let c = doc.get("constraints").context("constraints section")?;
    let mut memory_share_configs = Vec::new();
    if let Some(shares) = c.get_opt("memory_shares") {
        for entry in shares.as_seq()? {
            memory_share_configs.push(parse_shares(entry)?);
        }
    }
    let constraints = ArchConstraints {
        insn_tile_limit: c.get("insn_tile_limit")?.as_usize()?,
        fixed_spatial: c
            .get_opt("fixed_spatial")
            .map(|v| v.as_bool())
            .transpose()?
            .unwrap_or(true),
        supports_double_buffering: c
            .get_opt("double_buffering")
            .map(|v| v.as_bool())
            .transpose()?
            .unwrap_or(false),
        memory_share_configs,
    };

    let arch = ArchDesc { name, pe_dim, dataflows, levels, dma, host, constraints };
    arch.validate()?;
    Ok(arch)
}

/// Read the optional top-level `backend:` key of an accelerator config:
/// the registry id of the backend family that lowers for this target (see
/// [`crate::backend::lookup`]). Absent means `"gemmini"`, so existing
/// configs keep working unchanged.
pub fn backend_from_yaml(src: &str) -> Result<String> {
    let doc = yaml::parse(src)?;
    Ok(match doc.get_opt("backend") {
        Some(v) => v.as_str()?.to_string(),
        None => "gemmini".to_string(),
    })
}

/// Parse an architectural description from a YAML file.
pub fn arch_from_file(path: &std::path::Path) -> Result<ArchDesc> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    arch_from_yaml(&src).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const GEMMINI_YAML: &str = r#"
name: gemmini
pe_array:
  dim: 16
  dataflows: [WS, OS]
memory:
  - name: Accumulator
    size: 65536
    residents: [Output]
    elem_bytes: [1, 1, 4]
  - name: Scratchpad
    size: 262144
    residents: [Input, Weight]
dma:
  bytes_per_cycle: 16
  request_latency: 40
  per_row_overhead: 4
host:
  cycles_per_elem_alu: 4
  cycles_per_elem_move: 2
  insn_issue_cycles: 2
  fence_cycles: 20
constraints:
  insn_tile_limit: 16
  fixed_spatial: true
  double_buffering: true
  memory_shares:
    - [0.5, 0.5, 1.0]
    - [0.25, 0.75, 1.0]
"#;

    #[test]
    fn parses_gemmini_yaml() {
        let a = arch_from_yaml(GEMMINI_YAML).unwrap();
        assert_eq!(a.name, "gemmini");
        assert_eq!(a.pe_dim, 16);
        assert_eq!(a.dataflows.len(), 2);
        assert_eq!(a.levels.len(), 4); // PE + 2 on-chip + DRAM
        assert_eq!(a.levels[1].name, "Accumulator");
        assert_eq!(a.levels[1].size_bytes, 65536);
        assert!(a.constraints.supports_double_buffering);
        assert_eq!(a.constraints.memory_share_configs.len(), 2);
        assert_eq!(a.feed_level(Operand::Output), Some(1));
    }

    #[test]
    fn matches_builtin_gemmini() {
        // The YAML route and the programmatic default describe the same
        // machine (sizes / topology / limits).
        let y = arch_from_yaml(GEMMINI_YAML).unwrap();
        let b = ArchDesc::gemmini();
        assert_eq!(y.pe_dim, b.pe_dim);
        assert_eq!(y.levels.len(), b.levels.len());
        for (l1, l2) in y.levels.iter().zip(&b.levels) {
            assert_eq!(l1.name, l2.name);
            assert_eq!(l1.size_bytes, l2.size_bytes);
            assert_eq!(l1.residents, l2.residents);
        }
        assert_eq!(y.constraints.insn_tile_limit, b.constraints.insn_tile_limit);
    }

    #[test]
    fn backend_key_defaults_to_gemmini() {
        assert_eq!(backend_from_yaml(GEMMINI_YAML).unwrap(), "gemmini");
        let tagged = format!("backend: vector\n{GEMMINI_YAML}");
        assert_eq!(backend_from_yaml(&tagged).unwrap(), "vector");
    }

    #[test]
    fn rejects_unknown_dataflow() {
        let bad = GEMMINI_YAML.replace("[WS, OS]", "[XY]");
        assert!(arch_from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(arch_from_yaml("name: x\n").is_err());
    }

    #[test]
    fn shipped_config_file_parses() {
        // configs/gemmini.yaml is the canonical copy used by the CLI and
        // the examples; keep it in sync with the built-in default.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/gemmini.yaml");
        let a = arch_from_file(&path).unwrap();
        assert_eq!(a.name, "gemmini");
        assert_eq!(a.pe_dim, ArchDesc::gemmini().pe_dim);
    }
}
