//! Backend configurator (paper §3.3): the strategy generator, hardware
//! intrinsic generator, mapping generator and code generator that together
//! turn the accelerator description into a working compiler backend.

pub mod codegen;
pub mod intrin;
pub mod mapping;
pub mod strategy;
