//! Backend configurator (paper §3.3): the strategy generator, hardware
//! intrinsic generator, mapping generator and code generator that together
//! turn the accelerator description into a working compiler backend.
//!
//! Everything target-*family*-specific lives behind the [`Backend`] trait:
//! strategy binding, schedule search, schedule→TIR mapping, instruction
//! selection/codegen (including the cross-layer residency path), binary
//! encoding, and the timing hooks the simulator calls. The rest of the
//! pipeline — frontend, partitioner, scheduler cache, session/service
//! plumbing, fuzzing — is backend-agnostic and dispatches through the
//! registry ([`lookup`]), keyed by the `backend:` field of an accelerator
//! config (see [`crate::arch::parse::backend_from_yaml`]).
//!
//! Two families are registered:
//!
//! * [`GemminiBackend`] — the systolic-array reference target. Pure
//!   delegation to the module-level functions below, so programs are
//!   byte-identical to pre-trait output (golden-hash tested).
//! * [`vector::VectorBackend`] — a scalar/SIMD fallback engine with no
//!   systolic array and no software-managed scratchpad: strip-mined MAC
//!   loops streaming from DRAM, its own instruction encoding
//!   ([`crate::isa::vector_encode`]) and timing model.

pub mod codegen;
pub mod intrin;
pub mod mapping;
pub mod strategy;
pub mod vector;

use anyhow::{anyhow, Result};

use crate::accel::AccelDesc;
use crate::arch::ArchDesc;
use crate::isa::encode::{self, Word};
use crate::isa::program::Program;
use crate::isa::Instr;
use crate::relay::Node;
use crate::scheduler::graph::LayerResidency;
use crate::scheduler::sweep::{SweepOptions, SweepResult};
use crate::scheduler::Schedule;
use crate::tir::TirFunc;
use crate::workload::Gemm;

use codegen::LayerBufs;
use strategy::Strategy;

/// One target family's implementation of the compiler backend. Everything
/// here is dispatched per-accelerator via [`AccelDesc::backend_impl`]; a
/// new target family implements this trait (plus, if it introduces new
/// instructions, their simulator semantics) and registers itself in
/// [`lookup`] — partitioning, scheduling-cache, session, service and
/// fuzzing infrastructure come for free.
pub trait Backend: Sync {
    /// Registry id (the `backend:` value in accelerator configs).
    fn id(&self) -> &'static str;

    /// Build the full accelerator description for this family on a given
    /// architecture (the per-target analogue of the paper's user-written
    /// functional description).
    fn make_desc(&self, name: &str, arch: ArchDesc) -> Result<AccelDesc>;

    /// The family's shipped default description (its built-in reference
    /// architecture). The fuzz oracle and the CI backend matrix iterate
    /// the registry through this.
    fn default_desc(&self) -> Result<AccelDesc>;

    /// Bind a lowering strategy for one graph node. The default is the
    /// shared dense/GEMM binding; a family with different operator
    /// coverage overrides this.
    fn generate_strategy(
        &self,
        accel: &AccelDesc,
        node: &Node,
        input_shapes: &[Vec<usize>],
    ) -> Result<Strategy> {
        strategy::generate_strategy_typed(accel, node, input_shapes)
    }

    /// Run the schedule search for one GEMM workload on this family.
    fn sweep(&self, arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult;

    /// Apply a schedule to the unscheduled TIR function (tiling,
    /// reordering, tensorization, staging — or whatever the family's
    /// mapping looks like).
    fn apply_schedule(&self, accel: &AccelDesc, f: &TirFunc, s: &Schedule) -> Result<TirFunc>;

    /// Emit one layer's instruction stream (no cross-layer residency).
    fn generate(
        &self,
        accel: &AccelDesc,
        f: &TirFunc,
        s: &Schedule,
        bufs: &LayerBufs,
        prog: &mut Program,
    ) -> Result<()> {
        self.generate_resident(accel, f, s, bufs, &LayerResidency::default(), prog)
    }

    /// Emit one layer with cross-layer residency decisions. Families that
    /// return `false` from [`Backend::supports_residency`] are only ever
    /// called with the default (empty) residency.
    fn generate_resident(
        &self,
        accel: &AccelDesc,
        f: &TirFunc,
        s: &Schedule,
        bufs: &LayerBufs,
        resid: &LayerResidency,
        prog: &mut Program,
    ) -> Result<()>;

    /// Whether this family can keep activations resident on-chip across
    /// layer boundaries (drives the session's residency planner).
    fn supports_residency(&self) -> bool {
        false
    }

    /// Encode one instruction into command words. All families share the
    /// RoCC-style framing and disjoint funct ranges, so the default is the
    /// unified codec.
    fn encode(&self, i: &Instr) -> Vec<Word> {
        encode::encode(i)
    }

    /// Decode a command-word stream back into instructions.
    fn decode(&self, words: &[Word]) -> Result<Vec<Instr>> {
        encode::decode(words)
    }
}

/// The systolic-array reference family (Gemmini). Pure delegation to the
/// module-level strategy/mapping/codegen functions — programs are
/// byte-identical to direct calls (tested below and golden-hash tested in
/// `tests/golden_backend.rs`).
pub struct GemminiBackend;

impl Backend for GemminiBackend {
    fn id(&self) -> &'static str {
        "gemmini"
    }

    fn make_desc(&self, name: &str, arch: ArchDesc) -> Result<AccelDesc> {
        crate::accel::gemmini::desc_for_arch(name, arch)
    }

    fn default_desc(&self) -> Result<AccelDesc> {
        crate::accel::gemmini::gemmini_desc()
    }

    fn sweep(&self, arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
        crate::scheduler::sweep::sweep(arch, g, opts)
    }

    fn apply_schedule(&self, accel: &AccelDesc, f: &TirFunc, s: &Schedule) -> Result<TirFunc> {
        mapping::apply_schedule(accel, f, s)
    }

    fn generate_resident(
        &self,
        accel: &AccelDesc,
        f: &TirFunc,
        s: &Schedule,
        bufs: &LayerBufs,
        resid: &LayerResidency,
        prog: &mut Program,
    ) -> Result<()> {
        codegen::generate_resident(accel, f, s, bufs, resid, prog)
    }

    fn supports_residency(&self) -> bool {
        true
    }
}

/// The backend registry. Order is the display/iteration order of
/// [`backends`] (fuzzing and CI matrices iterate it).
static BACKENDS: [&dyn Backend; 2] = [&GemminiBackend, &vector::VectorBackend];

/// All registered backends, in registry order.
pub fn backends() -> impl Iterator<Item = &'static dyn Backend> {
    BACKENDS.iter().copied()
}

/// Registry ids of all registered backends, in registry order.
pub fn backend_ids() -> Vec<&'static str> {
    BACKENDS.iter().map(|b| b.id()).collect()
}

/// Resolve a backend by registry id (the `backend:` config value).
/// Unknown ids are a clean configuration error naming the known ids.
pub fn lookup(id: &str) -> Result<&'static dyn Backend> {
    BACKENDS.iter().copied().find(|b| b.id() == id).ok_or_else(|| {
        anyhow!(
            "unknown backend '{id}' — known backends: {}",
            backend_ids().join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::isa::Activation;
    use crate::scheduler::solver::{solve, SolverConfig};
    use crate::tir::{QuantAttrs, TirFunc};

    #[test]
    fn registry_resolves_known_ids() {
        assert_eq!(lookup("gemmini").unwrap().id(), "gemmini");
        assert_eq!(lookup("vector").unwrap().id(), "vector");
        assert_eq!(backend_ids(), vec!["gemmini", "vector"]);
        assert_eq!(backends().count(), BACKENDS.len());
    }

    #[test]
    fn unknown_backend_is_clean_config_error() {
        let err = lookup("npu9000").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'npu9000'"), "{err}");
        assert!(err.contains("gemmini"), "{err}");
        assert!(err.contains("vector"), "{err}");
    }

    #[test]
    fn gemmini_make_desc_matches_direct_path() {
        let via_trait = lookup("gemmini").unwrap().make_desc("gemmini", crate::arch::ArchDesc::gemmini()).unwrap();
        let direct = gemmini_desc().unwrap();
        assert_eq!(via_trait.functional_repr(), direct.functional_repr());
        assert_eq!(via_trait.backend, "gemmini");
    }

    /// The tentpole safety property, in miniature: routing Gemmini through
    /// the trait emits the exact same program as calling the module
    /// functions directly (the full-model version is the golden-hash test).
    #[test]
    fn trait_dispatch_is_byte_identical_for_gemmini() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(48, 40, 24);
        let cfg = SolverConfig::new(crate::arch::Dataflow::WeightStationary);
        let s = &solve(&accel.arch, g, &cfg)[0];
        let f = TirFunc::unscheduled(
            "layer",
            g,
            QuantAttrs { scale: 0.25, act: Activation::Relu },
        );
        let bufs = LayerBufs { x: 0, w: 4096, bias: 8192, out: 12288 };

        let direct_f = mapping::apply_schedule(&accel, &f, s).unwrap();
        let mut direct = Program::new("direct");
        codegen::generate(&accel, &direct_f, s, &bufs, &mut direct).unwrap();

        let b = lookup("gemmini").unwrap();
        let trait_f = b.apply_schedule(&accel, &f, s).unwrap();
        let mut via = Program::new("via");
        b.generate(&accel, &trait_f, s, &bufs, &mut via).unwrap();

        assert_eq!(direct.disassemble(), via.disassemble());
        let enc = |p: &Program| -> Vec<Word> {
            p.items
                .iter()
                .filter_map(|it| match it {
                    crate::isa::program::Item::Accel(i) => Some(b.encode(i)),
                    _ => None,
                })
                .flatten()
                .collect()
        };
        assert_eq!(enc(&direct), enc(&via));
    }
}
