//! The vector fallback backend: a genuinely different target family that
//! proves the [`super::Backend`] trait generalizes past systolic arrays.
//!
//! The modeled engine is an in-order scalar/SIMD core with `pe_dim` MAC
//! lanes and a single accumulator register file — no systolic array, no
//! software-managed scratchpad, no decoupled load/store queues. Operands
//! stream from DRAM on every use, so there is nothing to tile for reuse:
//! the code generator emits one strip-mined MAC loop nest directly from
//! the GEMM shape ([`Instr::VmacStrip`] and friends, encoded by
//! [`crate::isa::vector_encode`]), and the "schedule" is a single
//! degenerate candidate whose tiles are bookkeeping only.
//!
//! What the family still inherits for free by implementing the trait:
//! graph partitioning, the schedule cache (keyed by the description
//! fingerprint, which includes the backend id), session/service plumbing,
//! multi-target linking, and every fuzzing axis. Weights use the same
//! transposed `[C,K]` DRAM layout as the Gemmini family
//! ([`Preprocessing::WeightTranspose`]) so gemmini+vector multi-target
//! deployments share one constant layout.

use anyhow::{ensure, Result};

use crate::accel::{
    AccelDesc, ComputeArgs, ConfigArgs, CoreCompute, HwIntrinsic, MemArgs, Preprocessing,
};
use crate::arch::{ArchConstraints, ArchDesc, Dataflow, DmaParams, HostParams};
use crate::isa::program::Program;
use crate::isa::{Instr, LocalAddr};
use crate::scheduler::graph::LayerResidency;
use crate::scheduler::solver::SearchStats;
use crate::scheduler::sweep::{SweepOptions, SweepResult};
use crate::scheduler::{Estimate, Schedule};
use crate::tir::TirFunc;
use crate::workload::{Dim, Gemm};

use super::codegen::LayerBufs;
use super::Backend;

/// Longest reduction strip a single `VMAC_STRIP` covers (`n_in` is u16;
/// this keeps strips well inside it and bounds per-instruction occupancy).
pub const STRIP_MAX: usize = 4096;

/// The built-in default vector architecture (`configs/vector.yaml` mirrors
/// it): 8 MAC lanes, a narrow DMA, no double buffering. The on-chip level
/// sizes exist to satisfy the shared architecture contract (the simulator
/// allocates its scratchpad/accumulator from them) but the vector code
/// generator never addresses them.
pub fn vector_arch() -> ArchDesc {
    use crate::arch::{LevelKind, MemLevel};
    use crate::workload::Operand;
    ArchDesc {
        name: "vector".into(),
        pe_dim: 8,
        dataflows: vec![Dataflow::WeightStationary],
        levels: vec![
            MemLevel {
                name: "PEArray".into(),
                kind: LevelKind::PeArray,
                size_bytes: 0,
                residents: vec![Operand::Input, Operand::Weight, Operand::Output],
                elem_bytes: [1, 1, 4],
            },
            MemLevel {
                name: "Accumulator".into(),
                kind: LevelKind::OnChip,
                size_bytes: 4 * 1024,
                residents: vec![Operand::Output],
                elem_bytes: [1, 1, 4],
            },
            MemLevel {
                name: "Scratchpad".into(),
                kind: LevelKind::OnChip,
                size_bytes: 16 * 1024,
                residents: vec![Operand::Input, Operand::Weight],
                elem_bytes: [1, 1, 4],
            },
            MemLevel {
                name: "DRAM".into(),
                kind: LevelKind::Dram,
                size_bytes: usize::MAX,
                residents: vec![Operand::Input, Operand::Weight, Operand::Output],
                elem_bytes: [1, 1, 1],
            },
        ],
        dma: DmaParams { bytes_per_cycle: 8, request_latency: 40, per_row_overhead: 2 },
        host: HostParams {
            cycles_per_elem_alu: 4,
            cycles_per_elem_move: 2,
            insn_issue_cycles: 2,
            fence_cycles: 20,
        },
        constraints: ArchConstraints {
            insn_tile_limit: 8,
            fixed_spatial: true,
            supports_double_buffering: false,
            memory_share_configs: vec![],
        },
    }
}

/// Convenience: the full vector description on the default architecture.
pub fn vector_desc() -> Result<AccelDesc> {
    VectorBackend.make_desc("vector", vector_arch())
}

/// Config intrinsic: one `VCFG_REQ` sets the requant scale + activation
/// applied by every following `VST_OUT`. The vector engine has no store
/// pipeline stride (stores are contiguous runs), so `st_stride` and
/// `dataflow` are ignored.
fn vcfg(args: &ConfigArgs) -> Vec<Instr> {
    vec![Instr::VcfgReq { scale: args.scale, act: args.act }]
}

/// Memory-load intrinsic: `cols` int32 bias words into the accumulator
/// file (the only load the engine issues — activations and weights stream
/// inside `VMAC_STRIP`).
fn vld_bias(args: &MemArgs) -> Vec<Instr> {
    vec![Instr::VldBias { dram: args.dram, len: args.cols }]
}

/// Memory-store intrinsic: requantize + store `cols` accumulator lanes.
fn vst_out(args: &MemArgs) -> Vec<Instr> {
    vec![Instr::VstOut { dram: args.dram, len: args.cols }]
}

/// Compute-role binding. Never called: `ComputeArgs` carries on-chip tile
/// addresses, but the vector engine's MAC operands are DRAM addresses, so
/// [`generate_layer`] emits [`Instr::VmacStrip`] directly. Registered only
/// to satisfy the description's four-role contract
/// (`AccelDesc::validate`).
fn vmac_unused(_args: &ComputeArgs) -> Vec<Instr> {
    Vec::new()
}

/// Emit one dense layer for the vector engine: for every batch row, for
/// every lane-wide block of output columns, load the bias block, stream
/// the reduction in `STRIP_MAX` chunks, then requantize + store. Ragged
/// edges fall out of the `min`s.
fn generate_layer(
    accel: &AccelDesc,
    f: &TirFunc,
    s: &Schedule,
    bufs: &LayerBufs,
    prog: &mut Program,
) -> Result<()> {
    ensure!(f.gemm == s.workload, "schedule/function workload mismatch");
    let g = f.gemm;
    let lanes = accel.arch.pe_dim;
    for i in accel.emit_config(&ConfigArgs {
        dataflow: s.dataflow,
        st_stride: g.k as u32,
        scale: f.quant.scale,
        act: f.quant.act,
    })? {
        prog.push(i);
    }
    for n in 0..g.n {
        let mut kb = 0;
        while kb < g.k {
            let kl = lanes.min(g.k - kb);
            for i in accel.emit_mem(
                &accel.load_intrinsic,
                &MemArgs {
                    dram: bufs.bias + 4 * kb as u64,
                    local: LocalAddr::acc(0),
                    rows: 1,
                    cols: kl as u16,
                    stride: 0,
                },
            )? {
                prog.push(i);
            }
            let mut cb = 0;
            while cb < g.c {
                let cl = STRIP_MAX.min(g.c - cb);
                prog.push(Instr::VmacStrip {
                    x_dram: bufs.x + (n * g.c + cb) as u64,
                    w_dram: bufs.w + (cb * g.k + kb) as u64,
                    w_stride: g.k as u32,
                    n_out: kl as u16,
                    n_in: cl as u16,
                });
                cb += cl;
            }
            for i in accel.emit_mem(
                &accel.store_intrinsic,
                &MemArgs {
                    dram: bufs.out + (n * g.k + kb) as u64,
                    local: LocalAddr::acc(0),
                    rows: 1,
                    cols: kl as u16,
                    stride: g.k as u32,
                },
            )? {
                prog.push(i);
            }
            kb += kl;
        }
    }
    Ok(())
}

/// The vector target family. See the module docs for the modeled engine.
pub struct VectorBackend;

impl Backend for VectorBackend {
    fn id(&self) -> &'static str {
        "vector"
    }

    fn default_desc(&self) -> Result<AccelDesc> {
        vector_desc()
    }

    fn make_desc(&self, name: &str, arch: ArchDesc) -> Result<AccelDesc> {
        AccelDesc::builder(name, arch)
            .backend("vector")
            // Same constant preprocessing as the Gemmini family: weights in
            // transposed [C,K] layout (VMAC_STRIP strides down a column),
            // convolutions via im2col. Multi-target deployments share one
            // DRAM constant layout because of this.
            .register_preprocessing("dense", Preprocessing::WeightTranspose)
            .register_preprocessing("conv2d", Preprocessing::Im2col)
            .register_core_compute(CoreCompute::quantized_gemm("dense"))
            .register_core_compute(CoreCompute::quantized_gemm("conv2d"))
            .register_hw_intrinsic(HwIntrinsic::compute("vector_mac", vmac_unused))
            .register_hw_intrinsic(HwIntrinsic::memory("vector_ld_bias", vld_bias))
            .register_hw_intrinsic(HwIntrinsic::memory("vector_st_out", vst_out))
            .register_hw_intrinsic(HwIntrinsic::config("vector_cfg", vcfg))
            .build()
    }

    /// A single degenerate candidate: the engine streams the whole
    /// workload, so there is no tiling space to search. The attached
    /// estimate is an honest analytic model (lane-limited compute vs DRAM
    /// streaming) so multi-target partitioning can rank vector layers
    /// before simulator profiling refines them.
    fn sweep(&self, arch: &ArchDesc, g: Gemm, _opts: &SweepOptions) -> SweepResult {
        let lanes = arch.pe_dim as f64;
        let compute = (g.n * g.c) as f64 * (g.k as f64 / lanes).ceil();
        // Per batch row: the x strip once per k-block, the full weight
        // matrix, and the bias blocks — no on-chip reuse at all.
        let k_blocks = (g.k as f64 / lanes).ceil();
        let bytes = [
            (g.n * g.c) as f64 * k_blocks,
            (g.n * (g.c * g.k + 4 * g.k)) as f64,
            (g.n * g.k) as f64,
        ];
        let dma = bytes.iter().sum::<f64>() / arch.dma.bytes_per_cycle as f64;
        let insns =
            g.n as f64 * k_blocks * (2.0 + (g.c as f64 / STRIP_MAX as f64).ceil()) + 1.0;
        let issue = insns * arch.host.insn_issue_cycles as f64;
        let est = Estimate {
            compute_cycles: compute,
            dma_cycles: dma,
            issue_cycles: issue,
            // Single in-order queue: compute and streaming do not overlap
            // across different resources, only within a strip (max).
            latency: compute.max(dma) + issue,
            bytes,
            utilization: (g.k as f64 / lanes).min(1.0),
        };
        let s = Schedule {
            workload: g,
            dataflow: Dataflow::WeightStationary,
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: [1, 1, 1],
            onchip_tile: [g.n, g.c, g.k],
            dram_order: [Dim::N, Dim::C, Dim::K],
            est,
        };
        SweepResult { candidates: vec![s], configs_explored: 1, stats: SearchStats::default() }
    }

    /// Identity: the vector code generator interprets the GEMM shape
    /// directly, so the unscheduled nest is already its input form.
    fn apply_schedule(&self, _accel: &AccelDesc, f: &TirFunc, s: &Schedule) -> Result<TirFunc> {
        ensure!(f.gemm == s.workload, "schedule/function workload mismatch");
        Ok(f.clone())
    }

    fn generate_resident(
        &self,
        accel: &AccelDesc,
        f: &TirFunc,
        s: &Schedule,
        bufs: &LayerBufs,
        resid: &LayerResidency,
        prog: &mut Program,
    ) -> Result<()> {
        ensure!(
            *resid == LayerResidency::default(),
            "vector backend has no on-chip residency"
        );
        generate_layer(accel, f, s, bufs, prog)
    }
}

/// Timing hooks the simulator calls for the vector instruction family.
/// Every latency is a function of shapes and the architecture only — never
/// of data — which the fuzz oracle's determinism axis relies on.
pub mod timing {
    use crate::arch::ArchDesc;
    use crate::util::ceil_div;

    fn dma(arch: &ArchDesc, rows: u64, bytes: u64) -> (u64, u64) {
        let occ = rows * arch.dma.per_row_overhead
            + ceil_div(bytes as usize, arch.dma.bytes_per_cycle) as u64;
        (arch.dma.request_latency + occ, occ)
    }

    /// `(latency, occupancy)` of a bias load: one burst of `4·len` bytes.
    pub fn ld_bias(arch: &ArchDesc, len: u16) -> (u64, u64) {
        dma(arch, 1, 4 * len as u64)
    }

    /// `(latency, engine occupancy, DMA stream cycles)` of one MAC strip:
    /// the ALU retires `ceil(n_out/lanes)` lane groups per input element
    /// while the stream side moves the x strip plus one weight row per
    /// element; the in-order engine is busy for whichever dominates.
    pub fn mac_strip(arch: &ArchDesc, n_out: u16, n_in: u16) -> (u64, u64, u64) {
        let alu = n_in as u64 * ceil_div(n_out as usize, arch.pe_dim) as u64;
        let bytes = n_in as u64 * (1 + n_out as u64);
        let (_, stream) = dma(arch, n_in as u64, bytes);
        let occ = alu.max(stream);
        (arch.dma.request_latency + occ, occ, stream)
    }

    /// `(latency, occupancy)` of the requantized store: `len` bytes out.
    pub fn st_out(arch: &ArchDesc, len: u16) -> (u64, u64) {
        dma(arch, 1, len as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;
    use crate::sim::{memory::Dram, requantize, Simulator};
    use crate::tir::QuantAttrs;
    use crate::util::prng::Rng;

    fn reference(x: &[i8], w: &[i8], bias: &[i32], g: Gemm, scale: f32, act: Activation) -> Vec<i8> {
        let mut out = vec![0i8; g.n * g.k];
        for n in 0..g.n {
            for k in 0..g.k {
                let mut acc = bias[k];
                for c in 0..g.c {
                    acc = acc
                        .wrapping_add(x[n * g.c + c] as i32 * w[c * g.k + k] as i32);
                }
                out[n * g.k + k] = requantize(acc, scale, act);
            }
        }
        out
    }

    /// End-to-end: generate via the trait, execute, compare element-exactly
    /// against the reference. Ragged in every dim, k wider than the lane
    /// count, c wider than one strip.
    #[test]
    fn vector_layer_matches_reference() {
        let accel = vector_desc().unwrap();
        let b: &dyn Backend = &VectorBackend;
        let g = Gemm::new(3, STRIP_MAX + 5, 11);
        let quant = QuantAttrs { scale: 0.005, act: Activation::Clip { lo: -100, hi: 100 } };
        let f = TirFunc::unscheduled("vlayer", g, quant);
        let s = &b.sweep(&accel.arch, g, &SweepOptions::default()).candidates[0];
        let f = b.apply_schedule(&accel, &f, s).unwrap();

        let mut prog = Program::new("vec_e2e");
        let rx = prog.layout.alloc("x", (g.n * g.c) as u64).unwrap().offset;
        let rw = prog.layout.alloc("w", (g.c * g.k) as u64).unwrap().offset;
        let rb = prog.layout.alloc("bias", 4 * g.k as u64).unwrap().offset;
        let ro = prog.layout.alloc("out", (g.n * g.k) as u64).unwrap().offset;
        let bufs = LayerBufs { x: rx, w: rw, bias: rb, out: ro };
        b.generate(&accel, &f, s, &bufs, &mut prog).unwrap();

        let mut rng = Rng::new(0x7ec_1234_5678);
        let x: Vec<i8> = (0..g.n * g.c).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..g.c * g.k).map(|_| rng.i8()).collect();
        let bias: Vec<i32> = (0..g.k).map(|_| rng.below(2001) as i32 - 1000).collect();
        let mut dram = Dram::new(prog.layout.total_bytes() as usize + 64);
        dram.write_i8_slice(rx, &x).unwrap();
        dram.write_i8_slice(rw, &w).unwrap();
        for (j, &v) in bias.iter().enumerate() {
            dram.write_i32(rb + 4 * j as u64, v).unwrap();
        }

        let sim = Simulator::new(&accel.arch);
        let rep = sim.run(&prog, &mut dram).unwrap();
        let got = dram.read_i8_slice(ro, g.n * g.k).unwrap();
        assert_eq!(got, reference(&x, &w, &bias, g, quant.scale, quant.act));
        assert_eq!(rep.macs, (g.n * g.c * g.k) as u64);
        assert!(rep.cycles > 0);
    }

    /// The timing model is data-independent: the same program over
    /// different DRAM contents reports identical cycles.
    #[test]
    fn vector_timing_is_data_independent() {
        let accel = vector_desc().unwrap();
        let b: &dyn Backend = &VectorBackend;
        let g = Gemm::new(2, 30, 9);
        let f = TirFunc::unscheduled(
            "vtime",
            g,
            QuantAttrs { scale: 0.5, act: Activation::Relu },
        );
        let s = &b.sweep(&accel.arch, g, &SweepOptions::default()).candidates[0];
        let mut prog = Program::new("vec_time");
        let rx = prog.layout.alloc("x", (g.n * g.c) as u64).unwrap().offset;
        let rw = prog.layout.alloc("w", (g.c * g.k) as u64).unwrap().offset;
        let rb = prog.layout.alloc("bias", 4 * g.k as u64).unwrap().offset;
        let ro = prog.layout.alloc("out", (g.n * g.k) as u64).unwrap().offset;
        b.generate(&accel, &f, s, &LayerBufs { x: rx, w: rw, bias: rb, out: ro }, &mut prog)
            .unwrap();
        let sim = Simulator::new(&accel.arch);
        let size = prog.layout.total_bytes() as usize + 64;
        let mut d0 = Dram::new(size);
        let mut d1 = Dram::new(size);
        let fill: Vec<i8> = (0..g.n * g.c).map(|i| (i % 251) as i8).collect();
        d1.write_i8_slice(rx, &fill).unwrap();
        let r0 = sim.run(&prog, &mut d0).unwrap();
        let r1 = sim.run(&prog, &mut d1).unwrap();
        assert_eq!(r0.cycles, r1.cycles);
        assert_eq!(r0.dram_read_bytes, r1.dram_read_bytes);
    }

    #[test]
    fn desc_builds_with_vector_backend_id() {
        let d = vector_desc().unwrap();
        assert_eq!(d.backend, "vector");
        assert!(d.supported_ops().contains("accel.dense"));
        assert!(d.functional_repr().contains("backend(vector)"));
        assert_eq!(d.backend_impl().unwrap().id(), "vector");
    }

    /// configs/vector.yaml is the canonical copy used by the CLI and CI;
    /// keep it in sync with the built-in default.
    #[test]
    fn shipped_vector_config_matches_builtin() {
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/vector.yaml");
        let src = std::fs::read_to_string(&path).unwrap();
        let a = crate::arch::parse::arch_from_yaml(&src).unwrap();
        let b = vector_arch();
        assert_eq!(a.pe_dim, b.pe_dim);
        assert_eq!(a.dataflows, b.dataflows);
        assert_eq!(a.levels.len(), b.levels.len());
        for (l1, l2) in a.levels.iter().zip(&b.levels) {
            assert_eq!(l1.name, l2.name);
            assert_eq!(l1.size_bytes, l2.size_bytes);
        }
        assert_eq!(a.dma.bytes_per_cycle, b.dma.bytes_per_cycle);
        assert_eq!(a.constraints.insn_tile_limit, b.constraints.insn_tile_limit);
        assert_eq!(crate::arch::parse::backend_from_yaml(&src).unwrap(), "vector");
    }

    #[test]
    fn sweep_returns_one_degenerate_candidate() {
        let arch = vector_arch();
        let g = Gemm::new(10, 20, 30);
        let r = VectorBackend.sweep(&arch, g, &SweepOptions::default());
        assert_eq!(r.candidates.len(), 1);
        let s = &r.candidates[0];
        assert_eq!(s.workload, g);
        assert_eq!(s.onchip_tile, [10, 20, 30]);
        assert!(s.est.latency > 0.0);
        assert!(!VectorBackend.supports_residency());
    }
}
