//! Strategy Generator (paper §3.3).
//!
//! "In the backend, IR lowering requires a well-defined strategy that
//! consists of a tensor computation description and its scheduling. The
//! strategy generator creates the strategy by binding the user-defined
//! computation function and a default schedule to the corresponding
//! operator." Scheduling proper is deferred to the TIR level (the mapping
//! generator); the default schedule here is the unscheduled perfect nest.

use anyhow::{bail, Context, Result};

use crate::accel::{AccelDesc, CoreCompute};
use crate::isa::Activation;
use crate::relay::{Node, Op};
use crate::tir::{QuantAttrs, TirFunc};
use crate::workload::Gemm;

/// A lowering strategy for one graph node: the bound computation
/// description plus the default (unscheduled) TIR function.
#[derive(Debug, Clone)]
pub struct Strategy {
    pub compute: CoreCompute,
    pub tir: TirFunc,
    pub gemm: Gemm,
    pub quant: QuantAttrs,
}

/// Bind a strategy given the node and its resolved input types (the graph
/// carries them; this avoids threading the whole graph through).
pub fn generate_strategy_typed(
    accel: &AccelDesc,
    node: &Node,
    input_shapes: &[Vec<usize>],
) -> Result<Strategy> {
    match &node.op {
        Op::AccelDense { scale, act, weight_transposed } => {
            if !*weight_transposed {
                bail!(
                    "node '{}': weights still in importer layout — run the \
                     preprocessing insertion (legalize) first",
                    node.name
                );
            }
            let compute = accel
                .core_compute("dense")
                .context("accelerator registers no 'dense' core compute")?
                .clone();
            anyhow::ensure!(
                input_shapes.len() == 3,
                "accel.dense expects 3 inputs, got {}",
                input_shapes.len()
            );
            let x = &input_shapes[0];
            let n = x[0];
            let c = x[1];
            let k = node.ty.shape[1];
            let gemm = Gemm::new(n, c, k);
            let quant = QuantAttrs { scale: *scale, act: *act };
            let tir = TirFunc::unscheduled(node.name.clone(), gemm, quant);
            Ok(Strategy { compute, tir, gemm, quant })
        }
        other => bail!("no strategy for operator '{}'", other.name()),
    }
}

/// Convenience: default quantization attributes for host-only testing.
pub fn identity_quant() -> QuantAttrs {
    QuantAttrs { scale: 1.0, act: Activation::None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::{DType, GraphBuilder, Tensor, TensorData, TensorType};

    fn dense_node(weight_transposed: bool) -> (crate::relay::Graph, usize) {
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![4, 8], DType::I8));
        let wshape = if weight_transposed { vec![8, 6] } else { vec![6, 8] };
        let w = b.constant(
            "w",
            Tensor::new(wshape, TensorData::I8(vec![0; 48])).unwrap(),
        );
        let bias =
            b.constant("b", Tensor::new(vec![6], TensorData::I32(vec![0; 6])).unwrap());
        let d = b
            .op(
                "layer0",
                Op::AccelDense { scale: 0.5, act: Activation::Relu, weight_transposed },
                &[x, w, bias],
            )
            .unwrap();
        (b.outputs(&[d]), d)
    }

    #[test]
    fn binds_dense_strategy() {
        let accel = gemmini_desc().unwrap();
        let (g, id) = dense_node(true);
        let node = g.node(id);
        let shapes: Vec<Vec<usize>> =
            node.inputs.iter().map(|&i| g.node(i).ty.shape.clone()).collect();
        let s = generate_strategy_typed(&accel, node, &shapes).unwrap();
        assert_eq!(s.gemm, Gemm::new(4, 8, 6));
        assert_eq!(s.quant.scale, 0.5);
        assert_eq!(s.compute.relay_op, "accel.dense");
        // Default schedule is the unscheduled perfect nest.
        assert_eq!(s.tir.loop_chain().unwrap().len(), 3);
    }

    #[test]
    fn untransposed_weights_rejected() {
        let accel = gemmini_desc().unwrap();
        let (g, id) = dense_node(false);
        let node = g.node(id);
        let shapes: Vec<Vec<usize>> =
            node.inputs.iter().map(|&i| g.node(i).ty.shape.clone()).collect();
        assert!(generate_strategy_typed(&accel, node, &shapes).is_err());
    }

    #[test]
    fn unsupported_op_rejected() {
        let accel = gemmini_desc().unwrap();
        let mut b = GraphBuilder::new();
        let x = b.input("x", TensorType::new(vec![2, 2], DType::I8));
        let t = b.op("t", Op::Transpose, &[x]).unwrap();
        let g = b.outputs(&[t]);
        assert!(generate_strategy_typed(&accel, g.node(t), &[vec![2, 2]]).is_err());
    }
}
