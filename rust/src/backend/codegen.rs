//! Code generator: walk a scheduled TIR function and emit the accelerator
//! instruction stream through the registered hardware intrinsics.
//!
//! The walk is generic over loop orders (it interprets the TIR tree with
//! an index environment) and performs two load-elimination optimizations
//! that the scheduler's traffic model assumes:
//!
//! * **tile-reload dedup** — a `cache_read` whose DRAM tile coordinates
//!   are unchanged since the last load is skipped (the tile is still
//!   resident in its scratchpad slot);
//! * **stationary-tile dedup** — the compute intrinsic is asked to
//!   `preload` only when the stationary operand or destination changed.
//!
//! On-chip tiles are stored in *instruction-tile-wide column blocks* so a
//! tensorized compute never straddles scratchpad rows (see
//! `scheduler::footprint_rows`, which sizes capacity with the same
//! layout).
//!
//! Cross-layer residency ([`crate::scheduler::graph`]) plugs in here via
//! [`generate_resident`]: a layer whose *output* is resident parks its
//! requantized activation in a pinned scratchpad region (one
//! [`Instr::MvoutSpad`] per column block) instead of storing to DRAM, and
//! a layer whose *input* is resident reads straight from that region —
//! its input cache-reads vanish and its own tiles allocate below the
//! pinned rows. With no residency the emission is byte-identical to
//! [`generate`].

use anyhow::{bail, ensure, Context, Result};

use crate::accel::{AccelDesc, ComputeArgs, MemArgs};
use crate::arch::Dataflow;
use crate::isa::program::Program;
use crate::isa::{Instr, LocalAddr};
use crate::scheduler::graph::LayerResidency;
use crate::scheduler::Schedule;
use crate::tir::{LoopLevel, TirFunc, TirNode};
use crate::util::ceil_div;
use crate::workload::Dim;

/// DRAM bindings for one dense layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerBufs {
    /// Input activations `[N, C]` int8, row stride C.
    pub x: u64,
    /// Weights `[C, K]` int8 (accelerator layout), row stride K.
    pub w: u64,
    /// Bias `[K]` int32.
    pub bias: u64,
    /// Output `[N, K]` int8, row stride K.
    pub out: u64,
}

/// Scratchpad/accumulator allocation for one layer.
#[derive(Debug, Clone, Copy)]
struct Alloc {
    rows_in: u32,
    rows_w: u32,
    rows_out: u32,
    a_base: u32,
    w_base: u32,
    slots: u32,
}

struct Walker<'a> {
    accel: &'a AccelDesc,
    s: &'a Schedule,
    bufs: &'a LayerBufs,
    alloc: Alloc,
    dataflow: Dataflow,
    /// DRAM-level tile offsets per dim.
    off_dram: [usize; 3],
    /// On-chip offsets (within the current tile) per dim.
    off_onchip: [usize; 3],
    /// Actual (possibly ragged) extents of the current DRAM tile.
    tile_len: [usize; 3],
    /// Last loaded tile coordinates + slot parity per operand.
    a_state: Option<(usize, usize)>,
    w_state: Option<(usize, usize)>,
    a_slot: u32,
    w_slot: u32,
    acc_slot: u32,
    /// Stationary-tile dedup: (b_row, red, cols, dst_row).
    last_preload: Option<(u32, u16, u16, u32)>,
    /// Input activation is resident on-chip: skip its cache-reads (the
    /// producer parked it at `alloc.a_base`).
    input_resident: bool,
    /// Park the output activation at this scratchpad base instead of
    /// storing it to DRAM.
    output_base: Option<u32>,
}

impl<'a> Walker<'a> {
    fn nominal(&self, d: Dim) -> usize {
        self.s.onchip_tile[d.index()]
    }

    fn insn(&self, d: Dim) -> usize {
        self.s.insn_tile[d.index()]
    }

    fn walk(&mut self, nodes: &[TirNode], prog: &mut Program) -> Result<()> {
        for n in nodes {
            match n {
                TirNode::Loop { info, body } => {
                    let d = info.dim.index();
                    match info.level {
                        LoopLevel::Dram => {
                            let bound = self.s.workload.bound(info.dim);
                            for i in 0..info.extent {
                                let off = i * info.step;
                                if off >= bound {
                                    break;
                                }
                                self.off_dram[d] = off;
                                self.tile_len[d] = info.step.min(bound - off);
                                self.walk(body, prog)?;
                            }
                            self.off_dram[d] = 0;
                            self.tile_len[d] = info.step.min(bound);
                        }
                        LoopLevel::OnChip => {
                            for i in 0..info.extent {
                                let off = i * info.step;
                                if off >= self.tile_len[d] {
                                    break;
                                }
                                self.off_onchip[d] = off;
                                self.walk(body, prog)?;
                            }
                            self.off_onchip[d] = 0;
                        }
                        LoopLevel::Insn => {
                            bail!("Insn loops must be tensorized before codegen")
                        }
                    }
                }
                TirNode::CacheRead { operand, double_buffer } => {
                    self.cache_read(*operand, *double_buffer, prog)?;
                }
                TirNode::LoadBias => self.load_bias(prog)?,
                TirNode::CacheWrite => self.cache_write(prog)?,
                TirNode::Tensorize { .. } => self.tensorize(prog)?,
                TirNode::GemmBody => bail!("unscheduled GemmBody reached codegen"),
            }
        }
        Ok(())
    }

    fn cache_read(
        &mut self,
        operand: crate::workload::Operand,
        double_buffer: bool,
        prog: &mut Program,
    ) -> Result<()> {
        use crate::workload::Operand;
        let g = &self.s.workload;
        match operand {
            Operand::Input => {
                if self.input_resident {
                    // The producer parked the activation at `a_base` in
                    // exactly this block layout — nothing to load.
                    return Ok(());
                }
                let key = (self.off_dram[0], self.off_dram[1]);
                if self.a_state == Some(key) {
                    return Ok(());
                }
                if double_buffer && self.a_state.is_some() {
                    self.a_slot = (self.a_slot + 1) % self.alloc.slots;
                }
                self.a_state = Some(key);
                let (n_len, c_len) = (self.tile_len[0], self.tile_len[1]);
                let c0 = self.insn(Dim::C);
                let base = self.alloc.a_base + self.a_slot * self.alloc.rows_in;
                for cb in 0..ceil_div(c_len, c0) {
                    let cols = c0.min(c_len - cb * c0) as u16;
                    let dram = self.bufs.x
                        + (self.off_dram[0] * g.c + self.off_dram[1] + cb * c0) as u64;
                    let args = MemArgs {
                        dram,
                        local: LocalAddr::spad(base + (cb * self.nominal(Dim::N)) as u32),
                        rows: n_len as u16,
                        cols,
                        stride: g.c as u32,
                    };
                    for i in self.accel.emit_mem(&self.accel.load_intrinsic, &args)? {
                        prog.push(i);
                    }
                }
            }
            Operand::Weight => {
                let key = (self.off_dram[1], self.off_dram[2]);
                if self.w_state == Some(key) {
                    return Ok(());
                }
                if double_buffer && self.w_state.is_some() {
                    self.w_slot = (self.w_slot + 1) % self.alloc.slots;
                }
                self.w_state = Some(key);
                // New stationary contents: force re-preload.
                self.last_preload = None;
                let (c_len, k_len) = (self.tile_len[1], self.tile_len[2]);
                let k0 = self.insn(Dim::K);
                let base = self.alloc.w_base + self.w_slot * self.alloc.rows_w;
                for kb in 0..ceil_div(k_len, k0) {
                    let cols = k0.min(k_len - kb * k0) as u16;
                    let dram = self.bufs.w
                        + (self.off_dram[1] * g.k + self.off_dram[2] + kb * k0) as u64;
                    let args = MemArgs {
                        dram,
                        local: LocalAddr::spad(base + (kb * self.nominal(Dim::C)) as u32),
                        rows: c_len as u16,
                        cols,
                        stride: g.k as u32,
                    };
                    for i in self.accel.emit_mem(&self.accel.load_intrinsic, &args)? {
                        prog.push(i);
                    }
                }
            }
            Operand::Output => bail!("cache_read of Output is not a thing"),
        }
        Ok(())
    }

    fn load_bias(&mut self, prog: &mut Program) -> Result<()> {
        // One bias load per output tile; toggle the accumulator slot.
        self.acc_slot = (self.acc_slot + 1) % self.alloc.slots;
        self.last_preload = None;
        let (n_len, k_len) = (self.tile_len[0], self.tile_len[2]);
        let k0 = self.insn(Dim::K);
        let base = self.acc_slot * self.alloc.rows_out;
        for kb in 0..ceil_div(k_len, k0) {
            let cols = k0.min(k_len - kb * k0) as u16;
            let dram = self.bufs.bias + 4 * (self.off_dram[2] + kb * k0) as u64;
            let args = MemArgs {
                dram,
                // Broadcast the same bias row into every tile row.
                local: LocalAddr::acc(base + (kb * self.nominal(Dim::N)) as u32),
                rows: n_len as u16,
                cols,
                stride: 0,
            };
            for i in self.accel.emit_mem(&self.accel.load_intrinsic, &args)? {
                prog.push(i);
            }
        }
        Ok(())
    }

    fn tensorize(&mut self, prog: &mut Program) -> Result<()> {
        let [n_off, c_off, k_off] = self.off_onchip;
        let (n0, c0, k0) = (self.insn(Dim::N), self.insn(Dim::C), self.insn(Dim::K));
        let rows = n0.min(self.tile_len[0] - n_off) as u16;
        let red = c0.min(self.tile_len[1] - c_off) as u16;
        let cols = k0.min(self.tile_len[2] - k_off) as u16;

        let a_row = self.alloc.a_base
            + self.a_slot * self.alloc.rows_in
            + ((c_off / c0) * self.nominal(Dim::N) + n_off) as u32;
        let b_row = self.alloc.w_base
            + self.w_slot * self.alloc.rows_w
            + ((k_off / k0) * self.nominal(Dim::C) + c_off) as u32;
        let dst_row = self.acc_slot * self.alloc.rows_out
            + ((k_off / k0) * self.nominal(Dim::N) + n_off) as u32;

        // Stationary dedup: WS keys on (B subtile, dst); OS keys on dst
        // (output stationary) — encode both via the same tuple.
        let key = match self.dataflow {
            Dataflow::WeightStationary => (b_row, red, cols, dst_row),
            Dataflow::OutputStationary => (u32::MAX, rows, cols, dst_row),
        };
        let preload = self.last_preload != Some(key);
        let args = ComputeArgs {
            a: LocalAddr::spad(a_row),
            b: LocalAddr::spad(b_row),
            dst: LocalAddr::acc_accumulate(dst_row),
            rows,
            red,
            cols,
            preload,
            dataflow: self.dataflow,
        };
        for i in self.accel.emit_compute(&args)? {
            prog.push(i);
        }
        self.last_preload = Some(key);
        Ok(())
    }

    fn cache_write(&mut self, prog: &mut Program) -> Result<()> {
        let g = &self.s.workload;
        let (n_len, k_len) = (self.tile_len[0], self.tile_len[2]);
        let k0 = self.insn(Dim::K);
        let base = self.acc_slot * self.alloc.rows_out;
        if let Some(park) = self.output_base {
            // Resident edge: requantize each column block straight into
            // the pinned scratchpad region — the consumer's input layout —
            // eliding the DRAM store (and the consumer's reload). The
            // planner guarantees this single tile covers the whole output.
            for kb in 0..ceil_div(k_len, k0) {
                let cols = k0.min(k_len - kb * k0) as u16;
                prog.push(Instr::MvoutSpad {
                    src: LocalAddr::acc(base + (kb * self.nominal(Dim::N)) as u32),
                    dst: LocalAddr::spad(park + (kb * self.nominal(Dim::N)) as u32),
                    rows: n_len as u16,
                    cols,
                });
            }
            return Ok(());
        }
        for kb in 0..ceil_div(k_len, k0) {
            let cols = k0.min(k_len - kb * k0) as u16;
            let dram =
                self.bufs.out + (self.off_dram[0] * g.k + self.off_dram[2] + kb * k0) as u64;
            let args = MemArgs {
                dram,
                local: LocalAddr::acc(base + (kb * self.nominal(Dim::N)) as u32),
                rows: n_len as u16,
                cols,
                stride: g.k as u32,
            };
            for i in self.accel.emit_mem(&self.accel.store_intrinsic, &args)? {
                prog.push(i);
            }
        }
        Ok(())
    }
}

/// Emit the per-layer configuration + full instruction stream for a
/// scheduled TIR function into `prog` (no cross-layer residency; see
/// [`generate_resident`]).
pub fn generate(
    accel: &AccelDesc,
    f: &TirFunc,
    s: &Schedule,
    bufs: &LayerBufs,
    prog: &mut Program,
) -> Result<()> {
    generate_resident(accel, f, s, bufs, &LayerResidency::default(), prog)
}

/// [`generate`] with cross-layer residency decisions: a resident input
/// reads from its pinned region (no DRAM loads, no scratchpad slot of its
/// own), a resident output parks in its pinned region (no DRAM stores),
/// and the layer's own tiles must fit below `resid.reserved_rows`. The
/// default (empty) residency emits byte-identical code to [`generate`].
pub fn generate_resident(
    accel: &AccelDesc,
    f: &TirFunc,
    s: &Schedule,
    bufs: &LayerBufs,
    resid: &LayerResidency,
    prog: &mut Program,
) -> Result<()> {
    f.validate().with_context(|| format!("codegen input '{}'", f.name))?;
    s.validate(&accel.arch)?;
    ensure!(f.gemm == s.workload, "schedule/function workload mismatch");

    let arch = &accel.arch;
    // Same capacity numbers the residency planner checks against
    // (`ResidencyConstraint::admits` mirrors the ensures below).
    let (spad_rows, acc_rows) = crate::scheduler::graph::onchip_rows(arch)?;

    let [nt, ct, kt] = s.onchip_tile;
    let [_, c0, k0] = s.insn_tile;
    // A resident input lives in the pinned region the producer wrote — it
    // needs no staging rows (and never ping-pongs).
    let rows_in =
        if resid.input_base.is_some() { 0 } else { (nt * ceil_div(ct, c0)) as u32 };
    let rows_w = (ct * ceil_div(kt, k0)) as u32;
    let rows_out = (nt * ceil_div(kt, k0)) as u32;
    let slots: u32 = if s.double_buffer { 2 } else { 1 };
    let alloc = Alloc {
        rows_in,
        rows_w,
        rows_out,
        a_base: resid.input_base.unwrap_or(0),
        w_base: slots * rows_in,
        slots,
    };
    if resid.input_base.is_some() || resid.output_base.is_some() {
        // The planner only proposes whole-activation residency: exactly
        // one on-chip tile on each resident side.
        ensure!(nt == s.workload.n, "resident layer must hold its full batch on-chip");
        if resid.input_base.is_some() {
            ensure!(ct == s.workload.c, "resident input must be one on-chip tile");
        }
        if resid.output_base.is_some() {
            ensure!(kt == s.workload.k, "resident output must be one on-chip tile");
        }
    }
    ensure!(
        (slots * (rows_in + rows_w) + resid.reserved_rows) as usize <= spad_rows,
        "scratchpad overflow: {} rows needed (+{} pinned), {} available",
        slots * (rows_in + rows_w),
        resid.reserved_rows,
        spad_rows
    );
    ensure!(
        (slots * rows_out) as usize <= acc_rows,
        "accumulator overflow: {} rows needed, {} available",
        slots * rows_out,
        acc_rows
    );

    // Per-layer configuration via the registered config intrinsic.
    for i in accel.emit_config(&crate::accel::ConfigArgs {
        dataflow: s.dataflow,
        st_stride: s.workload.k as u32,
        scale: f.quant.scale,
        act: f.quant.act,
    })? {
        prog.push(i);
    }

    let mut w = Walker {
        accel,
        s,
        bufs,
        alloc,
        dataflow: s.dataflow,
        off_dram: [0; 3],
        off_onchip: [0; 3],
        tile_len: [
            nt.min(s.workload.n),
            ct.min(s.workload.c),
            kt.min(s.workload.k),
        ],
        a_state: None,
        w_state: None,
        a_slot: 0,
        w_slot: 0,
        acc_slot: slots - 1, // first LoadBias toggles to slot 0
        last_preload: None,
        input_resident: resid.input_base.is_some(),
        output_base: resid.output_base,
    };
    w.walk(&f.body, prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::backend::mapping::apply_schedule;
    use crate::isa::Activation;
    use crate::scheduler::solver::{solve, SolverConfig};
    use crate::scheduler::Schedule;
    use crate::sim::{requantize, Simulator};
    use crate::tir::{QuantAttrs, TirFunc};
    use crate::util::prng::Rng;
    use crate::workload::Gemm;

    /// Reference: O = requant(X·W + bias) with W in [C,K] layout.
    fn ref_out(
        x: &[i8],
        w: &[i8],
        bias: &[i32],
        g: Gemm,
        scale: f32,
        act: Activation,
    ) -> Vec<i8> {
        let mut out = vec![0i8; g.n * g.k];
        for i in 0..g.n {
            for j in 0..g.k {
                let mut s = bias[j];
                for c in 0..g.c {
                    s += x[i * g.c + c] as i32 * w[c * g.k + j] as i32;
                }
                out[i * g.k + j] = requantize(s, scale, act);
            }
        }
        out
    }

    /// Compile one layer with the given schedule and check simulator
    /// output against the reference.
    fn check_layer(g: Gemm, s: &Schedule, seed: u64) {
        let accel = gemmini_desc().unwrap();
        let quant = QuantAttrs { scale: 0.02, act: Activation::Relu };
        let f = TirFunc::unscheduled("layer", g, quant);
        let scheduled = apply_schedule(&accel, &f, s).unwrap();

        let mut rng = Rng::new(seed);
        let x = rng.i8_vec(g.n * g.c);
        let w = rng.i8_vec(g.c * g.k);
        let bias: Vec<i32> = (0..g.k).map(|_| rng.below(2000) as i32 - 1000).collect();

        let mut prog = Program::new("test");
        let bufs = LayerBufs {
            x: prog.layout.alloc("x", (g.n * g.c) as u64).unwrap().offset,
            w: prog.layout.alloc("w", (g.c * g.k) as u64).unwrap().offset,
            bias: prog.layout.alloc("bias", (g.k * 4) as u64).unwrap().offset,
            out: prog.layout.alloc("out", (g.n * g.k) as u64).unwrap().offset,
        };
        generate(&accel, &scheduled, s, &bufs, &mut prog).unwrap();
        prog.push(crate::isa::Instr::Fence);

        let mut dram = prog.make_dram().unwrap();
        dram.write_i8_slice(bufs.x, &x).unwrap();
        dram.write_i8_slice(bufs.w, &w).unwrap();
        dram.write_i32_slice(bufs.bias, &bias).unwrap();

        let sim = Simulator::new(&accel.arch);
        let rep = sim.run(&prog, &mut dram).unwrap();
        let got = dram.read_i8_slice(bufs.out, g.n * g.k).unwrap();
        let want = ref_out(&x, &w, &bias, g, quant.scale, quant.act);
        assert_eq!(got, want, "schedule {s}");
        assert_eq!(rep.macs, g.macs(), "every MAC must be performed exactly once");
    }

    #[test]
    fn codegen_correct_for_solver_schedules_64() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(64, 64, 64);
        let cfg = SolverConfig {
            top_k: 3,
            double_buffer: true,
            ..SolverConfig::new(crate::arch::Dataflow::WeightStationary)
        };
        for (i, s) in solve(&accel.arch, g, &cfg).iter().enumerate() {
            check_layer(g, s, 100 + i as u64);
        }
    }

    #[test]
    fn codegen_correct_for_os_dataflow() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(48, 32, 48);
        let cfg = SolverConfig {
            top_k: 2,
            ..SolverConfig::new(crate::arch::Dataflow::OutputStationary)
        };
        for (i, s) in solve(&accel.arch, g, &cfg).iter().enumerate() {
            check_layer(g, s, 200 + i as u64);
        }
    }

    #[test]
    fn codegen_correct_toycar_shapes() {
        let accel = gemmini_desc().unwrap();
        for (i, g) in [Gemm::new(1, 640, 128), Gemm::new(1, 128, 8), Gemm::new(1, 8, 128)]
            .into_iter()
            .enumerate()
        {
            let cfg = SolverConfig {
                double_buffer: true,
                ..SolverConfig::new(crate::arch::Dataflow::WeightStationary)
            };
            let scheds = solve(&accel.arch, g, &cfg);
            assert!(!scheds.is_empty());
            check_layer(g, &scheds[0], 300 + i as u64);
        }
    }

    #[test]
    fn resident_edge_equals_round_trip_with_less_dram() {
        use crate::scheduler::graph::{onchip_rows, LayerResidency};

        let accel = gemmini_desc().unwrap();
        let quant = QuantAttrs { scale: 0.05, act: Activation::Relu };
        let g1 = Gemm::new(4, 32, 48);
        let g2 = Gemm::new(4, 48, 16);
        let dim = accel.arch.pe_dim;
        let mk = |g: Gemm| Schedule {
            workload: g,
            dataflow: crate::arch::Dataflow::WeightStationary,
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: [g.n.min(dim), g.c.min(dim), g.k.min(dim)],
            onchip_tile: [g.n, g.c, g.k],
            dram_order: [
                crate::workload::Dim::N,
                crate::workload::Dim::C,
                crate::workload::Dim::K,
            ],
            est: Default::default(),
        };
        let (s1, s2) = (mk(g1), mk(g2));
        assert_eq!(s1.insn_tile[2], s2.insn_tile[1], "edge blocks must agree");
        let sch1 = apply_schedule(
            &accel,
            &TirFunc::unscheduled("l1", g1, quant),
            &s1,
        )
        .unwrap();
        let sch2 = apply_schedule(
            &accel,
            &TirFunc::unscheduled("l2", g2, quant),
            &s2,
        )
        .unwrap();

        let mut rng = Rng::new(99);
        let x = rng.i8_vec(g1.n * g1.c);
        let w1 = rng.i8_vec(g1.c * g1.k);
        let b1: Vec<i32> = (0..g1.k).map(|_| rng.below(200) as i32 - 100).collect();
        let w2 = rng.i8_vec(g2.c * g2.k);
        let b2: Vec<i32> = (0..g2.k).map(|_| rng.below(200) as i32 - 100).collect();

        let build = |resident: bool| {
            let mut prog = Program::new("pair");
            let bufs1 = LayerBufs {
                x: prog.layout.alloc("x", (g1.n * g1.c) as u64).unwrap().offset,
                w: prog.layout.alloc("w1", (g1.c * g1.k) as u64).unwrap().offset,
                bias: prog.layout.alloc("b1", (g1.k * 4) as u64).unwrap().offset,
                out: prog.layout.alloc("mid", (g1.n * g1.k) as u64).unwrap().offset,
            };
            let bufs2 = LayerBufs {
                x: bufs1.out,
                w: prog.layout.alloc("w2", (g2.c * g2.k) as u64).unwrap().offset,
                bias: prog.layout.alloc("b2", (g2.k * 4) as u64).unwrap().offset,
                out: prog.layout.alloc("out", (g2.n * g2.k) as u64).unwrap().offset,
            };
            if resident {
                let (spad_rows, _) = onchip_rows(&accel.arch).unwrap();
                let rows_e = (g1.n * ceil_div(g1.k, s1.insn_tile[2])) as u32;
                let base = spad_rows as u32 - rows_e;
                let r1 = LayerResidency {
                    input_base: None,
                    output_base: Some(base),
                    reserved_rows: rows_e,
                };
                let r2 = LayerResidency {
                    input_base: Some(base),
                    output_base: None,
                    reserved_rows: rows_e,
                };
                generate_resident(&accel, &sch1, &s1, &bufs1, &r1, &mut prog).unwrap();
                prog.push(Instr::Fence);
                generate_resident(&accel, &sch2, &s2, &bufs2, &r2, &mut prog).unwrap();
            } else {
                generate(&accel, &sch1, &s1, &bufs1, &mut prog).unwrap();
                prog.push(Instr::Fence);
                generate(&accel, &sch2, &s2, &bufs2, &mut prog).unwrap();
            }
            prog.push(Instr::Fence);

            let mut dram = prog.make_dram().unwrap();
            dram.write_i8_slice(bufs1.x, &x).unwrap();
            dram.write_i8_slice(bufs1.w, &w1).unwrap();
            dram.write_i32_slice(bufs1.bias, &b1).unwrap();
            dram.write_i8_slice(bufs2.w, &w2).unwrap();
            dram.write_i32_slice(bufs2.bias, &b2).unwrap();
            let sim = Simulator::new(&accel.arch);
            let rep = sim.run(&prog, &mut dram).unwrap();
            (dram.read_i8_slice(bufs2.out, g2.n * g2.k).unwrap(), rep)
        };

        let (base_out, base_rep) = build(false);
        let (res_out, res_rep) = build(true);
        // Element-exact: the parked int8 activation is exactly what the
        // DRAM round-trip would have stored and reloaded.
        assert_eq!(res_out, base_out);
        let mid = ref_out(&x, &w1, &b1, g1, quant.scale, quant.act);
        let want = ref_out(&mid, &w2, &b2, g2, quant.scale, quant.act);
        assert_eq!(res_out, want, "resident pair must match the reference");
        assert!(
            res_rep.dram_transfer_cycles < base_rep.dram_transfer_cycles,
            "residency must elide DRAM transfer cycles ({} vs {})",
            res_rep.dram_transfer_cycles,
            base_rep.dram_transfer_cycles
        );
        assert!(res_rep.dram_write_bytes < base_rep.dram_write_bytes);
        assert!(res_rep.dram_read_bytes < base_rep.dram_read_bytes);
        assert!(
            res_rep.insn_counts.contains_key("mvout_spad"),
            "the on-chip park must appear in the stream: {:?}",
            res_rep.insn_counts
        );
    }

    #[test]
    fn prop_codegen_matches_reference_across_shapes_and_schedules() {
        let accel = gemmini_desc().unwrap();
        crate::util::prop::check("codegen == reference", 25, |rng| {
            let pick = [1usize, 2, 4, 8, 16, 24, 32, 48, 64, 80, 96, 128];
            let g = Gemm::new(*rng.pick(&pick), *rng.pick(&pick), *rng.pick(&pick));
            let cfg = SolverConfig {
                dataflow: if rng.chance(0.7) {
                    crate::arch::Dataflow::WeightStationary
                } else {
                    crate::arch::Dataflow::OutputStationary
                },
                shares: *rng.pick(&[[0.5, 0.5, 1.0], [0.25, 0.75, 1.0]]),
                double_buffer: rng.chance(0.5),
                top_k: 2,
            };
            let scheds = solve(&accel.arch, g, &cfg);
            if scheds.is_empty() {
                return Ok(());
            }
            let s = rng.pick(&scheds).clone();
            let seed = rng.next_u64();
            // check_layer panics on mismatch; catch via result-style call.
            check_layer(g, &s, seed);
            Ok(())
        });
    }
}
