//! Mapping Generator (paper §3.3).
//!
//! "Scheduling decisions, including multi-level tiling and reordering, are
//! generated using the extended CoSA scheduler. CoSA produces a YAML file
//! that specifies the tile factors and the ordering of tensor dimensions
//! for each memory level. Based on this output, the mapping generator
//! applies loop transformations using TIR schedule primitives. ... the
//! mapping generator utilizes TVM's tensorization feature to rewrite TIR
//! stages with hardware intrinsics."

use anyhow::{ensure, Result};

use crate::accel::AccelDesc;
use crate::scheduler::Schedule;
use crate::tir::schedule::{insert_stages, reorder, split, tensorize};
use crate::tir::{LoopLevel, TirFunc};
use crate::workload::Dim;

use super::intrin::default_intrinsic;

/// The canonical total loop order for a schedule: DRAM loops in the
/// schedule's permutation with C rotated to the innermost DRAM slot, then
/// on-chip loops (stationary dims outer, streamed dim innermost), then the
/// instruction-tile loops.
pub fn canonical_order(s: &Schedule) -> Vec<(Dim, LoopLevel)> {
    // DRAM: keep the scheduler's relative order of the non-C dims, C last.
    let mut dram: Vec<Dim> = s.dram_order.iter().copied().filter(|&d| d != Dim::C).collect();
    dram.push(Dim::C);
    // On-chip: stationary-operand dims outer, streamed dim innermost
    // (WS: K, C outer with N streamed; OS: K, N outer with C streamed).
    let streamed = s.dataflow.streamed_dim();
    let mut onchip: Vec<Dim> = Dim::ALL.iter().copied().filter(|&d| d != streamed).collect();
    // Put K before the other non-streamed dim for weight-stationary-style
    // reuse of the stationary tile.
    onchip.sort_by_key(|&d| if d == Dim::K { 0 } else { 1 });
    onchip.push(streamed);

    let mut order: Vec<(Dim, LoopLevel)> =
        dram.into_iter().map(|d| (d, LoopLevel::Dram)).collect();
    order.extend(onchip.into_iter().map(|d| (d, LoopLevel::OnChip)));
    order.extend(Dim::ALL.into_iter().map(|d| (d, LoopLevel::Insn)));
    order
}

/// Apply a CoSA schedule to an unscheduled TIR function: multi-level
/// tiling → reordering → tensorization → memory staging. Returns the
/// fully scheduled function ready for codegen.
pub fn apply_schedule(accel: &AccelDesc, f: &TirFunc, s: &Schedule) -> Result<TirFunc> {
    ensure!(
        f.gemm == s.workload,
        "schedule is for {:?}, function computes {:?}",
        s.workload,
        f.gemm
    );
    s.validate(&accel.arch)?;
    let mut cur = f.clone();
    for d in Dim::ALL {
        cur = split(&cur, d, s.onchip_tile[d.index()], s.insn_tile[d.index()])?;
    }
    cur = reorder(&cur, &canonical_order(s))?;
    let intrinsic = default_intrinsic(accel)?;
    cur = tensorize(&cur, &intrinsic.name, intrinsic.max_tile)?;
    let staged = insert_stages(&cur, s.double_buffer)?;
    staged.validate()?;
    Ok(staged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::arch::Dataflow;
    use crate::isa::Activation;
    use crate::scheduler::solver::{solve, SolverConfig};
    use crate::tir::{QuantAttrs, TirNode};
    use crate::workload::Gemm;

    fn func(g: Gemm) -> TirFunc {
        TirFunc::unscheduled("layer", g, QuantAttrs { scale: 0.1, act: Activation::Relu })
    }

    #[test]
    fn applies_solver_schedule() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(64, 64, 64);
        let cfg = SolverConfig { double_buffer: true, ..SolverConfig::new(Dataflow::WeightStationary) };
        let s = &solve(&accel.arch, g, &cfg)[0];
        let f = apply_schedule(&accel, &func(g), s).unwrap();
        assert_eq!(f.count(&|n| matches!(n, TirNode::Tensorize { .. })), 1);
        assert_eq!(
            f.count(&|n| matches!(n, TirNode::CacheRead { double_buffer: true, .. })),
            2
        );
        let script = f.script();
        assert!(script.contains("gemmini_matmul"));
    }

    #[test]
    fn canonical_order_forces_c_innermost_dram() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(256, 256, 256);
        let cfg = SolverConfig::new(Dataflow::WeightStationary);
        for s in solve(&accel.arch, g, &cfg) {
            let order = canonical_order(&s);
            assert_eq!(order[2], (Dim::C, LoopLevel::Dram));
            let f = apply_schedule(&accel, &func(g), &s).unwrap();
            f.validate().unwrap();
        }
    }

    #[test]
    fn os_streams_c_innermost_onchip() {
        let accel = gemmini_desc().unwrap();
        let g = Gemm::new(128, 128, 128);
        let cfg = SolverConfig::new(Dataflow::OutputStationary);
        let s = &solve(&accel.arch, g, &cfg)[0];
        let order = canonical_order(s);
        // On-chip loops are positions 3..6; streamed dim (C under OS) last.
        assert_eq!(order[5], (Dim::C, LoopLevel::OnChip));
    }

    #[test]
    fn workload_mismatch_rejected() {
        let accel = gemmini_desc().unwrap();
        let cfg = SolverConfig::new(Dataflow::WeightStationary);
        let s = &solve(&accel.arch, Gemm::new(64, 64, 64), &cfg)[0];
        assert!(apply_schedule(&accel, &func(Gemm::new(32, 32, 32)), s).is_err());
    }
}
