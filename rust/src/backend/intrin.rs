//! Hardware Intrinsic Generator (paper §3.3).
//!
//! TVM's tensorization needs a registered *tensor intrinsic*: a
//! computation description (what region it covers) plus an implementation
//! (which hardware instructions realize it). "Instead of requiring manual
//! registration, the hardware intrinsic generator leverages the
//! user-defined functional description in the accelerator model to
//! automatically generate the necessary tensor intrinsics."

use anyhow::{Context, Result};

use crate::accel::{AccelDesc, IntrinsicClass};

/// A generated TIR tensor intrinsic: referenced by name from
/// `TirNode::Tensorize`, carrying the semantic description used for
/// matching and the Eq. (1) tile limit used for checking.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorIntrinsic {
    pub name: String,
    /// Computation description (the `desc` half of TVM's pair).
    pub desc: String,
    /// Maximum extent per dimension of a tensorized tile (Eq. 1).
    pub max_tile: usize,
}

/// Generate the tensor intrinsics for an accelerator description.
pub fn generate_intrinsics(accel: &AccelDesc) -> Result<Vec<TensorIntrinsic>> {
    let compute = accel
        .core_compute("dense")
        .context("no 'dense' core compute registered")?;
    let mut out = Vec::new();
    for hw in accel.intrinsics() {
        if hw.class == IntrinsicClass::Compute {
            out.push(TensorIntrinsic {
                name: hw.name.clone(),
                desc: compute.einsum.clone(),
                max_tile: accel.arch.constraints.insn_tile_limit,
            });
        }
    }
    anyhow::ensure!(!out.is_empty(), "no compute intrinsics registered");
    Ok(out)
}

/// The intrinsic codegen tensorizes with (the accelerator's designated
/// compute intrinsic).
pub fn default_intrinsic(accel: &AccelDesc) -> Result<TensorIntrinsic> {
    generate_intrinsics(accel)?
        .into_iter()
        .find(|i| i.name == accel.compute_intrinsic)
        .context("designated compute intrinsic not generated")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;

    #[test]
    fn generates_matmul_intrinsic() {
        let d = gemmini_desc().unwrap();
        let intrinsics = generate_intrinsics(&d).unwrap();
        assert_eq!(intrinsics.len(), 1);
        assert_eq!(intrinsics[0].name, "gemmini_matmul");
        assert_eq!(intrinsics[0].max_tile, 16);
        assert!(intrinsics[0].desc.contains("requant"));
    }

    #[test]
    fn default_is_designated_compute() {
        let d = gemmini_desc().unwrap();
        let i = default_intrinsic(&d).unwrap();
        assert_eq!(i.name, d.compute_intrinsic);
    }
}
