//! Frontend Configurator (paper §3.3).
//!
//! "The frontend configurator sets up the graph partitioning and
//! legalization passes using predefined supported operators, derived from
//! the functional description of the hardware accelerator." Given an
//! [`AccelDesc`] it derives the legalization config (which QNN sequences
//! fuse, which preprocessing gets inserted) and the supported-operator set
//! for partitioning, then runs the pass pipeline:
//! legalize → constant-fold → partition.

use anyhow::Result;

use crate::accel::{AccelDesc, Preprocessing};
use crate::relay::fold::fold_constants;
use crate::relay::legalize::{legalize, LegalizeConfig};
use crate::relay::partition::{partition, PartitionedGraph};
use crate::relay::Graph;

/// Derived frontend configuration.
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    pub legalize: LegalizeConfig,
    pub supported: std::collections::BTreeSet<String>,
    /// Run compile-time constant folding (the §4 UMA fix). The naive BYOC
    /// baseline disables this, reproducing the paper's degraded flow.
    pub fold_constants: bool,
}

/// Derive the frontend configuration from the accelerator description.
pub fn configure(accel: &AccelDesc) -> FrontendConfig {
    let dense_supported = accel.core_compute("dense").is_some();
    let conv_supported = accel.core_compute("conv2d").is_some()
        && accel.preprocessing("conv2d").contains(&Preprocessing::Im2col);
    let wants_transpose = accel
        .preprocessing("dense")
        .contains(&Preprocessing::WeightTranspose);
    FrontendConfig {
        legalize: LegalizeConfig {
            dense: dense_supported,
            conv2d: conv_supported,
            insert_weight_transpose: wants_transpose,
        },
        supported: accel.supported_ops(),
        fold_constants: true,
    }
}

/// Derive one frontend configuration covering a *set* of candidate
/// accelerators (the multi-target compile path): legalization is enabled
/// for an operator when **any** candidate supports it, and the supported
/// set is the union — per-node target choice then happens in the
/// cost-driven partitioner against each candidate's own set. With a single
/// candidate this is exactly [`configure`].
pub fn configure_all(accels: &[&AccelDesc]) -> FrontendConfig {
    let mut iter = accels.iter();
    let mut cfg = configure(iter.next().expect("at least one accelerator"));
    for a in iter {
        let c = configure(a);
        cfg.legalize.dense |= c.legalize.dense;
        cfg.legalize.conv2d |= c.legalize.conv2d;
        cfg.legalize.insert_weight_transpose |= c.legalize.insert_weight_transpose;
        cfg.supported.extend(c.supported);
    }
    cfg
}

/// The graph-rewriting half of the frontend (legalize + optional constant
/// fold), without partitioning. The session pipeline times this as its own
/// stage; [`run_frontend`] composes it with partitioning.
pub fn run_frontend_passes(g: &Graph, cfg: &FrontendConfig) -> Result<Graph> {
    let legalized = legalize(g, &cfg.legalize)?;
    if cfg.fold_constants {
        fold_constants(&legalized)
    } else {
        Ok(legalized)
    }
}

/// Run the configured frontend over an imported graph.
pub fn run_frontend(g: &Graph, cfg: &FrontendConfig) -> Result<PartitionedGraph> {
    let processed = run_frontend_passes(g, cfg)?;
    partition(&processed, &cfg.supported)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::legalize::op_histogram;
    use crate::relay::partition::Target;
    use crate::relay::quantize::{build_qnn_graph, quantize_mlp, FloatDense};
    use crate::util::prng::Rng;

    fn mlp_graph() -> Graph {
        let mut rng = Rng::new(41);
        let dims = [24usize, 16, 8];
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| rng.f64() as f32 - 0.5).collect(),
                bias: (0..w[1]).map(|_| rng.f64() as f32 - 0.5).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i == 0,
            })
            .collect();
        let q = quantize_mlp(&layers, &[0.05, 0.07, 0.09]).unwrap();
        build_qnn_graph(1, &q).unwrap()
    }

    #[test]
    fn proposed_flow_fuses_and_folds_everything() {
        let accel = gemmini_desc().unwrap();
        let cfg = configure(&accel);
        assert!(cfg.legalize.dense);
        assert!(cfg.legalize.insert_weight_transpose);
        let pg = run_frontend(&mlp_graph(), &cfg).unwrap();
        let h = op_histogram(&pg.graph);
        assert_eq!(h.get("accel.dense"), Some(&2));
        assert_eq!(h.get("transpose"), None); // folded
        assert_eq!(pg.accel_nodes(), 2);
        assert_eq!(pg.host_nodes(), 0);
        assert_eq!(pg.regions.len(), 1);
    }

    #[test]
    fn naive_flow_leaves_runtime_preprocessing() {
        let accel = gemmini_desc().unwrap();
        let mut cfg = configure(&accel);
        cfg.fold_constants = false; // the naive BYOC configuration
        let pg = run_frontend(&mlp_graph(), &cfg).unwrap();
        let h = op_histogram(&pg.graph);
        assert_eq!(h.get("accel.dense"), Some(&2));
        // Weight transposes remain as host-side runtime work.
        assert_eq!(h.get("transpose"), Some(&2));
        assert_eq!(pg.host_nodes(), 2);
        assert!(pg
            .targets
            .iter()
            .zip(&pg.graph.nodes)
            .any(|(t, n)| *t == Target::Host && n.op.name() == "transpose"));
    }
}
