//! The Gemmini reference accelerator description — the case study of the
//! paper's evaluation (§4) and the running example of Fig. 3.
//!
//! Everything a user writes to integrate Gemmini is in this file (plus
//! `configs/gemmini.yaml` for the architectural half); the compiler
//! backend is generated from it by the configurators. This is the LoC
//! that Table 1 counts on the "Proposed" side.

use crate::arch::{ArchDesc, Dataflow};
use crate::isa::{Instr, Space};

use super::{
    AccelDesc, ComputeArgs, ConfigArgs, CoreCompute, HwIntrinsic, MemArgs, Preprocessing,
};

/// Fig. 3(c): the matmul compute intrinsic. One instruction tile:
/// PRELOAD the stationary operand (if it changed), then fire the array.
/// Under WS the stationary tile is B (weights); under OS the PRELOAD
/// names the output tile and B rides the compute's second operand.
fn matmul(args: &ComputeArgs) -> Vec<Instr> {
    let mut out = Vec::with_capacity(2);
    match args.dataflow {
        Dataflow::WeightStationary => {
            if args.preload {
                out.push(Instr::Preload {
                    local: Some(args.b),
                    dst: args.dst,
                    rows: args.red,
                    cols: args.cols,
                });
            }
            out.push(Instr::Compute {
                a: args.a,
                d: None,
                rows: args.rows,
                cols: args.red,
                preloaded: args.preload,
            });
        }
        Dataflow::OutputStationary => {
            if args.preload {
                out.push(Instr::Preload {
                    local: None,
                    dst: args.dst,
                    rows: args.rows,
                    cols: args.cols,
                });
            }
            out.push(Instr::Compute {
                a: args.a,
                d: Some(args.b),
                rows: args.rows,
                cols: args.red,
                preloaded: args.preload,
            });
        }
    }
    out
}

/// Fig. 3(d): memory-load intrinsic (DRAM → scratchpad/accumulator).
fn mvin(args: &MemArgs) -> Vec<Instr> {
    vec![
        Instr::ConfigLd { stride: args.stride },
        Instr::Mvin { dram: args.dram, local: args.local, rows: args.rows, cols: args.cols },
    ]
}

/// Memory-store intrinsic (accumulator → DRAM with fused requantize; the
/// store pipeline's stride/scale/activation come from `config`).
fn mvout(args: &MemArgs) -> Vec<Instr> {
    debug_assert_eq!(args.local.space, Space::Acc);
    vec![Instr::Mvout {
        dram: args.dram,
        local: args.local,
        rows: args.rows,
        cols: args.cols,
    }]
}

/// Configuration intrinsic: set dataflow + store pipeline (output stride,
/// requantization scale, activation).
fn config(args: &ConfigArgs) -> Vec<Instr> {
    vec![
        Instr::ConfigEx { dataflow: args.dataflow },
        Instr::ConfigSt { stride: args.st_stride, scale: args.scale, act: args.act },
    ]
}

/// Build the full Gemmini description (functional + architectural).
pub fn gemmini_desc() -> anyhow::Result<AccelDesc> {
    AccelDesc::builder("gemmini", ArchDesc::gemmini())
        // Fig. 3(a): dense needs its weights transposed into [C,K];
        // convolutions reach the GEMM via im2col.
        .register_preprocessing("dense", Preprocessing::WeightTranspose)
        .register_preprocessing("conv2d", Preprocessing::Im2col)
        // Fig. 3(b): the core quantized-GEMM computation (shared by dense
        // and im2col-lowered convolution).
        .register_core_compute(CoreCompute::quantized_gemm("dense"))
        .register_core_compute(CoreCompute::quantized_gemm("conv2d"))
        // Fig. 3(c)/(d): the offload interface.
        .register_hw_intrinsic(HwIntrinsic::compute("gemmini_matmul", matmul))
        .register_hw_intrinsic(HwIntrinsic::memory("gemmini_mvin", mvin))
        .register_hw_intrinsic(HwIntrinsic::memory("gemmini_mvout", mvout))
        .register_hw_intrinsic(HwIntrinsic::config("gemmini_config", config))
        .build()
}

/// Same description on a custom architecture (used by the
/// `custom_accelerator` example and tests: the functional side transfers
/// unchanged to a different array size / dataflow).
pub fn desc_for_arch(name: &str, arch: ArchDesc) -> anyhow::Result<AccelDesc> {
    AccelDesc::builder(name, arch)
        .register_preprocessing("dense", Preprocessing::WeightTranspose)
        .register_preprocessing("conv2d", Preprocessing::Im2col)
        .register_core_compute(CoreCompute::quantized_gemm("dense"))
        .register_core_compute(CoreCompute::quantized_gemm("conv2d"))
        .register_hw_intrinsic(HwIntrinsic::compute("gemmini_matmul", matmul))
        .register_hw_intrinsic(HwIntrinsic::memory("gemmini_mvin", mvin))
        .register_hw_intrinsic(HwIntrinsic::memory("gemmini_mvout", mvout))
        .register_hw_intrinsic(HwIntrinsic::config("gemmini_config", config))
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::LocalAddr;

    #[test]
    fn mvin_emits_config_then_transfer() {
        let i = mvin(&MemArgs {
            dram: 0x100,
            local: LocalAddr::spad(4),
            rows: 16,
            cols: 16,
            stride: 64,
        });
        assert_eq!(i.len(), 2);
        assert_eq!(i[0], Instr::ConfigLd { stride: 64 });
        assert!(matches!(i[1], Instr::Mvin { rows: 16, cols: 16, .. }));
    }

    #[test]
    fn os_compute_routes_b_through_operand() {
        let args = ComputeArgs {
            a: LocalAddr::spad(0),
            b: LocalAddr::spad(32),
            dst: LocalAddr::acc_accumulate(0),
            rows: 8,
            red: 4,
            cols: 12,
            preload: true,
            dataflow: Dataflow::OutputStationary,
        };
        let i = matmul(&args);
        assert_eq!(i.len(), 2);
        // OS preload carries the C tile shape and no source.
        assert!(matches!(
            i[0],
            Instr::Preload { local: None, rows: 8, cols: 12, .. }
        ));
        assert!(matches!(i[1], Instr::Compute { d: Some(_), .. }));
    }
}
