//! Accelerator functional description (paper §3.2, Fig. 3).
//!
//! "Users define a hardware accelerator model comprising functional and
//! architectural descriptions." The architectural half is [`crate::arch`];
//! this module is the functional half: which operators the accelerator
//! supports (core computes + preprocessing) and the interface functions
//! used to offload work (hardware intrinsics, categorized into compute,
//! memory and configuration).
//!
//! The Rust analogue of the paper's Python decorators:
//!
//! ```ignore
//! AccelDesc::builder("gemmini", arch)
//!     .register_preprocessing("dense", Preprocessing::WeightTranspose)   // Fig 3(a)
//!     .register_core_compute(CoreCompute::quantized_gemm("dense"))       // Fig 3(b)
//!     .register_hw_intrinsic(HwIntrinsic::compute("gemmini_matmul", ..)) // Fig 3(c)
//!     .register_hw_intrinsic(HwIntrinsic::memory("gemmini_mvin", ..))    // Fig 3(d)
//!     .build()
//! ```
//!
//! Intrinsic implementations are plain functions from typed argument
//! structs to instruction sequences, so integrating a new accelerator
//! never requires touching the compiler's internals — the point of the
//! paper.

#![warn(missing_docs)]

pub mod gemmini;

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, ensure, Result};

use crate::arch::{ArchDesc, Dataflow};
use crate::isa::{Activation, Instr, LocalAddr};

/// Intrinsic categories (paper §3.2: "categorized into compute, memory,
/// and configuration intrinsics").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntrinsicClass {
    /// Fires the PE array (e.g. preload + matmul).
    Compute,
    /// Moves tiles between DRAM and on-chip memories.
    Memory,
    /// Sets machine state (dataflow, strides, requantization).
    Config,
}

/// Constant-related preprocessing registered for an operator (paper Fig.
/// 3a). Folded at compile time when the operand is constant; otherwise
/// executed on the host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preprocessing {
    /// Transpose weights from importer layout `[K,C]` to accelerator
    /// layout `[C,K]`.
    WeightTranspose,
    /// Flatten a 4-D activation into the dense 2-D shape.
    FlattenInput,
    /// im2col expansion for convolutions.
    Im2col,
}

/// A core computation registered for an operator tag (Fig. 3b): a
/// TE-style description the strategy generator binds to the generalized
/// relay operator.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreCompute {
    /// Operator tag ("dense", "conv2d").
    pub tag: String,
    /// The tensor-expression description (documentation + matching).
    pub einsum: String,
    /// Relay operator name this compute implements.
    pub relay_op: String,
}

impl CoreCompute {
    /// The quantized GEMM compute (dense layers; convs reach it via
    /// im2col preprocessing).
    pub fn quantized_gemm(tag: &str) -> CoreCompute {
        CoreCompute {
            tag: tag.to_string(),
            einsum: "O[n,k] = requant(sum(c, In[n,c] * W[c,k]) + B[k])".to_string(),
            relay_op: "accel.dense".to_string(),
        }
    }
}

/// Arguments handed to a compute-intrinsic implementation: one
/// instruction-tile GEMM `dst[rows×cols] (+)= A[rows×red] · B[red×cols]`.
#[derive(Debug, Clone, Copy)]
pub struct ComputeArgs {
    /// On-chip address of the streamed operand tile A.
    pub a: LocalAddr,
    /// On-chip address of the stationary operand tile B.
    pub b: LocalAddr,
    /// Accumulator destination tile.
    pub dst: LocalAddr,
    /// Rows of A (and of the destination).
    pub rows: u16,
    /// Reduction extent (cols of A / rows of B).
    pub red: u16,
    /// Cols of B (and of the destination).
    pub cols: u16,
    /// Whether the stationary tile must be (re)loaded into the array.
    pub preload: bool,
    /// Active dataflow (decides which operand is stationary).
    pub dataflow: Dataflow,
}

/// Arguments for a memory intrinsic (one strided tile transfer).
#[derive(Debug, Clone, Copy)]
pub struct MemArgs {
    /// DRAM byte offset of the tile's first row.
    pub dram: u64,
    /// On-chip address of the tile.
    pub local: LocalAddr,
    /// Rows to transfer.
    pub rows: u16,
    /// Elements per row.
    pub cols: u16,
    /// DRAM row stride in elements (0 = broadcast the same row).
    pub stride: u32,
}

/// Arguments for configuration intrinsics.
#[derive(Debug, Clone, Copy)]
pub struct ConfigArgs {
    /// Dataflow to configure the PE array for.
    pub dataflow: Dataflow,
    /// Output (store-pipeline) row stride in elements.
    pub st_stride: u32,
    /// Requantization scale applied on store.
    pub scale: f32,
    /// Activation fused into the store pipeline.
    pub act: Activation,
}

/// Implementation of an intrinsic: a plain function mapping typed
/// arguments to an instruction sequence.
#[derive(Clone, Copy)]
pub enum IntrinsicImpl {
    /// Emits one instruction-tile compute.
    Compute(fn(&ComputeArgs) -> Vec<Instr>),
    /// Emits one strided tile transfer.
    Memory(fn(&MemArgs) -> Vec<Instr>),
    /// Emits a configuration sequence.
    Config(fn(&ConfigArgs) -> Vec<Instr>),
}

impl std::fmt::Debug for IntrinsicImpl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IntrinsicImpl::Compute(_) => write!(f, "IntrinsicImpl::Compute(..)"),
            IntrinsicImpl::Memory(_) => write!(f, "IntrinsicImpl::Memory(..)"),
            IntrinsicImpl::Config(_) => write!(f, "IntrinsicImpl::Config(..)"),
        }
    }
}

/// A registered hardware intrinsic (Fig. 3c/3d).
#[derive(Debug, Clone)]
pub struct HwIntrinsic {
    /// Registered name (referenced by the codegen role bindings).
    pub name: String,
    /// Which of the three intrinsic categories this belongs to.
    pub class: IntrinsicClass,
    /// The emitting function.
    pub implementation: IntrinsicImpl,
}

impl HwIntrinsic {
    /// Register a compute intrinsic (Fig. 3c).
    pub fn compute(name: &str, f: fn(&ComputeArgs) -> Vec<Instr>) -> HwIntrinsic {
        HwIntrinsic {
            name: name.to_string(),
            class: IntrinsicClass::Compute,
            implementation: IntrinsicImpl::Compute(f),
        }
    }

    /// Register a memory intrinsic (Fig. 3d).
    pub fn memory(name: &str, f: fn(&MemArgs) -> Vec<Instr>) -> HwIntrinsic {
        HwIntrinsic {
            name: name.to_string(),
            class: IntrinsicClass::Memory,
            implementation: IntrinsicImpl::Memory(f),
        }
    }

    /// Register a configuration intrinsic.
    pub fn config(name: &str, f: fn(&ConfigArgs) -> Vec<Instr>) -> HwIntrinsic {
        HwIntrinsic {
            name: name.to_string(),
            class: IntrinsicClass::Config,
            implementation: IntrinsicImpl::Config(f),
        }
    }
}

/// The complete accelerator description: functional + architectural.
#[derive(Debug, Clone)]
pub struct AccelDesc {
    /// Display name of the accelerator (not part of the cache fingerprint).
    pub name: String,
    /// Registry id of the backend that lowers for this accelerator (see
    /// [`crate::backend::lookup`]). Part of the cache fingerprint: two
    /// descriptions differing only in backend never share schedule-cache
    /// entries.
    pub backend: String,
    /// The architectural half (array size, memories, timing, constraints).
    pub arch: ArchDesc,
    core: BTreeMap<String, CoreCompute>,
    preprocessing: BTreeMap<String, Vec<Preprocessing>>,
    intrinsics: BTreeMap<String, HwIntrinsic>,
    /// Name of the intrinsic codegen uses to fire the PE array.
    pub compute_intrinsic: String,
    /// Name of the intrinsic codegen uses for DRAM → on-chip loads.
    pub load_intrinsic: String,
    /// Name of the intrinsic codegen uses for on-chip → DRAM stores.
    pub store_intrinsic: String,
    /// Name of the intrinsic codegen uses for per-layer configuration.
    pub config_intrinsic: String,
}

impl AccelDesc {
    /// Start building a description (the decorator-API analogue).
    pub fn builder(name: &str, arch: ArchDesc) -> AccelDescBuilder {
        AccelDescBuilder {
            desc: AccelDesc {
                name: name.to_string(),
                backend: "gemmini".to_string(),
                arch,
                core: BTreeMap::new(),
                preprocessing: BTreeMap::new(),
                intrinsics: BTreeMap::new(),
                compute_intrinsic: String::new(),
                load_intrinsic: String::new(),
                store_intrinsic: String::new(),
                config_intrinsic: String::new(),
            },
        }
    }

    /// Relay operator names this accelerator supports (drives graph
    /// partitioning).
    pub fn supported_ops(&self) -> BTreeSet<String> {
        self.core.values().map(|c| c.relay_op.clone()).collect()
    }

    /// Stable textual representation of the functional description, used
    /// for schedule-cache fingerprinting: registered core computes,
    /// preprocessing, and the intrinsic registry with its role bindings.
    /// Intrinsic *behavior* is a function pointer and cannot be hashed
    /// portably; registered names + classes are the proxy, so two
    /// descriptions that bind different implementations under the same
    /// names are indistinguishable here (document accordingly).
    pub fn functional_repr(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for (tag, c) in &self.core {
            let _ = write!(s, "core({tag},{},{});", c.einsum, c.relay_op);
        }
        for (tag, ps) in &self.preprocessing {
            let _ = write!(s, "prep({tag},{ps:?});");
        }
        for (name, i) in &self.intrinsics {
            let _ = write!(s, "intr({name},{:?});", i.class);
        }
        let _ = write!(
            s,
            "roles({},{},{},{})",
            self.compute_intrinsic, self.load_intrinsic, self.store_intrinsic, self.config_intrinsic
        );
        let _ = write!(s, ";backend({})", self.backend);
        s
    }

    /// Resolve this description's backend implementation from the registry.
    pub fn backend_impl(&self) -> Result<&'static dyn crate::backend::Backend> {
        crate::backend::lookup(&self.backend)
    }

    /// The core compute registered under `tag` ("dense", "conv2d"), if any.
    pub fn core_compute(&self, tag: &str) -> Option<&CoreCompute> {
        self.core.get(tag)
    }

    /// The preprocessing steps registered for `tag` (empty if none).
    pub fn preprocessing(&self, tag: &str) -> &[Preprocessing] {
        self.preprocessing.get(tag).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Look up a registered intrinsic by name.
    pub fn intrinsic(&self, name: &str) -> Result<&HwIntrinsic> {
        self.intrinsics
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("intrinsic '{name}' not registered"))
    }

    /// All registered intrinsics, in name order.
    pub fn intrinsics(&self) -> impl Iterator<Item = &HwIntrinsic> {
        self.intrinsics.values()
    }

    /// Emit one compute tile via the registered compute intrinsic.
    pub fn emit_compute(&self, args: &ComputeArgs) -> Result<Vec<Instr>> {
        match self.intrinsic(&self.compute_intrinsic)?.implementation {
            IntrinsicImpl::Compute(f) => Ok(f(args)),
            _ => bail!("'{}' is not a compute intrinsic", self.compute_intrinsic),
        }
    }

    /// Emit one tile load / store via a registered memory intrinsic.
    pub fn emit_mem(&self, name: &str, args: &MemArgs) -> Result<Vec<Instr>> {
        match self.intrinsic(name)?.implementation {
            IntrinsicImpl::Memory(f) => Ok(f(args)),
            _ => bail!("'{name}' is not a memory intrinsic"),
        }
    }

    /// Emit the per-layer configuration sequence.
    pub fn emit_config(&self, args: &ConfigArgs) -> Result<Vec<Instr>> {
        match self.intrinsic(&self.config_intrinsic)?.implementation {
            IntrinsicImpl::Config(f) => Ok(f(args)),
            _ => bail!("'{}' is not a config intrinsic", self.config_intrinsic),
        }
    }

    fn validate(&self) -> Result<()> {
        ensure!(!self.core.is_empty(), "no core computes registered");
        for (role, name) in [
            ("compute", &self.compute_intrinsic),
            ("load", &self.load_intrinsic),
            ("store", &self.store_intrinsic),
            ("config", &self.config_intrinsic),
        ] {
            ensure!(!name.is_empty(), "no {role} intrinsic registered");
            ensure!(
                self.intrinsics.contains_key(name),
                "{role} intrinsic '{name}' not registered"
            );
        }
        self.arch.validate()?;
        Ok(())
    }
}

/// Builder mirroring the paper's decorator API.
#[derive(Debug)]
pub struct AccelDescBuilder {
    desc: AccelDesc,
}

impl AccelDescBuilder {
    /// Bind the backend registry id that lowers for this accelerator
    /// (defaults to `"gemmini"`).
    pub fn backend(mut self, id: &str) -> Self {
        self.desc.backend = id.to_string();
        self
    }

    /// `@register_core_compute(tag)` (Fig. 3b).
    pub fn register_core_compute(mut self, c: CoreCompute) -> Self {
        self.desc.core.insert(c.tag.clone(), c);
        self
    }

    /// `@register_preprocessing(tag)` (Fig. 3a).
    pub fn register_preprocessing(mut self, tag: &str, p: Preprocessing) -> Self {
        self.desc.preprocessing.entry(tag.to_string()).or_default().push(p);
        self
    }

    /// `@register_hw_intrinsic` (Fig. 3c/3d). The first registered
    /// intrinsic of each class becomes the default for its codegen role
    /// (loads before stores for memory intrinsics).
    pub fn register_hw_intrinsic(mut self, i: HwIntrinsic) -> Self {
        match i.class {
            IntrinsicClass::Compute if self.desc.compute_intrinsic.is_empty() => {
                self.desc.compute_intrinsic = i.name.clone();
            }
            IntrinsicClass::Memory if self.desc.load_intrinsic.is_empty() => {
                self.desc.load_intrinsic = i.name.clone();
            }
            IntrinsicClass::Memory if self.desc.store_intrinsic.is_empty() => {
                self.desc.store_intrinsic = i.name.clone();
            }
            IntrinsicClass::Config if self.desc.config_intrinsic.is_empty() => {
                self.desc.config_intrinsic = i.name.clone();
            }
            _ => {}
        }
        self.desc.intrinsics.insert(i.name.clone(), i);
        self
    }

    /// Validate and finish the description (all four codegen roles must be
    /// bound and the architecture must be well-formed).
    pub fn build(self) -> Result<AccelDesc> {
        self.desc.validate()?;
        Ok(self.desc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemmini_description_builds() {
        let d = gemmini::gemmini_desc().unwrap();
        assert_eq!(d.name, "gemmini");
        assert!(d.supported_ops().contains("accel.dense"));
        assert_eq!(d.preprocessing("dense"), &[Preprocessing::WeightTranspose]);
        assert!(d.intrinsic("gemmini_matmul").is_ok());
        assert!(d.intrinsic("nope").is_err());
    }

    #[test]
    fn backend_id_defaults_and_fingerprints() {
        let d = gemmini::gemmini_desc().unwrap();
        assert_eq!(d.backend, "gemmini");
        assert!(d.functional_repr().contains("backend(gemmini)"));
        assert_eq!(d.backend_impl().unwrap().id(), "gemmini");
    }

    #[test]
    fn builder_requires_all_roles() {
        let arch = ArchDesc::gemmini();
        let r = AccelDesc::builder("x", arch)
            .register_core_compute(CoreCompute::quantized_gemm("dense"))
            .build();
        assert!(r.is_err()); // no intrinsics registered
    }

    #[test]
    fn compute_emission_roundtrip() {
        let d = gemmini::gemmini_desc().unwrap();
        let args = ComputeArgs {
            a: LocalAddr::spad(0),
            b: LocalAddr::spad(64),
            dst: LocalAddr::acc_accumulate(0),
            rows: 16,
            red: 16,
            cols: 16,
            preload: true,
            dataflow: Dataflow::WeightStationary,
        };
        let instrs = d.emit_compute(&args).unwrap();
        assert_eq!(instrs.len(), 2); // preload + compute
        assert_eq!(instrs[0].mnemonic(), "preload");
        assert_eq!(instrs[1].mnemonic(), "compute_preloaded");
        let no_preload = d.emit_compute(&ComputeArgs { preload: false, ..args }).unwrap();
        assert_eq!(no_preload.len(), 1);
        assert_eq!(no_preload[0].mnemonic(), "compute_accumulated");
    }
}
