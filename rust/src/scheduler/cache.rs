//! Content-addressed schedule cache.
//!
//! Schedule selection (Fig. 2(b) sweep + simulator profiling) is the
//! compile-time hot path: for ToyCar-class edge models most layers share a
//! handful of `Gemm` shapes, and a long-lived [`crate::pipeline::Compiler`]
//! sees the same shapes again across models. The selected schedule depends
//! only on the *architecture*, the *workload shape* and the *search
//! options*, so the cache key is exactly that triple:
//!
//! * [`accel_fingerprint`] — a hash over every description parameter that
//!   can influence scheduling: the architectural half (PE dim, dataflows,
//!   memory levels, DMA/host timing, constraints) plus the functional half
//!   (registered computes/preprocessing and intrinsic role bindings, which
//!   the profiling path compiles through). The accelerator's display name
//!   is deliberately excluded: two differently-named descriptions of the
//!   same machine share entries, while any parameter change moves to a
//!   fresh key.
//! * the [`Gemm`] shape;
//! * a [`SearchKey`] of the sweep options plus the profiling depth.
//!
//! Whether the sweep runs serially or in parallel is *not* part of the key:
//! the parallel sweep is guaranteed (and tested) to return the identical
//! candidate list as the serial one.
//!
//! Because the accelerator enters the key only through its fingerprint,
//! one cache instance can serve *several* accelerator descriptions at
//! once: a [`crate::pipeline::MultiCompiler`] shares a single cache across
//! its candidate targets, so the cost probes its partition stage runs per
//! (layer, candidate) are the same searches its schedule stage would run,
//! and each is paid once. Two candidates that describe the same machine
//! (identical fingerprints) even share entries outright.
//!
//! Two extensions serve the long-lived compile service
//! ([`crate::service`]):
//!
//! * **Single-flight search gating** ([`ScheduleCache::begin`]): when
//!   several threads miss on the same key at once — concurrent compile
//!   requests sharing a layer shape — exactly one becomes the *leader*
//!   and runs the search; the rest block until the leader
//!   [`ScheduleCache::publish`]es and are then served the entry as a hit.
//! * **Persistence hooks** ([`ScheduleCache::snapshot`] /
//!   [`ScheduleCache::hydrate`]): entries are pure data, so they can be
//!   serialized to the on-disk artifact in [`super::persist`] and loaded
//!   back into a cold process.

use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::accel::AccelDesc;
use crate::arch::ArchDesc;
use crate::workload::Gemm;

use super::graph::ResidencyConstraint;
use super::sweep::SweepOptions;
use super::Schedule;

/// Wall-clock seconds since the Unix epoch — the last-served stamp the
/// persisted artifact records for LRU trimming (`tvm-accel cache gc`).
fn now_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

fn hash_str(s: &str) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    s.hash(&mut h);
    h.finish()
}

/// Textual form of the scheduling-relevant architectural parameters.
fn arch_repr(arch: &ArchDesc) -> String {
    let mut repr = String::new();
    let _ = write!(repr, "pe={};dataflows={:?};", arch.pe_dim, arch.dataflows);
    for l in &arch.levels {
        let _ = write!(
            repr,
            "level({},{:?},{},{:?},{:?});",
            l.name, l.kind, l.size_bytes, l.residents, l.elem_bytes
        );
    }
    let _ = write!(
        repr,
        "dma={:?};host={:?};constraints={:?}",
        arch.dma, arch.host, arch.constraints
    );
    repr
}

/// Hash of the scheduling-relevant architectural parameters.
pub fn arch_fingerprint(arch: &ArchDesc) -> u64 {
    hash_str(&arch_repr(arch))
}

/// Hash of everything about an accelerator description that can influence
/// a schedule selection: the architectural parameters plus the functional
/// description (registered computes/preprocessing and intrinsic role
/// bindings — profiling compiles the layer through those intrinsics).
/// Intrinsic implementations are function pointers and enter only by
/// registered name/class.
pub fn accel_fingerprint(accel: &AccelDesc) -> u64 {
    hash_str(&format!("{}##{}", arch_repr(&accel.arch), accel.functional_repr()))
}

/// The search-option half of the cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SearchKey {
    /// Candidates kept per sweep configuration point.
    pub top_k_per_config: usize,
    /// Global cap on candidates returned by the sweep.
    pub max_candidates: usize,
    /// Whether uneven memory shares were explored.
    pub uneven_mapping: bool,
    /// Whether double buffering was explored.
    pub double_buffering: bool,
    /// How many top candidates were profiled on the simulator.
    pub profile_candidates: usize,
}

impl SearchKey {
    /// The key half derived from the sweep options + profiling depth.
    pub fn new(sweep: &SweepOptions, profile_candidates: usize) -> SearchKey {
        SearchKey {
            top_k_per_config: sweep.top_k_per_config,
            max_candidates: sweep.max_candidates,
            uneven_mapping: sweep.uneven_mapping,
            double_buffering: sweep.double_buffering,
            profile_candidates,
        }
    }
}

/// Full cache key: accelerator fingerprint + workload shape + search
/// options (see [`accel_fingerprint`]). Keys are totally ordered so
/// persisted cache files are written in a deterministic entry order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    /// [`accel_fingerprint`] of the target description.
    pub arch: u64,
    /// The layer's workload shape.
    pub gemm: Gemm,
    /// The search options used for the selection.
    pub search: SearchKey,
    /// The cross-layer residency constraint the search ran under
    /// ([`ResidencyConstraint::NONE`] for the ordinary per-layer search).
    /// Boundary-constrained selections are memoized — and persisted —
    /// under their own keys, so re-compiling a graph with resident edges
    /// is as warm as re-compiling one without.
    pub residency: ResidencyConstraint,
}

impl CacheKey {
    /// The key of an ordinary (unconstrained) per-layer selection.
    pub fn unconstrained(arch: u64, gemm: Gemm, search: SearchKey) -> CacheKey {
        CacheKey { arch, gemm, search, residency: ResidencyConstraint::NONE }
    }
}

/// A cached selection: the winning schedule and, when profiling ran, its
/// measured cycle count.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedSelection {
    /// The winning schedule.
    pub schedule: Schedule,
    /// Measured cycles of that schedule, when profiling ran.
    pub profiled_cycles: Option<u64>,
}

/// Hit/miss counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that missed (and typically triggered a search).
    pub misses: u64,
    /// Selections currently stored.
    pub entries: usize,
}

/// Outcome of [`ScheduleCache::begin`]: either the selection is ready
/// (a hit, possibly after waiting out another thread's in-flight search)
/// or the caller has been elected leader and must run the search itself.
#[derive(Debug)]
pub enum SearchGate {
    /// The caller owns the search for this key: run it, then call
    /// [`ScheduleCache::publish`] on success or [`ScheduleCache::abandon`]
    /// on failure (so blocked followers can take over).
    Leader,
    /// The selection is available and was counted as a hit.
    Ready(CachedSelection),
}

/// Thread-safe schedule cache. Interior mutability so the compiler can
/// consult it from `&self` (and from profiling worker threads).
#[derive(Debug, Default)]
pub struct ScheduleCache {
    map: Mutex<HashMap<CacheKey, CachedSelection>>,
    /// Last-served wall-clock stamp per key (updated on every hit and on
    /// publish/insert), persisted for LRU trimming. Kept beside `map`
    /// rather than inside the values so selections stay pure data.
    stamps: Mutex<HashMap<CacheKey, u64>>,
    /// Keys whose search is currently running somewhere (single-flight
    /// gate); waiters block on `inflight_cv`.
    inflight: Mutex<HashSet<CacheKey>>,
    inflight_cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScheduleCache {
    /// An empty cache with zeroed counters.
    pub fn new() -> ScheduleCache {
        ScheduleCache::default()
    }

    /// Refresh `key`'s last-served stamp. This costs a second (uncontended
    /// in practice) lock plus a clock read per hit; keeping the stamps out
    /// of `map` keeps selections pure data for snapshot/persist. Fold the
    /// stamp into the map entries if hit-path contention ever shows up in
    /// profiles.
    fn touch(&self, key: &CacheKey) {
        self.stamps.lock().expect("schedule cache poisoned").insert(*key, now_secs());
    }

    /// Look up a selection, counting the hit or miss (a hit refreshes the
    /// key's last-served stamp).
    pub fn get(&self, key: &CacheKey) -> Option<CachedSelection> {
        let found = self.map.lock().expect("schedule cache poisoned").get(key).cloned();
        match &found {
            Some(_) => {
                self.touch(key);
                self.hits.fetch_add(1, Ordering::Relaxed)
            }
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store a selection under `key` (overwrites an existing entry).
    pub fn insert(&self, key: CacheKey, value: CachedSelection) {
        self.map.lock().expect("schedule cache poisoned").insert(key, value);
        self.touch(&key);
    }

    /// Whether `key` is stored, *without* touching the hit/miss counters
    /// (a planning peek — the compile service uses it to skip scheduling
    /// work for already-warm shapes without skewing request accounting).
    pub fn contains(&self, key: &CacheKey) -> bool {
        self.map.lock().expect("schedule cache poisoned").contains_key(key)
    }

    /// Single-flight lookup: returns [`SearchGate::Ready`] on a hit
    /// (counted as a hit, even when the caller had to wait for another
    /// thread's in-flight search on the same key) or elects the caller
    /// leader for the key (counted as a miss). A leader **must** follow up
    /// with [`ScheduleCache::publish`] or [`ScheduleCache::abandon`];
    /// dropping the obligation would block every later `begin` on the key.
    pub fn begin(&self, key: &CacheKey) -> SearchGate {
        let mut inflight = self.inflight.lock().expect("schedule cache poisoned");
        loop {
            // Re-check the map on every wakeup: the leader publishes the
            // entry before clearing the in-flight mark.
            let hit =
                self.map.lock().expect("schedule cache poisoned").get(key).cloned();
            if let Some(hit) = hit {
                self.touch(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return SearchGate::Ready(hit);
            }
            if !inflight.contains(key) {
                inflight.insert(*key);
                self.misses.fetch_add(1, Ordering::Relaxed);
                return SearchGate::Leader;
            }
            inflight =
                self.inflight_cv.wait(inflight).expect("schedule cache poisoned");
        }
    }

    /// Complete a leader's search: store the selection and release every
    /// thread blocked in [`ScheduleCache::begin`] on the same key.
    pub fn publish(&self, key: CacheKey, value: CachedSelection) {
        self.map.lock().expect("schedule cache poisoned").insert(key, value);
        self.touch(&key);
        self.inflight.lock().expect("schedule cache poisoned").remove(&key);
        self.inflight_cv.notify_all();
    }

    /// Give up a leadership claimed via [`ScheduleCache::begin`] without
    /// publishing (the search failed). One blocked follower is promoted to
    /// leader and will retry the search.
    pub fn abandon(&self, key: &CacheKey) {
        self.inflight.lock().expect("schedule cache poisoned").remove(key);
        self.inflight_cv.notify_all();
    }

    /// Clone out every stored entry, sorted by key, so persisted cache
    /// files are deterministic for identical contents.
    pub fn snapshot(&self) -> Vec<(CacheKey, CachedSelection)> {
        self.snapshot_stamped().into_iter().map(|(k, v, _)| (k, v)).collect()
    }

    /// [`ScheduleCache::snapshot`] with each entry's last-served stamp
    /// (0 when the entry was never served or stamped).
    pub fn snapshot_stamped(&self) -> Vec<(CacheKey, CachedSelection, u64)> {
        // Lock order: map before stamps, matching `hydrate_stamped`.
        let map = self.map.lock().expect("schedule cache poisoned");
        let stamps = self.stamps.lock().expect("schedule cache poisoned");
        let mut out: Vec<(CacheKey, CachedSelection, u64)> = map
            .iter()
            .map(|(k, v)| (*k, v.clone(), stamps.get(k).copied().unwrap_or(0)))
            .collect();
        drop(stamps);
        drop(map);
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Bulk-insert entries (disk hydration). Does not touch the hit/miss
    /// counters — hydrated entries only count when a lookup serves them.
    /// Returns the number of entries inserted.
    pub fn hydrate<I: IntoIterator<Item = (CacheKey, CachedSelection)>>(
        &self,
        entries: I,
    ) -> usize {
        self.hydrate_stamped(entries.into_iter().map(|(k, v)| (k, v, 0)))
    }

    /// [`ScheduleCache::hydrate`] preserving each entry's persisted
    /// last-served stamp (so LRU age survives process restarts).
    pub fn hydrate_stamped<I: IntoIterator<Item = (CacheKey, CachedSelection, u64)>>(
        &self,
        entries: I,
    ) -> usize {
        let mut map = self.map.lock().expect("schedule cache poisoned");
        let mut stamps = self.stamps.lock().expect("schedule cache poisoned");
        let mut n = 0;
        for (k, v, stamp) in entries {
            map.insert(k, v);
            if stamp > 0 {
                stamps.insert(k, stamp);
            }
            n += 1;
        }
        n
    }

    /// Number of stored selections.
    pub fn len(&self) -> usize {
        self.map.lock().expect("schedule cache poisoned").len()
    }

    /// Whether the cache holds no selections.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every stored selection (counters are kept).
    pub fn clear(&self) {
        self.map.lock().expect("schedule cache poisoned").clear();
        self.stamps.lock().expect("schedule cache poisoned").clear();
    }

    /// Snapshot of the hit/miss/entry counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;
    use crate::scheduler::Estimate;
    use crate::workload::Dim;

    fn dummy_schedule(g: Gemm) -> Schedule {
        Schedule {
            workload: g,
            dataflow: Dataflow::WeightStationary,
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: [1, 1, 1],
            onchip_tile: [1, 1, 1],
            dram_order: [Dim::N, Dim::C, Dim::K],
            est: Estimate::default(),
        }
    }

    fn key(arch: u64, g: Gemm) -> CacheKey {
        CacheKey::unconstrained(arch, g, SearchKey::new(&SweepOptions::default(), 6))
    }

    #[test]
    fn hit_and_miss_semantics() {
        let cache = ScheduleCache::new();
        let g = Gemm::new(8, 8, 8);
        assert!(cache.get(&key(1, g)).is_none());
        cache.insert(
            key(1, g),
            CachedSelection { schedule: dummy_schedule(g), profiled_cycles: Some(42) },
        );
        let hit = cache.get(&key(1, g)).expect("hit");
        assert_eq!(hit.profiled_cycles, Some(42));
        assert_eq!(hit.schedule.workload, g);
        // Different shape, different arch, different options: all misses.
        assert!(cache.get(&key(1, Gemm::new(8, 8, 16))).is_none());
        assert!(cache.get(&key(2, g)).is_none());
        let mut k = key(1, g);
        k.search.profile_candidates = 0;
        assert!(cache.get(&k).is_none());
        let stats = cache.stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 4);
        assert_eq!(stats.entries, 1);
    }

    #[test]
    fn fingerprint_ignores_name_but_not_parameters() {
        let a = ArchDesc::gemmini();
        let mut renamed = a.clone();
        renamed.name = "other".into();
        assert_eq!(arch_fingerprint(&a), arch_fingerprint(&renamed));

        let mut bigger = a.clone();
        bigger.pe_dim = 32;
        assert_ne!(arch_fingerprint(&a), arch_fingerprint(&bigger));

        let mut more_mem = a.clone();
        more_mem.levels[2].size_bytes *= 2;
        assert_ne!(arch_fingerprint(&a), arch_fingerprint(&more_mem));

        let mut no_db = a;
        no_db.constraints.supports_double_buffering = false;
        assert_ne!(arch_fingerprint(&no_db), arch_fingerprint(&ArchDesc::gemmini()));
    }

    #[test]
    fn accel_fingerprint_covers_functional_description() {
        use crate::accel::gemmini::{desc_for_arch, gemmini_desc};

        let a = gemmini_desc().unwrap();
        // Same registrations + same arch under a different display name:
        // identical fingerprint.
        let renamed = desc_for_arch("other-name", ArchDesc::gemmini()).unwrap();
        assert_eq!(accel_fingerprint(&a), accel_fingerprint(&renamed));

        // A different architecture moves the fingerprint.
        let mut arch = ArchDesc::gemmini();
        arch.pe_dim = 8;
        arch.constraints.insn_tile_limit = 8;
        let smaller = desc_for_arch("gemmini", arch).unwrap();
        assert_ne!(accel_fingerprint(&a), accel_fingerprint(&smaller));

        // Rebinding an intrinsic role moves the fingerprint even with the
        // architecture unchanged (profiling depends on the bound intrinsic).
        let mut rebound = gemmini_desc().unwrap();
        rebound.compute_intrinsic = "gemmini_mvin".into();
        assert_ne!(accel_fingerprint(&a), accel_fingerprint(&rebound));
    }

    #[test]
    fn single_flight_elects_one_leader_and_serves_followers() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;

        let cache = Arc::new(ScheduleCache::new());
        let g = Gemm::new(16, 16, 16);
        let k = key(11, g);
        // First begin() is the leader; a parallel begin() must block until
        // publish and then observe the entry as a hit.
        assert!(matches!(cache.begin(&k), SearchGate::Leader));
        let leaders = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..4 {
                let cache = cache.clone();
                let leaders = leaders.clone();
                handles.push(scope.spawn(move || match cache.begin(&k) {
                    SearchGate::Leader => {
                        leaders.fetch_add(1, Ordering::Relaxed);
                        None
                    }
                    SearchGate::Ready(hit) => Some(hit),
                }));
            }
            // Give the followers a moment to block, then publish.
            std::thread::sleep(std::time::Duration::from_millis(20));
            cache.publish(
                k,
                CachedSelection { schedule: dummy_schedule(g), profiled_cycles: Some(7) },
            );
            for h in handles {
                let got = h.join().expect("follower panicked");
                assert_eq!(got.expect("served from cache").profiled_cycles, Some(7));
            }
        });
        assert_eq!(leaders.load(Ordering::Relaxed), 0, "only one leader per key");
        let stats = cache.stats();
        assert_eq!(stats.misses, 1, "one miss for the single leader");
        assert_eq!(stats.hits, 4, "every follower is a hit");
    }

    #[test]
    fn abandon_promotes_a_new_leader() {
        let cache = ScheduleCache::new();
        let g = Gemm::new(8, 8, 8);
        let k = key(3, g);
        assert!(matches!(cache.begin(&k), SearchGate::Leader));
        cache.abandon(&k);
        // The key is searchable again (and counted as a second miss).
        assert!(matches!(cache.begin(&k), SearchGate::Leader));
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn snapshot_is_sorted_and_hydrate_restores() {
        let cache = ScheduleCache::new();
        let shapes = [Gemm::new(32, 8, 8), Gemm::new(4, 4, 4), Gemm::new(16, 16, 8)];
        for (i, g) in shapes.iter().enumerate() {
            cache.insert(
                key(9, *g),
                CachedSelection {
                    schedule: dummy_schedule(*g),
                    profiled_cycles: Some(i as u64),
                },
            );
        }
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 3);
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0, "snapshot must be key-sorted");
        }
        let fresh = ScheduleCache::new();
        assert_eq!(fresh.hydrate(snap.clone()), 3);
        assert_eq!(fresh.snapshot(), snap);
        let stats = fresh.stats();
        assert_eq!((stats.hits, stats.misses), (0, 0), "hydration is not a lookup");
    }

    #[test]
    fn residency_constraint_distinguishes_keys() {
        use crate::scheduler::graph::ResidencyConstraint;
        let cache = ScheduleCache::new();
        let g = Gemm::new(16, 16, 16);
        let unconstrained = key(5, g);
        cache.insert(
            unconstrained,
            CachedSelection { schedule: dummy_schedule(g), profiled_cycles: Some(1) },
        );
        let mut constrained = unconstrained;
        constrained.residency =
            ResidencyConstraint { in_block: 16, out_block: 0, reserved_rows: 8 };
        assert!(cache.get(&constrained).is_none(), "constraint must be part of the key");
        assert!(cache.get(&unconstrained).is_some());
    }

    #[test]
    fn stamps_follow_hits_and_survive_stamped_hydration() {
        let cache = ScheduleCache::new();
        let g = Gemm::new(8, 8, 8);
        cache.insert(
            key(1, g),
            CachedSelection { schedule: dummy_schedule(g), profiled_cycles: None },
        );
        let snap = cache.snapshot_stamped();
        assert_eq!(snap.len(), 1);
        assert!(snap[0].2 > 0, "insert must stamp the entry");
        // Hydrating with explicit stamps preserves them; plain hydration
        // leaves entries unstamped (age unknown).
        let aged: Vec<_> =
            snap.iter().map(|(k, v, _)| (*k, v.clone(), 12345u64)).collect();
        let fresh = ScheduleCache::new();
        fresh.hydrate_stamped(aged);
        assert_eq!(fresh.snapshot_stamped()[0].2, 12345);
        let cold = ScheduleCache::new();
        cold.hydrate(cache.snapshot());
        assert_eq!(cold.snapshot_stamped()[0].2, 0);
        // Serving the entry refreshes the stamp.
        assert!(cold.get(&key(1, g)).is_some());
        assert!(cold.snapshot_stamped()[0].2 > 0);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let cache = ScheduleCache::new();
        let g = Gemm::new(4, 4, 4);
        cache.insert(
            key(7, g),
            CachedSelection { schedule: dummy_schedule(g), profiled_cycles: None },
        );
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.get(&key(7, g)).is_none());
    }
}
