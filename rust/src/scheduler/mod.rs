//! Extended CoSA scheduler (paper §3.1).
//!
//! CoSA formulates DNN scheduling for spatial accelerators as constrained
//! optimization over a binary assignment `X[j, n, i, k]`: prime factor `n`
//! of loop-bound dimension `j` is mapped to memory/permutation level `i` as
//! spatial (`k=0`) or temporal (`k=1`). This module implements that
//! formulation for GEMM workloads with the paper's extensions:
//!
//! * **Instruction-set constraint (Eq. 1)** — at the PE-array level the
//!   spatial *and* temporal bounds per dimension may not exceed `DIM`,
//!   because one compute instruction covers at most a DIM-sized tile:
//!   `Σ_{n,k} log(prime_factor_{J,n}) · X_{J,n,I,k} ≤ log(DIM)`.
//! * **Dataflow constraints** — the spatial dims at the array are fixed by
//!   the accelerator's dataflow (WS: C×K, OS: N×K), not free variables.
//! * **Uneven mapping** — CoSA's per-level memory-share array becomes a
//!   swept tuning parameter: each configuration grants different fractions
//!   of each on-chip memory to Input/Weight/Output.
//! * **Double buffering** — when enabled, usable capacity per operand is
//!   halved so ping/pong tiles both fit.
//!
//! The solver ([`solver`]) performs exact branch-and-bound over the
//! exponent-grouped assignment (equivalent to the MIP, no commercial
//! solver needed), the analytic cost model lives in [`traffic`], and
//! [`sweep`] runs the Fig. 2(b) outer loop over dataflows × memory shares
//! × double buffering, returning candidates for on-hardware (simulator)
//! profiling.

#![warn(missing_docs)]

pub mod cache;
pub mod graph;
pub mod persist;
pub mod solver;
pub mod sweep;
pub mod traffic;

use std::fmt;

use crate::arch::{ArchDesc, Dataflow};
use crate::workload::{Dim, Gemm};

/// Analytic estimates attached to a schedule (used for ranking candidates
/// before simulator profiling picks the winner).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Estimate {
    /// Execute-queue busy cycles (preload + compute streaming).
    pub compute_cycles: f64,
    /// DMA busy cycles (all operand traffic).
    pub dma_cycles: f64,
    /// Host front-end issue cycles.
    pub issue_cycles: f64,
    /// Estimated end-to-end latency.
    pub latency: f64,
    /// DRAM traffic per operand in bytes (Input, Weight, Output).
    pub bytes: [f64; 3],
    /// Spatial utilization of the PE array in [0, 1].
    pub utilization: f64,
}

impl Estimate {
    /// Composite objective (lower is better): latency first, then light
    /// traffic and engine-occupancy tiebreakers (CoSA's "utilization +
    /// traffic" style) so overlap-hidden work still prefers fewer
    /// instructions and less data movement.
    pub fn cost(&self) -> f64 {
        self.latency
            + 1e-3 * (self.bytes[0] + self.bytes[1] + self.bytes[2])
            + 1e-4 * (self.compute_cycles + self.issue_cycles)
    }
}

/// A complete mapping decision for one GEMM on one accelerator
/// configuration — the information CoSA emits per memory level ("tile
/// factors and the ordering of tensor dimensions", §3.3 Mapping Generator).
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// The GEMM this schedule maps.
    pub workload: Gemm,
    /// Dataflow of the PE array for this mapping (fixes the spatial dims).
    pub dataflow: Dataflow,
    /// Whether ping/pong tile buffers overlap transfer with compute.
    pub double_buffer: bool,
    /// Memory shares (Input, Weight, Output) used for this mapping.
    pub shares: [f64; 3],
    /// Instruction-level tile `(n0, c0, k0)`: the per-compute-instruction
    /// bounds; every entry ≤ DIM (Eq. 1).
    pub insn_tile: [usize; 3],
    /// On-chip tile `(nt, ct, kt)`: elements resident per operand tile in
    /// scratchpad/accumulator; multiples of the instruction tile.
    pub onchip_tile: [usize; 3],
    /// DRAM-level loop order, outermost first, over on-chip tiles.
    pub dram_order: [Dim; 3],
    /// Analytic cost estimates the sweep attached to this candidate.
    pub est: Estimate,
}

impl Schedule {
    /// Trip count of the DRAM-level loop over dimension `d`.
    pub fn dram_trips(&self, d: Dim) -> usize {
        let b = self.workload.bound(d);
        let t = self.onchip_tile[d.index()];
        crate::util::ceil_div(b, t)
    }

    /// Trip count of the on-chip loop over `d` (instruction tiles per
    /// on-chip tile).
    pub fn onchip_trips(&self, d: Dim) -> usize {
        crate::util::ceil_div(self.onchip_tile[d.index()], self.insn_tile[d.index()])
    }

    /// Validate the schedule against the architecture and workload. These
    /// are exactly the MIP constraints; property tests check every emitted
    /// schedule satisfies them.
    pub fn validate(&self, arch: &ArchDesc) -> anyhow::Result<()> {
        use anyhow::ensure;
        let g = &self.workload;
        for d in Dim::ALL {
            let j = d.index();
            // Factor chain: insn | onchip | bound.
            ensure!(self.insn_tile[j] >= 1, "{d}: empty instruction tile");
            ensure!(
                self.onchip_tile[j] % self.insn_tile[j] == 0,
                "{d}: on-chip tile {} not a multiple of instruction tile {}",
                self.onchip_tile[j],
                self.insn_tile[j]
            );
            ensure!(
                self.onchip_tile[j] <= g.bound(d),
                "{d}: on-chip tile exceeds bound"
            );
            // Eq. (1): instruction tile within DIM at the PE-array level.
            ensure!(
                self.insn_tile[j] <= arch.constraints.insn_tile_limit,
                "{d}: instruction tile {} violates Eq.(1) limit {}",
                self.insn_tile[j],
                arch.constraints.insn_tile_limit
            );
        }
        // Dataflow: spatial dims live on the array; their instruction tile
        // is the spatial extent and must fit the physical array.
        for d in self.dataflow.spatial_dims() {
            ensure!(
                self.insn_tile[d.index()] <= arch.pe_dim,
                "{d}: spatial extent {} exceeds PE dim {}",
                self.insn_tile[d.index()],
                arch.pe_dim
            );
        }
        // Capacity constraints (with uneven shares and double buffering).
        let caps = capacity_rows(arch, &self.shares, self.double_buffer);
        let rows = footprint_rows(arch, &self.onchip_tile, &self.insn_tile);
        for (op_idx, (need, cap)) in rows.iter().zip(caps.iter()).enumerate() {
            ensure!(
                need <= cap,
                "operand {op_idx}: tile needs {need} rows, share allows {cap}"
            );
        }
        Ok(())
    }

    /// Render in CoSA's output style: tile factors + permutation per level.
    pub fn to_yaml(&self) -> String {
        let g = &self.workload;
        let mut s = String::new();
        s.push_str(&format!("# schedule for GEMM N={} C={} K={}\n", g.n, g.c, g.k));
        s.push_str(&format!("dataflow: {}\n", self.dataflow));
        s.push_str(&format!("double_buffer: {}\n", self.double_buffer));
        s.push_str(&format!(
            "memory_shares: [{}, {}, {}]\n",
            self.shares[0], self.shares[1], self.shares[2]
        ));
        s.push_str("levels:\n");
        s.push_str("  - name: PEArray\n");
        s.push_str(&format!(
            "    tile: [{}, {}, {}]\n",
            self.insn_tile[0], self.insn_tile[1], self.insn_tile[2]
        ));
        let sd = self.dataflow.spatial_dims();
        s.push_str(&format!("    spatial: [{}, {}]\n", sd[0], sd[1]));
        s.push_str("  - name: OnChip\n");
        s.push_str(&format!(
            "    tile: [{}, {}, {}]\n",
            self.onchip_tile[0], self.onchip_tile[1], self.onchip_tile[2]
        ));
        s.push_str("  - name: DRAM\n");
        s.push_str(&format!(
            "    permutation: [{}, {}, {}]\n",
            self.dram_order[0], self.dram_order[1], self.dram_order[2]
        ));
        s.push_str(&format!(
            "    trips: [{}, {}, {}]\n",
            self.dram_trips(self.dram_order[0]),
            self.dram_trips(self.dram_order[1]),
            self.dram_trips(self.dram_order[2])
        ));
        s
    }
}

impl fmt::Display for Schedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {} insn=({},{},{}) onchip=({},{},{}) order={}{}{} db={} est={:.0}cy",
            self.workload,
            self.dataflow,
            self.insn_tile[0],
            self.insn_tile[1],
            self.insn_tile[2],
            self.onchip_tile[0],
            self.onchip_tile[1],
            self.onchip_tile[2],
            self.dram_order[0],
            self.dram_order[1],
            self.dram_order[2],
            self.double_buffer,
            self.est.latency,
        )
    }
}

/// Per-operand capacity budget in on-chip *rows* (DIM-wide), honoring the
/// memory-share array and double buffering. Indexed by `Operand::index()`.
pub fn capacity_rows(arch: &ArchDesc, shares: &[f64; 3], double_buffer: bool) -> [usize; 3] {
    use crate::workload::Operand;
    let mut caps = [0usize; 3];
    for op in Operand::ALL {
        let li = arch.feed_level(op).expect("validated arch");
        let level = &arch.levels[li];
        let row_bytes = arch.pe_dim * level.elem_bytes[op.index()];
        let total_rows = level.size_bytes / row_bytes;
        let mut cap = (total_rows as f64 * shares[op.index()]).floor() as usize;
        if double_buffer {
            cap /= 2;
        }
        caps[op.index()] = cap;
    }
    caps
}

/// Rows occupied by each operand's on-chip tile, matching the codegen's
/// layout: tiles are stored in column blocks of the *instruction tile*
/// width (so a compute never straddles blocks). Indexed by
/// `Operand::index()`. `insn` defaults effectively to DIM-wide blocks when
/// the instruction tile saturates the array.
pub fn footprint_rows(arch: &ArchDesc, tile: &[usize; 3], insn: &[usize; 3]) -> [usize; 3] {
    use crate::util::ceil_div;
    let _ = arch;
    let [n, c, k] = *tile;
    let [_, c0, k0] = *insn;
    [
        n * ceil_div(c, c0.max(1)), // Input  n×c int8 rows, c0-wide blocks
        c * ceil_div(k, k0.max(1)), // Weight c×k int8 rows, k0-wide blocks
        n * ceil_div(k, k0.max(1)), // Output n×k int32 accumulator rows
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(insn: [usize; 3], onchip: [usize; 3]) -> Schedule {
        Schedule {
            workload: Gemm::new(64, 64, 64),
            dataflow: Dataflow::WeightStationary,
            double_buffer: false,
            shares: [0.5, 0.5, 1.0],
            insn_tile: insn,
            onchip_tile: onchip,
            dram_order: [Dim::N, Dim::C, Dim::K],
            est: Estimate::default(),
        }
    }

    #[test]
    fn valid_schedule_passes() {
        let arch = ArchDesc::gemmini();
        sched([16, 16, 16], [64, 64, 64]).validate(&arch).unwrap();
    }

    #[test]
    fn eq1_violation_caught() {
        let arch = ArchDesc::gemmini();
        let s = sched([32, 16, 16], [64, 64, 64]);
        assert!(s.validate(&arch).is_err());
    }

    #[test]
    fn non_multiple_tiles_caught() {
        let arch = ArchDesc::gemmini();
        let s = sched([16, 16, 16], [40, 64, 64]);
        assert!(s.validate(&arch).is_err());
    }

    #[test]
    fn capacity_violation_caught() {
        let arch = ArchDesc::gemmini();
        // A 512×512 int8 weight tile = 512×32 = 16384 rows > the 8192-row
        // half-scratchpad share.
        let s = Schedule {
            workload: Gemm::new(512, 512, 512),
            insn_tile: [16, 16, 16],
            onchip_tile: [16, 512, 512],
            ..sched([16, 16, 16], [16, 512, 512])
        };
        assert!(s.validate(&arch).is_err());
    }

    #[test]
    fn capacity_rows_shares_and_db() {
        let arch = ArchDesc::gemmini();
        // Scratchpad: 256 KiB / 16 B rows = 16384 rows; accumulator:
        // 64 KiB / 64 B rows = 1024 rows.
        let even = capacity_rows(&arch, &[0.5, 0.5, 1.0], false);
        assert_eq!(even, [8192, 8192, 1024]);
        let db = capacity_rows(&arch, &[0.5, 0.5, 1.0], true);
        assert_eq!(db, [4096, 4096, 512]);
        let uneven = capacity_rows(&arch, &[0.25, 0.75, 1.0], false);
        assert_eq!(uneven, [4096, 12288, 1024]);
    }

    #[test]
    fn footprint_rows_layout() {
        let arch = ArchDesc::gemmini();
        // tile (64, 64, 64) with a full 16x16x16 instruction tile: input
        // 64*ceil(64/16)=256 rows; weight same; output 64*4 = 256 acc rows.
        let full = [16usize, 16, 16];
        assert_eq!(footprint_rows(&arch, &[64, 64, 64], &full), [256, 256, 256]);
        assert_eq!(footprint_rows(&arch, &[1, 640, 128], &full), [40, 5120, 8]);
        // Narrower instruction tiles waste row space (c0-wide blocks).
        assert_eq!(footprint_rows(&arch, &[64, 64, 64], &[16, 8, 16]), [512, 256, 256]);
    }

    #[test]
    fn yaml_rendering_contains_levels() {
        let y = sched([16, 16, 16], [64, 64, 64]).to_yaml();
        assert!(y.contains("PEArray"));
        assert!(y.contains("permutation"));
        // And it parses with our own YAML parser.
        let doc = crate::util::yaml::parse(&y).unwrap();
        assert_eq!(doc.get("dataflow").unwrap().as_str().unwrap(), "WS");
    }
}
