//! Exact branch-and-bound over the CoSA assignment space for one
//! (dataflow, memory-share, double-buffering) configuration.
//!
//! The MIP's binary matrix `X[j, n, i, k]` assigns each prime factor of
//! each loop bound to a (level, spatial/temporal) slot. Grouping equal
//! primes, an assignment is equivalent to choosing per dimension `j` a
//! divisor chain `insn_j | onchip_j | bound_j` (the spatial/temporal split
//! at the PE level is then fixed by the dataflow, and the DRAM level takes
//! the remainder). The solver enumerates divisor chains depth-first with
//! constraint propagation:
//!
//! * Eq. (1) prunes instruction tiles above `DIM` before recursion;
//! * per-operand capacity (with shares / double-buffer halving) prunes a
//!   dimension's on-chip factor as soon as any operand using already-fixed
//!   dimensions overflows its budget;
//! * at each leaf all six DRAM permutations are costed analytically.
//!
//! Two search drivers share that structure. [`solve`] (via
//! `solve_exhaustive`) is the unpruned reference: it visits every feasible
//! leaf of one configuration. [`solve_group`] is the production path used
//! by the pruned sweep: it runs one DFS for a whole group of
//! configurations that differ only in memory shares, gating each node per
//! configuration and cutting subtrees with an admissible lower bound
//! ([`LowerBound`]) once a configuration's top-k list is full. Because the
//! bound never exceeds the true analytic cost, and a costed leaf is pushed
//! to every configuration that admits it in the exact order the reference
//! would produce, the per-configuration results are byte-identical to
//! `solve` — only cheaper to reach (differential- and property-tested in
//! `sweep.rs`).
//!
//! The search is exact over the discrete space — the same optimum the MIP
//! would return under the same objective — while taking well under a
//! millisecond for Table-2-sized workloads.

use crate::arch::{ArchDesc, Dataflow};
use crate::util::ceil_div;
use crate::workload::{factor::Factorization, Dim, Gemm, Operand};

use super::traffic::{estimate, Candidate};
use super::{capacity_rows, footprint_rows, Estimate, Schedule};

/// One scheduling configuration (a point of the Fig. 2(b) outer sweep).
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    /// PE-array dataflow to solve for (fixes the spatial dims).
    pub dataflow: Dataflow,
    /// Memory shares (Input, Weight, Output) granted to each operand.
    pub shares: [f64; 3],
    /// Solve with double buffering (halved usable capacity per operand).
    pub double_buffer: bool,
    /// How many top candidates to keep (by analytic cost).
    pub top_k: usize,
}

impl SolverConfig {
    /// A configuration for `dataflow` with even shares, no double
    /// buffering and the default `top_k`.
    pub fn new(dataflow: Dataflow) -> SolverConfig {
        SolverConfig { dataflow, shares: [0.5, 0.5, 1.0], double_buffer: false, top_k: 4 }
    }
}

/// Search-effort counters, accumulated across every solver invocation of
/// a sweep (and surfaced through the compile pipeline's stage reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Leaves whose six DRAM permutations were actually costed.
    pub leaves_visited: u64,
    /// Leaf costings skipped by the admissible lower bound (subtree cuts
    /// count their remaining K-table entries, so this upper-bounds the
    /// work avoided rather than the exact feasible-leaf count).
    pub leaves_pruned: u64,
    /// Configuration points whose capacities are pointwise ≤ another
    /// point's in the same (dataflow, double-buffer) group — they ride
    /// the shared DFS for free instead of running their own.
    pub configs_pruned: u64,
}

impl SearchStats {
    /// Fold another counter set into this one.
    pub fn absorb(&mut self, other: &SearchStats) {
        self.leaves_visited += other.leaves_visited;
        self.leaves_pruned += other.leaves_pruned;
        self.configs_pruned += other.configs_pruned;
    }
}

/// All divisors of `v` that are ≤ `limit`.
fn divisors_upto(v: usize, limit: usize) -> Vec<usize> {
    Factorization::of(v)
        .divisors()
        .into_iter()
        .filter(|&d| d <= limit)
        .collect()
}

/// Per-dimension (insn, onchip) divisor-chain tables for one workload.
///
/// The tables depend only on the workload bounds and the architecture's
/// instruction-tile limit — not on shares, dataflow or buffering — so a
/// sweep builds them once and shares them across all of its configuration
/// points instead of refactorizing the bounds per `solve` call.
#[derive(Debug, Clone)]
pub struct DimTables {
    per_dim: [Vec<(usize, usize)>; 3],
    /// Largest instruction-tile divisor per dimension; the subtree lower
    /// bound uses it as the best case for a dimension not yet fixed.
    max_insn: [usize; 3],
}

impl DimTables {
    /// Build the divisor tables for `g` under `arch`'s tile limit.
    pub fn new(arch: &ArchDesc, g: Gemm) -> DimTables {
        let insn_limit = arch.constraints.insn_tile_limit.min(arch.pe_dim);
        let mut per_dim: [Vec<(usize, usize)>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut max_insn = [1usize; 3];
        for &d in Dim::ALL.iter() {
            let bound = g.bound(d);
            let mut out = Vec::new();
            for insn in divisors_upto(bound, insn_limit.min(bound)) {
                max_insn[d.index()] = max_insn[d.index()].max(insn);
                for mult in Factorization::of(bound / insn).divisors() {
                    out.push((insn, insn * mult));
                }
            }
            per_dim[d.index()] = out;
        }
        DimTables { per_dim, max_insn }
    }
}

/// Insert `s` into `best` (kept sorted ascending by analytic cost,
/// capped at `top_k`). Equal costs keep insertion order, exactly like the
/// append + stable sort + truncate this replaced — same results, but
/// O(log n) search + one bounded shift instead of a full re-sort per push.
fn insert_bounded(best: &mut Vec<Schedule>, s: Schedule, top_k: usize) {
    let pos = best.partition_point(|b| b.est.cost() <= s.est.cost());
    if pos >= top_k {
        return;
    }
    best.insert(pos, s);
    best.truncate(top_k);
}

/// Cost the six DRAM permutations of one feasible leaf, returning the
/// cheapest estimate and its order (the first permutation wins ties).
fn leaf_estimate(
    arch: &ArchDesc,
    g: Gemm,
    dataflow: Dataflow,
    double_buffer: bool,
    insn: [usize; 3],
    onchip: [usize; 3],
) -> Option<(Estimate, [Dim; 3])> {
    let mut leaf_best: Option<(Estimate, [Dim; 3])> = None;
    for raw in PERMS {
        // The mapping generator canonicalizes the DRAM order with C
        // innermost whenever the C loop iterates (the output tile must
        // finish in the accumulator); cost the order that will actually
        // run.
        let order = if ceil_div(g.c, onchip[Dim::C.index()]) > 1 {
            let mut o: Vec<Dim> = raw.iter().copied().filter(|&d| d != Dim::C).collect();
            o.push(Dim::C);
            [o[0], o[1], o[2]]
        } else {
            raw
        };
        let cand = Candidate {
            workload: g,
            dataflow,
            double_buffer,
            insn_tile: insn,
            onchip_tile: onchip,
            dram_order: order,
        };
        let est = estimate(arch, &cand);
        if leaf_best.as_ref().map(|(b, _)| est.cost() < b.cost()).unwrap_or(true) {
            leaf_best = Some((est, order));
        }
    }
    leaf_best
}

/// An admissible lower bound on [`Estimate::cost`] over every DRAM
/// permutation and on-chip tiling reachable under a (partially) fixed
/// instruction tile. Each term is derived from `traffic::estimate` by
/// dropping work:
///
/// * every operand is fetched from DRAM at least once, so
///   `bytes ≥ N·C + C·K + N·K` (the revisit/int32 factors only add);
/// * DMA pays at least those payload bytes at `bytes_per_cycle`
///   (request latencies and row overheads dropped; the output term is
///   covered by the 4 B/element accumulator-read traffic);
/// * compute issues at least `ceil(N/n0)·ceil(C/c0)·ceil(K/k0)` matmuls
///   at `n0 + 8` cycles each (preloads dropped; uses
///   `ceil(B/t)·ceil(t/t0) ≥ ceil(B/t0)` per dimension);
/// * the front end issues those same instructions at `insn_issue_cycles`;
/// * latency is at least the slowest engine in both buffering modes.
///
/// Everything dropped only increases the true cost, so cutting a subtree
/// when the bound already exceeds a full top-k list's worst entry can
/// never change which candidates survive.
struct LowerBound {
    g: Gemm,
    bytes_lb: f64,
    dma_lb: f64,
    issue_per_insn: f64,
}

impl LowerBound {
    fn new(arch: &ArchDesc, g: Gemm) -> LowerBound {
        let bytes_lb = (g.n * g.c + g.c * g.k + g.n * g.k) as f64;
        LowerBound {
            g,
            bytes_lb,
            dma_lb: bytes_lb / arch.dma.bytes_per_cycle as f64,
            issue_per_insn: arch.host.insn_issue_cycles as f64,
        }
    }

    /// Best-case cost with the instruction tile fixed at `(n0, c0, k0)`.
    /// For a dimension whose divisor is not yet chosen, pass its largest
    /// table entry: the bound is nonincreasing in each tile size, so the
    /// maximum is the safe (weakest) choice for the whole subtree.
    fn cost(&self, n0: usize, c0: usize, k0: usize) -> f64 {
        let computes = (ceil_div(self.g.n, n0) * ceil_div(self.g.c, c0) * ceil_div(self.g.k, k0))
            as f64;
        let compute_lb = computes * (n0 as f64 + 8.0);
        let issue_lb = computes * self.issue_per_insn;
        compute_lb.max(self.dma_lb).max(issue_lb)
            + 1e-3 * self.bytes_lb
            + 1e-4 * (compute_lb + issue_lb)
    }
}

/// Solve one configuration, returning up to `top_k` schedules sorted by
/// analytic cost (best first). Returns an empty vec when no mapping fits
/// (e.g. shares too small for even a single instruction tile).
pub fn solve(arch: &ArchDesc, g: Gemm, cfg: &SolverConfig) -> Vec<Schedule> {
    let tables = DimTables::new(arch, g);
    solve_exhaustive(arch, g, cfg, &tables, &mut SearchStats::default())
}

/// The unpruned reference search: depth-first over (N, C, K) with
/// capacity propagation only, costing every feasible leaf. This is what
/// the differential tests compare the pruned group search against.
pub(crate) fn solve_exhaustive(
    arch: &ArchDesc,
    g: Gemm,
    cfg: &SolverConfig,
    tables: &DimTables,
    stats: &mut SearchStats,
) -> Vec<Schedule> {
    let caps = capacity_rows(arch, &cfg.shares, cfg.double_buffer);
    let mut best: Vec<Schedule> = Vec::new();
    for &(n_insn, n_tile) in &tables.per_dim[Dim::N.index()] {
        for &(c_insn, c_tile) in &tables.per_dim[Dim::C.index()] {
            // Input footprint depends only on N and C — prune early.
            let probe = [n_tile, c_tile, 1];
            let probe_insn = [n_insn, c_insn, 1];
            if footprint_rows(arch, &probe, &probe_insn)[Operand::Input.index()]
                > caps[Operand::Input.index()]
            {
                continue;
            }
            for &(k_insn, k_tile) in &tables.per_dim[Dim::K.index()] {
                let onchip = [n_tile, c_tile, k_tile];
                let insn = [n_insn, c_insn, k_insn];
                let rows = footprint_rows(arch, &onchip, &insn);
                if rows[Operand::Weight.index()] > caps[Operand::Weight.index()]
                    || rows[Operand::Output.index()] > caps[Operand::Output.index()]
                {
                    continue;
                }
                stats.leaves_visited += 1;
                if let Some((est, order)) =
                    leaf_estimate(arch, g, cfg.dataflow, cfg.double_buffer, insn, onchip)
                {
                    insert_bounded(
                        &mut best,
                        Schedule {
                            workload: g,
                            dataflow: cfg.dataflow,
                            double_buffer: cfg.double_buffer,
                            shares: cfg.shares,
                            insn_tile: insn,
                            onchip_tile: onchip,
                            dram_order: order,
                            est,
                        },
                        cfg.top_k,
                    );
                }
            }
        }
    }
    best
}

/// Solve a whole group of configurations that share (dataflow,
/// double-buffer, top_k) and differ only in memory shares, with one DFS.
///
/// The walk runs over the pointwise-max union of the group's capacities;
/// at each node a per-configuration admit mask records which members the
/// node is feasible for, and a leaf is costed once and pushed (in walk
/// order) to every admitting member's own top-k list. That makes each
/// member's list the exact subsequence `solve` would have produced —
/// byte-identical results. On top of that:
///
/// * a leaf (or whole K-subtree) is skipped when the admissible
///   [`LowerBound`] already exceeds the worst entry of every admitting
///   member whose list is full;
/// * members whose capacities are pointwise ≤ another member's explore a
///   strict subset of its nodes and are counted in
///   [`SearchStats::configs_pruned`] — they cost nothing extra beyond
///   their own top-k bookkeeping.
pub(crate) fn solve_group(
    arch: &ArchDesc,
    g: Gemm,
    cfgs: &[SolverConfig],
    tables: &DimTables,
    stats: &mut SearchStats,
) -> Vec<Vec<Schedule>> {
    debug_assert!(!cfgs.is_empty());
    debug_assert!(cfgs.windows(2).all(|w| {
        w[0].dataflow == w[1].dataflow
            && w[0].double_buffer == w[1].double_buffer
            && w[0].top_k == w[1].top_k
    }));
    let caps: Vec<[usize; 3]> =
        cfgs.iter().map(|c| capacity_rows(arch, &c.shares, c.double_buffer)).collect();
    for (i, ci) in caps.iter().enumerate() {
        let dominated = caps.iter().enumerate().any(|(j, cj)| {
            // Ties count only the later point, so a pair of equal
            // capacity vectors prunes one member, not both.
            j != i && ci.iter().zip(cj).all(|(a, b)| a <= b) && (ci != cj || j < i)
        });
        if dominated {
            stats.configs_pruned += 1;
        }
    }
    let mut union = [0usize; 3];
    for c in &caps {
        for (u, &v) in union.iter_mut().zip(c) {
            *u = (*u).max(v);
        }
    }

    let (dataflow, double_buffer) = (cfgs[0].dataflow, cfgs[0].double_buffer);
    let top_k = cfgs[0].top_k;
    let lb = LowerBound::new(arch, g);
    let mut best: Vec<Vec<Schedule>> = vec![Vec::new(); cfgs.len()];
    // A member still needs a leaf while its list has room, or while the
    // bound does not strictly beat its current worst. The worst of a full
    // list only ever decreases, so a cut decided here stays valid.
    let needs = |list: &[Schedule], bound: f64| {
        if list.len() < top_k {
            return true;
        }
        match list.last() {
            Some(worst) => bound <= worst.est.cost(),
            None => false, // top_k == 0: nothing can ever enter
        }
    };

    let mut admit_nc = vec![false; cfgs.len()];
    let mut admit = vec![false; cfgs.len()];
    for &(n_insn, n_tile) in &tables.per_dim[Dim::N.index()] {
        for &(c_insn, c_tile) in &tables.per_dim[Dim::C.index()] {
            let probe = [n_tile, c_tile, 1];
            let probe_insn = [n_insn, c_insn, 1];
            let in_rows = footprint_rows(arch, &probe, &probe_insn)[Operand::Input.index()];
            if in_rows > union[Operand::Input.index()] {
                continue;
            }
            for (a, cap) in admit_nc.iter_mut().zip(&caps) {
                *a = in_rows <= cap[Operand::Input.index()];
            }
            // Subtree bound: K's divisor is still free; its largest table
            // entry minimizes the bound over the whole subtree.
            let sub_lb = lb.cost(n_insn, c_insn, tables.max_insn[Dim::K.index()]);
            if !admit_nc.iter().zip(&best).any(|(&a, b)| a && needs(b.as_slice(), sub_lb)) {
                stats.leaves_pruned += tables.per_dim[Dim::K.index()].len() as u64;
                continue;
            }
            for &(k_insn, k_tile) in &tables.per_dim[Dim::K.index()] {
                let onchip = [n_tile, c_tile, k_tile];
                let insn = [n_insn, c_insn, k_insn];
                let rows = footprint_rows(arch, &onchip, &insn);
                if rows[Operand::Weight.index()] > union[Operand::Weight.index()]
                    || rows[Operand::Output.index()] > union[Operand::Output.index()]
                {
                    continue;
                }
                let mut any = false;
                for ((a, &nc), cap) in admit.iter_mut().zip(&admit_nc).zip(&caps) {
                    *a = nc
                        && rows[Operand::Weight.index()] <= cap[Operand::Weight.index()]
                        && rows[Operand::Output.index()] <= cap[Operand::Output.index()];
                    any |= *a;
                }
                if !any {
                    continue;
                }
                let leaf_lb = lb.cost(n_insn, c_insn, k_insn);
                if !admit.iter().zip(&best).any(|(&a, b)| a && needs(b.as_slice(), leaf_lb)) {
                    stats.leaves_pruned += 1;
                    continue;
                }
                stats.leaves_visited += 1;
                if let Some((est, order)) =
                    leaf_estimate(arch, g, dataflow, double_buffer, insn, onchip)
                {
                    for ((list, &a), cfg) in best.iter_mut().zip(&admit).zip(cfgs) {
                        if !a {
                            continue;
                        }
                        insert_bounded(
                            list,
                            Schedule {
                                workload: g,
                                dataflow,
                                double_buffer,
                                shares: cfg.shares,
                                insn_tile: insn,
                                onchip_tile: onchip,
                                dram_order: order,
                                est,
                            },
                            top_k,
                        );
                    }
                }
            }
        }
    }
    best
}

/// The six permutations of (N, C, K).
pub const PERMS: [[Dim; 3]; 6] = [
    [Dim::N, Dim::C, Dim::K],
    [Dim::N, Dim::K, Dim::C],
    [Dim::C, Dim::N, Dim::K],
    [Dim::C, Dim::K, Dim::N],
    [Dim::K, Dim::N, Dim::C],
    [Dim::K, Dim::C, Dim::N],
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn gemmini() -> ArchDesc {
        ArchDesc::gemmini()
    }

    #[test]
    fn solves_table2_sizes() {
        let arch = gemmini();
        for s in [64usize, 128, 256, 512] {
            let cfg = SolverConfig {
                double_buffer: true,
                ..SolverConfig::new(Dataflow::WeightStationary)
            };
            let scheds = solve(&arch, Gemm::new(s, s, s), &cfg);
            assert!(!scheds.is_empty(), "no schedule for {s}^3");
            let best = &scheds[0];
            best.validate(&arch).unwrap();
            // A sane optimum saturates the array.
            assert_eq!(best.insn_tile, [16, 16, 16], "size {s}: {best}");
            assert!(best.est.utilization > 0.99);
        }
    }

    #[test]
    fn toycar_layer_schedulable() {
        // N=1 (single inference): N factors are just {1}.
        let arch = gemmini();
        let cfg = SolverConfig::new(Dataflow::WeightStationary);
        let scheds = solve(&arch, Gemm::new(1, 640, 128), &cfg);
        assert!(!scheds.is_empty());
        let best = &scheds[0];
        best.validate(&arch).unwrap();
        assert_eq!(best.insn_tile[0], 1);
        // 640 = 2^7·5: the instruction tile for C must divide 640 and obey
        // Eq. (1): the largest allowed is 16.
        assert_eq!(best.insn_tile[1], 16);
    }

    #[test]
    fn respects_double_buffer_capacity() {
        let arch = gemmini();
        let db = SolverConfig {
            double_buffer: true,
            ..SolverConfig::new(Dataflow::WeightStationary)
        };
        for s in solve(&arch, Gemm::new(512, 512, 512), &db) {
            s.validate(&arch).unwrap(); // validate() re-checks halved caps
        }
    }

    #[test]
    fn os_dataflow_solves() {
        let arch = gemmini();
        let cfg = SolverConfig::new(Dataflow::OutputStationary);
        let scheds = solve(&arch, Gemm::new(128, 128, 128), &cfg);
        assert!(!scheds.is_empty());
        scheds[0].validate(&arch).unwrap();
        assert_eq!(scheds[0].dataflow, Dataflow::OutputStationary);
    }

    #[test]
    fn top_k_is_sorted_and_bounded() {
        let arch = gemmini();
        let cfg = SolverConfig {
            top_k: 3,
            ..SolverConfig::new(Dataflow::WeightStationary)
        };
        let scheds = solve(&arch, Gemm::new(256, 256, 256), &cfg);
        assert!(scheds.len() <= 3);
        for w in scheds.windows(2) {
            assert!(w[0].est.cost() <= w[1].est.cost());
        }
    }

    #[test]
    fn bounded_insertion_matches_sort_truncate() {
        // The reference semantics insert_bounded replaced: append, stable
        // sort by cost, truncate. Replaying a solver run's push sequence
        // through both must give identical lists (including tie order).
        let arch = gemmini();
        let cfg = SolverConfig {
            top_k: 3,
            ..SolverConfig::new(Dataflow::WeightStationary)
        };
        // top_k = usize::MAX keeps every feasible candidate; shuffling
        // gives an arbitrary push order, including equal-cost runs.
        let mut all = solve(
            &arch,
            Gemm::new(64, 96, 64),
            &SolverConfig { top_k: usize::MAX, ..cfg },
        );
        assert!(all.len() > cfg.top_k);
        Rng::new(3).shuffle(&mut all);
        let mut reference: Vec<Schedule> = Vec::new();
        let mut bounded: Vec<Schedule> = Vec::new();
        for s in &all {
            reference.push(s.clone());
            reference.sort_by(|a, b| a.est.cost().partial_cmp(&b.est.cost()).unwrap());
            reference.truncate(cfg.top_k);
            insert_bounded(&mut bounded, s.clone(), cfg.top_k);
        }
        assert_eq!(reference, bounded);
    }

    #[test]
    fn group_solve_matches_per_config_solve() {
        let arch = gemmini();
        let g = Gemm::new(256, 256, 256);
        let tables = DimTables::new(&arch, g);
        let cfgs: Vec<SolverConfig> = [[0.5, 0.5, 1.0], [0.25, 0.75, 1.0], [0.75, 0.25, 1.0]]
            .iter()
            .map(|&shares| SolverConfig {
                shares,
                ..SolverConfig::new(Dataflow::WeightStationary)
            })
            .collect();
        let mut stats = SearchStats::default();
        let grouped = solve_group(&arch, g, &cfgs, &tables, &mut stats);
        for (cfg, got) in cfgs.iter().zip(&grouped) {
            assert_eq!(got, &solve(&arch, g, cfg), "shares {:?}", cfg.shares);
        }
        assert!(stats.leaves_visited > 0);
    }

    #[test]
    fn lower_bound_is_admissible() {
        // The pruning bound must never exceed the true analytic cost of
        // any leaf it covers — checked over random shapes and tiles.
        let arch = gemmini();
        prop::check("lower bound admissible", 80, |rng: &mut Rng| {
            let pow2 = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
            let g = Gemm::new(*rng.pick(&pow2), *rng.pick(&pow2), *rng.pick(&pow2));
            let lb = LowerBound::new(&arch, g);
            let tables = DimTables::new(&arch, g);
            for _ in 0..8 {
                let pick = |d: Dim| *rng.pick(&tables.per_dim[d.index()]);
                let (n_insn, n_tile) = pick(Dim::N);
                let (c_insn, c_tile) = pick(Dim::C);
                let (k_insn, k_tile) = pick(Dim::K);
                let dataflow = if rng.chance(0.5) {
                    Dataflow::WeightStationary
                } else {
                    Dataflow::OutputStationary
                };
                let db = rng.chance(0.5);
                let Some((est, _)) = leaf_estimate(
                    &arch,
                    g,
                    dataflow,
                    db,
                    [n_insn, c_insn, k_insn],
                    [n_tile, c_tile, k_tile],
                ) else {
                    continue;
                };
                let bound = lb.cost(n_insn, c_insn, k_insn);
                if bound > est.cost() + 1e-6 {
                    return Err(format!(
                        "{g:?} insn=({n_insn},{c_insn},{k_insn}): bound {bound} > cost {}",
                        est.cost()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_emitted_schedules_always_valid() {
        let arch = gemmini();
        prop::check("solver schedules valid", 60, |rng: &mut Rng| {
            let pow2 = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
            let n = *rng.pick(&pow2);
            let c = *rng.pick(&[8usize, 16, 24, 40, 64, 96, 128, 320, 640]);
            let k = *rng.pick(&pow2);
            let cfg = SolverConfig {
                dataflow: if rng.chance(0.5) {
                    Dataflow::WeightStationary
                } else {
                    Dataflow::OutputStationary
                },
                shares: *rng.pick(&[[0.5, 0.5, 1.0], [0.25, 0.75, 1.0], [0.75, 0.25, 1.0]]),
                double_buffer: rng.chance(0.5),
                top_k: 3,
            };
            let g = Gemm::new(n, c, k);
            for s in solve(&arch, g, &cfg) {
                s.validate(&arch).map_err(|e| format!("{g:?} {cfg:?}: {e}"))?;
                // Eq. (1) in its original log form.
                for d in Dim::ALL {
                    let lhs: f64 = Factorization::of(s.insn_tile[d.index()])
                        .flat()
                        .iter()
                        .map(|&p| (p as f64).ln())
                        .sum();
                    if lhs > (arch.constraints.insn_tile_limit as f64).ln() + 1e-9 {
                        return Err(format!("Eq.(1) violated for {d} in {s}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_factor_chain_reconstructs_bound() {
        let arch = gemmini();
        prop::check("tile chain divides bound", 40, |rng: &mut Rng| {
            let n = rng.range(1, 64);
            let c = rng.range(1, 64);
            let k = rng.range(1, 64);
            let g = Gemm::new(n, c, k);
            let cfg = SolverConfig::new(Dataflow::WeightStationary);
            for s in solve(&arch, g, &cfg) {
                for d in Dim::ALL {
                    let j = d.index();
                    if g.bound(d) % s.onchip_tile[j] != 0
                        || s.onchip_tile[j] % s.insn_tile[j] != 0
                    {
                        return Err(format!("{d}: chain broken in {s}"));
                    }
                }
            }
            Ok(())
        });
    }
}
