//! Exact branch-and-bound over the CoSA assignment space for one
//! (dataflow, memory-share, double-buffering) configuration.
//!
//! The MIP's binary matrix `X[j, n, i, k]` assigns each prime factor of
//! each loop bound to a (level, spatial/temporal) slot. Grouping equal
//! primes, an assignment is equivalent to choosing per dimension `j` a
//! divisor chain `insn_j | onchip_j | bound_j` (the spatial/temporal split
//! at the PE level is then fixed by the dataflow, and the DRAM level takes
//! the remainder). The solver enumerates divisor chains depth-first with
//! constraint propagation:
//!
//! * Eq. (1) prunes instruction tiles above `DIM` before recursion;
//! * per-operand capacity (with shares / double-buffer halving) prunes a
//!   dimension's on-chip factor as soon as any operand using already-fixed
//!   dimensions overflows its budget;
//! * at each leaf all six DRAM permutations are costed analytically.
//!
//! The search is exact over the discrete space — the same optimum the MIP
//! would return under the same objective — while taking well under a
//! millisecond for Table-2-sized workloads.

use crate::arch::{ArchDesc, Dataflow};
use crate::workload::{factor::Factorization, Dim, Gemm, Operand};

use super::traffic::{estimate, Candidate};
use super::{capacity_rows, footprint_rows, Estimate, Schedule};

/// One scheduling configuration (a point of the Fig. 2(b) outer sweep).
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    /// PE-array dataflow to solve for (fixes the spatial dims).
    pub dataflow: Dataflow,
    /// Memory shares (Input, Weight, Output) granted to each operand.
    pub shares: [f64; 3],
    /// Solve with double buffering (halved usable capacity per operand).
    pub double_buffer: bool,
    /// How many top candidates to keep (by analytic cost).
    pub top_k: usize,
}

impl SolverConfig {
    /// A configuration for `dataflow` with even shares, no double
    /// buffering and the default `top_k`.
    pub fn new(dataflow: Dataflow) -> SolverConfig {
        SolverConfig { dataflow, shares: [0.5, 0.5, 1.0], double_buffer: false, top_k: 4 }
    }
}

/// All divisors of `v` that are ≤ `limit`.
fn divisors_upto(v: usize, limit: usize) -> Vec<usize> {
    Factorization::of(v)
        .divisors()
        .into_iter()
        .filter(|&d| d <= limit)
        .collect()
}

/// Solve one configuration, returning up to `top_k` schedules sorted by
/// analytic cost (best first). Returns an empty vec when no mapping fits
/// (e.g. shares too small for even a single instruction tile).
pub fn solve(arch: &ArchDesc, g: Gemm, cfg: &SolverConfig) -> Vec<Schedule> {
    let caps = capacity_rows(arch, &cfg.shares, cfg.double_buffer);
    let insn_limit = arch.constraints.insn_tile_limit.min(arch.pe_dim);

    // Candidate (insn, onchip) pairs per dimension.
    let per_dim: Vec<Vec<(usize, usize)>> = Dim::ALL
        .iter()
        .map(|&d| {
            let bound = g.bound(d);
            let mut out = Vec::new();
            for insn in divisors_upto(bound, insn_limit.min(bound)) {
                for mult in Factorization::of(bound / insn).divisors() {
                    out.push((insn, insn * mult));
                }
            }
            out
        })
        .collect();

    let mut best: Vec<Schedule> = Vec::new();
    let mut push = |s: Schedule| {
        best.push(s);
        best.sort_by(|a, b| a.est.cost().partial_cmp(&b.est.cost()).unwrap());
        best.truncate(cfg.top_k);
    };

    // Depth-first over (N, C, K) with capacity propagation.
    for &(n_insn, n_tile) in &per_dim[Dim::N.index()] {
        for &(c_insn, c_tile) in &per_dim[Dim::C.index()] {
            // Input footprint depends only on N and C — prune early.
            let probe = [n_tile, c_tile, 1];
            let probe_insn = [n_insn, c_insn, 1];
            if footprint_rows(arch, &probe, &probe_insn)[Operand::Input.index()]
                > caps[Operand::Input.index()]
            {
                continue;
            }
            for &(k_insn, k_tile) in &per_dim[Dim::K.index()] {
                let onchip = [n_tile, c_tile, k_tile];
                let insn_probe = [n_insn, c_insn, k_insn];
                let rows = footprint_rows(arch, &onchip, &insn_probe);
                if rows[Operand::Weight.index()] > caps[Operand::Weight.index()]
                    || rows[Operand::Output.index()] > caps[Operand::Output.index()]
                {
                    continue;
                }
                let insn = [n_insn, c_insn, k_insn];
                let mut leaf_best: Option<(Estimate, [Dim; 3])> = None;
                for raw in PERMS {
                    // The mapping generator canonicalizes the DRAM order
                    // with C innermost whenever the C loop iterates (the
                    // output tile must finish in the accumulator); cost
                    // the order that will actually run.
                    let order = if crate::util::ceil_div(g.c, c_tile) > 1 {
                        let mut o: Vec<Dim> =
                            raw.iter().copied().filter(|&d| d != Dim::C).collect();
                        o.push(Dim::C);
                        [o[0], o[1], o[2]]
                    } else {
                        raw
                    };
                    let cand = Candidate {
                        workload: g,
                        dataflow: cfg.dataflow,
                        double_buffer: cfg.double_buffer,
                        insn_tile: insn,
                        onchip_tile: onchip,
                        dram_order: order,
                    };
                    let est = estimate(arch, &cand);
                    if leaf_best
                        .as_ref()
                        .map(|(b, _)| est.cost() < b.cost())
                        .unwrap_or(true)
                    {
                        leaf_best = Some((est, order));
                    }
                }
                if let Some((est, order)) = leaf_best {
                    push(Schedule {
                        workload: g,
                        dataflow: cfg.dataflow,
                        double_buffer: cfg.double_buffer,
                        shares: cfg.shares,
                        insn_tile: insn,
                        onchip_tile: onchip,
                        dram_order: order,
                        est,
                    });
                }
            }
        }
    }
    best
}

/// The six permutations of (N, C, K).
pub const PERMS: [[Dim; 3]; 6] = [
    [Dim::N, Dim::C, Dim::K],
    [Dim::N, Dim::K, Dim::C],
    [Dim::C, Dim::N, Dim::K],
    [Dim::C, Dim::K, Dim::N],
    [Dim::K, Dim::N, Dim::C],
    [Dim::K, Dim::C, Dim::N],
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn gemmini() -> ArchDesc {
        ArchDesc::gemmini()
    }

    #[test]
    fn solves_table2_sizes() {
        let arch = gemmini();
        for s in [64usize, 128, 256, 512] {
            let cfg = SolverConfig {
                double_buffer: true,
                ..SolverConfig::new(Dataflow::WeightStationary)
            };
            let scheds = solve(&arch, Gemm::new(s, s, s), &cfg);
            assert!(!scheds.is_empty(), "no schedule for {s}^3");
            let best = &scheds[0];
            best.validate(&arch).unwrap();
            // A sane optimum saturates the array.
            assert_eq!(best.insn_tile, [16, 16, 16], "size {s}: {best}");
            assert!(best.est.utilization > 0.99);
        }
    }

    #[test]
    fn toycar_layer_schedulable() {
        // N=1 (single inference): N factors are just {1}.
        let arch = gemmini();
        let cfg = SolverConfig::new(Dataflow::WeightStationary);
        let scheds = solve(&arch, Gemm::new(1, 640, 128), &cfg);
        assert!(!scheds.is_empty());
        let best = &scheds[0];
        best.validate(&arch).unwrap();
        assert_eq!(best.insn_tile[0], 1);
        // 640 = 2^7·5: the instruction tile for C must divide 640 and obey
        // Eq. (1): the largest allowed is 16.
        assert_eq!(best.insn_tile[1], 16);
    }

    #[test]
    fn respects_double_buffer_capacity() {
        let arch = gemmini();
        let db = SolverConfig {
            double_buffer: true,
            ..SolverConfig::new(Dataflow::WeightStationary)
        };
        for s in solve(&arch, Gemm::new(512, 512, 512), &db) {
            s.validate(&arch).unwrap(); // validate() re-checks halved caps
        }
    }

    #[test]
    fn os_dataflow_solves() {
        let arch = gemmini();
        let cfg = SolverConfig::new(Dataflow::OutputStationary);
        let scheds = solve(&arch, Gemm::new(128, 128, 128), &cfg);
        assert!(!scheds.is_empty());
        scheds[0].validate(&arch).unwrap();
        assert_eq!(scheds[0].dataflow, Dataflow::OutputStationary);
    }

    #[test]
    fn top_k_is_sorted_and_bounded() {
        let arch = gemmini();
        let cfg = SolverConfig {
            top_k: 3,
            ..SolverConfig::new(Dataflow::WeightStationary)
        };
        let scheds = solve(&arch, Gemm::new(256, 256, 256), &cfg);
        assert!(scheds.len() <= 3);
        for w in scheds.windows(2) {
            assert!(w[0].est.cost() <= w[1].est.cost());
        }
    }

    #[test]
    fn prop_emitted_schedules_always_valid() {
        let arch = gemmini();
        prop::check("solver schedules valid", 60, |rng: &mut Rng| {
            let pow2 = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
            let n = *rng.pick(&pow2);
            let c = *rng.pick(&[8usize, 16, 24, 40, 64, 96, 128, 320, 640]);
            let k = *rng.pick(&pow2);
            let cfg = SolverConfig {
                dataflow: if rng.chance(0.5) {
                    Dataflow::WeightStationary
                } else {
                    Dataflow::OutputStationary
                },
                shares: *rng.pick(&[[0.5, 0.5, 1.0], [0.25, 0.75, 1.0], [0.75, 0.25, 1.0]]),
                double_buffer: rng.chance(0.5),
                top_k: 3,
            };
            let g = Gemm::new(n, c, k);
            for s in solve(&arch, g, &cfg) {
                s.validate(&arch).map_err(|e| format!("{g:?} {cfg:?}: {e}"))?;
                // Eq. (1) in its original log form.
                for d in Dim::ALL {
                    let lhs: f64 = Factorization::of(s.insn_tile[d.index()])
                        .flat()
                        .iter()
                        .map(|&p| (p as f64).ln())
                        .sum();
                    if lhs > (arch.constraints.insn_tile_limit as f64).ln() + 1e-9 {
                        return Err(format!("Eq.(1) violated for {d} in {s}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_factor_chain_reconstructs_bound() {
        let arch = gemmini();
        prop::check("tile chain divides bound", 40, |rng: &mut Rng| {
            let n = rng.range(1, 64);
            let c = rng.range(1, 64);
            let k = rng.range(1, 64);
            let g = Gemm::new(n, c, k);
            let cfg = SolverConfig::new(Dataflow::WeightStationary);
            for s in solve(&arch, g, &cfg) {
                for d in Dim::ALL {
                    let j = d.index();
                    if g.bound(d) % s.onchip_tile[j] != 0
                        || s.onchip_tile[j] % s.insn_tile[j] != 0
                    {
                        return Err(format!("{d}: chain broken in {s}"));
                    }
                }
            }
            Ok(())
        });
    }
}
