//! The Fig. 2(b) outer sweep: "run the extended CoSA across all valid
//! combinations of tuning parameters, including accelerator-supported
//! dataflows, uneven mapping strategies, and double buffering", then hand
//! the refined candidates to the mapping generator for on-hardware
//! (simulator) profiling.
//!
//! Three drivers produce the identical candidate list (differential- and
//! property-tested below):
//!
//! * [`sweep_serial`] — the exhaustive reference: one unpruned solve per
//!   configuration point;
//! * [`sweep_parallel`] — the same solves fanned across worker threads;
//! * [`sweep_pruned`] — the production path (`opts.pruned`, default on):
//!   points sharing a (dataflow, double-buffer) pair run as one grouped,
//!   lower-bound-pruned DFS ([`super::solver::solve_group`]), with the
//!   groups themselves parallelized when `opts.parallel` is set.

use std::collections::HashSet;

use crate::arch::{ArchDesc, Dataflow};
use crate::workload::{Dim, Gemm};

use super::solver::{solve_exhaustive, solve_group, DimTables, SearchStats, SolverConfig};
use super::Schedule;

/// Options controlling the sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    /// Candidates kept per configuration point.
    pub top_k_per_config: usize,
    /// Global cap on candidates returned (best-first).
    pub max_candidates: usize,
    /// Explore uneven memory shares (paper's uneven mapping).
    pub uneven_mapping: bool,
    /// Explore double buffering (halved capacity, overlapped execution).
    pub double_buffering: bool,
    /// Solve the configuration points on scoped worker threads. The result
    /// is byte-identical to the serial sweep (tested), so this is purely a
    /// compile-time speed knob and is not part of the schedule-cache key.
    pub parallel: bool,
    /// Use the grouped, lower-bound-pruned search. Also byte-identical to
    /// the serial sweep (differential- and property-tested), so like
    /// `parallel` it is a speed knob excluded from the cache key.
    pub pruned: bool,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            top_k_per_config: 2,
            max_candidates: 8,
            uneven_mapping: true,
            double_buffering: true,
            parallel: true,
            pruned: true,
        }
    }
}

/// Result of a sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Candidate schedules, best analytic cost first.
    pub candidates: Vec<Schedule>,
    /// Number of (dataflow, shares, double-buffer) points explored.
    pub configs_explored: usize,
    /// Search-effort counters (leaves costed / pruned, dominated points).
    pub stats: SearchStats,
}

/// The ordered grid of configuration points (dataflow × memory shares ×
/// double buffering) the sweep explores. Every sweep driver walks this
/// exact order, which is what makes their outputs identical: the final
/// sort is stable, so ties keep grid order.
fn config_points(arch: &ArchDesc, opts: &SweepOptions) -> Vec<SolverConfig> {
    let even = [0.5f64, 0.5, 1.0];
    let mut share_configs: Vec<[f64; 3]> = vec![even];
    if opts.uneven_mapping {
        for s in &arch.constraints.memory_share_configs {
            if !share_configs.contains(s) {
                share_configs.push(*s);
            }
        }
    }
    let explore_db = opts.double_buffering && arch.constraints.supports_double_buffering;
    let db_configs: Vec<bool> = if explore_db { vec![false, true] } else { vec![false] };

    let mut points = Vec::new();
    for &dataflow in &arch.dataflows {
        for shares in &share_configs {
            for &db in &db_configs {
                points.push(SolverConfig {
                    dataflow,
                    shares: *shares,
                    double_buffer: db,
                    top_k: opts.top_k_per_config,
                });
            }
        }
    }
    points
}

/// Run the sweep for one GEMM workload. Dispatches to the pruned grouped
/// search by default, else to the parallel or serial exhaustive drivers;
/// all paths return the identical result.
pub fn sweep(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    if opts.pruned {
        sweep_pruned(arch, g, opts)
    } else if opts.parallel {
        sweep_parallel(arch, g, opts)
    } else {
        sweep_serial(arch, g, opts)
    }
}

/// The reference serial sweep (Fig. 2(b) outer loop): exhaustive per
/// point, sharing only the divisor tables across points.
pub fn sweep_serial(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let points = config_points(arch, opts);
    let tables = DimTables::new(arch, g);
    let mut stats = SearchStats::default();
    let mut candidates = Vec::new();
    for cfg in &points {
        candidates.extend(solve_exhaustive(arch, g, cfg, &tables, &mut stats));
    }
    finalize(candidates, points.len(), stats, opts)
}

/// Parallel exhaustive sweep: fan the configuration points out across
/// scoped worker threads (contiguous chunks, results concatenated in grid
/// order), so the candidate list is byte-identical to [`sweep_serial`]'s.
pub fn sweep_parallel(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let points = config_points(arch, opts);
    if points.len() < 2 {
        return sweep_serial(arch, g, opts);
    }
    let tables = DimTables::new(arch, g);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(points.len());
    let chunk_len = crate::util::ceil_div(points.len(), workers);

    let mut per_point: Vec<Vec<Schedule>> = Vec::with_capacity(points.len());
    let mut stats = SearchStats::default();
    std::thread::scope(|scope| {
        let handles: Vec<_> = points
            .chunks(chunk_len)
            .map(|chunk| {
                let tables = &tables;
                scope.spawn(move || {
                    let mut s = SearchStats::default();
                    let lists: Vec<_> = chunk
                        .iter()
                        .map(|cfg| solve_exhaustive(arch, g, cfg, tables, &mut s))
                        .collect();
                    (lists, s)
                })
            })
            .collect();
        for h in handles {
            let (lists, s) = h.join().expect("sweep worker panicked");
            per_point.extend(lists);
            stats.absorb(&s);
        }
    });

    let candidates: Vec<Schedule> = per_point.into_iter().flatten().collect();
    finalize(candidates, points.len(), stats, opts)
}

/// Pruned sweep: group the configuration points by (dataflow,
/// double-buffer) — the axes that change the cost model — and run one
/// shared, lower-bound-pruned DFS per group, each group on its own scoped
/// thread when `opts.parallel` is set. Per-point results come back in
/// grid order, so the final list is byte-identical to [`sweep_serial`]'s
/// while costing strictly fewer solver leaves.
pub fn sweep_pruned(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let points = config_points(arch, opts);
    let tables = DimTables::new(arch, g);
    // Group points by (dataflow, double_buffer), remembering each point's
    // grid index so the per-point lists reassemble in grid order.
    let mut groups: Vec<(Vec<usize>, Vec<SolverConfig>)> = Vec::new();
    for (i, cfg) in points.iter().enumerate() {
        match groups.iter_mut().find(|(_, members)| {
            members[0].dataflow == cfg.dataflow && members[0].double_buffer == cfg.double_buffer
        }) {
            Some((indices, members)) => {
                indices.push(i);
                members.push(*cfg);
            }
            None => groups.push((vec![i], vec![*cfg])),
        }
    }

    let mut per_point: Vec<Vec<Schedule>> = vec![Vec::new(); points.len()];
    let mut stats = SearchStats::default();
    if opts.parallel && groups.len() >= 2 {
        let results: Vec<(Vec<Vec<Schedule>>, SearchStats)> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .iter()
                .map(|(_, members)| {
                    let tables = &tables;
                    scope.spawn(move || {
                        let mut s = SearchStats::default();
                        let lists = solve_group(arch, g, members, tables, &mut s);
                        (lists, s)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
        });
        for ((indices, _), (lists, s)) in groups.iter().zip(results) {
            stats.absorb(&s);
            for (&i, list) in indices.iter().zip(lists) {
                per_point[i] = list;
            }
        }
    } else {
        for (indices, members) in &groups {
            let lists = solve_group(arch, g, members, &tables, &mut stats);
            for (&i, list) in indices.iter().zip(lists) {
                per_point[i] = list;
            }
        }
    }

    let candidates: Vec<Schedule> = per_point.into_iter().flatten().collect();
    finalize(candidates, points.len(), stats, opts)
}

/// Rank, dedup and truncate the raw per-config candidates.
fn finalize(
    mut candidates: Vec<Schedule>,
    configs_explored: usize,
    stats: SearchStats,
    opts: &SweepOptions,
) -> SweepResult {
    candidates.sort_by(|a, b| a.est.cost().partial_cmp(&b.est.cost()).unwrap());
    // Global dedup: different share configs often produce the same mapping;
    // keep the first (cheapest) instance so the shortlist stays diverse.
    let mut seen: HashSet<([usize; 3], [usize; 3], [Dim; 3], Dataflow, bool)> =
        HashSet::with_capacity(candidates.len());
    candidates.retain(|s| {
        seen.insert((s.insn_tile, s.onchip_tile, s.dram_order, s.dataflow, s.double_buffer))
    });
    candidates.truncate(opts.max_candidates);
    SweepResult { candidates, configs_explored, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn sweep_explores_full_grid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(128, 128, 128), &SweepOptions::default());
        // 2 dataflows × 3 share configs (the even split is already one of
        // gemmini's share configs, so it dedups) × 2 db = 12.
        assert_eq!(r.configs_explored, 12);
        assert!(!r.candidates.is_empty());
        assert!(r.candidates.len() <= SweepOptions::default().max_candidates);
    }

    #[test]
    fn sweep_candidates_sorted_and_valid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(256, 256, 256), &SweepOptions::default());
        for w in r.candidates.windows(2) {
            assert!(w[0].est.cost() <= w[1].est.cost());
        }
        for s in &r.candidates {
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn sweep_explores_both_buffering_modes_for_large_layers() {
        // For streaming-scale GEMMs the trade-off between double buffering
        // (overlap) and single buffering (double the tile capacity) is
        // workload-dependent; the sweep must surface candidates of both
        // kinds so profiling can decide (Fig. 2b).
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions { max_candidates: 16, ..Default::default() };
        let r = sweep(&arch, Gemm::new(512, 512, 512), &opts);
        assert!(r.candidates.iter().any(|s| s.double_buffer));
        assert!(r.candidates.iter().any(|s| !s.double_buffer));
    }

    #[test]
    fn parallel_sweep_identical_to_serial() {
        // For the ToyCar layer shapes (and a couple of streaming-scale
        // shapes) the parallel sweep must return the exact candidate list
        // — same schedules, same order, same estimates — as the serial
        // reference.
        let arch = ArchDesc::gemmini();
        for g in toycar_and_table2_shapes() {
            let serial = sweep_serial(&arch, g, &SweepOptions::default());
            let parallel = sweep_parallel(&arch, g, &SweepOptions::default());
            assert_eq!(serial.configs_explored, parallel.configs_explored, "{g:?}");
            assert_eq!(serial.candidates, parallel.candidates, "{g:?}");
            // Both drivers are exhaustive: identical leaf counts too.
            assert_eq!(serial.stats, parallel.stats, "{g:?}");
        }
    }

    fn toycar_and_table2_shapes() -> Vec<Gemm> {
        vec![
            Gemm::new(1, 640, 128), // ToyCar input layer
            Gemm::new(1, 128, 128), // ToyCar trunk
            Gemm::new(1, 128, 8),   // ToyCar bottleneck
            Gemm::new(1, 8, 128),
            Gemm::new(1, 128, 640), // ToyCar output layer
            Gemm::new(64, 64, 64),
            Gemm::new(512, 512, 512),
        ]
    }

    #[test]
    fn pruned_sweep_identical_to_serial_with_fewer_leaves() {
        // The tentpole acceptance bar: the pruned grouped search must
        // return candidates byte-identical to the exhaustive serial
        // reference on the ToyCar + Table-2 shapes, while costing strictly
        // fewer solver leaves on a Table-2 workload.
        let arch = ArchDesc::gemmini();
        for g in toycar_and_table2_shapes() {
            let serial = sweep_serial(&arch, g, &SweepOptions::default());
            let pruned = sweep_pruned(&arch, g, &SweepOptions::default());
            assert_eq!(serial.configs_explored, pruned.configs_explored, "{g:?}");
            assert_eq!(serial.candidates, pruned.candidates, "{g:?}");
            assert!(
                pruned.stats.leaves_visited <= serial.stats.leaves_visited,
                "{g:?}: pruned visited {} > serial {}",
                pruned.stats.leaves_visited,
                serial.stats.leaves_visited
            );
        }
        // Strictly fewer on the largest Table-2 layer (512³): shared
        // group leaves alone guarantee it, lower-bound cuts add more.
        let g = Gemm::new(512, 512, 512);
        let serial = sweep_serial(&arch, g, &SweepOptions::default());
        let pruned = sweep_pruned(&arch, g, &SweepOptions::default());
        assert!(
            pruned.stats.leaves_visited < serial.stats.leaves_visited,
            "pruned visited {} >= serial {}",
            pruned.stats.leaves_visited,
            serial.stats.leaves_visited
        );
    }

    #[test]
    fn dominated_share_config_rides_free() {
        // A share point whose capacities are pointwise ≤ another's
        // explores a strict subset of its leaves; the grouped search
        // counts it as pruned and still returns its exact candidates.
        // Gemmini's stock share points are mutually incomparable, so add
        // one that is dominated by the even split.
        let mut arch = ArchDesc::gemmini();
        arch.constraints.memory_share_configs.push([0.25, 0.25, 1.0]);
        let g = Gemm::new(128, 128, 128);
        let serial = sweep_serial(&arch, g, &SweepOptions::default());
        let pruned = sweep_pruned(&arch, g, &SweepOptions::default());
        assert_eq!(serial.candidates, pruned.candidates);
        assert_eq!(serial.configs_explored, pruned.configs_explored);
        // 4 groups × 1 dominated member each.
        assert!(pruned.stats.configs_pruned > 0);
        assert_eq!(serial.stats.configs_pruned, 0);
    }

    #[test]
    fn parallel_flag_routes_both_ways() {
        let arch = ArchDesc::gemmini();
        let g = Gemm::new(96, 96, 96);
        let on = sweep(&arch, g, &SweepOptions { parallel: true, ..Default::default() });
        let off = sweep(&arch, g, &SweepOptions { parallel: false, ..Default::default() });
        assert_eq!(on.candidates, off.candidates);
        assert_eq!(on.stats, off.stats);
    }

    #[test]
    fn disabling_knobs_shrinks_grid() {
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions {
            uneven_mapping: false,
            double_buffering: false,
            ..Default::default()
        };
        let r = sweep(&arch, Gemm::new(64, 64, 64), &opts);
        // 2 dataflows × 1 share × 1 db.
        assert_eq!(r.configs_explored, 2);
    }

    #[test]
    fn dataflow_choice_tracks_workload_shape() {
        // Streaming many rows through resident weights favors WS; deep
        // reductions with small outputs favor OS (accumulate in place).
        // The sweep must surface the right dataflow per shape.
        let arch = ArchDesc::gemmini();
        let tall = sweep(&arch, Gemm::new(512, 64, 64), &SweepOptions::default());
        assert_eq!(tall.candidates[0].dataflow, Dataflow::WeightStationary);
        let deep = sweep(&arch, Gemm::new(16, 1024, 16), &SweepOptions::default());
        assert_eq!(deep.candidates[0].dataflow, Dataflow::OutputStationary);
    }

    #[test]
    fn prop_pruned_sweep_matches_serial_reference() {
        // Seeded property test over random GEMM shapes and sweep options:
        // the pruned search must match the unpruned serial reference
        // exactly — candidates, costs, and configs_explored accounting.
        let arch = ArchDesc::gemmini();
        prop::check("pruned sweep == serial sweep", 40, |rng: &mut Rng| {
            let pow2 = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
            let n = *rng.pick(&pow2);
            let c = *rng.pick(&[8usize, 16, 24, 40, 64, 96, 128, 320, 640]);
            let k = *rng.pick(&pow2);
            let g = Gemm::new(n, c, k);
            let opts = SweepOptions {
                top_k_per_config: rng.range(1, 3),
                max_candidates: rng.range(4, 16),
                uneven_mapping: rng.chance(0.8),
                double_buffering: rng.chance(0.8),
                parallel: rng.chance(0.5),
                pruned: false,
            };
            let serial = sweep_serial(&arch, g, &opts);
            let pruned = sweep_pruned(&arch, g, &opts);
            if serial.configs_explored != pruned.configs_explored {
                return Err(format!(
                    "{g:?} {opts:?}: configs {} != {}",
                    serial.configs_explored, pruned.configs_explored
                ));
            }
            if serial.candidates != pruned.candidates {
                return Err(format!("{g:?} {opts:?}: candidate lists differ"));
            }
            let costs_s: Vec<f64> = serial.candidates.iter().map(|s| s.est.cost()).collect();
            let costs_p: Vec<f64> = pruned.candidates.iter().map(|s| s.est.cost()).collect();
            if costs_s != costs_p {
                return Err(format!("{g:?} {opts:?}: costs differ"));
            }
            if pruned.stats.leaves_visited > serial.stats.leaves_visited {
                return Err(format!(
                    "{g:?} {opts:?}: pruned visited more leaves ({} > {})",
                    pruned.stats.leaves_visited, serial.stats.leaves_visited
                ));
            }
            Ok(())
        });
    }
}
