//! The Fig. 2(b) outer sweep: "run the extended CoSA across all valid
//! combinations of tuning parameters, including accelerator-supported
//! dataflows, uneven mapping strategies, and double buffering", then hand
//! the refined candidates to the mapping generator for on-hardware
//! (simulator) profiling.

use crate::arch::{ArchDesc, Dataflow};
use crate::workload::Gemm;

use super::solver::{solve, SolverConfig};
use super::Schedule;

/// Options controlling the sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    /// Candidates kept per configuration point.
    pub top_k_per_config: usize,
    /// Global cap on candidates returned (best-first).
    pub max_candidates: usize,
    /// Explore uneven memory shares (paper's uneven mapping).
    pub uneven_mapping: bool,
    /// Explore double buffering (halved capacity, overlapped execution).
    pub double_buffering: bool,
    /// Solve the configuration points on scoped worker threads. The result
    /// is byte-identical to the serial sweep (tested), so this is purely a
    /// compile-time speed knob and is not part of the schedule-cache key.
    pub parallel: bool,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            top_k_per_config: 2,
            max_candidates: 8,
            uneven_mapping: true,
            double_buffering: true,
            parallel: true,
        }
    }
}

/// Result of a sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Candidate schedules, best analytic cost first.
    pub candidates: Vec<Schedule>,
    /// Number of (dataflow, shares, double-buffer) points explored.
    pub configs_explored: usize,
}

/// The ordered grid of configuration points (dataflow × memory shares ×
/// double buffering) the sweep explores. Both the serial and the parallel
/// sweep walk this exact order, which is what makes their outputs
/// identical: the final sort is stable, so ties keep grid order.
fn config_points(arch: &ArchDesc, opts: &SweepOptions) -> Vec<SolverConfig> {
    let even = [0.5f64, 0.5, 1.0];
    let mut share_configs: Vec<[f64; 3]> = vec![even];
    if opts.uneven_mapping {
        for s in &arch.constraints.memory_share_configs {
            if !share_configs.contains(s) {
                share_configs.push(*s);
            }
        }
    }
    let explore_db = opts.double_buffering && arch.constraints.supports_double_buffering;
    let db_configs: Vec<bool> = if explore_db { vec![false, true] } else { vec![false] };

    let mut points = Vec::new();
    for &dataflow in &arch.dataflows {
        for shares in &share_configs {
            for &db in &db_configs {
                points.push(SolverConfig {
                    dataflow,
                    shares: *shares,
                    double_buffer: db,
                    top_k: opts.top_k_per_config,
                });
            }
        }
    }
    points
}

/// Run the sweep for one GEMM workload. Dispatches to the parallel
/// implementation when `opts.parallel` is set; both paths return the
/// identical result.
pub fn sweep(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    if opts.parallel {
        sweep_parallel(arch, g, opts)
    } else {
        sweep_serial(arch, g, opts)
    }
}

/// The reference serial sweep (Fig. 2(b) outer loop).
pub fn sweep_serial(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let points = config_points(arch, opts);
    let mut candidates = Vec::new();
    for cfg in &points {
        candidates.extend(solve(arch, g, cfg));
    }
    finalize(candidates, points.len(), opts)
}

/// Parallel sweep: fan the configuration points out across scoped worker
/// threads (contiguous chunks, results concatenated in grid order), so the
/// candidate list is byte-identical to [`sweep_serial`]'s.
pub fn sweep_parallel(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let points = config_points(arch, opts);
    if points.len() < 2 {
        return sweep_serial(arch, g, opts);
    }
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(points.len());
    let chunk_len = crate::util::ceil_div(points.len(), workers);

    let mut per_point: Vec<Vec<Schedule>> = Vec::with_capacity(points.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = points
            .chunks(chunk_len)
            .map(|chunk| {
                scope.spawn(move || {
                    chunk.iter().map(|cfg| solve(arch, g, cfg)).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            per_point.extend(h.join().expect("sweep worker panicked"));
        }
    });

    let candidates: Vec<Schedule> = per_point.into_iter().flatten().collect();
    finalize(candidates, points.len(), opts)
}

/// Rank, dedup and truncate the raw per-config candidates.
fn finalize(
    mut candidates: Vec<Schedule>,
    configs_explored: usize,
    opts: &SweepOptions,
) -> SweepResult {
    candidates.sort_by(|a, b| a.est.cost().partial_cmp(&b.est.cost()).unwrap());
    // Global dedup: different share configs often produce the same mapping;
    // keep the first (cheapest) instance so the shortlist stays diverse.
    let mut seen: Vec<([usize; 3], [usize; 3], [crate::workload::Dim; 3], Dataflow, bool)> =
        Vec::new();
    candidates.retain(|s| {
        let key = (s.insn_tile, s.onchip_tile, s.dram_order, s.dataflow, s.double_buffer);
        if seen.contains(&key) {
            false
        } else {
            seen.push(key);
            true
        }
    });
    candidates.truncate(opts.max_candidates);
    SweepResult { candidates, configs_explored }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_explores_full_grid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(128, 128, 128), &SweepOptions::default());
        // 2 dataflows × 3 share configs (the even split is already one of
        // gemmini's share configs, so it dedups) × 2 db = 12.
        assert_eq!(r.configs_explored, 12);
        assert!(!r.candidates.is_empty());
        assert!(r.candidates.len() <= SweepOptions::default().max_candidates);
    }

    #[test]
    fn sweep_candidates_sorted_and_valid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(256, 256, 256), &SweepOptions::default());
        for w in r.candidates.windows(2) {
            assert!(w[0].est.cost() <= w[1].est.cost());
        }
        for s in &r.candidates {
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn sweep_explores_both_buffering_modes_for_large_layers() {
        // For streaming-scale GEMMs the trade-off between double buffering
        // (overlap) and single buffering (double the tile capacity) is
        // workload-dependent; the sweep must surface candidates of both
        // kinds so profiling can decide (Fig. 2b).
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions { max_candidates: 16, ..Default::default() };
        let r = sweep(&arch, Gemm::new(512, 512, 512), &opts);
        assert!(r.candidates.iter().any(|s| s.double_buffer));
        assert!(r.candidates.iter().any(|s| !s.double_buffer));
    }

    #[test]
    fn parallel_sweep_identical_to_serial() {
        // The acceptance bar: for the ToyCar layer shapes (and a couple of
        // streaming-scale shapes) the parallel sweep must return the exact
        // candidate list — same schedules, same order, same estimates — as
        // the serial reference.
        let arch = ArchDesc::gemmini();
        let shapes = [
            Gemm::new(1, 640, 128), // ToyCar input layer
            Gemm::new(1, 128, 128), // ToyCar trunk
            Gemm::new(1, 128, 8),   // ToyCar bottleneck
            Gemm::new(1, 8, 128),
            Gemm::new(1, 128, 640), // ToyCar output layer
            Gemm::new(64, 64, 64),
            Gemm::new(512, 512, 512),
        ];
        for g in shapes {
            let serial = sweep_serial(&arch, g, &SweepOptions::default());
            let parallel = sweep_parallel(&arch, g, &SweepOptions::default());
            assert_eq!(serial.configs_explored, parallel.configs_explored, "{g:?}");
            assert_eq!(serial.candidates, parallel.candidates, "{g:?}");
        }
    }

    #[test]
    fn parallel_flag_routes_both_ways() {
        let arch = ArchDesc::gemmini();
        let g = Gemm::new(96, 96, 96);
        let on = sweep(&arch, g, &SweepOptions { parallel: true, ..Default::default() });
        let off = sweep(&arch, g, &SweepOptions { parallel: false, ..Default::default() });
        assert_eq!(on.candidates, off.candidates);
    }

    #[test]
    fn disabling_knobs_shrinks_grid() {
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions {
            uneven_mapping: false,
            double_buffering: false,
            ..Default::default()
        };
        let r = sweep(&arch, Gemm::new(64, 64, 64), &opts);
        // 2 dataflows × 1 share × 1 db.
        assert_eq!(r.configs_explored, 2);
    }

    #[test]
    fn dataflow_choice_tracks_workload_shape() {
        // Streaming many rows through resident weights favors WS; deep
        // reductions with small outputs favor OS (accumulate in place).
        // The sweep must surface the right dataflow per shape.
        let arch = ArchDesc::gemmini();
        let tall = sweep(&arch, Gemm::new(512, 64, 64), &SweepOptions::default());
        assert_eq!(tall.candidates[0].dataflow, Dataflow::WeightStationary);
        let deep = sweep(&arch, Gemm::new(16, 1024, 16), &SweepOptions::default());
        assert_eq!(deep.candidates[0].dataflow, Dataflow::OutputStationary);
    }
}
