//! The Fig. 2(b) outer sweep: "run the extended CoSA across all valid
//! combinations of tuning parameters, including accelerator-supported
//! dataflows, uneven mapping strategies, and double buffering", then hand
//! the refined candidates to the mapping generator for on-hardware
//! (simulator) profiling.

use crate::arch::{ArchDesc, Dataflow};
use crate::workload::Gemm;

use super::solver::{solve, SolverConfig};
use super::Schedule;

/// Options controlling the sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    /// Candidates kept per configuration point.
    pub top_k_per_config: usize,
    /// Global cap on candidates returned (best-first).
    pub max_candidates: usize,
    /// Explore uneven memory shares (paper's uneven mapping).
    pub uneven_mapping: bool,
    /// Explore double buffering (halved capacity, overlapped execution).
    pub double_buffering: bool,
}

impl Default for SweepOptions {
    fn default() -> SweepOptions {
        SweepOptions {
            top_k_per_config: 2,
            max_candidates: 8,
            uneven_mapping: true,
            double_buffering: true,
        }
    }
}

/// Result of a sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Candidate schedules, best analytic cost first.
    pub candidates: Vec<Schedule>,
    /// Number of (dataflow, shares, double-buffer) points explored.
    pub configs_explored: usize,
}

/// Run the sweep for one GEMM workload.
pub fn sweep(arch: &ArchDesc, g: Gemm, opts: &SweepOptions) -> SweepResult {
    let even = [0.5f64, 0.5, 1.0];
    let mut share_configs: Vec<[f64; 3]> = vec![even];
    if opts.uneven_mapping {
        for s in &arch.constraints.memory_share_configs {
            if !share_configs.contains(s) {
                share_configs.push(*s);
            }
        }
    }
    let db_configs: Vec<bool> = if opts.double_buffering && arch.constraints.supports_double_buffering
    {
        vec![false, true]
    } else {
        vec![false]
    };

    let mut candidates = Vec::new();
    let mut configs_explored = 0;
    for &dataflow in &arch.dataflows {
        for shares in &share_configs {
            for &db in &db_configs {
                configs_explored += 1;
                let cfg = SolverConfig {
                    dataflow,
                    shares: *shares,
                    double_buffer: db,
                    top_k: opts.top_k_per_config,
                };
                candidates.extend(solve(arch, g, &cfg));
            }
        }
    }
    candidates.sort_by(|a, b| a.est.cost().partial_cmp(&b.est.cost()).unwrap());
    // Global dedup: different share configs often produce the same mapping;
    // keep the first (cheapest) instance so the shortlist stays diverse.
    let mut seen: Vec<([usize; 3], [usize; 3], [crate::workload::Dim; 3], Dataflow, bool)> =
        Vec::new();
    candidates.retain(|s| {
        let key = (s.insn_tile, s.onchip_tile, s.dram_order, s.dataflow, s.double_buffer);
        if seen.contains(&key) {
            false
        } else {
            seen.push(key);
            true
        }
    });
    candidates.truncate(opts.max_candidates);
    SweepResult { candidates, configs_explored }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_explores_full_grid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(128, 128, 128), &SweepOptions::default());
        // 2 dataflows × 3 share configs (the even split is already one of
        // gemmini's share configs, so it dedups) × 2 db = 12.
        assert_eq!(r.configs_explored, 12);
        assert!(!r.candidates.is_empty());
        assert!(r.candidates.len() <= SweepOptions::default().max_candidates);
    }

    #[test]
    fn sweep_candidates_sorted_and_valid() {
        let arch = ArchDesc::gemmini();
        let r = sweep(&arch, Gemm::new(256, 256, 256), &SweepOptions::default());
        for w in r.candidates.windows(2) {
            assert!(w[0].est.cost() <= w[1].est.cost());
        }
        for s in &r.candidates {
            s.validate(&arch).unwrap();
        }
    }

    #[test]
    fn sweep_explores_both_buffering_modes_for_large_layers() {
        // For streaming-scale GEMMs the trade-off between double buffering
        // (overlap) and single buffering (double the tile capacity) is
        // workload-dependent; the sweep must surface candidates of both
        // kinds so profiling can decide (Fig. 2b).
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions { max_candidates: 16, ..Default::default() };
        let r = sweep(&arch, Gemm::new(512, 512, 512), &opts);
        assert!(r.candidates.iter().any(|s| s.double_buffer));
        assert!(r.candidates.iter().any(|s| !s.double_buffer));
    }

    #[test]
    fn disabling_knobs_shrinks_grid() {
        let arch = ArchDesc::gemmini();
        let opts = SweepOptions {
            uneven_mapping: false,
            double_buffering: false,
            ..Default::default()
        };
        let r = sweep(&arch, Gemm::new(64, 64, 64), &opts);
        // 2 dataflows × 1 share × 1 db.
        assert_eq!(r.configs_explored, 2);
    }

    #[test]
    fn dataflow_choice_tracks_workload_shape() {
        // Streaming many rows through resident weights favors WS; deep
        // reductions with small outputs favor OS (accumulate in place).
        // The sweep must surface the right dataflow per shape.
        let arch = ArchDesc::gemmini();
        let tall = sweep(&arch, Gemm::new(512, 64, 64), &SweepOptions::default());
        assert_eq!(tall.candidates[0].dataflow, Dataflow::WeightStationary);
        let deep = sweep(&arch, Gemm::new(16, 1024, 16), &SweepOptions::default());
        assert_eq!(deep.candidates[0].dataflow, Dataflow::OutputStationary);
    }
}
