//! Graph-aware cross-layer scheduling: on-chip activation residency.
//!
//! The per-layer schedule search ([`super::sweep`]) prices every GEMM in
//! isolation, so each layer boundary pays a full store-to-DRAM + reload
//! round-trip even when the producer's output fits in the scratchpad —
//! exactly the "uneven mapping" waste the paper's DSE is meant to remove,
//! lifted one level up. This module plans at the *graph* level: given the
//! session's per-layer winners, it decides per producer→consumer edge
//! whether the activation stays resident on-chip, eliding the
//! `mvout`/`mvin` pair and replacing it with a single on-chip
//! [`crate::isa::Instr::MvoutSpad`].
//!
//! An edge can go resident when
//!
//! * producer and consumer are **consecutive accelerator layers on the
//!   same target** (a target switch tears down on-chip state — which is
//!   exactly the boundary cost [`switch_round_trip_cycles`] now charges
//!   the multi-target partitioner), the producer's output has a single
//!   consumer, and it is not a graph output;
//! * the **whole activation** is held as one tile on both sides: the
//!   producer's on-chip tile covers its full `N × K` output (it finishes
//!   in the accumulator and is parked in the scratchpad once), and the
//!   consumer's covers its full `N × C` input (it would have loaded it
//!   exactly once);
//! * both sides agree on the **column-block width** of the parked layout
//!   (producer `k0` == consumer `c0`), so the consumer's tensorized reads
//!   address the producer's blocks directly;
//! * both layers' own working sets still fit **below the pinned region**
//!   ([`ResidencyConstraint::admits`] mirrors codegen's allocation
//!   checks).
//!
//! When the unconstrained winners' loop orders are incompatible, the
//! planner re-runs a *boundary-constrained* search per side — the
//! schedule-cache key is extended with the [`ResidencyConstraint`], so
//! constrained selections are memoized (and persisted) exactly like
//! unconstrained ones — and adopts the pair only when the constrained
//! costs beat the unconstrained ones by less than the elided round-trip.
//!
//! Pinned regions are allocated from the **top of the scratchpad
//! downward**; along a resident chain each edge's region stacks below the
//! previous one (no reclamation — simple, safe, and tiny for edge-model
//! activations), and every layer's `reserved_rows` records the rows its
//! own tiles must stay clear of.

use anyhow::{ensure, Context, Result};

use crate::arch::ArchDesc;
use crate::util::ceil_div;
use crate::workload::Gemm;

use super::Schedule;

/// The residency half of an (extended) schedule-cache key: what a
/// boundary-constrained search demands of its winner. The all-zero value
/// ([`ResidencyConstraint::NONE`]) is the unconstrained search every
/// per-layer selection uses.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResidencyConstraint {
    /// Required input column-block width (`insn_tile[C]`) with the full
    /// `N × C` input resident as one on-chip tile; 0 = input unconstrained.
    pub in_block: u32,
    /// Required output column-block width (`insn_tile[K]`) with the full
    /// `N × K` output finishing in the accumulator; 0 = unconstrained.
    pub out_block: u32,
    /// Scratchpad rows (from the top) pinned by live resident regions
    /// while this layer runs; the layer's own tiles must fit below.
    pub reserved_rows: u32,
}

impl ResidencyConstraint {
    /// The unconstrained search (the ordinary per-layer selection).
    pub const NONE: ResidencyConstraint =
        ResidencyConstraint { in_block: 0, out_block: 0, reserved_rows: 0 };

    /// Whether this is the unconstrained search.
    pub fn is_none(&self) -> bool {
        *self == ResidencyConstraint::NONE
    }

    /// Whether schedule `s` satisfies this constraint on `arch`. The
    /// capacity arithmetic mirrors codegen's allocation exactly (rows in
    /// instruction-tile-wide column blocks, ping/pong slots when double
    /// buffered, resident input occupying no slot of its own).
    pub fn admits(&self, s: &Schedule, arch: &ArchDesc) -> bool {
        let g = &s.workload;
        if self.in_block > 0
            && (s.onchip_tile[0] != g.n
                || s.onchip_tile[1] != g.c
                || s.insn_tile[1] != self.in_block as usize)
        {
            return false;
        }
        if self.out_block > 0
            && (s.onchip_tile[0] != g.n
                || s.onchip_tile[2] != g.k
                || s.insn_tile[2] != self.out_block as usize)
        {
            return false;
        }
        let Ok((spad_rows, acc_rows)) = onchip_rows(arch) else {
            return false;
        };
        let [nt, ct, kt] = s.onchip_tile;
        let [_, c0, k0] = s.insn_tile;
        let slots = if s.double_buffer { 2usize } else { 1 };
        let rows_in = if self.in_block > 0 { 0 } else { nt * ceil_div(ct, c0.max(1)) };
        let rows_w = ct * ceil_div(kt, k0.max(1));
        let rows_out = nt * ceil_div(kt, k0.max(1));
        slots * (rows_in + rows_w) + self.reserved_rows as usize <= spad_rows
            && slots * rows_out <= acc_rows
    }
}

/// Per-layer residency decisions, consumed by codegen.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerResidency {
    /// Scratchpad base row of the resident *input* region (the layer reads
    /// its activation there instead of issuing DRAM loads).
    pub input_base: Option<u32>,
    /// Scratchpad base row of the resident *output* region (the layer
    /// parks its requantized activation there instead of storing to DRAM).
    pub output_base: Option<u32>,
    /// Scratchpad rows from the top the layer's own tiles must stay below.
    pub reserved_rows: u32,
}

/// One accelerator layer as the planner sees it: the session's selected
/// schedule plus its shape, profiled cost and assigned target.
#[derive(Debug, Clone)]
pub struct LayerSched {
    /// Graph-node name (for diagnostics).
    pub name: String,
    /// The layer's GEMM shape.
    pub gemm: Gemm,
    /// The currently selected schedule (replaced in the planner's output
    /// when a boundary-constrained search wins).
    pub schedule: Schedule,
    /// Profiled cycles of that schedule, when profiling ran.
    pub profiled_cycles: Option<u64>,
    /// Index of the assigned accelerator.
    pub target: usize,
}

/// One adopted resident edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidentEdge {
    /// Producer layer index (into the planner's layer list).
    pub producer: usize,
    /// Consumer layer index (always `producer + 1`).
    pub consumer: usize,
    /// Agreed column-block width of the parked layout.
    pub block: usize,
    /// Scratchpad rows the region occupies.
    pub rows: u32,
    /// Scratchpad base row of the region.
    pub base: u32,
    /// Analytic estimate of the elided DRAM round-trip, in cycles.
    pub saved_cycles: u64,
}

/// The planner's output: per-layer (possibly re-searched) schedules and
/// residency decisions, plus the adopted edges and diagnostics.
#[derive(Debug, Clone)]
pub struct GraphSchedule {
    /// The layers, in the order given, with adopted constrained schedules
    /// substituted in.
    pub layers: Vec<LayerSched>,
    /// Per-layer residency decisions, parallel to `layers`.
    pub residency: Vec<LayerResidency>,
    /// Adopted resident edges, in adoption order.
    pub resident: Vec<ResidentEdge>,
    /// Boundary-constrained searches the planner requested.
    pub searches: usize,
    /// Human-readable per-edge diagnostics for the stage report.
    pub notes: Vec<String>,
}

impl GraphSchedule {
    /// Total analytic cycles the adopted edges elide.
    pub fn saved_cycles(&self) -> u64 {
        self.resident.iter().map(|e| e.saved_cycles).sum()
    }
}

/// (scratchpad rows, accumulator rows) of an architecture — the same
/// numbers codegen allocates against.
pub fn onchip_rows(arch: &ArchDesc) -> Result<(usize, usize)> {
    let spad = arch
        .levels
        .iter()
        .find(|l| l.name == "Scratchpad")
        .context("arch has no Scratchpad level")?;
    let acc = arch
        .levels
        .iter()
        .find(|l| l.name == "Accumulator")
        .context("arch has no Accumulator level")?;
    Ok((spad.size_bytes / arch.pe_dim, acc.size_bytes / (arch.pe_dim * 4)))
}

/// Largest block width ≤ min(PE dim, Eq.(1) limit) that divides `e` — the
/// width a boundary-constrained pair agrees on when the unconstrained
/// winners disagree.
pub fn pick_block(e: usize, arch: &ArchDesc) -> usize {
    let cap = arch.pe_dim.min(arch.constraints.insn_tile_limit).max(1);
    (1..=cap.min(e)).rev().find(|b| e % b == 0).unwrap_or(1)
}

/// Analytic cycle cost of the DRAM round-trip a resident edge elides: the
/// producer's requantizing stores (int32 accumulator rows out) plus the
/// consumer's reloads (int8 rows back in), block by block, with the DMA's
/// per-row overheads, two request latencies and the issue beats of the
/// elided commands.
pub fn round_trip_cycles(arch: &ArchDesc, n: usize, e: usize, block: usize) -> u64 {
    let blocks = ceil_div(e, block.max(1)) as u64;
    let row_overhead = blocks * n as u64 * arch.dma.per_row_overhead;
    let store = ceil_div(n * e * 4, arch.dma.bytes_per_cycle) as u64;
    let load = ceil_div(n * e, arch.dma.bytes_per_cycle) as u64;
    2 * arch.dma.request_latency
        + 2 * row_overhead
        + store
        + load
        + 2 * blocks * arch.host.insn_issue_cycles
}

/// Cycle cost of the DRAM round-trip a *target switch* forces on an
/// activation of `elems` int8 elements: the producer's target stores it
/// (int32 accumulator reads), the consumer's target reloads it. Staying on
/// one target could have elided this via residency; the multi-accelerator
/// partitioner charges it to candidates that differ from the producer's
/// placement (previously a switch was free in the objective).
pub fn switch_round_trip_cycles(store: &ArchDesc, load: &ArchDesc, elems: usize) -> u64 {
    let rows_s = ceil_div(elems, store.pe_dim.max(1)) as u64;
    let rows_l = ceil_div(elems, load.pe_dim.max(1)) as u64;
    store.dma.request_latency
        + rows_s * store.dma.per_row_overhead
        + ceil_div(elems * 4, store.dma.bytes_per_cycle) as u64
        + load.dma.request_latency
        + rows_l * load.dma.per_row_overhead
        + ceil_div(elems, load.dma.bytes_per_cycle) as u64
}

/// The portion of [`switch_round_trip_cycles`] the overlapped executor
/// hides: the consumer side's reload (its request latency, per-row
/// overheads and streaming beats) double-buffers under the producer's
/// tail, leaving only the producer's store on the boundary's critical
/// path. By construction this is the load half of the round trip, so it
/// is always ≤ the full penalty and the discounted objective never goes
/// negative.
pub fn switch_overlap_discount(load: &ArchDesc, elems: usize) -> u64 {
    let rows_l = ceil_div(elems, load.pe_dim.max(1)) as u64;
    load.dma.request_latency
        + rows_l * load.dma.per_row_overhead
        + ceil_div(elems, load.dma.bytes_per_cycle) as u64
}

fn cycles_of(s: &Schedule, profiled: Option<u64>) -> u64 {
    profiled.unwrap_or_else(|| s.est.cost() as u64)
}

/// Plan residency over a chain of accelerator layers.
///
/// `arches[t]` is the architecture of target `t`; `edges` lists candidate
/// producer→consumer pairs as *indices into `layers`* (each consumer must
/// be `producer + 1`; the session only proposes direct single-use edges
/// between same-target neighbors). `search` runs a boundary-constrained
/// schedule selection for `(target, shape, constraint)` and returns
/// `Ok(None)` when no valid mapping satisfies the constraint.
///
/// Greedy over edges in order: an edge whose current winners are already
/// compatible is adopted outright (eliding the round-trip is a pure win);
/// otherwise both sides are re-searched under the agreed block width and
/// the pair is adopted only if the constrained costs beat the
/// unconstrained ones by less than the elided round-trip. With no adopted
/// edges the returned schedules are exactly the inputs, so downstream
/// stages emit byte-identical programs.
pub fn plan<F>(
    arches: &[&ArchDesc],
    mut layers: Vec<LayerSched>,
    edges: &[(usize, usize)],
    mut search: F,
) -> Result<GraphSchedule>
where
    F: FnMut(usize, Gemm, ResidencyConstraint) -> Result<Option<(Schedule, Option<u64>)>>,
{
    let mut residency = vec![LayerResidency::default(); layers.len()];
    // Lowest live pinned base while each layer runs (scratchpad rows when
    // nothing is pinned yet), and the in-constraint the layer's current
    // schedule was chosen under.
    let mut floor: Vec<u32> = Vec::with_capacity(layers.len());
    for l in &layers {
        let arch = arches.get(l.target).context("layer target out of range")?;
        floor.push(onchip_rows(arch)?.0 as u32);
    }
    let mut in_block: Vec<u32> = vec![0; layers.len()];
    let mut resident = Vec::new();
    let mut notes = Vec::new();
    let mut searches = 0usize;

    for &(p, c) in edges {
        ensure!(
            c == p + 1 && c < layers.len(),
            "resident edges must join consecutive layers ({p} -> {c})"
        );
        let edge_name = format!("{} -> {}", layers[p].name, layers[c].name);
        if layers[p].target != layers[c].target {
            notes.push(format!("{edge_name}: target switch, not resident"));
            continue;
        }
        let t = layers[p].target;
        let arch = arches[t];
        let (gp, gc) = (layers[p].gemm, layers[c].gemm);
        ensure!(
            gp.n == gc.n && gp.k == gc.c,
            "{edge_name}: edge joins mismatched shapes {gp:?} / {gc:?}"
        );
        let (nrows, e) = (gp.n, gp.k);

        // Agree on the parked layout's block width: the producer's k0 when
        // both winners already share it, the widest valid divisor
        // otherwise.
        let pk = layers[p].schedule.insn_tile[2];
        let ck = layers[c].schedule.insn_tile[1];
        let block = if pk == ck && pk > 0 && e % pk == 0 { pk } else { pick_block(e, arch) };
        // Both branches guarantee divisibility (the fast path checks it,
        // `pick_block` only returns divisors).
        debug_assert_eq!(e % block, 0, "{edge_name}: block {block} must divide {e}");
        let rows_e = (nrows * ceil_div(e, block)) as u32;
        let Some(base) = floor[p].checked_sub(rows_e) else {
            notes.push(format!("{edge_name}: activation exceeds scratchpad, not resident"));
            continue;
        };
        let (spad_rows, _) = onchip_rows(arch)?;
        let reserved = spad_rows as u32 - base;
        let rc_p = ResidencyConstraint {
            in_block: in_block[p],
            out_block: block as u32,
            reserved_rows: reserved,
        };
        let rc_c = ResidencyConstraint {
            in_block: block as u32,
            out_block: 0,
            reserved_rows: reserved,
        };

        // Producer side: keep the current winner when it already satisfies
        // the boundary constraint, re-search otherwise.
        // A search may return a non-admitting schedule (the memoized
        // infeasibility marker — see `select_schedule_constrained`);
        // re-checking `admits` here turns that into "edge not resident".
        let (new_p, cyc_p, searched_p) = if rc_p.admits(&layers[p].schedule, arch) {
            (layers[p].schedule.clone(), layers[p].profiled_cycles, false)
        } else {
            searches += 1;
            match search(t, gp, rc_p)? {
                Some((s, cyc)) if rc_p.admits(&s, arch) => (s, cyc, true),
                _ => {
                    notes.push(format!(
                        "{edge_name}: no producer mapping under residency, not resident"
                    ));
                    continue;
                }
            }
        };
        let (new_c, cyc_c, searched_c) = if rc_c.admits(&layers[c].schedule, arch) {
            (layers[c].schedule.clone(), layers[c].profiled_cycles, false)
        } else {
            searches += 1;
            match search(t, gc, rc_c)? {
                Some((s, cyc)) if rc_c.admits(&s, arch) => (s, cyc, true),
                _ => {
                    notes.push(format!(
                        "{edge_name}: no consumer mapping under residency, not resident"
                    ));
                    continue;
                }
            }
        };

        let saving = round_trip_cycles(arch, nrows, e, block);
        let old = cycles_of(&layers[p].schedule, layers[p].profiled_cycles)
            + cycles_of(&layers[c].schedule, layers[c].profiled_cycles);
        let new = cycles_of(&new_p, cyc_p) + cycles_of(&new_c, cyc_c);
        if new >= old + saving {
            notes.push(format!(
                "{edge_name}: constrained pair costs {new} vs {old} + {saving} elided, \
                 not resident"
            ));
            continue;
        }

        layers[p].schedule = new_p;
        layers[p].profiled_cycles = cyc_p;
        layers[c].schedule = new_c;
        layers[c].profiled_cycles = cyc_c;
        residency[p].output_base = Some(base);
        residency[p].reserved_rows = reserved;
        residency[c].input_base = Some(base);
        residency[c].reserved_rows = reserved;
        floor[p] = base;
        floor[c] = base;
        in_block[c] = block as u32;
        notes.push(format!(
            "{edge_name}: resident ({rows_e} row(s) @ sp[{base}], block {block}, \
             ~{saving} cycle round-trip elided{})",
            match (searched_p, searched_c) {
                (false, false) => "",
                (true, false) => ", producer re-searched",
                (false, true) => ", consumer re-searched",
                (true, true) => ", both re-searched",
            }
        ));
        resident.push(ResidentEdge {
            producer: p,
            consumer: c,
            block,
            rows: rows_e,
            base,
            saved_cycles: saving,
        });
    }

    Ok(GraphSchedule { layers, residency, resident, searches, notes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::sweep::{sweep, SweepOptions};
    use crate::util::prng::Rng;

    fn winner(arch: &ArchDesc, g: Gemm) -> Schedule {
        sweep(arch, g, &SweepOptions::default()).candidates[0].clone()
    }

    fn chain(arch: &ArchDesc, widths: &[usize], batch: usize) -> Vec<LayerSched> {
        widths
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let g = Gemm::new(batch, w[0], w[1]);
                LayerSched {
                    name: format!("fc{i}"),
                    gemm: g,
                    schedule: winner(arch, g),
                    profiled_cycles: None,
                    target: 0,
                }
            })
            .collect()
    }

    fn all_edges(n: usize) -> Vec<(usize, usize)> {
        (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect()
    }

    /// A real boundary-constrained search: the full sweep filtered by the
    /// constraint (what `Compiler::select_schedule_constrained` does,
    /// minus cache and profiling).
    fn constrained_search(
        arch: &ArchDesc,
        g: Gemm,
        rc: ResidencyConstraint,
    ) -> Option<(Schedule, Option<u64>)> {
        sweep(arch, g, &SweepOptions::default())
            .candidates
            .into_iter()
            .find(|s| rc.admits(s, arch))
            .map(|s| (s, None))
    }

    #[test]
    fn toycar_chain_adopts_resident_edges() {
        let arch = ArchDesc::gemmini();
        let layers = chain(&arch, &crate::workload::suites::toycar_widths(), 1);
        let edges = all_edges(layers.len());
        let gs =
            plan(&[&arch], layers, &edges, |_, g, rc| Ok(constrained_search(&arch, g, rc)))
                .unwrap();
        assert!(
            !gs.resident.is_empty(),
            "ToyCar activations fit on-chip; some edge must go resident: {:?}",
            gs.notes
        );
        assert!(gs.saved_cycles() > 0);
        for e in &gs.resident {
            assert_eq!(e.consumer, e.producer + 1);
            assert_eq!(gs.residency[e.producer].output_base, Some(e.base));
            assert_eq!(gs.residency[e.consumer].input_base, Some(e.base));
        }
    }

    #[test]
    fn target_switch_blocks_residency() {
        let arch = ArchDesc::gemmini();
        let mut layers = chain(&arch, &[64, 64, 64], 4);
        layers[1].target = 1;
        let edges = all_edges(layers.len());
        let gs = plan(&[&arch, &arch], layers, &edges, |_, _, _| Ok(None)).unwrap();
        assert!(gs.resident.is_empty(), "cross-target edges must stay non-resident");
    }

    #[test]
    fn unconstrained_key_is_default_and_admits_mirrors_capacity() {
        let arch = ArchDesc::gemmini();
        assert!(ResidencyConstraint::NONE.is_none());
        assert_eq!(ResidencyConstraint::default(), ResidencyConstraint::NONE);
        let g = Gemm::new(1, 128, 128);
        let s = winner(&arch, g);
        // The unconstrained constraint admits any sweep winner.
        assert!(ResidencyConstraint::NONE.admits(&s, &arch));
        // An absurd reservation starves the layer's own tiles.
        let starved = ResidencyConstraint {
            in_block: 0,
            out_block: 0,
            reserved_rows: onchip_rows(&arch).unwrap().0 as u32,
        };
        assert!(!starved.admits(&s, &arch));
    }

    #[test]
    fn prop_residency_never_exceeds_capacity_rows() {
        // For random layer chains, every planned layer must keep its own
        // working set plus the pinned regions within the scratchpad, and
        // pinned regions must sit entirely inside the scratchpad.
        let arch = ArchDesc::gemmini();
        let (spad_rows, _) = onchip_rows(&arch).unwrap();
        crate::util::prop::check("residency fits capacity", 20, |rng: &mut Rng| {
            let pick = [8usize, 16, 32, 64, 128, 256, 640];
            let n_layers = rng.range(2, 5);
            let mut widths = Vec::with_capacity(n_layers + 1);
            for _ in 0..=n_layers {
                widths.push(*rng.pick(&pick));
            }
            let batch = *rng.pick(&[1usize, 2, 4, 8]);
            let layers = chain(&arch, &widths, batch);
            let edges = all_edges(layers.len());
            let gs = plan(&[&arch], layers, &edges, |_, g, rc| {
                Ok(constrained_search(&arch, g, rc))
            })
            .map_err(|e| e.to_string())?;
            for (i, l) in gs.layers.iter().enumerate() {
                let r = &gs.residency[i];
                if r.reserved_rows as usize > spad_rows {
                    return Err(format!("layer {i}: reserved beyond scratchpad"));
                }
                let rc = ResidencyConstraint {
                    in_block: 0,
                    out_block: 0,
                    reserved_rows: r.reserved_rows,
                };
                // The adopted schedule must fit beside the reservation
                // (admits checks shape constraints only when blocks are
                // set; here we check pure capacity).
                if r.input_base.is_none() && !rc.admits(&l.schedule, &arch) {
                    return Err(format!("layer {i}: working set overflows reservation"));
                }
                for base in [r.input_base, r.output_base].into_iter().flatten() {
                    if base as usize >= spad_rows {
                        return Err(format!("layer {i}: pinned base outside scratchpad"));
                    }
                    if (base as usize) < spad_rows - r.reserved_rows as usize {
                        return Err(format!("layer {i}: pinned base below reservation"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn round_trip_costs_are_positive_and_scale() {
        let arch = ArchDesc::gemmini();
        let small = round_trip_cycles(&arch, 1, 128, 16);
        let big = round_trip_cycles(&arch, 8, 640, 16);
        assert!(small > 0);
        assert!(big > small);
        let sw = switch_round_trip_cycles(&arch, &arch, 128);
        assert!(sw > 0);
    }

    #[test]
    fn overlap_discount_never_exceeds_the_round_trip() {
        let gem = ArchDesc::gemmini();
        let mut wide = ArchDesc::gemmini();
        wide.pe_dim = 32;
        wide.dma.bytes_per_cycle = 32;
        for elems in [1usize, 8, 128, 640, 1000] {
            for (s, l) in [(&gem, &gem), (&gem, &wide), (&wide, &gem)] {
                let rt = switch_round_trip_cycles(s, l, elems);
                let d = switch_overlap_discount(l, elems);
                assert!(d > 0, "the consumer reload always costs something");
                assert!(d < rt, "discount {d} must stay below round trip {rt}");
            }
        }
    }

    #[test]
    fn pick_block_divides_and_respects_limits() {
        let arch = ArchDesc::gemmini();
        assert_eq!(pick_block(128, &arch), 16);
        assert_eq!(pick_block(8, &arch), 8);
        assert_eq!(pick_block(6, &arch), 6);
        assert_eq!(pick_block(7, &arch), 7);
        for e in [1usize, 2, 3, 5, 9, 24, 100, 640] {
            let b = pick_block(e, &arch);
            assert!(b >= 1 && e % b == 0 && b <= arch.pe_dim);
        }
    }
}
