//! Analytic cost model for candidate mappings: DRAM traffic per operand
//! under a given DRAM-level loop permutation (Timeloop/CoSA-style reuse
//! analysis), execute-queue occupancy, and front-end issue load.
//!
//! The model intentionally mirrors the simulator's timing structure
//! (same DMA latency formula, same per-instruction systolic costs) so that
//! analytic ranking and simulator profiling agree on ordering in the
//! common case; final selection is still done by profiling (Fig. 2b).

use crate::arch::{ArchDesc, Dataflow};
use crate::util::ceil_div;
use crate::workload::{Dim, Gemm, Operand};

use super::Estimate;

/// Inputs to the cost model (a schedule candidate before packaging).
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// The GEMM being mapped.
    pub workload: Gemm,
    /// PE-array dataflow of the candidate mapping.
    pub dataflow: Dataflow,
    /// Whether transfers overlap compute via ping/pong buffers.
    pub double_buffer: bool,
    /// Per-compute-instruction tile `(n0, c0, k0)`.
    pub insn_tile: [usize; 3],
    /// On-chip-resident tile `(nt, ct, kt)`.
    pub onchip_tile: [usize; 3],
    /// DRAM-level loop order, outermost first.
    pub dram_order: [Dim; 3],
}

/// Number of times each operand's on-chip tile is (re)fetched from DRAM:
/// the product of trip counts of all DRAM loops at or outside the
/// operand's innermost use. Loops strictly inside that point iterate only
/// the operand's reuse dimension, so the resident tile is reused.
pub fn tile_loads(c: &Candidate, op: Operand) -> u64 {
    let trips = |d: Dim| ceil_div(c.workload.bound(d), c.onchip_tile[d.index()]) as u64;
    // Loops with a single trip never force a refetch; ignore them when
    // finding the operand's innermost use (keeps the model consistent with
    // codegen's reload-dedup).
    let last_use = c
        .dram_order
        .iter()
        .rposition(|&d| op.uses(d) && trips(d) > 1)
        .unwrap_or(0);
    c.dram_order[..=last_use].iter().map(|&d| trips(d)).product()
}

/// Full traffic/latency estimate for a candidate.
pub fn estimate(arch: &ArchDesc, c: &Candidate) -> Estimate {
    let g = &c.workload;
    let dim = arch.pe_dim;
    let [nt, ct, kt] = c.onchip_tile;
    let [n0, c0, k0] = c.insn_tile;
    let dma = &arch.dma;

    // --- DRAM traffic ----------------------------------------------------
    let loads_in = tile_loads(c, Operand::Input) as f64;
    let loads_w = tile_loads(c, Operand::Weight) as f64;
    let visits_out = tile_loads(c, Operand::Output) as f64;
    let out_tiles = (ceil_div(g.n, nt) * ceil_div(g.k, kt)) as f64;
    // Revisit factor > 1 means int32 partial sums spill to DRAM and return.
    let revisit = (visits_out / out_tiles).max(1.0);

    let tile_in = (nt * ct) as f64;
    let tile_w = (ct * kt) as f64;
    let tile_out = (nt * kt) as f64;
    let bytes_in = tile_in * loads_in;
    let bytes_w = tile_w * loads_w;
    // Final int8 write once per tile + int32 round trips for extra visits.
    let bytes_out = tile_out * out_tiles * (1.0 + (revisit - 1.0) * 8.0);

    // --- DMA cycles -------------------------------------------------------
    // One strided MVIN per insn-wide column block of a tile.
    let mvins_in = loads_in * ceil_div(ct, c0) as f64;
    let mvins_w = loads_w * ceil_div(kt, k0) as f64;
    let mvouts = out_tiles * revisit * ceil_div(kt, k0) as f64;
    let req = dma.request_latency as f64;
    let row_oh = dma.per_row_overhead as f64;
    let bpc = dma.bytes_per_cycle as f64;
    let dma_cycles = mvins_in * (req + nt as f64 * row_oh)
        + bytes_in / bpc
        + mvins_w * (req + ct as f64 * row_oh)
        + bytes_w / bpc
        + mvouts * (req + nt as f64 * row_oh)
        // Accumulator reads are 4 B/element on the on-chip side.
        + tile_out * out_tiles * revisit * 4.0 / bpc;

    // --- Execute-queue cycles ---------------------------------------------
    let outer: f64 = Dim::ALL
        .iter()
        .map(|&d| ceil_div(g.bound(d), c.onchip_tile[d.index()]) as f64)
        .product();
    // Preload count/cost mirrors the codegen's stationary-dedup: under WS
    // one preload per (c,k) instruction tile (streamed N inner); under OS
    // one per (n,k) tile, paying the array-drain cost.
    let (preloads_per, preload_cost) = match c.dataflow {
        Dataflow::WeightStationary => (
            (ceil_div(ct, c0) * ceil_div(kt, k0)) as f64,
            4.0, // overlapped with the previous compute
        ),
        Dataflow::OutputStationary => (
            (ceil_div(nt, n0) * ceil_div(kt, k0)) as f64,
            n0 as f64 + dim as f64,
        ),
    };
    let computes_per =
        (ceil_div(ct, c0) * ceil_div(kt, k0) * ceil_div(nt, n0)) as f64;
    let compute_cycles =
        outer * (preloads_per * preload_cost + computes_per * (n0 as f64 + 8.0));

    // --- Front-end issue --------------------------------------------------
    let insns = outer * (preloads_per + computes_per)
        + mvins_in
        + mvins_w
        + mvouts
        + mvins_in.max(mvins_w); // config churn
    let issue_cycles = insns * arch.host.insn_issue_cycles as f64;

    // --- Latency ----------------------------------------------------------
    let engines = compute_cycles + dma_cycles + issue_cycles;
    let bound = compute_cycles.max(dma_cycles).max(issue_cycles);
    let latency = if c.double_buffer {
        // Ping-pong buffers overlap DMA with compute; the run is bound by
        // the slowest engine.
        bound + req
    } else {
        // Single-buffered: the codegen's reload-dedup and the decoupled
        // queues still overlap most work; only the WAR stall on each
        // freshly streamed tile serializes. Model that as a fraction of
        // the non-dominant engine time (calibrated against the simulator,
        // EXPERIMENTS.md §Perf).
        bound + 0.25 * (engines - bound) + req
    };

    // --- Spatial utilization ----------------------------------------------
    let sd = c.dataflow.spatial_dims();
    let spatial = sd
        .iter()
        .map(|&d| c.insn_tile[d.index()] as f64)
        .product::<f64>();
    let utilization = spatial / (dim * dim) as f64;

    Estimate {
        compute_cycles,
        dma_cycles,
        issue_cycles,
        latency,
        bytes: [bytes_in, bytes_w, bytes_out],
        utilization,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(onchip: [usize; 3], order: [Dim; 3]) -> Candidate {
        Candidate {
            workload: Gemm::new(512, 512, 512),
            dataflow: Dataflow::WeightStationary,
            double_buffer: true,
            insn_tile: [16, 16, 16],
            onchip_tile: onchip,
            dram_order: order,
        }
    }

    #[test]
    fn k_innermost_reuses_input() {
        // Order (N, C, K): input's last-used loop is C (position 1); the K
        // loop inside reuses the input tile → input loaded exactly once.
        let c = cand([128, 128, 128], [Dim::N, Dim::C, Dim::K]);
        assert_eq!(tile_loads(&c, Operand::Input), 4 * 4);
        // Weight's last use is K (innermost) → full product.
        assert_eq!(tile_loads(&c, Operand::Weight), 4 * 4 * 4);
        // Output uses N and K; last use K → 64 visits over 16 tiles = 4
        // revisits per tile (C iterates outside the output's computation).
        assert_eq!(tile_loads(&c, Operand::Output), 64);
    }

    #[test]
    fn c_innermost_finishes_outputs() {
        // Order (N, K, C): output finished in one visit, no spills.
        let c = cand([128, 128, 128], [Dim::N, Dim::K, Dim::C]);
        let out_tiles = 4 * 4;
        assert_eq!(tile_loads(&c, Operand::Output), out_tiles);
    }

    #[test]
    fn spill_traffic_penalized() {
        let no_spill = estimate(&ArchDesc::gemmini(), &cand([128, 128, 128], [Dim::N, Dim::K, Dim::C]));
        let spill = estimate(&ArchDesc::gemmini(), &cand([128, 128, 128], [Dim::C, Dim::N, Dim::K]));
        assert!(spill.bytes[2] > no_spill.bytes[2] * 3.0);
    }

    #[test]
    fn bigger_tiles_reduce_weight_traffic() {
        let arch = ArchDesc::gemmini();
        let small = estimate(&arch, &cand([64, 64, 64], [Dim::N, Dim::K, Dim::C]));
        let big = estimate(&arch, &cand([128, 256, 128], [Dim::N, Dim::K, Dim::C]));
        assert!(big.bytes[1] < small.bytes[1]);
    }

    #[test]
    fn double_buffer_reduces_latency() {
        let arch = ArchDesc::gemmini();
        let mut c = cand([128, 128, 128], [Dim::N, Dim::K, Dim::C]);
        let db = estimate(&arch, &c);
        c.double_buffer = false;
        let serial = estimate(&arch, &c);
        assert!(db.latency < serial.latency);
    }

    #[test]
    fn utilization_full_array() {
        let arch = ArchDesc::gemmini();
        let e = estimate(&arch, &cand([128, 128, 128], [Dim::N, Dim::K, Dim::C]));
        assert!((e.utilization - 1.0).abs() < 1e-12);
        let mut c = cand([128, 128, 128], [Dim::N, Dim::K, Dim::C]);
        c.insn_tile = [16, 8, 16];
        let e2 = estimate(&arch, &c);
        assert!((e2.utilization - 0.5).abs() < 1e-12);
    }
}
