//! Persistent on-disk artifact for [`ScheduleCache`] entries.
//!
//! Selections are pure data (shape + tiling decision + measured cycles),
//! so a long-lived compile service — and even a plain repeat CLI
//! invocation — can skip the Fig. 2(b) sweep entirely by hydrating the
//! cache from disk. The format is a hand-rolled, versioned,
//! length-prefixed binary (no external dependencies):
//!
//! ```text
//! header  b"TVAS" (4 bytes) + format version u32 (LE)
//! entry*  payload_len u32 | fnv1a64(payload) u64 | payload
//! ```
//!
//! Every payload encodes one `(CacheKey, CachedSelection)` pair — since
//! format 2 including the key's cross-layer [`ResidencyConstraint`] and a
//! trailing *last-served* wall-clock stamp, which [`trim_file`] uses for
//! LRU eviction (`tvm-accel cache gc --max-entries N`). Format-1 files
//! (and any other version) simply load cold. Fields are little-endian and
//! fixed-width. Robustness rules, in order:
//!
//! * **missing file / bad magic / other format version** → empty load
//!   (cold cache), never an error;
//! * **corrupted entry** (checksum or field-level decode failure) → that
//!   entry is skipped, the scan continues at the next length prefix;
//! * **truncated file** (a length prefix or payload extends past EOF) →
//!   the scan stops, keeping everything decoded so far.
//!
//! Writes are atomic: the snapshot is serialized to a sibling temp file
//! and `rename(2)`d over the destination, so a crashed or concurrent
//! writer can never leave a half-written artifact where readers look.
//! The cache key embeds the accelerator fingerprint, the GEMM shape and
//! the search options, so one artifact safely serves many accelerator
//! descriptions at once — exactly like the in-memory cache it mirrors.

use std::path::Path;

use anyhow::{Context, Result};

use crate::arch::Dataflow;
use crate::pipeline::SessionMemo;
use crate::workload::{Dim, Gemm};

use super::cache::{CacheKey, CachedSelection, ScheduleCache, SearchKey};
use super::graph::ResidencyConstraint;
use super::{Estimate, Schedule};

/// File magic ("TVm-Accel Schedules").
pub const MAGIC: &[u8; 4] = b"TVAS";

/// Current format version. Bumping it invalidates every existing artifact
/// (old files load as empty, old readers skip new files). Version 2 added
/// the residency-constraint key half and the last-served LRU stamp.
pub const FORMAT_VERSION: u32 = 2;

/// File magic of the session-memo artifact ("TVm-Accel Memo"). The memo
/// ([`crate::pipeline::SessionMemo`]) persists next to the schedule cache
/// so *incremental* recompiles stay warm across processes; it shares the
/// cache artifact's entry codec (with a zero LRU stamp) but carries its
/// own magic + version so the two files can never be confused.
pub const MEMO_MAGIC: &[u8; 4] = b"TVAM";

/// Current format version of the memo artifact.
pub const MEMO_FORMAT_VERSION: u32 = 1;

/// Upper bound on one entry's payload (an entry is a few hundred bytes;
/// anything larger is a corrupted length prefix).
const MAX_ENTRY_BYTES: usize = 4096;

/// Stable 64-bit FNV-1a, the per-entry checksum of the cache artifact
/// (also handy as a cheap content hash for byte-identity assertions).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What a cache-file load found. Loading never fails: a missing,
/// truncated, corrupted or version-mismatched file yields fewer (or zero)
/// entries — a cold cache — instead of an error.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Entries decoded successfully.
    pub loaded: usize,
    /// Records skipped (checksum mismatch, undecodable payload, trailing
    /// truncation).
    pub skipped: usize,
}

// --- encoding ---------------------------------------------------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_gemm(out: &mut Vec<u8>, g: &Gemm) {
    put_usize(out, g.n);
    put_usize(out, g.c);
    put_usize(out, g.k);
}

/// Serialize one entry into its payload bytes.
fn encode_entry(key: &CacheKey, sel: &CachedSelection, last_served: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(256);
    // Key.
    put_u64(&mut p, key.arch);
    put_gemm(&mut p, &key.gemm);
    put_usize(&mut p, key.search.top_k_per_config);
    put_usize(&mut p, key.search.max_candidates);
    p.push(key.search.uneven_mapping as u8);
    p.push(key.search.double_buffering as u8);
    put_usize(&mut p, key.search.profile_candidates);
    put_u32(&mut p, key.residency.in_block);
    put_u32(&mut p, key.residency.out_block);
    put_u32(&mut p, key.residency.reserved_rows);
    // Measured cycles.
    match sel.profiled_cycles {
        Some(c) => {
            p.push(1);
            put_u64(&mut p, c);
        }
        None => {
            p.push(0);
            put_u64(&mut p, 0);
        }
    }
    // Schedule.
    let s = &sel.schedule;
    put_gemm(&mut p, &s.workload);
    p.push(match s.dataflow {
        Dataflow::WeightStationary => 0,
        Dataflow::OutputStationary => 1,
    });
    p.push(s.double_buffer as u8);
    for v in s.shares {
        put_f64(&mut p, v);
    }
    for v in s.insn_tile {
        put_usize(&mut p, v);
    }
    for v in s.onchip_tile {
        put_usize(&mut p, v);
    }
    for d in s.dram_order {
        p.push(d.index() as u8);
    }
    put_f64(&mut p, s.est.compute_cycles);
    put_f64(&mut p, s.est.dma_cycles);
    put_f64(&mut p, s.est.issue_cycles);
    put_f64(&mut p, s.est.latency);
    for v in s.est.bytes {
        put_f64(&mut p, v);
    }
    put_f64(&mut p, s.est.utilization);
    // LRU stamp (trailing so the schedule decode stays contiguous).
    put_u64(&mut p, last_served);
    p
}

// --- decoding ---------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return None;
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn bool(&mut self) -> Option<bool> {
        match self.u8()? {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn usize(&mut self) -> Option<usize> {
        usize::try_from(self.u64()?).ok()
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn gemm(&mut self) -> Option<Gemm> {
        let (n, c, k) = (self.usize()?, self.usize()?, self.usize()?);
        if n == 0 || c == 0 || k == 0 {
            return None; // Gemm::new would panic on zero dims
        }
        Some(Gemm { n, c, k })
    }

    fn usize3(&mut self) -> Option<[usize; 3]> {
        Some([self.usize()?, self.usize()?, self.usize()?])
    }

    fn f64x3(&mut self) -> Option<[f64; 3]> {
        Some([self.f64()?, self.f64()?, self.f64()?])
    }
}

/// Decode one payload; `None` on any structural problem.
fn decode_entry(payload: &[u8]) -> Option<(CacheKey, CachedSelection, u64)> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let key = CacheKey {
        arch: c.u64()?,
        gemm: c.gemm()?,
        search: SearchKey {
            top_k_per_config: c.usize()?,
            max_candidates: c.usize()?,
            uneven_mapping: c.bool()?,
            double_buffering: c.bool()?,
            profile_candidates: c.usize()?,
        },
        residency: ResidencyConstraint {
            in_block: c.u32()?,
            out_block: c.u32()?,
            reserved_rows: c.u32()?,
        },
    };
    let has_cycles = c.bool()?;
    let cycles = c.u64()?;
    let workload = c.gemm()?;
    let dataflow = match c.u8()? {
        0 => Dataflow::WeightStationary,
        1 => Dataflow::OutputStationary,
        _ => return None,
    };
    let double_buffer = c.bool()?;
    let shares = c.f64x3()?;
    let insn_tile = c.usize3()?;
    let onchip_tile = c.usize3()?;
    let mut dram_order = [Dim::N; 3];
    for slot in &mut dram_order {
        *slot = *Dim::ALL.get(c.u8()? as usize)?;
    }
    let est = Estimate {
        compute_cycles: c.f64()?,
        dma_cycles: c.f64()?,
        issue_cycles: c.f64()?,
        latency: c.f64()?,
        bytes: c.f64x3()?,
        utilization: c.f64()?,
    };
    let last_served = c.u64()?;
    if c.pos != payload.len() {
        return None; // trailing bytes: treat as corruption
    }
    let schedule = Schedule {
        workload,
        dataflow,
        double_buffer,
        shares,
        insn_tile,
        onchip_tile,
        dram_order,
        est,
    };
    Some((
        key,
        CachedSelection {
            schedule,
            profiled_cycles: if has_cycles { Some(cycles) } else { None },
        },
        last_served,
    ))
}

// --- file I/O ---------------------------------------------------------

/// Serialize stamped entries under an artifact header (shared by the
/// cache and memo artifacts).
fn encode_entries(
    entries: &[(CacheKey, CachedSelection, u64)],
    magic: &[u8; 4],
    version: u32,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + entries.len() * 300);
    out.extend_from_slice(magic);
    out.extend_from_slice(&version.to_le_bytes());
    for (key, sel, stamp) in entries {
        let payload = encode_entry(key, sel, *stamp);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
    }
    out
}

/// Serialize stamped `entries` (as produced by
/// [`ScheduleCache::snapshot_stamped`]) into the artifact byte format.
pub fn encode(entries: &[(CacheKey, CachedSelection, u64)]) -> Vec<u8> {
    encode_entries(entries, MAGIC, FORMAT_VERSION)
}

/// Decode an artifact byte buffer under the expected header, skipping
/// what cannot be read (see the module docs for the tolerance rules).
fn decode_entries(
    buf: &[u8],
    magic: &[u8; 4],
    expect_version: u32,
) -> (Vec<(CacheKey, CachedSelection, u64)>, LoadReport) {
    let mut rep = LoadReport::default();
    let mut entries = Vec::new();
    if buf.len() < 8 || &buf[0..4] != magic {
        return (entries, rep);
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes"));
    if version != expect_version {
        return (entries, rep);
    }
    let mut pos = 8;
    while pos < buf.len() {
        if pos + 12 > buf.len() {
            rep.skipped += 1; // trailing garbage shorter than a prefix
            break;
        }
        let len =
            u32::from_le_bytes(buf[pos..pos + 4].try_into().expect("4 bytes")) as usize;
        let sum = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().expect("8 bytes"));
        pos += 12;
        if len > MAX_ENTRY_BYTES || len > buf.len() - pos {
            rep.skipped += 1; // truncated or absurd length: cannot resync
            break;
        }
        let payload = &buf[pos..pos + len];
        pos += len;
        if fnv1a64(payload) != sum {
            rep.skipped += 1;
            continue;
        }
        match decode_entry(payload) {
            Some(e) => {
                entries.push(e);
                rep.loaded += 1;
            }
            None => rep.skipped += 1,
        }
    }
    (entries, rep)
}

/// Decode an artifact byte buffer, skipping what cannot be read (see the
/// module docs for the exact tolerance rules).
pub fn decode(buf: &[u8]) -> (Vec<(CacheKey, CachedSelection, u64)>, LoadReport) {
    decode_entries(buf, MAGIC, FORMAT_VERSION)
}

/// Load an artifact file. Never fails — see the module docs.
pub fn load_file(path: &Path) -> (Vec<(CacheKey, CachedSelection, u64)>, LoadReport) {
    match std::fs::read(path) {
        Ok(buf) => decode(&buf),
        Err(_) => (Vec::new(), LoadReport::default()),
    }
}

/// Hydrate `cache` from an artifact file (missing/corrupt files hydrate
/// zero entries), preserving persisted last-served stamps. Counters are
/// untouched.
pub fn hydrate_from_file(cache: &ScheduleCache, path: &Path) -> LoadReport {
    let (entries, rep) = load_file(path);
    cache.hydrate_stamped(entries);
    rep
}

/// Atomically replace `path` with `bytes` (temp file in the same
/// directory + rename). Parent directories are created as needed.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating cache dir {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing cache temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))
}

/// Atomically write `cache`'s entries to `path` (temp file in the same
/// directory + rename), **merged over** whatever the file already holds:
/// the atomic rename prevents torn files, but without the merge two
/// processes sharing one artifact would silently discard each other's
/// learning (last writer wins). This cache's entries take precedence on
/// key conflicts; last-served stamps merge to the freshest of the two
/// sides. Returns the number of entries written.
pub fn save_to_file(cache: &ScheduleCache, path: &Path) -> Result<usize> {
    let (disk, _) = load_file(path);
    let mut merged: std::collections::BTreeMap<CacheKey, (CachedSelection, u64)> =
        disk.into_iter().map(|(k, v, s)| (k, (v, s))).collect();
    for (k, v, stamp) in cache.snapshot_stamped() {
        let stamp = match merged.get(&k) {
            Some((_, disk_stamp)) => stamp.max(*disk_stamp),
            None => stamp,
        };
        merged.insert(k, (v, stamp));
    }
    let entries: Vec<(CacheKey, CachedSelection, u64)> =
        merged.into_iter().map(|(k, (v, s))| (k, v, s)).collect();
    write_atomic(path, &encode(&entries))?;
    Ok(entries.len())
}

// --- session-memo artifact --------------------------------------------

/// Serialize session-memo entries (as produced by
/// [`SessionMemo::snapshot`]). Memo entries carry no LRU stamp; zero is
/// written in the shared entry codec's stamp slot.
pub fn encode_memo(entries: &[(CacheKey, Schedule, Option<u64>)]) -> Vec<u8> {
    let stamped: Vec<(CacheKey, CachedSelection, u64)> = entries
        .iter()
        .map(|(k, s, c)| {
            (*k, CachedSelection { schedule: s.clone(), profiled_cycles: *c }, 0)
        })
        .collect();
    encode_entries(&stamped, MEMO_MAGIC, MEMO_FORMAT_VERSION)
}

/// Decode a memo artifact buffer (same tolerance rules as [`decode`]; a
/// schedule-cache artifact handed here loads cold thanks to the distinct
/// magic).
pub fn decode_memo(buf: &[u8]) -> (Vec<(CacheKey, Schedule, Option<u64>)>, LoadReport) {
    let (entries, rep) = decode_entries(buf, MEMO_MAGIC, MEMO_FORMAT_VERSION);
    let out = entries
        .into_iter()
        .map(|(k, v, _)| (k, v.schedule, v.profiled_cycles))
        .collect();
    (out, rep)
}

/// Load a memo artifact file. Never fails — missing/corrupt files load
/// cold, exactly like [`load_file`].
pub fn load_memo_file(path: &Path) -> (Vec<(CacheKey, Schedule, Option<u64>)>, LoadReport) {
    match std::fs::read(path) {
        Ok(buf) => decode_memo(&buf),
        Err(_) => (Vec::new(), LoadReport::default()),
    }
}

/// Hydrate `memo` from a memo artifact file (missing/corrupt files
/// hydrate zero entries). Hit counters are untouched.
pub fn hydrate_memo_from_file(memo: &SessionMemo, path: &Path) -> LoadReport {
    let (entries, rep) = load_memo_file(path);
    memo.hydrate(entries);
    rep
}

/// Atomically write `memo`'s selections to `path`, **merged over**
/// whatever the file already holds (same two-process rationale as
/// [`save_to_file`]; this memo's entries win key conflicts). Returns the
/// number of entries written.
pub fn save_memo_to_file(memo: &SessionMemo, path: &Path) -> Result<usize> {
    let (disk, _) = load_memo_file(path);
    let mut merged: std::collections::BTreeMap<CacheKey, (Schedule, Option<u64>)> =
        disk.into_iter().map(|(k, s, c)| (k, (s, c))).collect();
    for (k, s, c) in memo.snapshot() {
        merged.insert(k, (s, c));
    }
    let entries: Vec<(CacheKey, Schedule, Option<u64>)> =
        merged.into_iter().map(|(k, (s, c))| (k, s, c)).collect();
    write_atomic(path, &encode_memo(&entries))?;
    Ok(entries.len())
}

/// What an LRU trim did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrimReport {
    /// Entries kept in the rewritten artifact.
    pub kept: usize,
    /// Entries evicted (least recently served first).
    pub dropped: usize,
}

/// Trim the artifact at `path` to at most `max_entries` selections,
/// evicting least-recently-served entries first (ties break toward the
/// smaller key, so trimming is deterministic). The survivors are written
/// back atomically in key order; a file already within the bound is left
/// untouched.
///
/// This trims the artifact *at rest*: a live process that hydrated the
/// file before the trim still holds every entry in memory, and its next
/// [`save_to_file`] merges them back. Run `cache gc` against artifacts
/// no server currently holds hydrated (or restart the server afterward);
/// a save-side bound is a ROADMAP follow-on.
pub fn trim_file(path: &Path, max_entries: usize) -> Result<TrimReport> {
    let (mut entries, _) = load_file(path);
    if entries.len() <= max_entries {
        return Ok(TrimReport { kept: entries.len(), dropped: 0 });
    }
    // Most recently served first; unstamped (never-served) entries age out
    // before anything with a stamp.
    entries.sort_by(|a, b| b.2.cmp(&a.2).then_with(|| a.0.cmp(&b.0)));
    let dropped = entries.len() - max_entries;
    entries.truncate(max_entries);
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    write_atomic(path, &encode(&entries))?;
    Ok(TrimReport { kept: entries.len(), dropped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::sweep::SweepOptions;

    fn sample_entry(
        arch: u64,
        g: Gemm,
        cycles: Option<u64>,
    ) -> (CacheKey, CachedSelection, u64) {
        let schedule = Schedule {
            workload: g,
            dataflow: Dataflow::OutputStationary,
            double_buffer: true,
            shares: [0.25, 0.75, 1.0],
            insn_tile: [g.n.min(16), g.c.min(16), g.k.min(16)],
            onchip_tile: [g.n, g.c, g.k],
            dram_order: [Dim::K, Dim::N, Dim::C],
            est: Estimate {
                compute_cycles: 123.5,
                dma_cycles: 456.25,
                issue_cycles: 7.0,
                latency: 999.125,
                bytes: [1.0, 2.0, 3.0],
                utilization: 0.625,
            },
        };
        let key = CacheKey {
            arch,
            gemm: g,
            search: SearchKey::new(&SweepOptions::default(), 6),
            residency: ResidencyConstraint {
                in_block: (arch % 3) as u32 * 8,
                out_block: 16,
                reserved_rows: 40,
            },
        };
        (key, CachedSelection { schedule, profiled_cycles: cycles }, 1000 + arch)
    }

    #[test]
    fn entry_payload_roundtrips_exactly() {
        for cycles in [Some(42u64), None] {
            let (k, v, stamp) = sample_entry(0xdead_beef, Gemm::new(40, 16, 8), cycles);
            let payload = encode_entry(&k, &v, stamp);
            let (k2, v2, s2) = decode_entry(&payload).expect("decodes");
            assert_eq!(k, k2);
            assert_eq!(v, v2);
            assert_eq!(stamp, s2);
        }
    }

    #[test]
    fn buffer_roundtrip_preserves_order_and_values() {
        let entries = vec![
            sample_entry(1, Gemm::new(4, 4, 4), Some(10)),
            sample_entry(2, Gemm::new(64, 32, 16), None),
            sample_entry(1, Gemm::new(8, 8, 8), Some(77)),
        ];
        let bytes = encode(&entries);
        let (back, rep) = decode(&bytes);
        assert_eq!(back, entries);
        assert_eq!(rep, LoadReport { loaded: 3, skipped: 0 });
    }

    #[test]
    fn corrupted_entry_is_skipped_rest_survive() {
        let entries = vec![
            sample_entry(1, Gemm::new(4, 4, 4), Some(10)),
            sample_entry(2, Gemm::new(8, 8, 8), Some(20)),
        ];
        let mut bytes = encode(&entries);
        // Flip a byte inside the first payload (after header + prefix).
        bytes[8 + 12 + 3] ^= 0xff;
        let (back, rep) = decode(&bytes);
        assert_eq!(back.len(), 1, "second entry must survive");
        assert_eq!(back[0], entries[1]);
        assert_eq!(rep, LoadReport { loaded: 1, skipped: 1 });
    }

    #[test]
    fn truncated_buffer_keeps_decoded_prefix() {
        let entries = vec![
            sample_entry(1, Gemm::new(4, 4, 4), Some(10)),
            sample_entry(2, Gemm::new(8, 8, 8), Some(20)),
        ];
        let bytes = encode(&entries);
        let (back, rep) = decode(&bytes[..bytes.len() - 5]);
        assert_eq!(back.len(), 1);
        assert_eq!(rep.skipped, 1);
        // Header-only and garbage buffers are simply cold.
        assert_eq!(decode(&bytes[..8]).0.len(), 0);
        assert_eq!(decode(b"garbage not a cache").0.len(), 0);
    }

    #[test]
    fn version_mismatch_loads_cold() {
        let entries = vec![sample_entry(1, Gemm::new(4, 4, 4), Some(10))];
        let mut bytes = encode(&entries);
        bytes[4..8].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let (back, rep) = decode(&bytes);
        assert!(back.is_empty());
        assert_eq!(rep, LoadReport::default());
    }

    #[test]
    fn bad_dataflow_or_dim_tag_rejected() {
        let (k, v, stamp) = sample_entry(5, Gemm::new(4, 4, 4), None);
        let mut payload = encode_entry(&k, &v, stamp);
        // Dataflow byte sits right after the key (8+24+8+8+1+1+8 search
        // fields + 12 residency = 70), the cycles flag+value (9) and the
        // schedule workload (24): 70+9+24.
        let df_at = 70 + 9 + 24;
        payload[df_at] = 9;
        assert!(decode_entry(&payload).is_none());
    }

    #[test]
    fn save_merges_with_existing_artifact() {
        // Process A persisted entry X; process B (which never hydrated X)
        // saves entry Y to the same file: both must survive.
        let dir = std::env::temp_dir()
            .join(format!("tvm-accel-persist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("merge.bin");
        let _ = std::fs::remove_file(&file);
        let a = ScheduleCache::new();
        let (kx, vx, _) = sample_entry(1, Gemm::new(4, 4, 4), Some(10));
        a.insert(kx, vx.clone());
        save_to_file(&a, &file).unwrap();
        let b = ScheduleCache::new();
        let (ky, vy, _) = sample_entry(2, Gemm::new(8, 8, 8), None);
        b.insert(ky, vy.clone());
        let written = save_to_file(&b, &file).unwrap();
        assert_eq!(written, 2, "merge-on-save must keep the other process's entry");
        let (entries, _) = load_file(&file);
        let kv: Vec<(CacheKey, CachedSelection)> =
            entries.into_iter().map(|(k, v, _)| (k, v)).collect();
        assert_eq!(kv, vec![(kx, vx), (ky, vy)]);
        let _ = std::fs::remove_file(&file);
    }

    #[test]
    fn trim_evicts_least_recently_served_first() {
        let dir =
            std::env::temp_dir().join(format!("tvm-accel-trim-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("trim.bin");
        // Stamps 1001, 1002, 1003 (from sample_entry's 1000 + arch).
        let entries = vec![
            sample_entry(1, Gemm::new(4, 4, 4), Some(10)),
            sample_entry(2, Gemm::new(8, 8, 8), Some(20)),
            sample_entry(3, Gemm::new(16, 16, 16), Some(30)),
        ];
        write_atomic(&file, &encode(&entries)).unwrap();
        let rep = trim_file(&file, 2).unwrap();
        assert_eq!(rep, TrimReport { kept: 2, dropped: 1 });
        let (left, _) = load_file(&file);
        assert_eq!(left.len(), 2);
        assert!(
            left.iter().all(|(k, _, _)| k.arch != 1),
            "the oldest-served entry must be evicted"
        );
        // Within the bound: untouched, zero drops.
        let rep = trim_file(&file, 10).unwrap();
        assert_eq!(rep, TrimReport { kept: 2, dropped: 0 });
        let _ = std::fs::remove_file(&file);
    }

    #[test]
    fn memo_artifact_roundtrips_and_merges() {
        let dir = std::env::temp_dir()
            .join(format!("tvm-accel-memo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("session.memo");
        let _ = std::fs::remove_file(&file);

        let (k1, v1, _) = sample_entry(1, Gemm::new(4, 4, 4), Some(10));
        let (k2, v2, _) = sample_entry(2, Gemm::new(8, 8, 8), None);
        let a = SessionMemo::new();
        a.hydrate([(k1, v1.schedule.clone(), v1.profiled_cycles)]);
        assert_eq!(save_memo_to_file(&a, &file).unwrap(), 1);

        // A second process's memo merges over the artifact.
        let b = SessionMemo::new();
        b.hydrate([(k2, v2.schedule.clone(), v2.profiled_cycles)]);
        assert_eq!(save_memo_to_file(&b, &file).unwrap(), 2);

        let fresh = SessionMemo::new();
        let rep = hydrate_memo_from_file(&fresh, &file);
        assert_eq!(rep, LoadReport { loaded: 2, skipped: 0 });
        let mut back = fresh.snapshot();
        back.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(
            back,
            vec![
                (k1, v1.schedule, v1.profiled_cycles),
                (k2, v2.schedule, v2.profiled_cycles)
            ]
        );
        let _ = std::fs::remove_file(&file);
    }

    #[test]
    fn memo_and_cache_artifacts_never_cross_load() {
        let (k, v, stamp) = sample_entry(7, Gemm::new(4, 4, 4), Some(9));
        let cache_bytes = encode(&[(k, v.clone(), stamp)]);
        let memo_bytes = encode_memo(&[(k, v.schedule, v.profiled_cycles)]);
        assert!(decode_memo(&cache_bytes).0.is_empty(), "cache file must not hydrate a memo");
        assert!(decode(&memo_bytes).0.is_empty(), "memo file must not hydrate a cache");
    }

    #[test]
    fn fnv_is_stable() {
        // Known FNV-1a vectors (64-bit).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
