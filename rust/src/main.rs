//! `tvm-accel` — command-line driver for the compiler-integration
//! framework.
//!
//! Subcommands:
//!   schedule  — run the extended-CoSA sweep for a GEMM and print mappings
//!   compile   — compile a .qmodel and print the chosen schedules/program
//!   run       — compile + simulate a .qmodel (optionally golden-checked
//!               against an HLO artifact via PJRT)
//!   disasm    — compile and dump the instruction stream
//!
//! Examples:
//!   tvm-accel schedule --n 128 --c 128 --k 128
//!   tvm-accel run --model artifacts/toycar.qmodel --backend proposed \
//!       --golden artifacts/toycar.hlo.txt --inferences 10
//!   tvm-accel compile --model artifacts/dense_64.qmodel --backend naive

use anyhow::{bail, Context, Result};
use tvm_accel::accel::gemmini::{desc_for_arch, gemmini_desc};
use tvm_accel::accel::AccelDesc;
use tvm_accel::arch::parse::arch_from_file;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::{compile_naive, import_with_weight_chain};
use tvm_accel::metrics::describe;
use tvm_accel::pipeline::{Compiler, Deployment};
use tvm_accel::relay::import::{load_qmodel, QModel};
#[cfg(feature = "xla-runtime")]
use tvm_accel::runtime::{golden_inputs, Runtime};
use tvm_accel::scheduler::sweep::{sweep, SweepOptions};
use tvm_accel::sim::Simulator;
use tvm_accel::util::cli::Args;
use tvm_accel::util::prng::Rng;
use tvm_accel::util::table::commafy;
use tvm_accel::workload::Gemm;

const VALUE_OPTS: &[&str] = &[
    "n", "c", "k", "model", "backend", "arch", "golden", "inferences", "seed",
];

fn load_accel(args: &Args) -> Result<AccelDesc> {
    match args.opt("arch") {
        None => gemmini_desc(),
        Some(path) => {
            let arch = arch_from_file(std::path::Path::new(path))?;
            let name = arch.name.clone();
            desc_for_arch(&name, arch)
        }
    }
}

fn build_deployment(args: &Args, accel: &AccelDesc, model: &QModel) -> Result<Deployment> {
    match args.opt_or("backend", "proposed").as_str() {
        "proposed" => {
            let graph = import_with_weight_chain(model)?;
            Compiler::new(accel.clone()).compile(&graph)
        }
        "naive" | "byoc" => compile_naive(accel, model),
        "c-toolchain" | "c" => compile_c_toolchain(accel, model),
        other => bail!("unknown backend '{other}' (proposed|naive|c-toolchain)"),
    }
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let g = Gemm::new(
        args.opt_usize("n", 128)?,
        args.opt_usize("c", 128)?,
        args.opt_usize("k", 128)?,
    );
    let accel = load_accel(args)?;
    let r = sweep(&accel.arch, g, &SweepOptions::default());
    println!("{} config points explored for {g}; top candidates:", r.configs_explored);
    for (i, s) in r.candidates.iter().enumerate() {
        println!("  [{i}] {s}");
    }
    if let Some(best) = r.candidates.first() {
        println!("\nCoSA mapping of the best candidate:\n{}", best.to_yaml());
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let model = load_qmodel(std::path::Path::new(path))?;
    let accel = load_accel(args)?;
    let dep = build_deployment(args, &accel, &model)?;
    println!(
        "compiled '{}' for {}: {} items, {} DRAM bytes",
        path,
        accel.name,
        dep.program.items.len(),
        commafy(dep.program.layout.total_bytes())
    );
    for (name, s, cyc) in &dep.chosen {
        println!("  {name}: {s} (profiled {cyc:?})");
    }
    println!("instruction histogram:");
    for (m, n) in dep.program.histogram() {
        println!("  {m:<24} {n}");
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let model = load_qmodel(std::path::Path::new(path))?;
    let accel = load_accel(args)?;
    let dep = build_deployment(args, &accel, &model)?;
    let sim = Simulator::new(&accel.arch);
    let inferences = args.opt_usize("inferences", 1)?;
    anyhow::ensure!(inferences > 0, "--inferences must be at least 1");
    let mut rng = Rng::new(args.opt_usize("seed", 1)? as u64);

    #[cfg(feature = "xla-runtime")]
    let golden = match args.opt("golden") {
        Some(g) => {
            let rt = Runtime::cpu()?;
            Some(rt.load_hlo_text(std::path::Path::new(g))?)
        }
        None => None,
    };
    #[cfg(not(feature = "xla-runtime"))]
    if args.opt("golden").is_some() {
        bail!(
            "--golden needs the PJRT golden runtime: add the `xla` dependency \
             and build with `--features xla-runtime` (see rust/Cargo.toml)"
        );
    }
    #[cfg(not(feature = "xla-runtime"))]
    let golden: Option<()> = None;

    let mut total = 0u64;
    for i in 0..inferences {
        let x = rng.i8_vec(model.batch * model.layers[0].in_dim);
        let (out, rep) = dep.run(&sim, &x)?;
        total += rep.cycles;
        #[cfg(feature = "xla-runtime")]
        if let Some(g) = &golden {
            let want = g.run(&golden_inputs(&model, &x)?)?.to_vec::<i8>()?;
            if out != want {
                bail!("inference {i}: output mismatch vs golden model");
            }
        }
        #[cfg(not(feature = "xla-runtime"))]
        let _ = &out;
        if i == 0 {
            println!("{}", describe("first inference", &rep, accel.arch.pe_dim));
        }
    }
    println!(
        "{} inferences, mean latency {} cycles{}",
        inferences,
        commafy(total / inferences as u64),
        if golden.is_some() { ", all golden-checked ✔" } else { "" }
    );
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<()> {
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let model = load_qmodel(std::path::Path::new(path))?;
    let accel = load_accel(args)?;
    let dep = build_deployment(args, &accel, &model)?;
    print!("{}", dep.program.disassemble());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(VALUE_OPTS)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("schedule") => cmd_schedule(&args),
        Some("compile") => cmd_compile(&args),
        Some("run") => cmd_run(&args),
        Some("disasm") => cmd_disasm(&args),
        _ => {
            eprintln!(
                "usage: tvm-accel <schedule|compile|run|disasm> [--model F] \
                 [--backend proposed|naive|c-toolchain] [--arch F.yaml] \
                 [--golden F.hlo.txt] [--inferences N] [--n N --c C --k K]"
            );
            std::process::exit(2);
        }
    }
}
