//! `tvm-accel` — command-line driver for the compiler-integration
//! framework.
//!
//! Subcommands:
//!   schedule  — run the extended-CoSA sweep for a GEMM and print mappings
//!   compile   — compile a .qmodel and print the chosen schedules/program
//!               (add --socket to route through a running compile server)
//!   run       — compile + simulate a .qmodel as one batched execution
//!               (optionally golden-checked against an HLO artifact)
//!   disasm    — compile and dump the instruction stream
//!   serve     — long-lived compile server on a Unix domain socket,
//!               sharing one persistent schedule cache across requests
//!   cache     — stats|clear|warm|gc the persistent schedule-cache
//!               artifact (gc trims to --max-entries, least recently
//!               served first)
//!   bench     — cold-compile the Table-2 suite, print compile cost and
//!               simulated cycles, optionally write BENCH_*.json and gate
//!               against a committed baseline (the perf trajectory);
//!               --trace F.json writes the compile spans as a
//!               Chrome-trace-event (Perfetto-loadable) file
//!   profile   — compile a .qmodel with tracing on, run one profiled
//!               inference, and write a Perfetto-compatible execution
//!               timeline (compile spans + per-target DMA/compute/store/
//!               host tracks) to --trace
//!   metrics   — scrape a running serve's Prometheus exposition
//!               (tvm-accel metrics --socket S)
//!   gen-model — write a deterministic random .qmodel (for smoke tests)
//!   fuzz      — differential fuzzing: seeded random graphs through every
//!               compile-configuration axis, checked element-exactly
//!               against the interpreter; failures minimize to replayable
//!               .repro files (or replay one with --replay F)
//!
//! The `compile`, `run` and `cache warm` paths hydrate the on-disk
//! schedule cache (default: `~/.cache/tvm-accel/schedules.bin`, override
//! with --cache <file> or $TVM_ACCEL_CACHE, disable with --no-cache), so
//! a repeat invocation performs zero schedule sweeps.
//!
//! Examples:
//!   tvm-accel schedule --n 128 --c 128 --k 128
//!   tvm-accel run --model artifacts/toycar.qmodel --backend proposed \
//!       --golden artifacts/toycar.hlo.txt --inferences 10
//!   tvm-accel compile --model artifacts/dense_64.qmodel --backend naive
//!   tvm-accel serve --socket /tmp/tvm-accel.sock --cache /tmp/sched.bin
//!   tvm-accel compile --socket /tmp/tvm-accel.sock --model m.qmodel

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};
use tvm_accel::accel::gemmini::gemmini_desc;
use tvm_accel::accel::AccelDesc;
use tvm_accel::baselines::c_toolchain::compile_c_toolchain;
use tvm_accel::baselines::naive_byoc::compile_naive;
use tvm_accel::bench;
use tvm_accel::fuzz;
use tvm_accel::isa::program::Program;
use tvm_accel::obs::{describe, spans_to_chrome, timeline_to_chrome, ChromeTrace};
use tvm_accel::pipeline::{CompileOptions, Compiler, Deployment};
use tvm_accel::relay::import::{load_qmodel, synth_qmodel, to_qnn_graph, write_qmodel, QModel};
#[cfg(feature = "xla-runtime")]
use tvm_accel::runtime::{golden_inputs, Runtime};
use tvm_accel::backend::Backend;
use tvm_accel::scheduler::persist;
use tvm_accel::scheduler::sweep::SweepOptions;
use tvm_accel::service::protocol::{parse_message, ObjBuilder};
use tvm_accel::service::socket::{self, ServeOptions};
use tvm_accel::service::{default_cache_path, CompileServer, CompiledArtifact};
use tvm_accel::sim::Simulator;
use tvm_accel::util::cli::Args;
use tvm_accel::util::prng::Rng;
use tvm_accel::util::table::commafy;
use tvm_accel::workload::Gemm;

const VALUE_OPTS: &[&str] = &[
    "n", "c", "k", "model", "backend", "arch", "golden", "inferences", "seed", "socket",
    "cache", "workers", "dims", "batch", "out", "max-entries", "out-dir", "baseline",
    "max-regress", "cases", "replay", "trace",
];

/// Single-target variant of [`load_accels`] for subcommands that drive
/// one simulator (schedule/run/disasm) — a comma-separated `--arch` list
/// is a clear error here, not a mis-parsed file name.
fn load_accel(args: &Args) -> Result<AccelDesc> {
    let mut accels = load_accels(args)?;
    ensure!(
        accels.len() == 1,
        "this subcommand simulates a single target; pass exactly one --arch (got {})",
        accels.len()
    );
    Ok(accels.remove(0))
}

/// `--arch` accepts a comma-separated list of architecture YAMLs; several
/// files make the compile multi-target (cost-driven partition).
fn load_accels(args: &Args) -> Result<Vec<AccelDesc>> {
    match args.opt("arch") {
        None => Ok(vec![gemmini_desc()?]),
        Some(paths) => {
            let mut out = Vec::new();
            for p in paths.split(',').filter(|p| !p.is_empty()) {
                out.push(socket::load_target(Path::new(p))?);
            }
            ensure!(!out.is_empty(), "--arch lists no files");
            Ok(out)
        }
    }
}

/// The persistent-cache location this invocation uses.
fn cache_path(args: &Args) -> PathBuf {
    match args.opt("cache") {
        Some(p) => PathBuf::from(p),
        None => default_cache_path(),
    }
}

/// A local (in-process) compile server honoring --cache/--no-cache and
/// --workers.
fn local_server(args: &Args) -> Result<CompileServer> {
    let opts = CompileOptions::default();
    let server = if args.flag("no-cache") {
        CompileServer::new(opts)
    } else {
        CompileServer::with_cache_file(opts, cache_path(args)).0
    };
    Ok(match args.opt_usize("workers", 0)? {
        0 => server,
        n => server.with_workers(n),
    })
}

fn build_deployment(args: &Args, accel: &AccelDesc, model: &QModel) -> Result<Deployment> {
    match args.opt_or("backend", "proposed").as_str() {
        "proposed" => {
            // Route through the compile service so repeat invocations hit
            // the persistent schedule cache (and, with --incremental, the
            // persisted session memo).
            let server = local_server(args)?;
            let reply = if args.flag("incremental") {
                server.compile_model_incremental(model, std::slice::from_ref(accel))?
            } else {
                server.compile_model(model, std::slice::from_ref(accel))?
            };
            match reply.artifact {
                CompiledArtifact::Single(d) => Ok(d),
                CompiledArtifact::Multi(_) => bail!("one target cannot yield a multi deployment"),
            }
        }
        "naive" | "byoc" => compile_naive(accel, model),
        "c-toolchain" | "c" => compile_c_toolchain(accel, model),
        other => bail!("unknown backend '{other}' (proposed|naive|c-toolchain)"),
    }
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let g = Gemm::new(
        args.opt_usize("n", 128)?,
        args.opt_usize("c", 128)?,
        args.opt_usize("k", 128)?,
    );
    let accel = load_accel(args)?;
    let r = accel.backend_impl()?.sweep(&accel.arch, g, &SweepOptions::default());
    println!("{} config points explored for {g}; top candidates:", r.configs_explored);
    for (i, s) in r.candidates.iter().enumerate() {
        println!("  [{i}] {s}");
    }
    if let Some(best) = r.candidates.first() {
        println!("\nCoSA mapping of the best candidate:\n{}", best.to_yaml());
    }
    Ok(())
}

fn print_histogram(prog: &Program) {
    println!("instruction histogram:");
    for (m, n) in prog.histogram() {
        println!("  {m:<24} {n}");
    }
}

/// Send the compile request to a running `tvm-accel serve` instead of
/// compiling locally; prints the server's response line.
fn client_compile(args: &Args, sock: &str, model: &str) -> Result<()> {
    // The server resolves paths in its own working directory: send
    // absolute ones.
    let model_abs = std::fs::canonicalize(model)
        .with_context(|| format!("resolving model path {model}"))?;
    let mut req = ObjBuilder::new()
        .str_field("cmd", "compile")
        .str_field("model", &model_abs.display().to_string());
    if let Some(arch) = args.opt("arch") {
        let mut files = Vec::new();
        for p in arch.split(',').filter(|p| !p.is_empty()) {
            let abs = std::fs::canonicalize(p)
                .with_context(|| format!("resolving arch path {p}"))?;
            files.push(abs.display().to_string());
        }
        req = req.list_field("arch", &files);
    }
    let resp = socket::request(Path::new(sock), &req.finish())?;
    println!("{resp}");
    let msg = parse_message(&resp).context("parsing server response")?;
    if msg.bool_field("ok") != Some(true) {
        bail!("server error: {}", msg.str_field("error").unwrap_or("unknown"));
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    if let Some(sock) = args.opt("socket") {
        ensure!(
            args.opt_or("backend", "proposed") == "proposed",
            "--socket serves the proposed backend only; drop --socket to compile \
             the {} baseline locally",
            args.opt_or("backend", "proposed")
        );
        return client_compile(args, sock, path);
    }
    let model = load_qmodel(Path::new(path))?;
    if args.opt_or("backend", "proposed").as_str() != "proposed" {
        let accel = load_accel(args)?;
        let dep = build_deployment(args, &accel, &model)?;
        println!(
            "compiled '{}' for {}: {} items, {} DRAM bytes",
            path,
            accel.name,
            dep.program.items.len(),
            commafy(dep.program.layout.total_bytes())
        );
        for (name, s, cyc) in &dep.chosen {
            println!("  {name}: {s} (profiled {cyc:?})");
        }
        print_histogram(&dep.program);
        return Ok(());
    }

    let accels = load_accels(args)?;
    let server = local_server(args)?;
    let reply = if args.flag("incremental") {
        server.compile_model_incremental(&model, &accels)?
    } else {
        server.compile_model(&model, &accels)?
    };
    let names: Vec<&str> = accels.iter().map(|a| a.name.as_str()).collect();
    println!(
        "compiled '{}' for {}: {} items, {} DRAM bytes",
        path,
        names.join("+"),
        reply.artifact.program().items.len(),
        commafy(reply.artifact.program().layout.total_bytes())
    );
    match &reply.artifact {
        CompiledArtifact::Single(dep) => {
            for (name, s, cyc) in &dep.chosen {
                println!("  {name}: {s} (profiled {cyc:?})");
            }
            print_histogram(&dep.program);
        }
        CompiledArtifact::Multi(dep) => {
            print!("{}", dep.render_assignments());
            print_histogram(&dep.program);
        }
    }
    println!(
        "schedule cache: {} hit(s) / {} miss(es), {} sweep(s) this compile \
         ({} solver leaf(s) visited, {} config point(s) pruned)",
        reply.cache_hits,
        reply.cache_misses,
        reply.sweeps,
        reply.solver_leaves_visited,
        reply.configs_pruned
    );
    if args.flag("incremental") {
        println!(
            "session memo: {} hit(s) this compile, {} selection(s) memoized{}",
            reply.schedule_stats.memo_hits,
            server.memo().len(),
            match server.memo_path() {
                Some(p) => format!(", persisting to {}", p.display()),
                None => String::new(),
            }
        );
    }
    if let Some(p) = server.cache_path() {
        println!(
            "  {} entries persisted at {}",
            server.cache_stats().entries,
            p.display()
        );
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    ensure!(
        args.opt("socket").is_none(),
        "--socket applies to 'compile' only; 'run' executes locally on the simulator"
    );
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let model = load_qmodel(Path::new(path))?;
    let accel = load_accel(args)?;
    let dep = build_deployment(args, &accel, &model)?;
    let sim = Simulator::new(&accel.arch);
    let inferences = args.opt_usize("inferences", 1)?;
    ensure!(inferences > 0, "--inferences must be at least 1");
    let mut rng = Rng::new(args.opt_usize("seed", 1)? as u64);

    #[cfg(feature = "xla-runtime")]
    let golden = match args.opt("golden") {
        Some(g) => {
            let rt = Runtime::cpu()?;
            Some(rt.load_hlo_text(Path::new(g))?)
        }
        None => None,
    };
    #[cfg(not(feature = "xla-runtime"))]
    if args.opt("golden").is_some() {
        bail!(
            "--golden needs the PJRT golden runtime: add the `xla` dependency \
             and build with `--features xla-runtime` (see rust/Cargo.toml)"
        );
    }
    #[cfg(not(feature = "xla-runtime"))]
    let golden: Option<()> = None;

    // One batched execution: the DRAM image (constants included) is
    // staged once for the whole batch instead of once per inference.
    let elems = model.batch * model.layers[0].in_dim;
    let inputs: Vec<Vec<i8>> = (0..inferences).map(|_| rng.i8_vec(elems)).collect();
    let refs: Vec<&[i8]> = inputs.iter().map(|v| v.as_slice()).collect();
    let batch = dep.run_batch(&sim, &refs)?;

    #[cfg(feature = "xla-runtime")]
    if let Some(g) = &golden {
        for (i, out) in batch.outputs.iter().enumerate() {
            let want = g.run(&golden_inputs(&model, &inputs[i])?)?.to_vec::<i8>()?;
            if out != &want {
                bail!("inference {i}: output mismatch vs golden model");
            }
        }
    }

    println!("{}", describe("first inference", &batch.reports[0], accel.arch.pe_dim));
    println!(
        "{} inferences, mean latency {} cycles{}",
        inferences,
        commafy(batch.mean_cycles()),
        if golden.is_some() { ", all golden-checked ✔" } else { "" }
    );
    if inferences > 1 {
        println!(
            "pipelined batch model: {} cycles total vs {} serial",
            commafy(batch.pipelined_cycles),
            commafy(batch.serial_cycles)
        );
    }
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<()> {
    ensure!(
        args.opt("socket").is_none(),
        "--socket applies to 'compile' only; 'disasm' compiles locally"
    );
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let model = load_qmodel(Path::new(path))?;
    let accel = load_accel(args)?;
    let dep = build_deployment(args, &accel, &model)?;
    print!("{}", dep.program.disassemble());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let sock = args.opt("socket").context("--socket <path> required")?;
    let targets = load_accels(args)?;
    let server = local_server(args)?;
    let stats = server.cache_stats();
    eprintln!(
        "tvm-accel serve: listening on {} ({} cached schedule entries{})",
        sock,
        stats.entries,
        match server.cache_path() {
            Some(p) => format!(", persisting to {}", p.display()),
            None => ", persistence disabled".to_string(),
        }
    );
    socket::serve(
        std::sync::Arc::new(server),
        ServeOptions { socket: PathBuf::from(sock), default_targets: targets },
    )
}

fn cmd_cache(args: &Args) -> Result<()> {
    let action = args.positional.get(1).map(|s| s.as_str()).context(
        "usage: tvm-accel cache <stats|clear|warm|gc> [--cache F] [--model F] \
         [--max-entries N]",
    )?;
    let path = cache_path(args);
    match action {
        "stats" => {
            let (entries, rep) = persist::load_file(&path);
            println!(
                "cache file {}: {} entries ({} skipped)",
                path.display(),
                entries.len(),
                rep.skipped
            );
            let mut per_arch = std::collections::BTreeMap::new();
            for (k, _, _) in &entries {
                *per_arch.entry(k.arch).or_insert(0usize) += 1;
            }
            for (arch, n) in per_arch {
                println!("  arch {arch:016x}: {n} schedule(s)");
            }
            Ok(())
        }
        "gc" => {
            let max = args.opt_usize("max-entries", 0)?;
            ensure!(max > 0, "cache gc needs --max-entries <N> (N > 0)");
            let rep = persist::trim_file(&path, max)?;
            println!(
                "cache gc {}: kept {} entr{}, evicted {} (least recently served first)",
                path.display(),
                rep.kept,
                if rep.kept == 1 { "y" } else { "ies" },
                rep.dropped
            );
            if rep.dropped > 0 {
                println!(
                    "  note: a running server that hydrated this artifact still holds \
                     the evicted entries and will merge them back on its next save"
                );
            }
            Ok(())
        }
        "clear" => {
            match std::fs::remove_file(&path) {
                Ok(()) => println!("removed {}", path.display()),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    println!("nothing to clear at {}", path.display())
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("removing {}", path.display()))
                }
            }
            Ok(())
        }
        "warm" => {
            let model_path =
                args.opt("model").context("cache warm needs --model <file.qmodel>")?;
            let model = load_qmodel(Path::new(model_path))?;
            let accels = load_accels(args)?;
            let (server, _) =
                CompileServer::with_cache_file(CompileOptions::default(), path.clone());
            let reply = server.compile_model(&model, &accels)?;
            println!(
                "warmed '{}': {} sweep(s) run, {} cache hit(s); {} entries at {}",
                model_path,
                reply.sweeps,
                reply.cache_hits,
                server.cache_stats().entries,
                path.display()
            );
            Ok(())
        }
        other => bail!("unknown cache action '{other}' (stats|clear|warm|gc)"),
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    let max_regress: f64 = {
        let s = args.opt_or("max-regress", "10");
        s.parse::<f64>().map_err(|_| anyhow!("bad --max-regress '{s}' (a percentage)"))?
    };
    ensure!(max_regress >= 0.0, "--max-regress must be nonnegative");
    eprintln!("tvm-accel bench: cold-compiling the Table-2 suite (takes ~a minute)...");
    let suite = bench::standard_suite()?;
    let report = bench::run_suite(&suite)?;
    print!("{}", report.render());
    if let Some(dir) = args.opt("out-dir") {
        let dir = Path::new(dir);
        report.write_artifacts(dir)?;
        println!(
            "wrote {} and {} to {}",
            bench::COMPILE_FILE,
            bench::CYCLES_FILE,
            dir.display()
        );
    }
    if let Some(path) = args.opt("trace") {
        std::fs::write(path, report.chrome_trace())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote compile-span trace to {path} (load in ui.perfetto.dev)");
    }
    if let Some(base) = args.opt("baseline") {
        let outcome = bench::check_against_baseline(&report, Path::new(base), max_regress);
        print!("{}", outcome.render());
        if !outcome.passed() {
            bail!(
                "{} workload(s) regressed more than {max_regress}% in simulated cycles",
                outcome.failures.len()
            );
        }
        println!("cycle gate passed ({max_regress}% regression allowed)");
    }
    Ok(())
}

/// Compile with tracing on, run one profiled inference on the simulator,
/// and write a Chrome-trace-event JSON: the compile pipeline's spans as
/// process 1, then one process per execution target with a thread per
/// hardware track (DMA / compute / store / host; 1 simulated cycle =
/// 1 µs). Load the file in ui.perfetto.dev or chrome://tracing.
fn cmd_profile(args: &Args) -> Result<()> {
    let path = args.opt("model").context("--model <file.qmodel> required")?;
    let out_path = args.opt_or("trace", "trace.json");
    let model = load_qmodel(Path::new(path))?;
    let graph = to_qnn_graph(&model)?;
    let accels = load_accels(args)?;
    let elems = model.batch * model.layers[0].in_dim;
    let input = Rng::new(args.opt_usize("seed", 1)? as u64).i8_vec(elems);

    let mut ct = ChromeTrace::new();
    ct.process_name(1, "compile pipeline");
    ct.thread_name(1, 1, "stages");
    let (rep, pe_dim, targets) = if accels.len() == 1 {
        let accel = accels.into_iter().next().expect("len checked");
        let pe_dim = accel.arch.pe_dim;
        let name = accel.name.clone();
        let sim = Simulator::new(&accel.arch);
        let out = Compiler::new(accel).compile_traced(&graph)?;
        spans_to_chrome(&mut ct, 1, 1, &out.trace.spans());
        let (_, rep, tl) = out.deployment.run_profiled(&sim, &input)?;
        ct.process_name(2, &name);
        timeline_to_chrome(&mut ct, 2, &tl);
        (rep, pe_dim, 1)
    } else {
        let pe_dim = accels[0].arch.pe_dim;
        let out = Compiler::with_targets(&accels)?.compile_traced(&graph)?;
        spans_to_chrome(&mut ct, 1, 1, &out.trace.spans());
        let (_, rep, tls) = out.deployment.run_profiled(&input)?;
        let n = tls.len();
        for (i, (name, tl)) in tls.iter().enumerate() {
            let pid = 2 + i as u64;
            ct.process_name(pid, name);
            timeline_to_chrome(&mut ct, pid, tl);
        }
        (rep, pe_dim, n)
    };
    std::fs::write(&out_path, ct.render())
        .with_context(|| format!("writing {out_path}"))?;
    println!("{}", describe("profiled inference", &rep, pe_dim));
    println!(
        "wrote execution timeline for {} target segment(s) to {} \
         (load in ui.perfetto.dev)",
        targets, out_path
    );
    Ok(())
}

/// Scrape a running server's metric registry over the line protocol and
/// print the Prometheus text exposition.
fn cmd_metrics(args: &Args) -> Result<()> {
    let sock = args.opt("socket").context("--socket <path> required")?;
    let req = ObjBuilder::new().str_field("cmd", "metrics").finish();
    let resp = socket::request(Path::new(sock), &req)?;
    let msg = parse_message(&resp).context("parsing server response")?;
    if msg.bool_field("ok") != Some(true) {
        bail!("server error: {}", msg.str_field("error").unwrap_or("unknown"));
    }
    let text = msg
        .str_field("exposition")
        .context("metrics reply lacks an \"exposition\" field")?;
    print!("{text}");
    Ok(())
}

fn cmd_gen_model(args: &Args) -> Result<()> {
    let out = args.opt("out").context("--out <file.qmodel> required")?;
    let dims_s = args.opt_or("dims", "32,48,16");
    let dims: Vec<usize> = dims_s
        .split(',')
        .map(|d| d.trim().parse::<usize>().map_err(|_| anyhow!("bad dim '{d}'")))
        .collect::<Result<_>>()?;
    let batch = args.opt_usize("batch", 4)?;
    let model = synth_qmodel(args.opt_usize("seed", 1)? as u64, &dims, batch)?;
    std::fs::write(out, write_qmodel(&model))
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {} ({} layer(s), batch {})", out, model.layers.len(), batch);
    Ok(())
}

fn cmd_fuzz(args: &Args) -> Result<()> {
    if let Some(path) = args.opt("replay") {
        return match fuzz::replay_file(Path::new(path))? {
            fuzz::Verdict::Pass => {
                println!("reproducer {path}: all axes pass");
                Ok(())
            }
            fuzz::Verdict::Fail(f) => {
                bail!(
                    "reproducer {path}: axis {} [{}] still fails: {}",
                    f.axis,
                    f.backend,
                    f.detail
                )
            }
        };
    }
    let cases = args.opt_usize("cases", 500)? as u64;
    ensure!(cases > 0, "--cases must be at least 1");
    let opts = fuzz::FuzzOptions {
        cases,
        seed: args.opt_usize("seed", 0)? as u64,
        gen: fuzz::GenOptions::default(),
        out_dir: Some(PathBuf::from(args.opt_or("out-dir", "fuzz-reproducers"))),
    };
    eprintln!(
        "tvm-accel fuzz: {} case(s) from seed {}, every configuration axis \
         checked against the interpreter",
        opts.cases, opts.seed
    );
    let summary = fuzz::run_fuzz(&opts)?;
    print!("{}", summary.render());
    if !summary.passed() {
        bail!(
            "{} case(s) broke a compiler invariant (minimized reproducers above)",
            summary.findings.len()
        );
    }
    println!("all {} case(s) passed every axis", summary.cases);
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(VALUE_OPTS)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("schedule") => cmd_schedule(&args),
        Some("compile") => cmd_compile(&args),
        Some("run") => cmd_run(&args),
        Some("disasm") => cmd_disasm(&args),
        Some("serve") => cmd_serve(&args),
        Some("cache") => cmd_cache(&args),
        Some("bench") => cmd_bench(&args),
        Some("profile") => cmd_profile(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("gen-model") => cmd_gen_model(&args),
        Some("fuzz") => cmd_fuzz(&args),
        _ => {
            eprintln!(
                "usage: tvm-accel <schedule|compile|run|disasm|serve|cache|bench|profile|\n\
                 \x20                metrics|gen-model|fuzz>\n\
                 \x20 compile:     --model F.qmodel [--backend proposed|naive|c-toolchain]\n\
                 \x20              [--arch F.yaml[,G.yaml...]] [--cache F|--no-cache]\n\
                 \x20              [--incremental  (persist the session memo beside the cache)]\n\
                 \x20              [--socket S  (proposed backend via a running server)]\n\
                 \x20 run/disasm:  --model F.qmodel [--backend ...] [--arch F.yaml]\n\
                 \x20              [--golden F.hlo.txt] [--inferences N] [--cache F|--no-cache]\n\
                 \x20 schedule:    --n N --c C --k K\n\
                 \x20 serve:       --socket S [--arch ...] [--cache F|--no-cache] [--workers N]\n\
                 \x20 cache:       <stats|clear|warm|gc> [--cache F] [--model F.qmodel]\n\
                 \x20              [--max-entries N  (gc: LRU-trim the artifact)]\n\
                 \x20 bench:       [--out-dir D  (write BENCH_*.json)] [--baseline D]\n\
                 \x20              [--max-regress PCT  (cycle gate, default 10)]\n\
                 \x20              [--trace F.json  (compile spans, Perfetto-loadable)]\n\
                 \x20 profile:     --model F.qmodel [--arch F.yaml[,G.yaml...]] [--seed N]\n\
                 \x20              [--trace F.json  (default trace.json)]\n\
                 \x20 metrics:     --socket S  (print the server's Prometheus exposition)\n\
                 \x20 gen-model:   --out F.qmodel [--dims 32,48,16] [--batch N] [--seed N]\n\
                 \x20 fuzz:        [--cases N (default 500)] [--seed N]\n\
                 \x20              [--out-dir D  (reproducers, default fuzz-reproducers)]\n\
                 \x20              [--replay F.repro  (re-check one archived reproducer)]"
            );
            std::process::exit(2);
        }
    }
}
