//! # tvm-accel
//!
//! A compiler-integration framework for GEMM-based deep-learning
//! accelerators, reproducing *"A High-Level Compiler Integration Approach for
//! Deep Learning Accelerators Supporting Abstraction and Optimization"*
//! (Ahmadifarsani, Mueller-Gritschneder, Schlichtmann, 2025).
//!
//! The crate provides, end to end:
//!
//! * a compact **accelerator description** (functional + architectural) that
//!   is the only thing a user writes to integrate a new GEMM accelerator
//!   ([`accel`], [`arch`]);
//! * an **extended CoSA scheduler** — constrained optimization over loop
//!   mappings with instruction-set constraints, uneven memory-share mapping
//!   and double buffering ([`scheduler`]);
//! * an automated **integration flow** — frontend configurator, strategy
//!   generator, hardware-intrinsic generator and mapping generator — that
//!   turns the description into a working compiler backend ([`frontend`],
//!   [`backend`], [`pipeline`]), staged as an observable six-stage
//!   [`pipeline::CompilerSession`] with a content-addressed schedule cache;
//! * **cost-driven multi-accelerator partitioning** — one compile can
//!   target a *set* of descriptions, placing each layer on the candidate
//!   with the cheapest profiled schedule and linking a single deployment
//!   that drives every target's instruction stream
//!   ([`pipeline::MultiCompiler`]);
//! * a **compile service** — a long-lived [`service::CompileServer`] over
//!   a persistent, content-addressed schedule cache
//!   ([`scheduler::persist`]): repeat compiles — across requests,
//!   processes and the `tvm-accel serve` Unix-socket front door — skip
//!   the schedule search entirely, with single-flight de-duplication of
//!   concurrent searches and a bounded worker pool sharding the per-layer
//!   schedule stage;
//! * the substrates the paper depends on: a compact Relay-like graph IR with
//!   QNN ops and passes ([`relay`]), a TIR-like loop-nest IR with schedule
//!   primitives ([`tir`]), a Gemmini-class ISA ([`isa`]) and a cycle-level,
//!   functionally exact simulator ([`sim`]);
//! * the paper's two baselines ([`baselines`]) and a PJRT-backed golden
//!   reference runtime (`runtime`, behind the off-by-default `xla-runtime`
//!   cargo feature: it needs the pinned `xla_extension` 0.5.1 toolchain);
//! * **differential fuzzing at scale** — a seeded model-graph generator,
//!   a multi-axis differential oracle (every compile configuration checked
//!   element-exactly against the interpreter plus cross-config invariants),
//!   a deterministic minimizer and a replayable reproducer corpus
//!   ([`fuzz`], `tvm-accel fuzz --cases N --seed S`);
//! * a tracked **performance trajectory** — `tvm-accel bench` cold-compiles
//!   the Table-2 workloads, records compile cost and simulated cycles as
//!   `BENCH_compile.json` / `BENCH_cycles.json`, and [`bench`] gates CI on
//!   simulated-cycle regressions against the committed baseline.
//!
//! See the repository `README.md` for build/test instructions and
//! `src/pipeline/ARCHITECTURE.md` for the stage graph; `examples/` has
//! runnable entry points (`quickstart`, `heterogeneous`,
//! `custom_accelerator`, `scheduler_explore`, `perf_probe`).
//!
//! ## Quickstart
//!
//! Describe the accelerator, compile a quantized model, run it on the
//! cycle-level simulator:
//!
//! ```
//! use tvm_accel::accel::gemmini::gemmini_desc;
//! use tvm_accel::pipeline::Compiler;
//! use tvm_accel::relay::import::{from_quantized, to_qnn_graph};
//! use tvm_accel::relay::quantize::{quantize_mlp, FloatDense};
//! use tvm_accel::sim::Simulator;
//!
//! # fn main() -> anyhow::Result<()> {
//! // A one-layer quantized model (what a TFLite import would give us).
//! let layer = FloatDense {
//!     weight: vec![0.1; 8 * 4],
//!     bias: vec![0.0; 4],
//!     in_dim: 8,
//!     out_dim: 4,
//!     relu: false,
//! };
//! let q = quantize_mlp(&[layer], &[0.05, 0.05])?;
//! let graph = to_qnn_graph(&from_quantized(1, 0.05, &q))?;
//!
//! // The accelerator description is the whole integration effort.
//! let accel = gemmini_desc()?;
//! let deployment = Compiler::new(accel.clone()).compile(&graph)?;
//!
//! // Execute one inference, functionally exact and cycle-accounted.
//! let sim = Simulator::new(&accel.arch);
//! let (output, report) = deployment.run(&sim, &[1i8; 8])?;
//! assert_eq!(output.len(), 4);
//! assert!(report.cycles > 0);
//! # Ok(()) }
//! ```
//!
//! To target several accelerators in one deployment, swap the compiler
//! construction for `Compiler::with_targets(&[desc_a, desc_b])?` and run
//! the resulting [`pipeline::MultiDeployment`] directly (it owns one
//! simulator per target) — see `examples/heterogeneous.rs`.

pub mod accel;
pub mod arch;
pub mod backend;
pub mod baselines;
pub mod bench;
pub mod frontend;
pub mod fuzz;
pub mod isa;
pub mod obs;
pub mod pipeline;
pub mod relay;
#[cfg(feature = "xla-runtime")]
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod sim;
pub mod tir;
pub mod util;
pub mod workload;
