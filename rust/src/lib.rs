//! # tvm-accel
//!
//! A compiler-integration framework for GEMM-based deep-learning
//! accelerators, reproducing *"A High-Level Compiler Integration Approach for
//! Deep Learning Accelerators Supporting Abstraction and Optimization"*
//! (Ahmadifarsani, Mueller-Gritschneder, Schlichtmann, 2025).
//!
//! The crate provides, end to end:
//!
//! * a compact **accelerator description** (functional + architectural) that
//!   is the only thing a user writes to integrate a new GEMM accelerator
//!   ([`accel`], [`arch`]);
//! * an **extended CoSA scheduler** — constrained optimization over loop
//!   mappings with instruction-set constraints, uneven memory-share mapping
//!   and double buffering ([`scheduler`]);
//! * an automated **integration flow** — frontend configurator, strategy
//!   generator, hardware-intrinsic generator and mapping generator — that
//!   turns the description into a working compiler backend ([`frontend`],
//!   [`backend`], [`pipeline`]);
//! * the substrates the paper depends on: a compact Relay-like graph IR with
//!   QNN ops and passes ([`relay`]), a TIR-like loop-nest IR with schedule
//!   primitives ([`tir`]), a Gemmini-class ISA ([`isa`]) and a cycle-level,
//!   functionally exact simulator ([`sim`]);
//! * the paper's two baselines ([`baselines`]) and a PJRT-backed golden
//!   reference runtime (`runtime`, behind the off-by-default `xla-runtime`
//!   cargo feature: it needs the pinned `xla_extension` 0.5.1 toolchain).
//!
//! See `DESIGN.md` for the module inventory and the experiment index, and
//! `examples/` for runnable entry points (`quickstart`, `toycar_e2e`,
//! `custom_accelerator`, `scheduler_explore`).

pub mod accel;
pub mod arch;
pub mod backend;
pub mod baselines;
pub mod frontend;
pub mod isa;
pub mod metrics;
pub mod pipeline;
pub mod relay;
#[cfg(feature = "xla-runtime")]
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod tir;
pub mod util;
pub mod workload;
