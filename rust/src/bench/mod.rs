//! The tracked performance trajectory: `tvm-accel bench`.
//!
//! Cold-compiles every Table-2 workload (the 64³..512³ square dense
//! layers plus the full ToyCar stack) with a **fresh** compiler per
//! workload — no schedule-cache reuse across workloads, so the numbers
//! are honest cold-compile costs — then runs one simulated inference per
//! deployment and emits two flat-JSON artifacts:
//!
//! * `BENCH_compile.json` — per workload: `<name>.compile_us` (the
//!   session's root `compile` trace span — the same spans `tvm-accel
//!   profile` exports, so bench numbers and profiler timelines agree),
//!   `<name>.sweeps`, `<name>.solver_leaves`, `<name>.configs_pruned`
//!   (the search effort behind the compile).
//! * `BENCH_cycles.json` — per workload: simulated end-to-end cycles of
//!   the single-target gemmini compile (`{"<name>": cycles}`) plus the
//!   overlapped makespan of the same workload compiled against the
//!   heterogeneous gemmini+vector pair (`{"<name>.overlapped":
//!   cycles}`) — the graph-level async executor's headline number, gated
//!   exactly like the serial cycles.
//!
//! With `--trace <path>` the CLI additionally writes the concatenated
//! compile spans of every workload as Chrome-trace JSON
//! ([`BenchReport::chrome_trace`]), one process per workload — CI
//! uploads it as the `BENCH_trace.json` artifact.
//!
//! Both files are single-line flat JSON objects in the compile service's
//! wire subset ([`crate::service::protocol`]), so the same hand-rolled,
//! dependency-free parser reads them back — which is exactly what
//! [`check_against_baseline`] does in CI: simulated cycles more than
//! `max_regress_pct` above the committed baseline **fail** the gate;
//! compile-time deltas are reported but advisory (wall time is
//! machine-dependent, cycles are not). A missing baseline file, a missing
//! workload entry, or a `0` baseline value means "record-only": the run
//! reports its numbers and passes, and the gate activates once a measured
//! `BENCH_cycles.json` is committed (see the repository README's
//! Benchmarking section).

#![warn(missing_docs)]

use std::path::Path;

use anyhow::{Context, Result};

use crate::accel::gemmini::gemmini_desc;
use crate::backend::vector::vector_desc;
use crate::baselines::naive_byoc::import_with_weight_chain;
use crate::obs::chrome::ChromeTrace;
use crate::obs::span::Span;
use crate::obs::spans_to_chrome;
use crate::pipeline::{Compiler, MultiCompiler};
use crate::relay::import::{from_quantized, QModel};
use crate::relay::quantize::{quantize_mlp, FloatDense};
use crate::service::protocol::{parse_message, ObjBuilder};
use crate::sim::Simulator;
use crate::util::prng::Rng;
use crate::workload::suites;

/// File name of the compile-cost artifact.
pub const COMPILE_FILE: &str = "BENCH_compile.json";
/// File name of the simulated-cycles artifact.
pub const CYCLES_FILE: &str = "BENCH_cycles.json";
/// File name of the optional Chrome-trace artifact (`--trace`).
pub const TRACE_FILE: &str = "BENCH_trace.json";

/// One workload's measurements: cold-compile cost and simulated latency.
#[derive(Debug, Clone)]
pub struct WorkloadResult {
    /// Workload name (the Table-2 label, e.g. `"(64, 64, 64)"`).
    pub name: String,
    /// Cold-compile time in microseconds, derived from the session's
    /// root `compile` trace span (machine-dependent — reported, never
    /// gated).
    pub compile_us: u64,
    /// The compile's full trace spans (stages, sweeps, cache events) —
    /// what [`BenchReport::chrome_trace`] exports.
    pub spans: Vec<Span>,
    /// Schedule sweeps the cold compile executed.
    pub sweeps: u64,
    /// Solver leaves costed across those sweeps (the search effort).
    pub solver_leaves: u64,
    /// Dominated sweep configuration points that rode a group search.
    pub configs_pruned: u64,
    /// Simulated end-to-end cycles of one inference (deterministic —
    /// this is what the CI gate checks).
    pub cycles: u64,
    /// Overlapped makespan of one inference through the heterogeneous
    /// gemmini+vector compile of the same workload (deterministic, gated
    /// like `cycles`; always ≤ that compile's serial total).
    pub overlapped: u64,
}

/// Everything one bench run measured.
#[derive(Debug, Clone, Default)]
pub struct BenchReport {
    /// Per-workload results, in suite order.
    pub results: Vec<WorkloadResult>,
}

impl BenchReport {
    /// The `BENCH_compile.json` line (flat JSON, no trailing newline).
    pub fn compile_json(&self) -> String {
        let mut b = ObjBuilder::new();
        for r in &self.results {
            b = b
                .num_field(&format!("{}.compile_us", r.name), r.compile_us)
                .num_field(&format!("{}.sweeps", r.name), r.sweeps)
                .num_field(&format!("{}.solver_leaves", r.name), r.solver_leaves)
                .num_field(&format!("{}.configs_pruned", r.name), r.configs_pruned);
        }
        b.finish()
    }

    /// The `BENCH_cycles.json` line (flat JSON, no trailing newline).
    pub fn cycles_json(&self) -> String {
        let mut b = ObjBuilder::new();
        for r in &self.results {
            b = b
                .num_field(&r.name, r.cycles)
                .num_field(&format!("{}.overlapped", r.name), r.overlapped);
        }
        b.finish()
    }

    /// The concatenated compile spans of every workload as Chrome-trace
    /// JSON: one process per workload (pid = suite position + 1), the
    /// pipeline on thread 1. Loadable in Perfetto / `chrome://tracing`.
    pub fn chrome_trace(&self) -> String {
        let mut ct = ChromeTrace::new();
        for (i, r) in self.results.iter().enumerate() {
            let pid = i as u64 + 1;
            ct.process_name(pid, &r.name);
            ct.thread_name(pid, 1, "compile pipeline");
            spans_to_chrome(&mut ct, pid, 1, &r.spans);
        }
        ct.render()
    }

    /// Write both artifacts into `dir` (created if needed).
    pub fn write_artifacts(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating bench output dir {}", dir.display()))?;
        let compile = dir.join(COMPILE_FILE);
        std::fs::write(&compile, self.compile_json() + "\n")
            .with_context(|| format!("writing {}", compile.display()))?;
        let cycles = dir.join(CYCLES_FILE);
        std::fs::write(&cycles, self.cycles_json() + "\n")
            .with_context(|| format!("writing {}", cycles.display()))?;
        Ok(())
    }

    /// Render the results as an aligned table (for the CLI).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.results {
            out.push_str(&format!(
                "{:<16} {:>12} cycles   {:>12} overlapped   compile {:>9} µs   \
                 {:>3} sweep(s)   {:>9} leaf(s) visited   {:>3} config(s) pruned\n",
                r.name,
                r.cycles,
                r.overlapped,
                r.compile_us,
                r.sweeps,
                r.solver_leaves,
                r.configs_pruned
            ));
        }
        out
    }
}

/// A seeded square `size`×`size` single-dense-layer model (one Table-2
/// workload). Deterministic for a fixed seed — the bench suite and the
/// golden-hash byte-identity tests build the exact same models.
pub fn square_model(size: usize, seed: u64) -> Result<QModel> {
    let mut rng = Rng::new(seed);
    let l = FloatDense {
        weight: (0..size * size).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
        bias: (0..size).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
        in_dim: size,
        out_dim: size,
        relu: false,
    };
    Ok(from_quantized(size, 0.04, &quantize_mlp(&[l], &[0.04, 0.05])?))
}

/// The seeded full ToyCar MLP stack (see
/// [`crate::workload::suites::toycar_widths`]). Deterministic for a
/// fixed seed, like [`square_model`].
pub fn toycar_model(seed: u64) -> Result<QModel> {
    let mut rng = Rng::new(seed);
    let widths = suites::toycar_widths();
    let layers: Vec<FloatDense> = widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| FloatDense {
            weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.25).collect(),
            bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
            in_dim: w[0],
            out_dim: w[1],
            relu: i + 2 < widths.len(),
        })
        .collect();
    let scales: Vec<f32> = (0..widths.len()).map(|i| 0.04 + 0.01 * i as f32).collect();
    Ok(from_quantized(1, scales[0], &quantize_mlp(&layers, &scales)?))
}

/// The tracked suite: the Table-2 square layers plus the full ToyCar
/// stack, with the same seeds the `table2_latency` bench uses (so the
/// simulated cycles line up with the reproduced table).
pub fn standard_suite() -> Result<Vec<(String, QModel)>> {
    let mut suite = Vec::new();
    for (i, (name, g)) in suites::table2_single_layers().iter().enumerate() {
        suite.push((name.clone(), square_model(g.n, 500 + i as u64)?));
    }
    suite.push(("ToyCar".to_string(), toycar_model(600)?));
    Ok(suite)
}

/// Cold-compile and simulate every workload in `suite`. Each workload
/// gets a fresh [`Compiler`] (default options) so nothing is amortized
/// across workloads; the per-compiler counters therefore attribute
/// sweeps and solver leaves to exactly one workload.
pub fn run_suite(suite: &[(String, QModel)]) -> Result<BenchReport> {
    let accel = gemmini_desc()?;
    let vector = vector_desc()?;
    let sim = Simulator::new(&accel.arch);
    let mut results = Vec::new();
    for (name, model) in suite {
        let graph = import_with_weight_chain(model)
            .with_context(|| format!("importing bench workload '{name}'"))?;
        let compiler = Compiler::new(accel.clone());
        // Traced compile: per-stage cost and the headline compile_us both
        // come from the session's spans (one timing source), and tracing
        // is passive so the emitted program is byte-identical to an
        // untraced `compile` (property-tested in `tests/obs_passive.rs`).
        let out = compiler
            .compile_traced(&graph)
            .with_context(|| format!("cold-compiling '{name}'"))?;
        let compile_us = out
            .trace
            .spans_named("compile")
            .first()
            .map(|s| s.elapsed().as_micros() as u64)
            .unwrap_or(0);
        let spans = out.trace.spans();
        let dep = out.deployment;
        let x = Rng::new(7).i8_vec(model.batch * model.layers[0].in_dim);
        let (_, rep) =
            dep.run(&sim, &x).with_context(|| format!("simulating '{name}'"))?;
        // The same workload through the heterogeneous gemmini+vector
        // pair (fresh compiler, same cold-compile rules): the run prices
        // the overlapped segment schedule alongside the serial total.
        let multi = MultiCompiler::new(vec![accel.clone(), vector.clone()])?
            .compile(&graph)
            .with_context(|| format!("cold-compiling '{name}' (gemmini+vector)"))?;
        let (_, multi_rep) = multi
            .run(&x)
            .with_context(|| format!("simulating '{name}' (gemmini+vector)"))?;
        results.push(WorkloadResult {
            name: name.clone(),
            compile_us,
            spans,
            sweeps: compiler.sweeps_run(),
            solver_leaves: compiler.solver_leaves_visited(),
            configs_pruned: compiler.configs_pruned(),
            cycles: rep.cycles,
            overlapped: multi_rep.overlapped_cycles,
        });
    }
    Ok(BenchReport { results })
}

/// The regression gate's verdict: `failures` is what breaks CI,
/// `notes` is everything worth printing either way.
#[derive(Debug, Clone, Default)]
pub struct GateOutcome {
    /// Cycle regressions beyond the allowed percentage (CI fails on any).
    pub failures: Vec<String>,
    /// Per-workload comparisons, bootstrap notices and advisory
    /// compile-time deltas.
    pub notes: Vec<String>,
    /// Workload entries that were record-only (missing baseline file,
    /// missing entry, or unset `0` value). Nonzero means the gate is
    /// not actually armed and [`GateOutcome::render`] shouts about it.
    pub bootstrap_entries: usize,
}

impl GateOutcome {
    /// True when no workload regressed beyond the threshold.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// True when every workload was checked against a real measured
    /// baseline — the gate can actually fail.
    pub fn armed(&self) -> bool {
        self.bootstrap_entries == 0
    }

    /// Render notes then failures, one per line, plus a loud warning
    /// when any entry ran in record-only bootstrap mode.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for n in &self.notes {
            out.push_str(&format!("  {n}\n"));
        }
        for f in &self.failures {
            out.push_str(&format!("  REGRESSION: {f}\n"));
        }
        if !self.armed() {
            out.push_str(&format!(
                "  WARNING: cycle gate is in record-only bootstrap mode for {} \
                 workload(s) — regressions are NOT failing CI.\n  WARNING: commit a \
                 measured {CYCLES_FILE} (tvm-accel bench --out-dir <baseline dir> on a \
                 green run) to arm the gate.\n",
                self.bootstrap_entries
            ));
        }
        out
    }
}

fn read_flat_json(path: &Path) -> Option<crate::service::protocol::Message> {
    let text = std::fs::read_to_string(path).ok()?;
    parse_message(text.trim()).ok()
}

/// Diff `report` against the committed baseline in `baseline_dir`.
///
/// Simulated cycles more than `max_regress_pct` percent above the
/// baseline value fail the gate. A missing `BENCH_cycles.json`, a
/// missing workload entry, or a baseline value of `0` is the bootstrap
/// state: record-only, always passes. Compile-time deltas (from
/// `BENCH_compile.json`) are advisory notes, never failures.
pub fn check_against_baseline(
    report: &BenchReport,
    baseline_dir: &Path,
    max_regress_pct: f64,
) -> GateOutcome {
    let mut out = GateOutcome::default();
    let cycles_path = baseline_dir.join(CYCLES_FILE);
    match read_flat_json(&cycles_path) {
        None => {
            // Two gated entries per workload: serial cycles and the
            // overlapped makespan.
            out.bootstrap_entries += 2 * report.results.len();
            out.notes.push(format!(
                "no cycle baseline at {} — recording only",
                cycles_path.display()
            ))
        }
        Some(base) => {
            for r in &report.results {
                let tracked = [
                    (r.name.clone(), r.cycles),
                    (format!("{}.overlapped", r.name), r.overlapped),
                ];
                for (key, current) in tracked {
                    match base.num_field(&key) {
                        None => {
                            out.bootstrap_entries += 1;
                            out.notes.push(format!(
                                "{key}: no baseline entry — recording only"
                            ))
                        }
                        Some(b) if b <= 0.0 => {
                            out.bootstrap_entries += 1;
                            out.notes.push(format!(
                                "{key}: baseline unset (0) — gate activates once a \
                                 measured baseline is committed"
                            ))
                        }
                        Some(b) => {
                            let delta_pct = (current as f64 - b) / b * 100.0;
                            if delta_pct > max_regress_pct {
                                out.failures.push(format!(
                                    "{key}: {current} simulated cycles vs baseline {} \
                                     ({:+.1}% > {:.1}% allowed)",
                                    b as u64, delta_pct, max_regress_pct
                                ));
                            } else {
                                out.notes.push(format!(
                                    "{key}: {current} cycles vs baseline {} ({:+.1}%)",
                                    b as u64, delta_pct
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
    if let Some(base) = read_flat_json(&baseline_dir.join(COMPILE_FILE)) {
        for r in &report.results {
            if let Some(b) = base.num_field(&format!("{}.compile_us", r.name)) {
                if b > 0.0 {
                    let delta_pct = (r.compile_us as f64 - b) / b * 100.0;
                    out.notes.push(format!(
                        "{}: compile {} µs vs baseline {} µs ({:+.1}%, advisory)",
                        r.name, r.compile_us, b as u64, delta_pct
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report() -> BenchReport {
        BenchReport {
            results: vec![
                WorkloadResult {
                    name: "a".into(),
                    compile_us: 1000,
                    spans: vec![],
                    sweeps: 3,
                    solver_leaves: 50,
                    configs_pruned: 1,
                    cycles: 1100,
                    overlapped: 880,
                },
                WorkloadResult {
                    name: "b".into(),
                    compile_us: 2000,
                    spans: vec![],
                    sweeps: 5,
                    solver_leaves: 80,
                    configs_pruned: 0,
                    cycles: 900,
                    overlapped: 700,
                },
            ],
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvm-accel-bench-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn artifacts_roundtrip_through_protocol_parser() {
        let rep = fake_report();
        let dir = tmp_dir("roundtrip");
        rep.write_artifacts(&dir).unwrap();
        let cycles = read_flat_json(&dir.join(CYCLES_FILE)).unwrap();
        assert_eq!(cycles.num_field("a"), Some(1100.0));
        assert_eq!(cycles.num_field("b"), Some(900.0));
        assert_eq!(cycles.num_field("a.overlapped"), Some(880.0));
        assert_eq!(cycles.num_field("b.overlapped"), Some(700.0));
        let compile = read_flat_json(&dir.join(COMPILE_FILE)).unwrap();
        assert_eq!(compile.num_field("a.compile_us"), Some(1000.0));
        assert_eq!(compile.num_field("b.sweeps"), Some(5.0));
        assert_eq!(compile.num_field("a.configs_pruned"), Some(1.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gate_fails_only_on_regression_beyond_threshold() {
        let dir = tmp_dir("gate");
        // Baseline: 'a' at 1000 (current 1100 = +10%), 'b' at 1000
        // (current 900, an improvement — never a failure); both
        // overlapped entries at their current values (0%).
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":1000,\"a.overlapped\":880,\"b\":1000,\"b.overlapped\":700}\n",
        )
        .unwrap();
        let rep = fake_report();
        let loose = check_against_baseline(&rep, &dir, 15.0);
        assert!(loose.passed(), "+10% within a 15% gate: {:?}", loose.failures);
        assert!(loose.notes.iter().any(|n| n.starts_with("a:")));
        let tight = check_against_baseline(&rep, &dir, 5.0);
        assert!(!tight.passed(), "+10% must fail a 5% gate");
        assert_eq!(tight.failures.len(), 1, "only 'a' regressed: {:?}", tight.failures);
        assert!(tight.failures[0].starts_with("a:"), "{:?}", tight.failures);
        assert!(!tight.render().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_or_zero_baseline_is_record_only() {
        let rep = fake_report();
        let dir = tmp_dir("bootstrap");
        let missing = check_against_baseline(&rep, &dir, 10.0);
        assert!(missing.passed(), "no baseline file = record-only");
        assert!(!missing.notes.is_empty());
        assert!(!missing.armed(), "no baseline file means the gate is unarmed");
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":0,\"a.overlapped\":0,\"b\":0,\"b.overlapped\":0}\n",
        )
        .unwrap();
        let zero = check_against_baseline(&rep, &dir, 10.0);
        assert!(zero.passed(), "zero baseline = bootstrap, record-only");
        assert!(zero.notes.iter().any(|n| n.contains("baseline unset")));
        assert_eq!(zero.bootstrap_entries, 4, "two tracked entries per workload");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bootstrap_mode_warns_loudly_and_armed_mode_does_not() {
        let rep = fake_report();
        let dir = tmp_dir("warn");
        // All-zero bootstrap baseline: the rendered outcome must shout.
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":0,\"a.overlapped\":0,\"b\":0,\"b.overlapped\":0}\n",
        )
        .unwrap();
        let boot = check_against_baseline(&rep, &dir, 10.0);
        assert!(boot.render().contains("WARNING"), "got: {}", boot.render());
        assert!(boot.render().contains("record-only bootstrap"));
        // Measured baseline: armed, no warning.
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":1000,\"a.overlapped\":880,\"b\":1000,\"b.overlapped\":700}\n",
        )
        .unwrap();
        let armed = check_against_baseline(&rep, &dir, 15.0);
        assert!(armed.armed());
        assert!(!armed.render().contains("WARNING"), "got: {}", armed.render());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overlapped_regressions_fail_the_gate() {
        let dir = tmp_dir("overlapped");
        // Serial cycles at current values; 'a' overlapped baseline 800
        // (current 880 = +10%) regresses past a 5% gate.
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":1100,\"a.overlapped\":800,\"b\":900,\"b.overlapped\":700}\n",
        )
        .unwrap();
        let out = check_against_baseline(&fake_report(), &dir, 5.0);
        assert!(!out.passed(), "overlapped makespan is gated too");
        assert_eq!(out.failures.len(), 1, "{:?}", out.failures);
        assert!(out.failures[0].starts_with("a.overlapped:"), "{:?}", out.failures);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compile_time_deltas_are_advisory() {
        let dir = tmp_dir("advisory");
        std::fs::write(
            dir.join(CYCLES_FILE),
            "{\"a\":1100,\"a.overlapped\":880,\"b\":900,\"b.overlapped\":700}\n",
        )
        .unwrap();
        // Wildly slower compiles than baseline must not fail the gate.
        std::fs::write(
            dir.join(COMPILE_FILE),
            "{\"a.compile_us\":1,\"b.compile_us\":1}\n",
        )
        .unwrap();
        let out = check_against_baseline(&fake_report(), &dir, 10.0);
        assert!(out.passed(), "compile time is advisory: {:?}", out.failures);
        assert!(out.notes.iter().any(|n| n.contains("advisory")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_runs_a_small_workload_end_to_end() {
        let suite = vec![("(64, 64, 64)".to_string(), square_model(64, 500).unwrap())];
        let rep = run_suite(&suite).unwrap();
        assert_eq!(rep.results.len(), 1);
        let r = &rep.results[0];
        assert!(r.cycles > 0, "one simulated inference ran");
        assert!(r.overlapped > 0, "the gemmini+vector compile priced its overlap");
        assert!(r.sweeps > 0 && r.solver_leaves > 0, "cold compile searched");
        assert!(rep.cycles_json().contains("(64, 64, 64)"));
        assert!(rep.cycles_json().contains("(64, 64, 64).overlapped"));
        assert!(!rep.render().is_empty());
        // Span-derived timing: the compile root span exists and covers
        // every stage span recorded under it.
        assert!(r.compile_us > 0, "compile_us derives from the compile span");
        assert!(
            r.spans.iter().any(|s| s.name == "schedule"),
            "stage spans recorded: {:?}",
            r.spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
        let trace = rep.chrome_trace();
        assert!(trace.starts_with("{\"traceEvents\":["));
        assert!(trace.contains("(64, 64, 64)"), "workload names its process");
        assert!(trace.contains("\"name\":\"sweep\""), "sweep spans exported");
    }
}
