//! Benchmark workload suites matching the paper's evaluation (§4).

use super::Gemm;

/// The single-dense-layer shapes of Table 2: square (N, K, C) GEMMs.
pub fn table2_single_layers() -> Vec<(String, Gemm)> {
    [64usize, 128, 256, 512]
        .iter()
        .map(|&s| (format!("({s}, {s}, {s})"), Gemm::new(s, s, s)))
        .collect()
}

/// Dense-layer stack of the MLPerf-Tiny ToyCar anomaly-detection
/// autoencoder (fully-connected 640-128-128-128-128-8-128-128-128-128-640).
/// Each entry is (layer name, GEMM with batch N=1).
pub fn toycar_layers() -> Vec<(String, Gemm)> {
    let widths = [640usize, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640];
    widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| (format!("fc{}_{}x{}", i, w[0], w[1]), Gemm::new(1, w[0], w[1])))
        .collect()
}

/// The hidden widths of the ToyCar autoencoder, input first.
pub fn toycar_widths() -> Vec<usize> {
    vec![640, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_suite_shapes() {
        let s = table2_single_layers();
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].1, Gemm::new(64, 64, 64));
        assert_eq!(s[3].1, Gemm::new(512, 512, 512));
    }

    #[test]
    fn toycar_has_ten_dense_layers() {
        let layers = toycar_layers();
        assert_eq!(layers.len(), 10);
        // Encoder input layer 640 -> 128, bottleneck 128 -> 8, decoder output 128 -> 640.
        assert_eq!(layers[0].1, Gemm::new(1, 640, 128));
        assert_eq!(layers[4].1, Gemm::new(1, 128, 8));
        assert_eq!(layers[9].1, Gemm::new(1, 128, 640));
    }

    #[test]
    fn toycar_macs_are_small() {
        // The network is tiny: ~ a quarter-million MACs total. This is what
        // makes per-layer host-side preprocessing overhead catastrophic in
        // the naive BYOC backend (Table 2's ~200x ToyCar gap).
        let total: u64 = toycar_layers().iter().map(|(_, g)| g.macs()).sum();
        assert!(total < 600_000, "total={total}");
    }
}
