//! Workload definitions: the tensor-operation shapes the scheduler and
//! compiler operate on (GEMM and 2-D convolution), prime factorization of
//! loop bounds, and the benchmark suites used in the paper's evaluation.

pub mod factor;
pub mod suites;

use std::fmt;

/// The three GEMM dimensions, following the paper's convention:
/// `In ∈ R^{N×C}`, `W ∈ R^{C×K}`, `O ∈ R^{N×K}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    /// Output rows (batch / spatial positions).
    N,
    /// Reduction (input channels).
    C,
    /// Output columns (output channels).
    K,
}

impl Dim {
    pub const ALL: [Dim; 3] = [Dim::N, Dim::C, Dim::K];

    pub fn index(self) -> usize {
        match self {
            Dim::N => 0,
            Dim::C => 1,
            Dim::K => 2,
        }
    }

    pub fn from_index(i: usize) -> Dim {
        Dim::ALL[i]
    }

    pub fn parse(s: &str) -> Option<Dim> {
        match s {
            "N" | "n" => Some(Dim::N),
            "C" | "c" => Some(Dim::C),
            "K" | "k" => Some(Dim::K),
            _ => None,
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::N => write!(f, "N"),
            Dim::C => write!(f, "C"),
            Dim::K => write!(f, "K"),
        }
    }
}

/// The three GEMM operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Operand {
    Input,
    Weight,
    Output,
}

impl Operand {
    pub const ALL: [Operand; 3] = [Operand::Input, Operand::Weight, Operand::Output];

    pub fn index(self) -> usize {
        match self {
            Operand::Input => 0,
            Operand::Weight => 1,
            Operand::Output => 2,
        }
    }

    /// Which GEMM dimensions this operand's footprint depends on.
    /// (Input: N×C, Weight: C×K, Output: N×K.)
    pub fn dims(self) -> [Dim; 2] {
        match self {
            Operand::Input => [Dim::N, Dim::C],
            Operand::Weight => [Dim::C, Dim::K],
            Operand::Output => [Dim::N, Dim::K],
        }
    }

    /// Whether this operand's footprint depends on `d`.
    pub fn uses(self, d: Dim) -> bool {
        self.dims().contains(&d)
    }

    /// The dimension this operand is *reused over* (the GEMM dim it does not
    /// depend on): temporal iteration over that dim revisits the operand.
    pub fn reuse_dim(self) -> Dim {
        match self {
            Operand::Input => Dim::K,
            Operand::Weight => Dim::N,
            Operand::Output => Dim::C,
        }
    }
}

impl fmt::Display for Operand {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Operand::Input => write!(f, "Input"),
            Operand::Weight => write!(f, "Weight"),
            Operand::Output => write!(f, "Output"),
        }
    }
}

/// A GEMM workload: `O[N,K] = In[N,C] · W[C,K]` (plus bias / requantize in
/// the quantized pipeline). Convolutions are lowered to this via im2col.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gemm {
    pub n: usize,
    pub c: usize,
    pub k: usize,
}

impl Gemm {
    pub fn new(n: usize, c: usize, k: usize) -> Gemm {
        assert!(n > 0 && c > 0 && k > 0, "GEMM dims must be positive");
        Gemm { n, c, k }
    }

    pub fn bound(&self, d: Dim) -> usize {
        match d {
            Dim::N => self.n,
            Dim::C => self.c,
            Dim::K => self.k,
        }
    }

    /// Total multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        self.n as u64 * self.c as u64 * self.k as u64
    }

    /// Byte footprint of an operand tile with the given per-dim tile sizes,
    /// at `elem_bytes` bytes per element.
    pub fn operand_bytes(op: Operand, tile: &[usize; 3], elem_bytes: usize) -> usize {
        let [a, b] = op.dims();
        tile[a.index()] * tile[b.index()] * elem_bytes
    }
}

impl fmt::Display for Gemm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({}, {}, {})", self.n, self.k, self.c)
    }
}

/// A 2-D convolution workload (NHWC, OHWI weights), lowered to GEMM by
/// im2col: N' = batch·out_h·out_w, C' = kh·kw·in_c, K' = out_c.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Conv2d {
    pub batch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// The GEMM this convolution lowers to via im2col.
    pub fn to_gemm(&self) -> Gemm {
        Gemm::new(
            self.batch * self.out_h() * self.out_w(),
            self.kh * self.kw * self.in_c,
            self.out_c,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_dim_relations() {
        assert!(Operand::Input.uses(Dim::N) && Operand::Input.uses(Dim::C));
        assert!(!Operand::Input.uses(Dim::K));
        assert_eq!(Operand::Input.reuse_dim(), Dim::K);
        assert_eq!(Operand::Weight.reuse_dim(), Dim::N);
        assert_eq!(Operand::Output.reuse_dim(), Dim::C);
        for op in Operand::ALL {
            // reuse dim is exactly the dim not used.
            assert!(!op.uses(op.reuse_dim()));
        }
    }

    #[test]
    fn gemm_macs_and_bounds() {
        let g = Gemm::new(64, 128, 256);
        assert_eq!(g.bound(Dim::N), 64);
        assert_eq!(g.bound(Dim::C), 128);
        assert_eq!(g.bound(Dim::K), 256);
        assert_eq!(g.macs(), 64 * 128 * 256);
    }

    #[test]
    fn operand_bytes_footprint() {
        let tile = [16usize, 32, 8]; // n, c, k
        assert_eq!(Gemm::operand_bytes(Operand::Input, &tile, 1), 16 * 32);
        assert_eq!(Gemm::operand_bytes(Operand::Weight, &tile, 1), 32 * 8);
        assert_eq!(Gemm::operand_bytes(Operand::Output, &tile, 4), 16 * 8 * 4);
    }

    #[test]
    fn conv_im2col() {
        let c = Conv2d {
            batch: 1,
            in_h: 8,
            in_w: 8,
            in_c: 3,
            out_c: 16,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(c.out_h(), 8);
        assert_eq!(c.out_w(), 8);
        let g = c.to_gemm();
        assert_eq!(g, Gemm::new(64, 27, 16));
    }

    #[test]
    fn dim_roundtrip() {
        for d in Dim::ALL {
            assert_eq!(Dim::from_index(d.index()), d);
            assert_eq!(Dim::parse(&d.to_string()), Some(d));
        }
        assert_eq!(Dim::parse("Q"), None);
    }
}
