//! Prime factorization of loop bounds.
//!
//! CoSA's mapping variables assign *prime factors* of each loop bound to
//! (memory level, spatial/temporal) slots; we represent a bound as the
//! multiset of its prime factors grouped by prime (`2^7 · 5^1` for 640).

use std::fmt;

/// Prime factorization of a loop bound, grouped as `(prime, exponent)`
/// pairs in increasing prime order.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Factorization {
    pub value: usize,
    pub factors: Vec<(usize, u32)>,
}

impl Factorization {
    /// Factorize `v` by trial division (bounds are small: ≤ a few thousand).
    pub fn of(v: usize) -> Factorization {
        assert!(v > 0, "cannot factorize 0");
        let mut factors = Vec::new();
        let mut rest = v;
        let mut p = 2;
        while p * p <= rest {
            if rest % p == 0 {
                let mut e = 0;
                while rest % p == 0 {
                    rest /= p;
                    e += 1;
                }
                factors.push((p, e));
            }
            p += if p == 2 { 1 } else { 2 };
        }
        if rest > 1 {
            factors.push((rest, 1));
        }
        Factorization { value: v, factors }
    }

    /// Total number of prime factors counted with multiplicity
    /// (the `n` axis size of CoSA's X matrix for this dimension).
    pub fn num_prime_factors(&self) -> u32 {
        self.factors.iter().map(|&(_, e)| e).sum()
    }

    /// Flat list of primes with multiplicity, e.g. 12 → [2, 2, 3].
    pub fn flat(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for &(p, e) in &self.factors {
            for _ in 0..e {
                out.push(p);
            }
        }
        out
    }

    /// All divisors of the value, sorted ascending.
    pub fn divisors(&self) -> Vec<usize> {
        let mut divs = vec![1usize];
        for &(p, e) in &self.factors {
            let mut next = Vec::with_capacity(divs.len() * (e as usize + 1));
            for &d in &divs {
                let mut pe = 1usize;
                for _ in 0..=e {
                    next.push(d * pe);
                    pe *= p;
                }
            }
            divs = next;
        }
        divs.sort_unstable();
        divs
    }
}

impl fmt::Display for Factorization {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} = ", self.value)?;
        for (i, (p, e)) in self.factors.iter().enumerate() {
            if i > 0 {
                write!(f, " · ")?;
            }
            if *e == 1 {
                write!(f, "{p}")?;
            } else {
                write!(f, "{p}^{e}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn small_factorizations() {
        assert_eq!(Factorization::of(1).factors, vec![]);
        assert_eq!(Factorization::of(2).factors, vec![(2, 1)]);
        assert_eq!(Factorization::of(12).factors, vec![(2, 2), (3, 1)]);
        assert_eq!(Factorization::of(640).factors, vec![(2, 7), (5, 1)]);
        assert_eq!(Factorization::of(97).factors, vec![(97, 1)]);
    }

    #[test]
    fn flat_and_counts() {
        let f = Factorization::of(360); // 2^3 · 3^2 · 5
        assert_eq!(f.num_prime_factors(), 6);
        assert_eq!(f.flat(), vec![2, 2, 2, 3, 3, 5]);
    }

    #[test]
    fn divisors_of_64() {
        assert_eq!(Factorization::of(64).divisors(), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(Factorization::of(12).divisors(), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn prop_product_of_factors_reconstructs_value() {
        prop::check("factor product == value", 500, |rng: &mut Rng| {
            let v = rng.range(1, 5000);
            let f = Factorization::of(v);
            let prod: usize = f.flat().iter().product();
            prop::assert_prop(prod == v, format!("v={v} prod={prod}"))
        });
    }

    #[test]
    fn prop_divisors_divide() {
        prop::check("all divisors divide", 200, |rng: &mut Rng| {
            let v = rng.range(1, 2000);
            let f = Factorization::of(v);
            for d in f.divisors() {
                if v % d != 0 {
                    return Err(format!("v={v} d={d}"));
                }
            }
            // Count check: τ(v) = Π (e_i + 1).
            let tau: usize = f.factors.iter().map(|&(_, e)| e as usize + 1).product();
            prop::assert_prop(
                f.divisors().len() == tau,
                format!("v={v} τ mismatch"),
            )
        });
    }
}
