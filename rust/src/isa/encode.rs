//! Fixed-width instruction encoding: each [`Instr`] packs into a RoCC-style
//! `(funct: u8, rs1: u64, rs2: u64)` triple (plus one extension word for
//! `LOOP_WS`, which in real Gemmini is likewise split across several
//! commands).
//!
//! Field layout (our own packing, documented per instruction below) is
//! lossless: `decode(encode(i)) == i` for every well-formed instruction —
//! checked by a property test over random instructions.

use anyhow::{bail, Result};

use super::{Activation, Instr, LocalAddr, Space};
use crate::arch::Dataflow;

/// Function codes (RoCC `funct7`-style discriminators).
pub mod funct {
    pub const CONFIG_EX: u8 = 0;
    pub const CONFIG_LD: u8 = 1;
    pub const CONFIG_ST: u8 = 2;
    pub const MVIN: u8 = 3;
    pub const MVOUT: u8 = 4;
    pub const PRELOAD: u8 = 5;
    pub const COMPUTE_PRELOADED: u8 = 6;
    pub const COMPUTE_ACCUMULATED: u8 = 7;
    pub const LOOP_WS: u8 = 8;
    /// Second word of LOOP_WS (bounds + strides).
    pub const LOOP_WS_CONFIG: u8 = 9;
    pub const FENCE: u8 = 10;
    pub const FLUSH: u8 = 11;
    /// On-chip requantizing store (accumulator → scratchpad, no DRAM).
    pub const MVOUT_SPAD: u8 = 12;
}

/// One encoded command word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Word {
    pub funct: u8,
    pub rs1: u64,
    pub rs2: u64,
}

/// Local address packing (Gemmini-style): bit 31 = accumulator space,
/// bit 30 = accumulate flag, low 30 bits = row. `0xFFFF_FFFF` = garbage
/// (None).
fn pack_local(a: Option<LocalAddr>) -> u64 {
    match a {
        None => 0xFFFF_FFFF,
        Some(a) => {
            let mut v = a.row as u64 & 0x3FFF_FFFF;
            if a.space == Space::Acc {
                v |= 1 << 31;
            }
            if a.accumulate {
                v |= 1 << 30;
            }
            v
        }
    }
}

fn unpack_local(v: u64) -> Result<Option<LocalAddr>> {
    let v = v & 0xFFFF_FFFF;
    if v == 0xFFFF_FFFF {
        return Ok(None);
    }
    let space = if v & (1 << 31) != 0 { Space::Acc } else { Space::Spad };
    let accumulate = v & (1 << 30) != 0;
    if accumulate && space == Space::Spad {
        bail!("accumulate bit set on scratchpad address {v:#x}");
    }
    Ok(Some(LocalAddr { space, row: (v & 0x3FFF_FFFF) as u32, accumulate }))
}

/// Dims packing: rows in bits [15:0], cols in bits [31:16].
fn pack_dims(rows: u16, cols: u16) -> u64 {
    rows as u64 | ((cols as u64) << 16)
}

fn unpack_dims(v: u64) -> (u16, u16) {
    ((v & 0xFFFF) as u16, ((v >> 16) & 0xFFFF) as u16)
}

/// Encode one instruction into one or two command words.
pub fn encode(i: &Instr) -> Vec<Word> {
    match *i {
        Instr::ConfigEx { dataflow } => {
            let df = match dataflow {
                Dataflow::WeightStationary => 0u64,
                Dataflow::OutputStationary => 1u64,
            };
            vec![Word { funct: funct::CONFIG_EX, rs1: df, rs2: 0 }]
        }
        Instr::ConfigLd { stride } => {
            vec![Word { funct: funct::CONFIG_LD, rs1: stride as u64, rs2: 0 }]
        }
        Instr::ConfigSt { stride, scale, act } => {
            // rs1: stride in [31:0], activation tag in [33:32],
            //      clip bounds in [49:34] (lo, hi as u8 two's complement).
            let (tag, lo, hi) = match act {
                Activation::None => (0u64, 0u8, 0u8),
                Activation::Relu => (1, 0, 0),
                Activation::Clip { lo, hi } => (2, lo as u8, hi as u8),
            };
            let rs1 = (stride as u64)
                | (tag << 32)
                | ((lo as u64) << 34)
                | ((hi as u64) << 42);
            vec![Word { funct: funct::CONFIG_ST, rs1, rs2: f32::to_bits(scale) as u64 }]
        }
        Instr::Mvin { dram, local, rows, cols } => vec![Word {
            funct: funct::MVIN,
            rs1: dram,
            rs2: pack_local(Some(local)) | (pack_dims(rows, cols) << 32),
        }],
        Instr::Mvout { dram, local, rows, cols } => vec![Word {
            funct: funct::MVOUT,
            rs1: dram,
            rs2: pack_local(Some(local)) | (pack_dims(rows, cols) << 32),
        }],
        Instr::MvoutSpad { src, dst, rows, cols } => vec![Word {
            funct: funct::MVOUT_SPAD,
            rs1: pack_local(Some(src)) | (pack_dims(rows, cols) << 32),
            rs2: pack_local(Some(dst)),
        }],
        Instr::Preload { local, dst, rows, cols } => vec![Word {
            funct: funct::PRELOAD,
            rs1: pack_local(local) | (pack_dims(rows, cols) << 32),
            rs2: pack_local(Some(dst)),
        }],
        Instr::Compute { a, d, rows, cols, preloaded } => vec![Word {
            funct: if preloaded {
                funct::COMPUTE_PRELOADED
            } else {
                funct::COMPUTE_ACCUMULATED
            },
            rs1: pack_local(Some(a)) | (pack_dims(rows, cols) << 32),
            rs2: pack_local(d),
        }],
        Instr::LoopWs {
            a_dram,
            b_dram,
            c_dram,
            d_dram,
            m,
            n,
            k,
            a_stride,
            b_stride,
            c_stride,
        } => {
            // Word 1 (LOOP_WS_CONFIG): bounds m,n,k in 21-bit fields of
            // rs1; strides a,b in rs2 [31:0]/[63:32].
            let rs1 = (m as u64 & 0x1F_FFFF)
                | ((n as u64 & 0x1F_FFFF) << 21)
                | ((k as u64 & 0x1F_FFFF) << 42);
            let rs2 = a_stride as u64 | ((b_stride as u64) << 32);
            // Word 2 (LOOP_WS): a/b DRAM in rs1 packed 32+32 is too small
            // for byte offsets; we allow 40-bit offsets: rs1 = a (40) |
            // c_stride<<40; rs2 = b (40) | has_d<<40 ... to keep fields
            // honest we use three words in total: config, addrs1, addrs2.
            let w_cfg = Word { funct: funct::LOOP_WS_CONFIG, rs1, rs2 };
            let w_a = Word {
                funct: funct::LOOP_WS,
                rs1: a_dram,
                rs2: b_dram,
            };
            // Third word reuses LOOP_WS funct with a tag bit in rs2's top
            // bit? Keep it simple and honest: word 3 carries c/d + c_stride
            // under LOOP_WS_CONFIG with rs1 top bit set as a phase tag.
            let w_c = Word {
                funct: funct::LOOP_WS_CONFIG,
                rs1: (1 << 63) | (c_stride as u64),
                rs2: c_dram | ((d_dram.is_some() as u64) << 62),
            };
            let mut ws = vec![w_cfg, w_a, w_c];
            if let Some(d) = d_dram {
                ws.push(Word { funct: funct::LOOP_WS_CONFIG, rs1: (1 << 63) | (1 << 62), rs2: d });
            }
            ws
        }
        Instr::Fence => vec![Word { funct: funct::FENCE, rs1: 0, rs2: 0 }],
        Instr::Flush => vec![Word { funct: funct::FLUSH, rs1: 0, rs2: 0 }],
        // The vector family owns its own packing (disjoint funct range).
        Instr::VcfgReq { .. }
        | Instr::VldBias { .. }
        | Instr::VmacStrip { .. }
        | Instr::VstOut { .. } => super::vector_encode::encode_vector(i)
            .expect("vector-family variants always encode"),
    }
}

/// Decode a word stream back into instructions.
pub fn decode(words: &[Word]) -> Result<Vec<Instr>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < words.len() {
        let w = words[i];
        i += 1;
        let instr = match w.funct {
            funct::CONFIG_EX => Instr::ConfigEx {
                dataflow: if w.rs1 & 1 == 0 {
                    Dataflow::WeightStationary
                } else {
                    Dataflow::OutputStationary
                },
            },
            funct::CONFIG_LD => Instr::ConfigLd { stride: w.rs1 as u32 },
            funct::CONFIG_ST => {
                let stride = (w.rs1 & 0xFFFF_FFFF) as u32;
                let tag = (w.rs1 >> 32) & 0b11;
                let lo = ((w.rs1 >> 34) & 0xFF) as u8 as i8;
                let hi = ((w.rs1 >> 42) & 0xFF) as u8 as i8;
                let act = match tag {
                    0 => Activation::None,
                    1 => Activation::Relu,
                    2 => Activation::Clip { lo, hi },
                    t => bail!("bad activation tag {t}"),
                };
                Instr::ConfigSt { stride, scale: f32::from_bits(w.rs2 as u32), act }
            }
            funct::MVIN | funct::MVOUT => {
                let local = unpack_local(w.rs2 & 0xFFFF_FFFF)?
                    .ok_or_else(|| anyhow::anyhow!("garbage local addr in mvin/mvout"))?;
                let (rows, cols) = unpack_dims(w.rs2 >> 32);
                if w.funct == funct::MVIN {
                    Instr::Mvin { dram: w.rs1, local, rows, cols }
                } else {
                    Instr::Mvout { dram: w.rs1, local, rows, cols }
                }
            }
            funct::MVOUT_SPAD => {
                let src = unpack_local(w.rs1 & 0xFFFF_FFFF)?
                    .ok_or_else(|| anyhow::anyhow!("garbage mvout_spad src"))?;
                let (rows, cols) = unpack_dims(w.rs1 >> 32);
                let dst = unpack_local(w.rs2)?
                    .ok_or_else(|| anyhow::anyhow!("garbage mvout_spad dst"))?;
                Instr::MvoutSpad { src, dst, rows, cols }
            }
            funct::PRELOAD => {
                let local = unpack_local(w.rs1 & 0xFFFF_FFFF)?;
                let (rows, cols) = unpack_dims(w.rs1 >> 32);
                let dst = unpack_local(w.rs2)?
                    .ok_or_else(|| anyhow::anyhow!("garbage preload dst"))?;
                Instr::Preload { local, dst, rows, cols }
            }
            funct::COMPUTE_PRELOADED | funct::COMPUTE_ACCUMULATED => {
                let a = unpack_local(w.rs1 & 0xFFFF_FFFF)?
                    .ok_or_else(|| anyhow::anyhow!("garbage compute a"))?;
                let (rows, cols) = unpack_dims(w.rs1 >> 32);
                let d = unpack_local(w.rs2)?;
                Instr::Compute {
                    a,
                    d,
                    rows,
                    cols,
                    preloaded: w.funct == funct::COMPUTE_PRELOADED,
                }
            }
            funct::LOOP_WS_CONFIG => {
                // Must be the first of the LOOP_WS group.
                if w.rs1 >> 63 != 0 {
                    bail!("orphan LOOP_WS continuation word");
                }
                let m = (w.rs1 & 0x1F_FFFF) as u32;
                let n = ((w.rs1 >> 21) & 0x1F_FFFF) as u32;
                let k = ((w.rs1 >> 42) & 0x1F_FFFF) as u32;
                let a_stride = (w.rs2 & 0xFFFF_FFFF) as u32;
                let b_stride = (w.rs2 >> 32) as u32;
                let Some(w_a) = words.get(i) else { bail!("truncated LOOP_WS") };
                let Some(w_c) = words.get(i + 1) else { bail!("truncated LOOP_WS") };
                i += 2;
                if w_a.funct != funct::LOOP_WS || w_c.funct != funct::LOOP_WS_CONFIG {
                    bail!("malformed LOOP_WS sequence");
                }
                let c_stride = (w_c.rs1 & 0xFFFF_FFFF) as u32;
                let has_d = (w_c.rs2 >> 62) & 1 == 1;
                let c_dram = w_c.rs2 & 0x3FFF_FFFF_FFFF_FFFF;
                let d_dram = if has_d {
                    let Some(w_d) = words.get(i) else { bail!("truncated LOOP_WS d") };
                    i += 1;
                    Some(w_d.rs2)
                } else {
                    None
                };
                Instr::LoopWs {
                    a_dram: w_a.rs1,
                    b_dram: w_a.rs2,
                    c_dram,
                    d_dram,
                    m,
                    n,
                    k,
                    a_stride,
                    b_stride,
                    c_stride,
                }
            }
            funct::LOOP_WS => bail!("LOOP_WS word without preceding config"),
            funct::FENCE => Instr::Fence,
            funct::FLUSH => Instr::Flush,
            f if super::vector_encode::is_vector_funct(f) => {
                let (instr, used) = super::vector_encode::decode_one(&words[i - 1..])?;
                i += used - 1;
                instr
            }
            f => bail!("unknown funct {f}"),
        };
        out.push(instr);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn random_instr(rng: &mut Rng) -> Instr {
        let local = |rng: &mut Rng| {
            let row = rng.below(1 << 20) as u32;
            match rng.below(3) {
                0 => LocalAddr::spad(row),
                1 => LocalAddr::acc(row),
                _ => LocalAddr::acc_accumulate(row),
            }
        };
        match rng.below(14) {
            0 => Instr::ConfigEx {
                dataflow: if rng.chance(0.5) {
                    Dataflow::WeightStationary
                } else {
                    Dataflow::OutputStationary
                },
            },
            1 => Instr::ConfigLd { stride: rng.below(1 << 30) as u32 },
            2 => Instr::ConfigSt {
                stride: rng.below(1 << 30) as u32,
                scale: rng.f64() as f32,
                act: match rng.below(3) {
                    0 => Activation::None,
                    1 => Activation::Relu,
                    _ => Activation::Clip { lo: rng.i8(), hi: rng.i8() },
                },
            },
            3 => Instr::Mvin {
                dram: rng.below(1 << 40),
                local: local(rng),
                rows: rng.below(1 << 12) as u16,
                cols: rng.below(1 << 12) as u16,
            },
            4 => Instr::Mvout {
                dram: rng.below(1 << 40),
                local: local(rng),
                rows: rng.below(1 << 12) as u16,
                cols: rng.below(1 << 12) as u16,
            },
            5 => Instr::Preload {
                local: if rng.chance(0.8) { Some(local(rng)) } else { None },
                dst: local(rng),
                rows: rng.below(1 << 12) as u16,
                cols: rng.below(1 << 12) as u16,
            },
            6 => Instr::Compute {
                a: local(rng),
                d: if rng.chance(0.3) { Some(local(rng)) } else { None },
                rows: rng.below(1 << 12) as u16,
                cols: rng.below(1 << 12) as u16,
                preloaded: rng.chance(0.5),
            },
            9 => Instr::MvoutSpad {
                src: local(rng),
                dst: local(rng),
                rows: rng.below(1 << 12) as u16,
                cols: rng.below(1 << 12) as u16,
            },
            7 => Instr::LoopWs {
                a_dram: rng.below(1 << 40),
                b_dram: rng.below(1 << 40),
                c_dram: rng.below(1 << 40),
                d_dram: if rng.chance(0.5) { Some(rng.below(1 << 40)) } else { None },
                m: rng.below(1 << 16) as u32,
                n: rng.below(1 << 16) as u32,
                k: rng.below(1 << 16) as u32,
                a_stride: rng.below(1 << 20) as u32,
                b_stride: rng.below(1 << 20) as u32,
                c_stride: rng.below(1 << 20) as u32,
            },
            // Vector-family instructions mix into the same word stream
            // (multi-target programs): decode must stay unambiguous.
            10 => Instr::VcfgReq {
                scale: rng.f64() as f32,
                act: match rng.below(3) {
                    0 => Activation::None,
                    1 => Activation::Relu,
                    _ => Activation::Clip { lo: rng.i8(), hi: rng.i8() },
                },
            },
            11 => Instr::VldBias {
                dram: rng.below(1 << 40),
                len: rng.below(1 << 12) as u16,
            },
            12 => Instr::VmacStrip {
                x_dram: rng.below(1 << 40),
                w_dram: rng.below(1 << 40),
                w_stride: rng.below(1 << 20) as u32,
                n_out: rng.below(1 << 12) as u16,
                n_in: rng.below(1 << 12) as u16,
            },
            13 => Instr::VstOut {
                dram: rng.below(1 << 40),
                len: rng.below(1 << 12) as u16,
            },
            _ => {
                if rng.chance(0.5) {
                    Instr::Fence
                } else {
                    Instr::Flush
                }
            }
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        prop::check("isa roundtrip", 500, |rng| {
            let prog: Vec<Instr> = (0..rng.range(1, 20)).map(|_| random_instr(rng)).collect();
            let words: Vec<Word> = prog.iter().flat_map(|i| encode(i)).collect();
            let back = decode(&words).map_err(|e| e.to_string())?;
            if back.len() != prog.len() {
                return Err(format!("len {} != {}", back.len(), prog.len()));
            }
            for (a, b) in prog.iter().zip(&back) {
                // f32 scale roundtrips bit-exactly; PartialEq is fine here.
                if a != b {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_truncated_loop() {
        let full = encode(&Instr::LoopWs {
            a_dram: 0,
            b_dram: 0,
            c_dram: 0,
            d_dram: None,
            m: 1,
            n: 1,
            k: 1,
            a_stride: 1,
            b_stride: 1,
            c_stride: 1,
        });
        assert!(decode(&full[..1]).is_err());
        assert!(decode(&full[1..]).is_err());
    }

    #[test]
    fn decode_rejects_unknown_funct() {
        assert!(decode(&[Word { funct: 99, rs1: 0, rs2: 0 }]).is_err());
    }
}
