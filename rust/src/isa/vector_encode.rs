//! Binary encoding of the vector-backend instruction family.
//!
//! The vector engine shares the RoCC-style `(funct, rs1, rs2)` command
//! framing with the Gemmini-class family ([`super::encode`]) but owns a
//! disjoint funct range (`0x20..`), so a mixed multi-target word stream
//! decodes unambiguously. [`super::encode::encode`]/[`super::encode::decode`]
//! dispatch into this module for the `V*` variants; the packing itself is
//! defined here, next to the backend that owns it.

use anyhow::{bail, ensure, Result};

use super::encode::Word;
use super::{Activation, Instr};

/// Function codes of the vector family (disjoint from
/// [`super::encode::funct`], which stays below 0x20).
pub mod funct {
    /// Configure requant scale + activation for `VST_OUT`.
    pub const VCFG_REQ: u8 = 0x20;
    /// Load int32 bias words into the vector accumulator file.
    pub const VLD_BIAS: u8 = 0x21;
    /// First word of a `VMAC_STRIP` pair (stride + extents).
    pub const VMAC_STRIP_CFG: u8 = 0x22;
    /// Second word of a `VMAC_STRIP` pair (operand addresses).
    pub const VMAC_STRIP: u8 = 0x23;
    /// Requantize + store the accumulator file to DRAM.
    pub const VST_OUT: u8 = 0x24;
}

/// First funct value of the vector family.
pub const FUNCT_BASE: u8 = funct::VCFG_REQ;

/// Whether `f` is a vector-family funct.
pub fn is_vector_funct(f: u8) -> bool {
    (funct::VCFG_REQ..=funct::VST_OUT).contains(&f)
}

/// Whether `i` is a vector-family instruction.
pub fn is_vector_instr(i: &Instr) -> bool {
    matches!(
        i,
        Instr::VcfgReq { .. }
            | Instr::VldBias { .. }
            | Instr::VmacStrip { .. }
            | Instr::VstOut { .. }
    )
}

fn pack_act(act: Activation) -> u64 {
    // Tag in [1:0], clip bounds in [9:2]/[17:10] (two's complement u8),
    // mirroring the Gemmini CONFIG_ST layout.
    match act {
        Activation::None => 0,
        Activation::Relu => 1,
        Activation::Clip { lo, hi } => 2 | ((lo as u8 as u64) << 2) | ((hi as u8 as u64) << 10),
    }
}

fn unpack_act(v: u64) -> Result<Activation> {
    match v & 0b11 {
        0 => Ok(Activation::None),
        1 => Ok(Activation::Relu),
        2 => Ok(Activation::Clip {
            lo: ((v >> 2) & 0xFF) as u8 as i8,
            hi: ((v >> 10) & 0xFF) as u8 as i8,
        }),
        t => bail!("bad vector activation tag {t}"),
    }
}

/// Encode one vector-family instruction into one or two command words.
/// Errors on non-vector instructions (those belong to [`super::encode`]).
pub fn encode_vector(i: &Instr) -> Result<Vec<Word>> {
    Ok(match *i {
        Instr::VcfgReq { scale, act } => vec![Word {
            funct: funct::VCFG_REQ,
            rs1: pack_act(act),
            rs2: f32::to_bits(scale) as u64,
        }],
        Instr::VldBias { dram, len } => {
            vec![Word { funct: funct::VLD_BIAS, rs1: dram, rs2: len as u64 }]
        }
        Instr::VmacStrip { x_dram, w_dram, w_stride, n_out, n_in } => vec![
            Word {
                funct: funct::VMAC_STRIP_CFG,
                rs1: w_stride as u64 | ((n_out as u64) << 32) | ((n_in as u64) << 48),
                rs2: 0,
            },
            Word { funct: funct::VMAC_STRIP, rs1: x_dram, rs2: w_dram },
        ],
        Instr::VstOut { dram, len } => {
            vec![Word { funct: funct::VST_OUT, rs1: dram, rs2: len as u64 }]
        }
        ref other => bail!("'{}' is not a vector-family instruction", other.mnemonic()),
    })
}

/// Decode one vector-family instruction from the head of `words`,
/// returning it together with the number of words consumed.
pub fn decode_one(words: &[Word]) -> Result<(Instr, usize)> {
    ensure!(!words.is_empty(), "empty vector word stream");
    let w = words[0];
    Ok(match w.funct {
        funct::VCFG_REQ => (
            Instr::VcfgReq { scale: f32::from_bits(w.rs2 as u32), act: unpack_act(w.rs1)? },
            1,
        ),
        funct::VLD_BIAS => (Instr::VldBias { dram: w.rs1, len: w.rs2 as u16 }, 1),
        funct::VMAC_STRIP_CFG => {
            let Some(w_addr) = words.get(1) else { bail!("truncated VMAC_STRIP") };
            if w_addr.funct != funct::VMAC_STRIP {
                bail!("malformed VMAC_STRIP sequence");
            }
            (
                Instr::VmacStrip {
                    x_dram: w_addr.rs1,
                    w_dram: w_addr.rs2,
                    w_stride: (w.rs1 & 0xFFFF_FFFF) as u32,
                    n_out: ((w.rs1 >> 32) & 0xFFFF) as u16,
                    n_in: ((w.rs1 >> 48) & 0xFFFF) as u16,
                },
                2,
            )
        }
        funct::VMAC_STRIP => bail!("VMAC_STRIP word without preceding config"),
        funct::VST_OUT => (Instr::VstOut { dram: w.rs1, len: w.rs2 as u16 }, 1),
        f => bail!("funct {f} is not a vector-family instruction"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn random_vector_instr(rng: &mut Rng) -> Instr {
        match rng.below(4) {
            0 => Instr::VcfgReq {
                scale: rng.f64() as f32,
                act: match rng.below(3) {
                    0 => Activation::None,
                    1 => Activation::Relu,
                    _ => Activation::Clip { lo: rng.i8(), hi: rng.i8() },
                },
            },
            1 => Instr::VldBias { dram: rng.below(1 << 40), len: rng.below(1 << 12) as u16 },
            2 => Instr::VmacStrip {
                x_dram: rng.below(1 << 40),
                w_dram: rng.below(1 << 40),
                w_stride: rng.below(1 << 20) as u32,
                n_out: rng.below(1 << 12) as u16,
                n_in: rng.below(1 << 12) as u16,
            },
            _ => Instr::VstOut { dram: rng.below(1 << 40), len: rng.below(1 << 12) as u16 },
        }
    }

    #[test]
    fn prop_vector_encode_decode_roundtrip() {
        prop::check("vector isa roundtrip", 300, |rng| {
            let i = random_vector_instr(rng);
            let words = encode_vector(&i).map_err(|e| e.to_string())?;
            let (back, used) = decode_one(&words).map_err(|e| e.to_string())?;
            if used != words.len() {
                return Err(format!("consumed {used} of {} words", words.len()));
            }
            if back != i {
                return Err(format!("{back} != {i}"));
            }
            Ok(())
        });
    }

    #[test]
    fn vector_functs_disjoint_from_gemmini() {
        // The Gemmini family stays below the vector FUNCT_BASE so a mixed
        // multi-target word stream decodes unambiguously.
        use crate::isa::encode::funct as g;
        for f in [
            g::CONFIG_EX,
            g::CONFIG_LD,
            g::CONFIG_ST,
            g::MVIN,
            g::MVOUT,
            g::PRELOAD,
            g::COMPUTE_PRELOADED,
            g::COMPUTE_ACCUMULATED,
            g::LOOP_WS,
            g::LOOP_WS_CONFIG,
            g::FENCE,
            g::FLUSH,
            g::MVOUT_SPAD,
        ] {
            assert!(f < FUNCT_BASE, "funct {f} collides with the vector range");
            assert!(!is_vector_funct(f));
        }
    }

    #[test]
    fn rejects_orphan_and_truncated_mac() {
        let full = encode_vector(&Instr::VmacStrip {
            x_dram: 0,
            w_dram: 0,
            w_stride: 8,
            n_out: 4,
            n_in: 8,
        })
        .unwrap();
        assert!(decode_one(&full[..1]).is_err()); // truncated pair
        assert!(decode_one(&full[1..]).is_err()); // orphan addr word
        assert!(encode_vector(&Instr::Fence).is_err());
    }
}
