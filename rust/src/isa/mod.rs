//! Gemmini-class accelerator ISA.
//!
//! The instruction set mirrors the structure of Gemmini's RoCC commands:
//! explicit DMA between DRAM and the software-managed scratchpad /
//! accumulator (`MVIN`/`MVOUT`), systolic-array execution split into
//! `PRELOAD` + `COMPUTE` (weight/output-stationary), configuration
//! instructions, a hardware tiling loop (`LOOP_WS`, the FSM used by
//! Gemmini's optimized C functions), and `FENCE`/`FLUSH`.
//!
//! Encodings are fixed-width `(funct, rs1, rs2)` triples like RoCC custom
//! instructions; field packing is our own (documented per instruction) but
//! width-compatible with a 64-bit ISA. Programs ([`program::Program`]) are
//! what the compiler backend and the baselines emit, and what
//! [`crate::sim`] executes.

pub mod encode;
pub mod program;
pub mod vector_encode;

use std::fmt;

use crate::arch::Dataflow;

/// Which on-chip memory a local address points into.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Space {
    /// Scratchpad (int8 rows of DIM elements).
    Spad,
    /// Accumulator (int32 rows of DIM elements).
    Acc,
}

/// A local (on-chip) address: a row index in the scratchpad or accumulator.
/// `accumulate` selects read-modify-write on accumulator writes (Gemmini's
/// bit 30).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LocalAddr {
    pub space: Space,
    pub row: u32,
    pub accumulate: bool,
}

impl LocalAddr {
    pub fn spad(row: u32) -> LocalAddr {
        LocalAddr { space: Space::Spad, row, accumulate: false }
    }

    pub fn acc(row: u32) -> LocalAddr {
        LocalAddr { space: Space::Acc, row, accumulate: false }
    }

    pub fn acc_accumulate(row: u32) -> LocalAddr {
        LocalAddr { space: Space::Acc, row, accumulate: true }
    }
}

impl fmt::Display for LocalAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let tag = match (self.space, self.accumulate) {
            (Space::Spad, _) => "sp",
            (Space::Acc, false) => "acc",
            (Space::Acc, true) => "acc+",
        };
        write!(f, "{tag}[{}]", self.row)
    }
}

/// Activation applied on `MVOUT` from the accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    None,
    Relu,
    /// Clip to `[lo, hi]` (QNN clip after requantization).
    Clip { lo: i8, hi: i8 },
}

/// One accelerator instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    /// Configure the execution pipeline: dataflow and the systolic-array
    /// input shift (unused in this model but kept for encoding parity).
    ConfigEx { dataflow: Dataflow },
    /// Configure the load pipeline: DRAM row stride in elements for `MVIN`.
    ConfigLd { stride: u32 },
    /// Configure the store pipeline: DRAM row stride for `MVOUT`, the
    /// requantization scale (accumulator int32 → int8) and activation.
    ConfigSt { stride: u32, scale: f32, act: Activation },
    /// DMA DRAM → scratchpad/accumulator: a `rows × cols` tile
    /// (`cols ≤ DIM`). `dram` is a byte offset into simulator main memory.
    Mvin { dram: u64, local: LocalAddr, rows: u16, cols: u16 },
    /// DMA accumulator/scratchpad → DRAM, applying the configured
    /// requantization when reading int32 accumulator rows.
    Mvout { dram: u64, local: LocalAddr, rows: u16, cols: u16 },
    /// On-chip store: requantize an int32 accumulator tile (with the
    /// configured scale/activation, exactly like `MVOUT`) into int8
    /// scratchpad rows without touching DRAM. This is the cross-layer
    /// residency primitive: a producer layer parks its activation where
    /// the consumer's input tile would live, eliding the DRAM
    /// store + reload pair a layer boundary otherwise pays.
    MvoutSpad { src: LocalAddr, dst: LocalAddr, rows: u16, cols: u16 },
    /// Load a `rows × cols` tile into the PE array's stationary registers
    /// (the weight tile under WS), and name the destination accumulator
    /// tile of the following computes. `local = None` preloads zeros.
    Preload { local: Option<LocalAddr>, dst: LocalAddr, rows: u16, cols: u16 },
    /// Fire the systolic array on a `rows × cols_a` input tile at `a`
    /// (scratchpad), optionally adding bias tile `d`. `preloaded = true`
    /// uses the tile loaded by the last `Preload`
    /// (`COMPUTE_PRELOADED`); `false` re-uses the resident tile
    /// (`COMPUTE_ACCUMULATED`).
    Compute { a: LocalAddr, d: Option<LocalAddr>, rows: u16, cols: u16, preloaded: bool },
    /// Hardware tiling loop (Gemmini's `LOOP_WS` FSM): expands into a
    /// double-buffered mvin/preload/compute/mvout sequence over a
    /// `(ti × tj × tk)` grid of DIM-sized tiles of
    /// `O[m×n] (+)= A[m×k]·B[k×n]`; a single RoCC issue covers the whole
    /// loop nest. Strides are DRAM row strides in elements.
    LoopWs {
        a_dram: u64,
        b_dram: u64,
        c_dram: u64,
        /// Optional bias, added on the first k-tile.
        d_dram: Option<u64>,
        m: u32,
        n: u32,
        k: u32,
        a_stride: u32,
        b_stride: u32,
        c_stride: u32,
    },
    /// Wait until all in-flight accelerator work has drained.
    Fence,
    /// Flush the PE array's stationary state.
    Flush,
    /// Vector backend: configure the requantization scale and activation
    /// applied by `VST_OUT`.
    VcfgReq { scale: f32, act: Activation },
    /// Vector backend: load `len` int32 bias words from DRAM into the
    /// vector accumulator file starting at element 0.
    VldBias { dram: u64, len: u16 },
    /// Vector backend: strip-mined multiply-accumulate over a weight
    /// column block: `acc[o] += Σ_{c<n_in} x[x_dram+c] · w[w_dram +
    /// c·w_stride + o]` for `o < n_out`. Operands stream from DRAM (the
    /// vector engine has no software-managed scratchpad); weights are in
    /// the shared accelerator `[C,K]` layout with row stride `w_stride`.
    VmacStrip { x_dram: u64, w_dram: u64, w_stride: u32, n_out: u16, n_in: u16 },
    /// Vector backend: requantize `acc[0..len]` with the configured
    /// scale/activation and store to DRAM as int8.
    VstOut { dram: u64, len: u16 },
}

impl Instr {
    /// Mnemonic for disassembly and metrics bucketing.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::ConfigEx { .. } => "config_ex",
            Instr::ConfigLd { .. } => "config_ld",
            Instr::ConfigSt { .. } => "config_st",
            Instr::Mvin { .. } => "mvin",
            Instr::Mvout { .. } => "mvout",
            Instr::MvoutSpad { .. } => "mvout_spad",
            Instr::Preload { .. } => "preload",
            Instr::Compute { preloaded: true, .. } => "compute_preloaded",
            Instr::Compute { preloaded: false, .. } => "compute_accumulated",
            Instr::LoopWs { .. } => "loop_ws",
            Instr::Fence => "fence",
            Instr::Flush => "flush",
            Instr::VcfgReq { .. } => "vcfg_req",
            Instr::VldBias { .. } => "vld_bias",
            Instr::VmacStrip { .. } => "vmac_strip",
            Instr::VstOut { .. } => "vst_out",
        }
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Instr::ConfigEx { dataflow } => write!(f, "config_ex df={dataflow}"),
            Instr::ConfigLd { stride } => write!(f, "config_ld stride={stride}"),
            Instr::ConfigSt { stride, scale, act } => {
                write!(f, "config_st stride={stride} scale={scale:.6} act={act:?}")
            }
            Instr::Mvin { dram, local, rows, cols } => {
                write!(f, "mvin dram+{dram:#x} -> {local} {rows}x{cols}")
            }
            Instr::Mvout { dram, local, rows, cols } => {
                write!(f, "mvout {local} -> dram+{dram:#x} {rows}x{cols}")
            }
            Instr::MvoutSpad { src, dst, rows, cols } => {
                write!(f, "mvout_spad {src} -> {dst} {rows}x{cols}")
            }
            Instr::Preload { local, dst, rows, cols } => match local {
                Some(l) => write!(f, "preload {l} dst={dst} {rows}x{cols}"),
                None => write!(f, "preload <zeros> dst={dst} {rows}x{cols}"),
            },
            Instr::Compute { a, d, rows, cols, preloaded } => {
                let kind = if *preloaded { "preloaded" } else { "accumulated" };
                match d {
                    Some(d) => write!(f, "compute.{kind} a={a} d={d} {rows}x{cols}"),
                    None => write!(f, "compute.{kind} a={a} {rows}x{cols}"),
                }
            }
            Instr::LoopWs { m, n, k, .. } => write!(f, "loop_ws {m}x{n}x{k}"),
            Instr::Fence => write!(f, "fence"),
            Instr::Flush => write!(f, "flush"),
            Instr::VcfgReq { scale, act } => {
                write!(f, "vcfg_req scale={scale:.6} act={act:?}")
            }
            Instr::VldBias { dram, len } => {
                write!(f, "vld_bias dram+{dram:#x} -> vacc[0..{len}]")
            }
            Instr::VmacStrip { x_dram, w_dram, w_stride, n_out, n_in } => {
                write!(
                    f,
                    "vmac_strip x=dram+{x_dram:#x} w=dram+{w_dram:#x} stride={w_stride} {n_out}x{n_in}"
                )
            }
            Instr::VstOut { dram, len } => {
                write!(f, "vst_out vacc[0..{len}] -> dram+{dram:#x}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_addr_display() {
        assert_eq!(LocalAddr::spad(3).to_string(), "sp[3]");
        assert_eq!(LocalAddr::acc(7).to_string(), "acc[7]");
        assert_eq!(LocalAddr::acc_accumulate(7).to_string(), "acc+[7]");
    }

    #[test]
    fn mnemonics() {
        assert_eq!(Instr::Fence.mnemonic(), "fence");
        let c = Instr::Compute {
            a: LocalAddr::spad(0),
            d: None,
            rows: 16,
            cols: 16,
            preloaded: true,
        };
        assert_eq!(c.mnemonic(), "compute_preloaded");
    }
}
