//! Deployable programs: a DRAM layout plus an ordered list of accelerator
//! instructions and host-CPU operations.
//!
//! Host ops model the code that runs on the general-purpose core paired
//! with the accelerator (paper §1: accelerators "are typically paired with
//! general-purpose processors that manage unsupported tasks"). In the naive
//! BYOC baseline these include runtime tensor preprocessing — the source of
//! Table 2's slowdown; in the proposed flow constant-related preprocessing
//! is folded at compile time and never appears here.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, Result};

use super::Instr;

/// A named region of simulator DRAM.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub name: String,
    pub offset: u64,
    pub bytes: u64,
}

/// DRAM layout: bump-allocated named regions.
#[derive(Debug, Clone, Default)]
pub struct DramLayout {
    regions: Vec<Region>,
    by_name: BTreeMap<String, usize>,
    next: u64,
}

impl DramLayout {
    pub fn new() -> DramLayout {
        DramLayout::default()
    }

    /// Allocate `bytes` (16-byte aligned) under `name`; names are unique.
    pub fn alloc(&mut self, name: impl Into<String>, bytes: u64) -> Result<&Region> {
        let name = name.into();
        if self.by_name.contains_key(&name) {
            return Err(anyhow!("duplicate DRAM region '{name}'"));
        }
        let offset = (self.next + 15) & !15;
        self.next = offset + bytes;
        self.by_name.insert(name.clone(), self.regions.len());
        self.regions.push(Region { name, offset, bytes });
        Ok(self.regions.last().unwrap())
    }

    pub fn get(&self, name: &str) -> Result<&Region> {
        self.by_name
            .get(name)
            .map(|&i| &self.regions[i])
            .ok_or_else(|| anyhow!("unknown DRAM region '{name}'"))
    }

    pub fn total_bytes(&self) -> u64 {
        self.next
    }

    pub fn regions(&self) -> &[Region] {
        &self.regions
    }
}

/// An operation executed by the host CPU over DRAM regions. Offsets are
/// absolute DRAM byte offsets; shapes are in elements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HostOp {
    /// `dst[j][i] = src[i][j]` over int8 matrices.
    TransposeI8 { src: u64, dst: u64, rows: usize, cols: usize },
    /// Quantize float32 → int8: `dst[i] = clamp(round(src[i] / scale))`.
    QuantizeF32 { src: u64, dst: u64, n: usize, scale: f32 },
    /// Dequantize int8 → float32: `dst[i] = src[i] * scale`.
    DequantizeI8 { src: u64, dst: u64, n: usize, scale: f32 },
    /// Requantize int32 → int8 with saturation:
    /// `dst[i] = clamp(round(src[i] * scale))`.
    RequantizeI32 { src: u64, dst: u64, n: usize, scale: f32 },
    /// Widen int8 → int32 (e.g. staging a bias or a host-side matmul input).
    WidenI8ToI32 { src: u64, dst: u64, n: usize },
    /// Plain byte copy.
    Memcpy { src: u64, dst: u64, bytes: usize },
    /// Elementwise int32 add: `dst[i] = a[i] + b[i]`.
    AddI32 { a: u64, b: u64, dst: u64, n: usize },
    /// Broadcast bias add over rows: `dst[i][j] = x[i][j] + bias[j]`
    /// (int32, `n` rows of `k`).
    BiasAddI32 { x: u64, bias: u64, dst: u64, n: usize, k: usize },
    /// Host-side int8 GEMM with int32 accumulation (fallback path for ops
    /// the accelerator does not support): `c[nxk] = a[nxc] · b[cxk]`.
    MatmulI8 { a: u64, b: u64, c: u64, n: usize, c_dim: usize, k: usize },
    /// Elementwise clip of int8 to `[lo, hi]`.
    ClipI8 { buf: u64, n: usize, lo: i8, hi: i8 },
    /// im2col expansion on the host (runtime preprocessing of a
    /// non-constant conv activation): NHWC int8 → `[N·OH·OW, kh·kw·C]`.
    Im2col {
        src: u64,
        dst: u64,
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
}

impl HostOp {
    /// Number of scalar elements this op touches with ALU work.
    pub fn alu_elems(&self) -> u64 {
        match *self {
            HostOp::TransposeI8 { .. } | HostOp::Memcpy { .. } | HostOp::WidenI8ToI32 { .. } => 0,
            HostOp::QuantizeF32 { n, .. }
            | HostOp::DequantizeI8 { n, .. }
            | HostOp::RequantizeI32 { n, .. }
            | HostOp::AddI32 { n, .. }
            | HostOp::ClipI8 { n, .. } => n as u64,
            HostOp::BiasAddI32 { n, k, .. } => (n * k) as u64,
            HostOp::MatmulI8 { n, c_dim, k, .. } => (n * c_dim * k) as u64,
            HostOp::Im2col { .. } => 0,
        }
    }

    /// Number of elements moved through the host load/store path.
    pub fn moved_elems(&self) -> u64 {
        match *self {
            HostOp::TransposeI8 { rows, cols, .. } => (rows * cols) as u64,
            HostOp::QuantizeF32 { n, .. }
            | HostOp::DequantizeI8 { n, .. }
            | HostOp::RequantizeI32 { n, .. }
            | HostOp::WidenI8ToI32 { n, .. }
            | HostOp::ClipI8 { n, .. } => n as u64,
            HostOp::Memcpy { bytes, .. } => bytes as u64,
            HostOp::AddI32 { n, .. } => 2 * n as u64,
            HostOp::BiasAddI32 { n, k, .. } => (2 * n * k) as u64,
            HostOp::MatmulI8 { n, c_dim, k, .. } => (n * c_dim + c_dim * k + n * k) as u64,
            HostOp::Im2col { n, h, w, c, kh, kw, stride, pad, .. } => {
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (w + 2 * pad - kw) / stride + 1;
                (n * oh * ow * kh * kw * c) as u64
            }
        }
    }

    pub fn mnemonic(&self) -> &'static str {
        match self {
            HostOp::TransposeI8 { .. } => "host.transpose",
            HostOp::QuantizeF32 { .. } => "host.quantize",
            HostOp::DequantizeI8 { .. } => "host.dequantize",
            HostOp::RequantizeI32 { .. } => "host.requantize",
            HostOp::WidenI8ToI32 { .. } => "host.widen",
            HostOp::Memcpy { .. } => "host.memcpy",
            HostOp::AddI32 { .. } => "host.add",
            HostOp::BiasAddI32 { .. } => "host.bias_add",
            HostOp::MatmulI8 { .. } => "host.matmul",
            HostOp::ClipI8 { .. } => "host.clip",
            HostOp::Im2col { .. } => "host.im2col",
        }
    }
}

/// One program item: an accelerator instruction or a host operation.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    Accel(Instr),
    Host(HostOp),
}

/// A complete deployable program.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub name: String,
    pub layout: DramLayout,
    pub items: Vec<Item>,
    /// Initial DRAM image: `(offset, bytes)` blobs staged before the first
    /// run (constant weights/biases, compile-time-folded preprocessing
    /// results).
    pub init: Vec<(u64, Vec<u8>)>,
}

impl Program {
    pub fn new(name: impl Into<String>) -> Program {
        Program {
            name: name.into(),
            layout: DramLayout::new(),
            items: Vec::new(),
            init: Vec::new(),
        }
    }

    /// Record constant data to be staged at `offset` before execution.
    pub fn add_init(&mut self, offset: u64, bytes: Vec<u8>) {
        self.init.push((offset, bytes));
    }

    /// Stage the init image into a DRAM instance.
    pub fn stage(&self, dram: &mut crate::sim::memory::Dram) -> anyhow::Result<()> {
        for (off, bytes) in &self.init {
            let data: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
            dram.write_i8_slice(*off, &data)?;
        }
        Ok(())
    }

    /// A DRAM instance sized for this program's layout, with constants
    /// staged.
    pub fn make_dram(&self) -> anyhow::Result<crate::sim::memory::Dram> {
        let mut d = crate::sim::memory::Dram::new(self.layout.total_bytes() as usize + 64);
        self.stage(&mut d)?;
        Ok(d)
    }

    pub fn push(&mut self, i: Instr) {
        self.items.push(Item::Accel(i));
    }

    pub fn push_host(&mut self, h: HostOp) {
        self.items.push(Item::Host(h));
    }

    /// Count of accelerator instructions (LOOP_WS counts as one: it is a
    /// single issued command).
    pub fn accel_insn_count(&self) -> usize {
        self.items.iter().filter(|i| matches!(i, Item::Accel(_))).count()
    }

    /// Instruction histogram by mnemonic.
    pub fn histogram(&self) -> BTreeMap<&'static str, usize> {
        let mut h = BTreeMap::new();
        for item in &self.items {
            let m = match item {
                Item::Accel(i) => i.mnemonic(),
                Item::Host(hh) => hh.mnemonic(),
            };
            *h.entry(m).or_insert(0) += 1;
        }
        h
    }

    /// Human-readable disassembly.
    pub fn disassemble(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("; program '{}'\n", self.name));
        for r in self.layout.regions() {
            out.push_str(&format!("; region {:<16} +{:#x} {} bytes\n", r.name, r.offset, r.bytes));
        }
        for (i, item) in self.items.iter().enumerate() {
            match item {
                Item::Accel(ins) => out.push_str(&format!("{i:6}: {ins}\n")),
                Item::Host(h) => out.push_str(&format!("{i:6}: {h:?}\n")),
            }
        }
        out
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.disassemble())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::LocalAddr;

    #[test]
    fn layout_alloc_aligns_and_names() {
        let mut l = DramLayout::new();
        let a = l.alloc("a", 3).unwrap().clone();
        let b = l.alloc("b", 10).unwrap().clone();
        assert_eq!(a.offset, 0);
        assert_eq!(b.offset, 16); // aligned past a's 3 bytes
        assert_eq!(l.get("a").unwrap(), &a);
        assert!(l.get("zz").is_err());
        assert!(l.alloc("a", 1).is_err());
        assert_eq!(l.total_bytes(), 26);
    }

    #[test]
    fn histogram_counts() {
        let mut p = Program::new("t");
        p.push(Instr::Fence);
        p.push(Instr::Mvin { dram: 0, local: LocalAddr::spad(0), rows: 1, cols: 1 });
        p.push(Instr::Fence);
        p.push_host(HostOp::Memcpy { src: 0, dst: 0, bytes: 4 });
        let h = p.histogram();
        assert_eq!(h["fence"], 2);
        assert_eq!(h["mvin"], 1);
        assert_eq!(h["host.memcpy"], 1);
        assert_eq!(p.accel_insn_count(), 3);
    }

    #[test]
    fn host_op_cost_elems() {
        let t = HostOp::TransposeI8 { src: 0, dst: 0, rows: 4, cols: 8 };
        assert_eq!(t.alu_elems(), 0);
        assert_eq!(t.moved_elems(), 32);
        let m = HostOp::MatmulI8 { a: 0, b: 0, c: 0, n: 2, c_dim: 3, k: 4 };
        assert_eq!(m.alu_elems(), 24);
    }
}
