//! The two baselines of the paper's evaluation (§4, Table 2):
//!
//! * [`c_toolchain`] — Gemmini's manually implemented C-function-based
//!   toolchain: weights pre-laid-out offline, one hardware `LOOP_WS` tiling
//!   loop per layer ("large GEMM tiling and efficient loop instruction
//!   invocation").
//! * [`naive_byoc`] — a naive UMA/BYOC backend: the generalized operator is
//!   offloaded, but constant folding never runs (runtime weight
//!   dequantize→quantize→transpose on the host) and no scheduling is
//!   performed (single-instruction-tile default schedule, no double
//!   buffering).

pub mod c_toolchain;
pub mod naive_byoc;
