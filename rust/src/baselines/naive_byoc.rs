//! Baseline (2): the naive UMA/BYOC backend.
//!
//! This reproduces the configuration the paper measures as
//! "BYOC/UMA Backend": the generalized dense operator *is* offloaded, but
//!
//! * **no constant folding** — the importer's weight chain
//!   (dequantize → quantize → transpose, the constant-related
//!   preprocessing TVM would normally fold) executes on the host at every
//!   inference ("TVM typically disables constant folding for matched
//!   operators after graph partitioning", §4);
//! * **no scheduling** — the default schedule offloads single
//!   instruction-sized tiles with no double buffering, no uneven mapping
//!   and no loop-order optimization.

use anyhow::Result;

use crate::accel::AccelDesc;
use crate::pipeline::{CompileOptions, Compiler, Deployment};
use crate::relay::import::QModel;
use crate::relay::{Graph, GraphBuilder, Op, Tensor, TensorData, TensorType};
use crate::relay::DType;
use crate::scheduler::sweep::SweepOptions;

/// Build the imported graph *with the explicit weight-preprocessing
/// chain*: `const w[K,C] i8 → dequantize → quantize` feeding each QNN
/// dense (this is what a QNN importer materializes when scale parameters
/// ride on the edges). The proposed flow folds the whole chain; the naive
/// flow executes it per inference.
pub fn import_with_weight_chain(m: &QModel) -> Result<Graph> {
    let mut b = GraphBuilder::new();
    let mut cur = b.input("x", TensorType::new(vec![m.batch, m.layers[0].in_dim], DType::I8));
    for (i, l) in m.layers.iter().enumerate() {
        let w = b.constant(
            format!("w{i}"),
            Tensor::new(vec![l.out_dim, l.in_dim], TensorData::I8(l.weight.clone()))?,
        );
        // Importer artifact: weights pass through dequantize/quantize
        // (identity on values, but real runtime work when not folded).
        let wd = b.op(format!("w{i}_dq"), Op::Dequantize { scale: 0.015 }, &[w])?;
        let wq = b.op(format!("w{i}_q"), Op::Quantize { scale: 0.015 }, &[wd])?;
        let bias = b.constant(
            format!("b{i}"),
            Tensor::new(vec![l.out_dim], TensorData::I32(l.bias.clone()))?,
        );
        let d = b.op(format!("dense{i}"), Op::QnnDense, &[cur, wq])?;
        let a = b.op(format!("bias{i}"), Op::BiasAdd, &[d, bias])?;
        let r = b.op(format!("requant{i}"), Op::Requantize { scale: l.requant }, &[a])?;
        cur = match l.act {
            0 => r,
            1 => b.op(format!("relu{i}"), Op::Relu, &[r])?,
            _ => b.op(format!("clip{i}"), Op::Clip { lo: l.lo, hi: l.hi }, &[r])?,
        };
    }
    let g = b.outputs(&[cur]);
    g.validate()?;
    Ok(g)
}

/// Compiler options reproducing the naive BYOC/UMA configuration.
pub fn naive_options() -> CompileOptions {
    CompileOptions {
        use_scheduler: false,
        fold_constants: false,
        profile_candidates: 0,
        schedule_cache: false,
        cross_layer: false,
        sweep: SweepOptions::default(),
    }
}

/// Compile a model with the naive BYOC backend.
pub fn compile_naive(accel: &AccelDesc, model: &QModel) -> Result<Deployment> {
    let graph = import_with_weight_chain(model)?;
    Compiler::with_options(accel.clone(), naive_options()).compile(&graph)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::baselines::c_toolchain::compile_c_toolchain;
    use crate::relay::eval::eval;
    use crate::relay::import::from_quantized;
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::sim::Simulator;
    use crate::util::prng::Rng;

    fn model(rng: &mut Rng, dims: &[usize], batch: usize) -> QModel {
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.4).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i + 2 < dims.len(),
            })
            .collect();
        let scales: Vec<f32> = (0..=layers.len()).map(|i| 0.02 + 0.01 * i as f32).collect();
        from_quantized(batch, scales[0], &quantize_mlp(&layers, &scales).unwrap())
    }

    #[test]
    fn naive_correct_but_with_runtime_preprocessing() {
        let mut rng = Rng::new(66);
        let m = model(&mut rng, &[32, 32, 16], 4);
        let accel = gemmini_desc().unwrap();
        let dep = compile_naive(&accel, &m).unwrap();
        let sim = Simulator::new(&accel.arch);
        let input = rng.i8_vec(4 * 32);
        let (got, rep) = dep.run(&sim, &input).unwrap();

        // Semantics identical to the importer graph (dequant/quant is an
        // exact int8 roundtrip).
        let graph = import_with_weight_chain(&m).unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::relay::Tensor::new(vec![4, 32], TensorData::I8(input)).unwrap(),
        );
        let want = eval(&graph, &inputs).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data);

        // Runtime host preprocessing present (the paper's mechanism).
        assert!(rep.host_cycles > 0);
        let h = &rep.insn_counts;
        assert!(h.contains_key("host.transpose"));
        assert!(h.contains_key("host.dequantize"));
        assert!(h.contains_key("host.quantize"));
    }

    #[test]
    fn ordering_naive_slowest_c_toolchain_fast() {
        // The Table 2 ordering on a mid-sized layer stack.
        let mut rng = Rng::new(67);
        let m = model(&mut rng, &[64, 64], 16);
        let accel = gemmini_desc().unwrap();
        let sim = Simulator::new(&accel.arch);
        let input = rng.i8_vec(16 * 64);

        let naive = compile_naive(&accel, &m).unwrap();
        let (out_n, rep_n) = naive.run(&sim, &input).unwrap();
        let ct = compile_c_toolchain(&accel, &m).unwrap();
        let (out_c, rep_c) = ct.run(&sim, &input).unwrap();
        let proposed = crate::pipeline::Compiler::new(accel.clone())
            .compile(&import_with_weight_chain(&m).unwrap())
            .unwrap();
        let (out_p, rep_p) = proposed.run(&sim, &input).unwrap();

        // All three functionally identical.
        assert_eq!(out_n, out_c);
        assert_eq!(out_n, out_p);
        // Performance ordering: naive ≫ {proposed, c-toolchain}.
        assert!(
            rep_n.cycles > 2 * rep_p.cycles,
            "naive {} vs proposed {}",
            rep_n.cycles,
            rep_p.cycles
        );
        assert!(rep_n.cycles > rep_c.cycles);
    }
}
