//! Baseline (1): the Gemmini C-function toolchain.
//!
//! Gemmini ships a hand-written C library (`tiled_matmul_auto`) that the
//! paper uses as its performance reference: weights are laid out offline
//! (compile-time, like the generated `.h` files), and each dense layer is
//! executed by the hardware tiling FSM via a single `LOOP_WS` command with
//! the requantization configured on the store pipeline.

use anyhow::{ensure, Result};

use crate::accel::AccelDesc;
use crate::isa::program::Program;
use crate::isa::{Activation, Instr};
use crate::pipeline::Deployment;
use crate::relay::import::{to_qnn_graph, QModel};

/// Compile a quantized MLP with the C-toolchain strategy.
pub fn compile_c_toolchain(accel: &AccelDesc, model: &QModel) -> Result<Deployment> {
    ensure!(!model.layers.is_empty(), "empty model");
    let mut prog = Program::new(format!("{}_c_toolchain", accel.name));

    // DRAM image: activations ping-pong between per-layer regions;
    // weights are stored **pre-transposed** ([C,K]) — the offline layout
    // step the C toolchain does when generating its parameter headers.
    let batch = model.batch;
    let x0 = prog
        .layout
        .alloc("input", (batch * model.layers[0].in_dim) as u64)?
        .offset;
    let mut acts = vec![x0];
    let mut weights = Vec::new();
    let mut biases = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        let w = prog
            .layout
            .alloc(format!("w{i}"), (l.in_dim * l.out_dim) as u64)?
            .offset;
        // Transpose [K,C] -> [C,K] at compile time.
        let mut wt = vec![0u8; l.in_dim * l.out_dim];
        for k in 0..l.out_dim {
            for c in 0..l.in_dim {
                wt[c * l.out_dim + k] = l.weight[k * l.in_dim + c] as u8;
            }
        }
        prog.add_init(w, wt);
        weights.push(w);
        let b = prog.layout.alloc(format!("b{i}"), (l.out_dim * 4) as u64)?.offset;
        prog.add_init(b, l.bias.iter().flat_map(|v| v.to_le_bytes()).collect());
        biases.push(b);
        let o = prog
            .layout
            .alloc(format!("act{}", i + 1), (batch * l.out_dim) as u64)?
            .offset;
        acts.push(o);
    }

    // tiled_matmul_auto: partition M×N into chunks whose A/B panels fit
    // the scratchpad (K stays whole so each output chunk accumulates fully
    // on chip), then hand each chunk to the LOOP_WS FSM.
    let dim = accel.arch.pe_dim;
    let spad_rows = accel
        .arch
        .levels
        .iter()
        .find(|l| l.name == "Scratchpad")
        .expect("validated arch")
        .size_bytes
        / dim;
    for (i, l) in model.layers.iter().enumerate() {
        let act = match l.act {
            0 => Activation::None,
            1 => Activation::Relu,
            _ => Activation::Clip { lo: l.lo, hi: l.hi },
        };
        prog.push(Instr::ConfigSt { stride: l.out_dim as u32, scale: l.requant, act });

        let (m, n, k) = (batch, l.out_dim, l.in_dim);
        let tk = crate::util::ceil_div(k, dim);
        let budget = spad_rows / (tk * dim);
        ensure!(
            budget >= 2,
            "layer {i}: reduction {k} too deep for scratchpad-resident panels"
        );
        let tm_full = crate::util::ceil_div(m, dim);
        let tn_full = crate::util::ceil_div(n, dim);
        let ti = tm_full.min(budget / 2).max(1);
        let tj = tn_full.min(budget - ti).max(1);
        let (chunk_m, chunk_n) = (ti * dim, tj * dim);

        let mut m_off = 0;
        while m_off < m {
            let mc = chunk_m.min(m - m_off);
            let mut n_off = 0;
            while n_off < n {
                let nc = chunk_n.min(n - n_off);
                prog.push(Instr::LoopWs {
                    a_dram: acts[i] + (m_off * k) as u64,
                    b_dram: weights[i] + n_off as u64,
                    c_dram: acts[i + 1] + (m_off * n + n_off) as u64,
                    d_dram: Some(biases[i] + 4 * n_off as u64),
                    m: mc as u32,
                    n: nc as u32,
                    k: k as u32,
                    a_stride: k as u32,
                    b_stride: n as u32,
                    c_stride: n as u32,
                });
                n_off += chunk_n;
            }
            m_off += chunk_m;
        }
        // The C library fences between layers (gemmini_fence()).
        prog.push(Instr::Fence);
    }

    let out_elems = batch * model.layers.last().unwrap().out_dim;
    Ok(Deployment {
        input_offset: x0,
        input_elems: batch * model.layers[0].in_dim,
        output_offset: *acts.last().unwrap(),
        output_elems: out_elems,
        program: prog,
        graph: to_qnn_graph(model)?,
        chosen: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gemmini::gemmini_desc;
    use crate::relay::eval::eval;
    use crate::relay::import::from_quantized;
    use crate::relay::quantize::{quantize_mlp, FloatDense};
    use crate::relay::{Tensor, TensorData};
    use crate::sim::Simulator;
    use crate::util::prng::Rng;

    #[test]
    fn c_toolchain_matches_graph_semantics() {
        let mut rng = Rng::new(55);
        let dims = [24usize, 32, 8];
        let layers: Vec<FloatDense> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FloatDense {
                weight: (0..w[0] * w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.4).collect(),
                bias: (0..w[1]).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect(),
                in_dim: w[0],
                out_dim: w[1],
                relu: i == 0,
            })
            .collect();
        let q = quantize_mlp(&layers, &[0.03, 0.05, 0.07]).unwrap();
        let model = from_quantized(2, 0.03, &q);

        let accel = gemmini_desc().unwrap();
        let dep = compile_c_toolchain(&accel, &model).unwrap();
        let sim = Simulator::new(&accel.arch);
        let input = rng.i8_vec(2 * dims[0]);
        let (got, rep) = dep.run(&sim, &input).unwrap();

        let graph = to_qnn_graph(&model).unwrap();
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![2, dims[0]], TensorData::I8(input)).unwrap(),
        );
        let want = eval(&graph, &m).unwrap();
        assert_eq!(TensorData::I8(got), want[0].data);
        // Few issued commands: config + loop_ws chunk(s) + fence per layer.
        assert!(rep.issued_commands <= 4 * 2, "got {}", rep.issued_commands);
        assert_eq!(rep.host_cycles, 0);
    }
}
