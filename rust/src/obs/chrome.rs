//! Chrome-trace-event JSON writer (Perfetto / `chrome://tracing`
//! loadable).
//!
//! Emits the JSON-object trace format: `{"traceEvents":[...]}` with
//! `ph:"X"` complete events (microsecond `ts`/`dur`), `ph:"i"` instants,
//! and `ph:"M"` metadata records naming processes and threads. The
//! profiler maps compile spans onto one process and each execution
//! target segment onto its own process with DMA / compute / store / host
//! threads; simulated cycles are rendered as 1 cycle = 1 µs so the
//! timeline is legible regardless of clock frequency.

/// One trace event, held structured until [`ChromeTrace::render`] so
/// tests can assert on the schema without parsing JSON.
#[derive(Debug, Clone)]
pub enum Event {
    /// `ph:"M"` metadata: names a process (`what == "process_name"`) or
    /// thread (`what == "thread_name"`).
    Meta {
        /// Process id.
        pid: u64,
        /// Thread id.
        tid: u64,
        /// `"process_name"` or `"thread_name"`.
        what: &'static str,
        /// The display name.
        name: String,
    },
    /// `ph:"X"` complete event: one slice on a track.
    Complete {
        /// Process id (track group).
        pid: u64,
        /// Thread id (track).
        tid: u64,
        /// Slice name.
        name: String,
        /// Start, microseconds.
        ts_us: f64,
        /// Duration, microseconds.
        dur_us: f64,
        /// Extra `args` key/values.
        args: Vec<(String, String)>,
    },
    /// `ph:"i"` instant event (thread-scoped).
    Instant {
        /// Process id.
        pid: u64,
        /// Thread id.
        tid: u64,
        /// Event name.
        name: String,
        /// Timestamp, microseconds.
        ts_us: f64,
        /// Extra `args` key/values.
        args: Vec<(String, String)>,
    },
}

/// An in-progress Chrome trace: push events, then [`render`][Self::render]
/// to JSON.
#[derive(Debug, Default)]
pub struct ChromeTrace {
    /// Events in emission order.
    pub events: Vec<Event>,
}

impl ChromeTrace {
    /// An empty trace.
    pub fn new() -> ChromeTrace {
        ChromeTrace::default()
    }

    /// Name process `pid`.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.events.push(Event::Meta { pid, tid: 0, what: "process_name", name: name.to_string() });
    }

    /// Name thread `tid` of process `pid`.
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        self.events.push(Event::Meta { pid, tid, what: "thread_name", name: name.to_string() });
    }

    /// Push a complete (`ph:"X"`) slice.
    pub fn complete(
        &mut self,
        pid: u64,
        tid: u64,
        name: &str,
        ts_us: f64,
        dur_us: f64,
        args: Vec<(String, String)>,
    ) {
        self.events.push(Event::Complete { pid, tid, name: name.to_string(), ts_us, dur_us, args });
    }

    /// Push an instant (`ph:"i"`) event.
    pub fn instant(
        &mut self,
        pid: u64,
        tid: u64,
        name: &str,
        ts_us: f64,
        args: Vec<(String, String)>,
    ) {
        self.events.push(Event::Instant { pid, tid, name: name.to_string(), ts_us, args });
    }

    /// Serialize to Chrome trace JSON (`{"traceEvents":[...]}`).
    pub fn render(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            render_event(&mut out, ev);
        }
        out.push_str("],\"displayTimeUnit\":\"ms\"}");
        out
    }
}

fn render_event(out: &mut String, ev: &Event) {
    match ev {
        Event::Meta { pid, tid, what, name } => {
            out.push_str(&format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{what}\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                json_escape(name)
            ));
        }
        Event::Complete { pid, tid, name, ts_us, dur_us, args } => {
            out.push_str(&format!(
                "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{}\",\
                 \"ts\":{},\"dur\":{}",
                json_escape(name),
                json_number(*ts_us),
                json_number(*dur_us)
            ));
            render_args(out, args);
            out.push('}');
        }
        Event::Instant { pid, tid, name, ts_us, args } => {
            out.push_str(&format!(
                "{{\"ph\":\"i\",\"s\":\"t\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{}\",\"ts\":{}",
                json_escape(name),
                json_number(*ts_us)
            ));
            render_args(out, args);
            out.push('}');
        }
    }
}

fn render_args(out: &mut String, args: &[(String, String)]) {
    if args.is_empty() {
        return;
    }
    out.push_str(",\"args\":{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":\"{}\"", json_escape(k), json_escape(v)));
    }
    out.push('}');
}

/// Render an f64 as a JSON number (no NaN/Inf — clamp to 0).
fn json_number(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_trace_events() {
        let mut t = ChromeTrace::new();
        t.process_name(1, "compile");
        t.thread_name(1, 1, "pipeline");
        t.complete(1, 1, "frontend", 0.0, 12.5, vec![("layers".into(), "4".into())]);
        t.instant(1, 1, "cache_hit", 5.0, vec![]);
        let json = t.render();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"M\""));
        let slice = "\"ph\":\"X\",\"pid\":1,\"tid\":1,\"name\":\"frontend\",\"ts\":0,\"dur\":12.5";
        assert!(json.contains(slice));
        assert!(json.contains("\"args\":{\"layers\":\"4\"}"));
        assert!(json.contains("\"ph\":\"i\",\"s\":\"t\""));
    }

    #[test]
    fn escapes_names() {
        let mut t = ChromeTrace::new();
        t.complete(1, 1, "a\"b\\c\nd", 1.0, 2.0, vec![]);
        let json = t.render();
        assert!(json.contains("a\\\"b\\\\c\\nd"));
    }
}
