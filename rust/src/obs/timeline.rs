//! The simulated execution timeline: per-track occupancy slices
//! reconstructed from the cycle-accurate simulator.
//!
//! While the simulator walks a program it can record, for every
//! instruction it prices, *which* hardware resource was busy and for
//! which cycle interval: the shared DMA engine streaming DRAM↔SRAM, the
//! execute queue doing preloads/computes, the store queue draining
//! scratchpad, and the host core running fallback ops. The timing model
//! already guarantees each of these serializes internally (the DMA
//! cursor `dma_busy`, per-queue in-order issue, host ops running after
//! `drained()`), so each track's slices never overlap — which is exactly
//! the shape a Perfetto track wants, and what the schema test asserts.
//!
//! Timestamps are simulated cycles. Recording is optional (the hot
//! simulation paths pass `None`) and purely additive: a profiled run
//! returns the same outputs and `RunReport` as an unprofiled one.

/// Which hardware resource a slice occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Track {
    /// The shared DMA engine (DRAM↔local streams, vector strip streams).
    Dma,
    /// The execute queue (preload / compute / flush / vector MAC).
    Compute,
    /// The store queue (scratchpad-to-scratchpad `mvout_spad` drains).
    Store,
    /// Host-core fallback ops.
    Host,
}

impl Track {
    /// Display name for timeline exports.
    pub fn name(self) -> &'static str {
        match self {
            Track::Dma => "dma",
            Track::Compute => "compute",
            Track::Store => "store",
            Track::Host => "host",
        }
    }
}

/// One occupancy interval on a track, in simulated cycles.
#[derive(Debug, Clone)]
pub struct Slice {
    /// Resource the slice occupies.
    pub track: Track,
    /// Instruction mnemonic (or host-op name).
    pub name: &'static str,
    /// First busy cycle.
    pub start: u64,
    /// One past the last busy cycle.
    pub end: u64,
}

/// The recorded timeline of one simulated program slice.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Slices in issue order (per track this is also start order, since
    /// every track serializes).
    pub slices: Vec<Slice>,
}

impl Timeline {
    /// An empty timeline.
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Record a slice; zero-length intervals are dropped.
    pub fn push(&mut self, track: Track, name: &'static str, start: u64, end: u64) {
        if end > start {
            self.slices.push(Slice { track, name, start, end });
        }
    }

    /// Busy cycles on `track` (sum of slice lengths).
    pub fn busy(&self, track: Track) -> u64 {
        self.slices.iter().filter(|s| s.track == track).map(|s| s.end - s.start).sum()
    }

    /// The slices of one track, in recorded order.
    pub fn track(&self, track: Track) -> Vec<&Slice> {
        self.slices.iter().filter(|s| s.track == track).collect()
    }

    /// Last cycle covered by any slice.
    pub fn horizon(&self) -> u64 {
        self.slices.iter().map(|s| s.end).max().unwrap_or(0)
    }

    /// Shift every slice `offset` cycles later. The simulator records
    /// slice-local timestamps; a multi-target profile shifts each
    /// segment's timeline by its *overlapped-schedule* start cycle so the
    /// exported tracks show true concurrent starts, not serial offsets.
    pub fn shift(&mut self, offset: u64) {
        for s in &mut self.slices {
            s.start += offset;
            s.end += offset;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_drops_empty_slices_and_busy_sums_per_track() {
        let mut tl = Timeline::new();
        tl.push(Track::Dma, "mvin", 0, 10);
        tl.push(Track::Dma, "mvin", 10, 10); // dropped
        tl.push(Track::Compute, "matmul.compute", 4, 20);
        tl.push(Track::Dma, "mvout", 12, 18);
        assert_eq!(tl.slices.len(), 3);
        assert_eq!(tl.busy(Track::Dma), 16);
        assert_eq!(tl.busy(Track::Compute), 16);
        assert_eq!(tl.busy(Track::Host), 0);
        assert_eq!(tl.horizon(), 20);
        assert_eq!(tl.track(Track::Dma).len(), 2);
    }

    #[test]
    fn shift_moves_every_slice_by_the_offset() {
        let mut tl = Timeline::new();
        tl.push(Track::Dma, "mvin", 0, 10);
        tl.push(Track::Host, "host.memcpy", 12, 20);
        tl.shift(100);
        assert_eq!(tl.slices[0].start, 100);
        assert_eq!(tl.slices[0].end, 110);
        assert_eq!(tl.slices[1].start, 112);
        assert_eq!(tl.horizon(), 120);
        assert_eq!(tl.busy(Track::Dma), 10, "shift preserves slice lengths");
    }
}
