//! Hand-rolled Prometheus metrics: counters, gauges, and histograms
//! rendered in the text exposition format.
//!
//! No client library — the whole registry is a `Mutex<Vec<Family>>` of
//! atomics, which is all a single-process compile server needs. The
//! rendered output follows the exposition format rules the conformance
//! test (`tests/obs_format.rs`) checks: one `# HELP` / `# TYPE` pair per
//! family, histogram `_bucket` series with cumulative counts ending in
//! `le="+Inf"`, and `_sum` / `_count` lines agreeing with the buckets.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`s shared
//! between the registry (which renders them) and the instrumented code
//! (which bumps them), so recording a sample is a single atomic op.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge: a value that can go up and down (queue depths, in-flight
/// requests, cache entry counts).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set to an absolute value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Cumulative histogram over fixed bucket bounds.
///
/// The sum is accumulated in integer microseconds so observation stays
/// a pair of atomic adds; `render` divides back to seconds.
pub struct Histogram {
    bounds: Vec<f64>,
    /// One counter per bound plus the `+Inf` bucket.
    buckets: Vec<AtomicU64>,
    sum_micros: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_micros: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation, in seconds.
    pub fn observe(&self, seconds: f64) {
        let idx = self.bounds.iter().position(|&b| seconds <= b).unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let micros = (seconds * 1e6).max(0.0).round() as u64;
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

/// Latency bucket bounds suited to compile-path timings: 100µs to 10s.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0,
];

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Series {
    labels: Vec<(String, String)>,
    metric: Metric,
}

struct Family {
    name: String,
    help: String,
    series: Vec<Series>,
}

/// A metric registry: families in registration order, rendered as
/// Prometheus text exposition.
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Family>> {
        self.families.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut families = self.lock();
        let fam = match families.iter_mut().find(|f| f.name == name) {
            Some(f) => f,
            None => {
                families.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    series: Vec::new(),
                });
                families.last_mut().expect("just pushed")
            }
        };
        if let Some(existing) = fam
            .series
            .iter()
            .find(|s| s.labels.len() == labels.len() && labels_eq(&s.labels, labels))
        {
            return clone_metric(&existing.metric);
        }
        let metric = make();
        fam.series.push(Series {
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            metric: clone_metric(&metric),
        });
        metric
    }

    /// Register (or fetch) an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, &[])
    }

    /// Register (or fetch) a labeled counter series.
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.register(name, help, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    /// Register (or fetch) an unlabeled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        match self.register(name, help, &[], || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    /// Register (or fetch) an unlabeled histogram with the given bucket
    /// bounds.
    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Arc<Histogram> {
        self.histogram_with(name, help, bounds, &[])
    }

    /// Register (or fetch) a labeled histogram series.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        let make = || Metric::Histogram(Arc::new(Histogram::new(bounds)));
        match self.register(name, help, labels, make) {
            Metric::Histogram(h) => h,
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    /// Render the whole registry as Prometheus text exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for fam in self.lock().iter() {
            let kind = fam.series.first().map(|s| s.metric.kind()).unwrap_or("counter");
            out.push_str(&format!("# HELP {} {}\n", fam.name, escape_help(&fam.help)));
            out.push_str(&format!("# TYPE {} {}\n", fam.name, kind));
            for series in &fam.series {
                render_series(&mut out, &fam.name, series);
            }
        }
        out
    }
}

fn labels_eq(a: &[(String, String)], b: &[(&str, &str)]) -> bool {
    a.iter().zip(b.iter()).all(|((ak, av), (bk, bv))| ak == bk && av == bv)
}

fn clone_metric(m: &Metric) -> Metric {
    match m {
        Metric::Counter(c) => Metric::Counter(Arc::clone(c)),
        Metric::Gauge(g) => Metric::Gauge(Arc::clone(g)),
        Metric::Histogram(h) => Metric::Histogram(Arc::clone(h)),
    }
}

fn render_series(out: &mut String, name: &str, series: &Series) {
    let base_labels = render_labels(&series.labels, None);
    match &series.metric {
        Metric::Counter(c) => out.push_str(&format!("{name}{base_labels} {}\n", c.get())),
        Metric::Gauge(g) => out.push_str(&format!("{name}{base_labels} {}\n", g.get())),
        Metric::Histogram(h) => {
            let mut cumulative = 0u64;
            for (i, bound) in h.bounds.iter().enumerate() {
                cumulative += h.buckets[i].load(Ordering::Relaxed);
                let le = format_bound(*bound);
                let labels = render_labels(&series.labels, Some(&le));
                out.push_str(&format!("{name}_bucket{labels} {cumulative}\n"));
            }
            cumulative += h.buckets[h.bounds.len()].load(Ordering::Relaxed);
            let labels = render_labels(&series.labels, Some("+Inf"));
            out.push_str(&format!("{name}_bucket{labels} {cumulative}\n"));
            let sum = h.sum_micros.load(Ordering::Relaxed) as f64 / 1e6;
            out.push_str(&format!("{name}_sum{base_labels} {sum}\n"));
            out.push_str(&format!("{name}_count{base_labels} {}\n", h.count()));
        }
    }
}

fn render_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Render a bucket bound without trailing-zero noise (`0.001`, not
/// `0.001000`), matching how Prometheus clients print `le` values.
fn format_bound(b: f64) -> String {
    let s = format!("{b}");
    if s.contains('.') || s.contains('e') {
        s
    } else {
        format!("{s}.0")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_registration_order() {
        let r = Registry::new();
        let c = r.counter("tvmaccel_requests_total", "Total compile requests.");
        let g = r.gauge("tvmaccel_requests_in_flight", "Requests currently compiling.");
        c.add(3);
        g.set(2);
        g.add(-1);
        let text = r.render();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "# HELP tvmaccel_requests_total Total compile requests.");
        assert_eq!(lines[1], "# TYPE tvmaccel_requests_total counter");
        assert_eq!(lines[2], "tvmaccel_requests_total 3");
        assert_eq!(lines[3], "# HELP tvmaccel_requests_in_flight Requests currently compiling.");
        assert_eq!(lines[4], "# TYPE tvmaccel_requests_in_flight gauge");
        assert_eq!(lines[5], "tvmaccel_requests_in_flight 1");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let r = Registry::new();
        let h = r.histogram(
            "tvmaccel_compile_duration_seconds",
            "Compile latency.",
            &[0.001, 0.01, 0.1],
        );
        h.observe(0.0005);
        h.observe(0.005);
        h.observe(5.0);
        let text = r.render();
        assert!(text.contains("tvmaccel_compile_duration_seconds_bucket{le=\"0.001\"} 1"));
        assert!(text.contains("tvmaccel_compile_duration_seconds_bucket{le=\"0.01\"} 2"));
        assert!(text.contains("tvmaccel_compile_duration_seconds_bucket{le=\"0.1\"} 2"));
        assert!(text.contains("tvmaccel_compile_duration_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("tvmaccel_compile_duration_seconds_count 3"));
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("tvmaccel_compile_duration_seconds_sum"))
            .expect("sum line");
        let sum: f64 = sum_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!((sum - 5.0055).abs() < 1e-6, "sum was {sum}");
    }

    #[test]
    fn labeled_series_share_one_family_header() {
        let r = Registry::new();
        let name = "tvmaccel_stage_duration_seconds";
        let a = r.histogram_with(name, "Stage latency.", &[0.01], &[("stage", "frontend")]);
        let b = r.histogram_with(name, "Stage latency.", &[0.01], &[("stage", "codegen")]);
        a.observe(0.001);
        b.observe(1.0);
        let text = r.render();
        assert_eq!(text.matches("# TYPE tvmaccel_stage_duration_seconds histogram").count(), 1);
        assert!(text
            .contains("tvmaccel_stage_duration_seconds_bucket{stage=\"frontend\",le=\"0.01\"} 1"));
        assert!(text
            .contains("tvmaccel_stage_duration_seconds_bucket{stage=\"codegen\",le=\"+Inf\"} 1"));
        // Re-registering the same series returns the same handle.
        let a2 = r.histogram_with(name, "Stage latency.", &[0.01], &[("stage", "frontend")]);
        assert_eq!(a2.count(), 1);
    }
}
