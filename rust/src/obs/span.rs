//! The trace-span recorder: one monotonic clock, explicit parent/child
//! nesting, zero dependencies.
//!
//! A [`Trace`] is the instrumentation core every observability surface
//! shares: the session's stage reports are a *view* over its spans
//! ([`crate::pipeline::StageReport`] carries the span-derived duration),
//! `tvm-accel bench` derives compile cost from the same spans, and the
//! Chrome-trace exporter ([`super::chrome`]) serializes them for
//! Perfetto. Recording is strictly passive: spans never feed back into
//! cache keys, schedule selection, or codegen — a traced compile is
//! byte-identical to an untraced one (property-tested in
//! `tests/obs_passive.rs`).
//!
//! Timestamps are nanoseconds since the trace's construction (`Instant`
//! epoch, monotonic). Parent/child nesting is explicit: [`Trace::begin`]
//! opens a span under the innermost open span, [`Trace::end`] closes it;
//! [`Trace::record`] and [`Trace::instant`] attach completed spans /
//! point events under the currently open span (this is how the schedule
//! stage's cache-hit / sweep events land inside the `schedule` stage
//! span).

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One recorded span: a named interval with attributes and an optional
/// parent (index into the trace's span list).
#[derive(Debug, Clone)]
pub struct Span {
    /// Span name (stage names, `"sweep"`, `"cache_hit"`, …).
    pub name: &'static str,
    /// Start, in nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// End, in nanoseconds since the trace epoch (== `start_ns` for
    /// instant events, and until the span is closed).
    pub end_ns: u64,
    /// Index of the enclosing span, if any.
    pub parent: Option<usize>,
    /// Key/value attributes (layer names, hit counters, sweep effort).
    pub attrs: Vec<(&'static str, String)>,
}

impl Span {
    /// The span's duration.
    pub fn elapsed(&self) -> Duration {
        Duration::from_nanos(self.end_ns.saturating_sub(self.start_ns))
    }
}

/// Handle to an open span (returned by [`Trace::begin`], consumed by
/// [`Trace::end`]).
#[derive(Debug, Clone, Copy)]
pub struct SpanId(pub(crate) usize);

#[derive(Default)]
struct TraceInner {
    spans: Vec<Span>,
    /// Indices of currently open spans, outermost first.
    open: Vec<usize>,
}

/// A lightweight span recorder. Cheap to create (one `Instant`), safe to
/// share across threads (`Mutex` inside), and purely observational.
pub struct Trace {
    epoch: Instant,
    inner: Mutex<TraceInner>,
}

impl Default for Trace {
    fn default() -> Trace {
        Trace::new()
    }
}

impl Trace {
    /// A fresh trace whose epoch is "now".
    pub fn new() -> Trace {
        Trace { epoch: Instant::now(), inner: Mutex::new(TraceInner::default()) }
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TraceInner> {
        // Span data is plain values; a panic mid-record leaves nothing
        // half-updated worth poisoning over.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Open a span under the innermost open span.
    pub fn begin(&self, name: &'static str) -> SpanId {
        let now = self.now_ns();
        let mut inner = self.lock();
        let parent = inner.open.last().copied();
        let id = inner.spans.len();
        inner.spans.push(Span { name, start_ns: now, end_ns: now, parent, attrs: Vec::new() });
        inner.open.push(id);
        SpanId(id)
    }

    /// Close an open span, attaching `attrs`.
    pub fn end(&self, id: SpanId, attrs: Vec<(&'static str, String)>) {
        let now = self.now_ns();
        let mut inner = self.lock();
        inner.open.retain(|&i| i != id.0);
        if let Some(s) = inner.spans.get_mut(id.0) {
            s.end_ns = now;
            s.attrs.extend(attrs);
        }
    }

    /// Record a completed span that started at `started` and ends now,
    /// nested under the innermost open span (e.g. a schedule sweep inside
    /// the `schedule` stage).
    pub fn record(&self, name: &'static str, started: Instant, attrs: Vec<(&'static str, String)>) {
        let end_ns = self.now_ns();
        let start_ns = started.saturating_duration_since(self.epoch).as_nanos() as u64;
        let mut inner = self.lock();
        let parent = inner.open.last().copied();
        inner.spans.push(Span {
            name,
            start_ns: start_ns.min(end_ns),
            end_ns,
            parent,
            attrs,
        });
    }

    /// Record a zero-duration point event under the innermost open span
    /// (cache hits/misses, memo consults, single-flight elections).
    pub fn instant(&self, name: &'static str, attrs: Vec<(&'static str, String)>) {
        let now = self.now_ns();
        let mut inner = self.lock();
        let parent = inner.open.last().copied();
        inner.spans.push(Span { name, start_ns: now, end_ns: now, parent, attrs });
    }

    /// The duration of span `id` as recorded so far.
    pub fn elapsed_of(&self, id: SpanId) -> Duration {
        self.lock().spans.get(id.0).map(|s| s.elapsed()).unwrap_or_default()
    }

    /// Name and duration of span `id` (the stage-report view over a
    /// span).
    pub fn info_of(&self, id: SpanId) -> Option<(&'static str, Duration)> {
        self.lock().spans.get(id.0).map(|s| (s.name, s.elapsed()))
    }

    /// Snapshot every recorded span, in recording order (parents precede
    /// their children).
    pub fn spans(&self) -> Vec<Span> {
        self.lock().spans.clone()
    }

    /// Total nanoseconds covered by top-level (parentless) spans.
    pub fn root_ns(&self) -> u64 {
        self.lock()
            .spans
            .iter()
            .filter(|s| s.parent.is_none())
            .map(|s| s.end_ns.saturating_sub(s.start_ns))
            .sum()
    }

    /// Spans named `name`, cloned (for tests and report derivation).
    pub fn spans_named(&self, name: &str) -> Vec<Span> {
        self.lock().spans.iter().filter(|s| s.name == name).cloned().collect()
    }
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        f.debug_struct("Trace")
            .field("spans", &inner.spans.len())
            .field("open", &inner.open.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_under_open_parent() {
        let t = Trace::new();
        let outer = t.begin("outer");
        let inner = t.begin("inner");
        t.instant("tick", vec![("n", "1".into())]);
        t.end(inner, vec![]);
        t.end(outer, vec![("layers", "2".into())]);

        let spans = t.spans();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].name, "outer");
        assert_eq!(spans[0].parent, None);
        assert_eq!(spans[1].parent, Some(0), "inner nests under outer");
        assert_eq!(spans[2].parent, Some(1), "instant nests under inner");
        assert!(spans[0].end_ns >= spans[1].end_ns);
        assert_eq!(spans[0].attrs, vec![("layers", "2".to_string())]);
    }

    #[test]
    fn record_backfills_a_completed_interval() {
        let t = Trace::new();
        let stage = t.begin("schedule");
        let started = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        t.record("sweep", started, vec![("leaves", "42".into())]);
        t.end(stage, vec![]);
        let sweeps = t.spans_named("sweep");
        assert_eq!(sweeps.len(), 1);
        assert_eq!(sweeps[0].parent, Some(0));
        assert!(sweeps[0].end_ns > sweeps[0].start_ns, "sweep has real duration");
        assert!(t.elapsed_of(stage) >= sweeps[0].elapsed());
    }

    #[test]
    fn timestamps_are_monotone_in_recording_order() {
        let t = Trace::new();
        for _ in 0..5 {
            let s = t.begin("step");
            t.end(s, vec![]);
        }
        let spans = t.spans();
        for w in spans.windows(2) {
            assert!(w[1].start_ns >= w[0].start_ns);
        }
        assert!(t.root_ns() <= t.now_ns());
    }
}
