//! Unified observability: trace spans, Prometheus metrics, and
//! execution-timeline profiling over one instrumentation core.
//!
//! Three surfaces share the same recorded facts:
//!
//! * [`span`] — a lightweight span recorder ([`Trace`]) every
//!   [`crate::pipeline::CompilerSession`] threads through its seven
//!   stages; `StageReport`s are a view over these spans, and schedule
//!   events (cache hits/misses, memo consults, single-flight elections,
//!   solver sweeps) nest inside the `schedule` stage span.
//! * [`prom`] — a hand-rolled metric [`Registry`] rendered in Prometheus
//!   text exposition format; [`crate::service::CompileServer`] keeps one
//!   and serves it through the line protocol's `metrics` verb
//!   (`tvm-accel metrics --socket …`).
//! * [`chrome`] + [`timeline`] — a Chrome-trace-event/Perfetto JSON
//!   exporter fed by both the compile spans and the simulator's
//!   per-track execution [`Timeline`] (DMA / compute / store / host
//!   occupancy per target segment), behind `tvm-accel profile`.
//!
//! The hard invariant: everything here is *passive*. Nothing in this
//! module feeds back into cache keys, schedule selection, or codegen —
//! a traced compile is byte-identical to an untraced one
//! (`tests/obs_passive.rs`), and golden program hashes do not move when
//! tracing is enabled.
//!
//! This module also carries the human-readable reporting helpers that
//! previously lived in `metrics/` (Table-2 rendering, one-line run
//! summaries).

pub mod chrome;
pub mod prom;
pub mod span;
pub mod timeline;

pub use chrome::{ChromeTrace, Event};
pub use prom::{Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};
pub use span::{Span, SpanId, Trace};
pub use timeline::{Slice, Timeline, Track};

use crate::sim::report::RunReport;
use crate::util::table::{commafy, Table};

/// Append one trace's spans to `ct` on `(pid, tid)`: spans with real
/// duration become `ph:"X"` complete slices (properly nested, since the
/// recorder closes children before parents), zero-width spans become
/// `ph:"i"` instants. Span attributes travel as slice `args`.
/// Nanosecond timestamps map to Chrome's microsecond `ts`.
pub fn spans_to_chrome(ct: &mut ChromeTrace, pid: u64, tid: u64, spans: &[Span]) {
    for s in spans {
        let ts = s.start_ns as f64 / 1000.0;
        let args: Vec<(String, String)> =
            s.attrs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
        if s.end_ns > s.start_ns {
            ct.complete(pid, tid, s.name, ts, (s.end_ns - s.start_ns) as f64 / 1000.0, args);
        } else {
            ct.instant(pid, tid, s.name, ts, args);
        }
    }
}

/// Thread ids `timeline_to_chrome` assigns to the hardware tracks.
pub const TRACK_TIDS: [(Track, u64); 4] =
    [(Track::Dma, 1), (Track::Compute, 2), (Track::Store, 3), (Track::Host, 4)];

/// Append one execution timeline to `ct` as process `pid`, one named
/// thread per hardware track (1 simulated cycle = 1 µs, so the timeline
/// is legible regardless of clock frequency).
pub fn timeline_to_chrome(ct: &mut ChromeTrace, pid: u64, tl: &Timeline) {
    for (track, tid) in TRACK_TIDS {
        ct.thread_name(pid, tid, track.name());
    }
    for s in &tl.slices {
        let tid = TRACK_TIDS
            .iter()
            .find(|(t, _)| *t == s.track)
            .map(|(_, tid)| *tid)
            .unwrap_or(1);
        ct.complete(pid, tid, s.name, s.start as f64, (s.end - s.start) as f64, Vec::new());
    }
}

/// One Table-2-style row: a workload and its latency under each backend.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    /// Workload label, e.g. `(64, 64, 64)`.
    pub workload: String,
    /// Cycles under the vendor C toolchain baseline.
    pub c_toolchain: u64,
    /// Cycles under the naive BYOC/UMA-style baseline.
    pub byoc_uma: u64,
    /// Cycles under the proposed integration flow.
    pub proposed: u64,
}

/// Render rows in the layout of the paper's Table 2.
pub fn table2(rows: &[LatencyRow]) -> Table {
    let mut t = Table::new("Table 2: Deployment results — Latency (Cycles)").header(&[
        "Workload",
        "C-based Toolchain",
        "Proposed",
        "BYOC/UMA Backend",
        "BYOC/Proposed",
    ]);
    for r in rows {
        t.row(vec![
            r.workload.clone(),
            commafy(r.c_toolchain),
            commafy(r.proposed),
            commafy(r.byoc_uma),
            format!("{:.2}x", r.byoc_uma as f64 / r.proposed as f64),
        ]);
    }
    t
}

/// One-line textual summary of a run report, including the
/// data-movement counters (`dram_transfer_cycles`, `input_stage_cycles`)
/// the cross-layer and double-buffering optimizations act on. Multi-target
/// runs (nonzero [`RunReport::overlapped_cycles`]) additionally show the
/// overlapped makespan next to the serial total.
pub fn describe(name: &str, rep: &RunReport, pe_dim: usize) -> String {
    let overlap = if rep.overlapped_cycles > 0 {
        format!(" (overlapped {})", commafy(rep.overlapped_cycles))
    } else {
        String::new()
    };
    format!(
        "{name}: {} cycles{overlap} (host {}), util {:.1}%, dram {}/{} B ({} xfer cyc), \
         staged-in {} cyc, {} cmds",
        commafy(rep.cycles),
        commafy(rep.host_cycles),
        rep.utilization(pe_dim) * 100.0,
        commafy(rep.dram_read_bytes),
        commafy(rep.dram_write_bytes),
        commafy(rep.dram_transfer_cycles),
        commafy(rep.input_stage_cycles),
        commafy(rep.issued_commands),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_formats_ratio() {
        let rows = vec![LatencyRow {
            workload: "(64, 64, 64)".into(),
            c_toolchain: 69_994,
            byoc_uma: 160_163,
            proposed: 69_995,
        }];
        let t = table2(&rows);
        let s = t.render();
        assert!(s.contains("2.29x"));
        assert!(s.contains("160,163"));
    }

    #[test]
    fn spans_and_timelines_export_to_chrome_events() {
        let tr = Trace::new();
        let root = tr.begin("compile");
        tr.instant("cache_hit", vec![("shape", "8x8x8".into())]);
        tr.end(root, vec![]);
        let mut ct = ChromeTrace::new();
        spans_to_chrome(&mut ct, 1, 1, &tr.spans());
        let mut tl = Timeline::new();
        tl.push(Track::Dma, "mvin", 0, 10);
        tl.push(Track::Host, "host.memcpy", 12, 20);
        timeline_to_chrome(&mut ct, 2, &tl);
        let json = ct.render();
        assert!(json.contains("\"name\":\"compile\""));
        assert!(json.contains("\"ph\":\"i\""), "cache_hit renders as an instant");
        assert!(json.contains("\"name\":\"mvin\""));
        assert!(json.contains("\"tid\":4"), "host track gets its own thread");
        assert!(json.contains("\"name\":\"thread_name\""));
    }

    #[test]
    fn describe_surfaces_data_movement_counters() {
        let rep = RunReport {
            cycles: 1000,
            dram_transfer_cycles: 321,
            input_stage_cycles: 45,
            ..RunReport::default()
        };
        let s = describe("w", &rep, 16);
        assert!(s.contains("321 xfer cyc"), "missing dram_transfer_cycles: {s}");
        assert!(s.contains("staged-in 45 cyc"), "missing input_stage_cycles: {s}");
        assert!(!s.contains("overlapped"), "single-target runs stay quiet: {s}");
        let multi = RunReport { overlapped_cycles: 800, ..rep };
        let s = describe("w", &multi, 16);
        assert!(s.contains("(overlapped 800)"), "overlapped makespan surfaced: {s}");
    }
}
